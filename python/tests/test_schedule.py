"""Schedule mirror: same invariants as rust/src/bulge/schedule.rs tests,
swept with hypothesis — and the element-disjointness property the whole
parallel design rests on."""

from hypothesis import given, settings, strategies as st

from compile.schedule import Stage, stage_plan


@given(bw0=st.integers(2, 128), tw=st.integers(1, 64))
def test_stage_plan_reaches_bidiagonal(bw0, tw):
    plan = stage_plan(bw0, tw)
    b = bw0
    for s in plan:
        assert s.b == b and 1 <= s.d <= s.b - 1
        b = s.b_out
    assert b == 1


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(8, 120),
    b=st.integers(2, 12),
    d_frac=st.floats(0.01, 1.0),
)
def test_every_task_fires_exactly_once(n, b, d_frac):
    d = max(1, min(b - 1, int(b * d_frac)))
    s = Stage(b, d)
    seen = set()
    for t in range(s.total_launches(n)):
        for (k, c, anchor, pivot) in s.tasks_at(n, t):
            assert (k, c) not in seen
            seen.add((k, c))
            assert t == 3 * k + c
            assert anchor <= n - 2
            assert pivot < anchor or (c == 0 and pivot == k)
    expect = sum(s.cmax(n, k) + 1 for k in range(s.num_sweeps(n)))
    assert len(seen) == expect
    assert s.tasks_at(n, s.total_launches(n)) == []


def _rects(stage, n, anchor, pivot):
    d, b = stage.d, stage.b
    right = (pivot, min(anchor + d, n - 1), anchor, min(anchor + d, n - 1))
    left = (anchor, min(anchor + d, n - 1), anchor, min(anchor + b + d, n - 1))
    return [right, left]


def _intersects(a, b):
    return a[0] <= b[1] and b[0] <= a[1] and a[2] <= b[3] and b[2] <= a[3]


@settings(max_examples=20, deadline=None)
@given(n=st.integers(16, 96), b=st.integers(2, 10))
def test_simultaneous_tasks_element_disjoint(n, b):
    # Includes the tight case d = b - 1 (paper §III-A, three-cycle rule).
    for d in {1, b // 2 or 1, b - 1}:
        s = Stage(b, d)
        for t in range(s.total_launches(n)):
            tasks = s.tasks_at(n, t)
            for i in range(len(tasks)):
                for j in range(i + 1, len(tasks)):
                    ra = _rects(s, n, tasks[i][2], tasks[i][3])
                    rb = _rects(s, n, tasks[j][2], tasks[j][3])
                    for x in ra:
                        for y in rb:
                            assert not _intersects(x, y), (
                                f"overlap t={t} b={b} d={d}: {tasks[i]} {tasks[j]}"
                            )


@given(n=st.integers(8, 2000), b=st.integers(2, 64))
def test_max_slots_bounds_actual_parallelism(n, b):
    d = max(1, b // 2)
    s = Stage(b, d)
    slots = s.max_slots(n)
    total = s.total_launches(n)
    # Sample a few launches plus the theoretical peak region.
    probe = set(range(0, total, max(1, total // 17))) | {total // 2}
    for t in probe:
        if t < total:
            assert len(s.tasks_at(n, t)) <= slots
