"""L2 model (cycle / stage / full reduction) vs the numpy banded oracle
and vs ground-truth singular values."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from compile.schedule import Stage, stage_plan


def random_storage(n, bw, tw, seed=0):
    rng = np.random.default_rng(seed)
    nb = ref.NumpyBanded.from_random(n, bw, tw, rng)
    return nb


def off_band_max(nb: ref.NumpyBanded, keep_super=1):
    dense = nb.to_dense()
    sub = np.abs(np.tril(dense, -1)).max(initial=0.0)
    sup = np.abs(np.triu(dense, keep_super + 1)).max(initial=0.0)
    return max(sub, sup)


def as_numpy_banded(arr, n, bw, tw):
    nb = ref.NumpyBanded(n, bw, tw)
    nb.data = np.asarray(arr, np.float64)
    return nb


def test_single_cycle_matches_numpy_oracle():
    n, bw, tw = 32, 6, 3
    stage = Stage(6, 3)
    nb = random_storage(n, bw, tw, seed=1)
    cycle = jax.jit(model.make_cycle_fn(n, bw, tw, stage, use_pallas=False))
    storage = jnp.asarray(nb.data, jnp.float32)
    # Walk the first launches and compare after each.
    oracle = ref.NumpyBanded(n, bw, tw)
    oracle.data = nb.data.copy()
    for t in range(12):
        storage = cycle(storage, t)
        for (k, c, anchor, pivot) in stage.tasks_at(n, t):
            ref.exec_cycle_numpy(oracle, stage, anchor, pivot)
        np.testing.assert_allclose(
            np.asarray(storage), oracle.data.astype(np.float32), rtol=3e-5, atol=3e-5,
            err_msg=f"t={t}",
        )


@settings(max_examples=8, deadline=None)
@given(
    n=st.integers(16, 48),
    bw=st.integers(2, 8),
    tw=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_full_reduction_reaches_bidiagonal(n, bw, tw, seed):
    tw = min(tw, bw - 1) if bw > 1 else 1
    if tw < 1:
        tw = 1
    nb = random_storage(n, bw, tw, seed=seed)
    storage = jnp.asarray(nb.data, jnp.float32)
    out = model.reduce_banded(storage, n, bw, tw, use_pallas=False)
    result = as_numpy_banded(out, n, bw, tw)
    assert off_band_max(result) < 5e-5, f"n={n} bw={bw} tw={tw}"


def test_full_reduction_preserves_singular_values():
    n, bw, tw = 40, 5, 2
    nb = random_storage(n, bw, tw, seed=3)
    sv0 = np.linalg.svd(nb.to_dense(), compute_uv=False)
    out = model.reduce_banded(jnp.asarray(nb.data, jnp.float32), n, bw, tw)
    result = as_numpy_banded(out, n, bw, tw)
    sv1 = np.linalg.svd(result.to_dense(), compute_uv=False)
    np.testing.assert_allclose(sv1, sv0, rtol=0, atol=2e-4 * sv0[0])


def test_pallas_and_ref_paths_agree():
    n, bw, tw = 36, 6, 3
    nb = random_storage(n, bw, tw, seed=4)
    s = jnp.asarray(nb.data, jnp.float32)
    a = model.reduce_banded(s, n, bw, tw, use_pallas=True)
    b = model.reduce_banded(s, n, bw, tw, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


def test_stage_fn_equals_cycle_loop():
    n, bw, tw = 28, 4, 2
    stage = stage_plan(bw, tw)[0]
    nb = random_storage(n, bw, tw, seed=5)
    s0 = jnp.asarray(nb.data, jnp.float32)
    # Fused whole-stage artifact path.
    fused = jax.jit(model.make_stage_fn(n, bw, tw, stage, use_pallas=False))(s0)
    # Per-cycle loop (what the Rust coordinator drives).
    cycle = jax.jit(model.make_cycle_fn(n, bw, tw, stage, use_pallas=False))
    s = s0
    for t in range(stage.total_launches(n)):
        s = cycle(s, t)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(s), rtol=0, atol=0)


def test_cycle_is_noop_before_and_after_schedule():
    # Launch index beyond the schedule: every slot is invalid -> identity.
    n, bw, tw = 24, 4, 2
    stage = stage_plan(bw, tw)[0]
    nb = random_storage(n, bw, tw, seed=6)
    s0 = jnp.asarray(nb.data, jnp.float32)
    cycle = jax.jit(model.make_cycle_fn(n, bw, tw, stage, use_pallas=False))
    out = cycle(s0, stage.total_launches(n) + 5)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(s0))


def test_norm_preserved_through_stages():
    n, bw, tw = 32, 6, 5
    nb = random_storage(n, bw, tw, seed=7)
    before = np.linalg.norm(nb.data)
    out = model.reduce_banded(jnp.asarray(nb.data, jnp.float32), n, bw, tw)
    after = np.linalg.norm(np.asarray(out))
    assert abs(before - after) < 1e-4 * before


def test_extract_bidiagonal_matches_dense():
    n, bw, tw = 24, 3, 2
    nb = random_storage(n, bw, tw, seed=8)
    out = model.reduce_banded(jnp.asarray(nb.data, jnp.float32), n, bw, tw)
    d, e = model.extract_bidiagonal(out, n, bw, tw)
    dense = as_numpy_banded(out, n, bw, tw).to_dense()
    np.testing.assert_allclose(np.asarray(d), np.diag(dense), atol=1e-6)
    np.testing.assert_allclose(np.asarray(e), np.diag(dense, 1), atol=1e-6)


@pytest.mark.parametrize("n,bw,tw", [(20, 2, 1), (33, 7, 6), (26, 5, 5)])
def test_edge_configurations(n, bw, tw):
    tw = min(tw, bw - 1) if bw > 1 else 1
    nb = random_storage(n, bw, tw, seed=9)
    out = model.reduce_banded(jnp.asarray(nb.data, jnp.float32), n, bw, tw)
    assert off_band_max(as_numpy_banded(out, n, bw, tw)) < 5e-5
