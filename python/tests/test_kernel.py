"""L1 Pallas kernels vs the pure-jnp oracle — the core correctness
signal of the compile path (hypothesis sweeps shapes and values)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bulge, ref

jax.config.update("jax_enable_x64", False)


def _random_tile(rng, rows, cols, scale=1.0):
    return jnp.asarray(rng.standard_normal((rows, cols)) * scale, jnp.float32)


@settings(max_examples=40, deadline=None)
@given(
    rows=st.integers(2, 24),
    d=st.integers(1, 8),
    tpb=st.sampled_from([4, 8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_right_kernel_matches_ref(rows, d, tpb, seed):
    rng = np.random.default_rng(seed)
    tile = _random_tile(rng, rows, d + 1)
    got = bulge.make_right_kernel(rows, d + 1, tpb)(tile)
    want = ref.right_tile_ref(tile)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@settings(max_examples=40, deadline=None)
@given(
    cols=st.integers(2, 24),
    d=st.integers(1, 8),
    tpb=st.sampled_from([4, 8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_left_kernel_matches_ref(cols, d, tpb, seed):
    rng = np.random.default_rng(seed)
    tile = _random_tile(rng, d + 1, cols)
    got = bulge.make_left_kernel(d + 1, cols, tpb)(tile)
    want = ref.left_tile_ref(tile)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_right_kernel_annihilates_pivot_row():
    rng = np.random.default_rng(7)
    tile = _random_tile(rng, 10, 5)
    out = np.asarray(bulge.make_right_kernel(10, 5)(tile))
    assert np.all(out[0, 1:] == 0.0), "pivot row tail must be exactly zero"
    # beta = -sign(alpha)*norm of the pivot row.
    norm = np.linalg.norm(np.asarray(tile)[0, :])
    assert abs(abs(out[0, 0]) - norm) < 1e-5 * max(norm, 1)


def test_left_kernel_annihilates_pivot_col():
    rng = np.random.default_rng(8)
    tile = _random_tile(rng, 5, 12)
    out = np.asarray(bulge.make_left_kernel(5, 12)(tile))
    assert np.all(out[1:, 0] == 0.0)
    norm = np.linalg.norm(np.asarray(tile)[:, 0])
    assert abs(abs(out[0, 0]) - norm) < 1e-5 * max(norm, 1)


@settings(max_examples=25, deadline=None)
@given(rows=st.integers(2, 16), d=st.integers(1, 6), seed=st.integers(0, 2**31 - 1))
def test_right_kernel_preserves_row_norms(rows, d, seed):
    # A right orthogonal transform preserves each row's 2-norm.
    rng = np.random.default_rng(seed)
    tile = _random_tile(rng, rows, d + 1)
    out = np.asarray(bulge.make_right_kernel(rows, d + 1)(tile))
    for i in range(rows):
        a = np.linalg.norm(np.asarray(tile)[i])
        b = np.linalg.norm(out[i])
        assert abs(a - b) <= 1e-4 * max(a, 1.0), f"row {i}: {a} vs {b}"


@settings(max_examples=25, deadline=None)
@given(cols=st.integers(2, 16), d=st.integers(1, 6), seed=st.integers(0, 2**31 - 1))
def test_left_kernel_preserves_col_norms(cols, d, seed):
    rng = np.random.default_rng(seed)
    tile = _random_tile(rng, d + 1, cols)
    out = np.asarray(bulge.make_left_kernel(d + 1, cols)(tile))
    for j in range(cols):
        a = np.linalg.norm(np.asarray(tile)[:, j])
        b = np.linalg.norm(out[:, j])
        assert abs(a - b) <= 1e-4 * max(a, 1.0), f"col {j}: {a} vs {b}"


def test_zero_tail_is_identity():
    # Already-annihilated bulge: tau = 0, tile untouched (the near-zero
    # guard of Alg. 2 / [11]).
    tile = jnp.zeros((6, 4), jnp.float32).at[0, 0].set(3.0).at[2, 1].set(1.5)
    out = np.asarray(bulge.make_right_kernel(6, 4)(tile))
    np.testing.assert_array_equal(out, np.asarray(tile))


def test_zero_tile_stays_zero():
    # Phantom/padding tiles must pass through untouched (the masking
    # mechanism of the L2 model relies on this).
    tile = jnp.zeros((9, 5), jnp.float32)
    out_r = np.asarray(bulge.make_right_kernel(9, 5)(tile))
    out_l = np.asarray(bulge.make_left_kernel(5, 9)(tile.T))
    assert np.all(out_r == 0.0) and np.all(out_l == 0.0)


def test_kernel_involution_on_other_rows():
    # Applying the same reflector twice returns the original (H² = I):
    # check via the ref oracle on the body rows.
    rng = np.random.default_rng(11)
    tile = _random_tile(rng, 8, 4)
    v, tau, _ = ref.householder(tile[0, :])
    body = tile[1:, :]
    once = body - tau * jnp.outer(body @ v, v)
    twice = once - tau * jnp.outer(once @ v, v)
    np.testing.assert_allclose(np.asarray(twice), np.asarray(body), rtol=1e-5, atol=1e-6)


def test_vmem_footprint_estimate():
    # Paper headline config (b=64, tw=32, fp32): ~12.8 KB per program,
    # comfortably inside VMEM.
    bytes_ = bulge.vmem_footprint_bytes(64, 32, 4)
    assert 12_000 < bytes_ < 14_000
    assert bulge.vmem_footprint_bytes(128, 32, 4) < 16 * 2**20


@pytest.mark.parametrize("dtype", [jnp.float32])
def test_kernels_are_cached(dtype):
    k1 = bulge.make_right_kernel(8, 4, 32, dtype)
    k2 = bulge.make_right_kernel(8, 4, 32, dtype)
    assert k1 is k2
