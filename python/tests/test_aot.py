"""AOT emission: HLO text artifacts parse, have the right parameter
signature, and the manifest matches the schedule."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.schedule import stage_plan


def test_emit_variant_writes_expected_files(tmp_path):
    out = str(tmp_path)
    paths = aot.emit_variant(out, n=64, bw=4, tw=2, verbose=False)
    plan = stage_plan(4, 2)
    # cycle + fused per stage, plus the manifest.
    assert len(paths) == 2 * len(plan) + 1
    for p in paths:
        full = os.path.join(out, p)
        assert os.path.exists(full), p
        assert os.path.getsize(full) > 0, p


def test_manifest_contents(tmp_path):
    out = str(tmp_path)
    aot.emit_variant(out, n=64, bw=4, tw=2, verbose=False)
    text = open(os.path.join(out, "manifest_n64_bw4_tw2.txt")).read()
    assert "n=64" in text and "bw=4" in text and "tw=2" in text
    kd_super, kd_sub, ld = model.storage_dims(4, 2)
    assert f"ld={ld}" in text and f"kd_super={kd_super}" in text
    plan = stage_plan(4, 2)
    for i, st in enumerate(plan):
        assert f"stage index={i} b={st.b} d={st.d}" in text
        assert f"launches={st.total_launches(64)}" in text


def test_hlo_text_is_parseable_hlo(tmp_path):
    out = str(tmp_path)
    aot.emit_variant(out, n=48, bw=4, tw=2, fused=False, verbose=False)
    text = open(os.path.join(out, "cycle_n48_bw4_tw2_s0.hlo.txt")).read()
    assert text.startswith("HloModule"), text[:80]
    # Two parameters (storage f32[48, ld], t s32[]) and a tuple root.
    kd_super, kd_sub, ld = model.storage_dims(4, 2)
    assert f"f32[48,{ld}]" in text
    assert "s32[]" in text


def test_emitted_cycle_executes_like_model(tmp_path):
    # Round-trip through the lowering: execute the lowered/compiled cycle
    # via jax and compare with the eager model (same function object the
    # Rust runtime will run through PJRT).
    n, bw, tw = 48, 4, 2
    stage = stage_plan(bw, tw)[0]
    cycle = model.make_cycle_fn(n, bw, tw, stage)
    compiled = jax.jit(cycle).lower(
        jax.ShapeDtypeStruct((n, model.storage_dims(bw, tw)[2]), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32),
    ).compile()
    rng = np.random.default_rng(0)
    from compile.kernels import ref

    nb = ref.NumpyBanded.from_random(n, bw, tw, rng)
    s = jnp.asarray(nb.data, jnp.float32)
    got = compiled(s, jnp.int32(0))
    want = cycle(s, 0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_parse_variants():
    assert aot.parse_variants("256:8:4,96:6:3") == [(256, 8, 4), (96, 6, 3)]
