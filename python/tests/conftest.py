"""Test bootstrap: import path + hypothesis fallback.

- Puts ``python/`` on ``sys.path`` so ``from compile import ...`` works no
  matter where pytest is invoked from.
- If the real ``hypothesis`` package is unavailable (the offline container
  has no network), registers a tiny API-compatible fallback that drives the
  ``@given`` properties with deterministic pseudo-random examples. CI
  installs the real hypothesis, so the full shrinking/edge-case machinery
  still runs there; the fallback only keeps the suite *runnable* offline.
"""

import os
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - exercised only in offline containers
    from _hypothesis_fallback import install as _install_hypothesis_fallback

    _install_hypothesis_fallback()
