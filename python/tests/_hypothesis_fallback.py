"""Minimal, deterministic stand-in for the subset of the ``hypothesis``
API these tests use (``given``, ``settings``, ``strategies.integers``,
``strategies.floats``, ``strategies.sampled_from``).

The real hypothesis is preferred and is installed in CI; this fallback
exists so the suite still runs in offline environments where ``pip
install`` is unavailable. Examples are drawn from a seeded PRNG (so
failures are reproducible) and always include the boundary values, which
is where most schedule/kernel bugs live.
"""


import random
import types
import zlib


class _Strategy:
    def __init__(self, sample, boundaries=()):
        self._sample = sample
        self.boundaries = list(boundaries)

    def example(self, rng):
        return self._sample(rng)


def integers(min_value, max_value):
    return _Strategy(
        lambda rng: rng.randint(min_value, max_value),
        boundaries=[min_value, max_value],
    )


def floats(min_value, max_value, **_kwargs):
    return _Strategy(
        lambda rng: rng.uniform(min_value, max_value),
        boundaries=[min_value, max_value],
    )


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements), boundaries=elements[:1])


class settings:  # noqa: N801 - mirrors the hypothesis name
    def __init__(self, max_examples=40, deadline=None, **_kwargs):
        self.max_examples = max_examples
        self.deadline = deadline

    def __call__(self, fn):
        fn._fallback_settings = self
        return fn


def given(**strategies):
    def decorate(fn):
        inner = fn

        def wrapper():
            # @settings may sit above @given (decorating this wrapper) or
            # below it (decorating the test function) — honour both.
            cfg = (
                getattr(wrapper, "_fallback_settings", None)
                or getattr(inner, "_fallback_settings", None)
                or settings()
            )
            # str hashes are salted per process; crc32 keeps the PRNG seed
            # stable across runs so falsifying examples can be replayed.
            rng = random.Random(0xB5BD5EED ^ zlib.crc32(inner.__name__.encode()))
            names = list(strategies)
            # First examples: all-lower and all-upper boundary corners.
            corners = []
            for pick in (0, -1):
                corner = {}
                ok = True
                for name in names:
                    bounds = strategies[name].boundaries
                    if not bounds:
                        ok = False
                        break
                    corner[name] = bounds[pick]
                if ok:
                    corners.append(corner)
            cases = corners + [
                {name: strategies[name].example(rng) for name in names}
                for _ in range(max(1, cfg.max_examples - len(corners)))
            ]
            for case in cases:
                try:
                    inner(**case)
                except Exception as e:  # noqa: BLE001 - re-raise with the case
                    raise AssertionError(
                        f"falsifying example (fallback hypothesis): {case}"
                    ) from e

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return decorate


def install():
    """Register the fallback as ``hypothesis`` / ``hypothesis.strategies``."""
    import sys

    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.floats = floats
    st_mod.sampled_from = sampled_from

    hyp_mod = types.ModuleType("hypothesis")
    hyp_mod.given = given
    hyp_mod.settings = settings
    hyp_mod.strategies = st_mod
    hyp_mod.__is_fallback__ = True

    sys.modules["hypothesis"] = hyp_mod
    sys.modules["hypothesis.strategies"] = st_mod
