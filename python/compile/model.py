"""L2 — JAX model of the bulge-chasing reduction over banded storage.

Builds the functions that ``aot.py`` lowers to HLO text for the Rust
coordinator:

- ``make_cycle_fn(n, stage)``   — (storage, t) -> storage: one kernel
  launch (all live sweeps at global cycle t), the unit the L3 launch loop
  drives through PJRT.
- ``make_stage_fn(n, stage)``   — storage -> storage: a whole bandwidth
  stage as a ``lax.fori_loop`` over global cycles (the fused perf path:
  one PJRT call per stage).
- ``reduce_banded(storage, n, bw, tw)`` — full reduction (build-time /
  test convenience).

Storage: (n, ld) row-major with ``S[j, kd_super + i - j] = A[i, j]``
(kd_super = bw0 + tw, ld = bw0 + 2·tw + 1) — bit-identical layout to the
Rust ``Banded`` flat buffer, so literals cross the PJRT boundary without
reshuffling. The matrix is padded with ``3·b`` zero columns at trace time
so every gather/scatter is statically in bounds; phantom elements stay
zero under the transforms (a Householder reflector of a zero tail is the
identity), which subsumes all edge clamping — same argument as DESIGN.md
§3.

The slot loop covers ``stage.max_slots(n)`` concurrent sweeps; anchors
are computed analytically from (t, slot) exactly as in
``rust/src/bulge/schedule.rs``. Inactive slots degenerate to gathers of
zero tiles (identity ops) via masking of the anchor into the pad region.
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax

from compile.kernels import bulge as kernels
from compile.kernels import ref
from compile.schedule import Stage, stage_plan


def storage_dims(bw0: int, tw: int):
    """(kd_super, kd_sub, ld) for a reduction with these parameters."""
    kd_super = bw0 + tw
    kd_sub = tw
    return kd_super, kd_sub, kd_super + kd_sub + 1


def _gather_right(storage, kd_super, pivot, anchor, rows, d1):
    """Gather the right-op tile: rows pivot..pivot+rows-1 of columns
    anchor..anchor+d. Column jj of the tile is a contiguous slice of
    storage row (anchor+jj)."""
    cols = []
    for jj in range(d1):
        col = anchor + jj
        off = kd_super + pivot - col
        seg = lax.dynamic_slice(storage, (col, off), (1, rows))
        cols.append(seg[0])
    return jnp.stack(cols, axis=1)  # (rows, d1)


def _scatter_right(storage, kd_super, pivot, anchor, tile):
    rows, d1 = tile.shape
    for jj in range(d1):
        col = anchor + jj
        off = kd_super + pivot - col
        storage = lax.dynamic_update_slice(storage, tile[None, :, jj], (col, off))
    return storage


def _gather_left(storage, kd_super, anchor, d1, cols):
    """Gather the left-op tile: rows anchor..anchor+d of columns
    anchor..anchor+cols-1."""
    segs = []
    for jj in range(cols):
        col = anchor + jj
        off = kd_super + anchor - col
        seg = lax.dynamic_slice(storage, (col, off), (1, d1))
        segs.append(seg[0])
    return jnp.stack(segs, axis=1)  # (d1, cols)


def _scatter_left(storage, kd_super, anchor, tile):
    d1, cols = tile.shape
    for jj in range(cols):
        col = anchor + jj
        off = kd_super + anchor - col
        storage = lax.dynamic_update_slice(storage, tile[None, :, jj], (col, off))
    return storage


def make_cycle_fn(n: int, bw0: int, tw: int, stage: Stage, *, tpb: int = 32,
                  use_pallas: bool = True):
    """Build the per-launch function (storage, t) -> storage.

    ``storage`` is the unpadded (n, ld) array; padding is applied and
    stripped inside (XLA fuses it away across the fori_loop in the fused
    stage variant).
    """
    kd_super, _, ld = storage_dims(bw0, tw)
    b, d = stage.b, stage.d
    rows_r = 1 + b + d      # right-op tile height (pivot + b+d rows)
    d1 = d + 1
    cols_l = 1 + b + d      # left-op tile width
    pad_cols = 3 * b + d + 2  # pad columns so all slices stay in bounds
    # Cycle-0 right tiles overrun the band depth by up to d rows (the
    # Rust executor clamps instead); pad the ld axis so those phantom
    # cells exist, hold zeros, and stay zero (reflector linearity).
    pad_ld = d
    slots = max(stage.max_slots(n), 1)
    ns = stage.num_sweeps(n)

    if use_pallas:
        right_k = kernels.make_right_kernel(rows_r, d1, tpb)
        left_k = kernels.make_left_kernel(d1, cols_l, tpb)
    else:
        right_k = ref.right_tile_ref
        left_k = ref.left_tile_ref

    def one_slot(s, carry):
        storage, t = carry
        # Schedule arithmetic (mirrors schedule.rs::tasks_at).
        k = t // 3 - s
        c = t - 3 * k
        cmax = (n - 2 - (k + (b - d))) // b
        valid = (k >= 0) & (k < ns) & (c >= 0) & (c <= cmax)
        anchor_real = k + (b - d) + c * b
        pivot_real = jnp.where(c == 0, k, anchor_real - b)
        # Inactive slots are routed into the zero-pad region: the ops
        # become exact identities on zeros.
        anchor = jnp.where(valid, anchor_real, n + d)
        pivot = jnp.where(valid, pivot_real, n + d)
        # Right op.
        tile = _gather_right(storage, kd_super, pivot, anchor, rows_r, d1)
        tile = right_k(tile)
        storage = _scatter_right(storage, kd_super, pivot, anchor, tile)
        # Left op.
        tile = _gather_left(storage, kd_super, anchor, d1, cols_l)
        tile = left_k(tile)
        storage = _scatter_left(storage, kd_super, anchor, tile)
        return storage, t

    def cycle(storage, t):
        assert storage.shape == (n, ld), (storage.shape, (n, ld))
        t = jnp.asarray(t, jnp.int32)
        padded = jnp.pad(storage, ((0, pad_cols), (0, pad_ld)))
        padded, _ = lax.fori_loop(0, slots, one_slot, (padded, t))
        return padded[:n, :ld]

    return cycle


def make_stage_fn(n: int, bw0: int, tw: int, stage: Stage, *, tpb: int = 32,
                  use_pallas: bool = True):
    """Whole-stage function storage -> storage (fori_loop over launches)."""
    cycle = make_cycle_fn(n, bw0, tw, stage, tpb=tpb, use_pallas=use_pallas)
    total = stage.total_launches(n)

    def stage_fn(storage):
        return lax.fori_loop(
            0, total, lambda t, s: cycle(s, t), storage
        )

    return stage_fn


def reduce_banded(storage, n: int, bw: int, tw: int, *, tpb: int = 32,
                  use_pallas: bool = True, jit: bool = True):
    """Full banded -> bidiagonal reduction of an (n, ld) storage array."""
    for stage in stage_plan(bw, tw):
        fn = make_stage_fn(n, bw, tw, stage, tpb=tpb, use_pallas=use_pallas)
        if jit:
            fn = jax.jit(fn)
        storage = fn(storage)
    return storage


def extract_bidiagonal(storage, n: int, bw0: int, tw: int):
    """(diag, superdiag) from an (n, ld) storage array."""
    kd_super, _, _ = storage_dims(bw0, tw)
    d = storage[jnp.arange(n), jnp.full(n, kd_super)]
    e = storage[jnp.arange(1, n), jnp.full(n - 1, kd_super - 1)]
    return d, e
