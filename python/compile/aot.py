"""AOT compile path: lower the L2 cycle/stage functions to HLO **text**
for the Rust PJRT runtime.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
XLA (xla_extension 0.5.1) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts per (n, bw, tw) variant, one pair per bandwidth stage:

- ``cycle_n{n}_bw{bw}_tw{tw}_s{i}.hlo.txt``  — (storage, t) -> storage,
  one kernel launch; the L3 coordinator drives the launch loop.
- ``stage_n{n}_bw{bw}_tw{tw}_s{i}.hlo.txt``  — storage -> storage, the
  fused whole-stage fori_loop (one PJRT call per stage; the perf path).
- ``manifest_n{n}_bw{bw}_tw{tw}.txt``        — layout + stage metadata
  the Rust runtime parses (simple ``key=value`` lines).

Usage:
    python -m compile.aot --out-dir ../artifacts \
        --variants 256:8:4,128:6:3
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.schedule import stage_plan


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (the only interchange
    the 0.5.1-era text parser accepts)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    # return_tuple=False: a bare-array root lets the Rust side chain the
    # output buffer straight into the next launch (no tuple unwrap).
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def emit_variant(out_dir: str, n: int, bw: int, tw: int, tpb: int = 32,
                 fused: bool = True, verbose: bool = True):
    """Emit all artifacts for one (n, bw, tw) variant. Returns paths."""
    kd_super, kd_sub, ld = model.storage_dims(bw, tw)
    plan = stage_plan(bw, tw)
    os.makedirs(out_dir, exist_ok=True)
    tag = f"n{n}_bw{bw}_tw{tw}"
    paths = []
    manifest = [
        "version=1",
        f"n={n}",
        f"bw={bw}",
        f"tw={tw}",
        f"ld={ld}",
        f"kd_super={kd_super}",
        f"kd_sub={kd_sub}",
        "dtype=f32",
        f"tpb={tpb}",
        f"stages={len(plan)}",
    ]
    storage_spec = jax.ShapeDtypeStruct((n, ld), jnp.float32)
    t_spec = jax.ShapeDtypeStruct((), jnp.int32)
    for i, stage in enumerate(plan):
        cycle = model.make_cycle_fn(n, bw, tw, stage, tpb=tpb)
        cycle_name = f"cycle_{tag}_s{i}.hlo.txt"
        lowered = jax.jit(cycle).lower(storage_spec, t_spec)
        text = to_hlo_text(lowered)
        with open(os.path.join(out_dir, cycle_name), "w") as f:
            f.write(text)
        paths.append(cycle_name)

        stage_name = ""
        if fused:
            stage_fn = model.make_stage_fn(n, bw, tw, stage, tpb=tpb)
            stage_name = f"stage_{tag}_s{i}.hlo.txt"
            lowered = jax.jit(stage_fn).lower(storage_spec)
            with open(os.path.join(out_dir, stage_name), "w") as f:
                f.write(to_hlo_text(lowered))
            paths.append(stage_name)

        manifest.append(
            f"stage index={i} b={stage.b} d={stage.d} "
            f"launches={stage.total_launches(n)} slots={stage.max_slots(n)} "
            f"cycle={cycle_name} fused={stage_name}"
        )
        if verbose:
            print(f"  stage {i}: b={stage.b} d={stage.d} "
                  f"launches={stage.total_launches(n)} -> {cycle_name}"
                  + (f", {stage_name}" if stage_name else ""))
    man_name = f"manifest_{tag}.txt"
    with open(os.path.join(out_dir, man_name), "w") as f:
        f.write("\n".join(manifest) + "\n")
    paths.append(man_name)
    if verbose:
        print(f"  wrote {man_name}")
    return paths


def parse_variants(spec: str):
    out = []
    for part in spec.split(","):
        n, bw, tw = (int(x) for x in part.strip().split(":"))
        out.append((n, bw, tw))
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--variants",
        default="256:8:4,96:6:3",
        help="comma-separated n:bw:tw variants to compile",
    )
    ap.add_argument("--tpb", type=int, default=32)
    ap.add_argument("--no-fused", action="store_true",
                    help="skip the fused whole-stage artifacts")
    # Back-compat with the scaffold Makefile (--out file): treat as a
    # marker file written after the variant set builds.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    out_dir = args.out_dir
    if args.out:
        out_dir = os.path.dirname(args.out) or "."
    for n, bw, tw in parse_variants(args.variants):
        print(f"variant n={n} bw={bw} tw={tw}")
        emit_variant(out_dir, n, bw, tw, tpb=args.tpb, fused=not args.no_fused)
    if args.out:
        with open(args.out, "w") as f:
            f.write("ok\n")
    print(f"artifacts in {os.path.abspath(out_dir)}")


if __name__ == "__main__":
    main()
