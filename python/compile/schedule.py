"""Bulge-chasing schedule — exact Python mirror of ``rust/src/bulge/schedule.rs``.

Used by the L2 model (to size slot counts and loop bounds at trace time),
by ``aot.py`` (to enumerate stage artifacts), and by the tests (to check
the Pallas/JAX path executes exactly the schedule the Rust coordinator
expects).
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class Stage:
    """One bandwidth-reduction stage: b -> b - d."""

    b: int
    d: int

    def __post_init__(self):
        assert self.b >= 2, f"stage needs bandwidth >= 2 (got {self.b})"
        assert 1 <= self.d <= self.b - 1, f"need 1 <= d <= b-1 (b={self.b}, d={self.d})"

    @property
    def b_out(self) -> int:
        return self.b - self.d

    def num_sweeps(self, n: int) -> int:
        return max(0, (n - 1) - self.b_out)

    def anchor(self, k: int, c: int) -> int:
        return k + self.b_out + c * self.b

    def cmax(self, n: int, k: int) -> int:
        assert k < self.num_sweeps(n)
        return (n - 2 - self.anchor(k, 0)) // self.b

    def pivot_row(self, k: int, c: int) -> int:
        return k if c == 0 else self.anchor(k, c - 1)

    def total_launches(self, n: int) -> int:
        ns = self.num_sweeps(n)
        if ns == 0:
            return 0
        return 3 * (ns - 1) + self.cmax(n, ns - 1) + 1

    def tasks_at(self, n: int, t: int):
        """(sweep, cycle, anchor, pivot) tuples live at global cycle t."""
        ns = self.num_sweeps(n)
        out = []
        if ns == 0:
            return out
        k_hi = min(t // 3, ns - 1)
        c0 = self.cmax(n, 0)
        k_lo = (t - c0 + 2) // 3 if t > c0 else 0
        for k in range(max(k_lo, 0), k_hi + 1):
            c = t - 3 * k
            if 0 <= c <= self.cmax(n, k):
                out.append((k, c, self.anchor(k, c), self.pivot_row(k, c)))
        return out

    def max_slots(self, n: int) -> int:
        """Maximum simultaneous tasks over the whole stage (static slot
        count for the L2 kernel)."""
        ns = self.num_sweeps(n)
        if ns == 0:
            return 0
        # Peak parallelism = ceil((cmax(0)+1)/3) bounded by sweeps.
        return min(ns, self.cmax(n, 0) // 3 + 1)


def stage_plan(bw0: int, tw: int):
    """Successive band reduction plan: consume min(tw, b-1) per stage."""
    assert tw >= 1
    plan = []
    b = bw0
    while b > 1:
        d = min(tw, b - 1)
        plan.append(Stage(b, d))
        b -= d
    return plan
