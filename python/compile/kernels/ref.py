"""Pure-jnp / numpy correctness oracles for the L1 Pallas kernels.

Two layers of reference:

- ``householder``, ``right_tile_ref``, ``left_tile_ref`` — jnp oracles for
  the tile kernels (the unit the Pallas kernels are tested against).
- ``NumpyBanded`` + ``exec_cycle_numpy`` — a plain-numpy port of the Rust
  cycle executor on banded storage, used to validate the full L2 cycle /
  stage functions end to end.

Storage convention (shared with the Rust side and the AOT artifacts):
column-major banded — a (n, ld) row-major array ``S`` with
``S[j, kd_super + i - j] = A[i, j]``; a column segment of A is contiguous
along axis 1.
"""

import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------
# jnp tile oracles
# --------------------------------------------------------------------------

def householder(x):
    """LAPACK larfg-style reflector of vector x (jnp).

    Returns (v, tau, beta) with v[0] = 1 such that
    (I - tau v v^T) x = (beta, 0, ..., 0). tau = 0 when the tail is zero.
    """
    alpha = x[0]
    tail = x[1:]
    ssq = jnp.sum(tail * tail)
    norm = jnp.sqrt(alpha * alpha + ssq)
    beta = jnp.where(alpha >= 0, -norm, norm)
    safe = ssq > 0
    denom = jnp.where(safe, alpha - beta, 1.0)
    v = jnp.concatenate([jnp.ones((1,), x.dtype), tail / denom])
    tau = jnp.where(safe, (beta - alpha) / jnp.where(beta == 0, 1.0, beta), 0.0)
    beta_out = jnp.where(safe, beta, alpha)
    return v, tau.astype(x.dtype), beta_out.astype(x.dtype)


def right_tile_ref(tile):
    """Right op on a gathered tile (rows, d+1): row 0 is the pivot row.

    Annihilates tile[0, 1:] into tile[0, 0] and applies the reflector from
    the right to every other row. Matches ``exec_right`` in Rust.
    """
    v, tau, beta = householder(tile[0, :])
    w = tile @ v  # (rows,)
    out = tile - tau * jnp.outer(w, v)
    d1 = tile.shape[1]
    row0 = jnp.where(jnp.arange(d1) == 0, beta, jnp.zeros((), tile.dtype))
    return out.at[0, :].set(jnp.where(tau != 0, row0, tile[0, :]))


def left_tile_ref(tile):
    """Left op on a gathered tile (d+1, cols): column 0 is the pivot
    column. Matches ``exec_left`` in Rust."""
    v, tau, beta = householder(tile[:, 0])
    w = v @ tile  # (cols,)
    out = tile - tau * jnp.outer(v, w)
    d1 = tile.shape[0]
    col0 = jnp.where(jnp.arange(d1) == 0, beta, jnp.zeros((), tile.dtype))
    return out.at[:, 0].set(jnp.where(tau != 0, col0, tile[:, 0]))


# --------------------------------------------------------------------------
# numpy banded-cycle oracle (port of rust/src/bulge/cycle.rs)
# --------------------------------------------------------------------------

class NumpyBanded:
    """Banded storage mirroring rust Banded<T>: (n, ld) row-major."""

    def __init__(self, n, bw, tw, dtype=np.float64):
        self.n = n
        self.kd_super = bw + tw
        self.kd_sub = tw
        self.ld = self.kd_super + self.kd_sub + 1
        self.data = np.zeros((n, self.ld), dtype=dtype)

    def in_band(self, i, j):
        return 0 <= i < self.n and 0 <= j < self.n and \
            j + self.kd_sub >= i and i + self.kd_super >= j

    def get(self, i, j):
        if not self.in_band(i, j):
            return 0.0
        return self.data[j, self.kd_super + i - j]

    def set(self, i, j, v):
        assert self.in_band(i, j), (i, j)
        self.data[j, self.kd_super + i - j] = v

    def to_dense(self):
        out = np.zeros((self.n, self.n), dtype=self.data.dtype)
        for j in range(self.n):
            lo = max(0, j - self.kd_super)
            hi = min(self.n - 1, j + self.kd_sub)
            for i in range(lo, hi + 1):
                out[i, j] = self.get(i, j)
        return out

    @staticmethod
    def from_random(n, bw, tw, rng):
        b = NumpyBanded(n, bw, tw)
        for i in range(n):
            for j in range(i, min(i + bw, n - 1) + 1):
                b.set(i, j, rng.standard_normal())
        return b


def _np_householder(x):
    alpha = x[0]
    ssq = float(np.sum(x[1:] * x[1:]))
    if ssq == 0.0:
        return None, 0.0, alpha
    norm = np.sqrt(alpha * alpha + ssq)
    beta = -norm if alpha >= 0 else norm
    tau = (beta - alpha) / beta
    v = np.concatenate([[1.0], x[1:] / (alpha - beta)])
    return v, tau, beta


def exec_cycle_numpy(a: NumpyBanded, stage, anchor: int, pivot: int):
    """One bulge-chasing cycle (right + left op) on NumpyBanded."""
    n, d, b = a.n, stage.d, stage.b
    j0 = anchor
    jd = min(j0 + d, n - 1)
    dd = jd - j0
    if dd == 0:
        return
    # Right op.
    x = np.array([a.get(pivot, j0 + jj) for jj in range(dd + 1)])
    v, tau, beta = _np_householder(x)
    if tau != 0.0:
        a.set(pivot, j0, beta)
        for jj in range(1, dd + 1):
            a.set(pivot, j0 + jj, 0.0)
        r0, r1 = pivot + 1, jd
        if r0 <= r1:
            rows = np.array(
                [[a.get(i, j0 + jj) for jj in range(dd + 1)] for i in range(r0, r1 + 1)]
            )
            w = tau * (rows @ v)
            rows -= np.outer(w, v)
            for ii, i in enumerate(range(r0, r1 + 1)):
                for jj in range(dd + 1):
                    a.set(i, j0 + jj, rows[ii, jj])
    # Left op.
    i1 = min(j0 + d, n - 1)
    dd = i1 - j0
    if dd == 0:
        return
    x = np.array([a.get(j0 + ii, j0) for ii in range(dd + 1)])
    v, tau, beta = _np_householder(x)
    if tau == 0.0:
        return
    a.set(j0, j0, beta)
    for ii in range(1, dd + 1):
        a.set(j0 + ii, j0, 0.0)
    c1 = min(j0 + b + d, n - 1)
    for col in range(j0 + 1, c1 + 1):
        seg = np.array([a.get(j0 + ii, col) for ii in range(dd + 1)])
        cfac = tau * (v @ seg)
        seg -= cfac * v
        for ii in range(dd + 1):
            a.set(j0 + ii, col, seg[ii])


def reduce_numpy(a: NumpyBanded, plan):
    """Full sweep-major reduction (oracle for the L2 stage function)."""
    for stage in plan:
        ns = stage.num_sweeps(a.n)
        for k in range(ns):
            for c in range(stage.cmax(a.n, k) + 1):
                exec_cycle_numpy(a, stage, stage.anchor(k, c), stage.pivot_row(k, c))
