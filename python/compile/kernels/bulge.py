"""L1 — Pallas kernels for the bulge-chasing cycle (paper Algorithm 2).

Each kernel processes one gathered tile:

- ``right_tile_kernel``: tile (rows, d+1); row 0 is the pivot row whose
  trailing d elements are annihilated; the Householder reflector is
  computed cooperatively (the shared-memory vector of Alg. 2 lines 3-6)
  and applied to the remaining rows in TPB-sized chunks (lines 8-13).
- ``left_tile_kernel``: the column analog (line 15).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the CUDA
shared-memory vector maps to a VMEM-resident row; the per-thread register
row maps to the vector-lane accumulator of a chunk. ``interpret=True``
always — the CPU PJRT client cannot execute Mosaic custom-calls; on a
real TPU the same BlockSpec structure lowers to VMEM tiles.

VMEM footprint per program: (rows × (d+1) + (d+1)) elements — e.g.
(1+64+32)×33×4 B ≈ 12.8 KB for the paper's (b=64, tw=32) FP32 stage, far
inside a TPU core's ~16 MB VMEM; the MXU is not engaged (rank-1 updates
are VPU work), so the roofline target is VPU/HBM bandwidth, mirroring the
paper's memory-bound analysis.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Chunk height mirroring the paper's threads-per-block loop (Alg. 2
# line 8): the apply walks the tile in TPB-row chunks.
DEFAULT_TPB = 32


def _householder_inline(x):
    """Reflector of x inside a kernel: returns (v, tau, beta)."""
    alpha = x[0]
    tail = x[1:]
    ssq = jnp.sum(tail * tail)
    norm = jnp.sqrt(alpha * alpha + ssq)
    beta = jnp.where(alpha >= 0, -norm, norm)
    safe = ssq > 0
    denom = jnp.where(safe, alpha - beta, jnp.ones((), x.dtype))
    v = jnp.concatenate([jnp.ones((1,), x.dtype), tail / denom])
    tau = jnp.where(safe, (beta - alpha) / jnp.where(beta == 0, 1.0, beta), 0.0)
    return v, tau.astype(x.dtype), jnp.where(safe, beta, alpha).astype(x.dtype)


def _right_kernel_body(tpb: int, tile_ref, out_ref):
    """Pallas kernel: right op on one (rows, d+1) tile."""
    tile = tile_ref[...]
    rows, d1 = tile.shape
    # --- cooperative reflector (Alg. 2 lines 3-6) ---
    v, tau, beta = _householder_inline(tile[0, :])
    # --- chunked apply (Alg. 2 lines 8-13) ---
    # Process the body rows in TPB-row chunks; each chunk computes its
    # dot products against the shared vector and updates in place. The
    # chunk loop is unrolled at trace time (static tile shape).
    n_chunks = -(-rows // tpb)
    updated = []
    for c in range(n_chunks):
        lo = c * tpb
        hi = min(lo + tpb, rows)
        chunk = tile[lo:hi, :]
        w = tau * (chunk @ v)
        updated.append(chunk - w[:, None] * v[None, :])
    body = jnp.concatenate(updated, axis=0)
    # Pivot row becomes (beta, 0, ..., 0) — exact zeros, like the Rust
    # executor; tau == 0 leaves the tile untouched.
    row0 = jnp.where(jnp.arange(d1) == 0, beta, jnp.zeros((), tile.dtype))
    result = body.at[0, :].set(row0)
    out_ref[...] = jnp.where(tau != 0, result, tile)


def _left_kernel_body(tpb: int, tile_ref, out_ref):
    """Pallas kernel: left op on one (d+1, cols) tile."""
    tile = tile_ref[...]
    d1, cols = tile.shape
    v, tau, beta = _householder_inline(tile[:, 0])
    n_chunks = -(-cols // tpb)
    updated = []
    for c in range(n_chunks):
        lo = c * tpb
        hi = min(lo + tpb, cols)
        chunk = tile[:, lo:hi]
        w = tau * (v @ chunk)
        updated.append(chunk - v[:, None] * w[None, :])
    body = jnp.concatenate(updated, axis=1)
    col0 = jnp.where(jnp.arange(d1) == 0, beta, jnp.zeros((), tile.dtype))
    result = body.at[:, 0].set(col0)
    out_ref[...] = jnp.where(tau != 0, result, tile)


@functools.lru_cache(maxsize=None)
def make_right_kernel(rows: int, d1: int, tpb: int = DEFAULT_TPB, dtype=jnp.float32):
    """Compiled (interpret-mode) right-op tile kernel for a static shape."""
    return pl.pallas_call(
        functools.partial(_right_kernel_body, tpb),
        out_shape=jax.ShapeDtypeStruct((rows, d1), dtype),
        interpret=True,
    )


@functools.lru_cache(maxsize=None)
def make_left_kernel(d1: int, cols: int, tpb: int = DEFAULT_TPB, dtype=jnp.float32):
    """Compiled (interpret-mode) left-op tile kernel for a static shape."""
    return pl.pallas_call(
        functools.partial(_left_kernel_body, tpb),
        out_shape=jax.ShapeDtypeStruct((d1, cols), dtype),
        interpret=True,
    )


def vmem_footprint_bytes(b: int, d: int, es: int = 4) -> int:
    """Estimated VMEM bytes per kernel program (tile + vector), used by
    the roofline discussion in DESIGN.md/EXPERIMENTS.md."""
    rows = 1 + b + d
    return (rows * (d + 1) + (d + 1)) * es
