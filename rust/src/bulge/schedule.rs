//! Bulge-chasing schedule (paper Algorithm 1 + §III-A).
//!
//! One *stage* reduces the upper bandwidth from `b` to `b − d` (d = inner
//! tilewidth). Within a stage, *sweep* k chases the fill created by
//! annihilating the last `d` in-band elements of row k; sweep k's cycle c
//! is anchored at column/row
//!
//! ```text
//!     j(k, c) = k + (b − d) + c·b
//! ```
//!
//! and consists of a **right** op (annihilate `d` row elements of the
//! pivot row into column `j`, creating a column bulge below `(j, j)`) and
//! a **left** op (annihilate the column bulge, creating the next row
//! bulge at `(j, j+b+1 .. j+b+d)`).
//!
//! The parallel schedule runs cycle `c = t − 3k` of every live sweep at
//! global cycle `t` — the paper's three-cycle separation. Element-level
//! disjointness of simultaneous tasks is proved by `access` rectangles and
//! enforced by property tests.

/// One bandwidth-reduction stage: `b → b − d`.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct Stage {
    /// Bandwidth at stage entry.
    pub b: usize,
    /// Inner tilewidth consumed by this stage (1 ≤ d ≤ b − 1).
    pub d: usize,
}

/// One bulge-chasing task: cycle `c` of sweep `k` (a right op followed by
/// a left op at the same anchor). Maps to one GPU thread block.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct CycleTask {
    pub sweep: usize,
    pub cycle: usize,
    /// Anchor column/row `j(k, c)`.
    pub anchor: usize,
    /// Row whose excess elements the right op annihilates
    /// (`k` for c = 0, else the previous anchor `j(k, c−1)`).
    pub pivot_row: usize,
}

/// Inclusive element rectangle `[row0..=row1] × [col0..=col1]`.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct Rect {
    pub row0: usize,
    pub row1: usize,
    pub col0: usize,
    pub col1: usize,
}

impl Rect {
    pub fn intersects(&self, o: &Rect) -> bool {
        self.row0 <= o.row1 && o.row0 <= self.row1 && self.col0 <= o.col1 && o.col0 <= self.col1
    }
}

impl Stage {
    pub fn new(b: usize, d: usize) -> Self {
        assert!(b >= 2, "stage needs bandwidth ≥ 2 (got {b})");
        assert!(d >= 1 && d <= b - 1, "need 1 ≤ d ≤ b−1 (b={b}, d={d})");
        Self { b, d }
    }

    /// Bandwidth after this stage completes.
    pub fn b_out(&self) -> usize {
        self.b - self.d
    }

    /// Number of sweeps for an n×n matrix: rows 0..n−1−(b−d) have excess
    /// elements to annihilate.
    pub fn num_sweeps(&self, n: usize) -> usize {
        (n - 1).saturating_sub(self.b_out())
    }

    /// Anchor column of sweep k, cycle c.
    #[inline]
    pub fn anchor(&self, k: usize, c: usize) -> usize {
        k + self.b_out() + c * self.b
    }

    /// Last valid cycle index of sweep k (anchors must stay ≤ n − 2).
    pub fn cmax(&self, n: usize, k: usize) -> usize {
        debug_assert!(k < self.num_sweeps(n));
        (n - 2 - self.anchor(k, 0)) / self.b
    }

    /// Build the task for (sweep k, cycle c).
    pub fn task(&self, k: usize, c: usize) -> CycleTask {
        CycleTask {
            sweep: k,
            cycle: c,
            anchor: self.anchor(k, c),
            pivot_row: if c == 0 { k } else { self.anchor(k, c - 1) },
        }
    }

    /// Total number of global cycles ("kernel launches") for the parallel
    /// schedule: the last sweep finishes at `t = 3·(ns−1) + cmax(ns−1)`.
    pub fn total_launches(&self, n: usize) -> usize {
        let ns = self.num_sweeps(n);
        if ns == 0 {
            return 0;
        }
        3 * (ns - 1) + self.cmax(n, ns - 1) + 1
    }

    /// Tasks live at global cycle `t` (paper: sweep k runs cycle t − 3k).
    /// Ordered by ascending sweep (descending anchor).
    pub fn tasks_at(&self, n: usize, t: usize) -> Vec<CycleTask> {
        let mut out = Vec::new();
        self.tasks_at_into(n, t, &mut out);
        out
    }

    /// Append the tasks of global cycle `t` to `out` (allocation-free
    /// materialization for the plan executor's reused launch buffers).
    pub fn tasks_at_into(&self, n: usize, t: usize, out: &mut Vec<CycleTask>) {
        let ns = self.num_sweeps(n);
        if ns == 0 {
            return;
        }
        // k must satisfy 3k ≤ t and t − 3k ≤ cmax(k).
        let k_hi = (t / 3).min(ns - 1);
        // cmax is non-increasing in k, so once t − 3k > cmax(0) we can
        // stop; bound the scan from below accordingly.
        let c0 = self.cmax(n, 0);
        let k_lo = if t > c0 { (t - c0 + 2) / 3 } else { 0 };
        for k in k_lo..=k_hi {
            let c = t - 3 * k;
            if c <= self.cmax(n, k) {
                out.push(self.task(k, c));
            }
        }
    }

    /// Number of tasks at global cycle `t`, in O(1) (closed form).
    ///
    /// `k` is live iff `0 ≤ t − 3k` and `t − 3k ≤ cmax(k)`. With
    /// `cmax(k) = ⌊(C0 − k)/b⌋`, `C0 = n − 2 − (b−d)`, and integer `c`,
    /// the second condition is `b(t − 3k) ≤ C0 − k`, i.e.
    /// `k ≥ ⌈(b·t − C0) / (3b − 1)⌉`.
    pub fn tasks_at_count(&self, n: usize, t: usize) -> usize {
        let ns = self.num_sweeps(n);
        if ns == 0 {
            return 0;
        }
        let k_hi = (t / 3).min(ns - 1) as i64;
        let b = self.b as i64;
        let c0 = (n as i64) - 2 - (self.b_out() as i64);
        let num = b * (t as i64) - c0;
        let den = 3 * b - 1;
        let k_lo = if num <= 0 { 0 } else { (num + den - 1) / den };
        (k_hi - k_lo + 1).max(0) as usize
    }

    /// Element rectangle read/written by the **right** op of a task: rows
    /// `pivot..min(anchor+d, n−1)`, columns `anchor..min(anchor+d, n−1)`.
    pub fn right_access(&self, task: &CycleTask, n: usize) -> Rect {
        Rect {
            row0: task.pivot_row,
            row1: (task.anchor + self.d).min(n - 1),
            col0: task.anchor,
            col1: (task.anchor + self.d).min(n - 1),
        }
    }

    /// Element rectangle read/written by the **left** op: rows
    /// `anchor..min(anchor+d, n−1)`, columns `anchor..min(anchor+b+d, n−1)`.
    pub fn left_access(&self, task: &CycleTask, n: usize) -> Rect {
        Rect {
            row0: task.anchor,
            row1: (task.anchor + self.d).min(n - 1),
            col0: task.anchor,
            col1: (task.anchor + self.b + self.d).min(n - 1),
        }
    }

    /// Combined footprint of the task (for dependency checks): union’s
    /// bounding rectangles are *not* used for disjointness — the property
    /// tests check the two precise rectangles pairwise.
    pub fn accesses(&self, task: &CycleTask, n: usize) -> [Rect; 2] {
        [self.right_access(task, n), self.left_access(task, n)]
    }
}

/// Successive band-reduction plan (paper Fig. 1): repeatedly consume
/// `min(tw, b−1)` diagonals until bidiagonal (bandwidth 1).
pub fn stage_plan(bw0: usize, tw: usize) -> Vec<Stage> {
    assert!(tw >= 1, "tilewidth must be ≥ 1");
    let mut plan = Vec::new();
    let mut b = bw0;
    while b > 1 {
        let d = tw.min(b - 1);
        plan.push(Stage::new(b, d));
        b -= d;
    }
    plan
}

/// Total tasks (thread blocks) across a full stage — used by the
/// simulator and the occupancy model.
pub fn stage_task_count(stage: &Stage, n: usize) -> usize {
    let ns = stage.num_sweeps(n);
    (0..ns).map(|k| stage.cmax(n, k) + 1).sum()
}

/// A problem's launch-ordered stream of ready cycle-tasks.
///
/// Walks a stage plan in schedule order — stage by stage, global cycle by
/// global cycle — yielding `(stage_index, tasks)` for every *non-empty*
/// launch. Launches must execute in stream order with a barrier between
/// them (launch `t+1` reads what launch `t` wrote); the tasks *within* one
/// yielded launch are pairwise element-disjoint and may run concurrently.
///
/// This is the unit the batch engine interleaves: each co-scheduled
/// problem contributes at most one launch of tasks per shared launch, so
/// per-problem ordering (and therefore bitwise results) is preserved no
/// matter how streams from different problems are packed together.
#[derive(Clone, Debug)]
pub struct TaskStream {
    plan: Vec<Stage>,
    n: usize,
    stage_idx: usize,
    t: usize,
    launches_emitted: usize,
}

impl TaskStream {
    /// Stream over an explicit stage plan for an n×n problem.
    pub fn new(plan: Vec<Stage>, n: usize) -> Self {
        let mut s = Self { plan, n, stage_idx: 0, t: 0, launches_emitted: 0 };
        s.settle();
        s
    }

    /// Stream for a bandwidth-`bw` problem reduced with tilewidth `tw`.
    pub fn for_problem(n: usize, bw: usize, tw: usize) -> Self {
        Self::new(stage_plan(bw, tw), n)
    }

    /// Advance the cursor to the next launch with at least one task (or to
    /// the end of the plan).
    fn settle(&mut self) {
        while self.stage_idx < self.plan.len() {
            let stage = &self.plan[self.stage_idx];
            let total = stage.total_launches(self.n);
            while self.t < total && stage.tasks_at_count(self.n, self.t) == 0 {
                self.t += 1;
            }
            if self.t < total {
                return;
            }
            self.stage_idx += 1;
            self.t = 0;
        }
    }

    /// True once every launch of every stage has been emitted.
    pub fn is_done(&self) -> bool {
        self.stage_idx >= self.plan.len()
    }

    pub fn plan(&self) -> &[Stage] {
        &self.plan
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Non-empty launches yielded so far.
    pub fn launches_emitted(&self) -> usize {
        self.launches_emitted
    }

    /// Task count of the next launch without advancing — O(1) via the
    /// closed-form count, so packing policies can bin-pack cheaply.
    pub fn peek_count(&self) -> usize {
        if self.is_done() {
            0
        } else {
            self.plan[self.stage_idx].tasks_at_count(self.n, self.t)
        }
    }

    /// Yield the next launch *symbolically*: `(stage index, global cycle,
    /// task count)`, without materializing the tasks. This is the unit the
    /// plan IR ([`crate::plan::LaunchPlan`]) is lowered from; executors
    /// materialize the tasks later with [`Stage::tasks_at`].
    pub fn next_slot(&mut self) -> Option<(usize, usize, usize)> {
        if self.is_done() {
            return None;
        }
        let si = self.stage_idx;
        let t = self.t;
        let count = self.plan[si].tasks_at_count(self.n, t);
        debug_assert!(count > 0, "settle() must skip empty launches");
        self.t += 1;
        self.launches_emitted += 1;
        self.settle();
        Some((si, t, count))
    }

    /// Yield the next launch: its stage index and its ready tasks
    /// (materialized form of [`TaskStream::next_slot`]).
    pub fn next_launch(&mut self) -> Option<(usize, Vec<CycleTask>)> {
        let (si, t, _) = self.next_slot()?;
        Some((si, self.plan[si].tasks_at(self.n, t)))
    }
}

impl Iterator for TaskStream {
    type Item = (usize, Vec<CycleTask>);

    fn next(&mut self) -> Option<Self::Item> {
        self.next_launch()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_plan_reaches_bidiagonal() {
        for (bw0, tw) in [(8, 4), (64, 32), (64, 48), (7, 3), (2, 1), (33, 32), (128, 16)] {
            let plan = stage_plan(bw0, tw);
            let mut b = bw0;
            for s in &plan {
                assert_eq!(s.b, b);
                assert!(s.d >= 1 && s.d <= s.b - 1);
                b = s.b_out();
            }
            assert_eq!(b, 1, "plan for bw0={bw0}, tw={tw} must end at 1");
        }
    }

    #[test]
    fn stage_plan_of_bidiagonal_is_empty() {
        assert!(stage_plan(1, 8).is_empty());
    }

    #[test]
    fn paper_table3_stage_counts() {
        // Paper profiles "reduction of the bandwidth from 64 to 32 or from
        // 64 to 48": tw=32 first stage consumes 32, tw=16 consumes 16.
        assert_eq!(stage_plan(64, 32)[0], Stage::new(64, 32));
        assert_eq!(stage_plan(64, 16)[0], Stage::new(64, 16));
    }

    #[test]
    fn stage_plan_lengths() {
        assert_eq!(stage_plan(64, 32).iter().map(|s| s.d).collect::<Vec<_>>(), vec![32, 31]);
        assert_eq!(stage_plan(8, 4).iter().map(|s| s.d).collect::<Vec<_>>(), vec![4, 3]);
        assert_eq!(
            stage_plan(16, 4).iter().map(|s| s.d).collect::<Vec<_>>(),
            vec![4, 4, 4, 3]
        );
    }

    #[test]
    fn anchors_advance_by_b() {
        let s = Stage::new(8, 4);
        let t0 = s.task(3, 0);
        let t1 = s.task(3, 1);
        assert_eq!(t0.anchor, 3 + 4);
        assert_eq!(t1.anchor, t0.anchor + 8);
        assert_eq!(t1.pivot_row, t0.anchor);
        assert_eq!(t0.pivot_row, 3);
    }

    #[test]
    fn every_task_appears_exactly_once_across_launches() {
        let n = 64;
        for (b, d) in [(8, 4), (4, 3), (6, 1), (2, 1)] {
            let s = Stage::new(b, d);
            let mut seen = std::collections::HashSet::new();
            for t in 0..s.total_launches(n) {
                for task in s.tasks_at(n, t) {
                    assert!(
                        seen.insert((task.sweep, task.cycle)),
                        "duplicate task {task:?} at t={t}"
                    );
                    assert_eq!(t, 3 * task.sweep + task.cycle);
                }
            }
            let expect: usize = (0..s.num_sweeps(n)).map(|k| s.cmax(n, k) + 1).sum();
            assert_eq!(seen.len(), expect, "b={b} d={d}");
            // And nothing fires after the last launch.
            assert!(s.tasks_at(n, s.total_launches(n)).is_empty());
        }
    }

    #[test]
    fn tasks_at_count_matches_materialized() {
        let n = 200;
        let s = Stage::new(10, 6);
        for t in 0..s.total_launches(n) + 3 {
            assert_eq!(s.tasks_at_count(n, t), s.tasks_at(n, t).len(), "t={t}");
        }
    }

    #[test]
    fn simultaneous_tasks_have_disjoint_element_access() {
        // The paper's §III-A claim, at element granularity, including the
        // tight case b = d + 1.
        let n = 96;
        for (b, d) in [(8, 4), (5, 4), (2, 1), (12, 2), (6, 5)] {
            let s = Stage::new(b, d);
            for t in 0..s.total_launches(n) {
                let tasks = s.tasks_at(n, t);
                for (i, a) in tasks.iter().enumerate() {
                    for bb in tasks.iter().skip(i + 1) {
                        for ra in s.accesses(a, n) {
                            for rb in s.accesses(bb, n) {
                                assert!(
                                    !ra.intersects(&rb),
                                    "overlap at t={t}: {a:?} vs {bb:?} (b={b}, d={d})"
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn anchor_spacing_is_3b_minus_1() {
        let n = 128;
        let s = Stage::new(8, 4);
        for t in 0..s.total_launches(n) {
            let tasks = s.tasks_at(n, t);
            for w in tasks.windows(2) {
                assert_eq!(w[0].anchor - w[1].anchor, 3 * s.b - 1);
            }
        }
    }

    #[test]
    fn max_parallelism_matches_occupancy_formula() {
        // Peak simultaneous tasks ≈ n / (3·b) (paper eq. (1) spacing).
        let n = 1024;
        let s = Stage::new(8, 4);
        let peak = (0..s.total_launches(n))
            .map(|t| s.tasks_at(n, t).len())
            .max()
            .unwrap();
        let expect = n / (3 * s.b);
        assert!(
            (peak as i64 - expect as i64).abs() <= 2,
            "peak {peak} vs n/(3b) = {expect}"
        );
    }

    #[test]
    fn small_matrices_have_no_tasks_when_already_reduced() {
        // n smaller than the output bandwidth: nothing to do.
        let s = Stage::new(8, 4);
        assert_eq!(s.num_sweeps(5), 0);
        assert_eq!(s.total_launches(5), 0);
        assert!(s.tasks_at(5, 0).is_empty());
    }

    #[test]
    fn task_stream_covers_every_task_in_schedule_order() {
        for (n, bw, tw) in [(64usize, 8usize, 4usize), (40, 6, 5), (24, 2, 1), (96, 12, 3)] {
            let plan = stage_plan(bw, tw);
            let mut stream = TaskStream::new(plan.clone(), n);
            for (si, stage) in plan.iter().enumerate() {
                let mut expect = Vec::new();
                for t in 0..stage.total_launches(n) {
                    let tasks = stage.tasks_at(n, t);
                    if !tasks.is_empty() {
                        expect.push(tasks);
                    }
                }
                for want in expect {
                    let (got_si, got) = stream.next_launch().expect("stream ended early");
                    assert_eq!(got_si, si, "n={n} bw={bw} tw={tw}");
                    assert_eq!(got, want, "n={n} bw={bw} tw={tw}");
                }
            }
            assert!(stream.is_done());
            assert!(stream.next_launch().is_none());
        }
    }

    #[test]
    fn task_stream_peek_matches_next() {
        let mut stream = TaskStream::for_problem(48, 6, 3);
        let mut launches = 0;
        while !stream.is_done() {
            let peek = stream.peek_count();
            let (_, tasks) = stream.next_launch().unwrap();
            assert_eq!(peek, tasks.len());
            assert!(!tasks.is_empty(), "stream must skip empty launches");
            launches += 1;
        }
        assert_eq!(stream.launches_emitted(), launches);
        assert_eq!(stream.peek_count(), 0);
    }

    #[test]
    fn task_stream_total_tasks_match_stage_counts() {
        let (n, bw, tw) = (72usize, 9usize, 4usize);
        let plan = stage_plan(bw, tw);
        let expect: usize = plan.iter().map(|s| stage_task_count(s, n)).sum();
        let got: usize = TaskStream::new(plan, n).map(|(_, tasks)| tasks.len()).sum();
        assert_eq!(got, expect);
    }

    #[test]
    fn task_stream_of_bidiagonal_problem_is_empty() {
        let mut stream = TaskStream::for_problem(16, 1, 4);
        assert!(stream.is_done());
        assert!(stream.next_launch().is_none());
    }

    #[test]
    fn rect_intersection_logic() {
        let a = Rect { row0: 0, row1: 2, col0: 0, col1: 2 };
        let b = Rect { row0: 2, row1: 4, col0: 2, col1: 4 };
        let c = Rect { row0: 3, row1: 4, col0: 0, col1: 4 };
        assert!(a.intersects(&b)); // corner touch counts
        assert!(!a.intersects(&c));
    }
}
