//! The paper's core contribution: memory-aware bulge chasing with
//! bandwidth tiling.
//!
//! - [`schedule`] — stage plan, sweep/cycle anchors, the 3-cycle
//!   separation parallel schedule, and access-rectangle dependency proofs.
//! - [`cycle`]    — the right/left Householder cycle kernel on banded
//!   storage (native analog of the L1 Pallas kernel).
//! - [`stage`]    — sequential / launch-order / thread-pool executors.
//! - [`tiling`]   — successive band reduction driver to bidiagonal form.

pub mod cycle;
pub mod schedule;
pub mod stage;
pub mod tiling;

pub use schedule::{stage_plan, CycleTask, Stage};
pub use tiling::{reduce_to_bidiagonal, reduce_to_bidiagonal_parallel, ReductionResult};
