//! Stage executors: run every task of one bandwidth-reduction stage.
//!
//! Three native orders, all producing bitwise-identical results (the same
//! reflector ops on disjoint data):
//! - [`run_stage_sequential`] — classic sweep-major order (Lang 1996).
//! - [`run_stage_launches`]   — launch-major order: the exact order the
//!   GPU schedule executes, still single-threaded. Used to validate the
//!   schedule against the sequential oracle.
//! - [`run_stage_parallel`]   — launch-major with tasks of one launch
//!   distributed over the thread pool (the GPU execution model: one task
//!   per "thread block", device-wide barrier between launches).

use crate::banded::storage::Banded;
use crate::bulge::cycle::{exec_cycle, exec_cycle_shared, CycleWorkspace, SharedBanded};
use crate::bulge::schedule::Stage;
use crate::scalar::Scalar;
use crate::util::threadpool::{ThreadPool, WorkerLocal};

/// Sweep-major order: finish sweep k before starting sweep k+1.
pub fn run_stage_sequential<T: Scalar>(a: &mut Banded<T>, stage: &Stage) {
    let n = a.n();
    let mut ws = CycleWorkspace::new(stage);
    for k in 0..stage.num_sweeps(n) {
        for c in 0..=stage.cmax(n, k) {
            exec_cycle(a, stage, &stage.task(k, c), &mut ws);
        }
    }
}

/// Launch-major order, single-threaded (schedule-order oracle).
pub fn run_stage_launches<T: Scalar>(a: &mut Banded<T>, stage: &Stage) {
    let n = a.n();
    let mut ws = CycleWorkspace::new(stage);
    for t in 0..stage.total_launches(n) {
        for task in stage.tasks_at(n, t) {
            exec_cycle(a, stage, &task, &mut ws);
        }
    }
}

/// Launch-major order with intra-launch parallelism over `pool`.
///
/// `block_capacity` bounds how many tasks run concurrently (the paper's
/// MaxBlocks × execution-units limit); excess tasks are executed
/// sequentially inside a worker ("software loop unrolling", §III-C-c).
pub fn run_stage_parallel<T: Scalar>(
    a: &mut Banded<T>,
    stage: &Stage,
    pool: &ThreadPool,
    block_capacity: usize,
) {
    let n = a.n();
    let view = SharedBanded::new(a);
    let capacity = block_capacity.max(1);
    // One persistent workspace per chunk index (no allocation inside the
    // launch loop — the packed-tile buffer is large for wide stages).
    let max_chunks = pool.len().max(1);
    let workspaces: WorkerLocal<CycleWorkspace<T>> =
        WorkerLocal::new(max_chunks, |_| CycleWorkspace::new(stage));
    for t in 0..stage.total_launches(n) {
        let tasks = stage.tasks_at(n, t);
        if tasks.is_empty() {
            continue;
        }
        let chunks = tasks.len().min(capacity).min(max_chunks);
        pool.for_each_chunk_indexed(tasks.len(), chunks, |c, range| {
            // SAFETY (workspaces): chunk index `c` is claimed by exactly
            // one worker per dispatch, and the barrier between launches
            // orders reuse across launches.
            let ws = unsafe { workspaces.get_mut(c) };
            for idx in range {
                // SAFETY: tasks within one launch access pairwise-disjoint
                // element rectangles (schedule.rs property), and the
                // barrier at the end of the dispatch orders launches.
                unsafe { exec_cycle_shared(&view, stage, &tasks[idx], ws) };
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generate::random_banded;
    use crate::util::rng::Xoshiro256;

    fn fresh(n: usize, b: usize, d: usize, seed: u64) -> Banded<f64> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        random_banded::<f64>(n, b, d, &mut rng)
    }

    #[test]
    fn stage_reduces_bandwidth() {
        for (n, b, d) in [(32usize, 8usize, 4usize), (33, 8, 4), (40, 5, 4), (24, 2, 1)] {
            let stage = Stage::new(b, d);
            let mut a = fresh(n, b, d, 1);
            run_stage_sequential(&mut a, &stage);
            assert_eq!(
                a.max_off_band(stage.b_out()),
                0.0,
                "n={n} b={b} d={d}: band not reduced"
            );
        }
    }

    #[test]
    fn stage_preserves_frobenius_norm() {
        let stage = Stage::new(6, 3);
        let mut a = fresh(48, 6, 3, 2);
        let before = a.fro_norm();
        run_stage_sequential(&mut a, &stage);
        assert!((a.fro_norm() - before).abs() < 1e-10 * before);
    }

    #[test]
    fn launch_order_matches_sweep_order_bitwise() {
        // The commutation argument of DESIGN.md §3: both orders execute
        // the same reflectors on disjoint data ⇒ identical floats.
        for (n, b, d) in [(40usize, 8usize, 4usize), (31, 5, 4), (26, 3, 2)] {
            let stage = Stage::new(b, d);
            let mut a1 = fresh(n, b, d, 3);
            let mut a2 = a1.clone();
            run_stage_sequential(&mut a1, &stage);
            run_stage_launches(&mut a2, &stage);
            assert_eq!(a1, a2, "n={n} b={b} d={d}");
        }
    }

    #[test]
    fn parallel_matches_sequential_bitwise() {
        let pool = ThreadPool::new(4);
        for (n, b, d) in [(64usize, 8usize, 4usize), (50, 4, 3), (37, 6, 5)] {
            let stage = Stage::new(b, d);
            let mut a1 = fresh(n, b, d, 4);
            let mut a2 = a1.clone();
            run_stage_sequential(&mut a1, &stage);
            run_stage_parallel(&mut a2, &stage, &pool, usize::MAX);
            assert_eq!(a1, a2, "n={n} b={b} d={d}");
        }
    }

    #[test]
    fn parallel_respects_block_capacity() {
        // Tiny capacity forces heavy loop unrolling; result must not change.
        let pool = ThreadPool::new(4);
        let stage = Stage::new(8, 4);
        let mut a1 = fresh(96, 8, 4, 5);
        let mut a2 = a1.clone();
        run_stage_sequential(&mut a1, &stage);
        run_stage_parallel(&mut a2, &stage, &pool, 2);
        assert_eq!(a1, a2);
    }
}
