//! The bulge-chasing cycle kernel — native-Rust analog of the paper's
//! Algorithm 2 (and of the L1 Pallas kernel in
//! `python/compile/kernels/bulge.py`).
//!
//! A cycle = one **right** op (annihilate `d` elements of the pivot row by
//! combining `d+1` columns) + one **left** op (annihilate the generated
//! column bulge by combining `d+1` rows). Both walk storage
//! column-by-column so every inner loop runs over a *contiguous* memory
//! segment — the CPU analog of the coalesced/cache-line-aligned accesses
//! the paper engineers on GPUs.
//!
//! The kernels are generic over a [`BandView`], so the same code (and
//! therefore the exact same float-op order — bitwise-identical results)
//! runs against two storages:
//!
//! - [`SharedBanded`] — the full banded array, chased in place.
//! - a packed tile ([`crate::banded::storage::TileSpec`]) — the cycle's
//!   whole footprint gathered into a contiguous per-worker workspace,
//!   chased there, and written back once. This is the memory-aware path
//!   (the paper's L1-resident tiles): wide stages re-touch the tile
//!   `~6×` through the cache hierarchy, so keeping it dense and hot in
//!   one core's cache beats striding across the band.
//!
//! [`exec_cycle`] / [`exec_cycle_shared`] pick the path per stage with
//! [`stage_uses_packed`]; both paths produce identical bits.

use crate::banded::storage::{Banded, TileSpec};
use crate::bulge::schedule::{CycleTask, Stage};
use crate::householder::make_reflector_simd;
use crate::plan::LaunchPlan;
use crate::scalar::Scalar;
use crate::simd::{AlignedVec, SimdSpec};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Default minimum stage span `b + d` for routing through the packed-tile
/// path. Narrow tiles fit a handful of cache lines each — the pack/unpack
/// copies cost more than contiguity saves. Wide stages (the bw ≥ 64
/// regime the paper profiles) chase cache-resident.
///
/// Overridable without a rebuild via `BSVD_PACKED_SPAN_MIN` (resolved on
/// first use): `0` forces every stage through the packed path, a huge
/// value forces in-place — the tuning lever `benches/perf_hotpath.rs`
/// measures (see docs/performance-model.md for the tuning recipe).
/// In-process, tests and benches pin it with [`set_packed_span_min`].
pub const PACKED_SPAN_MIN: usize = 48;

/// Sentinel for "gate not yet resolved from the environment".
const GATE_UNSET: usize = usize::MAX;

static PACKED_SPAN_MIN_GATE: AtomicUsize = AtomicUsize::new(GATE_UNSET);

fn packed_span_min() -> usize {
    let v = PACKED_SPAN_MIN_GATE.load(Ordering::Relaxed);
    if v != GATE_UNSET {
        return v;
    }
    // First read (or post-reset): resolve env → default. Two racing
    // threads resolve the same value, so the double-store is benign.
    let resolved = std::env::var("BSVD_PACKED_SPAN_MIN")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .map(|v| v.min(GATE_UNSET - 1))
        .unwrap_or(PACKED_SPAN_MIN);
    PACKED_SPAN_MIN_GATE.store(resolved, Ordering::Relaxed);
    resolved
}

/// Override the packed-path gate **process-wide**: `Some(v)` pins
/// `stage_uses_packed` to `b + d >= v` (so `Some(0)` forces every stage
/// packed and `Some(usize::MAX - 1)` forces in-place); `None` resets the
/// gate so the next read re-resolves `BSVD_PACKED_SPAN_MIN` / the
/// default. For tests and benches exercising both paths in one process —
/// not part of the tuning API, and racy against concurrently running
/// executors, so test binaries using it must serialize around it.
#[doc(hidden)]
pub fn set_packed_span_min(gate: Option<usize>) {
    let v = match gate {
        Some(v) => v.min(GATE_UNSET - 1),
        None => GATE_UNSET,
    };
    PACKED_SPAN_MIN_GATE.store(v, Ordering::Relaxed);
}

/// True when `stage`'s cycles run through the packed-tile workspace.
/// Every executor (sequential, parallel, batch) consults the same gate,
/// so all paths stay bitwise identical regardless of the setting.
#[inline]
pub fn stage_uses_packed(stage: &Stage) -> bool {
    stage.b + stage.d >= packed_span_min()
}

/// Reusable scratch for cycle execution (no allocation on the hot path —
/// the paper keeps these in shared memory / registers). One lives per
/// worker slot, persistently, so the tile workspace stays in that core's
/// cache across launches (see `ThreadPool::for_each_slot`).
///
/// All three buffers are 64-byte aligned ([`AlignedVec`]): the packed
/// tile and the `w` accumulator are exactly what the SIMD lane kernels
/// stream over, so their loads never start from a split cache line.
#[derive(Clone, Debug)]
pub struct CycleWorkspace<T> {
    /// Householder vector: x[0] = β after `make_reflector`, x[1..] = tail.
    x: AlignedVec<T>,
    /// Per-row dot products for the right op.
    w: AlignedVec<T>,
    /// Packed tile buffer (empty until a packed-path stage runs).
    tile: AlignedVec<T>,
}

impl<T: Scalar> CycleWorkspace<T> {
    pub fn new(stage: &Stage) -> Self {
        let tile = if stage_uses_packed(stage) {
            let side = stage.b + stage.d + 1;
            AlignedVec::filled(side * side, T::zero())
        } else {
            AlignedVec::new()
        };
        Self {
            x: AlignedVec::filled(stage.d + 1, T::zero()),
            w: AlignedVec::filled(stage.b + stage.d + 1, T::zero()),
            tile,
        }
    }

    /// An empty workspace that grows on demand ([`Self::ensure_stage`]) —
    /// used by the plan executor's per-slot scratch, which is shared by
    /// problems of mixed shapes.
    pub fn growable() -> Self {
        Self { x: AlignedVec::new(), w: AlignedVec::new(), tile: AlignedVec::new() }
    }

    /// Grow the Householder buffers to cover `stage` (the packed-tile
    /// buffer grows inside [`exec_cycle_packed`] as needed). Cheap: two
    /// length compares on the hot path once warm.
    pub fn ensure_stage(&mut self, stage: &Stage) {
        if self.x.len() < stage.d + 1 {
            self.x.resize(stage.d + 1, T::zero());
        }
        if self.w.len() < stage.b + stage.d + 1 {
            self.w.resize(stage.b + stage.d + 1, T::zero());
        }
    }

    /// Test-only: every buffer starts on a 64-byte boundary (empty
    /// buffers report their well-aligned dangling pointer).
    #[cfg(test)]
    pub(crate) fn alignment_ok(&self) -> bool {
        self.x.as_ptr() as usize % 64 == 0
            && self.w.as_ptr() as usize % 64 == 0
            && self.tile.as_ptr() as usize % 64 == 0
    }

    /// Workspace sized for every launch of a plan, straight from the IR's
    /// max-slot metadata (`max_d`, `max_bd`) — no stage re-scan.
    pub fn for_plan(plan: &LaunchPlan) -> Self {
        let tile_side = plan.max_bd + 1;
        let needs_tile = plan
            .problems
            .iter()
            .flat_map(|p| p.stages.iter())
            .any(stage_uses_packed);
        Self {
            x: AlignedVec::filled(plan.max_d + 1, T::zero()),
            w: AlignedVec::filled(plan.max_bd + 1, T::zero()),
            tile: if needs_tile {
                AlignedVec::filled(tile_side * tile_side, T::zero())
            } else {
                AlignedVec::new()
            },
        }
    }
}

/// Storage a cycle kernel chases through: banded array or packed tile.
/// Implementations translate `(i, j)` element coordinates; the kernels
/// never see the difference, which is what guarantees the two paths are
/// bitwise identical.
pub trait BandView<T: Scalar> {
    fn n(&self) -> usize;

    /// # Safety
    /// Caller must guarantee no concurrent access to the element.
    unsafe fn get(&self, i: usize, j: usize) -> T;

    /// # Safety
    /// Caller must guarantee no concurrent access to the element.
    unsafe fn set(&self, i: usize, j: usize, v: T);

    /// Contiguous mutable column segment (i0..=i1, j).
    ///
    /// # Safety
    /// Caller must guarantee no concurrent access to these elements.
    unsafe fn col_segment_mut<'a>(&self, j: usize, i0: usize, i1: usize) -> &'a mut [T];
}

/// A raw, `Send + Sync` view over banded storage used by the launch-level
/// parallel executor. Safety rests on the schedule's disjointness
/// guarantee (proved in `schedule.rs` tests): simultaneous tasks touch
/// disjoint element sets, hence disjoint storage indices.
pub struct SharedBanded<T> {
    data: *mut T,
    n: usize,
    kd_super: usize,
    ld: usize,
}

unsafe impl<T: Send> Send for SharedBanded<T> {}
unsafe impl<T: Send> Sync for SharedBanded<T> {}

impl<T: Scalar> SharedBanded<T> {
    pub fn new(a: &mut Banded<T>) -> Self {
        Self {
            n: a.n(),
            kd_super: a.kd_super(),
            ld: a.ld(),
            data: a.data_mut().as_mut_ptr(),
        }
    }

    #[inline]
    fn idx(&self, i: usize, j: usize) -> usize {
        debug_assert!(j + self.kd_super >= i, "({i},{j}) below band");
        j * self.ld + (self.kd_super + i - j)
    }

    /// Gather the tile into the contiguous workspace `out` — the same
    /// [`TileSpec::col_span`] index map as the safe [`Banded::pack_tile`].
    ///
    /// # Safety
    /// Caller must guarantee no concurrent access to the tile's elements.
    unsafe fn pack_tile(&self, spec: &TileSpec, out: &mut [T]) {
        for j in spec.j0..=spec.c1 {
            let (off, lo, len) = spec.col_span(j);
            out[off..off + len].copy_from_slice(self.col_segment_mut(j, lo, spec.hi));
        }
    }

    /// Write the chased tile back — inverse of [`SharedBanded::pack_tile`].
    ///
    /// # Safety
    /// Caller must guarantee no concurrent access to the tile's elements.
    unsafe fn unpack_tile(&self, spec: &TileSpec, buf: &[T]) {
        for j in spec.j0..=spec.c1 {
            let (off, lo, len) = spec.col_span(j);
            self.col_segment_mut(j, lo, spec.hi).copy_from_slice(&buf[off..off + len]);
        }
    }
}

impl<T: Scalar> BandView<T> for SharedBanded<T> {
    #[inline]
    fn n(&self) -> usize {
        self.n
    }

    #[inline]
    unsafe fn get(&self, i: usize, j: usize) -> T {
        *self.data.add(self.idx(i, j))
    }

    #[inline]
    unsafe fn set(&self, i: usize, j: usize, v: T) {
        *self.data.add(self.idx(i, j)) = v;
    }

    #[inline]
    unsafe fn col_segment_mut<'a>(&self, j: usize, i0: usize, i1: usize) -> &'a mut [T] {
        let lo = self.idx(i0, j);
        std::slice::from_raw_parts_mut(self.data.add(lo), i1 - i0 + 1)
    }
}

/// View over a packed tile workspace, addressed in the *original* matrix
/// coordinates so the kernels are oblivious to the packing.
struct TileView<T> {
    data: *mut T,
    spec: TileSpec,
    pitch: usize,
    n: usize,
}

impl<T: Scalar> TileView<T> {
    #[inline]
    fn idx(&self, i: usize, j: usize) -> usize {
        let lo = self.spec.lo(j);
        debug_assert!(
            j >= self.spec.j0 && j <= self.spec.c1 && i >= lo && i <= self.spec.hi,
            "({i},{j}) outside packed tile {:?}",
            self.spec
        );
        (j - self.spec.j0) * self.pitch + (i - lo)
    }
}

impl<T: Scalar> BandView<T> for TileView<T> {
    #[inline]
    fn n(&self) -> usize {
        self.n
    }

    #[inline]
    unsafe fn get(&self, i: usize, j: usize) -> T {
        *self.data.add(self.idx(i, j))
    }

    #[inline]
    unsafe fn set(&self, i: usize, j: usize, v: T) {
        *self.data.add(self.idx(i, j)) = v;
    }

    #[inline]
    unsafe fn col_segment_mut<'a>(&self, j: usize, i0: usize, i1: usize) -> &'a mut [T] {
        let lo = self.idx(i0, j);
        std::slice::from_raw_parts_mut(self.data.add(lo), i1 - i0 + 1)
    }
}

/// The tile a cycle task touches (both ops) — see the index diagram at
/// [`TileSpec`]: block A is the right op's rows `pivot..=jd` × cols
/// `anchor..=jd`, block B the left op's rows `anchor..=jd` × cols
/// `jd+1..=c1`.
pub fn task_tile_spec(stage: &Stage, task: &CycleTask, n: usize) -> TileSpec {
    let j0 = task.anchor;
    let jd = (j0 + stage.d).min(n - 1);
    let c1 = (j0 + stage.b + stage.d).min(n - 1);
    TileSpec::new(j0, jd, c1, task.pivot_row, j0, jd)
}

/// Destination for one task's two reflector records — a borrowed slice
/// pair over a [`crate::plan::ReflectorLog`] arena record, each laid out
/// as `[τ, v₁ .. v_dd]`. Values are converted to f64 at capture time
/// (exact for every supported working precision), immediately after
/// `make_reflector_simd` forms them — before the apply loops (and, on
/// the packed path, the tile write-back) can overwrite the workspace.
pub struct TaskCapture<'a> {
    /// Right (column-combining, V-side) reflector record.
    pub right: &'a mut [f64],
    /// Left (row-combining, U-side) reflector record.
    pub left: &'a mut [f64],
}

#[inline]
fn record_reflector<T: Scalar>(out: &mut [f64], tau: T, tail: &[T]) {
    debug_assert_eq!(out.len(), tail.len() + 1, "capture record sized for another task");
    out[0] = tau.to_f64();
    for (o, v) in out[1..].iter_mut().zip(tail.iter()) {
        *o = v.to_f64();
    }
}

/// Execute the **right** op of `task`: annihilate the pivot row's elements
/// in columns `anchor+1 ..= min(anchor+d, n−1)` into `(pivot, anchor)`,
/// applying the reflector to rows `pivot+1 ..= min(anchor+d, n−1)`.
///
/// # Safety
/// `view` elements inside the task's `right_access` rectangle must not be
/// accessed concurrently.
pub unsafe fn exec_right<T: Scalar, V: BandView<T>>(
    view: &V,
    stage: &Stage,
    task: &CycleTask,
    ws: &mut CycleWorkspace<T>,
) {
    exec_right_with(view, stage, task, ws, SimdSpec::scalar())
}

/// [`exec_right`] with every hot loop routed through the [`Scalar`]
/// `simd_*` hooks under `spec` — the SIMD dispatch seam. With the scalar
/// spec (or a non-contracting vector spec) results are bitwise-identical
/// to the historical loops; see the `crate::simd` equivalence contract.
///
/// # Safety
/// As [`exec_right`].
pub unsafe fn exec_right_with<T: Scalar, V: BandView<T>>(
    view: &V,
    stage: &Stage,
    task: &CycleTask,
    ws: &mut CycleWorkspace<T>,
    spec: SimdSpec,
) {
    exec_right_cap(view, stage, task, ws, spec, None)
}

/// [`exec_right_with`] with an optional reflector-capture destination
/// (`Some` records `[τ, v₁..v_dd]` the moment the reflector is formed).
/// The numerical path is byte-for-byte the uncaptured one — the capture
/// only *reads* the workspace between `make_reflector_simd` and the
/// apply loops.
///
/// # Safety
/// As [`exec_right`].
unsafe fn exec_right_cap<T: Scalar, V: BandView<T>>(
    view: &V,
    stage: &Stage,
    task: &CycleTask,
    ws: &mut CycleWorkspace<T>,
    spec: SimdSpec,
    cap: Option<&mut [f64]>,
) {
    let n = view.n();
    let j0 = task.anchor;
    let rp = task.pivot_row;
    debug_assert!(j0 <= n - 2, "task anchor out of range");
    let jd = (j0 + stage.d).min(n - 1);
    let dd = jd - j0; // effective tail length (≥ 1 by schedule)
    if dd == 0 {
        return;
    }
    // Gather pivot-row segment x = A[rp, j0..=jd] (Alg. 2 line 3: the
    // cooperative load of the vector to reflect).
    let x = &mut ws.x[..=dd];
    for (jj, xv) in x.iter_mut().enumerate() {
        *xv = view.get(rp, j0 + jj);
    }
    let tau = make_reflector_simd(x, spec);
    if let Some(out) = cap {
        record_reflector(out, tau, &x[1..=dd]);
    }
    // Write back β and exact zeros (Alg. 2 line 6).
    view.set(rp, j0, x[0]);
    for jj in 1..=dd {
        view.set(rp, j0 + jj, T::zero());
    }
    if tau == T::zero() {
        return;
    }
    // Apply (I − τ v vᵀ) from the right to rows rp+1..=r1 (Alg. 2 lines
    // 8–13; the TPB chunking happens one level up, in the executor).
    let r1 = jd; // min(j0 + d, n−1)
    let r0 = rp + 1;
    if r0 > r1 {
        return;
    }
    let rows = r1 - r0 + 1;
    let w = &mut ws.w[..rows];
    // Pass 1: w = Σ_jj v_jj · A[r0..=r1, j0+jj]   (column-major friendly)
    {
        let seg = view.col_segment_mut(j0, r0, r1);
        w.copy_from_slice(seg); // v_0 = 1
    }
    for jj in 1..=dd {
        let vj = x[jj];
        let seg = view.col_segment_mut(j0 + jj, r0, r1);
        T::simd_fma_axpy(spec, w, vj, seg);
    }
    // Scale by τ once.
    T::simd_scale(spec, w, tau);
    // Pass 2: A[., j0+jj] −= w · v_jj
    {
        let seg = view.col_segment_mut(j0, r0, r1);
        T::simd_sub(spec, seg, w);
    }
    for jj in 1..=dd {
        let vj = x[jj];
        let seg = view.col_segment_mut(j0 + jj, r0, r1);
        T::simd_sub_scaled(spec, seg, w, vj);
    }
}

/// Execute the **left** op of `task`: annihilate the column bulge in rows
/// `anchor+1 ..= min(anchor+d, n−1)` of column `anchor` into the diagonal,
/// applying the reflector to columns `anchor+1 ..= min(anchor+b+d, n−1)`.
///
/// # Safety
/// `view` elements inside the task's `left_access` rectangle must not be
/// accessed concurrently.
pub unsafe fn exec_left<T: Scalar, V: BandView<T>>(
    view: &V,
    stage: &Stage,
    task: &CycleTask,
    ws: &mut CycleWorkspace<T>,
) {
    exec_left_with(view, stage, task, ws, SimdSpec::scalar())
}

/// [`exec_left`] with the column dot/update loops routed through the
/// [`Scalar`] `simd_*` hooks under `spec` (see [`exec_right_with`]).
///
/// # Safety
/// As [`exec_left`].
pub unsafe fn exec_left_with<T: Scalar, V: BandView<T>>(
    view: &V,
    stage: &Stage,
    task: &CycleTask,
    ws: &mut CycleWorkspace<T>,
    spec: SimdSpec,
) {
    exec_left_cap(view, stage, task, ws, spec, None)
}

/// [`exec_left_with`] with an optional reflector-capture destination —
/// see [`exec_right_cap`].
///
/// # Safety
/// As [`exec_left`].
unsafe fn exec_left_cap<T: Scalar, V: BandView<T>>(
    view: &V,
    stage: &Stage,
    task: &CycleTask,
    ws: &mut CycleWorkspace<T>,
    spec: SimdSpec,
    cap: Option<&mut [f64]>,
) {
    let n = view.n();
    let j0 = task.anchor;
    let i1 = (j0 + stage.d).min(n - 1);
    let dd = i1 - j0;
    if dd == 0 {
        return;
    }
    // Gather pivot-column segment (contiguous) and reflect.
    let x = &mut ws.x[..=dd];
    {
        let seg = view.col_segment_mut(j0, j0, i1);
        x.copy_from_slice(seg);
    }
    let tau = make_reflector_simd(x, spec);
    if let Some(out) = cap {
        record_reflector(out, tau, &x[1..=dd]);
    }
    {
        let seg = view.col_segment_mut(j0, j0, i1);
        seg[0] = x[0];
        for s in seg[1..].iter_mut() {
            *s = T::zero();
        }
    }
    if tau == T::zero() {
        return;
    }
    // Apply (I − τ v vᵀ) from the left to the remaining columns; each
    // column is one contiguous dot + update of ≤ d+1 elements — the
    // "one thread per column" granularity of Alg. 2 line 15.
    let c1 = (j0 + stage.b + stage.d).min(n - 1);
    for col in (j0 + 1)..=c1 {
        let seg = view.col_segment_mut(col, j0, i1);
        let dot = T::simd_dot_fma(spec, seg[0], &x[1..], &seg[1..]);
        let cfac = tau * dot;
        seg[0] = seg[0] - cfac;
        T::simd_sub_scaled(spec, &mut seg[1..], &x[1..], cfac);
    }
}

/// Execute a full cycle *inside a packed tile workspace*: gather the
/// task's whole footprint into `ws.tile`, chase there (right then left),
/// write back once. Bitwise identical to the in-place path — the same
/// generic kernels run, only the addressing differs.
///
/// # Safety
/// As [`exec_cycle_shared`]: the task's access rectangles must be
/// disjoint from every concurrently executing task's.
pub unsafe fn exec_cycle_packed<T: Scalar>(
    view: &SharedBanded<T>,
    stage: &Stage,
    task: &CycleTask,
    ws: &mut CycleWorkspace<T>,
) {
    exec_cycle_packed_with(view, stage, task, ws, SimdSpec::scalar())
}

/// [`exec_cycle_packed`] chasing the packed tile with the SIMD kernels
/// selected by `spec` — the only place vector kernels run: the packed
/// workspace is the contiguous, 64-byte-aligned memory they are built
/// for. Bitwise-identical to the scalar path for non-contracting specs.
///
/// # Safety
/// As [`exec_cycle_packed`].
pub unsafe fn exec_cycle_packed_with<T: Scalar>(
    view: &SharedBanded<T>,
    stage: &Stage,
    task: &CycleTask,
    ws: &mut CycleWorkspace<T>,
    simd: SimdSpec,
) {
    exec_cycle_packed_cap(view, stage, task, ws, simd, None)
}

/// [`exec_cycle_packed_with`] with an optional [`TaskCapture`] — both
/// reflectors are recorded from inside the tile, before the write-back.
///
/// # Safety
/// As [`exec_cycle_packed`].
unsafe fn exec_cycle_packed_cap<T: Scalar>(
    view: &SharedBanded<T>,
    stage: &Stage,
    task: &CycleTask,
    ws: &mut CycleWorkspace<T>,
    simd: SimdSpec,
    cap: Option<TaskCapture<'_>>,
) {
    let spec = task_tile_spec(stage, task, view.n);
    let elems = spec.elems();
    let mut tile = std::mem::take(&mut ws.tile);
    if tile.len() < elems {
        tile.resize(elems, T::zero());
    }
    view.pack_tile(&spec, &mut tile[..elems]);
    let tv = TileView { data: tile.as_mut_ptr(), spec, pitch: spec.pitch(), n: view.n };
    let (rcap, lcap) = match cap {
        Some(c) => (Some(c.right), Some(c.left)),
        None => (None, None),
    };
    exec_right_cap(&tv, stage, task, ws, simd, rcap);
    exec_left_cap(&tv, stage, task, ws, simd, lcap);
    view.unpack_tile(&spec, &tile[..elems]);
    ws.tile = tile;
}

/// Execute a full cycle (right then left) directly on the banded array.
///
/// # Safety
/// As [`exec_cycle_shared`].
pub unsafe fn exec_cycle_inplace<T: Scalar>(
    view: &SharedBanded<T>,
    stage: &Stage,
    task: &CycleTask,
    ws: &mut CycleWorkspace<T>,
) {
    exec_right(view, stage, task, ws);
    exec_left(view, stage, task, ws);
}

/// Execute a full cycle on an exclusively-borrowed matrix — the safe
/// entry point used by the sequential executor. Routes through the
/// packed-tile workspace for wide stages ([`stage_uses_packed`]).
pub fn exec_cycle<T: Scalar>(
    a: &mut Banded<T>,
    stage: &Stage,
    task: &CycleTask,
    ws: &mut CycleWorkspace<T>,
) {
    let view = SharedBanded::new(a);
    // SAFETY: exclusive &mut borrow ⇒ no concurrent access at all.
    unsafe { exec_cycle_shared(&view, stage, task, ws) }
}

/// Execute a full cycle through a shared view — used by the launch-level
/// parallel executor. Routes through the packed-tile workspace for wide
/// stages ([`stage_uses_packed`]).
///
/// # Safety
/// The task's access rectangles must be disjoint from those of every
/// other task executing concurrently (guaranteed by `Stage::tasks_at`).
pub unsafe fn exec_cycle_shared<T: Scalar>(
    view: &SharedBanded<T>,
    stage: &Stage,
    task: &CycleTask,
    ws: &mut CycleWorkspace<T>,
) {
    exec_cycle_shared_with(view, stage, task, ws, SimdSpec::scalar())
}

/// [`exec_cycle_shared`] with a SIMD spec: packed-path stages chase
/// through the vector kernels, in-place (below-gate) stages always run
/// the scalar loops — narrow strided columns have nothing for the lanes
/// to stream over, and keeping them scalar keeps the below-gate path
/// byte-for-byte shared with every other backend.
///
/// # Safety
/// As [`exec_cycle_shared`].
pub unsafe fn exec_cycle_shared_with<T: Scalar>(
    view: &SharedBanded<T>,
    stage: &Stage,
    task: &CycleTask,
    ws: &mut CycleWorkspace<T>,
    simd: SimdSpec,
) {
    if stage_uses_packed(stage) {
        exec_cycle_packed_with(view, stage, task, ws, simd);
    } else {
        exec_cycle_inplace(view, stage, task, ws);
    }
}

/// [`exec_cycle_shared_with`] additionally recording the task's two
/// reflectors into `cap` — the seam every vectors-capable backend runs
/// through (`Backend::execute_logged`). Below-gate stages capture from
/// the scalar in-place kernels, above-gate stages from inside the
/// packed tile, so the captured bits are identical across paths exactly
/// like the band bits are.
///
/// # Safety
/// As [`exec_cycle_shared`]; additionally `cap`'s record slices must
/// not be aliased by any concurrently executing task (the reflector log
/// hands out disjoint records per plan task ordinal).
pub unsafe fn exec_cycle_shared_logged_with<T: Scalar>(
    view: &SharedBanded<T>,
    stage: &Stage,
    task: &CycleTask,
    ws: &mut CycleWorkspace<T>,
    simd: SimdSpec,
    cap: TaskCapture<'_>,
) {
    if stage_uses_packed(stage) {
        exec_cycle_packed_cap(view, stage, task, ws, simd, Some(cap));
    } else {
        exec_right_cap(view, stage, task, ws, SimdSpec::scalar(), Some(cap.right));
        exec_left_cap(view, stage, task, ws, SimdSpec::scalar(), Some(cap.left));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::banded::dense::Dense;
    use crate::generate::random_banded;
    use crate::householder::make_reflector;
    use crate::util::rng::Xoshiro256;

    /// Dense-oracle version of one cycle, built from the generic dense
    /// reflector helpers. Used to validate the banded kernel exactly.
    fn exec_cycle_dense(a: &mut Dense<f64>, stage: &Stage, task: &CycleTask) {
        use crate::householder::{apply_reflector_cols, apply_reflector_rows};
        let n = a.rows;
        let j0 = task.anchor;
        let rp = task.pivot_row;
        let jd = (j0 + stage.d).min(n - 1);
        let dd = jd - j0;
        if dd == 0 {
            return;
        }
        // Right op.
        let mut x: Vec<f64> = (0..=dd).map(|jj| a.get(rp, j0 + jj)).collect();
        let tau = make_reflector(&mut x);
        let v = x[1..].to_vec();
        apply_reflector_cols(a, tau, &v, j0, rp, jd);
        // force exact zeros like the banded kernel
        a.set(rp, j0, x[0]);
        for jj in 1..=dd {
            a.set(rp, j0 + jj, 0.0);
        }
        // Left op.
        let i1 = (j0 + stage.d).min(n - 1);
        let mut x: Vec<f64> = (j0..=i1).map(|i| a.get(i, j0)).collect();
        let tau = make_reflector(&mut x);
        let v = x[1..].to_vec();
        let c1 = (j0 + stage.b + stage.d).min(n - 1);
        apply_reflector_rows(a, tau, &v, j0, j0, c1);
        a.set(j0, j0, x[0]);
        for i in (j0 + 1)..=i1 {
            a.set(i, j0, 0.0);
        }
    }

    #[test]
    fn banded_cycle_matches_dense_oracle() {
        let mut rng = Xoshiro256::seed_from_u64(42);
        for (n, b, d) in [(24usize, 6usize, 3usize), (20, 4, 3), (16, 3, 1), (30, 8, 7)] {
            let stage = Stage::new(b, d);
            let mut banded = random_banded::<f64>(n, b, d, &mut rng);
            let mut dense = Dense::from_vec(n, n, banded.to_dense());
            let mut ws = CycleWorkspace::new(&stage);
            // Run the first few tasks of sweep 0 and compare after each.
            for c in 0..=stage.cmax(n, 0) {
                let task = stage.task(0, c);
                exec_cycle(&mut banded, &stage, &task, &mut ws);
                exec_cycle_dense(&mut dense, &stage, &task);
                let bd = banded.to_dense();
                for i in 0..n {
                    for j in 0..n {
                        let got = bd[i * n + j];
                        let want = dense.get(i, j);
                        assert!(
                            (got - want).abs() < 1e-12,
                            "n={n} b={b} d={d} cycle {c}: ({i},{j}) {got} vs {want}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn packed_path_is_bitwise_equal_to_inplace() {
        // Every (b, d) below and above the PACKED_SPAN_MIN gate, full
        // sweeps including the clamped matrix edge.
        let mut rng = Xoshiro256::seed_from_u64(77);
        for (n, b, d) in [(40usize, 5usize, 4usize), (96, 12, 6), (200, 32, 16), (280, 40, 24)] {
            let stage = Stage::new(b, d);
            let base = random_banded::<f64>(n, b, d, &mut rng);
            let mut a1 = base.clone();
            let mut a2 = base.clone();
            let mut ws1 = CycleWorkspace::new(&stage);
            let mut ws2 = CycleWorkspace::new(&stage);
            for k in 0..stage.num_sweeps(n) {
                for c in 0..=stage.cmax(n, k) {
                    let task = stage.task(k, c);
                    let v1 = SharedBanded::new(&mut a1);
                    let v2 = SharedBanded::new(&mut a2);
                    // SAFETY: exclusive borrows, no concurrency.
                    unsafe {
                        exec_cycle_inplace(&v1, &stage, &task, &mut ws1);
                        exec_cycle_packed(&v2, &stage, &task, &mut ws2);
                    }
                }
            }
            assert_eq!(a1, a2, "n={n} b={b} d={d}");
            assert_eq!(a1.max_off_band(stage.b_out()), 0.0);
        }
    }

    #[test]
    fn captured_reflectors_are_path_invariant_and_leave_numerics_alone() {
        // The capture seam must (a) record identical bits from the
        // in-place and packed paths, and (b) never perturb the chased
        // band relative to the uncaptured kernels.
        let mut rng = Xoshiro256::seed_from_u64(123);
        for (n, b, d) in [(40usize, 5usize, 4usize), (96, 12, 6), (200, 32, 16)] {
            let stage = Stage::new(b, d);
            let base = random_banded::<f64>(n, b, d, &mut rng);
            let mut plain = base.clone();
            let mut inplace = base.clone();
            let mut packed = base.clone();
            let mut ws0 = CycleWorkspace::new(&stage);
            let mut ws1 = CycleWorkspace::new(&stage);
            let mut ws2 = CycleWorkspace::new(&stage);
            let mut rec1: Vec<Vec<f64>> = Vec::new();
            let mut rec2: Vec<Vec<f64>> = Vec::new();
            for k in 0..stage.num_sweeps(n) {
                for c in 0..=stage.cmax(n, k) {
                    let task = stage.task(k, c);
                    let jd = (task.anchor + d).min(n - 1);
                    let dd = jd - task.anchor;
                    let mut r1 = vec![0.0; 2 * (dd + 1)];
                    let mut r2 = vec![0.0; 2 * (dd + 1)];
                    let v0 = SharedBanded::new(&mut plain);
                    let v1 = SharedBanded::new(&mut inplace);
                    let v2 = SharedBanded::new(&mut packed);
                    // SAFETY: exclusive borrows, no concurrency.
                    unsafe {
                        exec_cycle_inplace(&v0, &stage, &task, &mut ws0);
                        {
                            let (right, left) = r1.split_at_mut(dd + 1);
                            exec_right_cap(
                                &v1, &stage, &task, &mut ws1,
                                SimdSpec::scalar(), Some(right),
                            );
                            exec_left_cap(
                                &v1, &stage, &task, &mut ws1,
                                SimdSpec::scalar(), Some(left),
                            );
                        }
                        {
                            let (right, left) = r2.split_at_mut(dd + 1);
                            exec_cycle_packed_cap(
                                &v2, &stage, &task, &mut ws2,
                                SimdSpec::scalar(),
                                Some(TaskCapture { right, left }),
                            );
                        }
                    }
                    rec1.push(r1);
                    rec2.push(r2);
                }
            }
            assert_eq!(rec1, rec2, "n={n} b={b} d={d}: capture diverges across paths");
            assert_eq!(plain, inplace, "n={n} b={b} d={d}: capture perturbed the band");
            assert_eq!(plain, packed, "n={n} b={b} d={d}: packed capture perturbed the band");
        }
    }

    #[test]
    fn tile_spec_covers_access_rectangles() {
        // The packed tile must contain both proved-disjoint access
        // rectangles — that containment is what makes whole-tile
        // write-back sound under concurrency.
        let n = 64;
        for (b, d) in [(8usize, 4usize), (5, 4), (2, 1), (12, 2)] {
            let stage = Stage::new(b, d);
            for t in 0..stage.total_launches(n) {
                for task in stage.tasks_at(n, t) {
                    let spec = task_tile_spec(&stage, &task, n);
                    for rect in stage.accesses(&task, n) {
                        assert!(rect.col0 >= spec.j0 && rect.col1 <= spec.c1, "{task:?}");
                        for j in rect.col0..=rect.col1 {
                            assert!(rect.row0 >= spec.lo(j) && rect.row1 <= spec.hi, "{task:?}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn workspace_for_plan_covers_widest_stage() {
        use crate::config::TuneParams;
        let params = TuneParams { tpb: 32, tw: 32, max_blocks: 8 };
        let plan = LaunchPlan::for_problem(256, 64, &params);
        let ws = CycleWorkspace::<f64>::for_plan(&plan);
        assert_eq!(ws.x.len(), plan.max_d + 1);
        assert_eq!(ws.w.len(), plan.max_bd + 1);
        // bw=64, tw=32 stages are all ≥ the packed gate: tile preallocated.
        assert_eq!(ws.tile.len(), (plan.max_bd + 1) * (plan.max_bd + 1));
        // Narrow plans skip the tile allocation.
        let narrow = LaunchPlan::for_problem(64, 4, &TuneParams { tpb: 32, tw: 2, max_blocks: 8 });
        assert!(CycleWorkspace::<f64>::for_plan(&narrow).tile.is_empty());
    }

    #[test]
    fn workspace_buffers_are_64_byte_aligned() {
        // The SIMD alignment contract: every buffer the lane kernels can
        // stream over starts on a cache line, through growth.
        let stage = Stage::new(40, 24); // above the packed gate
        let ws = CycleWorkspace::<f64>::new(&stage);
        assert_eq!(ws.x.as_ptr() as usize % 64, 0);
        assert_eq!(ws.w.as_ptr() as usize % 64, 0);
        assert_eq!(ws.tile.as_ptr() as usize % 64, 0);
        let mut grown = CycleWorkspace::<f32>::growable();
        grown.ensure_stage(&stage);
        assert_eq!(grown.x.as_ptr() as usize % 64, 0);
        assert_eq!(grown.w.as_ptr() as usize % 64, 0);
    }

    #[test]
    fn simd_packed_cycle_is_bitwise_equal_to_scalar_packed_cycle() {
        use crate::simd::{detect_isa, SimdIsa};
        // Full sweeps over shapes above the gate (b + d ≥ 48), every
        // host-constructible non-contracting spec vs the scalar loops.
        let mut rng = Xoshiro256::seed_from_u64(91);
        let isas = [SimdIsa::Portable, detect_isa().unwrap_or(SimdIsa::Portable)];
        for (n, b, d) in [(200usize, 32usize, 16usize), (280, 40, 24)] {
            let stage = Stage::new(b, d);
            let base = random_banded::<f64>(n, b, d, &mut rng);
            for isa in isas {
                let spec = SimdSpec::with_contract(isa, false);
                let mut a1 = base.clone();
                let mut a2 = base.clone();
                let mut ws1 = CycleWorkspace::new(&stage);
                let mut ws2 = CycleWorkspace::new(&stage);
                for k in 0..stage.num_sweeps(n) {
                    for c in 0..=stage.cmax(n, k) {
                        let task = stage.task(k, c);
                        let v1 = SharedBanded::new(&mut a1);
                        let v2 = SharedBanded::new(&mut a2);
                        // SAFETY: exclusive borrows, no concurrency.
                        unsafe {
                            exec_cycle_packed(&v1, &stage, &task, &mut ws1);
                            exec_cycle_packed_with(&v2, &stage, &task, &mut ws2, spec);
                        }
                    }
                }
                assert_eq!(a1, a2, "n={n} b={b} d={d} {isa:?}");
            }
        }
    }

    #[test]
    fn contracted_simd_cycle_stays_within_reduction_tolerance() {
        use crate::simd::SimdIsa;
        // The contracted path reassociates only the reductions; a chased
        // band must stay element-wise close to the scalar result and
        // still annihilate exactly (zeros are written, not computed).
        let mut rng = Xoshiro256::seed_from_u64(92);
        let (n, b, d) = (200usize, 32usize, 16usize);
        let stage = Stage::new(b, d);
        let base = random_banded::<f64>(n, b, d, &mut rng);
        let spec = SimdSpec::with_contract(SimdIsa::Portable, true);
        let mut a1 = base.clone();
        let mut a2 = base.clone();
        let mut ws1 = CycleWorkspace::new(&stage);
        let mut ws2 = CycleWorkspace::new(&stage);
        for k in 0..stage.num_sweeps(n) {
            for c in 0..=stage.cmax(n, k) {
                let task = stage.task(k, c);
                let v1 = SharedBanded::new(&mut a1);
                let v2 = SharedBanded::new(&mut a2);
                // SAFETY: exclusive borrows, no concurrency.
                unsafe {
                    exec_cycle_packed(&v1, &stage, &task, &mut ws1);
                    exec_cycle_packed_with(&v2, &stage, &task, &mut ws2, spec);
                }
            }
        }
        assert_eq!(a2.max_off_band(stage.b_out()), 0.0, "exact zeros survive contraction");
        let scale = a1.fro_norm();
        let mut worst = 0.0f64;
        for (x, y) in a1.data().iter().zip(a2.data().iter()) {
            worst = worst.max((x - y).abs());
        }
        // Loose sanity bound: reassociation perturbs each reflector at
        // O(d·eps); the chase amplifies but must stay far below 1e-8
        // relative for this well-conditioned random band.
        assert!(worst <= 1e-8 * scale, "worst {worst:e} vs scale {scale:e}");
    }

    #[test]
    fn right_op_annihilates_pivot_row_tail() {
        let mut rng = Xoshiro256::seed_from_u64(7);
        let (n, b, d) = (16, 5, 2);
        let stage = Stage::new(b, d);
        let mut a = random_banded::<f64>(n, b, d, &mut rng);
        let task = stage.task(0, 0);
        let mut ws = CycleWorkspace::new(&stage);
        let view = SharedBanded::new(&mut a);
        unsafe { exec_right(&view, &stage, &task, &mut ws) };
        // Row 0 entries beyond column b−d must now be exactly zero.
        for j in (stage.b_out() + 1)..=b {
            assert_eq!(a.get(0, j), 0.0, "col {j}");
        }
        // Column bulge created below the anchor diagonal.
        let j0 = task.anchor;
        let bulge: f64 = (j0 + 1..=j0 + d).map(|i| a.get(i, j0).abs()).sum();
        assert!(bulge > 0.0, "expected a column bulge at ({},..)", j0);
    }

    #[test]
    fn left_op_annihilates_column_bulge() {
        let mut rng = Xoshiro256::seed_from_u64(8);
        let (n, b, d) = (16, 5, 2);
        let stage = Stage::new(b, d);
        let mut a = random_banded::<f64>(n, b, d, &mut rng);
        let task = stage.task(0, 0);
        let mut ws = CycleWorkspace::new(&stage);
        exec_cycle(&mut a, &stage, &task, &mut ws);
        let j0 = task.anchor;
        for i in (j0 + 1)..=(j0 + d) {
            assert_eq!(a.get(i, j0), 0.0, "row {i}");
        }
        // Row bulge created beyond the band at row j0.
        let bulge: f64 = ((j0 + b + 1)..=(j0 + b + d).min(n - 1))
            .map(|j| a.get(j0, j).abs())
            .sum();
        assert!(bulge > 0.0, "expected a row bulge at row {}", j0);
    }

    #[test]
    fn cycle_preserves_frobenius_norm() {
        // Orthogonal transforms preserve ‖A‖_F.
        let mut rng = Xoshiro256::seed_from_u64(9);
        let (n, b, d) = (32, 6, 5);
        let stage = Stage::new(b, d);
        let mut a = random_banded::<f64>(n, b, d, &mut rng);
        let before = a.fro_norm();
        let mut ws = CycleWorkspace::new(&stage);
        for c in 0..=stage.cmax(n, 0) {
            exec_cycle(&mut a, &stage, &stage.task(0, c), &mut ws);
        }
        assert!((a.fro_norm() - before).abs() < 1e-10 * before.max(1.0));
    }

    #[test]
    fn cycle_near_matrix_edge_is_clamped() {
        // Last sweep: anchors close to n−1 exercise all the clamping —
        // through both paths.
        let mut rng = Xoshiro256::seed_from_u64(10);
        for (n, b, d) in [(12usize, 4usize, 3usize), (150, 30, 18)] {
            let stage = Stage::new(b, d);
            let mut a = random_banded::<f64>(n, b, d, &mut rng);
            let mut ws = CycleWorkspace::new(&stage);
            let k = stage.num_sweeps(n) - 1;
            for c in 0..=stage.cmax(n, k) {
                exec_cycle(&mut a, &stage, &stage.task(k, c), &mut ws);
            }
            // Row k must be reduced to bandwidth b−d.
            for j in (k + stage.b_out() + 1)..n {
                assert_eq!(a.get(k, j), 0.0, "({k},{j})");
            }
        }
    }
}
