//! The bulge-chasing cycle kernel — native-Rust analog of the paper's
//! Algorithm 2 (and of the L1 Pallas kernel in
//! `python/compile/kernels/bulge.py`).
//!
//! A cycle = one **right** op (annihilate `d` elements of the pivot row by
//! combining `d+1` columns) + one **left** op (annihilate the generated
//! column bulge by combining `d+1` rows). Both walk the banded storage
//! column-by-column so every inner loop runs over a *contiguous* memory
//! segment — the CPU analog of the coalesced/cache-line-aligned accesses
//! the paper engineers on GPUs.

use crate::banded::storage::Banded;
use crate::bulge::schedule::{CycleTask, Stage};
use crate::householder::make_reflector;
use crate::scalar::Scalar;

/// Reusable scratch for cycle execution (no allocation on the hot path —
/// the paper keeps these in shared memory / registers).
#[derive(Clone, Debug)]
pub struct CycleWorkspace<T> {
    /// Householder vector: x[0] = β after `make_reflector`, x[1..] = tail.
    x: Vec<T>,
    /// Per-row dot products for the right op.
    w: Vec<T>,
}

impl<T: Scalar> CycleWorkspace<T> {
    pub fn new(stage: &Stage) -> Self {
        Self {
            x: vec![T::zero(); stage.d + 1],
            w: vec![T::zero(); stage.b + stage.d + 1],
        }
    }

    /// Workspace sized for the largest stage of a plan.
    pub fn for_plan(plan: &[Stage]) -> Self {
        let d = plan.iter().map(|s| s.d).max().unwrap_or(1);
        let bd = plan.iter().map(|s| s.b + s.d).max().unwrap_or(2);
        Self { x: vec![T::zero(); d + 1], w: vec![T::zero(); bd + 1] }
    }
}

/// A raw, `Send + Sync` view over banded storage used by the launch-level
/// parallel executor. Safety rests on the schedule's disjointness
/// guarantee (proved in `schedule.rs` tests): simultaneous tasks touch
/// disjoint element sets, hence disjoint storage indices.
pub struct SharedBanded<T> {
    data: *mut T,
    n: usize,
    kd_super: usize,
    ld: usize,
}

unsafe impl<T: Send> Send for SharedBanded<T> {}
unsafe impl<T: Send> Sync for SharedBanded<T> {}

impl<T: Scalar> SharedBanded<T> {
    pub fn new(a: &mut Banded<T>) -> Self {
        Self {
            n: a.n(),
            kd_super: a.kd_super(),
            ld: a.ld(),
            data: a.data_mut().as_mut_ptr(),
        }
    }

    #[inline]
    fn idx(&self, i: usize, j: usize) -> usize {
        debug_assert!(j + self.kd_super >= i, "({i},{j}) below band");
        j * self.ld + (self.kd_super + i - j)
    }

    /// Contiguous mutable column segment (i0..=i1, j).
    ///
    /// # Safety
    /// Caller must guarantee no concurrent access to these elements.
    #[inline]
    unsafe fn col_segment_mut<'a>(&self, j: usize, i0: usize, i1: usize) -> &'a mut [T] {
        let lo = self.idx(i0, j);
        std::slice::from_raw_parts_mut(self.data.add(lo), i1 - i0 + 1)
    }

    #[inline]
    unsafe fn get(&self, i: usize, j: usize) -> T {
        *self.data.add(self.idx(i, j))
    }

    #[inline]
    unsafe fn set(&self, i: usize, j: usize, v: T) {
        *self.data.add(self.idx(i, j)) = v;
    }
}

/// Execute the **right** op of `task`: annihilate the pivot row's elements
/// in columns `anchor+1 ..= min(anchor+d, n−1)` into `(pivot, anchor)`,
/// applying the reflector to rows `pivot+1 ..= min(anchor+d, n−1)`.
///
/// # Safety
/// `view` elements inside the task's `right_access` rectangle must not be
/// accessed concurrently.
pub unsafe fn exec_right<T: Scalar>(
    view: &SharedBanded<T>,
    stage: &Stage,
    task: &CycleTask,
    ws: &mut CycleWorkspace<T>,
) {
    let n = view.n;
    let j0 = task.anchor;
    let rp = task.pivot_row;
    debug_assert!(j0 <= n - 2, "task anchor out of range");
    let jd = (j0 + stage.d).min(n - 1);
    let dd = jd - j0; // effective tail length (≥ 1 by schedule)
    if dd == 0 {
        return;
    }
    // Gather pivot-row segment x = A[rp, j0..=jd] (Alg. 2 line 3: the
    // cooperative load of the vector to reflect).
    let x = &mut ws.x[..=dd];
    for (jj, xv) in x.iter_mut().enumerate() {
        *xv = view.get(rp, j0 + jj);
    }
    let tau = make_reflector(x);
    // Write back β and exact zeros (Alg. 2 line 6).
    view.set(rp, j0, x[0]);
    for jj in 1..=dd {
        view.set(rp, j0 + jj, T::zero());
    }
    if tau == T::zero() {
        return;
    }
    // Apply (I − τ v vᵀ) from the right to rows rp+1..=r1 (Alg. 2 lines
    // 8–13; the TPB chunking happens one level up, in the executor).
    let r1 = jd; // min(j0 + d, n−1)
    let r0 = rp + 1;
    if r0 > r1 {
        return;
    }
    let rows = r1 - r0 + 1;
    let w = &mut ws.w[..rows];
    // Pass 1: w = Σ_jj v_jj · A[r0..=r1, j0+jj]   (column-major friendly)
    {
        let seg = view.col_segment_mut(j0, r0, r1);
        w.copy_from_slice(seg); // v_0 = 1
    }
    for jj in 1..=dd {
        let vj = x[jj];
        let seg = view.col_segment_mut(j0 + jj, r0, r1);
        for (wi, si) in w.iter_mut().zip(seg.iter()) {
            *wi = vj.mul_add(*si, *wi);
        }
    }
    // Scale by τ once.
    for wi in w.iter_mut() {
        *wi = tau * *wi;
    }
    // Pass 2: A[., j0+jj] −= w · v_jj
    {
        let seg = view.col_segment_mut(j0, r0, r1);
        for (si, wi) in seg.iter_mut().zip(w.iter()) {
            *si = *si - *wi;
        }
    }
    for jj in 1..=dd {
        let vj = x[jj];
        let seg = view.col_segment_mut(j0 + jj, r0, r1);
        for (si, wi) in seg.iter_mut().zip(w.iter()) {
            *si = *si - *wi * vj;
        }
    }
}

/// Execute the **left** op of `task`: annihilate the column bulge in rows
/// `anchor+1 ..= min(anchor+d, n−1)` of column `anchor` into the diagonal,
/// applying the reflector to columns `anchor+1 ..= min(anchor+b+d, n−1)`.
///
/// # Safety
/// `view` elements inside the task's `left_access` rectangle must not be
/// accessed concurrently.
pub unsafe fn exec_left<T: Scalar>(
    view: &SharedBanded<T>,
    stage: &Stage,
    task: &CycleTask,
    ws: &mut CycleWorkspace<T>,
) {
    let n = view.n;
    let j0 = task.anchor;
    let i1 = (j0 + stage.d).min(n - 1);
    let dd = i1 - j0;
    if dd == 0 {
        return;
    }
    // Gather pivot-column segment (contiguous) and reflect.
    let x = &mut ws.x[..=dd];
    {
        let seg = view.col_segment_mut(j0, j0, i1);
        x.copy_from_slice(seg);
    }
    let tau = make_reflector(x);
    {
        let seg = view.col_segment_mut(j0, j0, i1);
        seg[0] = x[0];
        for s in seg[1..].iter_mut() {
            *s = T::zero();
        }
    }
    if tau == T::zero() {
        return;
    }
    // Apply (I − τ v vᵀ) from the left to the remaining columns; each
    // column is one contiguous dot + update of ≤ d+1 elements — the
    // "one thread per column" granularity of Alg. 2 line 15.
    let c1 = (j0 + stage.b + stage.d).min(n - 1);
    for col in (j0 + 1)..=c1 {
        let seg = view.col_segment_mut(col, j0, i1);
        let mut dot = seg[0];
        for (vi, si) in x[1..].iter().zip(seg[1..].iter()) {
            dot = vi.mul_add(*si, dot);
        }
        let cfac = tau * dot;
        seg[0] = seg[0] - cfac;
        for (vi, si) in x[1..].iter().zip(seg[1..].iter_mut()) {
            *si = *si - cfac * *vi;
        }
    }
}

/// Execute a full cycle (right then left) on an exclusively-borrowed
/// matrix — the safe entry point used by the sequential executor.
pub fn exec_cycle<T: Scalar>(
    a: &mut Banded<T>,
    stage: &Stage,
    task: &CycleTask,
    ws: &mut CycleWorkspace<T>,
) {
    let view = SharedBanded::new(a);
    // SAFETY: exclusive &mut borrow ⇒ no concurrent access at all.
    unsafe {
        exec_right(&view, stage, task, ws);
        exec_left(&view, stage, task, ws);
    }
}

/// Execute a full cycle through a shared view — used by the launch-level
/// parallel executor.
///
/// # Safety
/// The task's access rectangles must be disjoint from those of every
/// other task executing concurrently (guaranteed by `Stage::tasks_at`).
pub unsafe fn exec_cycle_shared<T: Scalar>(
    view: &SharedBanded<T>,
    stage: &Stage,
    task: &CycleTask,
    ws: &mut CycleWorkspace<T>,
) {
    exec_right(view, stage, task, ws);
    exec_left(view, stage, task, ws);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::banded::dense::Dense;
    use crate::generate::random_banded;
    use crate::util::rng::Xoshiro256;

    /// Dense-oracle version of one cycle, built from the generic dense
    /// reflector helpers. Used to validate the banded kernel exactly.
    fn exec_cycle_dense(a: &mut Dense<f64>, stage: &Stage, task: &CycleTask) {
        use crate::householder::{apply_reflector_cols, apply_reflector_rows};
        let n = a.rows;
        let j0 = task.anchor;
        let rp = task.pivot_row;
        let jd = (j0 + stage.d).min(n - 1);
        let dd = jd - j0;
        if dd == 0 {
            return;
        }
        // Right op.
        let mut x: Vec<f64> = (0..=dd).map(|jj| a.get(rp, j0 + jj)).collect();
        let tau = make_reflector(&mut x);
        let v = x[1..].to_vec();
        apply_reflector_cols(a, tau, &v, j0, rp, jd);
        // force exact zeros like the banded kernel
        a.set(rp, j0, x[0]);
        for jj in 1..=dd {
            a.set(rp, j0 + jj, 0.0);
        }
        // Left op.
        let i1 = (j0 + stage.d).min(n - 1);
        let mut x: Vec<f64> = (j0..=i1).map(|i| a.get(i, j0)).collect();
        let tau = make_reflector(&mut x);
        let v = x[1..].to_vec();
        let c1 = (j0 + stage.b + stage.d).min(n - 1);
        apply_reflector_rows(a, tau, &v, j0, j0, c1);
        a.set(j0, j0, x[0]);
        for i in (j0 + 1)..=i1 {
            a.set(i, j0, 0.0);
        }
    }

    #[test]
    fn banded_cycle_matches_dense_oracle() {
        let mut rng = Xoshiro256::seed_from_u64(42);
        for (n, b, d) in [(24usize, 6usize, 3usize), (20, 4, 3), (16, 3, 1), (30, 8, 7)] {
            let stage = Stage::new(b, d);
            let mut banded = random_banded::<f64>(n, b, d, &mut rng);
            let mut dense = Dense::from_vec(n, n, banded.to_dense());
            let mut ws = CycleWorkspace::new(&stage);
            // Run the first few tasks of sweep 0 and compare after each.
            for c in 0..=stage.cmax(n, 0) {
                let task = stage.task(0, c);
                exec_cycle(&mut banded, &stage, &task, &mut ws);
                exec_cycle_dense(&mut dense, &stage, &task);
                let bd = banded.to_dense();
                for i in 0..n {
                    for j in 0..n {
                        let got = bd[i * n + j];
                        let want = dense.get(i, j);
                        assert!(
                            (got - want).abs() < 1e-12,
                            "n={n} b={b} d={d} cycle {c}: ({i},{j}) {got} vs {want}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn right_op_annihilates_pivot_row_tail() {
        let mut rng = Xoshiro256::seed_from_u64(7);
        let (n, b, d) = (16, 5, 2);
        let stage = Stage::new(b, d);
        let mut a = random_banded::<f64>(n, b, d, &mut rng);
        let task = stage.task(0, 0);
        let mut ws = CycleWorkspace::new(&stage);
        let view = SharedBanded::new(&mut a);
        unsafe { exec_right(&view, &stage, &task, &mut ws) };
        // Row 0 entries beyond column b−d must now be exactly zero.
        for j in (stage.b_out() + 1)..=b {
            assert_eq!(a.get(0, j), 0.0, "col {j}");
        }
        // Column bulge created below the anchor diagonal.
        let j0 = task.anchor;
        let bulge: f64 = (j0 + 1..=j0 + d).map(|i| a.get(i, j0).abs()).sum();
        assert!(bulge > 0.0, "expected a column bulge at ({},..)", j0);
    }

    #[test]
    fn left_op_annihilates_column_bulge() {
        let mut rng = Xoshiro256::seed_from_u64(8);
        let (n, b, d) = (16, 5, 2);
        let stage = Stage::new(b, d);
        let mut a = random_banded::<f64>(n, b, d, &mut rng);
        let task = stage.task(0, 0);
        let mut ws = CycleWorkspace::new(&stage);
        exec_cycle(&mut a, &stage, &task, &mut ws);
        let j0 = task.anchor;
        for i in (j0 + 1)..=(j0 + d) {
            assert_eq!(a.get(i, j0), 0.0, "row {i}");
        }
        // Row bulge created beyond the band at row j0.
        let bulge: f64 = ((j0 + b + 1)..=(j0 + b + d).min(n - 1))
            .map(|j| a.get(j0, j).abs())
            .sum();
        assert!(bulge > 0.0, "expected a row bulge at row {}", j0);
    }

    #[test]
    fn cycle_preserves_frobenius_norm() {
        // Orthogonal transforms preserve ‖A‖_F.
        let mut rng = Xoshiro256::seed_from_u64(9);
        let (n, b, d) = (32, 6, 5);
        let stage = Stage::new(b, d);
        let mut a = random_banded::<f64>(n, b, d, &mut rng);
        let before = a.fro_norm();
        let mut ws = CycleWorkspace::new(&stage);
        for c in 0..=stage.cmax(n, 0) {
            exec_cycle(&mut a, &stage, &stage.task(0, c), &mut ws);
        }
        assert!((a.fro_norm() - before).abs() < 1e-10 * before.max(1.0));
    }

    #[test]
    fn cycle_near_matrix_edge_is_clamped() {
        // Last sweep: anchors close to n−1 exercise all the clamping.
        let mut rng = Xoshiro256::seed_from_u64(10);
        let (n, b, d) = (12, 4, 3);
        let stage = Stage::new(b, d);
        let mut a = random_banded::<f64>(n, b, d, &mut rng);
        let mut ws = CycleWorkspace::new(&stage);
        let k = stage.num_sweeps(n) - 1;
        for c in 0..=stage.cmax(n, k) {
            exec_cycle(&mut a, &stage, &stage.task(k, c), &mut ws);
        }
        // Row k must be reduced to bandwidth b−d.
        for j in (k + stage.b_out() + 1)..n {
            assert_eq!(a.get(k, j), 0.0, "({k},{j})");
        }
    }
}
