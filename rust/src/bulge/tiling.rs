//! Successive band reduction driver (paper Fig. 1 / Algorithm 1 outer
//! loop): reduce the bandwidth by the inner tilewidth per stage until the
//! matrix is upper bidiagonal.

use crate::banded::storage::Banded;
use crate::bulge::schedule::{stage_plan, Stage};
use crate::bulge::stage::{run_stage_parallel, run_stage_sequential};
use crate::config::TuneParams;
use crate::scalar::Scalar;
use crate::util::threadpool::ThreadPool;

/// Outcome of a reduction: the bidiagonal (d, e) plus run statistics.
#[derive(Clone, Debug)]
pub struct ReductionResult<T> {
    pub diag: Vec<T>,
    pub superdiag: Vec<T>,
    pub stages: Vec<Stage>,
    pub total_launches: usize,
    pub total_tasks: usize,
}

/// Reduce `a` (upper-banded, bandwidth `bw`, working storage with
/// `kd_sub ≥ effective tilewidth`) to bidiagonal form in place,
/// sequentially. Returns the bidiagonal and schedule statistics.
pub fn reduce_to_bidiagonal<T: Scalar>(
    a: &mut Banded<T>,
    bw: usize,
    params: &TuneParams,
) -> ReductionResult<T> {
    let tw = params.effective_tw(bw);
    assert!(
        a.kd_sub() >= tw && a.kd_super() >= bw + tw,
        "storage too small for bw={bw}, tw={tw}: kd_sub={}, kd_super={}",
        a.kd_sub(),
        a.kd_super()
    );
    let plan = stage_plan(bw, tw);
    let n = a.n();
    let mut launches = 0;
    let mut tasks = 0;
    for stage in &plan {
        run_stage_sequential(a, stage);
        launches += stage.total_launches(n);
        tasks += crate::bulge::schedule::stage_task_count(stage, n);
    }
    let (diag, superdiag) = a.bidiagonal();
    ReductionResult { diag, superdiag, stages: plan, total_launches: launches, total_tasks: tasks }
}

/// Parallel (launch-level) variant: one barrier per launch, tasks of a
/// launch spread over `pool`, at most `params.max_blocks × units`
/// concurrent blocks (`units` = pool threads here).
pub fn reduce_to_bidiagonal_parallel<T: Scalar>(
    a: &mut Banded<T>,
    bw: usize,
    params: &TuneParams,
    pool: &ThreadPool,
) -> ReductionResult<T> {
    let tw = params.effective_tw(bw);
    assert!(a.kd_sub() >= tw && a.kd_super() >= bw + tw);
    let plan = stage_plan(bw, tw);
    let n = a.n();
    let capacity = params.max_blocks.saturating_mul(pool.len().max(1));
    let mut launches = 0;
    let mut tasks = 0;
    for stage in &plan {
        run_stage_parallel(a, stage, pool, capacity);
        launches += stage.total_launches(n);
        tasks += crate::bulge::schedule::stage_task_count(stage, n);
    }
    let (diag, superdiag) = a.bidiagonal();
    ReductionResult { diag, superdiag, stages: plan, total_launches: launches, total_tasks: tasks }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generate::random_banded;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn full_reduction_reaches_bidiagonal() {
        for (n, bw, tw) in [
            (32usize, 8usize, 4usize),
            (48, 8, 8), // tw clamps to 7
            (40, 12, 5),
            (30, 2, 1),
            (64, 16, 16),
            (33, 7, 2),
        ] {
            let mut rng = Xoshiro256::seed_from_u64(n as u64);
            let params = TuneParams { tpb: 32, tw, max_blocks: 192 };
            let eff = params.effective_tw(bw);
            let mut a = random_banded::<f64>(n, bw, eff, &mut rng);
            let before = a.fro_norm();
            let res = reduce_to_bidiagonal(&mut a, bw, &params);
            assert_eq!(a.max_off_band(1), 0.0, "n={n} bw={bw} tw={tw}: not bidiagonal");
            assert!((a.fro_norm() - before).abs() < 1e-9 * before.max(1.0));
            assert_eq!(res.diag.len(), n);
            assert_eq!(res.superdiag.len(), n - 1);
            assert!(!res.stages.is_empty());
        }
    }

    #[test]
    fn parallel_reduction_is_bitwise_equal_to_sequential() {
        let pool = ThreadPool::new(4);
        for (n, bw, tw) in [(64usize, 8usize, 4usize), (48, 6, 6), (56, 12, 3)] {
            let params = TuneParams { tpb: 32, tw, max_blocks: 4 };
            let eff = params.effective_tw(bw);
            let mut rng = Xoshiro256::seed_from_u64(77);
            let mut a1 = random_banded::<f64>(n, bw, eff, &mut rng);
            let mut a2 = a1.clone();
            let r1 = reduce_to_bidiagonal(&mut a1, bw, &params);
            let r2 = reduce_to_bidiagonal_parallel(&mut a2, bw, &params, &pool);
            assert_eq!(a1, a2, "n={n} bw={bw} tw={tw}");
            assert_eq!(r1.total_launches, r2.total_launches);
        }
    }

    #[test]
    fn already_bidiagonal_is_noop() {
        let n = 16;
        let params = TuneParams::default();
        let mut a = Banded::<f64>::for_reduction(n, 1, 1);
        for i in 0..n {
            a.set(i, i, 1.0 + i as f64);
            if i + 1 < n {
                a.set(i, i + 1, 0.5);
            }
        }
        let before = a.clone();
        let res = reduce_to_bidiagonal(&mut a, 1, &params);
        assert_eq!(a, before);
        assert_eq!(res.total_launches, 0);
        assert!(res.stages.is_empty());
    }

    #[test]
    fn tilewidth_does_not_change_singular_values_proxy() {
        // ‖A‖_F and ‖bidiagonal‖_F must agree across tilewidths (full
        // singular-value checks live in pipeline tests).
        let n = 40;
        let bw = 8;
        let mut rng = Xoshiro256::seed_from_u64(5);
        let base = random_banded::<f64>(n, bw, bw - 1, &mut rng);
        let norm0 = base.fro_norm();
        for tw in [1usize, 2, 4, 7] {
            let params = TuneParams { tpb: 32, tw, max_blocks: 192 };
            // Re-embed into storage sized for this tilewidth.
            let dense = base.to_dense();
            let mut a = Banded::from_dense(&dense, n, bw, params.effective_tw(bw));
            reduce_to_bidiagonal(&mut a, bw, &params);
            let bn: f64 = a.fro_norm();
            assert!(
                (bn - norm0).abs() < 1e-9 * norm0,
                "tw={tw}: norm drifted {bn} vs {norm0}"
            );
            assert_eq!(a.max_off_band(1), 0.0, "tw={tw}");
        }
    }
}
