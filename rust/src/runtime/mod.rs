//! PJRT runtime: load AOT-compiled HLO-text artifacts (produced by
//! `python/compile/aot.py`) and execute them on the CPU PJRT client via
//! the `xla` crate. See /opt/xla-example for the wiring this follows.

pub mod engine;
pub mod manifest;
#[cfg(not(feature = "pjrt"))]
pub mod stub;

pub use engine::{PjrtEngine, PjrtRunStats};
pub use manifest::{Manifest, StageArtifact};

/// Default artifact directory, overridable via BSVD_ARTIFACTS.
pub fn artifact_dir() -> std::path::PathBuf {
    std::env::var("BSVD_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}
