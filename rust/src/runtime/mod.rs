//! PJRT runtime: load AOT-compiled HLO-text artifacts (produced by
//! `python/compile/aot.py`) and execute them on the CPU PJRT client via
//! the `xla` crate (xla-rs).
//!
//! # Feature gating and the stub
//!
//! The `xla` crate needs an XLA toolchain to build, so it sits behind the
//! off-by-default `pjrt` cargo feature. Without the feature — the normal
//! offline build — [`stub`] compiles in its place: a type-for-type mirror
//! of the subset of xla-rs the engine uses, whose every entry point fails
//! at run time with a clear "build with `--features pjrt`" error before
//! any work is attempted. The engine therefore type-checks identically
//! against both, and `cargo build` / `cargo test` never require XLA. See
//! the "Backends" section of the top-level README for the selection
//! matrix and `docs/backends.md` for the execution contract.
//!
//! Plan-driven execution lives in [`crate::backend::PjrtBackend`]; this
//! module owns artifact loading ([`Manifest`]), compilation, and the raw
//! per-launch / fused execution primitives ([`PjrtEngine`]).

pub mod engine;
pub mod manifest;
#[cfg(not(feature = "pjrt"))]
pub mod stub;

pub use engine::{PjrtEngine, PjrtRunStats};
pub use manifest::{Manifest, StageArtifact};

/// Default artifact directory (`artifacts/`), overridable without a
/// rebuild via the `BSVD_ARTIFACTS` environment variable. Artifacts are
/// produced by `python/compile/aot.py` (`make artifacts`).
pub fn artifact_dir() -> std::path::PathBuf {
    std::env::var("BSVD_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}
