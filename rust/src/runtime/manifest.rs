//! Artifact manifest parsing.
//!
//! `python/compile/aot.py` writes one `manifest_n{n}_bw{bw}_tw{tw}.txt`
//! per compiled variant: simple `key=value` tokens, one logical record
//! per line (`stage …` lines describe per-stage artifacts). Kept as a
//! line format rather than JSON so the runtime needs no JSON parser.

use crate::error::{Error, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One bandwidth stage's artifacts.
#[derive(Clone, Debug)]
pub struct StageArtifact {
    pub index: usize,
    pub b: usize,
    pub d: usize,
    pub launches: usize,
    pub slots: usize,
    /// Per-launch executable file name ((storage, t) -> storage).
    pub cycle_file: String,
    /// Fused whole-stage executable file name (storage -> storage).
    pub fused_file: Option<String>,
}

/// A compiled (n, bw, tw) variant.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub n: usize,
    pub bw: usize,
    pub tw: usize,
    pub ld: usize,
    pub kd_super: usize,
    pub kd_sub: usize,
    pub tpb: usize,
    pub stages: Vec<StageArtifact>,
    /// Directory the manifest was loaded from (for resolving files).
    pub dir: PathBuf,
}

fn kv(tokens: &[&str]) -> HashMap<String, String> {
    tokens
        .iter()
        .filter_map(|t| t.split_once('='))
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect()
}

fn req(map: &HashMap<String, String>, key: &str) -> Result<usize> {
    map.get(key)
        .and_then(|v| v.parse().ok())
        .ok_or_else(|| Error::Config(format!("manifest missing/invalid key {key:?}")))
}

impl Manifest {
    /// Parse manifest text (see aot.py for the writer).
    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut top: HashMap<String, String> = HashMap::new();
        let mut stages = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let tokens: Vec<&str> = line.split_whitespace().collect();
            if tokens[0] == "stage" {
                let m = kv(&tokens[1..]);
                stages.push(StageArtifact {
                    index: req(&m, "index")?,
                    b: req(&m, "b")?,
                    d: req(&m, "d")?,
                    launches: req(&m, "launches")?,
                    slots: req(&m, "slots")?,
                    cycle_file: m
                        .get("cycle")
                        .cloned()
                        .ok_or_else(|| Error::Config("stage missing cycle file".into()))?,
                    fused_file: m.get("fused").filter(|s| !s.is_empty()).cloned(),
                });
            } else {
                top.extend(kv(&tokens));
            }
        }
        let man = Manifest {
            n: req(&top, "n")?,
            bw: req(&top, "bw")?,
            tw: req(&top, "tw")?,
            ld: req(&top, "ld")?,
            kd_super: req(&top, "kd_super")?,
            kd_sub: req(&top, "kd_sub")?,
            tpb: req(&top, "tpb")?,
            stages,
            dir: dir.to_path_buf(),
        };
        if man.stages.is_empty() {
            return Err(Error::Config("manifest has no stages".into()));
        }
        // Cross-check against the Rust-side schedule (defense against
        // python/rust drift).
        let plan = crate::bulge::schedule::stage_plan(man.bw, man.tw);
        if plan.len() != man.stages.len() {
            return Err(Error::Config(format!(
                "manifest stage count {} != schedule {}",
                man.stages.len(),
                plan.len()
            )));
        }
        for (s, p) in man.stages.iter().zip(plan.iter()) {
            if s.b != p.b || s.d != p.d || s.launches != p.total_launches(man.n) {
                return Err(Error::Config(format!(
                    "manifest stage {} (b={}, d={}, launches={}) disagrees with schedule \
                     (b={}, d={}, launches={})",
                    s.index,
                    s.b,
                    s.d,
                    s.launches,
                    p.b,
                    p.d,
                    p.total_launches(man.n)
                )));
            }
        }
        Ok(man)
    }

    /// Conventional manifest file name for a variant.
    pub fn file_name(n: usize, bw: usize, tw: usize) -> String {
        format!("manifest_n{n}_bw{bw}_tw{tw}.txt")
    }

    /// Load a variant manifest from an artifact directory.
    pub fn load(dir: &Path, n: usize, bw: usize, tw: usize) -> Result<Self> {
        let path = dir.join(Self::file_name(n, bw, tw));
        let text = std::fs::read_to_string(&path).map_err(|_| Error::ArtifactMissing {
            path: path.display().to_string(),
            variant: format!("n={n} bw={bw} tw={tw}"),
        })?;
        Self::parse(&text, dir)
    }

    pub fn cycle_path(&self, stage: usize) -> PathBuf {
        self.dir.join(&self.stages[stage].cycle_file)
    }

    pub fn fused_path(&self, stage: usize) -> Option<PathBuf> {
        self.stages[stage].fused_file.as_ref().map(|f| self.dir.join(f))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
version=1
n=96
bw=6
tw=3
ld=13
kd_super=9
kd_sub=3
dtype=f32
tpb=32
stages=2
stage index=0 b=6 d=3 launches=274 slots=16 cycle=c0.hlo.txt fused=s0.hlo.txt
stage index=1 b=3 d=2 launches=280 slots=31 cycle=c1.hlo.txt fused=
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!((m.n, m.bw, m.tw, m.ld), (96, 6, 3, 13));
        assert_eq!(m.stages.len(), 2);
        assert_eq!(m.stages[0].cycle_file, "c0.hlo.txt");
        assert_eq!(m.stages[0].fused_file.as_deref(), Some("s0.hlo.txt"));
        assert!(m.stages[1].fused_file.is_none());
        assert_eq!(m.cycle_path(1), Path::new("/tmp/a").join("c1.hlo.txt"));
    }

    #[test]
    fn rejects_schedule_mismatch() {
        let bad = SAMPLE.replace("launches=274", "launches=999");
        let err = Manifest::parse(&bad, Path::new(".")).unwrap_err();
        assert!(err.to_string().contains("disagrees"), "{err}");
    }

    #[test]
    fn rejects_missing_keys() {
        assert!(Manifest::parse("n=4\n", Path::new(".")).is_err());
    }

    #[test]
    fn file_name_convention_matches_aot() {
        assert_eq!(Manifest::file_name(256, 8, 4), "manifest_n256_bw8_tw4.txt");
    }
}
