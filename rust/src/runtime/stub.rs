//! Compile-time stand-in for the `xla` crate (xla-rs) used when the
//! `pjrt` feature is disabled — the default, since the offline build
//! environment has no XLA toolchain.
//!
//! The type surface mirrors exactly the subset of xla-rs the engine
//! uses, so `engine.rs` type-checks identically against both; every
//! entry point fails at run time with a clear error before any real
//! work could be attempted (`PjRtClient::cpu` is the constructor, so an
//! engine can never be built on the stub).

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error` (message-only).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unsupported<T>() -> Result<T, Error> {
    Err(Error(
        "PJRT support not compiled in: build with `--features pjrt` and a vendored \
         `xla` crate (see rust/Cargo.toml)"
            .to_string(),
    ))
}

pub struct PjRtClient;
pub struct PjRtLoadedExecutable;
pub struct PjRtBuffer;
pub struct HloModuleProto;
pub struct XlaComputation;
pub struct Literal;

impl PjRtClient {
    pub fn cpu() -> Result<Self, Error> {
        unsupported()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unsupported()
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        unsupported()
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unsupported()
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unsupported()
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &Path) -> Result<Self, Error> {
        unsupported()
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

impl Literal {
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unsupported()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_loudly() {
        let err = PjRtClient::cpu().err().expect("stub must not construct");
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
