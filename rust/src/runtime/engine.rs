//! PJRT execution engine: load AOT HLO-text artifacts, compile them on
//! the CPU PJRT client, and run banded reductions from the Rust hot path
//! (python never executes at run time).
//!
//! Two execution modes, matching the two artifact kinds:
//! - **per-cycle**: the coordinator drives one `execute` per kernel
//!   launch ((storage, t) -> storage), keeping the storage buffer
//!   device-resident between launches (`execute_b` chaining).
//! - **fused**: one `execute` per bandwidth stage (the whole launch loop
//!   is a `fori_loop` inside the artifact).

use crate::banded::storage::Banded;
use crate::error::{Error, Result};
use crate::runtime::manifest::Manifest;
#[cfg(not(feature = "pjrt"))]
use crate::runtime::stub as xla;
use crate::scalar::Scalar;
use std::path::Path;
use std::time::{Duration, Instant};

/// Statistics of one PJRT-backed reduction.
#[derive(Clone, Debug, Default)]
pub struct PjrtRunStats {
    pub launches: usize,
    pub stages: usize,
    pub compile_time: Duration,
    pub exec_time: Duration,
    /// Host<->device transfer time (initial upload + final download).
    pub transfer_time: Duration,
}

/// A loaded variant: compiled executables for every stage.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cycle_exes: Vec<xla::PjRtLoadedExecutable>,
    fused_exes: Vec<Option<xla::PjRtLoadedExecutable>>,
    pub compile_time: Duration,
}

fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path).map_err(|e| {
        Error::Pjrt(format!("loading {}: {e}", path.display()))
    })?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

impl PjrtEngine {
    /// Load and compile every artifact of variant (n, bw, tw) from `dir`.
    pub fn load(dir: &Path, n: usize, bw: usize, tw: usize) -> Result<Self> {
        let manifest = Manifest::load(dir, n, bw, tw)?;
        let client = xla::PjRtClient::cpu()?;
        let t0 = Instant::now();
        let mut cycle_exes = Vec::new();
        let mut fused_exes = Vec::new();
        for i in 0..manifest.stages.len() {
            cycle_exes.push(compile(&client, &manifest.cycle_path(i))?);
            fused_exes.push(match manifest.fused_path(i) {
                Some(p) => Some(compile(&client, &p)?),
                None => None,
            });
        }
        let compile_time = t0.elapsed();
        Ok(Self { client, manifest, cycle_exes, fused_exes, compile_time })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// True if every stage has a fused whole-stage executable.
    pub fn has_fused(&self) -> bool {
        self.fused_exes.iter().all(|e| e.is_some())
    }

    fn upload(&self, storage: &[f32]) -> Result<xla::PjRtBuffer> {
        let m = &self.manifest;
        Ok(self
            .client
            .buffer_from_host_buffer::<f32>(storage, &[m.n, m.ld], None)?)
    }

    /// Unwrap the (single-output tuple) result of an execute call.
    fn first_out(mut outs: Vec<Vec<xla::PjRtBuffer>>) -> Result<xla::PjRtBuffer> {
        let replica = outs
            .pop()
            .ok_or_else(|| Error::Pjrt("no replica outputs".into()))?;
        replica
            .into_iter()
            .next()
            .ok_or_else(|| Error::Pjrt("no output buffers".into()))
    }

    fn download(&self, buf: &xla::PjRtBuffer, out: &mut Vec<f32>) -> Result<()> {
        // Artifacts are lowered with return_tuple=False: the output is a
        // bare f32[n, ld] array.
        let lit = buf.to_literal_sync()?;
        *out = lit.to_vec::<f32>()?;
        Ok(())
    }

    /// Upload a flat f32 storage buffer (the `(ld × n)` column-major
    /// artifact layout) to a device-resident buffer. Entry point for the
    /// plan-driven executor (`crate::backend::PjrtBackend`), which keeps
    /// one such buffer alive per plan problem.
    pub(crate) fn upload_flat(&self, storage: &[f32]) -> Result<xla::PjRtBuffer> {
        self.upload(storage)
    }

    /// Download a device-resident storage buffer into `out`.
    pub(crate) fn download_flat(&self, buf: &xla::PjRtBuffer, out: &mut Vec<f32>) -> Result<()> {
        self.download(buf, out)
    }

    /// Execute one plan launch — stage `si` at global cycle `t` — on a
    /// device-resident storage buffer, returning the chained output
    /// buffer. The storage never round-trips to the host between
    /// launches; only the 4-byte cycle index is uploaded per call.
    pub(crate) fn execute_cycle_step(
        &self,
        buf: xla::PjRtBuffer,
        si: usize,
        t: usize,
    ) -> Result<xla::PjRtBuffer> {
        let exe = &self.cycle_exes[si];
        let t_buf = self
            .client
            .buffer_from_host_buffer::<i32>(&[t as i32], &[], None)?;
        Self::first_out(exe.execute_b::<xla::PjRtBuffer>(&[buf, t_buf])?)
    }

    /// Run the full reduction with per-launch executables, keeping the
    /// storage buffer device-resident; `on_launch` is invoked once per
    /// cycle index with (stage, t), including empty ramp cycles. This is
    /// the legacy manifest-driven loop — plan-driven execution (which
    /// skips empty cycles and supports multi-problem plans) lives in
    /// `crate::backend::PjrtBackend` on top of
    /// `PjrtEngine::execute_cycle_step`.
    pub fn reduce_per_cycle(
        &self,
        storage: &mut Vec<f32>,
        mut on_launch: impl FnMut(usize, usize),
    ) -> Result<PjrtRunStats> {
        let mut stats = PjrtRunStats { stages: self.manifest.stages.len(), ..Default::default() };
        let t0 = Instant::now();
        let mut buf = self.upload(storage)?;
        stats.transfer_time += t0.elapsed();

        for (si, stage) in self.manifest.stages.iter().enumerate() {
            let exe = &self.cycle_exes[si];
            for t in 0..stage.launches {
                let t0 = Instant::now();
                let t_buf = self
                    .client
                    .buffer_from_host_buffer::<i32>(&[t as i32], &[], None)?;
                let out = exe.execute_b::<xla::PjRtBuffer>(&[buf, t_buf])?;
                buf = Self::first_out(out)?;
                stats.exec_time += t0.elapsed();
                stats.launches += 1;
                on_launch(si, t);
            }
        }
        let t0 = Instant::now();
        self.download(&buf, storage)?;
        stats.transfer_time += t0.elapsed();
        Ok(stats)
    }

    /// Run the full reduction with fused whole-stage executables: one
    /// PJRT call per stage (the optimized path).
    pub fn reduce_fused(&self, storage: &mut Vec<f32>) -> Result<PjrtRunStats> {
        if !self.has_fused() {
            return Err(Error::Config(
                "variant compiled without fused stage artifacts (aot.py --no-fused)".into(),
            ));
        }
        let mut stats = PjrtRunStats { stages: self.manifest.stages.len(), ..Default::default() };
        let t0 = Instant::now();
        let mut buf = self.upload(storage)?;
        stats.transfer_time += t0.elapsed();
        for (si, stage) in self.manifest.stages.iter().enumerate() {
            let exe = self.fused_exes[si].as_ref().unwrap();
            let t0 = Instant::now();
            let out = exe.execute_b::<xla::PjRtBuffer>(&[buf])?;
            buf = Self::first_out(out)?;
            stats.exec_time += t0.elapsed();
            stats.launches += stage.launches;
        }
        let t0 = Instant::now();
        self.download(&buf, storage)?;
        stats.transfer_time += t0.elapsed();
        Ok(stats)
    }

    /// Convenience: reduce a [`Banded`] matrix in place through PJRT.
    /// The matrix must match the loaded variant's (n, bw, tw) layout.
    pub fn reduce_banded<T: Scalar>(
        &self,
        a: &mut Banded<T>,
        fused: bool,
    ) -> Result<PjrtRunStats> {
        let m = &self.manifest;
        if a.n() != m.n || a.ld() != m.ld || a.kd_super() != m.kd_super {
            return Err(Error::Config(format!(
                "matrix layout (n={}, ld={}, kd_super={}) does not match artifact variant \
                 (n={}, ld={}, kd_super={})",
                a.n(),
                a.ld(),
                a.kd_super(),
                m.n,
                m.ld,
                m.kd_super
            )));
        }
        let mut flat = a.to_f32_flat();
        let stats = if fused {
            self.reduce_fused(&mut flat)?
        } else {
            self.reduce_per_cycle(&mut flat, |_, _| {})?
        };
        a.from_f32_flat(&flat);
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    // PJRT round-trip tests live in `rust/tests/pjrt_roundtrip.rs` (they
    // need artifacts built by `make artifacts`).
}
