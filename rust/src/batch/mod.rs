//! Batched multi-problem reduction engine.
//!
//! The paper's launch loop saturates a GPU with *one* matrix only once
//! `n` is large (Table I); production workloads are usually the
//! opposite — many small-to-medium banded problems per call (covariance
//! spectra, per-head attention blocks, PDE operator sweeps). This module
//! reduces a heterogeneous set of [`Banded`] problems (mixed `n`, `bw`,
//! precision) *concurrently*: each problem's schedule is lowered to a
//! single-problem [`crate::plan::LaunchPlan`], and the batch interleaver
//! is a **plan merge** ([`crate::plan::LaunchPlan::merge`]) — per-problem
//! launch streams packed into shared launches under the joint `MaxBlocks`
//! capacity, exactly how a GPU co-schedules thread blocks from
//! independent grids. The engine then simply executes the merged plan.
//!
//! Correctness invariant (enforced by the merge): a shared launch
//! contains **at most one launch per problem**, so each problem's
//! launches still execute in stream order with a barrier between them.
//! Per-problem results are therefore bitwise identical to a solo
//! [`crate::coordinator::Coordinator`] run (property-tested in
//! `rust/tests/batch_equivalence.rs`); tasks from different problems
//! touch different buffers and are trivially disjoint.
//!
//! - [`BatchInput`]       — one problem: a banded matrix + its bandwidth,
//!   in any supported precision.
//! - [`BatchPlan`]        — the static packing plan: per-problem plans
//!   plus the merged shared-launch plan the engine executes.
//! - [`BatchCoordinator`] — owns the knobs and the selected
//!   [`crate::backend::Backend`]; executes the merged plan through it.
//!   The single-problem coordinator is the batch-size-1 case of this
//!   path, and any backend (threadpool, sequential, PJRT multi-buffer)
//!   can carry a merged plan.
//! - [`BatchReport`]      — per-problem bidiagonals + [`LaunchMetrics`],
//!   plus aggregate occupancy of the shared launches.
//!
//! [`LaunchMetrics`]: crate::coordinator::metrics::LaunchMetrics

pub(crate) mod engine;
mod plan;

pub use engine::{BatchCoordinator, BatchMetrics, BatchReport, ProblemReport};
pub use plan::{BatchPlan, ProblemPlan};

use crate::banded::storage::Banded;
use crate::config::TuneParams;
use crate::error::Result;
use crate::scalar::{Scalar, F16};

/// One problem of a batch: an owned banded matrix (reduced in place by
/// [`BatchCoordinator::run`]) plus its bandwidth, in one of the three
/// precisions of the paper's accuracy axis.
#[derive(Clone, Debug)]
pub enum BatchInput {
    F64 { a: Banded<f64>, bw: usize },
    F32 { a: Banded<f32>, bw: usize },
    F16 { a: Banded<F16>, bw: usize },
}

impl BatchInput {
    pub fn n(&self) -> usize {
        match self {
            BatchInput::F64 { a, .. } => a.n(),
            BatchInput::F32 { a, .. } => a.n(),
            BatchInput::F16 { a, .. } => a.n(),
        }
    }

    pub fn bw(&self) -> usize {
        match self {
            BatchInput::F64 { bw, .. } | BatchInput::F32 { bw, .. } | BatchInput::F16 { bw, .. } => {
                *bw
            }
        }
    }

    /// Paper-style precision label ("fp64" / "fp32" / "fp16").
    pub fn precision(&self) -> &'static str {
        match self {
            BatchInput::F64 { .. } => <f64 as Scalar>::NAME,
            BatchInput::F32 { .. } => <f32 as Scalar>::NAME,
            BatchInput::F16 { .. } => <F16 as Scalar>::NAME,
        }
    }

    /// Element size in bytes of the stored precision (traffic accounting
    /// and plan-cache keys).
    pub fn element_bytes(&self) -> usize {
        match self {
            BatchInput::F64 { .. } => <f64 as Scalar>::BYTES,
            BatchInput::F32 { .. } => <f32 as Scalar>::BYTES,
            BatchInput::F16 { .. } => <F16 as Scalar>::BYTES,
        }
    }

    /// Main diagonal and first superdiagonal, widened to f64.
    pub fn bidiagonal_f64(&self) -> (Vec<f64>, Vec<f64>) {
        fn widen<T: Scalar>(a: &Banded<T>) -> (Vec<f64>, Vec<f64>) {
            let (d, e) = a.bidiagonal();
            (
                d.iter().map(|v| v.to_f64()).collect(),
                e.iter().map(|v| v.to_f64()).collect(),
            )
        }
        match self {
            BatchInput::F64 { a, .. } => widen(a),
            BatchInput::F32 { a, .. } => widen(a),
            BatchInput::F16 { a, .. } => widen(a),
        }
    }

    /// Largest |element| outside the first `keep_super` superdiagonals.
    pub fn max_off_band(&self, keep_super: usize) -> f64 {
        match self {
            BatchInput::F64 { a, .. } => a.max_off_band(keep_super),
            BatchInput::F32 { a, .. } => a.max_off_band(keep_super),
            BatchInput::F16 { a, .. } => a.max_off_band(keep_super),
        }
    }

    /// Type-erased mutable view of the matrix — what the batch
    /// coordinator hands to the selected [`crate::backend::Backend`].
    pub(crate) fn as_band_storage_mut(&mut self) -> crate::backend::BandStorageMut<'_> {
        match self {
            BatchInput::F64 { a, .. } => crate::backend::BandStorageMut::F64(a),
            BatchInput::F32 { a, .. } => crate::backend::BandStorageMut::F32(a),
            BatchInput::F16 { a, .. } => crate::backend::BandStorageMut::F16(a),
        }
    }

    /// Check the problem's working storage against the tuning parameters,
    /// returning `(n, bw, effective_tw)` on success.
    pub(crate) fn validate(&self, params: &TuneParams) -> Result<(usize, usize, usize)> {
        fn check<T: Scalar>(
            a: &Banded<T>,
            bw: usize,
            params: &TuneParams,
        ) -> Result<(usize, usize, usize)> {
            let tw = params.effective_tw(bw);
            a.check_reduction_storage(bw, tw)?;
            Ok((a.n(), bw, tw))
        }
        match self {
            BatchInput::F64 { a, bw } => check(a, *bw, params),
            BatchInput::F32 { a, bw } => check(a, *bw, params),
            BatchInput::F16 { a, bw } => check(a, *bw, params),
        }
    }
}

impl From<(Banded<f64>, usize)> for BatchInput {
    fn from((a, bw): (Banded<f64>, usize)) -> Self {
        BatchInput::F64 { a, bw }
    }
}

impl From<(Banded<f32>, usize)> for BatchInput {
    fn from((a, bw): (Banded<f32>, usize)) -> Self {
        BatchInput::F32 { a, bw }
    }
}

impl From<(Banded<F16>, usize)> for BatchInput {
    fn from((a, bw): (Banded<F16>, usize)) -> Self {
        BatchInput::F16 { a, bw }
    }
}
