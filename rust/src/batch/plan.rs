//! Static planning for a batched reduction: per-problem stage plans and
//! launch/task totals, plus the joint capacity and packing policy the
//! engine will schedule under. Computed up front (all counts come from
//! the closed-form schedule, no matrix data is touched) so callers can
//! size a batch before committing to it.

use crate::batch::BatchInput;
use crate::bulge::schedule::{stage_plan, Stage};
use crate::config::{BatchConfig, PackingPolicy, TuneParams};
use crate::error::Result;

/// One problem's slice of the plan.
#[derive(Clone, Debug)]
pub struct ProblemPlan {
    /// Index into the batch (stable across plan/report).
    pub index: usize,
    pub n: usize,
    pub bw: usize,
    /// Effective inner tilewidth (clamped to `bw − 1`).
    pub tw: usize,
    pub precision: &'static str,
    pub stages: Vec<Stage>,
    /// Non-empty launches this problem will contribute.
    pub launches: usize,
    /// Total cycle-tasks (thread blocks) across all stages.
    pub tasks: usize,
}

/// The packing plan for a whole batch.
#[derive(Clone, Debug)]
pub struct BatchPlan {
    /// Joint MaxBlocks capacity shared launches are packed under.
    pub capacity: usize,
    pub policy: PackingPolicy,
    pub max_coresident: usize,
    pub problems: Vec<ProblemPlan>,
}

impl BatchPlan {
    /// Validate every input and lay out its schedule.
    pub fn new(inputs: &[BatchInput], params: &TuneParams, cfg: &BatchConfig) -> Result<Self> {
        let mut problems = Vec::with_capacity(inputs.len());
        for (index, input) in inputs.iter().enumerate() {
            let (n, bw, tw) = input.validate(params)?;
            let stages = stage_plan(bw, tw);
            let mut launches = 0;
            let mut tasks = 0;
            for stage in &stages {
                for t in 0..stage.total_launches(n) {
                    let count = stage.tasks_at_count(n, t);
                    if count > 0 {
                        launches += 1;
                        tasks += count;
                    }
                }
            }
            problems.push(ProblemPlan {
                index,
                n,
                bw,
                tw,
                precision: input.precision(),
                stages,
                launches,
                tasks,
            });
        }
        Ok(Self {
            capacity: params.max_blocks.max(1),
            policy: cfg.policy,
            max_coresident: cfg.max_coresident.max(1),
            problems,
        })
    }

    /// Total cycle-tasks across the batch.
    pub fn total_tasks(&self) -> usize {
        self.problems.iter().map(|p| p.tasks).sum()
    }

    /// Total per-problem launches — the shared-launch count when problems
    /// run strictly one after another (`max_coresident = 1`).
    pub fn total_launches(&self) -> usize {
        self.problems.iter().map(|p| p.launches).sum()
    }

    /// Lower bound on shared launches when the whole batch is co-resident
    /// and capacity never binds: streams advance in lockstep, so the
    /// longest stream dominates.
    pub fn min_shared_launches(&self) -> usize {
        self.problems.iter().map(|p| p.launches).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bulge::schedule::TaskStream;
    use crate::generate::random_banded;
    use crate::util::rng::Xoshiro256;

    fn inputs() -> Vec<BatchInput> {
        let mut rng = Xoshiro256::seed_from_u64(9);
        vec![
            BatchInput::from((random_banded::<f64>(48, 6, 3, &mut rng), 6)),
            BatchInput::from((random_banded::<f32>(32, 4, 3, &mut rng), 4)),
        ]
    }

    #[test]
    fn plan_counts_match_task_streams() {
        let params = TuneParams { tpb: 32, tw: 3, max_blocks: 16 };
        let plan = BatchPlan::new(&inputs(), &params, &BatchConfig::default()).unwrap();
        assert_eq!(plan.problems.len(), 2);
        assert_eq!(plan.capacity, 16);
        for p in &plan.problems {
            let stream = TaskStream::new(p.stages.clone(), p.n);
            let mut launches = 0;
            let mut tasks = 0;
            for (_, ts) in stream {
                launches += 1;
                tasks += ts.len();
            }
            assert_eq!(p.launches, launches, "problem {}", p.index);
            assert_eq!(p.tasks, tasks, "problem {}", p.index);
        }
        assert_eq!(plan.total_launches(), plan.problems.iter().map(|p| p.launches).sum());
        assert!(plan.min_shared_launches() <= plan.total_launches());
        assert!(plan.total_tasks() > 0);
    }

    #[test]
    fn plan_rejects_undersized_storage() {
        use crate::banded::storage::Banded;
        let params = TuneParams { tpb: 32, tw: 8, max_blocks: 16 };
        let bad = vec![BatchInput::from((Banded::<f64>::zeros(32, 9, 1), 8))];
        assert!(BatchPlan::new(&bad, &params, &BatchConfig::default()).is_err());
    }

    #[test]
    fn plan_records_precision_labels() {
        let params = TuneParams { tpb: 32, tw: 3, max_blocks: 16 };
        let plan = BatchPlan::new(&inputs(), &params, &BatchConfig::default()).unwrap();
        assert_eq!(plan.problems[0].precision, "fp64");
        assert_eq!(plan.problems[1].precision, "fp32");
    }
}
