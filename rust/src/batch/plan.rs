//! Static planning for a batched reduction, built on the launch-plan IR:
//! each problem is lowered to a single-problem [`LaunchPlan`], and the
//! batch interleaver is a *plan merge* ([`LaunchPlan::merge`]) — the
//! merged plan is the exact value the engine executes. Computed up front
//! (all counts come from the closed-form schedule, no matrix data is
//! touched) so callers can size a batch before committing to it.
//!
//! Lowering and merging are deterministic, so both route through the
//! service plan cache ([`PlanCache`]) — one lowering path shared by
//! `banded-svd batch` and `banded-svd serve`; repeated shapes and
//! repeated batch signatures are cache hits, not re-lowerings.

use crate::batch::BatchInput;
use crate::bulge::schedule::Stage;
use crate::config::{BatchConfig, PackingPolicy, TuneParams};
use crate::error::Result;
use crate::plan::LaunchPlan;
use crate::service::cache::{PlanCache, PlanKey};
use std::sync::Arc;

/// One problem's slice of the plan. All shape data lives in the
/// problem's own single-problem [`LaunchPlan`] (`part`); the accessors
/// delegate so there is exactly one source of truth.
#[derive(Clone, Debug)]
pub struct ProblemPlan {
    /// Index into the batch (stable across plan/report).
    pub index: usize,
    pub precision: &'static str,
    /// The problem's own single-problem launch plan (merge input; also
    /// sizes the runner's workspaces). Shared with the plan cache, hence
    /// the `Arc` — a cache hit hands out the same lowering.
    pub part: Arc<LaunchPlan>,
}

impl ProblemPlan {
    pub fn n(&self) -> usize {
        self.part.problems[0].n
    }

    pub fn bw(&self) -> usize {
        self.part.problems[0].bw
    }

    /// Effective inner tilewidth (clamped to `bw − 1`).
    pub fn tw(&self) -> usize {
        self.part.problems[0].tw
    }

    pub fn stages(&self) -> &[Stage] {
        &self.part.problems[0].stages
    }

    /// Non-empty launches this problem will contribute.
    pub fn launches(&self) -> usize {
        self.part.problems[0].launches
    }

    /// Total cycle-tasks (thread blocks) across all stages.
    pub fn tasks(&self) -> usize {
        self.part.problems[0].tasks
    }
}

/// The packing plan for a whole batch.
#[derive(Clone, Debug)]
pub struct BatchPlan {
    /// Joint MaxBlocks capacity shared launches are packed under.
    pub capacity: usize,
    pub policy: PackingPolicy,
    pub max_coresident: usize,
    pub problems: Vec<ProblemPlan>,
    /// The merged shared-launch plan the engine executes — per-problem
    /// streams interleaved under `capacity` by `policy`. Shared with the
    /// plan cache's merge-skeleton store.
    pub merged: Arc<LaunchPlan>,
}

impl BatchPlan {
    /// Validate every input, lower its schedule, and merge the streams.
    /// Uses a batch-private cache; [`crate::batch::BatchCoordinator`]
    /// routes through its own shared [`PlanCache`] instead
    /// ([`BatchPlan::new_cached`]) so repeated calls reuse lowerings.
    pub fn new(inputs: &[BatchInput], params: &TuneParams, cfg: &BatchConfig) -> Result<Self> {
        Self::new_cached(inputs, params, cfg, &PlanCache::new(inputs.len().max(1)))
    }

    /// [`BatchPlan::new`] through an explicit plan cache: every
    /// single-problem lowering is a [`PlanCache::plan_for`] lookup and
    /// the merge a [`PlanCache::merged_for`] lookup, so a repeated batch
    /// signature re-lowers nothing.
    pub fn new_cached(
        inputs: &[BatchInput],
        params: &TuneParams,
        cfg: &BatchConfig,
        cache: &PlanCache,
    ) -> Result<Self> {
        let capacity = params.capacity();
        let max_coresident = cfg.max_coresident.max(1);
        let mut precisions = Vec::with_capacity(inputs.len());
        let mut keys = Vec::with_capacity(inputs.len());
        let mut parts: Vec<Arc<LaunchPlan>> = Vec::with_capacity(inputs.len());
        for input in inputs {
            let (n, bw, _tw) = input.validate(params)?;
            precisions.push(input.precision());
            let key = PlanKey { n, bw, es: input.element_bytes(), params: *params };
            keys.push(key);
            parts.push(cache.plan_for(key));
        }
        let merged = cache.merged_for(&keys, &parts, capacity, cfg.policy, max_coresident);
        let problems = precisions
            .into_iter()
            .zip(parts)
            .enumerate()
            .map(|(index, (precision, part))| ProblemPlan { index, precision, part })
            .collect();
        Ok(Self { capacity, policy: cfg.policy, max_coresident, problems, merged })
    }

    /// Total cycle-tasks across the batch.
    pub fn total_tasks(&self) -> usize {
        self.problems.iter().map(|p| p.tasks()).sum()
    }

    /// Total per-problem launches — the shared-launch count when problems
    /// run strictly one after another (`max_coresident = 1`).
    pub fn total_launches(&self) -> usize {
        self.problems.iter().map(|p| p.launches()).sum()
    }

    /// Lower bound on shared launches when the whole batch is co-resident
    /// and capacity never binds: streams advance in lockstep, so the
    /// longest stream dominates.
    pub fn min_shared_launches(&self) -> usize {
        self.problems.iter().map(|p| p.launches()).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bulge::schedule::TaskStream;
    use crate::generate::random_banded;
    use crate::util::rng::Xoshiro256;

    fn inputs() -> Vec<BatchInput> {
        let mut rng = Xoshiro256::seed_from_u64(9);
        vec![
            BatchInput::from((random_banded::<f64>(48, 6, 3, &mut rng), 6)),
            BatchInput::from((random_banded::<f32>(32, 4, 3, &mut rng), 4)),
        ]
    }

    #[test]
    fn plan_counts_match_task_streams() {
        let params = TuneParams { tpb: 32, tw: 3, max_blocks: 16 };
        let plan = BatchPlan::new(&inputs(), &params, &BatchConfig::default()).unwrap();
        assert_eq!(plan.problems.len(), 2);
        assert_eq!(plan.capacity, 16);
        for p in &plan.problems {
            let stream = TaskStream::new(p.stages().to_vec(), p.n());
            let mut launches = 0;
            let mut tasks = 0;
            for (_, ts) in stream {
                launches += 1;
                tasks += ts.len();
            }
            assert_eq!(p.launches(), launches, "problem {}", p.index);
            assert_eq!(p.tasks(), tasks, "problem {}", p.index);
            assert_eq!(p.part.total_tasks(), tasks, "problem {}", p.index);
        }
        let per_problem: usize = plan.problems.iter().map(|p| p.launches()).sum();
        assert_eq!(plan.total_launches(), per_problem);
        assert!(plan.min_shared_launches() <= plan.total_launches());
        assert!(plan.total_tasks() > 0);
    }

    #[test]
    fn merged_plan_carries_every_task() {
        let params = TuneParams { tpb: 32, tw: 3, max_blocks: 16 };
        let plan = BatchPlan::new(&inputs(), &params, &BatchConfig::default()).unwrap();
        assert_eq!(plan.merged.total_tasks(), plan.total_tasks());
        assert_eq!(plan.merged.problems.len(), plan.problems.len());
        assert!(plan.merged.num_launches() >= plan.min_shared_launches());
        assert!(plan.merged.num_launches() <= plan.total_launches());
    }

    #[test]
    fn plan_rejects_undersized_storage() {
        use crate::banded::storage::Banded;
        let params = TuneParams { tpb: 32, tw: 8, max_blocks: 16 };
        let bad = vec![BatchInput::from((Banded::<f64>::zeros(32, 9, 1), 8))];
        assert!(BatchPlan::new(&bad, &params, &BatchConfig::default()).is_err());
    }

    #[test]
    fn cached_planning_reuses_lowered_parts() {
        let params = TuneParams { tpb: 32, tw: 3, max_blocks: 16 };
        let cache = PlanCache::new(8);
        let inputs = inputs();
        let first = BatchPlan::new_cached(&inputs, &params, &BatchConfig::default(), &cache)
            .unwrap();
        let second = BatchPlan::new_cached(&inputs, &params, &BatchConfig::default(), &cache)
            .unwrap();
        // Same Arc'd lowerings and merge skeleton, not re-lowered copies.
        for (a, b) in first.problems.iter().zip(second.problems.iter()) {
            assert!(Arc::ptr_eq(&a.part, &b.part), "problem {}", a.index);
        }
        assert!(Arc::ptr_eq(&first.merged, &second.merged));
        let stats = cache.stats();
        assert_eq!(stats.plan_misses, 2);
        assert_eq!(stats.plan_hits, 2);
        assert_eq!(stats.merge_misses, 1);
        assert_eq!(stats.merge_hits, 1);
        // And the uncached constructor produces the identical plan value.
        let direct = BatchPlan::new(&inputs, &params, &BatchConfig::default()).unwrap();
        assert_eq!(*direct.merged, *first.merged);
    }

    #[test]
    fn plan_records_precision_labels() {
        let params = TuneParams { tpb: 32, tw: 3, max_blocks: 16 };
        let plan = BatchPlan::new(&inputs(), &params, &BatchConfig::default()).unwrap();
        assert_eq!(plan.problems[0].precision, "fp64");
        assert_eq!(plan.problems[1].precision, "fp32");
    }
}
