//! The interleaved launch loop shared by the batch coordinator and the
//! single-problem coordinator (which is the batch-size-1 case).
//!
//! Each co-resident problem owns a [`TaskStream`]; every *shared launch*
//! pops at most one launch from each selected stream, flattens the tasks
//! into one list, and dispatches it over the thread pool with a single
//! barrier — the CPU analog of co-scheduling thread blocks from
//! independent grids under the joint MaxBlocks capacity.

use crate::banded::storage::Banded;
use crate::batch::plan::BatchPlan;
use crate::batch::BatchInput;
use crate::bulge::cycle::{exec_cycle_shared, CycleWorkspace, SharedBanded};
use crate::bulge::schedule::{stage_plan, CycleTask, Stage, TaskStream};
use crate::config::{BatchConfig, PackingPolicy, TuneParams};
use crate::coordinator::metrics::LaunchMetrics;
use crate::error::Result;
use crate::scalar::Scalar;
use crate::util::threadpool::ThreadPool;
use std::marker::PhantomData;
use std::time::{Duration, Instant};

/// Type-erased executor for one problem's cycle-tasks (erases the scalar
/// type so problems of mixed precision share one launch loop).
trait ProblemExec: Sync {
    /// Execute `tasks` of stage `si` back-to-back on this problem.
    ///
    /// # Safety
    /// The tasks must be pairwise element-disjoint from every other task
    /// concurrently executing on the same problem (guaranteed when all
    /// come from a single `TaskStream` launch), and the problem's buffer
    /// must not be accessed otherwise for the duration of the call.
    unsafe fn exec_tasks(&self, si: usize, tasks: &[CycleTask]);
}

struct NativeExec<T> {
    view: SharedBanded<T>,
    plan: Vec<Stage>,
}

impl<T: Scalar> ProblemExec for NativeExec<T> {
    unsafe fn exec_tasks(&self, si: usize, tasks: &[CycleTask]) {
        let stage = self.plan[si];
        let mut ws = CycleWorkspace::new(&stage);
        for task in tasks {
            exec_cycle_shared(&self.view, &stage, task, &mut ws);
        }
    }
}

/// One problem admitted to the interleaved launch loop: its erased
/// executor, its launch stream, and its private metrics.
pub(crate) struct Runner<'a> {
    exec: Box<dyn ProblemExec + Sync + 'a>,
    pub(crate) stream: TaskStream,
    pub(crate) metrics: LaunchMetrics,
    /// Exclusive borrow of the underlying matrix for the runner's life.
    _borrow: PhantomData<&'a mut ()>,
}

impl<'a> Runner<'a> {
    pub(crate) fn new<T: Scalar>(
        a: &'a mut Banded<T>,
        bw: usize,
        params: &TuneParams,
    ) -> Result<Self> {
        let tw = params.effective_tw(bw);
        a.check_reduction_storage(bw, tw)?;
        let n = a.n();
        let plan = stage_plan(bw, tw);
        let stream = TaskStream::new(plan.clone(), n);
        let exec: Box<dyn ProblemExec + Sync + 'a> =
            Box::new(NativeExec { view: SharedBanded::new(a), plan });
        Ok(Self { exec, stream, metrics: LaunchMetrics::default(), _borrow: PhantomData })
    }
}

/// Aggregate accounting of the shared launch loop.
#[derive(Clone, Debug)]
pub struct BatchMetrics {
    /// Shared launches (each = one pool dispatch + one barrier).
    pub aggregate: LaunchMetrics,
    /// Joint MaxBlocks capacity the launches were packed under.
    pub capacity: usize,
    pub problems: usize,
    /// Shared launches that carried tasks from more than one problem.
    pub co_scheduled_launches: usize,
    pub max_problems_per_launch: usize,
}

impl BatchMetrics {
    /// Mean fraction of the capacity filled per shared launch (> 1.0 when
    /// software loop unrolling engages).
    pub fn occupancy_ratio(&self) -> f64 {
        self.aggregate.occupancy_ratio(self.capacity)
    }
}

/// Drive every runner's stream to completion, packing launches into
/// shared launches under `capacity` according to `policy`. At most
/// `max_coresident` problems are interleaved at a time; later problems
/// are admitted as earlier ones finish.
pub(crate) fn run_interleaved(
    runners: &mut [Runner<'_>],
    pool: &ThreadPool,
    capacity: usize,
    policy: PackingPolicy,
    max_coresident: usize,
) -> BatchMetrics {
    let capacity = capacity.max(1);
    let max_coresident = max_coresident.max(1);
    let mut bm = BatchMetrics {
        aggregate: LaunchMetrics::default(),
        capacity,
        problems: runners.len(),
        co_scheduled_launches: 0,
        max_problems_per_launch: 0,
    };
    let mut rotation = 0usize;
    // Flattened shared launch, rebuilt every iteration: `keys[i]` names
    // the (problem, stage) of `tasks[i]`; same-key runs are contiguous so
    // workers can share one workspace per run.
    let mut keys: Vec<(u32, u32)> = Vec::new();
    let mut tasks: Vec<CycleTask> = Vec::new();
    loop {
        // Admission window: the first `max_coresident` unfinished problems.
        let admitted: Vec<usize> = (0..runners.len())
            .filter(|&p| !runners[p].stream.is_done())
            .take(max_coresident)
            .collect();
        if admitted.is_empty() {
            break;
        }
        let order: Vec<usize> = match policy {
            PackingPolicy::RoundRobin => {
                let start = rotation % admitted.len();
                admitted[start..].iter().chain(admitted[..start].iter()).copied().collect()
            }
            PackingPolicy::GreedyFill => {
                let mut by_size = admitted.clone();
                by_size.sort_by_key(|&p| std::cmp::Reverse(runners[p].stream.peek_count()));
                by_size
            }
        };
        rotation = rotation.wrapping_add(1);

        // Select: pop at most one launch per problem while it fits (the
        // first always fits, guaranteeing progress).
        keys.clear();
        tasks.clear();
        let mut selected = 0usize;
        for &p in &order {
            let count = runners[p].stream.peek_count();
            if !tasks.is_empty() && tasks.len() + count > capacity {
                continue;
            }
            let (si, mut ts) = runners[p].stream.next_launch().expect("admitted => not done");
            runners[p].metrics.record_launch(ts.len(), capacity);
            for task in ts.drain(..) {
                keys.push((p as u32, si as u32));
                tasks.push(task);
            }
            selected += 1;
            if tasks.len() >= capacity {
                break;
            }
        }
        bm.aggregate.record_launch(tasks.len(), capacity);
        if selected > 1 {
            bm.co_scheduled_launches += 1;
        }
        bm.max_problems_per_launch = bm.max_problems_per_launch.max(selected);

        // Execute: one pool dispatch, one barrier — tasks within the
        // shared launch are disjoint (schedule property within a problem,
        // separate buffers across problems).
        let chunks = tasks.len().min(capacity).min(pool.len().max(1));
        let keys_ref: &[(u32, u32)] = &keys;
        let tasks_ref: &[CycleTask] = &tasks;
        let runners_ref: &[Runner<'_>] = runners;
        pool.for_each_chunk(tasks.len(), chunks, |range| {
            let mut i = range.start;
            while i < range.end {
                let key = keys_ref[i];
                let mut j = i + 1;
                while j < range.end && keys_ref[j] == key {
                    j += 1;
                }
                let (p, si) = (key.0 as usize, key.1 as usize);
                // SAFETY: within a shared launch every task is disjoint
                // from every other (see above); launches are ordered by
                // the pool barrier.
                unsafe { runners_ref[p].exec.exec_tasks(si, &tasks_ref[i..j]) };
                i = j;
            }
        });
    }
    bm
}

/// Per-problem slice of a [`BatchReport`].
#[derive(Clone, Debug)]
pub struct ProblemReport {
    pub n: usize,
    pub bw: usize,
    pub precision: &'static str,
    pub diag: Vec<f64>,
    pub superdiag: Vec<f64>,
    /// Largest |element| outside the bidiagonal after the run.
    pub residual_off_band: f64,
    pub metrics: LaunchMetrics,
}

/// Outcome of a batched reduction.
#[derive(Clone, Debug)]
pub struct BatchReport {
    pub plan: BatchPlan,
    pub problems: Vec<ProblemReport>,
    pub metrics: BatchMetrics,
    pub wall: Duration,
}

impl BatchReport {
    /// Problems reduced per second of wall-clock.
    pub fn throughput(&self) -> f64 {
        let secs = self.wall.as_secs_f64();
        if secs > 0.0 {
            self.problems.len() as f64 / secs
        } else {
            0.0
        }
    }
}

/// The batch coordinator: tuning parameters, batch knobs, worker pool.
pub struct BatchCoordinator {
    pub params: TuneParams,
    pub cfg: BatchConfig,
    pool: ThreadPool,
}

impl BatchCoordinator {
    /// `threads == 0` uses all available hardware threads.
    pub fn new(params: TuneParams, cfg: BatchConfig, threads: usize) -> Self {
        Self { params, cfg, pool: ThreadPool::new(threads) }
    }

    pub fn pool(&self) -> &ThreadPool {
        &self.pool
    }

    fn capacity(&self) -> usize {
        self.params.max_blocks.max(1)
    }

    /// Validate the batch and lay out its packing plan without running it.
    pub fn plan(&self, inputs: &[BatchInput]) -> Result<BatchPlan> {
        BatchPlan::new(inputs, &self.params, &self.cfg)
    }

    /// Reduce every problem to bidiagonal form in place, interleaving
    /// their launch streams into shared launches.
    pub fn run(&self, inputs: &mut [BatchInput]) -> Result<BatchReport> {
        let plan = BatchPlan::new(inputs, &self.params, &self.cfg)?;
        let t_start = Instant::now();
        let mut runners: Vec<Runner<'_>> = Vec::with_capacity(inputs.len());
        for input in inputs.iter_mut() {
            runners.push(match input {
                BatchInput::F64 { a, bw } => Runner::new(a, *bw, &self.params)?,
                BatchInput::F32 { a, bw } => Runner::new(a, *bw, &self.params)?,
                BatchInput::F16 { a, bw } => Runner::new(a, *bw, &self.params)?,
            });
        }
        let mut metrics = run_interleaved(
            &mut runners,
            &self.pool,
            self.capacity(),
            self.cfg.policy,
            self.cfg.max_coresident,
        );
        let per_problem: Vec<LaunchMetrics> = runners.iter().map(|r| r.metrics.clone()).collect();
        drop(runners);
        let wall = t_start.elapsed();
        metrics.aggregate.wall = wall;
        let problems = inputs
            .iter()
            .zip(per_problem)
            .map(|(input, m)| {
                let (diag, superdiag) = input.bidiagonal_f64();
                ProblemReport {
                    n: input.n(),
                    bw: input.bw(),
                    precision: input.precision(),
                    diag,
                    superdiag,
                    residual_off_band: input.max_off_band(1),
                    metrics: m,
                }
            })
            .collect();
        Ok(BatchReport { plan, problems, metrics, wall })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Backend;
    use crate::coordinator::Coordinator;
    use crate::generate::random_banded;
    use crate::util::rng::Xoshiro256;

    fn mixed_batch(seed: u64) -> Vec<BatchInput> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        vec![
            BatchInput::from((random_banded::<f64>(64, 8, 4, &mut rng), 8)),
            BatchInput::from((random_banded::<f64>(40, 6, 4, &mut rng), 6)),
            BatchInput::from((random_banded::<f32>(48, 5, 4, &mut rng), 5)),
            BatchInput::from((random_banded::<crate::scalar::F16>(24, 3, 3, &mut rng), 3)),
        ]
    }

    fn params() -> TuneParams {
        TuneParams { tpb: 32, tw: 4, max_blocks: 24 }
    }

    #[test]
    fn batch_reduces_every_problem_exactly() {
        for policy in [PackingPolicy::RoundRobin, PackingPolicy::GreedyFill] {
            let cfg = BatchConfig { max_coresident: 8, policy };
            let coord = BatchCoordinator::new(params(), cfg, 4);
            let mut inputs = mixed_batch(11);
            let report = coord.run(&mut inputs).unwrap();
            assert_eq!(report.problems.len(), 4);
            for (i, p) in report.problems.iter().enumerate() {
                assert_eq!(p.residual_off_band, 0.0, "problem {i} ({policy:?})");
                assert_eq!(p.diag.len(), p.n);
                assert_eq!(p.superdiag.len(), p.n - 1);
                assert!(p.metrics.launches > 0);
            }
            assert_eq!(
                report.metrics.aggregate.tasks,
                report.plan.total_tasks(),
                "{policy:?}"
            );
        }
    }

    #[test]
    fn batched_f64_results_are_bitwise_equal_to_solo_runs() {
        let cfg = BatchConfig { max_coresident: 8, policy: PackingPolicy::RoundRobin };
        let batch_coord = BatchCoordinator::new(params(), cfg, 4);
        let solo_coord = Coordinator::new(params(), 4);
        let mut rng = Xoshiro256::seed_from_u64(21);
        let shapes = [(64usize, 8usize), (40, 6), (52, 9)];
        let mats: Vec<_> = shapes
            .iter()
            .map(|&(n, bw)| random_banded::<f64>(n, bw, params().effective_tw(bw), &mut rng))
            .collect();
        let mut inputs: Vec<BatchInput> = mats
            .iter()
            .zip(shapes.iter())
            .map(|(a, &(_, bw))| BatchInput::from((a.clone(), bw)))
            .collect();
        let report = batch_coord.run(&mut inputs).unwrap();
        for ((a, &(_, bw)), p) in mats.iter().zip(shapes.iter()).zip(report.problems.iter()) {
            let mut solo = a.clone();
            let r = solo_coord.reduce_native(&mut solo, bw, Backend::Parallel).unwrap();
            assert_eq!(r.diag, p.diag);
            assert_eq!(r.superdiag, p.superdiag);
            assert_eq!(r.metrics.launches, p.metrics.launches);
            assert_eq!(r.metrics.tasks, p.metrics.tasks);
        }
    }

    #[test]
    fn shared_launches_actually_co_schedule() {
        let cfg = BatchConfig { max_coresident: 8, policy: PackingPolicy::RoundRobin };
        let coord = BatchCoordinator::new(params(), cfg, 4);
        let mut inputs = mixed_batch(31);
        let report = coord.run(&mut inputs).unwrap();
        assert!(report.metrics.co_scheduled_launches > 0);
        assert!(report.metrics.max_problems_per_launch > 1);
        // Interleaving strictly beats running the problems back to back.
        assert!(report.metrics.aggregate.launches < report.plan.total_launches());
        assert!(report.metrics.aggregate.launches >= report.plan.min_shared_launches());
        assert!(report.metrics.occupancy_ratio() > 0.0);
        assert!(report.throughput() > 0.0);
    }

    #[test]
    fn max_coresident_one_serializes_the_batch() {
        let cfg = BatchConfig { max_coresident: 1, policy: PackingPolicy::GreedyFill };
        let coord = BatchCoordinator::new(params(), cfg, 2);
        let mut inputs = mixed_batch(41);
        let report = coord.run(&mut inputs).unwrap();
        assert_eq!(report.metrics.co_scheduled_launches, 0);
        assert_eq!(report.metrics.max_problems_per_launch, 1);
        assert_eq!(report.metrics.aggregate.launches, report.plan.total_launches());
        for p in &report.problems {
            assert_eq!(p.residual_off_band, 0.0);
        }
    }

    #[test]
    fn policies_agree_on_results() {
        let mk = |policy| {
            let cfg = BatchConfig { max_coresident: 8, policy };
            let coord = BatchCoordinator::new(params(), cfg, 4);
            let mut inputs = mixed_batch(51);
            coord.run(&mut inputs).unwrap()
        };
        let rr = mk(PackingPolicy::RoundRobin);
        let greedy = mk(PackingPolicy::GreedyFill);
        for (a, b) in rr.problems.iter().zip(greedy.problems.iter()) {
            assert_eq!(a.diag, b.diag);
            assert_eq!(a.superdiag, b.superdiag);
        }
    }

    #[test]
    fn undersized_storage_is_rejected_before_any_work() {
        use crate::banded::storage::Banded;
        let coord = BatchCoordinator::new(
            TuneParams { tpb: 32, tw: 8, max_blocks: 8 },
            BatchConfig::default(),
            1,
        );
        let mut inputs = vec![BatchInput::from((Banded::<f64>::zeros(32, 9, 1), 8))];
        assert!(coord.run(&mut inputs).is_err());
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let coord = BatchCoordinator::new(params(), BatchConfig::default(), 1);
        let report = coord.run(&mut []).unwrap();
        assert_eq!(report.problems.len(), 0);
        assert_eq!(report.metrics.aggregate.launches, 0);
    }
}
