//! The plan executor shared by the batch coordinator and the
//! single-problem coordinator (which is the batch-size-1 case).
//!
//! All scheduling decisions are made *before* execution: per-problem
//! launch streams are lowered to single-problem [`LaunchPlan`]s and
//! merged ([`LaunchPlan::merge`]) into one shared-launch plan under the
//! joint MaxBlocks capacity. [`execute_plan`] then walks that plan launch
//! by launch — one pinned pool dispatch + one barrier each — the CPU
//! analog of co-scheduling thread blocks from independent grids.
//!
//! Tasks are routed to pool slots by *column-window affinity*
//! ([`affinity_slot`]): the same (problem, window) lands on the same OS
//! thread across launches, so a chased window — and the slot's persistent
//! packed-tile workspace ([`WorkerLocal`]) — stays in one core's cache.

use crate::backend::{Backend, BandStorageMut, ThreadpoolBackend};
use crate::banded::storage::Banded;
use crate::batch::plan::BatchPlan;
use crate::batch::BatchInput;
use crate::bulge::cycle::{
    exec_cycle_shared_logged_with, exec_cycle_shared_with, stage_uses_packed, CycleWorkspace,
    SharedBanded, TaskCapture,
};
use crate::bulge::schedule::{CycleTask, Stage};
use crate::config::{BatchConfig, TuneParams};
use crate::coordinator::metrics::LaunchMetrics;
use crate::error::Result;
use crate::obs::{calibrate, trace};
use crate::plan::reflectors::LogView;
use crate::plan::{slot_bytes, LaunchPlan, ProblemShape, ReflectorLog};
use crate::service::cache::PlanCache;
use crate::scalar::Scalar;
use crate::simd::SimdSpec;
use crate::util::threadpool::{ThreadPool, WorkerLocal};
use std::any::{Any, TypeId};
use std::collections::HashMap;
use std::marker::PhantomData;
use std::time::{Duration, Instant};

/// Per-slot scratch shared by *every* problem of a run: one growable
/// [`CycleWorkspace`] per scalar type (at most three), created lazily and
/// grown on demand. A slot runs one task at a time, so one workspace per
/// precision is all it can ever use — memory stays `slots × precisions`
/// instead of `slots × problems`.
pub(crate) struct SlotScratch {
    by_type: HashMap<TypeId, Box<dyn Any + Send>>,
}

impl SlotScratch {
    pub(crate) fn new() -> Self {
        Self { by_type: HashMap::new() }
    }

    fn workspace<T: Scalar>(&mut self) -> &mut CycleWorkspace<T> {
        self.by_type
            .entry(TypeId::of::<T>())
            .or_insert_with(|| Box::new(CycleWorkspace::<T>::growable()))
            .downcast_mut::<CycleWorkspace<T>>()
            .expect("scratch entry keyed by its own TypeId")
    }
}

/// Type-erased executor for one problem's cycle-tasks (erases the scalar
/// type so problems of mixed precision share one launch loop).
trait ProblemExec: Sync {
    /// Execute one task of stage `si` using the calling slot's scratch.
    /// `ordinal` is the task's plan-order index within its problem —
    /// the position its reflector record occupies in an attached
    /// [`LogView`] (ignored when no log is attached).
    ///
    /// # Safety
    /// The task must be element-disjoint from every other task
    /// concurrently executing on the same problem (guaranteed within one
    /// plan launch), and the problem's buffer must not be otherwise
    /// accessed for the duration of the call.
    unsafe fn exec_task(
        &self,
        si: usize,
        task: &CycleTask,
        ordinal: usize,
        scratch: &mut SlotScratch,
    );

    /// Element size of the problem's scalar type (for traffic accounting).
    fn element_bytes(&self) -> usize;
}

struct NativeExec<T> {
    view: SharedBanded<T>,
    stages: Vec<Stage>,
    /// SIMD kernel selection for packed-path tasks —
    /// `SimdSpec::scalar()` on every backend except `SimdBackend`.
    spec: SimdSpec,
    /// Reflector capture destination (`Backend::execute_logged`), or
    /// `None` for plain value-only execution.
    log: Option<LogView>,
}

impl<T: Scalar> ProblemExec for NativeExec<T> {
    unsafe fn exec_task(
        &self,
        si: usize,
        task: &CycleTask,
        ordinal: usize,
        scratch: &mut SlotScratch,
    ) {
        let stage = &self.stages[si];
        let ws = scratch.workspace::<T>();
        ws.ensure_stage(stage);
        match self.log {
            Some(log) => {
                // SAFETY: each plan ordinal names exactly one task, so
                // this record is aliased by no concurrent task.
                let (right, left) = log.task_mut(ordinal);
                exec_cycle_shared_logged_with(
                    &self.view,
                    stage,
                    task,
                    ws,
                    self.spec,
                    TaskCapture { right, left },
                );
            }
            None => exec_cycle_shared_with(&self.view, stage, task, ws, self.spec),
        }
    }

    fn element_bytes(&self) -> usize {
        T::BYTES
    }
}

/// One problem admitted to the plan executor: its erased executor and its
/// private metrics. The launch stream itself lives in the merged
/// [`LaunchPlan`].
pub(crate) struct Runner<'a> {
    exec: Box<dyn ProblemExec + Sync + 'a>,
    pub(crate) metrics: LaunchMetrics,
    /// Exclusive borrow of the underlying matrix for the runner's life.
    _borrow: PhantomData<&'a mut ()>,
}

impl<'a> Runner<'a> {
    /// Build a runner for `a` against its plan shape (scalar kernels).
    pub(crate) fn new<T: Scalar>(a: &'a mut Banded<T>, shape: &ProblemShape) -> Result<Self> {
        Self::with_kernel(a, shape, SimdSpec::scalar())
    }

    /// Build a runner whose packed-path tasks run the SIMD kernels
    /// selected by `spec` — the seam `SimdBackend` threads its resolved
    /// spec through.
    pub(crate) fn with_kernel<T: Scalar>(
        a: &'a mut Banded<T>,
        shape: &ProblemShape,
        spec: SimdSpec,
    ) -> Result<Self> {
        Self::with_kernel_logged(a, shape, spec, None)
    }

    /// [`Runner::with_kernel`] with an optional reflector-log view the
    /// runner records every task's reflectors into (the capture side of
    /// `Backend::execute_logged`).
    pub(crate) fn with_kernel_logged<T: Scalar>(
        a: &'a mut Banded<T>,
        shape: &ProblemShape,
        spec: SimdSpec,
        log: Option<LogView>,
    ) -> Result<Self> {
        a.check_reduction_storage(shape.bw, shape.tw)?;
        let exec: Box<dyn ProblemExec + Sync + 'a> = Box::new(NativeExec {
            view: SharedBanded::new(a),
            stages: shape.stages.clone(),
            spec,
            log,
        });
        Ok(Self { exec, metrics: LaunchMetrics::default(), _borrow: PhantomData })
    }

    /// Build a runner from a type-erased storage view — the entry the
    /// trait backends use, so one launch loop serves mixed precisions.
    pub(crate) fn for_band(
        band: &'a mut BandStorageMut<'_>,
        shape: &ProblemShape,
    ) -> Result<Self> {
        Self::for_band_with_kernel(band, shape, SimdSpec::scalar())
    }

    /// [`Runner::for_band`] with an explicit SIMD spec.
    pub(crate) fn for_band_with_kernel(
        band: &'a mut BandStorageMut<'_>,
        shape: &ProblemShape,
        spec: SimdSpec,
    ) -> Result<Self> {
        Self::for_band_logged(band, shape, spec, None)
    }

    /// [`Runner::for_band_with_kernel`] with an optional reflector-log
    /// view (see [`Runner::with_kernel_logged`]).
    pub(crate) fn for_band_logged(
        band: &'a mut BandStorageMut<'_>,
        shape: &ProblemShape,
        spec: SimdSpec,
        log: Option<LogView>,
    ) -> Result<Self> {
        match band {
            BandStorageMut::F64(a) => Runner::with_kernel_logged(&mut **a, shape, spec, log),
            BandStorageMut::F32(a) => Runner::with_kernel_logged(&mut **a, shape, spec, log),
            BandStorageMut::F16(a) => Runner::with_kernel_logged(&mut **a, shape, spec, log),
        }
    }

    /// Execute one task of stage `si` using `scratch`; `ordinal` is the
    /// task's plan-order index within its problem (consumed by the
    /// reflector log, ignored otherwise).
    ///
    /// # Safety
    /// See [`ProblemExec::exec_task`]: the task must be element-disjoint
    /// from every task concurrently executing on the same problem, and
    /// the problem's buffer must not be otherwise accessed for the
    /// duration of the call.
    pub(crate) unsafe fn exec_task(
        &self,
        si: usize,
        task: &CycleTask,
        ordinal: usize,
        scratch: &mut SlotScratch,
    ) {
        self.exec.exec_task(si, task, ordinal, scratch)
    }

    /// Element size of the problem's scalar type.
    pub(crate) fn element_bytes(&self) -> usize {
        self.exec.element_bytes()
    }
}

/// Aggregate accounting of the shared launch loop.
#[derive(Clone, Debug)]
pub struct BatchMetrics {
    /// Shared launches (each = one pool dispatch + one barrier).
    pub aggregate: LaunchMetrics,
    /// Joint MaxBlocks capacity the launches were packed under.
    pub capacity: usize,
    pub problems: usize,
    /// Shared launches that carried tasks from more than one problem.
    pub co_scheduled_launches: usize,
    pub max_problems_per_launch: usize,
}

impl BatchMetrics {
    /// Mean fraction of the capacity filled per shared launch (> 1.0 when
    /// software loop unrolling engages).
    pub fn occupancy_ratio(&self) -> f64 {
        self.aggregate.occupancy_ratio(self.capacity)
    }
}

/// Pool slot a task is routed to — stable across launches. Anchors within
/// one launch are spaced `3b−1` apart and a sweep's anchor advances `b`
/// per launch, so `window = anchor / (3b−1)` keeps a chased column window
/// on one slot for ~3 consecutive launches while spreading the launch's
/// simultaneous tasks over distinct windows (and therefore slots). Tasks
/// are routed into the first `lanes ≤ slots` slots only: `lanes` is
/// capped by the MaxBlocks capacity, so at most `capacity` tasks execute
/// concurrently and the excess serializes inside a lane — the paper's
/// software loop unrolling (§III-C-c), same as the simulator's `unroll`.
#[inline]
fn affinity_slot(problem: usize, stage: &Stage, task: &CycleTask, lanes: usize) -> usize {
    let window = task.anchor / (3 * stage.b - 1);
    problem.wrapping_mul(0x9E37_79B9).wrapping_add(window) % lanes
}

/// Execute every launch of `plan` over `pool`, in plan order with a
/// barrier between launches. `runners[p]` executes the tasks of plan
/// problem `p`; per-problem metrics land in each runner, aggregate
/// accounting in the returned [`LaunchMetrics`].
pub(crate) fn execute_plan(
    plan: &LaunchPlan,
    runners: &mut [Runner<'_>],
    pool: &ThreadPool,
) -> LaunchMetrics {
    assert_eq!(plan.problems.len(), runners.len(), "one runner per plan problem");
    let capacity = plan.capacity;
    let slots = pool.slots();
    let lanes = slots.min(capacity);
    let mut aggregate = LaunchMetrics::default();
    // Persistent per-slot scratch (Householder vectors + packed-tile
    // workspace), alive across every launch of the run.
    let scratch: WorkerLocal<SlotScratch> = WorkerLocal::new(slots, |_| SlotScratch::new());
    // Flattened launch buffers, reused across launches: `keys[i]` names
    // the (problem, stage, per-problem task ordinal) of `tasks[i]`;
    // `buckets[w]` lists the task indices routed to pool slot `w`. The
    // ordinal advances in *plan* order (slot order × tasks_at order) —
    // never execution order — so a reflector log filled concurrently is
    // position-identical to one filled by the sequential oracle.
    let mut tasks: Vec<CycleTask> = Vec::new();
    let mut keys: Vec<(u32, u32, u32)> = Vec::new();
    let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); slots];
    let mut ordinals: Vec<u32> = vec![0; runners.len()];
    // Observation hooks: per-launch wall timing is taken only when
    // tracing or calibration is live — the common path pays one relaxed
    // atomic load per run. `classes` tallies each launch's slots by
    // kernel class so the measured wall splits proportionally to tasks.
    let observing = crate::obs::observing();
    let mut classes: Vec<(usize, usize, usize, bool, u64)> = Vec::new();
    for li in 0..plan.num_launches() {
        tasks.clear();
        keys.clear();
        for b in buckets.iter_mut() {
            b.clear();
        }
        classes.clear();
        let mut launch_bytes = 0u64;
        for slot in plan.launch(li) {
            let p = slot.problem as usize;
            let shape = &plan.problems[p];
            let stage = &shape.stages[slot.stage as usize];
            let es = runners[p].exec.element_bytes();
            let bytes = slot_bytes(stage, slot.count as usize, es);
            launch_bytes += bytes;
            if observing {
                let packed = stage_uses_packed(stage);
                classes.push((stage.b, stage.d, es, packed, slot.count as u64));
            }
            runners[p].metrics.record_launch(slot.count as usize, capacity, bytes);
            let start = tasks.len();
            stage.tasks_at_into(shape.n, slot.t as usize, &mut tasks);
            debug_assert_eq!(tasks.len() - start, slot.count as usize);
            let base = ordinals[p];
            for (i, task) in tasks[start..].iter().enumerate() {
                keys.push((slot.problem, slot.stage, base + i as u32));
                let w = affinity_slot(p, stage, task, lanes);
                buckets[w].push((start + i) as u32);
            }
            ordinals[p] = base + slot.count;
        }
        aggregate.record_launch(tasks.len(), capacity, launch_bytes);

        // Execute: one pinned pool dispatch, one barrier — tasks within
        // the launch are disjoint (schedule property within a problem,
        // separate buffers across problems).
        let keys_ref: &[(u32, u32, u32)] = &keys;
        let tasks_ref: &[CycleTask] = &tasks;
        let buckets_ref: &[Vec<u32>] = &buckets;
        let runners_ref: &[Runner<'_>] = runners;
        let scratch_ref: &WorkerLocal<SlotScratch> = &scratch;
        let t_launch = observing.then(Instant::now);
        pool.for_each_slot_where(|w| !buckets_ref[w].is_empty(), |w| {
            // SAFETY (scratch): pinned dispatch gives slot `w` to exactly
            // one thread at a time.
            let ws = unsafe { scratch_ref.get_mut(w) };
            for &i in &buckets_ref[w] {
                let (p, si, ord) = keys_ref[i as usize];
                // SAFETY: within a launch every task is disjoint from
                // every other (see above); launches are ordered by the
                // pool barrier.
                unsafe {
                    runners_ref[p as usize].exec.exec_task(
                        si as usize,
                        &tasks_ref[i as usize],
                        ord as usize,
                        ws,
                    )
                };
            }
        });
        if let Some(t0) = t_launch {
            let dur = t0.elapsed();
            trace::record_launch(li, tasks.len(), dur);
            // The pool dispatch is one barrier — per-class cost is the
            // launch wall split proportionally to each class's tasks.
            let ns = dur.as_nanos() as f64;
            let total = tasks.len().max(1) as f64;
            for &(b, d, es, packed, count) in &classes {
                calibrate::record_sample(b, d, es, packed, count, ns * count as f64 / total);
            }
        }
    }
    aggregate
}

/// Per-problem slice of a [`BatchReport`].
#[derive(Clone, Debug)]
pub struct ProblemReport {
    pub n: usize,
    pub bw: usize,
    pub precision: &'static str,
    pub diag: Vec<f64>,
    pub superdiag: Vec<f64>,
    /// Largest |element| outside the bidiagonal after the run.
    pub residual_off_band: f64,
    pub metrics: LaunchMetrics,
}

/// Outcome of a batched reduction.
#[derive(Clone, Debug)]
pub struct BatchReport {
    pub plan: BatchPlan,
    pub problems: Vec<ProblemReport>,
    pub metrics: BatchMetrics,
    pub wall: Duration,
}

impl BatchReport {
    /// Problems reduced per second of wall-clock. Platforms with coarse
    /// monotone clocks can report a zero wall for a tiny batch, so the
    /// elapsed time is clamped to one nanosecond — the rate is finite
    /// and positive whenever any problem ran, on every platform (the
    /// `shared_launches_actually_co_schedule` assertion relies on it).
    pub fn throughput(&self) -> f64 {
        if self.problems.is_empty() {
            return 0.0;
        }
        self.problems.len() as f64 / self.wall.as_secs_f64().max(1e-9)
    }
}

/// The batch coordinator: tuning parameters, batch knobs, the
/// [`Backend`] that executes the merged plan, and the [`PlanCache`] its
/// plans route through (shared with `banded-svd serve` when the caller
/// passes the service's cache — one lowering path for both).
pub struct BatchCoordinator {
    pub params: TuneParams,
    pub cfg: BatchConfig,
    backend: Box<dyn Backend>,
    cache: PlanCache,
}

impl BatchCoordinator {
    /// Batch coordinator on the default [`ThreadpoolBackend`];
    /// `threads == 0` uses all available hardware threads.
    pub fn new(params: TuneParams, cfg: BatchConfig, threads: usize) -> Self {
        Self::with_backend(params, cfg, Box::new(ThreadpoolBackend::new(threads)))
    }

    /// Batch coordinator on an explicit backend — any [`Backend`] can
    /// execute a merged plan (the PJRT backend maps each plan problem
    /// onto its own device-resident buffer).
    pub fn with_backend(params: TuneParams, cfg: BatchConfig, backend: Box<dyn Backend>) -> Self {
        Self { params, cfg, backend, cache: PlanCache::default() }
    }

    /// Share an existing plan cache (e.g. the reduction service's) so
    /// repeated shapes are lowered once across both subsystems.
    pub fn with_plan_cache(mut self, cache: PlanCache) -> Self {
        self.cache = cache;
        self
    }

    pub fn backend(&self) -> &dyn Backend {
        self.backend.as_ref()
    }

    /// The coordinator's plan cache (hit/miss counters included).
    pub fn plan_cache(&self) -> &PlanCache {
        &self.cache
    }

    /// Validate the batch and lay out its packing plan — including the
    /// merged [`LaunchPlan`] that [`BatchCoordinator::run`] executes —
    /// without touching any matrix data. Lowerings and the merge skeleton
    /// come from the plan cache: calling this twice for the same batch
    /// signature lowers nothing the second time.
    pub fn plan(&self, inputs: &[BatchInput]) -> Result<BatchPlan> {
        BatchPlan::new_cached(inputs, &self.params, &self.cfg, &self.cache)
    }

    /// Reduce every problem to bidiagonal form in place, executing the
    /// merged shared-launch plan on the selected backend.
    pub fn run(&self, inputs: &mut [BatchInput]) -> Result<BatchReport> {
        let plan = self.plan(inputs)?;
        self.execute(plan, inputs, None)
    }

    /// [`BatchCoordinator::run`] with reflector capture: executes the
    /// same merged plan through [`Backend::execute_logged`] and returns
    /// the filled [`ReflectorLog`] alongside the report, so callers can
    /// accumulate singular-vector panels
    /// ([`crate::pipeline::accumulate_panels`]) per plan problem.
    /// Bands, σ inputs, and metrics are bitwise identical to
    /// [`BatchCoordinator::run`] — recording never changes what the
    /// kernels write.
    pub fn run_logged(&self, inputs: &mut [BatchInput]) -> Result<(BatchReport, ReflectorLog)> {
        let plan = self.plan(inputs)?;
        let mut log = ReflectorLog::for_plan(plan.merged.as_ref());
        let report = self.execute(plan, inputs, Some(&mut log))?;
        Ok((report, log))
    }

    /// Shared execution body of [`BatchCoordinator::run`] /
    /// [`BatchCoordinator::run_logged`].
    fn execute(
        &self,
        plan: BatchPlan,
        inputs: &mut [BatchInput],
        log: Option<&mut ReflectorLog>,
    ) -> Result<BatchReport> {
        let t_start = Instant::now();
        let exec = {
            let mut bands: Vec<BandStorageMut<'_>> =
                inputs.iter_mut().map(|input| input.as_band_storage_mut()).collect();
            match log {
                Some(log) => self.backend.execute_logged(plan.merged.as_ref(), &mut bands, log)?,
                None => self.backend.execute(plan.merged.as_ref(), &mut bands)?,
            }
        };
        let wall = t_start.elapsed();
        let mut aggregate = exec.aggregate;
        aggregate.wall = wall;
        let metrics = BatchMetrics {
            aggregate,
            capacity: plan.capacity,
            problems: inputs.len(),
            co_scheduled_launches: plan.merged.co_scheduled_launches(),
            max_problems_per_launch: plan.merged.max_problems_per_launch(),
        };
        let problems = inputs
            .iter()
            .zip(exec.per_problem)
            .map(|(input, m)| {
                let (diag, superdiag) = input.bidiagonal_f64();
                ProblemReport {
                    n: input.n(),
                    bw: input.bw(),
                    precision: input.precision(),
                    diag,
                    superdiag,
                    residual_off_band: input.max_off_band(1),
                    metrics: m,
                }
            })
            .collect();
        Ok(BatchReport { plan, problems, metrics, wall })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BackendKind, PackingPolicy};
    use crate::coordinator::Coordinator;
    use crate::generate::random_banded;
    use crate::util::rng::Xoshiro256;

    fn mixed_batch(seed: u64) -> Vec<BatchInput> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        vec![
            BatchInput::from((random_banded::<f64>(64, 8, 4, &mut rng), 8)),
            BatchInput::from((random_banded::<f64>(40, 6, 4, &mut rng), 6)),
            BatchInput::from((random_banded::<f32>(48, 5, 4, &mut rng), 5)),
            BatchInput::from((random_banded::<crate::scalar::F16>(24, 3, 3, &mut rng), 3)),
        ]
    }

    fn params() -> TuneParams {
        TuneParams { tpb: 32, tw: 4, max_blocks: 24 }
    }

    #[test]
    fn batch_reduces_every_problem_exactly() {
        for policy in [PackingPolicy::RoundRobin, PackingPolicy::GreedyFill] {
            let cfg = BatchConfig { max_coresident: 8, policy };
            let coord = BatchCoordinator::new(params(), cfg, 4);
            let mut inputs = mixed_batch(11);
            let report = coord.run(&mut inputs).unwrap();
            assert_eq!(report.problems.len(), 4);
            for (i, p) in report.problems.iter().enumerate() {
                assert_eq!(p.residual_off_band, 0.0, "problem {i} ({policy:?})");
                assert_eq!(p.diag.len(), p.n);
                assert_eq!(p.superdiag.len(), p.n - 1);
                assert!(p.metrics.launches > 0);
                assert!(p.metrics.bytes > 0);
            }
            assert_eq!(
                report.metrics.aggregate.tasks,
                report.plan.total_tasks(),
                "{policy:?}"
            );
        }
    }

    #[test]
    fn batched_f64_results_are_bitwise_equal_to_solo_runs() {
        let cfg = BatchConfig { max_coresident: 8, policy: PackingPolicy::RoundRobin };
        let batch_coord = BatchCoordinator::new(params(), cfg, 4);
        let solo_coord = Coordinator::new(params(), 4);
        let mut rng = Xoshiro256::seed_from_u64(21);
        let shapes = [(64usize, 8usize), (40, 6), (52, 9)];
        let mats: Vec<_> = shapes
            .iter()
            .map(|&(n, bw)| random_banded::<f64>(n, bw, params().effective_tw(bw), &mut rng))
            .collect();
        let mut inputs: Vec<BatchInput> = mats
            .iter()
            .zip(shapes.iter())
            .map(|(a, &(_, bw))| BatchInput::from((a.clone(), bw)))
            .collect();
        let report = batch_coord.run(&mut inputs).unwrap();
        for ((a, &(_, bw)), p) in mats.iter().zip(shapes.iter()).zip(report.problems.iter()) {
            let mut solo = a.clone();
            let r = solo_coord.reduce_native(&mut solo, bw, BackendKind::Threadpool).unwrap();
            assert_eq!(r.diag, p.diag);
            assert_eq!(r.superdiag, p.superdiag);
            assert_eq!(r.metrics.launches, p.metrics.launches);
            assert_eq!(r.metrics.tasks, p.metrics.tasks);
            assert_eq!(r.metrics.per_launch, p.metrics.per_launch);
            assert_eq!(r.metrics.bytes, p.metrics.bytes);
        }
    }

    #[test]
    fn logged_runs_match_plain_runs_bitwise() {
        // Recording reflectors must not perturb the reduction: bands,
        // σ inputs, and metrics are bitwise those of the plain run, and
        // the filled log matches the merged plan it was built for.
        let cfg = BatchConfig { max_coresident: 8, policy: PackingPolicy::RoundRobin };
        let coord = BatchCoordinator::new(params(), cfg, 4);
        let mut plain = mixed_batch(91);
        let mut logged = mixed_batch(91);
        let report = coord.run(&mut plain).unwrap();
        let (logged_report, log) = coord.run_logged(&mut logged).unwrap();
        assert_eq!(log.num_problems(), logged_report.problems.len());
        log.check_plan(logged_report.plan.merged.as_ref()).unwrap();
        for (a, b) in report.problems.iter().zip(logged_report.problems.iter()) {
            assert_eq!(a.diag, b.diag);
            assert_eq!(a.superdiag, b.superdiag);
            assert_eq!(a.residual_off_band, b.residual_off_band);
            assert_eq!(a.metrics.launches, b.metrics.launches);
            assert_eq!(a.metrics.tasks, b.metrics.tasks);
            assert_eq!(a.metrics.bytes, b.metrics.bytes);
        }
    }

    #[test]
    fn shared_launches_actually_co_schedule() {
        let cfg = BatchConfig { max_coresident: 8, policy: PackingPolicy::RoundRobin };
        let coord = BatchCoordinator::new(params(), cfg, 4);
        let mut inputs = mixed_batch(31);
        let report = coord.run(&mut inputs).unwrap();
        assert!(report.metrics.co_scheduled_launches > 0);
        assert!(report.metrics.max_problems_per_launch > 1);
        // Interleaving strictly beats running the problems back to back.
        assert!(report.metrics.aggregate.launches < report.plan.total_launches());
        assert!(report.metrics.aggregate.launches >= report.plan.min_shared_launches());
        assert!(report.metrics.occupancy_ratio() > 0.0);
        assert!(report.throughput() > 0.0);
        // The executed launch count is the merged plan's, by construction.
        assert_eq!(report.metrics.aggregate.launches, report.plan.merged.num_launches());
    }

    #[test]
    fn max_coresident_one_serializes_the_batch() {
        let cfg = BatchConfig { max_coresident: 1, policy: PackingPolicy::GreedyFill };
        let coord = BatchCoordinator::new(params(), cfg, 2);
        let mut inputs = mixed_batch(41);
        let report = coord.run(&mut inputs).unwrap();
        assert_eq!(report.metrics.co_scheduled_launches, 0);
        assert_eq!(report.metrics.max_problems_per_launch, 1);
        assert_eq!(report.metrics.aggregate.launches, report.plan.total_launches());
        for p in &report.problems {
            assert_eq!(p.residual_off_band, 0.0);
        }
    }

    #[test]
    fn policies_agree_on_results() {
        let mk = |policy| {
            let cfg = BatchConfig { max_coresident: 8, policy };
            let coord = BatchCoordinator::new(params(), cfg, 4);
            let mut inputs = mixed_batch(51);
            coord.run(&mut inputs).unwrap()
        };
        let rr = mk(PackingPolicy::RoundRobin);
        let greedy = mk(PackingPolicy::GreedyFill);
        for (a, b) in rr.problems.iter().zip(greedy.problems.iter()) {
            assert_eq!(a.diag, b.diag);
            assert_eq!(a.superdiag, b.superdiag);
        }
    }

    #[test]
    fn undersized_storage_is_rejected_before_any_work() {
        use crate::banded::storage::Banded;
        let coord = BatchCoordinator::new(
            TuneParams { tpb: 32, tw: 8, max_blocks: 8 },
            BatchConfig::default(),
            1,
        );
        let mut inputs = vec![BatchInput::from((Banded::<f64>::zeros(32, 9, 1), 8))];
        assert!(coord.run(&mut inputs).is_err());
    }

    #[test]
    fn repeated_planning_hits_the_plan_cache() {
        let cfg = BatchConfig { max_coresident: 8, policy: PackingPolicy::RoundRobin };
        let coord = BatchCoordinator::new(params(), cfg, 2);
        let inputs = mixed_batch(61);
        coord.plan(&inputs).unwrap();
        let cold = coord.plan_cache().stats();
        assert_eq!(cold.plan_hits, 0);
        assert_eq!(cold.plan_misses, inputs.len() as u64);
        assert_eq!(cold.merge_misses, 1);
        // Same batch signature again: every lowering and the merge
        // skeleton come from cache.
        coord.plan(&inputs).unwrap();
        let warm = coord.plan_cache().stats();
        assert_eq!(warm.plan_hits, inputs.len() as u64);
        assert_eq!(warm.plan_misses, cold.plan_misses);
        assert_eq!(warm.merge_hits, 1);
        assert_eq!(warm.merge_misses, 1);
        assert!(warm.hit_rate() > 0.0);
    }

    #[test]
    fn shared_cache_spans_coordinators() {
        // The serve path hands its cache to a BatchCoordinator this way:
        // lowerings from one consumer are hits for the other.
        let cache = PlanCache::new(16);
        let cfg = BatchConfig { max_coresident: 8, policy: PackingPolicy::RoundRobin };
        let a = BatchCoordinator::new(params(), cfg, 1).with_plan_cache(cache.clone());
        let b = BatchCoordinator::new(params(), cfg, 1).with_plan_cache(cache.clone());
        let inputs = mixed_batch(81);
        a.plan(&inputs).unwrap();
        b.plan(&inputs).unwrap();
        let stats = cache.stats();
        assert_eq!(stats.plan_hits, inputs.len() as u64);
        assert_eq!(stats.plan_misses, inputs.len() as u64);
        assert_eq!((stats.merge_hits, stats.merge_misses), (1, 1));
    }

    #[test]
    fn run_reuses_the_plans_that_planning_lowered() {
        let cfg = BatchConfig { max_coresident: 8, policy: PackingPolicy::GreedyFill };
        let coord = BatchCoordinator::new(params(), cfg, 2);
        let mut inputs = mixed_batch(71);
        coord.plan(&inputs).unwrap();
        let planned = coord.plan_cache().stats();
        coord.run(&mut inputs).unwrap();
        let ran = coord.plan_cache().stats();
        assert_eq!(ran.plan_misses, planned.plan_misses, "run re-lowered a plan");
        assert_eq!(ran.merge_misses, planned.merge_misses, "run re-merged the skeleton");
        assert_eq!(ran.plan_hits, planned.plan_hits + inputs.len() as u64);
    }

    #[test]
    fn slot_scratch_hands_out_aligned_workspaces() {
        // The scratch a pool slot receives is what the SIMD kernels
        // stream over — alignment must survive the type-erased route and
        // on-demand growth.
        let mut scratch = SlotScratch::new();
        let wide = Stage::new(40, 24);
        let ws = scratch.workspace::<f64>();
        ws.ensure_stage(&wide);
        assert!(ws.alignment_ok());
        let ws32 = scratch.workspace::<f32>();
        ws32.ensure_stage(&Stage::new(12, 6));
        assert!(ws32.alignment_ok());
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let coord = BatchCoordinator::new(params(), BatchConfig::default(), 1);
        let report = coord.run(&mut []).unwrap();
        assert_eq!(report.problems.len(), 0);
        assert_eq!(report.metrics.aggregate.launches, 0);
    }
}
