//! banded-svd CLI — the L3 entry point.
//!
//! Subcommands map one-to-one onto the paper's experiments (DESIGN.md §4)
//! plus operational commands for running reductions and pipelines.

use banded_svd::banded::Dense;
use banded_svd::client::{
    Client, LocalClient, ReductionOutcome, ReductionRequest, RemoteClient, RouteStrategy,
    ShardedClient,
};
use banded_svd::config::{
    BackendKind, BatchConfig, PackingPolicy, ServiceConfig, ShardRouting, TuneParams,
};
use banded_svd::coordinator::Coordinator;
use banded_svd::generate::{dense_with_spectrum, random_banded, Spectrum};
use banded_svd::pipeline::{
    bidiagonal_singular_values, jacobi_singular_values, relative_sv_error,
    singular_values_3stage_mixed, SvdOptions,
};
use banded_svd::runtime::{artifact_dir, PjrtEngine};
use banded_svd::scalar::{ScalarKind, F16};
use banded_svd::simulator::{self, hw};
use banded_svd::util::bench::{fmt_duration, Table};
use banded_svd::util::cli::{flag, opt, Cli, Command};
use banded_svd::util::rng::Xoshiro256;
use std::time::Duration;

fn cli() -> Cli {
    Cli {
        program: "banded-svd",
        about: "memory-aware bulge-chasing banded→bidiagonal reduction (paper reproduction)",
        commands: vec![
            Command {
                name: "reduce",
                about: "reduce a random banded matrix to bidiagonal form",
                opts: vec![
                    opt("n", "matrix size", "512"),
                    opt("bw", "matrix bandwidth", "16"),
                    opt("tw", "inner tilewidth", "8"),
                    opt("tpb", "threads per block", "32"),
                    opt("max-blocks", "block capacity per launch", "192"),
                    opt("backend", "sequential|threadpool|simd|pjrt|pjrt-fused", "threadpool"),
                    opt("threads", "worker threads (0 = all cores)", "0"),
                    opt("seed", "rng seed", "42"),
                    flag("verify", "check singular values against the Jacobi oracle (n ≤ 512)"),
                    flag("vectors", "compute full singular vectors (dense U/Vᵀ panels)"),
                ],
            },
            Command {
                name: "batch",
                about: "reduce a batch of random banded problems through shared launches",
                opts: vec![
                    opt("count", "number of problems", "8"),
                    opt("n", "matrix size of each problem", "256"),
                    opt("bw", "bandwidth of each problem", "16"),
                    opt(
                        "spec",
                        "explicit problem list n:bw[:fp16|fp32|fp64],... (overrides count/n/bw)",
                        "",
                    ),
                    opt("precision", "default precision: fp16|fp32|fp64", "fp64"),
                    opt("tw", "inner tilewidth", "8"),
                    opt("tpb", "threads per block", "32"),
                    opt("max-blocks", "joint block capacity per shared launch", "192"),
                    opt("policy", "packing policy: round-robin|greedy-fill", "round-robin"),
                    opt("max-coresident", "max problems interleaved at once", "64"),
                    opt("backend", "sequential|threadpool|simd|pjrt", "threadpool"),
                    opt("threads", "worker threads (0 = all cores)", "0"),
                    opt("seed", "rng seed", "42"),
                ],
            },
            Command {
                name: "client",
                about: "submit reduction requests through the unified client (local or remote)",
                opts: vec![
                    opt(
                        "remote",
                        "serve endpoint(s) to submit to, comma-separated (several = sharded \
                         client with failover; empty = run locally)",
                        "",
                    ),
                    opt("route", "sharded endpoint routing: hash|least-loaded", "hash"),
                    opt("client-id", "caller identity for server-side quota accounting", ""),
                    opt("quota-class", "quota bucket shared across client ids", ""),
                    flag("queued", "local mode: queue through an embedded in-process service"),
                    opt("count", "number of problems", "4"),
                    opt("n", "matrix size of each problem", "128"),
                    opt("bw", "bandwidth of each problem", "8"),
                    opt(
                        "spec",
                        "explicit problem list n:bw[:fp16|fp32|fp64],... (overrides count/n/bw)",
                        "",
                    ),
                    opt("precision", "default precision: fp16|fp32|fp64", "fp64"),
                    opt("priority", "priority class (lower drains first)", "0"),
                    opt("deadline-ms", "fail jobs still queued after this many ms", ""),
                    opt("tw", "inner tilewidth (local modes)", "8"),
                    opt("tpb", "threads per block (local modes)", "32"),
                    opt("max-blocks", "block capacity per launch (local modes)", "192"),
                    opt("policy", "packing policy: round-robin|greedy-fill", "round-robin"),
                    opt("max-coresident", "max problems interleaved at once", "16"),
                    opt("backend", "sequential|threadpool|simd|pjrt (local modes)", "threadpool"),
                    opt("threads", "worker threads (0 = all cores, local modes)", "0"),
                    opt("seed", "rng seed", "42"),
                    flag("vectors", "request dense U/Vᵀ singular-vector panels per problem"),
                    flag(
                        "binary-frames",
                        "ship band payloads as length-prefixed binary frames \
                         (single remote endpoint, proto >= 4)",
                    ),
                    flag("metrics", "after the run, print the server(s)' Prometheus metrics"),
                    flag("shutdown", "after the run, ask the remote server(s) to shut down"),
                ],
            },
            Command {
                name: "serve",
                about: "serve a stream of reduction jobs over TCP (JSON lines)",
                opts: vec![
                    opt("addr", "listen address (port 0 = ephemeral)", "127.0.0.1:7070"),
                    opt("backend", "sequential|threadpool|simd|pjrt", "threadpool"),
                    opt("threads", "worker threads (0 = all cores)", "0"),
                    opt("workers", "batcher shards, each with its own backend (overrides env)", ""),
                    opt("routing", "job-to-shard routing: least-loaded|size-class", "least-loaded"),
                    opt("quota-cap", "max pending jobs per client (0 = no quota)", "0"),
                    opt("vectors-cap", "largest n admitted for singular-vector jobs", "4096"),
                    opt("max-coresident", "micro-batch size flush trigger", "16"),
                    opt("policy", "packing policy: round-robin|greedy-fill", "round-robin"),
                    opt("window-us", "micro-batch window in µs (overrides env)", ""),
                    opt("queue-cap", "max pending jobs", "1024"),
                    opt("backlog-cap-s", "admission cap on modeled backlog seconds", "60"),
                    opt("cache-cap", "plan/autotune cache entries per store", "256"),
                    opt("arch", "cost-model architecture for admission pricing", "H100"),
                    opt("tw", "inner tilewidth", "8"),
                    opt("tpb", "threads per block", "32"),
                    opt("max-blocks", "joint block capacity per shared launch", "192"),
                    opt("trace", "append span events as JSON lines to this file", ""),
                ],
            },
            Command {
                name: "stats",
                about: "query a running serve endpoint for stats or Prometheus metrics",
                opts: vec![
                    opt("remote", "serve endpoint to query", "127.0.0.1:7070"),
                    opt("format", "output format: json|prom", "json"),
                ],
            },
            Command {
                name: "loadgen",
                about: "open-loop SLO load generator against the serving tier",
                opts: vec![
                    opt(
                        "target",
                        "local:queued|local:direct|serve address(es), comma-separated \
                         (one connection per submitter, round-robin)",
                        "local:queued",
                    ),
                    opt("mix", "workload mix: preset name or inline spec", "smoke"),
                    opt(
                        "process",
                        "arrivals: constant:RATE|poisson:RATE|\
                         bursty:BASE:BURST:PERIOD_S:DUTY|ramp:START:END",
                        "constant:40",
                    ),
                    opt("duration-s", "schedule horizon in seconds", "2"),
                    opt("seed", "schedule/payload seed (same seed = same request stream)", "42"),
                    opt("submitters", "submitter threads", "2"),
                    opt("retries", "retry budget per request for retryable rejections", "0"),
                    opt(
                        "slo",
                        "assert bounds, e.g. p99_ms=250,miss_rate=0.01 (exit 1 on violation)",
                        "",
                    ),
                    opt("out", "also write the report JSON to this path", ""),
                    flag("plan-only", "print the canonical arrival plan and exit (no traffic)"),
                    flag(
                        "profile",
                        "add modeled-vs-observed per-class latency (BSVD_PROFILE calibrates)",
                    ),
                    opt("arch", "cost-model architecture for --profile", "H100"),
                    opt(
                        "backend",
                        "sequential|threadpool|simd|pjrt (local targets; --profile cost model)",
                        "threadpool",
                    ),
                    opt("threads", "worker threads (0 = all cores, local targets)", "0"),
                    opt("queue-cap", "max pending jobs (local:queued; overrides env)", ""),
                    opt("quota-cap", "max pending jobs per client (local:queued, 0 = off)", "0"),
                    opt("tw", "inner tilewidth (local targets)", "8"),
                    opt("tpb", "threads per block (local targets)", "32"),
                    opt("max-blocks", "block capacity per launch (local targets)", "192"),
                ],
            },
            Command {
                name: "demo",
                about: "run an end-to-end scenario (positional: name; no name lists the catalog)",
                opts: vec![
                    opt("target", "local:direct|local:queued|serve address", "local:direct"),
                    flag("full", "full-size configuration (default is the short CI sizing)"),
                    opt("seed", "scenario seed", "7"),
                    opt("backend", "sequential|threadpool|simd|pjrt (local targets)", "threadpool"),
                    opt("threads", "worker threads (0 = all cores, local targets)", "0"),
                    opt("tw", "inner tilewidth (must match a remote server's tuning)", "8"),
                    opt("tpb", "threads per block", "32"),
                    opt("max-blocks", "block capacity per launch", "192"),
                ],
            },
            Command {
                name: "svd",
                about: "full 3-stage singular-value pipeline on a random dense matrix",
                opts: vec![
                    opt("n", "matrix size", "256"),
                    opt("bw", "intermediate bandwidth", "16"),
                    opt("tw", "inner tilewidth", "8"),
                    opt("precision", "stage-2 precision: fp16|fp32|fp64", "fp64"),
                    opt("spectrum", "arithmetic|logarithmic|quarter-circle", "arithmetic"),
                    opt("seed", "rng seed", "42"),
                ],
            },
            Command {
                name: "accuracy",
                about: "Fig. 3 protocol: relative error across precisions/spectra",
                opts: vec![
                    opt("sizes", "matrix sizes", "64,128,256"),
                    opt("bw", "bandwidth", "16"),
                    opt("tw", "inner tilewidth", "8"),
                    opt("trials", "trials per cell", "3"),
                    opt("seed", "rng seed", "7"),
                ],
            },
            Command {
                name: "occupancy",
                about: "Table I: matrix size for full GPU occupancy (eq. 1)",
                opts: vec![opt("cbw", "current bandwidth", "32")],
            },
            Command {
                name: "sweep",
                about: "Fig. 4 hyperparameter sweep on the hardware model",
                opts: vec![
                    opt("arch", "gpu architecture", "H100"),
                    opt("n", "matrix size", "65536"),
                    opt("bw", "bandwidth", "128"),
                    opt("precision", "fp16|fp32|fp64", "fp32"),
                ],
            },
            Command {
                name: "hardware",
                about: "Figs. 5/7: architecture comparison on the hardware model",
                opts: vec![
                    opt("sizes", "matrix sizes", "4096,16384,65536"),
                    opt("bw", "bandwidth", "32"),
                    opt("precision", "fp16|fp32|fp64", "fp32"),
                ],
            },
            Command {
                name: "profile",
                about: "Table III: modeled kernel profile on RTX4060 (or --measure: calibrate)",
                opts: vec![
                    flag("measure", "time real launches and write a bsvd-profile-v1 JSON"),
                    opt("out", "calibration file to write (--measure)", "profile_calibration.json"),
                    opt("n", "matrix size of each measured problem", "192"),
                    opt("bw", "bandwidth of each measured problem", "16"),
                    opt("count", "measured problems per precision", "4"),
                    opt("backend", "sequential|threadpool|simd|pjrt (--measure)", "threadpool"),
                    opt("threads", "worker threads (0 = all cores, --measure)", "0"),
                    opt("seed", "rng seed (--measure)", "42"),
                ],
            },
            Command {
                name: "tune",
                about: "auto-tune (TPB, TW, MaxBlocks) for an architecture (paper §VII)",
                opts: vec![
                    opt("arch", "gpu architecture", "H100"),
                    opt("n", "matrix size", "65536"),
                    opt("bw", "bandwidth", "128"),
                    opt("precision", "fp16|fp32|fp64", "fp32"),
                    opt(
                        "backend",
                        "cost profile to tune for: native|simd|pjrt|pjrt-streaming",
                        "native",
                    ),
                ],
            },
            Command {
                name: "bench-collect",
                about: "merge bench experiment JSON into one BENCH snapshot file",
                opts: vec![
                    opt("dir", "experiments directory to harvest", "target/experiments"),
                    opt("out", "snapshot file to write", "BENCH.json"),
                    opt("label", "snapshot label (e.g. a PR or host name)", "local"),
                ],
            },
            Command {
                name: "bench-gate",
                about: "fail (exit 1) when a BENCH snapshot regresses vs a baseline",
                opts: vec![
                    opt("baseline", "committed baseline snapshot", "BENCH_PR7.json"),
                    opt("current", "freshly collected snapshot", "BENCH.json"),
                    opt("tolerance", "allowed fractional regression", "0.10"),
                ],
            },
            Command {
                name: "bench-promote",
                about: "promote a measured BENCH snapshot over an unmeasured baseline",
                opts: vec![
                    opt("candidate", "freshly collected measured snapshot", "BENCH.json"),
                    opt("baseline", "committed baseline to replace", "BENCH_PR7.json"),
                    flag("force", "replace even a baseline that is already measured"),
                ],
            },
            Command {
                name: "artifacts-info",
                about: "inspect compiled PJRT artifacts for a variant",
                opts: vec![
                    opt("n", "matrix size", "256"),
                    opt("bw", "bandwidth", "8"),
                    opt("tw", "tilewidth", "4"),
                ],
            },
        ],
    }
}

fn es_of(precision: &str) -> usize {
    match precision {
        "fp16" => 2,
        "fp64" => 8,
        _ => 4,
    }
}

fn main() {
    // BSVD_TRACE=<path> turns on span tracing for any subcommand; the
    // `serve --trace` flag layers the same file sink on explicitly.
    banded_svd::obs::trace::init_from_env();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let parsed = match cli().parse(&argv) {
        Ok(p) => p,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(if msg.starts_with("unknown") { 2 } else { 0 });
        }
    };
    let code = match parsed.command.as_str() {
        "reduce" => cmd_reduce(&parsed.args),
        "batch" => cmd_batch(&parsed.args),
        "client" => cmd_client(&parsed.args),
        "serve" => cmd_serve(&parsed.args),
        "stats" => cmd_stats(&parsed.args),
        "loadgen" => cmd_loadgen(&parsed.args),
        "demo" => cmd_demo(&parsed.args),
        "svd" => cmd_svd(&parsed.args),
        "accuracy" => cmd_accuracy(&parsed.args),
        "occupancy" => cmd_occupancy(&parsed.args),
        "sweep" => cmd_sweep(&parsed.args),
        "hardware" => cmd_hardware(&parsed.args),
        "profile" => cmd_profile(&parsed.args),
        "tune" => cmd_tune(&parsed.args),
        "bench-collect" => cmd_bench_collect(&parsed.args),
        "bench-gate" => cmd_bench_gate(&parsed.args),
        "bench-promote" => cmd_bench_promote(&parsed.args),
        "artifacts-info" => cmd_artifacts_info(&parsed.args),
        _ => unreachable!(),
    };
    std::process::exit(code);
}

/// Verify singular values against the Jacobi oracle on the pre-reduction
/// dense matrix; returns the process exit code.
fn verify_against_oracle(sv: &[f64], dense_before: Option<&Dense<f64>>) -> i32 {
    if let Some(dense) = dense_before {
        let oracle = jacobi_singular_values(dense);
        let err = relative_sv_error(sv, &oracle);
        println!("singular-value relative error vs Jacobi oracle: {err:.3e}");
        if err > 1e-4 {
            eprintln!("VERIFICATION FAILED");
            return 1;
        }
        println!("verification OK");
    }
    0
}

fn cmd_reduce(args: &banded_svd::util::cli::Args) -> i32 {
    let n: usize = args.parse_or("n", 512);
    let bw: usize = args.parse_or("bw", 16);
    let params = TuneParams {
        tpb: args.parse_or("tpb", 32),
        tw: args.parse_or("tw", 8),
        max_blocks: args.parse_or("max-blocks", 192),
    };
    let backend: BackendKind = match args.get("backend").unwrap_or("threadpool").parse() {
        Ok(b) => b,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let seed: u64 = args.parse_or("seed", 42);
    let threads: usize = args.parse_or("threads", 0);
    if backend == BackendKind::Simd {
        // Provenance: the backend name stays "simd" everywhere; what ISA
        // actually resolved is an executor detail, reported here.
        println!("simd kernels: {}", banded_svd::simd::SimdSpec::from_env().describe());
    }
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let tw = params.effective_tw(bw);
    let a = random_banded::<f64>(n, bw, tw, &mut rng);
    let dense_before = if args.flag("verify") && n <= 512 {
        Some(Dense::from_vec(n, n, a.to_dense()))
    } else {
        None
    };

    let vectors = args.flag("vectors");
    // pjrt-fused executes whole-stage artifacts (one call per stage)
    // outside the plan-executor path; every plan backend goes through
    // the unified client front door.
    if backend == BackendKind::PjrtFused {
        if vectors {
            eprintln!(
                "--vectors needs a plan backend with reflector capture \
                 (sequential|threadpool|simd); pjrt-fused serves values only"
            );
            return 2;
        }
        let mut af = a.convert::<f32>();
        let engine = match PjrtEngine::load(&artifact_dir(), n, bw, tw) {
            Ok(e) => e,
            Err(e) => {
                eprintln!("error: {e}");
                return 1;
            }
        };
        let coord = Coordinator::new(params, threads);
        return match coord.reduce_pjrt(&engine, &mut af, backend) {
            Ok(r) => {
                println!(
                    "reduced n={n} bw={bw} tw={tw} backend={:?}: {} launches, {} tasks, \
                     max parallel {}, wall {}",
                    r.backend,
                    r.metrics.launches,
                    r.metrics.tasks,
                    r.metrics.max_parallel,
                    fmt_duration(r.metrics.wall)
                );
                println!("residual off-bidiagonal: {:.3e}", r.residual_off_band);
                let sv = bidiagonal_singular_values(&r.diag, &r.superdiag);
                verify_against_oracle(&sv, dense_before.as_ref())
            }
            Err(e) => {
                eprintln!("error: {e}");
                1
            }
        };
    }

    let client = match LocalClient::direct(params, BatchConfig::default(), backend, threads) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    match client.submit_wait(ReductionRequest::new().problem((a, bw)).with_vectors(vectors)) {
        Ok(outcome) => {
            let p = &outcome.problems[0];
            println!(
                "reduced n={n} bw={bw} tw={tw} backend={}: {} launches, {} tasks, \
                 max parallel {}, wall {}",
                outcome.provenance.backend,
                p.metrics.launches,
                p.metrics.tasks,
                p.metrics.max_parallel,
                fmt_duration(outcome.wall)
            );
            if let Some(residual) = p.residual_off_band {
                println!("residual off-bidiagonal: {residual:.3e}");
            }
            if let (Some(u), Some(vt)) = (&p.u, &p.vt) {
                println!(
                    "singular vectors: U {}x{}, Vt {}x{}; orthogonality error \
                     U {:.3e}, V {:.3e}",
                    u.rows,
                    u.cols,
                    vt.rows,
                    vt.cols,
                    u.orthogonality_error(),
                    vt.orthogonality_error()
                );
            }
            verify_against_oracle(&p.sv, dense_before.as_ref())
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

/// Parse the shared problem-list options (`--spec` or `--count/--n/--bw`
/// with `--precision`) into `(n, bw, kind)` shapes.
fn parse_problem_shapes(
    args: &banded_svd::util::cli::Args,
) -> Result<Vec<(usize, usize, ScalarKind)>, String> {
    let default_prec: ScalarKind = args.get("precision").unwrap_or("fp64").parse()?;
    let mut shapes = Vec::new();
    let spec = args.get("spec").unwrap_or("");
    if spec.is_empty() {
        let count: usize = args.parse_or("count", 8);
        let n: usize = args.parse_or("n", 256);
        let bw: usize = args.parse_or("bw", 16);
        shapes.extend((0..count).map(|_| (n, bw, default_prec)));
    } else {
        for item in spec.split(',') {
            let parts: Vec<&str> = item.trim().split(':').collect();
            let parsed = match parts.as_slice() {
                [n, bw] => (n.parse(), bw.parse(), Ok(default_prec)),
                [n, bw, prec] => (n.parse(), bw.parse(), prec.parse::<ScalarKind>()),
                _ => {
                    return Err(format!("bad --spec entry {item:?} (want n:bw or n:bw:precision)"))
                }
            };
            match parsed {
                (Ok(n), Ok(bw), Ok(kind)) => shapes.push((n, bw, kind)),
                (_, _, Err(e)) => return Err(format!("bad --spec entry {item:?}: {e}")),
                _ => return Err(format!("bad --spec entry {item:?}: n and bw must be integers")),
            }
        }
    }
    Ok(shapes)
}

/// Build a [`ReductionRequest`] of seeded random problems from shapes.
fn request_from_shapes(shapes: &[(usize, usize, ScalarKind)], seed: u64) -> ReductionRequest {
    let mut request = ReductionRequest::new();
    for (i, &(n, bw, kind)) in shapes.iter().enumerate() {
        request = request.random(n, bw, kind, seed.wrapping_add(i as u64));
    }
    request
}

/// Render a completed [`ReductionOutcome`] as the per-problem table plus
/// the aggregate/provenance summary — shared by `batch` and `client`.
fn print_outcome(outcome: &ReductionOutcome) {
    let mut table = Table::new(vec![
        "problem", "n", "bw", "prec", "launches", "tasks", "max par", "bytes", "sigma_max",
    ]);
    for (i, p) in outcome.problems.iter().enumerate() {
        table.row(vec![
            i.to_string(),
            p.n.to_string(),
            p.bw.to_string(),
            p.precision.to_string(),
            p.metrics.launches.to_string(),
            p.metrics.tasks.to_string(),
            p.metrics.max_parallel.to_string(),
            p.metrics.bytes.to_string(),
            format!("{:.4}", p.sv.first().copied().unwrap_or(0.0)),
        ]);
    }
    table.print();
    let with_panels = outcome.problems.iter().filter(|p| p.u.is_some()).count();
    if with_panels > 0 {
        let worst = outcome
            .problems
            .iter()
            .flat_map(|p| [p.u.as_ref(), p.vt.as_ref()])
            .flatten()
            .map(|panel| panel.orthogonality_error())
            .fold(0.0f64, f64::max);
        println!(
            "singular vectors: {with_panels} problem(s) carry dense U/Vt panels \
             (worst orthogonality error {worst:.3e})"
        );
    }
    let problems = outcome.problems.len();
    let throughput = outcome.throughput();
    if let Some(batch) = &outcome.batch {
        println!(
            "aggregate: {} shared launches ({} co-scheduled, <= {} problems/launch), \
             {} tasks, occupancy {:.2}, {throughput:.1} problems/s, wall {}",
            batch.aggregate.launches,
            batch.co_scheduled_launches,
            batch.max_problems_per_launch,
            batch.aggregate.tasks,
            batch.occupancy_ratio(),
            fmt_duration(outcome.wall)
        );
    } else {
        println!(
            "aggregate: {problems} problems, {throughput:.1} problems/s, wall {}",
            fmt_duration(outcome.wall)
        );
    }
    let prov = &outcome.provenance;
    let cache = match &prov.cache {
        Some(c) => format!(", plan cache {} hits / {} misses", c.hits(), c.misses()),
        None => String::new(),
    };
    println!("provenance: {} on {}{cache}", prov.source.name(), prov.backend);
}

fn cmd_batch(args: &banded_svd::util::cli::Args) -> i32 {
    let params = TuneParams {
        tpb: args.parse_or("tpb", 32),
        tw: args.parse_or("tw", 8),
        max_blocks: args.parse_or("max-blocks", 192),
    };
    let policy: PackingPolicy = match args.get("policy").unwrap_or("round-robin").parse() {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let cfg = BatchConfig { max_coresident: args.parse_or("max-coresident", 64).max(1), policy };
    let shapes = match parse_problem_shapes(args) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    // Select the executor through the backend trait: any registered plan
    // backend can carry a merged batch plan (the PJRT backend holds one
    // device-resident buffer per problem).
    let kind: BackendKind = match args.get("backend").unwrap_or("threadpool").parse() {
        Ok(k) => k,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let client = match LocalClient::direct(params, cfg, kind, args.parse_or("threads", 0)) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    let request = request_from_shapes(&shapes, args.parse_or("seed", 42));
    let outcome = match client.submit_wait(request) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    println!(
        "batch of {} problems on {} backend, capacity {} ({:?}), max co-resident {}",
        outcome.problems.len(),
        outcome.provenance.backend,
        params.capacity(),
        cfg.policy,
        cfg.max_coresident
    );
    print_outcome(&outcome);
    0
}

fn cmd_client(args: &banded_svd::util::cli::Args) -> i32 {
    let params = TuneParams {
        tpb: args.parse_or("tpb", 32),
        tw: args.parse_or("tw", 8),
        max_blocks: args.parse_or("max-blocks", 192),
    };
    let policy: PackingPolicy = match args.get("policy").unwrap_or("round-robin").parse() {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let batch = BatchConfig { max_coresident: args.parse_or("max-coresident", 16).max(1), policy };
    let kind: BackendKind = match args.get("backend").unwrap_or("threadpool").parse() {
        Ok(k) => k,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let shapes = match parse_problem_shapes(args) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let mut request = request_from_shapes(&shapes, args.parse_or("seed", 42));
    // Absent-or-valid, like the server's own field handling: an
    // out-of-range priority is an error, never silently clamped.
    match args.parse_opt::<u8>("priority") {
        Ok(Some(p)) => request = request.priority(p),
        Ok(None) => {}
        Err(e) => {
            eprintln!("{e} (priority must be an integer in 0..=255)");
            return 2;
        }
    }
    match args.parse_opt::<u64>("deadline-ms") {
        Ok(Some(ms)) => request = request.deadline(Duration::from_millis(ms)),
        Ok(None) => {}
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    }
    if let Some(id) = args.get("client-id").filter(|s| !s.is_empty()) {
        request = request.client_id(id);
    }
    if let Some(class) = args.get("quota-class").filter(|s| !s.is_empty()) {
        request = request.quota_class(class);
    }
    if args.flag("vectors") {
        request = request.with_vectors(true);
    }

    // One driver for every execution surface: request handling below is
    // identical whether the client is local (direct or queued through an
    // embedded service) or a remote `banded-svd serve` endpoint.
    fn drive(client: &dyn Client, request: ReductionRequest, label: &str) -> i32 {
        match client.submit_wait(request) {
            Ok(outcome) => {
                println!("client ({label}): {} problems completed", outcome.problems.len());
                print_outcome(&outcome);
                let stats = client.stats();
                println!(
                    "client stats: {} submitted, {} completed, {} failed",
                    stats.jobs_submitted, stats.jobs_completed, stats.jobs_failed
                );
                0
            }
            Err(e) => {
                let hint = if e.is_retryable() { " (retryable: server is loaded)" } else { "" };
                eprintln!("error: {e}{hint}");
                1
            }
        }
    }

    // Fetch and print each endpoint's Prometheus rendering — the
    // unified-metrics view of the counters `stats` reports, plus the
    // queue-wait/exec latency histograms.
    fn print_server_metrics(addrs: &[&str]) -> i32 {
        for &addr in addrs {
            match RemoteClient::connect(addr).and_then(|c| c.server_metrics()) {
                Ok(text) => {
                    if addrs.len() > 1 {
                        println!("# endpoint {addr}");
                    }
                    print!("{text}");
                }
                Err(e) => {
                    eprintln!("metrics {addr}: {e}");
                    return 1;
                }
            }
        }
        0
    }

    let remote_addr = args.get("remote").unwrap_or("").to_string();
    let endpoints: Vec<&str> =
        remote_addr.split(',').map(str::trim).filter(|s| !s.is_empty()).collect();
    if args.flag("metrics") && endpoints.is_empty() {
        eprintln!("--metrics queries a running server; pass --remote <addr>");
        return 2;
    }
    if args.flag("binary-frames") && endpoints.len() != 1 {
        eprintln!("--binary-frames negotiates per connection; pass exactly one --remote address");
        return 2;
    }
    if endpoints.len() > 1 {
        // Several endpoints: the sharded client routes, health-checks,
        // and fails over across the fleet.
        let route: RouteStrategy = match args.get("route").unwrap_or("hash").parse() {
            Ok(r) => r,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        let client = match ShardedClient::connect(&endpoints, route) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("error: connect {remote_addr}: {e}");
                return 1;
            }
        };
        let code = drive(
            &client,
            request,
            &format!("sharded over {} endpoints, {} routing", endpoints.len(), route.name()),
        );
        if args.flag("metrics") {
            let rc = print_server_metrics(&endpoints);
            if rc != 0 {
                return rc;
            }
        }
        if args.flag("shutdown") {
            if let Err(e) = client.shutdown() {
                eprintln!("shutdown: {e}");
                return 1;
            }
            println!("servers acknowledged shutdown");
        }
        code
    } else if let Some(&addr) = endpoints.first() {
        let mut client = match RemoteClient::connect(addr) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("error: connect {addr}: {e}");
                return 1;
            }
        };
        if args.flag("binary-frames") {
            if let Err(e) = client.binary_band_frames(true) {
                eprintln!("error: {e}");
                return 1;
            }
            println!("binary band frames on (server speaks proto {})", client.proto());
        }
        let code = drive(&client, request, &format!("remote {addr}"));
        if args.flag("metrics") {
            let rc = print_server_metrics(&[addr]);
            if rc != 0 {
                return rc;
            }
        }
        if args.flag("shutdown") {
            if let Err(e) = client.shutdown() {
                eprintln!("shutdown: {e}");
                return 1;
            }
            println!("server acknowledged shutdown");
        }
        code
    } else if args.flag("queued") {
        let cfg = ServiceConfig {
            params,
            batch,
            backend: kind,
            threads: args.parse_or("threads", 0),
            ..ServiceConfig::default()
        };
        let client = match LocalClient::queued(cfg) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("error: {e}");
                return 2;
            }
        };
        drive(&client, request, "local, queued through an embedded service")
    } else {
        let client = match LocalClient::direct(params, batch, kind, args.parse_or("threads", 0)) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("error: {e}");
                return 2;
            }
        };
        drive(&client, request, "local, direct")
    }
}

fn cmd_serve(args: &banded_svd::util::cli::Args) -> i32 {
    use banded_svd::service::Server;
    use std::io::Write as _;

    let params = TuneParams {
        tpb: args.parse_or("tpb", 32),
        tw: args.parse_or("tw", 8),
        max_blocks: args.parse_or("max-blocks", 192),
    };
    let policy: PackingPolicy = match args.get("policy").unwrap_or("round-robin").parse() {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let backend: BackendKind = match args.get("backend").unwrap_or("threadpool").parse() {
        Ok(b) => b,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let arch = match hw::arch_by_name(args.get("arch").unwrap_or("H100")) {
        Some(a) => a.name,
        None => {
            eprintln!("unknown arch; known: A100 H100 RTX4060 MI250X MI300X PVC1100 M1");
            return 2;
        }
    };
    // Defaults pick up the BSVD_SERVICE_* environment knobs; explicit
    // flags override them.
    let base = ServiceConfig::default();
    let window = match args.parse_opt::<u64>("window-us") {
        Ok(Some(us)) => Duration::from_micros(us),
        Ok(None) => base.window,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let routing: ShardRouting = match args.get("routing").unwrap_or("least-loaded").parse() {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let workers = match args.parse_opt::<usize>("workers") {
        Ok(Some(w)) if w > 0 => w,
        Ok(Some(_)) => {
            eprintln!("--workers must be positive");
            return 2;
        }
        Ok(None) => base.workers,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let cfg = ServiceConfig {
        params,
        batch: BatchConfig { max_coresident: args.parse_or("max-coresident", 16).max(1), policy },
        backend,
        threads: args.parse_or("threads", 0),
        window,
        queue_cap: args.parse_or("queue-cap", base.queue_cap),
        backlog_cap_s: args.parse_or("backlog-cap-s", base.backlog_cap_s),
        cache_cap: args.parse_or("cache-cap", base.cache_cap),
        arch,
        workers,
        routing,
        quota_pending_cap: args.parse_or("quota-cap", 0),
        vectors_cap_n: args.parse_or("vectors-cap", base.vectors_cap_n),
    };
    if let Some(path) = args.get("trace").filter(|s| !s.is_empty()) {
        if let Err(e) = banded_svd::obs::trace::enable_file(path) {
            eprintln!("error: --trace {path}: {e}");
            return 2;
        }
        println!("tracing span events to {path}");
    }
    let addr = args.get("addr").unwrap_or("127.0.0.1:7070").to_string();
    let server = match Server::bind(cfg, &addr) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    {
        let cfg = server.service().config();
        if cfg.backend == BackendKind::Simd {
            println!("simd kernels: {}", banded_svd::simd::SimdSpec::from_env().describe());
        }
        println!(
            "banded-svd serve listening on {} (backend {}, {} worker shard(s), {} routing, \
             max co-resident {}, window {} µs, queue cap {})",
            server.local_addr(),
            cfg.backend.name(),
            cfg.workers,
            cfg.routing.name(),
            cfg.batch.max_coresident,
            cfg.window.as_micros(),
            cfg.queue_cap
        );
    }
    // Smoke tests wait for the line above before connecting.
    let _ = std::io::stdout().flush();
    match server.run() {
        Ok(()) => {
            println!("banded-svd serve: clean shutdown");
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_stats(args: &banded_svd::util::cli::Args) -> i32 {
    let addr = args.get("remote").unwrap_or("127.0.0.1:7070");
    let client = match RemoteClient::connect(addr) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: connect {addr}: {e}");
            return 1;
        }
    };
    match args.get("format").unwrap_or("json") {
        "json" => match client.server_stats() {
            Ok(stats) => {
                println!("{}", stats.render());
                0
            }
            Err(e) => {
                eprintln!("error: {e}");
                1
            }
        },
        "prom" => match client.server_metrics() {
            Ok(text) => {
                print!("{text}");
                0
            }
            Err(e) => {
                eprintln!("error: {e}");
                1
            }
        },
        other => {
            eprintln!("unknown --format {other:?} (json|prom)");
            2
        }
    }
}

/// `loadgen`: plan a seeded open-loop run, drive it through the selected
/// client surface, and write the `bsvd-load-v1` report (optionally
/// asserting `--slo` bounds against it).
fn cmd_loadgen(args: &banded_svd::util::cli::Args) -> i32 {
    use banded_svd::loadgen;
    use banded_svd::obs::calibrate;
    use banded_svd::util::json::{write_experiment, Json};

    let mix = match loadgen::WorkloadMix::resolve(args.get("mix").unwrap_or("smoke")) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let process_spec = args.get("process").unwrap_or("constant:40");
    let process = match loadgen::ArrivalProcess::parse(process_spec) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let slo = match loadgen::Slo::parse(args.get("slo").unwrap_or("")) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("bad --slo: {e}");
            return 2;
        }
    };
    let duration_s: f64 = args.parse_or("duration-s", 2.0);
    if !(duration_s > 0.0 && duration_s.is_finite()) {
        eprintln!("--duration-s must be positive and finite");
        return 2;
    }
    let opts = loadgen::RunOptions {
        seed: args.parse_or("seed", 42),
        duration: Duration::from_secs_f64(duration_s),
        max_retries: args.parse_or("retries", 0),
        ..loadgen::RunOptions::default()
    };
    let planned = loadgen::plan(&process, &mix, opts.seed, opts.duration);
    if args.flag("plan-only") {
        print!("{}", loadgen::plan_lines(&planned, &mix));
        eprintln!("{} arrivals planned (no traffic sent)", planned.len());
        return 0;
    }

    let params = TuneParams {
        tpb: args.parse_or("tpb", 32),
        tw: args.parse_or("tw", 8),
        max_blocks: args.parse_or("max-blocks", 192),
    };
    let backend: BackendKind = match args.get("backend").unwrap_or("threadpool").parse() {
        Ok(b) => b,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    // Resolve every --profile input before any traffic is sent, so a
    // usage error cannot waste a finished run.
    let profile_ctx = if args.flag("profile") {
        let arch = match hw::arch_by_name(args.get("arch").unwrap_or("H100")) {
            Some(a) => a,
            None => {
                eprintln!("unknown arch; known: A100 H100 RTX4060 MI250X MI300X PVC1100 M1");
                return 2;
            }
        };
        let cost_model = match backend {
            BackendKind::Simd => simulator::BackendCostModel::simd(),
            BackendKind::Pjrt | BackendKind::PjrtFused => simulator::BackendCostModel::pjrt(),
            _ => simulator::BackendCostModel::native(),
        };
        Some((arch, cost_model))
    } else {
        None
    };
    let submitters: usize = args.parse_or("submitters", 2).max(1);
    let threads: usize = args.parse_or("threads", 0);
    let target = args.get("target").unwrap_or("local:queued").to_string();

    println!(
        "loadgen: {} arrivals over {duration_s:.1}s ({}, offered {:.1}/s) -> {target}, \
         {submitters} submitter(s)",
        planned.len(),
        process.name(),
        process.offered_rate_hz()
    );
    let (output, client_stats, server_stats) = match target.as_str() {
        "local:queued" => {
            let base = ServiceConfig::default();
            let queue_cap = match args.parse_opt::<usize>("queue-cap") {
                Ok(Some(cap)) => cap,
                Ok(None) => base.queue_cap,
                Err(e) => {
                    eprintln!("{e}");
                    return 2;
                }
            };
            let cfg = ServiceConfig {
                params,
                backend,
                threads,
                queue_cap,
                quota_pending_cap: args.parse_or("quota-cap", 0),
                ..base
            };
            let client = match LocalClient::queued(cfg) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("error: {e}");
                    return 2;
                }
            };
            let clients: Vec<&(dyn Client + Sync)> =
                (0..submitters).map(|_| &client as &(dyn Client + Sync)).collect();
            let output = loadgen::run(&clients, &mix, &process, &opts);
            // The driver blocked until every submit_wait resolved, so
            // these are the drained counters reconciliation expects.
            let server = client.service().map(|service| {
                let st = service.stats();
                Json::obj()
                    .set("jobs_submitted", st.jobs_submitted as i64)
                    .set("jobs_rejected", st.jobs_rejected as i64)
                    .set("jobs_completed", st.jobs_completed as i64)
                    .set("jobs_failed", st.jobs_failed as i64)
                    .set("queue_depth", st.queue_depth as i64)
            });
            (output, Some(client.stats()), server)
        }
        "local:direct" => {
            let built = LocalClient::direct(params, BatchConfig::default(), backend, threads);
            let client = match built {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("error: {e}");
                    return 2;
                }
            };
            let clients: Vec<&(dyn Client + Sync)> =
                (0..submitters).map(|_| &client as &(dyn Client + Sync)).collect();
            let output = loadgen::run(&clients, &mix, &process, &opts);
            (output, Some(client.stats()), None)
        }
        addrs => {
            let endpoints: Vec<&str> =
                addrs.split(',').map(str::trim).filter(|s| !s.is_empty()).collect();
            if endpoints.is_empty() {
                eprintln!("--target needs local:queued, local:direct, or serve address(es)");
                return 2;
            }
            let mut remotes = Vec::with_capacity(submitters);
            for i in 0..submitters {
                let addr = endpoints[i % endpoints.len()];
                match RemoteClient::connect(addr) {
                    Ok(c) => remotes.push(c),
                    Err(e) => {
                        eprintln!("error: connect {addr}: {e}");
                        return 1;
                    }
                }
            }
            let clients: Vec<&(dyn Client + Sync)> =
                remotes.iter().map(|c| c as &(dyn Client + Sync)).collect();
            let output = loadgen::run(&clients, &mix, &process, &opts);
            let mut stats = banded_svd::client::ClientStats::default();
            for c in &remotes {
                let s = c.stats();
                stats.jobs_submitted += s.jobs_submitted;
                stats.jobs_completed += s.jobs_completed;
                stats.jobs_failed += s.jobs_failed;
            }
            // Reconciliation needs the counters of *the* server; with a
            // fleet each endpoint saw only a slice, so skip the fetch.
            let server = if endpoints.len() == 1 {
                match remotes[0].server_stats() {
                    Ok(s) => Some(s),
                    Err(e) => {
                        eprintln!("warning: stats fetch failed: {e}");
                        None
                    }
                }
            } else {
                None
            };
            (output, Some(stats), server)
        }
    };

    let profile = profile_ctx.map(|(arch, cost_model)| {
        loadgen::report::profile_section(
            &mix,
            &params,
            &arch,
            &cost_model,
            calibrate::from_env(),
            &output.records,
        )
    });
    let inputs = loadgen::ReportInputs {
        mix: &mix,
        process: &process,
        opts: &opts,
        output: &output,
        submitters,
        target: &target,
        client_stats,
        server_stats,
        profile,
    };
    let mut report = loadgen::build_report(&inputs);
    let violations = slo.check(&report);
    if !slo.is_empty() {
        let rendered: Vec<Json> = violations.iter().map(|v| Json::s(v.as_str())).collect();
        report = report.set(
            "slo",
            Json::obj()
                .set("spec", slo.spec())
                .set("ok", violations.is_empty())
                .set("violations", Json::Arr(rendered)),
        );
    }

    let metric = |path: &[&str]| -> Option<f64> {
        let mut node = &report;
        for key in path {
            node = node.get(key)?;
        }
        node.as_f64().filter(|v| v.is_finite())
    };
    let int = |path: &[&str]| metric(path).map(|v| v as i64).unwrap_or(0);
    println!(
        "completed {} / {} scheduled, {} failed; achieved {:.1} jobs/s",
        int(&["tally", "completed"]),
        int(&["tally", "scheduled"]),
        int(&["tally", "failed"]),
        metric(&["throughput", "achieved_jobs_per_s"]).unwrap_or(f64::NAN)
    );
    println!(
        "latency ms: p50 {:.1}  p99 {:.1}  max {:.1}; deadline miss rate {}",
        metric(&["tally", "latency_ms", "p50"]).unwrap_or(f64::NAN),
        metric(&["tally", "latency_ms", "p99"]).unwrap_or(f64::NAN),
        metric(&["tally", "latency_ms", "max"]).unwrap_or(f64::NAN),
        match metric(&["tally", "deadline", "miss_rate"]) {
            Some(rate) => format!("{rate:.4}"),
            None => "n/a (no deadline classes)".to_string(),
        }
    );
    match report.get("reconciliation").and_then(|r| r.get("ok")).and_then(Json::as_bool) {
        Some(true) => println!("reconciliation vs server counters: ok"),
        Some(false) => println!("reconciliation vs server counters: MISMATCH (see report)"),
        None => {}
    }
    match write_experiment("loadgen", &report) {
        Ok(path) => println!("report: {}", path.display()),
        Err(e) => {
            eprintln!("error: write report: {e}");
            return 1;
        }
    }
    if let Some(out) = args.get("out").filter(|s| !s.is_empty()) {
        if let Err(e) = std::fs::write(out, report.render() + "\n") {
            eprintln!("error: write {out}: {e}");
            return 1;
        }
        println!("report copy: {out}");
    }
    if slo.is_empty() {
        return 0;
    }
    if violations.is_empty() {
        println!("SLO met: {}", slo.spec());
        0
    } else {
        for v in &violations {
            eprintln!("SLO violation: {v}");
        }
        eprintln!("SLO NOT met: {}", slo.spec());
        1
    }
}

/// `demo <name>`: run one scenario through the selected client surface,
/// write its report, and exit non-zero when the scenario's own
/// correctness check fails.
fn cmd_demo(args: &banded_svd::util::cli::Args) -> i32 {
    use banded_svd::loadgen::scenario::{self, ScenarioOptions, SCENARIOS};
    use banded_svd::util::json::{write_experiment, Json};

    let Some(name) = args.positionals().first().cloned() else {
        eprintln!("usage: banded-svd demo <name> [options]\n\nSCENARIOS:");
        for (n, what) in SCENARIOS {
            eprintln!("  {n:<18} {what}");
        }
        return 2;
    };
    let params = TuneParams {
        tpb: args.parse_or("tpb", 32),
        tw: args.parse_or("tw", 8),
        max_blocks: args.parse_or("max-blocks", 192),
    };
    let backend: BackendKind = match args.get("backend").unwrap_or("threadpool").parse() {
        Ok(b) => b,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let threads: usize = args.parse_or("threads", 0);
    let opts = ScenarioOptions {
        short: !args.flag("full"),
        seed: args.parse_or("seed", 7),
        params,
    };
    let target = args.get("target").unwrap_or("local:direct").to_string();
    let result = match target.as_str() {
        "local:direct" => {
            let built = LocalClient::direct(params, BatchConfig::default(), backend, threads);
            match built {
                Ok(client) => scenario::run(&name, &client, &opts),
                Err(e) => {
                    eprintln!("error: {e}");
                    return 2;
                }
            }
        }
        "local:queued" => {
            let cfg = ServiceConfig { params, backend, threads, ..ServiceConfig::default() };
            match LocalClient::queued(cfg) {
                Ok(client) => scenario::run(&name, &client, &opts),
                Err(e) => {
                    eprintln!("error: {e}");
                    return 2;
                }
            }
        }
        addr => match RemoteClient::connect(addr) {
            Ok(client) => scenario::run(&name, &client, &opts),
            Err(e) => {
                eprintln!("error: connect {addr}: {e}");
                return 1;
            }
        },
    };
    let report = match result {
        Ok(r) => r,
        Err(e) => {
            eprintln!("error: {e}");
            // An unknown scenario name is a usage error; anything else
            // (transport, execution) is a runtime failure.
            return match e {
                banded_svd::error::Error::Config(_) => 2,
                _ => 1,
            };
        }
    };
    println!("{}", report.render());
    let experiment = format!("demo_{}", name.replace('-', "_"));
    match write_experiment(&experiment, &report) {
        Ok(path) => println!("report: {}", path.display()),
        Err(e) => {
            eprintln!("error: write report: {e}");
            return 1;
        }
    }
    let checks = [
        ("spectral-monitor", "drift_detected", "variance shift shows as sigma_max drift"),
        ("lowrank-compress", "error_agrees", "measured truncation error matches Eckart-Young"),
        ("spectral-pde", "frobenius_ok", "Frobenius identity holds along the c trajectory"),
    ];
    let Some((_, key, what)) = checks.iter().find(|(n, _, _)| *n == name.as_str()) else {
        return 0;
    };
    match report.get(key).and_then(Json::as_bool) {
        Some(true) => {
            println!("demo {name} ({target}): ok — {what}");
            0
        }
        _ => {
            eprintln!("demo {name} ({target}): check FAILED — {what}");
            1
        }
    }
}

fn cmd_svd(args: &banded_svd::util::cli::Args) -> i32 {
    let n: usize = args.parse_or("n", 256);
    let bw: usize = args.parse_or("bw", 16);
    let tw: usize = args.parse_or("tw", 8);
    let seed: u64 = args.parse_or("seed", 42);
    let spectrum = match args.get("spectrum").unwrap_or("arithmetic") {
        "logarithmic" => Spectrum::Logarithmic,
        "quarter-circle" => Spectrum::QuarterCircle,
        _ => Spectrum::Arithmetic,
    };
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let sigma = spectrum.sample(n, &mut rng);
    let a = dense_with_spectrum(n, &sigma, &mut rng, n.min(64));
    let opts = SvdOptions {
        bandwidth: bw,
        params: TuneParams { tpb: 32, tw, max_blocks: 192 },
    };
    let precision = args.get("precision").unwrap_or("fp64").to_string();
    let (sv, times) = match precision.as_str() {
        "fp16" => singular_values_3stage_mixed::<F16>(&a, &opts),
        "fp32" => singular_values_3stage_mixed::<f32>(&a, &opts),
        _ => singular_values_3stage_mixed::<f64>(&a, &opts),
    };
    let err = relative_sv_error(&sv, &sigma);
    println!(
        "3-stage SVD n={n} bw={bw} tw={tw} stage2={precision} [{}]",
        spectrum.name()
    );
    println!(
        "  stage1 {}  stage2 {}  stage3 {}  total {}",
        fmt_duration(times.stage1),
        fmt_duration(times.stage2),
        fmt_duration(times.stage3),
        fmt_duration(times.total())
    );
    println!("  σ_max {:.6}  σ_min {:.3e}  rel-err vs ground truth {err:.3e}", sv[0], sv[n - 1]);
    0
}

fn cmd_accuracy(args: &banded_svd::util::cli::Args) -> i32 {
    let sizes: Vec<usize> = args.parse_list("sizes", &[64, 128, 256]);
    let bw: usize = args.parse_or("bw", 16);
    let tw: usize = args.parse_or("tw", 8);
    let trials: usize = args.parse_or("trials", 3).clamp(1, 3);
    let seed: u64 = args.parse_or("seed", 7);
    let mut table = Table::new(vec!["n", "spectrum", "fp64", "fp32", "fp16"]);
    for &n in &sizes {
        for spectrum in Spectrum::ALL {
            let mut errs = [[0.0f64; 3]; 3];
            for trial in 0..trials {
                let mut rng = Xoshiro256::seed_from_u64(seed + trial as u64 * 1000 + n as u64);
                let sigma = spectrum.sample(n, &mut rng);
                let a = dense_with_spectrum(n, &sigma, &mut rng, n.min(48));
                let opts = SvdOptions {
                    bandwidth: bw.min(n / 2),
                    params: TuneParams { tpb: 32, tw, max_blocks: 192 },
                };
                let (s64, _) = singular_values_3stage_mixed::<f64>(&a, &opts);
                let (s32, _) = singular_values_3stage_mixed::<f32>(&a, &opts);
                let (s16, _) = singular_values_3stage_mixed::<F16>(&a, &opts);
                errs[0][trial] = relative_sv_error(&s64, &sigma);
                errs[1][trial] = relative_sv_error(&s32, &sigma);
                errs[2][trial] = relative_sv_error(&s16, &sigma);
            }
            let med = |xs: &[f64; 3]| {
                let mut v = xs[..trials].to_vec();
                v.sort_by(|a, b| a.partial_cmp(b).unwrap());
                v[v.len() / 2]
            };
            table.row(vec![
                n.to_string(),
                spectrum.name().to_string(),
                format!("{:.2e}", med(&errs[0])),
                format!("{:.2e}", med(&errs[1])),
                format!("{:.2e}", med(&errs[2])),
            ]);
        }
    }
    table.print();
    0
}

fn cmd_occupancy(args: &banded_svd::util::cli::Args) -> i32 {
    let cbw: usize = args.parse_or("cbw", 32);
    let mut table = Table::new(vec!["GPU", "ALUs", "n for full occupancy"]);
    for row in simulator::table1(cbw) {
        table.row(vec![row.arch.to_string(), row.alus.to_string(), row.n_required.to_string()]);
    }
    table.print();
    0
}

fn cmd_sweep(args: &banded_svd::util::cli::Args) -> i32 {
    let arch = match hw::arch_by_name(args.get("arch").unwrap_or("H100")) {
        Some(a) => a,
        None => {
            eprintln!("unknown arch; known: A100 H100 RTX4060 MI250X MI300X PVC1100 M1");
            return 2;
        }
    };
    let n: usize = args.parse_or("n", 65536);
    let bw: usize = args.parse_or("bw", 128);
    let es = es_of(args.get("precision").unwrap_or("fp32"));
    let mut table = Table::new(vec!["max_blocks", "tw", "tpb", "modeled time", "rel"]);
    let mut rows = Vec::new();
    let mut best = f64::INFINITY;
    for mb in [48usize, 96, 192, 384] {
        for tw in [8usize, 16, 32, 64] {
            if tw >= bw {
                continue;
            }
            for tpb in [16usize, 32, 64] {
                let p = TuneParams { tpb, tw, max_blocks: mb };
                let r = simulator::simulate_reduction(&arch, es, n, bw, &p);
                best = best.min(r.seconds);
                rows.push((mb, tw, tpb, r.seconds));
            }
        }
    }
    for (mb, tw, tpb, secs) in rows {
        table.row(vec![
            mb.to_string(),
            tw.to_string(),
            tpb.to_string(),
            format!("{secs:.3} s"),
            format!("{:.2}x", secs / best),
        ]);
    }
    table.print();
    0
}

fn cmd_hardware(args: &banded_svd::util::cli::Args) -> i32 {
    let sizes: Vec<usize> = args.parse_list("sizes", &[4096, 16384, 65536]);
    let bw: usize = args.parse_or("bw", 32);
    let es = es_of(args.get("precision").unwrap_or("fp32"));
    let mut headers = vec!["GPU".to_string()];
    headers.extend(sizes.iter().map(|n| format!("n={n}")));
    let mut table = Table::new(headers);
    for arch in hw::all_archs() {
        let p = TuneParams { tpb: 32, tw: (128 / es).min(bw - 1).max(1), max_blocks: 192 };
        let mut row = vec![arch.name.to_string()];
        for &n in &sizes {
            let r = simulator::simulate_reduction(&arch, es, n, bw, &p);
            row.push(format!("{:.4} s", r.seconds));
        }
        table.row(row);
    }
    table.print();
    0
}

/// `profile --measure`: run real reductions with the calibration
/// collector armed and write the folded `bsvd-profile-v1` artifact.
/// One batch per precision covers the element-size axis of the profile.
fn cmd_profile_measure(args: &banded_svd::util::cli::Args) -> i32 {
    use banded_svd::obs::calibrate;
    let n: usize = args.parse_or("n", 192);
    let bw: usize = args.parse_or("bw", 16);
    let count: usize = args.parse_or("count", 4).max(1);
    let seed: u64 = args.parse_or("seed", 42);
    let out = args.get("out").unwrap_or("profile_calibration.json").to_string();
    let kind: BackendKind = match args.get("backend").unwrap_or("threadpool").parse() {
        Ok(k) => k,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let params = TuneParams { tpb: 32, tw: 8, max_blocks: 192 };
    let threads: usize = args.parse_or("threads", 0);
    let client = match LocalClient::direct(params, BatchConfig::default(), kind, threads) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    let mut shapes = Vec::new();
    for prec in [ScalarKind::F64, ScalarKind::F32, ScalarKind::F16] {
        shapes.extend((0..count).map(|_| (n, bw, prec)));
    }
    let request = request_from_shapes(&shapes, seed);
    calibrate::begin();
    let outcome = match client.submit_wait(request) {
        Ok(o) => o,
        Err(e) => {
            calibrate::finish();
            eprintln!("error: {e}");
            return 1;
        }
    };
    let profile = calibrate::finish();
    let tasks: u64 = profile.entries.iter().map(|e| e.tasks).sum();
    match std::fs::write(&out, profile.to_json().render() + "\n") {
        Ok(()) => {
            println!(
                "measured {} problems on {}: {} kernel classes over {tasks} tasks -> {out}",
                outcome.problems.len(),
                outcome.provenance.backend,
                profile.entries.len()
            );
            0
        }
        Err(e) => {
            eprintln!("error: write {out}: {e}");
            1
        }
    }
}

fn cmd_profile(args: &banded_svd::util::cli::Args) -> i32 {
    use banded_svd::bulge::schedule::Stage;
    if args.flag("measure") {
        return cmd_profile_measure(args);
    }
    let grid = [
        (64usize, 48usize, 32usize),
        (64, 96, 32),
        (32, 96, 32),
        (32, 192, 32),
        (16, 192, 32),
        (32, 96, 16),
        (32, 192, 16),
        (64, 96, 16),
    ];
    let mut table = Table::new(vec![
        "tpb", "max_blocks", "tw", "time(us)", "mem%", "dram%", "l1%", "l2%", "compute%",
        "warps/sm",
    ]);
    for (tpb, mb, tw) in grid {
        let stage = Stage::new(64, tw);
        let blocks = 32768 / (3 * 64);
        let m = simulator::profile_kernel(&hw::RTX4060, 4, &stage, tpb, mb, blocks);
        table.row(vec![
            tpb.to_string(),
            mb.to_string(),
            tw.to_string(),
            format!("{:.0}", m.time_us),
            format!("{:.0}", m.memory_pct),
            format!("{:.0}", m.dram_pct),
            format!("{:.0}", m.l1_pct),
            format!("{:.0}", m.l2_pct),
            format!("{:.1}", m.compute_pct),
            format!("{:.2}", m.warps_per_sm),
        ]);
    }
    table.print();
    let g = simulator::profile_geam_reference(&hw::RTX4060, 4, 16384);
    println!(
        "\ngeam reference (B = A + Aᵀ, 16k): dram {:.0}%  l1 {:.0}%  l2 {:.0}%",
        g.dram_pct, g.l1_pct, g.l2_pct
    );
    0
}

fn cmd_tune(args: &banded_svd::util::cli::Args) -> i32 {
    let arch = match hw::arch_by_name(args.get("arch").unwrap_or("H100")) {
        Some(a) => a,
        None => {
            eprintln!("unknown arch; known: A100 H100 RTX4060 MI250X MI300X PVC1100 M1");
            return 2;
        }
    };
    let n: usize = args.parse_or("n", 65536);
    let bw: usize = args.parse_or("bw", 128);
    let es = es_of(args.get("precision").unwrap_or("fp32"));
    // Tune under the cost profile of the backend that will actually run.
    let profile_name = args.get("backend").unwrap_or("native");
    let profile = match profile_name {
        "native" => simulator::BackendCostModel::native(),
        "simd" => simulator::BackendCostModel::simd(),
        "pjrt" => simulator::BackendCostModel::pjrt(),
        "pjrt-streaming" => simulator::BackendCostModel::pjrt_tile_streaming(),
        other => {
            eprintln!("unknown cost profile {other:?} (native|simd|pjrt|pjrt-streaming)");
            return 2;
        }
    };
    let heuristic = simulator::heuristic_params(&arch, es, bw);
    let h_time = simulator::simulate_reduction_for(&arch, es, n, bw, &heuristic, &profile).seconds;
    println!(
        "heuristic ({}, {profile_name}): tpb={} tw={} max_blocks={}  ->  {:.3} s (modeled)",
        arch.name, heuristic.tpb, heuristic.tw, heuristic.max_blocks, h_time
    );
    let tuned = simulator::autotune_for(&arch, es, n, bw, &profile);
    println!(
        "autotuned      : tpb={} tw={} max_blocks={}  ->  {:.3} s (modeled, {} configs, {:.1}% faster)",
        tuned.params.tpb,
        tuned.params.tw,
        tuned.params.max_blocks,
        tuned.modeled_seconds,
        tuned.evaluated,
        100.0 * (h_time - tuned.modeled_seconds) / h_time
    );
    0
}

fn cmd_bench_collect(args: &banded_svd::util::cli::Args) -> i32 {
    use banded_svd::util::benchcmp::{collect_experiments, snapshot};
    let dir = std::path::PathBuf::from(args.get("dir").unwrap_or("target/experiments"));
    let out = args.get("out").unwrap_or("BENCH.json").to_string();
    let label = args.get("label").unwrap_or("local").to_string();
    let metrics = collect_experiments(&dir);
    if metrics.is_empty() {
        eprintln!(
            "no bench metrics under {} (run the perf benches first: \
             perf_hotpath, batch_scaling, service_throughput)",
            dir.display()
        );
        return 1;
    }
    let snap = snapshot(&label, true, &metrics);
    match std::fs::write(&out, snap.render() + "\n") {
        Ok(()) => {
            println!("wrote {} metrics to {out} (label {label}, measured)", metrics.len());
            0
        }
        Err(e) => {
            eprintln!("error: write {out}: {e}");
            1
        }
    }
}

fn cmd_bench_gate(args: &banded_svd::util::cli::Args) -> i32 {
    use banded_svd::util::benchcmp::{gate, GateOutcome};
    use banded_svd::util::json::Json;
    let read = |path: &str| -> Result<Json, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        Json::parse(&text).map_err(|e| format!("parse {path}: {e}"))
    };
    let baseline_path = args.get("baseline").unwrap_or("BENCH_PR7.json");
    let current_path = args.get("current").unwrap_or("BENCH.json");
    let tolerance: f64 = args.parse_or("tolerance", 0.10);
    let (baseline, current) = match (read(baseline_path), read(current_path)) {
        (Ok(b), Ok(c)) => (b, c),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    match gate(&baseline, &current, tolerance) {
        GateOutcome::SkippedUnmeasured => {
            println!(
                "baseline {baseline_path} is an unmeasured seed (or not a bench snapshot); \
                 nothing to gate against — passing"
            );
            0
        }
        GateOutcome::Compared(deltas) => {
            let mut table = Table::new(vec!["metric", "baseline", "current", "worse%", "verdict"]);
            let mut failed = false;
            for d in &deltas {
                failed |= d.regressed;
                table.row(vec![
                    d.name.clone(),
                    format!("{:.1}", d.baseline),
                    format!("{:.1}", d.current),
                    format!("{:+.1}", d.worsened_by * 100.0),
                    if d.regressed { "REGRESSED".into() } else { "ok".into() },
                ]);
            }
            table.print();
            if failed {
                eprintln!(
                    "bench gate FAILED: regression beyond {:.0}% vs {baseline_path}",
                    tolerance * 100.0
                );
                1
            } else {
                println!(
                    "bench gate passed: {} metric(s) within {:.0}% of {baseline_path}",
                    deltas.len(),
                    tolerance * 100.0
                );
                0
            }
        }
    }
}

/// `bench-promote`: replace an unmeasured BENCH baseline with a freshly
/// measured snapshot — the step that turns the bench gate from vacuous
/// (skipping an unmeasured seed) into a real regression check.
fn cmd_bench_promote(args: &banded_svd::util::cli::Args) -> i32 {
    use banded_svd::util::benchcmp::parse_snapshot;
    use banded_svd::util::json::Json;
    let candidate_path = args.get("candidate").unwrap_or("BENCH.json");
    let baseline_path = args.get("baseline").unwrap_or("BENCH_PR7.json");
    let text = match std::fs::read_to_string(candidate_path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("error: read {candidate_path}: {e}");
            return 1;
        }
    };
    let candidate = match Json::parse(&text) {
        Ok(j) => j,
        Err(e) => {
            eprintln!("error: parse {candidate_path}: {e}");
            return 1;
        }
    };
    let Some((measured, metrics)) = parse_snapshot(&candidate) else {
        eprintln!("error: {candidate_path} is not a bench snapshot");
        return 1;
    };
    if !measured {
        eprintln!("error: {candidate_path} is unmeasured; refusing to promote placeholders");
        return 1;
    }
    if metrics.is_empty() {
        eprintln!("error: {candidate_path} carries no metrics; nothing worth promoting");
        return 1;
    }
    // Replacing a measured baseline moves the regression reference and
    // needs an explicit --force; an unmeasured seed (or a missing or
    // alien file) is exactly what promotion exists to replace.
    let baseline_measured = std::fs::read_to_string(baseline_path)
        .ok()
        .and_then(|t| Json::parse(&t).ok())
        .and_then(|j| parse_snapshot(&j))
        .map(|(m, _)| m);
    if baseline_measured == Some(true) && !args.flag("force") {
        println!("baseline {baseline_path} is already measured; keeping it (--force replaces)");
        return 0;
    }
    match std::fs::write(baseline_path, text) {
        Ok(()) => {
            println!(
                "promoted {candidate_path} -> {baseline_path} ({} measured metrics)",
                metrics.len()
            );
            0
        }
        Err(e) => {
            eprintln!("error: write {baseline_path}: {e}");
            1
        }
    }
}

fn cmd_artifacts_info(args: &banded_svd::util::cli::Args) -> i32 {
    let n: usize = args.parse_or("n", 256);
    let bw: usize = args.parse_or("bw", 8);
    let tw: usize = args.parse_or("tw", 4);
    match PjrtEngine::load(&artifact_dir(), n, bw, tw) {
        Ok(engine) => {
            let m = engine.manifest();
            println!(
                "variant n={} bw={} tw={} (ld={}, kd_super={}, tpb={}), {} stages, fused={}",
                m.n,
                m.bw,
                m.tw,
                m.ld,
                m.kd_super,
                m.tpb,
                m.stages.len(),
                engine.has_fused()
            );
            for s in &m.stages {
                println!(
                    "  stage {}: b={} d={} launches={} slots={} ({})",
                    s.index, s.b, s.d, s.launches, s.slots, s.cycle_file
                );
            }
            println!("compile time: {}", fmt_duration(engine.compile_time));
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}
