//! Precision abstraction: the paper's FP16 / FP32 / FP64 axis.
//!
//! The offline crate set has no `half`, so [`F16`] is a software IEEE
//! binary16: storage is 16-bit, arithmetic converts through f32 (matching
//! how GPU half-precision behaves for the scalar operations bulge-chasing
//! performs — every op rounds back to binary16).

use crate::simd::SimdSpec;
use std::fmt;
use std::ops::{Add, Div, Mul, Neg, Sub};

/// Element type for all numeric kernels in the library.
pub trait Scalar:
    Copy
    + Clone
    + Send
    + Sync
    + PartialEq
    + PartialOrd
    + fmt::Debug
    + fmt::Display
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Div<Output = Self>
    + Neg<Output = Self>
    + 'static
{
    /// Human-readable precision name (matches the paper's labels).
    const NAME: &'static str;
    /// Bytes per element (drives the cache-line utilization model).
    const BYTES: usize;
    /// Machine epsilon as f64.
    const EPS: f64;

    fn zero() -> Self;
    fn one() -> Self;
    fn from_f64(x: f64) -> Self;
    fn to_f64(self) -> f64;

    #[inline]
    fn abs(self) -> Self {
        if self < Self::zero() {
            -self
        } else {
            self
        }
    }

    #[inline]
    fn sqrt(self) -> Self {
        Self::from_f64(self.to_f64().sqrt())
    }

    /// Fused multiply-add where the hardware provides it.
    #[inline]
    fn mul_add(self, a: Self, b: Self) -> Self {
        self * a + b
    }

    #[inline]
    fn is_finite(self) -> bool {
        self.to_f64().is_finite()
    }

    /// SIMD lane width of this type's explicit vector kernels — `1`
    /// means the type has none, and every `simd_*` hook below runs its
    /// scalar default body regardless of the requested
    /// [`SimdSpec`].
    const LANES: usize = 1;

    /// `w[i] = v.mul_add(s[i], w[i])` over the zipped prefix — the
    /// streaming reflector-apply accumulation of the cycle kernels.
    ///
    /// The default body is the scalar reference loop; `f32`/`f64`
    /// override it to dispatch through [`crate::simd::kernels`], whose
    /// element-wise arms are bitwise-identical to this body on every
    /// ISA (see the `crate::simd` equivalence contract).
    #[inline]
    fn simd_fma_axpy(_spec: SimdSpec, w: &mut [Self], v: Self, s: &[Self]) {
        for (wi, si) in w.iter_mut().zip(s.iter()) {
            *wi = v.mul_add(*si, *wi);
        }
    }

    /// `w[i] = c * w[i]` — the `tau` scaling pass.
    #[inline]
    fn simd_scale(_spec: SimdSpec, w: &mut [Self], c: Self) {
        for wi in w.iter_mut() {
            *wi = c * *wi;
        }
    }

    /// `dst[i] = dst[i] - src[i]` over the zipped prefix.
    #[inline]
    fn simd_sub(_spec: SimdSpec, dst: &mut [Self], src: &[Self]) {
        for (di, si) in dst.iter_mut().zip(src.iter()) {
            *di = *di - *si;
        }
    }

    /// `dst[i] = dst[i] - src[i] * c` — the rank-1 update column pass.
    #[inline]
    fn simd_sub_scaled(_spec: SimdSpec, dst: &mut [Self], src: &[Self], c: Self) {
        for (di, si) in dst.iter_mut().zip(src.iter()) {
            *di = *di - *si * c;
        }
    }

    /// Fused dot product `init + Σ v[i]*s[i]`, accumulated with
    /// `mul_add` in sequence. A reduction: stays sequential (bitwise
    /// vs this default) unless the spec opts in to contracted lane
    /// partials, which are deterministic but only ulp-close.
    #[inline]
    fn simd_dot_fma(_spec: SimdSpec, init: Self, v: &[Self], s: &[Self]) -> Self {
        let mut acc = init;
        for (vi, si) in v.iter().zip(s.iter()) {
            acc = vi.mul_add(*si, acc);
        }
        acc
    }

    /// Widened sum of squares `Σ to_f64(x[i])²` — the column norm
    /// behind `householder::make_reflector`. Same reduction contract
    /// as [`Scalar::simd_dot_fma`].
    #[inline]
    fn simd_tail_sum_squares(_spec: SimdSpec, x: &[Self]) -> f64 {
        let mut ssq = 0.0f64;
        for v in x {
            let t = v.to_f64();
            ssq += t * t;
        }
        ssq
    }
}

impl Scalar for f64 {
    const NAME: &'static str = "fp64";
    const BYTES: usize = 8;
    const EPS: f64 = f64::EPSILON;

    #[inline]
    fn zero() -> Self {
        0.0
    }
    #[inline]
    fn one() -> Self {
        1.0
    }
    #[inline]
    fn from_f64(x: f64) -> Self {
        x
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self
    }
    #[inline]
    fn abs(self) -> Self {
        f64::abs(self)
    }
    #[inline]
    fn sqrt(self) -> Self {
        f64::sqrt(self)
    }
    #[inline]
    fn mul_add(self, a: Self, b: Self) -> Self {
        f64::mul_add(self, a, b)
    }

    const LANES: usize = crate::simd::lane::F64x4::LANES;

    #[inline]
    fn simd_fma_axpy(spec: SimdSpec, w: &mut [Self], v: Self, s: &[Self]) {
        crate::simd::kernels::kern_f64::fma_axpy(spec, w, v, s)
    }
    #[inline]
    fn simd_scale(spec: SimdSpec, w: &mut [Self], c: Self) {
        crate::simd::kernels::kern_f64::scale(spec, w, c)
    }
    #[inline]
    fn simd_sub(spec: SimdSpec, dst: &mut [Self], src: &[Self]) {
        crate::simd::kernels::kern_f64::sub(spec, dst, src)
    }
    #[inline]
    fn simd_sub_scaled(spec: SimdSpec, dst: &mut [Self], src: &[Self], c: Self) {
        crate::simd::kernels::kern_f64::sub_scaled(spec, dst, src, c)
    }
    #[inline]
    fn simd_dot_fma(spec: SimdSpec, init: Self, v: &[Self], s: &[Self]) -> Self {
        crate::simd::kernels::kern_f64::dot_fma(spec, init, v, s)
    }
    #[inline]
    fn simd_tail_sum_squares(spec: SimdSpec, x: &[Self]) -> f64 {
        crate::simd::kernels::kern_f64::tail_sum_squares(spec, x)
    }
}

impl Scalar for f32 {
    const NAME: &'static str = "fp32";
    const BYTES: usize = 4;
    const EPS: f64 = f32::EPSILON as f64;

    #[inline]
    fn zero() -> Self {
        0.0
    }
    #[inline]
    fn one() -> Self {
        1.0
    }
    #[inline]
    fn from_f64(x: f64) -> Self {
        x as f32
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self as f64
    }
    #[inline]
    fn abs(self) -> Self {
        f32::abs(self)
    }
    #[inline]
    fn sqrt(self) -> Self {
        f32::sqrt(self)
    }
    #[inline]
    fn mul_add(self, a: Self, b: Self) -> Self {
        f32::mul_add(self, a, b)
    }

    const LANES: usize = crate::simd::lane::F32x8::LANES;

    #[inline]
    fn simd_fma_axpy(spec: SimdSpec, w: &mut [Self], v: Self, s: &[Self]) {
        crate::simd::kernels::kern_f32::fma_axpy(spec, w, v, s)
    }
    #[inline]
    fn simd_scale(spec: SimdSpec, w: &mut [Self], c: Self) {
        crate::simd::kernels::kern_f32::scale(spec, w, c)
    }
    #[inline]
    fn simd_sub(spec: SimdSpec, dst: &mut [Self], src: &[Self]) {
        crate::simd::kernels::kern_f32::sub(spec, dst, src)
    }
    #[inline]
    fn simd_sub_scaled(spec: SimdSpec, dst: &mut [Self], src: &[Self], c: Self) {
        crate::simd::kernels::kern_f32::sub_scaled(spec, dst, src, c)
    }
    #[inline]
    fn simd_dot_fma(spec: SimdSpec, init: Self, v: &[Self], s: &[Self]) -> Self {
        crate::simd::kernels::kern_f32::dot_fma(spec, init, v, s)
    }
    #[inline]
    fn simd_tail_sum_squares(spec: SimdSpec, x: &[Self]) -> f64 {
        crate::simd::kernels::kern_f32::tail_sum_squares(spec, x)
    }
}

/// IEEE 754 binary16 with round-to-nearest-even conversions; arithmetic is
/// performed in f32 and rounded back, mirroring GPU `half` behaviour.
#[derive(Copy, Clone, PartialEq)]
pub struct F16(pub u16);

impl F16 {
    pub const ZERO: F16 = F16(0);
    pub const ONE: F16 = F16(0x3C00);
    /// Machine epsilon for binary16: 2^-10.
    pub const EPSILON_F64: f64 = 9.765625e-4;

    /// Convert from f32 with round-to-nearest-even (standard bit algorithm).
    pub fn from_f32(x: f32) -> F16 {
        let bits = x.to_bits();
        let sign = ((bits >> 16) & 0x8000) as u16;
        let exp = ((bits >> 23) & 0xFF) as i32;
        let man = bits & 0x7F_FFFF;

        if exp == 0xFF {
            // Inf / NaN
            let payload = if man != 0 { 0x0200 } else { 0 };
            return F16(sign | 0x7C00 | payload);
        }
        // Unbiased exponent.
        let e = exp - 127;
        if e > 15 {
            // Overflow -> infinity.
            return F16(sign | 0x7C00);
        }
        if e >= -14 {
            // Normal half. 10 mantissa bits; round-to-nearest-even on the
            // 13 dropped bits.
            let half_exp = ((e + 15) as u16) << 10;
            let half_man = (man >> 13) as u16;
            let rest = man & 0x1FFF;
            let mut h = sign | half_exp | half_man;
            if rest > 0x1000 || (rest == 0x1000 && (half_man & 1) == 1) {
                h = h.wrapping_add(1); // may carry into exponent: correct
            }
            return F16(h);
        }
        if e >= -25 {
            // Subnormal half.
            let full_man = man | 0x80_0000; // implicit bit
            let shift = (-e - 1) as u32; // 14..24 -> shift 13+? derive:
            // value = 1.man * 2^e ; half subnormal unit = 2^-24
            // mantissa_half = round(1.man * 2^(e+24)) = full_man >> (23 - (e+24))
            let sh = (23 - (e + 24)) as u32;
            debug_assert!(sh >= 1 && sh <= 24, "sh={sh} shift={shift}");
            let half_man = (full_man >> sh) as u16;
            let rest = full_man & ((1u32 << sh) - 1);
            let halfway = 1u32 << (sh - 1);
            let mut h = sign | half_man;
            if rest > halfway || (rest == halfway && (half_man & 1) == 1) {
                h = h.wrapping_add(1);
            }
            return F16(h);
        }
        // Underflow to signed zero.
        F16(sign)
    }

    /// Convert to f32 (exact).
    pub fn to_f32(self) -> f32 {
        let h = self.0 as u32;
        let sign = (h & 0x8000) << 16;
        let exp = (h >> 10) & 0x1F;
        let man = h & 0x3FF;
        let bits = if exp == 0 {
            if man == 0 {
                sign // signed zero
            } else {
                // Subnormal: value = man · 2⁻²⁴. Normalize man = 1.f · 2ᵖ
                // (0 ≤ p ≤ 9) so the f32 exponent is p − 24 + 127.
                let p = 31 - man.leading_zeros() as i32; // floor(log2(man)), man: u32
                let m = (man << (10 - p)) & 0x3FF;
                let exp32 = (p - 24 + 127) as u32;
                sign | (exp32 << 23) | (m << 13)
            }
        } else if exp == 0x1F {
            sign | 0x7F80_0000 | (man << 13) // Inf / NaN
        } else {
            let exp32 = exp + (127 - 15);
            sign | (exp32 << 23) | (man << 13)
        };
        f32::from_bits(bits)
    }
}

impl fmt::Debug for F16 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "F16({})", self.to_f32())
    }
}

impl fmt::Display for F16 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.to_f32())
    }
}

impl PartialOrd for F16 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        self.to_f32().partial_cmp(&other.to_f32())
    }
}

macro_rules! f16_binop {
    ($trait:ident, $method:ident, $op:tt) => {
        impl $trait for F16 {
            type Output = F16;
            #[inline]
            fn $method(self, rhs: F16) -> F16 {
                F16::from_f32(self.to_f32() $op rhs.to_f32())
            }
        }
    };
}
f16_binop!(Add, add, +);
f16_binop!(Sub, sub, -);
f16_binop!(Mul, mul, *);
f16_binop!(Div, div, /);

impl Neg for F16 {
    type Output = F16;
    #[inline]
    fn neg(self) -> F16 {
        F16(self.0 ^ 0x8000)
    }
}

impl Scalar for F16 {
    const NAME: &'static str = "fp16";
    const BYTES: usize = 2;
    const EPS: f64 = F16::EPSILON_F64;

    #[inline]
    fn zero() -> Self {
        F16::ZERO
    }
    #[inline]
    fn one() -> Self {
        F16::ONE
    }
    #[inline]
    fn from_f64(x: f64) -> Self {
        F16::from_f32(x as f32)
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self.to_f32() as f64
    }
    #[inline]
    fn sqrt(self) -> Self {
        F16::from_f32(self.to_f32().sqrt())
    }
}

/// Runtime name for one of the three supported precisions — the typed
/// form of the paper's `fp16|fp32|fp64` axis used wherever a precision
/// is *data* rather than a type parameter (client request specs, CLI
/// flags, wire payloads).
///
/// # Examples
///
/// ```
/// use banded_svd::scalar::ScalarKind;
///
/// let kind: ScalarKind = "fp32".parse().unwrap();
/// assert_eq!(kind, ScalarKind::F32);
/// assert_eq!(kind.name(), "fp32");
/// assert_eq!(kind.element_bytes(), 4);
/// assert!("fp128".parse::<ScalarKind>().is_err());
/// ```
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub enum ScalarKind {
    F16,
    F32,
    F64,
}

impl ScalarKind {
    /// Every supported precision, widest first (the paper's accuracy
    /// axis order).
    pub const ALL: [ScalarKind; 3] = [ScalarKind::F64, ScalarKind::F32, ScalarKind::F16];

    /// Paper-style label — matches [`Scalar::NAME`] of the concrete type.
    pub fn name(self) -> &'static str {
        match self {
            ScalarKind::F16 => F16::NAME,
            ScalarKind::F32 => <f32 as Scalar>::NAME,
            ScalarKind::F64 => <f64 as Scalar>::NAME,
        }
    }

    /// Bytes per element — matches [`Scalar::BYTES`].
    pub fn element_bytes(self) -> usize {
        match self {
            ScalarKind::F16 => F16::BYTES,
            ScalarKind::F32 => <f32 as Scalar>::BYTES,
            ScalarKind::F64 => <f64 as Scalar>::BYTES,
        }
    }
}

impl std::str::FromStr for ScalarKind {
    type Err = String;

    fn from_str(s: &str) -> std::result::Result<Self, String> {
        match s {
            "fp16" | "f16" | "half" => Ok(ScalarKind::F16),
            "fp32" | "f32" | "single" => Ok(ScalarKind::F32),
            "fp64" | "f64" | "double" => Ok(ScalarKind::F64),
            other => Err(format!("unknown precision {other:?} (fp16|fp32|fp64)")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_kind_names_match_the_scalar_trait() {
        assert_eq!(ScalarKind::F64.name(), <f64 as Scalar>::NAME);
        assert_eq!(ScalarKind::F32.name(), <f32 as Scalar>::NAME);
        assert_eq!(ScalarKind::F16.name(), F16::NAME);
        assert_eq!(ScalarKind::F64.element_bytes(), 8);
        assert_eq!(ScalarKind::F16.element_bytes(), 2);
        for kind in ScalarKind::ALL {
            assert_eq!(kind.name().parse::<ScalarKind>().unwrap(), kind);
        }
    }

    #[test]
    fn f16_roundtrip_exact_values() {
        for &x in &[0.0f32, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0, 6.1035156e-5] {
            let h = F16::from_f32(x);
            assert_eq!(h.to_f32(), x, "roundtrip {x}");
        }
    }

    #[test]
    fn f16_known_bit_patterns() {
        assert_eq!(F16::from_f32(1.0).0, 0x3C00);
        assert_eq!(F16::from_f32(-2.0).0, 0xC000);
        assert_eq!(F16::from_f32(65504.0).0, 0x7BFF); // max finite
        assert_eq!(F16::from_f32(f32::INFINITY).0, 0x7C00);
        assert_eq!(F16::from_f32(1e9).0, 0x7C00); // overflow -> inf
        assert_eq!(F16::from_f32(5.9604645e-8).0, 0x0001); // min subnormal
    }

    #[test]
    fn f16_round_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and 1+2^-10:
        // must round to even mantissa (1.0).
        let x = 1.0f32 + (2.0f32).powi(-11);
        assert_eq!(F16::from_f32(x).0, 0x3C00);
        // 1 + 3*2^-11 is halfway between 1+2^-10 and 1+2^-9: rounds to
        // even -> 1+2^-9 (mantissa 2).
        let y = 1.0f32 + 3.0 * (2.0f32).powi(-11);
        assert_eq!(F16::from_f32(y).0, 0x3C02);
    }

    #[test]
    fn f16_subnormal_roundtrip() {
        for bits in [0x0001u16, 0x0010, 0x03FF, 0x8001, 0x83FF] {
            let h = F16(bits);
            let back = F16::from_f32(h.to_f32());
            assert_eq!(back.0, bits, "bits {bits:04x}");
        }
    }

    #[test]
    fn f16_exhaustive_roundtrip_finite() {
        // Every finite f16 must survive f16 -> f32 -> f16 exactly.
        for bits in 0..=0xFFFFu16 {
            let exp = (bits >> 10) & 0x1F;
            if exp == 0x1F {
                continue; // inf/nan
            }
            let h = F16(bits);
            assert_eq!(F16::from_f32(h.to_f32()).0, bits, "bits {bits:04x}");
        }
    }

    #[test]
    fn f16_arithmetic_rounds() {
        let a = F16::from_f32(1.0);
        let b = F16::from_f32(2.0f32.powi(-12)); // too small to change 1.0
        assert_eq!((a + b).to_f32(), 1.0);
        let c = F16::from_f32(3.0);
        assert_eq!((a + c).to_f32(), 4.0);
        assert_eq!((c * c).to_f32(), 9.0);
        assert_eq!((-c).to_f32(), -3.0);
    }

    #[test]
    fn scalar_trait_consistency() {
        fn probe<T: Scalar>() {
            assert_eq!(T::zero().to_f64(), 0.0);
            assert_eq!(T::one().to_f64(), 1.0);
            let two = T::from_f64(2.0);
            assert!((two.sqrt().to_f64() - std::f64::consts::SQRT_2).abs() < 2.0 * T::EPS);
            assert_eq!((-two).abs().to_f64(), 2.0);
            assert!(two.is_finite());
        }
        probe::<f64>();
        probe::<f32>();
        probe::<F16>();
    }

    #[test]
    fn simd_hooks_match_their_scalar_defaults_bitwise() {
        use crate::simd::{detect_isa, SimdIsa, SimdSpec};
        // Element-wise hooks must be bitwise-identical on every arm the
        // host can construct; F16 has no vector kernels (LANES == 1) so
        // the spec is inert there by construction.
        assert_eq!(<f64 as Scalar>::LANES, 4);
        assert_eq!(<f32 as Scalar>::LANES, 8);
        assert_eq!(<F16 as Scalar>::LANES, 1);
        let specs = [
            SimdSpec::scalar(),
            SimdSpec::with_contract(SimdIsa::Portable, false),
            SimdSpec::with_contract(detect_isa().unwrap_or(SimdIsa::Portable), false),
        ];
        fn probe<T: Scalar>(spec: SimdSpec) {
            let v: Vec<T> = (0..13).map(|i| T::from_f64(i as f64 * 0.375 - 2.0)).collect();
            let s: Vec<T> = (0..13).map(|i| T::from_f64(1.0 / (i as f64 + 1.5))).collect();
            let mut w = v.clone();
            T::simd_fma_axpy(spec, &mut w, T::from_f64(1.25), &s);
            let mut want = v.clone();
            for (wi, si) in want.iter_mut().zip(s.iter()) {
                *wi = T::from_f64(1.25).mul_add(*si, *wi);
            }
            assert!(w.iter().zip(&want).all(|(a, b)| a.to_f64() == b.to_f64()));
            let dot = T::simd_dot_fma(spec, T::one(), &v, &s);
            let mut acc = T::one();
            for (vi, si) in v.iter().zip(s.iter()) {
                acc = vi.mul_add(*si, acc);
            }
            assert_eq!(dot.to_f64(), acc.to_f64(), "{spec:?}");
            let mut ssq = 0.0f64;
            for x in &v {
                let t = x.to_f64();
                ssq += t * t;
            }
            assert_eq!(T::simd_tail_sum_squares(spec, &v), ssq, "{spec:?}");
        }
        for spec in specs {
            probe::<f64>(spec);
            probe::<f32>(spec);
            probe::<F16>(spec);
        }
    }

    #[test]
    fn eps_ordering_matches_precision() {
        assert!(f64::EPS < f32::EPS && f32::EPS < F16::EPS);
        assert_eq!(F16::BYTES, 2);
        assert_eq!(f32::BYTES, 4);
        assert_eq!(f64::BYTES, 8);
    }
}
