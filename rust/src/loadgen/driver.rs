//! The open-loop driver: render the seeded plan, fire it at any
//! [`Client`], record per-request outcomes.
//!
//! The whole run is planned up front ([`plan`]): the arrival process
//! fixes *when* each request fires, the mix fixes *what* it is, and both
//! come from one seed — so two runs with the same `(seed, mix, process,
//! duration)` produce byte-identical request streams
//! ([`plan_lines`] is the canonical rendering the property test
//! compares). Execution then never consults randomness again: submitter
//! `s` of `N` owns arrivals `s, s+N, s+2N, …` and fires each at its
//! scheduled offset, or immediately if the previous request on that
//! submitter ran long (the recorded [`RequestRecord::lateness`] makes
//! schedule slip visible instead of silently re-timing the run).
//!
//! Open-loop means arrivals are never skipped and never rescheduled:
//! under overload the queue sees the full offered rate and must shed —
//! which is exactly the behavior the SLO report measures.

use super::arrival::ArrivalProcess;
use super::mix::WorkloadMix;
use crate::client::Client;
use crate::obs::trace::TraceId;
use crate::util::rng::SplitMix64;
use std::time::{Duration, Instant};

/// Stream-splitting constants: decorrelate the class stream and the
/// problem-seed stream from the arrival stream without touching the
/// user-visible seed (arbitrary odd constants, in the SplitMix64
/// tradition).
const CLASS_STREAM: u64 = 0x9e37_79b9_7f4a_7c15;
const PROBLEM_STREAM: u64 = 0xd1b5_4a32_d192_ed03;

/// One planned arrival: when it fires, which class renders it, and the
/// seed of its band payload.
#[derive(Clone, Debug, PartialEq)]
pub struct PlannedArrival {
    pub index: u64,
    /// Offset from the run start.
    pub at: Duration,
    /// Index into the mix's classes.
    pub class: usize,
    /// Seed of the request's random band payload.
    pub problem_seed: u64,
    /// Deterministic per-request trace id (carried on the request, so
    /// client- and server-side spans join under it when tracing is on).
    pub trace: TraceId,
}

/// Render the full run plan — a pure function of its arguments.
pub fn plan(
    process: &ArrivalProcess,
    mix: &WorkloadMix,
    seed: u64,
    duration: Duration,
) -> Vec<PlannedArrival> {
    let schedule = process.schedule(seed, duration);
    let mut class_rng = SplitMix64::new(seed ^ CLASS_STREAM);
    let mut problem_rng = SplitMix64::new(seed ^ PROBLEM_STREAM);
    schedule
        .into_iter()
        .enumerate()
        .map(|(index, at)| PlannedArrival {
            index: index as u64,
            at,
            class: mix.pick(&mut class_rng),
            problem_seed: problem_rng.next_u64(),
            trace: TraceId(problem_rng.next_u64()),
        })
        .collect()
}

/// The canonical one-line-per-arrival rendering of a plan — what the
/// byte-identical determinism property compares across runs.
pub fn plan_lines(plan: &[PlannedArrival], mix: &WorkloadMix) -> String {
    let mut out = String::new();
    for arrival in plan {
        out.push_str(&format!(
            "{} at_ns={} trace={} {}\n",
            arrival.index,
            arrival.at.as_nanos(),
            arrival.trace.to_hex(),
            mix.classes[arrival.class].plan_line(arrival.problem_seed),
        ));
    }
    out
}

/// How one request ended.
#[derive(Clone, Debug, PartialEq)]
pub enum Disposition {
    Completed,
    Failed {
        /// The [`crate::error::JobError::kind`] wire code, or `"error"`
        /// for non-job failures (transport, config).
        kind: &'static str,
        retryable: bool,
        message: String,
    },
}

/// The per-request outcome row the report aggregates.
#[derive(Clone, Debug)]
pub struct RequestRecord {
    pub index: u64,
    pub class: usize,
    /// Scheduled offset from the run start.
    pub scheduled: Duration,
    /// How far past its schedule the request actually fired (submitter
    /// busy with the previous request) — open-loop slip, zero when the
    /// generator kept up.
    pub lateness: Duration,
    /// Submit → final wait return, retries included.
    pub latency: Duration,
    pub disposition: Disposition,
    /// Extra attempts beyond the first (retryable failures re-submitted
    /// under the retry budget).
    pub retries: u32,
    /// Attempts that ended in a retryable rejection
    /// (`overloaded`/`quota-exceeded`) — what the server counts in
    /// `jobs_rejected`, so reconciliation can match attempt-for-attempt.
    pub rejected_attempts: u32,
    /// A deadline-carrying request that did not complete within its
    /// deadline (expired in queue, shed, or returned late).
    pub missed_deadline: bool,
    pub trace: TraceId,
}

/// Run options beyond the plan inputs.
#[derive(Clone, Debug)]
pub struct RunOptions {
    pub seed: u64,
    pub duration: Duration,
    /// Retry budget per request for retryable failures (0 keeps every
    /// shed visible as a failure).
    pub max_retries: u32,
    /// Pause between retry attempts (scaled by the attempt number).
    pub retry_backoff: Duration,
}

impl Default for RunOptions {
    fn default() -> Self {
        Self {
            seed: 0,
            duration: Duration::from_secs(2),
            max_retries: 0,
            retry_backoff: Duration::from_micros(500),
        }
    }
}

/// What a run produced: the per-request records plus the measured wall
/// time from first scheduled arrival to last wait return.
#[derive(Clone, Debug)]
pub struct RunOutput {
    pub records: Vec<RequestRecord>,
    pub elapsed: Duration,
}

/// Drive the planned load through the given clients — one submitter
/// thread per slice element (pass the same reference several times to
/// share one client, e.g. a `LocalClient::queued`; pass distinct
/// `RemoteClient`s to avoid serializing on one connection's round-trip
/// lock). Blocks until every request has resolved.
pub fn run(
    clients: &[&(dyn Client + Sync)],
    mix: &WorkloadMix,
    process: &ArrivalProcess,
    opts: &RunOptions,
) -> RunOutput {
    let planned = plan(process, mix, opts.seed, opts.duration);
    let submitters = clients.len().max(1);
    let t0 = Instant::now();
    let mut records: Vec<RequestRecord> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(submitters);
        for (submitter, client) in clients.iter().enumerate() {
            let planned = &planned;
            let handle = scope.spawn(move || {
                let mut local = Vec::new();
                for arrival in planned.iter().skip(submitter).step_by(submitters) {
                    let now = t0.elapsed();
                    if now < arrival.at {
                        std::thread::sleep(arrival.at - now);
                    }
                    let lateness = t0.elapsed().saturating_sub(arrival.at);
                    local.push(fire(*client, mix, arrival, opts, lateness));
                }
                local
            });
            handles.push(handle);
        }
        handles.into_iter().flat_map(|h| h.join().expect("submitter panicked")).collect()
    });
    let elapsed = t0.elapsed();
    records.sort_by_key(|r| r.index);
    RunOutput { records, elapsed }
}

/// Submit one planned arrival (with retries) and record the outcome.
fn fire(
    client: &(dyn Client + Sync),
    mix: &WorkloadMix,
    arrival: &PlannedArrival,
    opts: &RunOptions,
    lateness: Duration,
) -> RequestRecord {
    let class = &mix.classes[arrival.class];
    let submitted = Instant::now();
    let mut retries = 0u32;
    let mut rejected_attempts = 0u32;
    let disposition = loop {
        let request = class.render(arrival.problem_seed).trace(arrival.trace);
        match client.submit_wait(request) {
            Ok(_) => break Disposition::Completed,
            Err(e) => {
                let retryable = e.is_retryable();
                if retryable {
                    rejected_attempts += 1;
                }
                if retryable && retries < opts.max_retries {
                    retries += 1;
                    std::thread::sleep(opts.retry_backoff * retries);
                    continue;
                }
                let kind = e.as_job().map_or("error", |job| job.kind());
                break Disposition::Failed { kind, retryable, message: e.to_string() };
            }
        }
    };
    let latency = submitted.elapsed();
    let missed_deadline = class.deadline.is_some_and(|deadline| match &disposition {
        Disposition::Completed => latency > deadline,
        Disposition::Failed { .. } => true,
    });
    RequestRecord {
        index: arrival.index,
        class: arrival.class,
        scheduled: arrival.at,
        lateness,
        latency,
        disposition,
        retries,
        rejected_attempts,
        missed_deadline,
        trace: arrival.trace,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mix() -> WorkloadMix {
        WorkloadMix::parse("name=a,weight=3,n=32,bw=4;name=b,n=48,bw=6,prec=fp32").unwrap()
    }

    #[test]
    fn plans_are_pure_functions_of_their_seed() {
        let process = ArrivalProcess::Poisson { rate_hz: 400.0 };
        let mix = mix();
        let d = Duration::from_secs(1);
        let a = plan(&process, &mix, 42, d);
        let b = plan(&process, &mix, 42, d);
        assert_eq!(a, b);
        assert_eq!(plan_lines(&a, &mix), plan_lines(&b, &mix));
        let c = plan(&process, &mix, 43, d);
        assert_ne!(plan_lines(&a, &mix), plan_lines(&c, &mix), "seed must matter");
        // Both classes are actually exercised.
        assert!(a.iter().any(|p| p.class == 0) && a.iter().any(|p| p.class == 1));
    }

    #[test]
    fn plan_lines_carry_one_line_per_arrival() {
        let process = ArrivalProcess::Constant { rate_hz: 50.0 };
        let mix = mix();
        let planned = plan(&process, &mix, 7, Duration::from_secs(1));
        let lines = plan_lines(&planned, &mix);
        assert_eq!(lines.lines().count(), planned.len());
        assert!(lines.lines().next().unwrap().contains("at_ns="));
    }
}
