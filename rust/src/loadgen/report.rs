//! The `bsvd-load-v1` report: per-class latency quantiles, deadline-miss
//! rate, achieved-vs-offered throughput, shed breakdown — reconciled
//! against the server's own counters — plus the SLO assertion grammar
//! that turns a report into a CI gate.
//!
//! Latency quantiles here are **interpolated** from the exact recorded
//! samples (rank `q·(len−1)`, linear between neighbors) — unlike the
//! service's log₂-bucketed histograms, the load generator holds every
//! sample, so it reports exact order statistics and the two surfaces
//! cross-check each other (histogram quantiles are upper bounds within
//! one bucket, ≤ 2× the interpolated value).
//!
//! Reconciliation compares client-observed outcomes attempt-for-attempt
//! with the service's `stats` counters: completions with
//! `jobs_completed`, terminal job failures with `jobs_failed`, retryable
//! rejections with `jobs_rejected`, and requires the queue drained —
//! exact against a service that saw only this run's traffic (the CI
//! smoke starts a fresh `serve` for precisely this reason).

use super::arrival::ArrivalProcess;
use super::driver::{Disposition, RequestRecord, RunOptions, RunOutput};
use super::mix::WorkloadMix;
use crate::client::ClientStats;
use crate::config::TuneParams;
use crate::obs::calibrate::MeasuredProfile;
use crate::plan::LaunchPlan;
use crate::simulator::hw::GpuArch;
use crate::simulator::model::{simulate_plan_calibrated, BackendCostModel};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Schema tag stamped on every report.
pub const SCHEMA: &str = "bsvd-load-v1";

/// Interpolated `q`-quantile of an ascending-sorted slice (exact order
/// statistics: rank `q·(len−1)`, linear between neighbors). `NaN` when
/// empty — the JSON layer renders it as `null`.
pub fn interp_quantile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

fn latency_json(samples_ms: &mut [f64]) -> Json {
    samples_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = if samples_ms.is_empty() {
        f64::NAN
    } else {
        samples_ms.iter().sum::<f64>() / samples_ms.len() as f64
    };
    Json::obj()
        .set("count", samples_ms.len())
        .set("p50", interp_quantile(samples_ms, 0.5))
        .set("p99", interp_quantile(samples_ms, 0.99))
        .set("p999", interp_quantile(samples_ms, 0.999))
        .set("mean", mean)
        .set("max", samples_ms.last().copied().unwrap_or(f64::NAN))
}

fn failures_json(records: &[&RequestRecord]) -> Json {
    let mut by_kind: BTreeMap<&'static str, i64> = BTreeMap::new();
    for record in records {
        if let Disposition::Failed { kind, .. } = &record.disposition {
            *by_kind.entry(kind).or_insert(0) += 1;
        }
    }
    let mut out = Json::obj();
    for (kind, count) in by_kind {
        out = out.set(kind, count);
    }
    out
}

fn deadline_json(records: &[&RequestRecord], mix: &WorkloadMix) -> Json {
    let eligible = records.iter().filter(|r| mix.classes[r.class].deadline.is_some()).count();
    let missed = records.iter().filter(|r| r.missed_deadline).count();
    let rate = if eligible == 0 { f64::NAN } else { missed as f64 / eligible as f64 };
    Json::obj().set("eligible", eligible).set("missed", missed).set("miss_rate", rate)
}

fn tally_json(records: &[&RequestRecord], mix: &WorkloadMix) -> Json {
    let completed = records.iter().filter(|r| r.disposition == Disposition::Completed).count();
    let mut latencies: Vec<f64> = records
        .iter()
        .filter(|r| r.disposition == Disposition::Completed)
        .map(|r| r.latency.as_secs_f64() * 1e3)
        .collect();
    let rejected: i64 = records.iter().map(|r| r.rejected_attempts as i64).sum();
    Json::obj()
        .set("scheduled", records.len())
        .set("completed", completed)
        .set("failed", records.len() - completed)
        .set("retries", records.iter().map(|r| r.retries as i64).sum::<i64>())
        .set("rejected_attempts", rejected)
        .set("latency_ms", latency_json(&mut latencies))
        .set("deadline", deadline_json(records, mix))
        .set("failures", failures_json(records))
}

/// Everything a report is built from. `server_stats` is the body of the
/// service's `stats` verb (or [`crate::service::Service::stats`] rendered
/// the same way); reconciliation runs only when it is present.
pub struct ReportInputs<'a> {
    pub mix: &'a WorkloadMix,
    pub process: &'a ArrivalProcess,
    pub opts: &'a RunOptions,
    pub output: &'a RunOutput,
    pub submitters: usize,
    pub target: &'a str,
    pub client_stats: Option<ClientStats>,
    pub server_stats: Option<Json>,
    pub profile: Option<Json>,
}

/// Build the `bsvd-load-v1` report.
pub fn build_report(inputs: &ReportInputs) -> Json {
    let records = &inputs.output.records;
    let all: Vec<&RequestRecord> = records.iter().collect();
    let elapsed_s = inputs.output.elapsed.as_secs_f64();
    let completed = records.iter().filter(|r| r.disposition == Disposition::Completed).count();
    let transport_errors = records
        .iter()
        .filter(|r| matches!(&r.disposition, Disposition::Failed { kind, .. } if *kind == "error"))
        .count();

    let mut classes = Vec::with_capacity(inputs.mix.classes.len());
    for (index, class) in inputs.mix.classes.iter().enumerate() {
        let rows: Vec<&RequestRecord> = records.iter().filter(|r| r.class == index).collect();
        let deadline_ms = match class.deadline {
            Some(d) => Json::from(d.as_secs_f64() * 1e3),
            None => Json::Null,
        };
        classes.push(
            Json::obj()
                .set("name", class.name.as_str())
                .set("n", class.n)
                .set("bw", class.bw)
                .set("precision", class.kind.name())
                .set("priority", class.priority as i64)
                .set("vectors", class.vectors)
                .set("deadline_ms", deadline_ms)
                .set("tally", tally_json(&rows, inputs.mix)),
        );
    }

    let mut lateness_ms: Vec<f64> =
        records.iter().map(|r| r.lateness.as_secs_f64() * 1e3).collect();

    let mut report = Json::obj()
        .set("schema", SCHEMA)
        .set("seed", inputs.opts.seed as i64)
        .set("target", inputs.target)
        .set("submitters", inputs.submitters)
        .set("duration_s", inputs.opts.duration.as_secs_f64())
        .set("elapsed_s", elapsed_s)
        .set(
            "process",
            Json::obj()
                .set("name", inputs.process.name())
                .set("offered_rate_hz", inputs.process.offered_rate_hz()),
        )
        .set(
            "throughput",
            Json::obj()
                .set(
                    "offered_jobs_per_s",
                    records.len() as f64 / inputs.opts.duration.as_secs_f64(),
                )
                .set(
                    "achieved_jobs_per_s",
                    if elapsed_s > 0.0 { completed as f64 / elapsed_s } else { f64::NAN },
                ),
        )
        .set("tally", tally_json(&all, inputs.mix))
        .set("transport_errors", transport_errors)
        .set("lateness_ms", latency_json(&mut lateness_ms))
        .set("classes", Json::Arr(classes));

    report = match inputs.client_stats {
        Some(stats) => report.set(
            "client_stats",
            Json::obj()
                .set("submitted", stats.jobs_submitted as i64)
                .set("completed", stats.jobs_completed as i64)
                .set("failed", stats.jobs_failed as i64),
        ),
        None => report.set("client_stats", Json::Null),
    };

    let reconciliation = match &inputs.server_stats {
        Some(server) => reconcile(records, transport_errors, server),
        None => Json::obj().set("checked", false).set("ok", Json::Null),
    };
    report = report.set("server", inputs.server_stats.clone().unwrap_or(Json::Null));
    report = report.set("reconciliation", reconciliation);
    report.set("profile", inputs.profile.clone().unwrap_or(Json::Null))
}

/// Compare client-observed outcomes with the server's counters —
/// attempt-for-attempt, after drain. Exact when the server saw only this
/// run's traffic.
fn reconcile(records: &[RequestRecord], transport_errors: usize, server: &Json) -> Json {
    let completed = records
        .iter()
        .filter(|r| r.disposition == Disposition::Completed)
        .count() as i64;
    // Terminal *job* failures the server also counted (a job error that
    // is not a retryable rejection was admitted and failed server-side).
    let failed_terminal = records
        .iter()
        .filter(|r| {
            matches!(
                &r.disposition,
                Disposition::Failed { kind, retryable: false, .. } if *kind != "error"
            )
        })
        .count() as i64;
    let rejected_attempts: i64 = records.iter().map(|r| r.rejected_attempts as i64).sum();

    let server_int = |key: &str| server.get(key).and_then(Json::as_i64).unwrap_or(i64::MIN);
    let mut checks = Vec::new();
    let mut all_ok = transport_errors == 0;
    let mut check = |name: &str, client: i64, server_value: i64| {
        let ok = client == server_value;
        all_ok &= ok;
        checks.push(
            Json::obj()
                .set("name", name)
                .set("client", client)
                .set("server", server_value)
                .set("ok", ok),
        );
    };
    check("completed", completed, server_int("jobs_completed"));
    check("failed_terminal", failed_terminal, server_int("jobs_failed"));
    check("rejected_attempts", rejected_attempts, server_int("jobs_rejected"));
    check("queue_drained", 0, server_int("queue_depth"));
    // The server's own invariant, independent of client observation.
    check(
        "server_submitted_equals_completed_plus_failed_plus_queued",
        server_int("jobs_submitted"),
        server_int("jobs_completed") + server_int("jobs_failed") + server_int("queue_depth"),
    );
    Json::obj()
        .set("checked", true)
        .set("ok", all_ok)
        .set("transport_errors", transport_errors)
        .set("checks", Json::Arr(checks))
}

/// Modeled admission cost vs measured latency, per class — the
/// `--profile` section. Lowers each class's plan once and prices it with
/// the plain model and (when `BSVD_PROFILE` supplied one) the measured
/// calibration, so the report shows model, calibrated model, and
/// observed wall latency side by side.
pub fn profile_section(
    mix: &WorkloadMix,
    params: &TuneParams,
    arch: &GpuArch,
    cost_model: &BackendCostModel,
    measured: Option<&MeasuredProfile>,
    records: &[RequestRecord],
) -> Json {
    let mut classes = Vec::with_capacity(mix.classes.len());
    for (index, class) in mix.classes.iter().enumerate() {
        let plan = LaunchPlan::for_problem(class.n, class.bw, params);
        let es = class.kind.element_bytes();
        let modeled_ms =
            simulate_plan_calibrated(arch, es, &plan, params.tpb, cost_model, None).seconds * 1e3;
        let calibrated_ms = measured.map(|profile| {
            simulate_plan_calibrated(arch, es, &plan, params.tpb, cost_model, Some(profile))
                .seconds
                * 1e3
        });
        let mut observed: Vec<f64> = records
            .iter()
            .filter(|r| r.class == index && r.disposition == Disposition::Completed)
            .map(|r| r.latency.as_secs_f64() * 1e3)
            .collect();
        observed.sort_by(|a, b| a.partial_cmp(b).unwrap());
        classes.push(
            Json::obj()
                .set("name", class.name.as_str())
                .set("modeled_ms", modeled_ms)
                .set("calibrated_ms", calibrated_ms.map(Json::from).unwrap_or(Json::Null))
                .set("observed_p50_ms", interp_quantile(&observed, 0.5))
                .set("observed_p99_ms", interp_quantile(&observed, 0.99)),
        );
    }
    Json::obj()
        .set("calibrated", measured.is_some())
        .set(
            "fingerprint",
            measured.map(|m| Json::s(&format!("{:016x}", m.fingerprint()))).unwrap_or(Json::Null),
        )
        .set("classes", Json::Arr(classes))
}

/// A parsed `--slo` assertion: `key=value` pairs separated by commas.
///
/// Keys: `p50_ms`, `p99_ms`, `p999_ms`, `mean_ms`, `max_ms` (aggregate
/// completion latency upper bounds), `miss_rate` (deadline-miss-rate
/// upper bound over deadline-carrying requests), `error_rate` (failed /
/// scheduled upper bound), `min_jobs_per_s` (achieved-throughput lower
/// bound).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Slo {
    entries: Vec<(String, f64)>,
}

const SLO_KEYS: [&str; 8] = [
    "p50_ms", "p99_ms", "p999_ms", "mean_ms", "max_ms", "miss_rate", "error_rate",
    "min_jobs_per_s",
];

impl Slo {
    /// Parse `p99_ms=250,miss_rate=0.01`. Empty input parses to an empty
    /// (never-violated) assertion.
    pub fn parse(spec: &str) -> Result<Self, String> {
        let mut entries = Vec::new();
        for field in spec.split(',') {
            let field = field.trim();
            if field.is_empty() {
                continue;
            }
            let (key, value) = field
                .split_once('=')
                .ok_or_else(|| format!("expected key=value, got {field:?}"))?;
            if !SLO_KEYS.contains(&key) {
                return Err(format!("unknown SLO key {key:?}; known: {}", SLO_KEYS.join(", ")));
            }
            let bound: f64 = value
                .parse()
                .map_err(|_| format!("bad SLO bound {value:?} for {key}"))?;
            if !bound.is_finite() || bound < 0.0 {
                return Err(format!("SLO bound for {key} must be finite and >= 0"));
            }
            entries.push((key.to_string(), bound));
        }
        Ok(Self { entries })
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The normalized spec string (for the report).
    pub fn spec(&self) -> String {
        self.entries
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Check a built report; returns one message per violated bound. A
    /// bound whose metric is absent (e.g. a latency quantile with zero
    /// completions, or a miss rate with no deadline-carrying requests)
    /// counts as violated — an SLO over traffic that never completed is
    /// not met.
    pub fn check(&self, report: &Json) -> Vec<String> {
        let mut violations = Vec::new();
        let metric = |path: &[&str]| -> Option<f64> {
            let mut node = report;
            for key in path {
                node = node.get(key)?;
            }
            node.as_f64().filter(|v| v.is_finite())
        };
        let error_rate = match (metric(&["tally", "failed"]), metric(&["tally", "scheduled"])) {
            (Some(failed), Some(scheduled)) if scheduled > 0.0 => Some(failed / scheduled),
            _ => None,
        };
        for (key, bound) in &self.entries {
            // Every key is an upper bound except the throughput floor.
            let lower = key.as_str() == "min_jobs_per_s";
            let actual = match key.as_str() {
                "miss_rate" => metric(&["tally", "deadline", "miss_rate"]),
                "error_rate" => error_rate,
                "min_jobs_per_s" => metric(&["throughput", "achieved_jobs_per_s"]),
                latency => metric(&["tally", "latency_ms", latency.trim_end_matches("_ms")]),
            };
            match actual {
                None => violations.push(format!("{key}: no measured value in the report")),
                Some(v) if lower && v < *bound => {
                    violations.push(format!("{key}: {v:.4} is below the bound {bound}"));
                }
                Some(v) if !lower && v > *bound => {
                    violations.push(format!("{key}: {v:.4} exceeds the bound {bound}"));
                }
                Some(_) => {}
            }
        }
        violations
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::TraceId;
    use std::time::Duration;

    #[test]
    fn interpolated_quantiles_are_exact_order_statistics() {
        assert!(interp_quantile(&[], 0.5).is_nan());
        let one = [7.0];
        assert_eq!(interp_quantile(&one, 0.0), 7.0);
        assert_eq!(interp_quantile(&one, 0.99), 7.0);
        let ladder: Vec<f64> = (1..=101).map(|k| k as f64).collect();
        assert_eq!(interp_quantile(&ladder, 0.5), 51.0);
        assert_eq!(interp_quantile(&ladder, 0.99), 100.0);
        assert_eq!(interp_quantile(&ladder, 1.0), 101.0);
        // Linear interpolation between neighbors.
        let pair = [10.0, 20.0];
        assert_eq!(interp_quantile(&pair, 0.5), 15.0);
        assert_eq!(interp_quantile(&pair, 0.75), 17.5);
    }

    #[test]
    fn slo_specs_parse_normalize_and_reject() {
        let slo = Slo::parse("p99_ms=250,miss_rate=0.01").unwrap();
        assert!(!slo.is_empty());
        assert_eq!(slo.spec(), "p99_ms=250,miss_rate=0.01");
        assert!(Slo::parse("").unwrap().is_empty());
        for bad in ["p98_ms=1", "p99_ms", "p99_ms=abc", "p99_ms=-1", "p99_ms=inf"] {
            assert!(Slo::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    fn record(
        index: u64,
        class: usize,
        latency_ms: u64,
        disposition: Disposition,
        rejected: u32,
        missed: bool,
    ) -> RequestRecord {
        RequestRecord {
            index,
            class,
            scheduled: Duration::from_millis(index),
            lateness: Duration::ZERO,
            latency: Duration::from_millis(latency_ms),
            disposition,
            retries: 0,
            rejected_attempts: rejected,
            missed_deadline: missed,
            trace: TraceId(index),
        }
    }

    fn fixture() -> (WorkloadMix, RunOutput) {
        let mix = WorkloadMix::parse("name=fast,n=32,bw=4,deadline_ms=100;n=64,bw=8").unwrap();
        let shed = Disposition::Failed {
            kind: "overloaded",
            retryable: true,
            message: "queue full".into(),
        };
        let records = vec![
            record(0, 0, 10, Disposition::Completed, 0, false),
            record(1, 0, 150, Disposition::Completed, 0, true),
            record(2, 1, 30, Disposition::Completed, 0, false),
            record(3, 1, 1, shed, 1, false),
        ];
        (mix, RunOutput { records, elapsed: Duration::from_secs(1) })
    }

    #[test]
    fn report_aggregates_classes_deadlines_and_sheds() {
        let (mix, output) = fixture();
        let process = ArrivalProcess::Constant { rate_hz: 4.0 };
        let opts = RunOptions { seed: 9, duration: Duration::from_secs(1), ..Default::default() };
        let report = build_report(&ReportInputs {
            mix: &mix,
            process: &process,
            opts: &opts,
            output: &output,
            submitters: 2,
            target: "local:queued",
            client_stats: None,
            server_stats: None,
            profile: None,
        });
        assert_eq!(report.get("schema").and_then(Json::as_str), Some(SCHEMA));
        let tally = report.get("tally").unwrap();
        assert_eq!(tally.get("scheduled").and_then(Json::as_i64), Some(4));
        assert_eq!(tally.get("completed").and_then(Json::as_i64), Some(3));
        assert_eq!(tally.get("failed").and_then(Json::as_i64), Some(1));
        assert_eq!(
            tally.get("failures").and_then(|f| f.get("overloaded")).and_then(Json::as_i64),
            Some(1)
        );
        let deadline = tally.get("deadline").unwrap();
        assert_eq!(deadline.get("eligible").and_then(Json::as_i64), Some(2));
        assert_eq!(deadline.get("missed").and_then(Json::as_i64), Some(1));
        assert_eq!(deadline.get("miss_rate").and_then(Json::as_f64), Some(0.5));
        let classes = report.get("classes").and_then(Json::as_array).unwrap();
        assert_eq!(classes.len(), 2);
        assert_eq!(
            classes[0].get("tally").and_then(|t| t.get("completed")).and_then(Json::as_i64),
            Some(2)
        );
        // Unchecked reconciliation when no server stats were supplied.
        let rec = report.get("reconciliation").unwrap();
        assert_eq!(rec.get("checked").and_then(Json::as_bool), Some(false));
        // The report round-trips through the JSON layer.
        let parsed = Json::parse(&report.render()).unwrap();
        assert_eq!(parsed.get("schema").and_then(Json::as_str), Some(SCHEMA));
    }

    #[test]
    fn reconciliation_matches_counters_attempt_for_attempt() {
        let (mix, output) = fixture();
        let process = ArrivalProcess::Constant { rate_hz: 4.0 };
        let opts = RunOptions::default();
        let server_ok = Json::obj()
            .set("jobs_submitted", 3i64)
            .set("jobs_completed", 3i64)
            .set("jobs_failed", 0i64)
            .set("jobs_rejected", 1i64)
            .set("queue_depth", 0i64);
        let inputs = |server: Json| ReportInputs {
            mix: &mix,
            process: &process,
            opts: &opts,
            output: &output,
            submitters: 1,
            target: "local:queued",
            client_stats: None,
            server_stats: Some(server),
            profile: None,
        };
        let report = build_report(&inputs(server_ok.clone()));
        let rec = report.get("reconciliation").unwrap();
        assert_eq!(rec.get("checked").and_then(Json::as_bool), Some(true));
        assert_eq!(rec.get("ok").and_then(Json::as_bool), Some(true), "{}", rec.render());

        // One completion unaccounted for server-side must flip ok.
        // (`set` appends and `get` takes the first binding, so build the
        // bad counters fresh rather than re-setting keys.)
        let server_bad = Json::obj()
            .set("jobs_submitted", 2i64)
            .set("jobs_completed", 2i64)
            .set("jobs_failed", 0i64)
            .set("jobs_rejected", 1i64)
            .set("queue_depth", 0i64);
        let report = build_report(&inputs(server_bad));
        let rec = report.get("reconciliation").unwrap();
        assert_eq!(rec.get("ok").and_then(Json::as_bool), Some(false));
    }

    #[test]
    fn slo_checks_flag_violations_and_missing_metrics() {
        let (mix, output) = fixture();
        let process = ArrivalProcess::Constant { rate_hz: 4.0 };
        let opts = RunOptions::default();
        let report = build_report(&ReportInputs {
            mix: &mix,
            process: &process,
            opts: &opts,
            output: &output,
            submitters: 1,
            target: "local:queued",
            client_stats: None,
            server_stats: None,
            profile: None,
        });
        // Latencies are 10/30/150 ms; p99 ≈ 147.6. A 200 ms bound holds,
        // a 50 ms bound does not.
        assert!(Slo::parse("p99_ms=200").unwrap().check(&report).is_empty());
        let violations = Slo::parse("p99_ms=50,miss_rate=0.25").unwrap().check(&report);
        assert_eq!(violations.len(), 2, "{violations:?}");
        // Throughput lower bound: 3 completions over 1 s.
        assert!(Slo::parse("min_jobs_per_s=2").unwrap().check(&report).is_empty());
        assert_eq!(Slo::parse("min_jobs_per_s=10").unwrap().check(&report).len(), 1);
        // error_rate = 1/4.
        assert!(Slo::parse("error_rate=0.5").unwrap().check(&report).is_empty());
        assert_eq!(Slo::parse("error_rate=0.1").unwrap().check(&report).len(), 1);
    }
}
