//! Declarative workload mixes: weighted request classes rendered into
//! [`ReductionRequest`]s.
//!
//! A [`WorkloadMix`] is a list of [`WorkloadClass`]es, each a weighted
//! template over the request surface (matrix size, bandwidth, precision,
//! priority, deadline, quota class, vectors on/off). The load generator
//! samples a class per arrival from the seeded stream and renders a
//! single-problem request whose matrix is itself seeded — so the whole
//! request stream, band payloads included, is a pure function of one
//! seed (see [`super::plan`]).
//!
//! The spec grammar (CLI `--mix`) is classes separated by `;`, fields by
//! `,`, each `key=value`:
//!
//! ```text
//! name=interactive,weight=6,n=64,bw=6,prec=fp32,priority=0,deadline_ms=500;\
//! name=bulk,weight=1,n=384,bw=24,priority=2,quota=bulk
//! ```
//!
//! `n` and `bw` are required per class; everything else defaults
//! (weight 1, fp64, priority 0, no deadline, no quota class, values
//! only). Named presets cover the regimes the related work calls out —
//! `tiny-batch` for the many-small-problems regime of batched-SVD
//! solvers, `large-band` for wide single problems.

use crate::client::ReductionRequest;
use crate::scalar::ScalarKind;
use crate::util::rng::SplitMix64;
use std::time::Duration;

/// One weighted request template of a [`WorkloadMix`].
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadClass {
    pub name: String,
    /// Relative sampling weight (> 0).
    pub weight: f64,
    pub n: usize,
    pub bw: usize,
    pub kind: ScalarKind,
    /// Queue priority, lower drains first.
    pub priority: u8,
    /// Queue deadline; a request still queued past it fails
    /// `deadline-expired` instead of executing.
    pub deadline: Option<Duration>,
    /// Quota identity shared by every request of the class (the
    /// service's `--quota-cap` keys on it).
    pub quota_class: Option<String>,
    /// Request dense U/Vᵀ panels alongside the singular values.
    pub vectors: bool,
}

impl WorkloadClass {
    /// Render one request from this template with a seeded band payload.
    pub fn render(&self, problem_seed: u64) -> ReductionRequest {
        let mut request = ReductionRequest::new()
            .random(self.n, self.bw, self.kind, problem_seed)
            .priority(self.priority)
            .with_vectors(self.vectors);
        if let Some(d) = self.deadline {
            request = request.deadline(d);
        }
        if let Some(q) = &self.quota_class {
            request = request.quota_class(q.clone());
        }
        request
    }

    /// One canonical line describing a rendered request — what the
    /// byte-identical determinism property compares.
    pub fn plan_line(&self, problem_seed: u64) -> String {
        format!(
            "{} n={} bw={} prec={} prio={} deadline_ms={} quota={} vectors={} seed={:016x}",
            self.name,
            self.n,
            self.bw,
            self.kind.name(),
            self.priority,
            self.deadline.map_or(-1i64, |d| d.as_millis() as i64),
            self.quota_class.as_deref().unwrap_or("-"),
            u8::from(self.vectors),
            problem_seed,
        )
    }
}

/// A weighted set of request classes — see the module docs for the spec
/// grammar and presets.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadMix {
    pub classes: Vec<WorkloadClass>,
}

/// Named mixes for the CLI and CI: `(name, spec, what it exercises)`.
pub const PRESETS: [(&str, &str, &str); 5] = [
    (
        "smoke",
        "name=small,weight=3,n=48,bw=6;name=medium,weight=1,n=96,bw=8,prec=fp32,priority=1",
        "two tiny classes; fast enough for CI smoke runs",
    ),
    (
        "mixed",
        "name=interactive,weight=6,n=64,bw=6,prec=fp32,priority=0,deadline_ms=500;\
         name=analytic,weight=3,n=192,bw=12,priority=1;\
         name=bulk,weight=1,n=384,bw=24,priority=2,quota=bulk",
        "mixed priorities, a deadline class, and a quota-limited bulk tier",
    ),
    (
        "tiny-batch",
        "name=tiny,weight=1,n=32,bw=4,prec=fp32",
        "many tiny problems: the per-problem-overhead regime of batched SVD solvers",
    ),
    (
        "large-band",
        "name=wide,weight=1,n=1024,bw=64",
        "wide single problems: the large-bandwidth regime of tiled bidiagonalization",
    ),
    (
        "vectors",
        "name=svd,weight=1,n=64,bw=6,vectors=1,priority=1",
        "full-SVD requests carrying dense U/Vᵀ panels back",
    ),
];

impl WorkloadMix {
    /// Resolve a CLI `--mix` value: a preset name, or an inline spec
    /// (anything containing `=`).
    pub fn resolve(value: &str) -> Result<Self, String> {
        if let Some((_, spec, _)) = PRESETS.iter().find(|(name, _, _)| *name == value) {
            return Self::parse(spec);
        }
        if value.contains('=') {
            return Self::parse(value);
        }
        let names: Vec<&str> = PRESETS.iter().map(|(n, _, _)| *n).collect();
        Err(format!("unknown mix {value:?}; presets: {}, or an inline spec", names.join(", ")))
    }

    /// Parse an inline spec (see the module docs for the grammar).
    pub fn parse(spec: &str) -> Result<Self, String> {
        let mut classes = Vec::new();
        for (index, class_spec) in spec.split(';').enumerate() {
            let class_spec = class_spec.trim();
            if class_spec.is_empty() {
                continue;
            }
            let mut class = WorkloadClass {
                name: format!("class{index}"),
                weight: 1.0,
                n: 0,
                bw: 0,
                kind: ScalarKind::F64,
                priority: 0,
                deadline: None,
                quota_class: None,
                vectors: false,
            };
            for field in class_spec.split(',') {
                let field = field.trim();
                if field.is_empty() {
                    continue;
                }
                let (key, value) = field
                    .split_once('=')
                    .ok_or_else(|| format!("expected key=value, got {field:?}"))?;
                let parse_err = |what: &str| format!("bad {what} {value:?} in class {index}");
                match key {
                    "name" => class.name = value.to_string(),
                    "weight" => {
                        class.weight =
                            value.parse().map_err(|_| parse_err("weight"))?;
                    }
                    "n" => class.n = value.parse().map_err(|_| parse_err("n"))?,
                    "bw" => class.bw = value.parse().map_err(|_| parse_err("bw"))?,
                    "prec" => {
                        class.kind = value.parse().map_err(|_| parse_err("precision"))?;
                    }
                    "priority" => {
                        class.priority = value.parse().map_err(|_| parse_err("priority"))?;
                    }
                    "deadline_ms" => {
                        let ms: u64 = value.parse().map_err(|_| parse_err("deadline_ms"))?;
                        class.deadline = Some(Duration::from_millis(ms));
                    }
                    "quota" => class.quota_class = Some(value.to_string()),
                    "vectors" => {
                        class.vectors = match value {
                            "1" | "true" | "on" => true,
                            "0" | "false" | "off" => false,
                            _ => return Err(parse_err("vectors flag")),
                        };
                    }
                    _ => return Err(format!("unknown field {key:?} in class {index}")),
                }
            }
            if class.n < 2 || class.bw == 0 || class.bw >= class.n {
                return Err(format!(
                    "class {} needs n >= 2 and 1 <= bw < n (got n={}, bw={})",
                    class.name, class.n, class.bw
                ));
            }
            if !(class.weight > 0.0 && class.weight.is_finite()) {
                return Err(format!("class {} weight must be positive", class.name));
            }
            classes.push(class);
        }
        if classes.is_empty() {
            return Err("workload mix has no classes".into());
        }
        Ok(Self { classes })
    }

    /// Sample a class index from the seeded stream, proportionally to
    /// the class weights.
    pub fn pick(&self, rng: &mut SplitMix64) -> usize {
        let total: f64 = self.classes.iter().map(|c| c.weight).sum();
        let u = (rng.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        let mut target = u * total;
        for (index, class) in self.classes.iter().enumerate() {
            if target < class.weight {
                return index;
            }
            target -= class.weight;
        }
        self.classes.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inline_specs_parse_with_defaults_and_overrides() {
        let mix = WorkloadMix::parse(
            "n=48,bw=6;name=big,weight=2.5,n=256,bw=16,prec=fp32,priority=3,\
             deadline_ms=250,quota=tenant-a,vectors=1",
        )
        .unwrap();
        assert_eq!(mix.classes.len(), 2);
        let a = &mix.classes[0];
        assert_eq!((a.name.as_str(), a.n, a.bw), ("class0", 48, 6));
        assert_eq!((a.weight, a.kind, a.priority), (1.0, ScalarKind::F64, 0));
        assert_eq!((a.deadline, a.quota_class.clone(), a.vectors), (None, None, false));
        let b = &mix.classes[1];
        assert_eq!((b.name.as_str(), b.weight, b.n, b.bw), ("big", 2.5, 256, 16));
        assert_eq!((b.kind, b.priority), (ScalarKind::F32, 3));
        assert_eq!(b.deadline, Some(Duration::from_millis(250)));
        assert_eq!(b.quota_class.as_deref(), Some("tenant-a"));
        assert!(b.vectors);
    }

    #[test]
    fn bad_specs_are_rejected_with_context() {
        for bad in [
            "",
            "n=48",
            "bw=6",
            "n=1,bw=1",
            "n=48,bw=48",
            "n=48,bw=6,weight=0",
            "n=48,bw=6,prec=fp128",
            "n=48,bw=6,vectors=maybe",
            "n=48,bw=6,shape=weird",
            "48:6",
        ] {
            assert!(WorkloadMix::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn presets_resolve_and_inline_passthrough_works() {
        for (name, _, _) in PRESETS {
            let mix = WorkloadMix::resolve(name).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(!mix.classes.is_empty(), "{name}");
        }
        assert!(WorkloadMix::resolve("n=48,bw=6").is_ok());
        assert!(WorkloadMix::resolve("no-such-preset").is_err());
    }

    #[test]
    fn weighted_pick_tracks_the_weights() {
        let mix = WorkloadMix::parse("name=a,weight=9,n=32,bw=4;name=b,weight=1,n=32,bw=4")
            .unwrap();
        let mut rng = SplitMix64::new(11);
        let picks_a = (0..10_000).filter(|_| mix.pick(&mut rng) == 0).count();
        assert!((picks_a as f64 - 9000.0).abs() < 300.0, "{picks_a}");
    }

    #[test]
    fn render_carries_every_template_field() {
        let mix = WorkloadMix::parse(
            "name=c,n=64,bw=8,prec=fp32,priority=2,deadline_ms=100,quota=q,vectors=1",
        )
        .unwrap();
        let request = mix.classes[0].render(7);
        assert_eq!(request.len(), 1);
        let line = mix.classes[0].plan_line(7);
        assert!(line.contains("n=64 bw=8 prec=fp32 prio=2 deadline_ms=100 quota=q vectors=1"));
        assert!(line.ends_with(&format!("seed={:016x}", 7)));
    }
}
