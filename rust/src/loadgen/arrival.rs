//! Deterministic open-loop arrival processes.
//!
//! An arrival process turns `(seed, duration)` into a fixed schedule of
//! submission instants **before the run starts** — the driver fires each
//! arrival at its scheduled offset whether or not earlier requests have
//! completed, which is what makes measured overload real instead of
//! self-throttled (a closed-loop generator slows down exactly when the
//! server does, hiding the queue it should be filling).
//!
//! Every process is a pure function of its seed: randomness comes from
//! one [`SplitMix64`] stream seeded explicitly, never from the clock or
//! any other ambient entropy, so one seed reproduces one schedule
//! byte-for-byte (property-tested in `rust/tests/loadgen_slo.rs`).

use crate::util::rng::SplitMix64;
use std::time::Duration;

/// Map a `u64` draw onto `[0, 1)` with 53 uniform mantissa bits.
fn unit_f64(x: u64) -> f64 {
    (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// A seeded arrival process — see the module docs for the open-loop
/// contract. Parsed from the CLI spec grammar in
/// [`ArrivalProcess::parse`].
#[derive(Clone, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Evenly spaced arrivals at `rate_hz`.
    Constant { rate_hz: f64 },
    /// Poisson arrivals: exponential inter-arrival times with mean
    /// `1/rate_hz`, drawn from the seeded stream.
    Poisson { rate_hz: f64 },
    /// On/off square wave: each `period_s` window spends `duty · period`
    /// seconds at `burst_hz`, the remainder at `base_hz`.
    Bursty { base_hz: f64, burst_hz: f64, period_s: f64, duty: f64 },
    /// Linear ramp from `start_hz` at t=0 to `end_hz` at the end of the
    /// run (arrivals from the inverted cumulative-rate integral, so the
    /// instantaneous rate is exact, not stair-stepped).
    Ramp { start_hz: f64, end_hz: f64 },
}

impl ArrivalProcess {
    /// Parse the CLI spec: `constant:RATE`, `poisson:RATE`,
    /// `bursty:BASE:BURST:PERIOD_S:DUTY`, `ramp:START:END` (all rates in
    /// arrivals/second).
    pub fn parse(spec: &str) -> Result<Self, String> {
        let mut parts = spec.split(':');
        let name = parts.next().unwrap_or("");
        let nums: Vec<f64> = parts
            .map(|p| p.parse::<f64>().map_err(|_| format!("bad number {p:?} in {spec:?}")))
            .collect::<Result<_, _>>()?;
        let positive = |x: f64, what: &str| {
            if x > 0.0 && x.is_finite() {
                Ok(x)
            } else {
                Err(format!("{what} must be positive and finite in {spec:?}"))
            }
        };
        match (name, nums.as_slice()) {
            ("constant", [rate]) => Ok(Self::Constant { rate_hz: positive(*rate, "rate")? }),
            ("poisson", [rate]) => Ok(Self::Poisson { rate_hz: positive(*rate, "rate")? }),
            ("bursty", [base, burst, period, duty]) => {
                if !(0.0..=1.0).contains(duty) {
                    return Err(format!("duty must be in [0, 1] in {spec:?}"));
                }
                if *base < 0.0 || !base.is_finite() {
                    return Err(format!("base rate must be >= 0 and finite in {spec:?}"));
                }
                Ok(Self::Bursty {
                    base_hz: *base,
                    burst_hz: positive(*burst, "burst rate")?,
                    period_s: positive(*period, "period")?,
                    duty: *duty,
                })
            }
            ("ramp", [start, end]) => Ok(Self::Ramp {
                start_hz: positive(*start, "start rate")?,
                end_hz: positive(*end, "end rate")?,
            }),
            _ => Err(format!(
                "unknown arrival process {spec:?}; expected constant:RATE, poisson:RATE, \
                 bursty:BASE:BURST:PERIOD_S:DUTY, or ramp:START:END"
            )),
        }
    }

    /// The process family name (for reports and labels).
    pub fn name(&self) -> &'static str {
        match self {
            Self::Constant { .. } => "constant",
            Self::Poisson { .. } => "poisson",
            Self::Bursty { .. } => "bursty",
            Self::Ramp { .. } => "ramp",
        }
    }

    /// Mean offered rate over a run, arrivals/second — the denominator of
    /// achieved-vs-offered throughput in the report.
    pub fn offered_rate_hz(&self) -> f64 {
        match self {
            Self::Constant { rate_hz } | Self::Poisson { rate_hz } => *rate_hz,
            Self::Bursty { base_hz, burst_hz, duty, .. } => {
                duty * burst_hz + (1.0 - duty) * base_hz
            }
            Self::Ramp { start_hz, end_hz } => 0.5 * (start_hz + end_hz),
        }
    }

    /// The full arrival schedule for one run: offsets from the run start,
    /// strictly non-decreasing, every offset `< duration`. Pure function
    /// of `(self, seed, duration)`.
    pub fn schedule(&self, seed: u64, duration: Duration) -> Vec<Duration> {
        let horizon = duration.as_secs_f64();
        let mut out = Vec::new();
        match self {
            Self::Constant { rate_hz } => {
                let mut k = 1u64;
                loop {
                    let t = k as f64 / rate_hz;
                    if t >= horizon {
                        break;
                    }
                    out.push(Duration::from_secs_f64(t));
                    k += 1;
                }
            }
            Self::Poisson { rate_hz } => {
                let mut rng = SplitMix64::new(seed);
                let mut t = 0.0f64;
                loop {
                    let u = unit_f64(rng.next_u64());
                    t += -(1.0 - u).ln() / rate_hz;
                    if t >= horizon {
                        break;
                    }
                    out.push(Duration::from_secs_f64(t));
                }
            }
            Self::Bursty { base_hz, burst_hz, period_s, duty } => {
                // Walk time, stepping by the inter-arrival gap of the
                // regime in force at the current instant; a base rate of
                // zero jumps straight to the next burst window.
                let mut t = 0.0f64;
                let on = duty * period_s;
                loop {
                    let phase = t.rem_euclid(*period_s);
                    let rate = if phase < on { *burst_hz } else { *base_hz };
                    if rate <= 0.0 {
                        // Off regime with no base traffic: skip to the
                        // start of the next period's burst window.
                        t = (t / period_s).floor() * period_s + period_s;
                        if t >= horizon {
                            break;
                        }
                        continue;
                    }
                    t += 1.0 / rate;
                    if t >= horizon {
                        break;
                    }
                    out.push(Duration::from_secs_f64(t));
                }
            }
            Self::Ramp { start_hz, end_hz } => {
                // Cumulative arrivals Λ(t) = start·t + (end−start)·t²/2T;
                // arrival k fires at the t solving Λ(t) = k.
                let slope = (end_hz - start_hz) / horizon;
                let mut k = 1u64;
                loop {
                    let t = if slope.abs() < 1e-12 {
                        k as f64 / start_hz
                    } else {
                        let disc = start_hz * start_hz + 2.0 * slope * k as f64;
                        if disc < 0.0 {
                            break;
                        }
                        (-start_hz + disc.sqrt()) / slope
                    };
                    if !t.is_finite() || t < 0.0 || t >= horizon {
                        break;
                    }
                    out.push(Duration::from_secs_f64(t));
                    k += 1;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_parse_and_reject() {
        assert_eq!(
            ArrivalProcess::parse("constant:200").unwrap(),
            ArrivalProcess::Constant { rate_hz: 200.0 }
        );
        assert_eq!(
            ArrivalProcess::parse("poisson:150.5").unwrap(),
            ArrivalProcess::Poisson { rate_hz: 150.5 }
        );
        assert_eq!(
            ArrivalProcess::parse("bursty:50:400:2:0.25").unwrap(),
            ArrivalProcess::Bursty { base_hz: 50.0, burst_hz: 400.0, period_s: 2.0, duty: 0.25 }
        );
        assert_eq!(
            ArrivalProcess::parse("ramp:10:500").unwrap(),
            ArrivalProcess::Ramp { start_hz: 10.0, end_hz: 500.0 }
        );
        for bad in [
            "steady:10",
            "constant",
            "constant:0",
            "constant:-5",
            "poisson:nan",
            "bursty:1:2:3",
            "bursty:1:2:3:1.5",
            "ramp:10",
        ] {
            assert!(ArrivalProcess::parse(bad).is_err(), "{bad} should not parse");
        }
    }

    #[test]
    fn schedules_are_monotone_bounded_and_seed_deterministic() {
        let d = Duration::from_secs(2);
        for spec in ["constant:100", "poisson:100", "bursty:20:300:0.5:0.3", "ramp:50:150"] {
            let p = ArrivalProcess::parse(spec).unwrap();
            let a = p.schedule(7, d);
            let b = p.schedule(7, d);
            assert_eq!(a, b, "{spec}: same seed must reproduce the schedule");
            assert!(!a.is_empty(), "{spec}: schedule empty");
            assert!(a.windows(2).all(|w| w[0] <= w[1]), "{spec}: not monotone");
            assert!(a.iter().all(|t| *t < d), "{spec}: offset past the horizon");
        }
    }

    #[test]
    fn poisson_seeds_differ_and_mean_rate_is_close() {
        let p = ArrivalProcess::Poisson { rate_hz: 500.0 };
        let d = Duration::from_secs(4);
        let a = p.schedule(1, d);
        let b = p.schedule(2, d);
        assert_ne!(a, b, "different seeds must give different Poisson schedules");
        // 2000 expected arrivals; 5σ ≈ 224.
        assert!((a.len() as f64 - 2000.0).abs() < 250.0, "got {}", a.len());
    }

    #[test]
    fn constant_and_ramp_match_their_closed_forms() {
        let c = ArrivalProcess::Constant { rate_hz: 10.0 };
        let s = c.schedule(0, Duration::from_secs(1));
        assert_eq!(s.len(), 9, "arrivals at 0.1 .. 0.9");
        assert!((s[0].as_secs_f64() - 0.1).abs() < 1e-12);

        // Ramp 0→? average (10+30)/2 = 20 Hz over 2 s ≈ 40 arrivals.
        let r = ArrivalProcess::Ramp { start_hz: 10.0, end_hz: 30.0 };
        let s = r.schedule(0, Duration::from_secs(2));
        assert!((s.len() as i64 - 40).unsigned_abs() <= 1, "got {}", s.len());
        // Early gaps are wider than late gaps (the rate actually ramps).
        let first_gap = s[1].as_secs_f64() - s[0].as_secs_f64();
        let last_gap = s[s.len() - 1].as_secs_f64() - s[s.len() - 2].as_secs_f64();
        assert!(first_gap > last_gap, "{first_gap} vs {last_gap}");
    }

    #[test]
    fn bursty_concentrates_arrivals_in_the_duty_window() {
        let p =
            ArrivalProcess::Bursty { base_hz: 10.0, burst_hz: 400.0, period_s: 1.0, duty: 0.25 };
        let s = p.schedule(3, Duration::from_secs(1));
        let in_burst = s.iter().filter(|t| t.as_secs_f64() < 0.25).count();
        assert!(in_burst as f64 > 0.8 * s.len() as f64, "{in_burst}/{}", s.len());
        assert_eq!(p.offered_rate_hz(), 0.25 * 400.0 + 0.75 * 10.0);
    }
}
