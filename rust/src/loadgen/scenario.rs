//! The demo scenario suite: three end-to-end applications driven
//! through the [`Client`] seam.
//!
//! Each scenario is a small, self-contained application of banded SVD
//! that exercises a different part of the serving surface, runs against
//! *any* client (direct, queued, remote, sharded — the CLI picks), and
//! returns a machine-checkable JSON summary:
//!
//! - `spectral-monitor` — streaming spectral monitoring: a seeded
//!   Gaussian data stream, a sliding-window covariance restricted to a
//!   band, one reduction per window; a variance shift injected mid-stream
//!   must show up as σ_max drift in the report.
//! - `lowrank-compress` — a low-rank compression service: a matrix with
//!   logarithmically decaying spectrum is banded (stage 1), reduced with
//!   `vectors: true`, and truncated to the rank hitting a tail-energy
//!   target; the measured reconstruction error must match the predicted
//!   `sqrt(Σ tail σ²)` — the vectors path verified end to end.
//! - `spectral-pde` — the `spectral_pde` example scaled up and pushed
//!   through the client seam: an ultraspherical-style banded operator
//!   `D2 + c·D1`, condition-number trajectory as the advection
//!   coefficient `c` marches, Frobenius identity checked per step.
//!
//! Every scenario has a `short` configuration sized for CI (seconds, not
//! minutes) and a full configuration for real runs; both are pure
//! functions of [`ScenarioOptions::seed`].

use crate::banded::dense::Dense;
use crate::banded::storage::Banded;
use crate::client::{Client, ReductionRequest};
use crate::config::TuneParams;
use crate::error::{Error, Result};
use crate::generate::{dense_with_spectrum, Spectrum};
use crate::pipeline::stage1::dense_to_band;
use crate::util::json::Json;
use crate::util::rng::Xoshiro256;
use std::collections::VecDeque;

/// Scenario catalog: `(name, what it demonstrates)`.
pub const SCENARIOS: [(&str, &str); 3] = [
    (
        "spectral-monitor",
        "streaming sliding-window covariance -> singular values; detects a variance shift",
    ),
    (
        "lowrank-compress",
        "vectors-enabled truncation service; measured vs predicted reconstruction error",
    ),
    (
        "spectral-pde",
        "banded spectral operator D2 + c*D1; condition trajectory as c marches",
    ),
];

/// How to run a scenario. `params` must match the tuning of the
/// executing side (explicit band payloads are laid out under its
/// effective tile width).
#[derive(Clone, Debug)]
pub struct ScenarioOptions {
    /// CI-sized configuration (seconds) instead of the full run.
    pub short: bool,
    pub seed: u64,
    pub params: TuneParams,
}

impl Default for ScenarioOptions {
    fn default() -> Self {
        Self { short: true, seed: 7, params: TuneParams::default() }
    }
}

/// Run one scenario by catalog name against any client.
pub fn run(name: &str, client: &dyn Client, opts: &ScenarioOptions) -> Result<Json> {
    match name {
        "spectral-monitor" => spectral_monitor(client, opts),
        "lowrank-compress" => lowrank_compress(client, opts),
        "spectral-pde" => spectral_pde(client, opts),
        _ => {
            let names: Vec<&str> = SCENARIOS.iter().map(|(n, _)| *n).collect();
            Err(Error::Config(format!(
                "unknown scenario {name:?}; available: {}",
                names.join(", ")
            )))
        }
    }
}

/// One fresh sample of the monitored stream. After the injected shift,
/// the first quarter of the coordinates triple their standard deviation
/// — a ~9x variance jump the covariance spectrum must expose.
fn monitor_sample(n: usize, shifted: bool, rng: &mut Xoshiro256) -> Vec<f64> {
    (0..n)
        .map(|i| {
            let amp = if shifted && i < n / 4 { 3.0 } else { 1.0 };
            amp * rng.gaussian()
        })
        .collect()
}

/// Sliding-window covariance restricted to the monitored band (the
/// upper `bw` off-diagonals — exactly the structure the reduction
/// consumes, so no dense detour).
fn banded_covariance(samples: &VecDeque<Vec<f64>>, n: usize, bw: usize, tw: usize) -> Banded<f64> {
    let mut cov = Banded::<f64>::for_reduction(n, bw, tw);
    let scale = 1.0 / samples.len() as f64;
    for i in 0..n {
        for j in i..(i + bw + 1).min(n) {
            let mut acc = 0.0;
            for x in samples {
                acc += x[i] * x[j];
            }
            cov.set(i, j, acc * scale);
        }
    }
    cov
}

fn spectral_monitor(client: &dyn Client, opts: &ScenarioOptions) -> Result<Json> {
    let (n, bw, window, fresh, steps) =
        if opts.short { (48, 6, 32, 16, 6) } else { (256, 8, 128, 64, 24) };
    let tw = opts.params.effective_tw(bw);
    let shift_step = steps / 2;
    let mut rng = Xoshiro256::seed_from_u64(opts.seed);
    let mut samples: VecDeque<Vec<f64>> = VecDeque::new();
    for _ in 0..window {
        samples.push_back(monitor_sample(n, false, &mut rng));
    }

    let mut sigma_max = Vec::with_capacity(steps);
    let mut step_rows = Vec::with_capacity(steps);
    for step in 0..steps {
        let shifted = step >= shift_step;
        for _ in 0..fresh {
            samples.push_back(monitor_sample(n, shifted, &mut rng));
        }
        while samples.len() > window {
            samples.pop_front();
        }
        let cov = banded_covariance(&samples, n, bw, tw);
        let outcome = client.submit_wait(ReductionRequest::new().problem((cov, bw)))?;
        let sv = &outcome.problems[0].sv;
        sigma_max.push(sv[0]);
        step_rows.push(
            Json::obj()
                .set("step", step)
                .set("shifted", shifted)
                .set("sigma_max", sv[0])
                .set("sigma_min", sv[n - 1]),
        );
    }

    // By the last step the window holds only post-shift samples, so the
    // top singular value must sit well above the pre-shift baseline.
    let drift_ratio = sigma_max[steps - 1] / sigma_max[0];
    Ok(Json::obj()
        .set("scenario", "spectral-monitor")
        .set("short", opts.short)
        .set("n", n)
        .set("bw", bw)
        .set("window", window)
        .set("steps", Json::Arr(step_rows))
        .set("shift_step", shift_step)
        .set("drift_ratio", drift_ratio)
        .set("drift_detected", drift_ratio > 1.5))
}

/// `sqrt(Σ_{k >= keep} σ_k²)` — the Frobenius error of the best rank-
/// `keep` approximation (Eckart–Young).
fn tail_energy(sv: &[f64], keep: usize) -> f64 {
    sv[keep..].iter().map(|s| s * s).sum::<f64>().sqrt()
}

/// Smallest rank whose truncation error is within `tol` of the total
/// Frobenius norm.
fn rank_for(sv: &[f64], tol: f64, total: f64) -> usize {
    (0..=sv.len()).find(|&k| tail_energy(sv, k) <= tol * total).unwrap_or(sv.len())
}

/// `U[:, :rank] · diag(σ[:rank]) · Vt[:rank, :]`.
fn truncated(u: &Dense<f64>, sv: &[f64], vt: &Dense<f64>, rank: usize) -> Dense<f64> {
    let n = u.rows;
    let mut out = Dense::<f64>::zeros(n, n);
    for t in 0..rank {
        for i in 0..n {
            let uis = u.get(i, t) * sv[t];
            let row = out.row_mut(i);
            let vrow = vt.row(t);
            for j in 0..n {
                row[j] += uis * vrow[j];
            }
        }
    }
    out
}

fn fro_diff(a: &Dense<f64>, b: &Dense<f64>) -> f64 {
    a.data.iter().zip(&b.data).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
}

fn lowrank_compress(client: &dyn Client, opts: &ScenarioOptions) -> Result<Json> {
    let (n, bw) = if opts.short { (48, 6) } else { (96, 8) };
    let tw = opts.params.effective_tw(bw);
    let mut rng = Xoshiro256::seed_from_u64(opts.seed);
    let sigma = Spectrum::Logarithmic.sample(n, &mut rng);
    let dense = dense_with_spectrum(n, &sigma, &mut rng, n);
    let band = dense_to_band(&dense, bw, tw);
    let band_dense = Dense::from_vec(n, n, band.to_dense());

    let request = ReductionRequest::new().problem((band, bw)).with_vectors(true);
    let outcome = client.submit_wait(request)?;
    let problem = &outcome.problems[0];
    let missing = || Error::Config("vectors missing from a with_vectors outcome".into());
    let u = problem.u.as_ref().ok_or_else(missing)?;
    let vt = problem.vt.as_ref().ok_or_else(missing)?;
    let sv = &problem.sv;
    let total = tail_energy(sv, 0);

    let tols = [1e-1, 1e-2, 1e-3];
    let ranks: Vec<usize> = tols.iter().map(|&tol| rank_for(sv, tol, total)).collect();
    let rank_rows: Vec<Json> = tols
        .iter()
        .zip(&ranks)
        .map(|(&tol, &rank)| {
            Json::obj()
                .set("tol", tol)
                .set("rank", rank)
                .set("predicted_err", tail_energy(sv, rank))
        })
        .collect();

    // Verify the middle truncation against an explicit reconstruction:
    // the measured Frobenius error must match Eckart–Young exactly (up
    // to f64 accumulation).
    let rank = ranks[1];
    let approx = truncated(u, sv, vt, rank);
    let measured = fro_diff(&band_dense, &approx);
    let predicted = tail_energy(sv, rank);
    let agreement = (measured - predicted).abs() <= 1e-8 * total.max(1.0);
    let stored = rank * (2 * n + 1);
    Ok(Json::obj()
        .set("scenario", "lowrank-compress")
        .set("short", opts.short)
        .set("n", n)
        .set("bw", bw)
        .set("fro_norm", total)
        .set("ranks", Json::Arr(rank_rows))
        .set("verified_rank", rank)
        .set("measured_err", measured)
        .set("predicted_err", predicted)
        .set("error_agrees", agreement)
        .set("compression_ratio", stored as f64 / (n * n) as f64))
}

/// Banded spectral operator `D2 + c·D1` in a coefficient basis — the
/// `spectral_pde` example's generator, here driven through the client
/// seam at larger scale.
fn spectral_operator(n: usize, c: f64, bw: usize, tw: usize) -> Banded<f64> {
    let mut a = Banded::<f64>::for_reduction(n, bw, tw);
    for i in 0..n {
        let k = i as f64 + 1.0;
        a.set(i, i, k * (k + 1.0));
        for off in 1..=bw.min(n - 1 - i) {
            let w = c * k / (k + off as f64);
            a.set(i, i + off, if off % 2 == 1 { w } else { w / 2.0 });
        }
    }
    a
}

fn spectral_pde(client: &dyn Client, opts: &ScenarioOptions) -> Result<Json> {
    let (n, cs): (usize, &[f64]) = if opts.short {
        (192, &[0.0, 1.0, 10.0])
    } else {
        (2048, &[0.0, 0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0])
    };
    let bw = 4;
    let tw = opts.params.effective_tw(bw);

    let mut rows = Vec::with_capacity(cs.len());
    let mut worst_fro_rel = 0.0f64;
    for &c in cs {
        let op = spectral_operator(n, c, bw, tw);
        let fro = op.fro_norm();
        let outcome = client.submit_wait(ReductionRequest::new().problem((op, bw)))?;
        let sv = &outcome.problems[0].sv;
        let sigma_max = sv[0];
        let sigma_min = sv[n - 1].max(1e-300);
        let sv_fro = tail_energy(sv, 0);
        let fro_rel = (sv_fro - fro).abs() / fro.max(1e-300);
        worst_fro_rel = worst_fro_rel.max(fro_rel);
        rows.push(
            Json::obj()
                .set("c", c)
                .set("sigma_max", sigma_max)
                .set("sigma_min", sigma_min)
                .set("cond", sigma_max / sigma_min)
                .set("fro_rel_err", fro_rel),
        );
    }

    Ok(Json::obj()
        .set("scenario", "spectral-pde")
        .set("short", opts.short)
        .set("n", n)
        .set("bw", bw)
        .set("steps", Json::Arr(rows))
        .set("worst_fro_rel_err", worst_fro_rel)
        .set("frobenius_ok", worst_fro_rel < 1e-8))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::LocalClient;

    fn options() -> ScenarioOptions {
        ScenarioOptions {
            short: true,
            seed: 7,
            params: TuneParams { tpb: 32, tw: 4, max_blocks: 24 },
        }
    }

    #[test]
    fn unknown_scenarios_are_rejected_with_the_catalog() {
        let client = LocalClient::new(options().params);
        let err = run("no-such-demo", &client, &options()).unwrap_err();
        assert!(err.to_string().contains("spectral-monitor"), "{err}");
    }

    #[test]
    fn spectral_monitor_detects_the_injected_shift() {
        let opts = options();
        let client = LocalClient::new(opts.params);
        let report = run("spectral-monitor", &client, &opts).unwrap();
        assert_eq!(report.get("drift_detected").and_then(Json::as_bool), Some(true));
        let steps = report.get("steps").and_then(Json::as_array).unwrap();
        assert_eq!(steps.len(), 6);
    }

    #[test]
    fn lowrank_compress_matches_eckart_young() {
        let opts = options();
        let client = LocalClient::new(opts.params);
        let report = run("lowrank-compress", &client, &opts).unwrap();
        assert_eq!(report.get("error_agrees").and_then(Json::as_bool), Some(true));
        // A six-decade logarithmic spectrum compresses well below full
        // rank at the 1e-2 tail target.
        let rank = report.get("verified_rank").and_then(Json::as_usize).unwrap();
        assert!(rank > 0 && rank < 48, "rank {rank}");
    }

    #[test]
    fn spectral_pde_holds_the_frobenius_identity() {
        let opts = options();
        let client = LocalClient::new(opts.params);
        let report = run("spectral-pde", &client, &opts).unwrap();
        assert_eq!(report.get("frobenius_ok").and_then(Json::as_bool), Some(true));
        let steps = report.get("steps").and_then(Json::as_array).unwrap();
        let conds: Vec<f64> =
            steps.iter().map(|s| s.get("cond").and_then(Json::as_f64).unwrap()).collect();
        assert!(conds.iter().all(|c| c.is_finite() && *c >= 1.0), "{conds:?}");
    }
}
