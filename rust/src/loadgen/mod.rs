//! Open-loop SLO load generation and the demo scenario suite.
//!
//! The serving tier ([`crate::service`], fronted by [`crate::client`])
//! exists to absorb *streams* of reduction traffic; this module is the
//! machinery that proves it, with numbers:
//!
//! - [`arrival`] — deterministic open-loop arrival processes
//!   (constant, Poisson, bursty on/off, linear ramp). Arrivals fire on
//!   schedule whether or not earlier requests completed, so overload is
//!   real, not self-throttled.
//! - [`mix`] — declarative weighted [`mix::WorkloadMix`] over the
//!   request surface (n/bandwidth/precision, priority, deadline, quota
//!   class, vectors), rendered into seeded
//!   [`crate::client::ReductionRequest`]s.
//! - [`driver`] — plans a run as a pure function of one seed (same
//!   seed ⇒ byte-identical request stream) and drives it through any
//!   [`crate::client::Client`] on N submitter threads, recording
//!   per-request latency, typed failure kind, retries, and deadline
//!   outcome.
//! - [`report`] — the `bsvd-load-v1` JSON report: interpolated
//!   p50/p99/p999 per class, deadline-miss rate, achieved-vs-offered
//!   throughput, shed breakdown, client/server counter reconciliation,
//!   and the [`report::Slo`] assertion grammar that makes a run a CI
//!   gate (`banded-svd loadgen --slo 'p99_ms=250,miss_rate=0.01'`).
//! - [`scenario`] — three end-to-end demos through the same client
//!   seam (`banded-svd demo <name>`): streaming spectral monitoring,
//!   low-rank compression with verified truncation error, and the
//!   scaled-up spectral-PDE stepper.
//!
//! See `docs/scenarios.md` for the catalog, the mix grammar, the report
//! schema, and SLO recipes.

pub mod arrival;
pub mod driver;
pub mod mix;
pub mod report;
pub mod scenario;

pub use arrival::ArrivalProcess;
pub use driver::{plan, plan_lines, run, Disposition, RequestRecord, RunOptions, RunOutput};
pub use mix::{WorkloadClass, WorkloadMix};
pub use report::{build_report, ReportInputs, Slo};
pub use scenario::{ScenarioOptions, SCENARIOS};
