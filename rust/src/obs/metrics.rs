//! Unified metrics: log₂-bucketed latency histograms with quantile
//! derivation, the always-on [`ServiceMetrics`] block the batcher records
//! into, and the Prometheus-style text exposition that renders the
//! existing ad-hoc stats surfaces ([`crate::service::ServiceStats`],
//! per-shard breakdowns, plan-cache hit/miss) onto one naming scheme.
//!
//! Recording is lock-free (relaxed atomics, one `fetch_add` per bucket
//! hit) and cheap enough to stay on unconditionally — same policy as the
//! existing `WorkerStats` counters. Quantiles are derived at *read* time
//! from the bucket counts; an empty histogram reports `NaN`, which the
//! JSON layer renders as `null` ([`crate::util::json`]) and the
//! Prometheus exposition as the literal `NaN` both formats define.

use crate::service::ServiceStats;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};

/// Number of log₂ buckets: bucket `k` holds samples in `[2^k, 2^{k+1})`
/// nanoseconds, so 64 buckets cover the full `u64` range (584 years).
pub const BUCKETS: usize = 64;

/// A lock-free latency histogram over log₂-spaced nanosecond buckets.
///
/// Bucket `k` counts samples whose value in nanoseconds lies in
/// `[2^k, 2^{k+1})` (zero clamps to bucket 0), giving exact counts, an
/// exact sum, and quantiles with at most 2× relative error — the right
/// trade for latencies spanning microseconds to seconds.
#[derive(Debug)]
pub struct Histogram {
    counts: [AtomicU64; BUCKETS],
    sum_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self { counts: std::array::from_fn(|_| AtomicU64::new(0)), sum_ns: AtomicU64::new(0) }
    }

    /// The bucket index for a sample of `ns` nanoseconds: the position of
    /// its highest set bit (`ns` in `[2^k, 2^{k+1})` → bucket `k`; zero
    /// clamps to bucket 0).
    pub fn bucket_index(ns: u64) -> usize {
        (63 - ns.max(1).leading_zeros()) as usize
    }

    /// The largest nanosecond value bucket `index` holds
    /// (`2^{index+1} - 1`, saturating at `u64::MAX` for the last bucket)
    /// — what [`Histogram::quantile`] reports for samples in it.
    pub fn bucket_bound(index: usize) -> u64 {
        if index >= BUCKETS - 1 {
            u64::MAX
        } else {
            (1u64 << (index + 1)) - 1
        }
    }

    /// Record one sample of `ns` nanoseconds.
    pub fn record_ns(&self, ns: u64) {
        self.counts[Self::bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Record one sample from a [`std::time::Duration`].
    pub fn record(&self, d: std::time::Duration) {
        self.record_ns(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// Sum of all recorded samples, nanoseconds.
    pub fn sum_ns(&self) -> u64 {
        self.sum_ns.load(Ordering::Relaxed)
    }

    /// Mean sample, nanoseconds (`NaN` when empty).
    pub fn mean_ns(&self) -> f64 {
        let count = self.count();
        if count == 0 {
            f64::NAN
        } else {
            self.sum_ns() as f64 / count as f64
        }
    }

    /// The `q`-quantile (`0.0 ..= 1.0`) in nanoseconds: the upper bound
    /// of the bucket holding the sample of rank `ceil(q · count)`.
    /// Returns `NaN` when the histogram is empty — rendered as `null`
    /// by the JSON layer, the property the satellite round-trip test in
    /// [`crate::util::json`] locks in.
    pub fn quantile(&self, q: f64) -> f64 {
        let counts: Vec<u64> = self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return f64::NAN;
        }
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (index, &count) in counts.iter().enumerate() {
            seen += count;
            if seen >= rank {
                return Self::bucket_bound(index) as f64;
            }
        }
        Self::bucket_bound(BUCKETS - 1) as f64
    }

    /// Non-empty buckets as `(upper_bound_ns, cumulative_count)` pairs,
    /// ascending — the exposition's `le` series.
    pub fn cumulative_buckets(&self) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        let mut cumulative = 0u64;
        for (index, count) in self.counts.iter().enumerate() {
            let count = count.load(Ordering::Relaxed);
            if count > 0 {
                cumulative += count;
                out.push((Self::bucket_bound(index), cumulative));
            }
        }
        out
    }
}

/// The service's latency histograms, shared (`Arc`) between the shards
/// that record and the surfaces that read (`stats`/`metrics` verbs,
/// [`prometheus`]). One block per service; per-shard attribution stays on
/// the existing counter breakdown.
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    /// Time jobs spent queued before their flush drained them.
    pub queue_wait: Histogram,
    /// Merged-plan execution wall time, one sample per flush.
    pub exec: Histogram,
}

fn prom_f64(x: f64) -> String {
    if x.is_nan() {
        "NaN".into()
    } else if x.is_infinite() {
        (if x > 0.0 { "+Inf" } else { "-Inf" }).into()
    } else {
        format!("{x}")
    }
}

fn counter(out: &mut String, name: &str, help: &str, value: u64) {
    let _ = writeln!(out, "# HELP {name} {help}\n# TYPE {name} counter\n{name} {value}");
}

fn gauge(out: &mut String, name: &str, help: &str, value: f64) {
    let _ = writeln!(out, "# HELP {name} {help}\n# TYPE {name} gauge\n{name} {}", prom_f64(value));
}

fn histogram(out: &mut String, name: &str, help: &str, h: &Histogram) {
    let _ = writeln!(out, "# HELP {name} {help}\n# TYPE {name} histogram");
    for (bound_ns, cumulative) in h.cumulative_buckets() {
        let le = prom_f64(bound_ns as f64 / 1e9);
        let _ = writeln!(out, "{name}_bucket{{le=\"{le}\"}} {cumulative}");
    }
    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", h.count());
    let _ = writeln!(out, "{name}_sum {}", prom_f64(h.sum_ns() as f64 / 1e9));
    let _ = writeln!(out, "{name}_count {}", h.count());
    // Derived quantiles under distinct metric names (a histogram and a
    // summary may not share one family in the exposition format).
    for (suffix, q) in [("p50", 0.5), ("p99", 0.99)] {
        let quantile = h.quantile(q) / 1e9;
        let derived = format!("{name}_{suffix}");
        gauge(out, &derived, "Derived quantile of the histogram above.", quantile);
    }
}

/// Render the service's operational state as Prometheus text exposition
/// (version 0.0.4): `bsvd_`-prefixed counters and gauges from
/// [`ServiceStats`], per-shard series labeled `{shard="i"}` whose sums
/// equal the aggregates (the reconciliation invariant the service tests
/// lock in), cache counters labeled by store, and the latency histograms
/// with derived `_p50`/`_p99` gauges.
pub fn prometheus(stats: &ServiceStats, metrics: &ServiceMetrics) -> String {
    let mut out = String::new();
    counter(&mut out, "bsvd_jobs_submitted_total", "Jobs admitted.", stats.jobs_submitted);
    let rejected = stats.jobs_rejected;
    counter(&mut out, "bsvd_jobs_rejected_total", "Jobs rejected at admission.", rejected);
    counter(&mut out, "bsvd_jobs_completed_total", "Jobs completed.", stats.jobs_completed);
    let failed = stats.jobs_failed;
    counter(&mut out, "bsvd_jobs_failed_total", "Jobs failed (backend or deadline).", failed);
    counter(&mut out, "bsvd_batches_total", "Merged-plan flushes executed.", stats.batches);
    counter(&mut out, "bsvd_launches_total", "Shared launches executed.", stats.launches);
    counter(&mut out, "bsvd_tasks_total", "Cycle-tasks executed.", stats.tasks);
    let depth = stats.queue_depth as f64;
    gauge(&mut out, "bsvd_queue_depth", "Jobs admitted, not yet flushed.", depth);
    let backlog = stats.backlog_seconds;
    gauge(&mut out, "bsvd_backlog_seconds", "Modeled seconds of queued work.", backlog);
    gauge(&mut out, "bsvd_occupancy", "Tasks per offered capacity slot.", stats.occupancy);
    gauge(&mut out, "bsvd_avg_batch_jobs", "Mean jobs per flush.", stats.avg_batch_jobs);
    gauge(&mut out, "bsvd_busy_seconds", "Wall time executing merged plans.", stats.busy_seconds);
    gauge(&mut out, "bsvd_uptime_seconds", "Service uptime.", stats.uptime.as_secs_f64());
    gauge(
        &mut out,
        "bsvd_throughput_jobs_per_second",
        "Completed jobs per second of uptime.",
        stats.throughput_jobs_per_s,
    );

    let cache = &stats.cache;
    let _ = writeln!(
        out,
        "# HELP bsvd_cache_hits_total Plan-cache hits by store.\n\
         # TYPE bsvd_cache_hits_total counter"
    );
    for (store, hits) in
        [("plan", cache.plan_hits), ("merge", cache.merge_hits), ("tune", cache.tune_hits)]
    {
        let _ = writeln!(out, "bsvd_cache_hits_total{{store=\"{store}\"}} {hits}");
    }
    let _ = writeln!(
        out,
        "# HELP bsvd_cache_misses_total Plan-cache misses by store.\n\
         # TYPE bsvd_cache_misses_total counter"
    );
    for (store, misses) in
        [("plan", cache.plan_misses), ("merge", cache.merge_misses), ("tune", cache.tune_misses)]
    {
        let _ = writeln!(out, "bsvd_cache_misses_total{{store=\"{store}\"}} {misses}");
    }

    let _ = writeln!(
        out,
        "# HELP bsvd_shard_jobs_completed_total Jobs completed per shard.\n\
         # TYPE bsvd_shard_jobs_completed_total counter"
    );
    for shard in &stats.shards {
        let _ = writeln!(
            out,
            "bsvd_shard_jobs_completed_total{{shard=\"{}\"}} {}",
            shard.shard, shard.jobs_completed
        );
    }
    let _ = writeln!(
        out,
        "# HELP bsvd_shard_busy_fraction Fraction of uptime each shard spent executing.\n\
         # TYPE bsvd_shard_busy_fraction gauge"
    );
    for shard in &stats.shards {
        let _ = writeln!(
            out,
            "bsvd_shard_busy_fraction{{shard=\"{}\"}} {}",
            shard.shard,
            prom_f64(shard.busy_fraction)
        );
    }
    let _ = writeln!(
        out,
        "# HELP bsvd_shard_queue_depth Jobs queued per shard.\n\
         # TYPE bsvd_shard_queue_depth gauge"
    );
    for shard in &stats.shards {
        let _ = writeln!(
            out,
            "bsvd_shard_queue_depth{{shard=\"{}\"}} {}",
            shard.shard, shard.queue_depth
        );
    }

    histogram(
        &mut out,
        "bsvd_queue_wait_seconds",
        "Time jobs spent queued before their flush.",
        &metrics.queue_wait,
    );
    histogram(
        &mut out,
        "bsvd_exec_seconds",
        "Merged-plan execution wall time per flush.",
        &metrics.exec,
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::{CacheStats, ShardStats};
    use std::time::Duration;

    #[test]
    fn bucket_index_is_the_floor_log2() {
        assert_eq!(Histogram::bucket_index(0), 0, "zero clamps into bucket 0");
        assert_eq!(Histogram::bucket_index(1), 0);
        assert_eq!(Histogram::bucket_index(2), 1);
        assert_eq!(Histogram::bucket_index(3), 1);
        assert_eq!(Histogram::bucket_index(4), 2);
        assert_eq!(Histogram::bucket_index(1000), 9, "1000 ∈ [512, 1024)");
        assert_eq!(Histogram::bucket_index(1023), 9);
        assert_eq!(Histogram::bucket_index(1024), 10);
        assert_eq!(Histogram::bucket_index(u64::MAX), 63);
    }

    #[test]
    fn bucket_bounds_are_inclusive_maxima() {
        assert_eq!(Histogram::bucket_bound(0), 1);
        assert_eq!(Histogram::bucket_bound(9), 1023);
        assert_eq!(Histogram::bucket_bound(63), u64::MAX);
        for ns in [1u64, 7, 1000, 123_456_789] {
            let index = Histogram::bucket_index(ns);
            assert!(ns <= Histogram::bucket_bound(index), "{ns}");
            if index > 0 {
                assert!(ns > Histogram::bucket_bound(index - 1), "{ns}");
            }
        }
    }

    #[test]
    fn quantiles_report_exact_bucket_bounds() {
        let h = Histogram::new();
        assert!(h.quantile(0.5).is_nan(), "empty histogram has no quantiles");
        assert!(h.mean_ns().is_nan());

        // 10 samples in bucket 6 ([64, 128)) and 10 in bucket 9
        // ([512, 1024)): the median lands on the last sample of the lower
        // bucket, p99 on the last of the upper.
        for _ in 0..10 {
            h.record_ns(100);
        }
        for _ in 0..10 {
            h.record_ns(1000);
        }
        assert_eq!(h.count(), 20);
        assert_eq!(h.sum_ns(), 11_000);
        assert_eq!(h.mean_ns(), 550.0);
        assert_eq!(h.quantile(0.5), 127.0);
        assert_eq!(h.quantile(0.99), 1023.0);
        assert_eq!(h.quantile(0.0), 127.0, "rank clamps to the first sample");
        assert_eq!(h.quantile(1.0), 1023.0);
        assert_eq!(h.cumulative_buckets(), vec![(127, 10), (1023, 20)]);
    }

    #[test]
    fn durations_record_in_nanoseconds() {
        let h = Histogram::new();
        h.record(Duration::from_micros(1)); // 1000 ns -> bucket 9
        assert_eq!(h.quantile(0.5), 1023.0);
        assert_eq!(h.sum_ns(), 1000);
    }

    fn stats_fixture() -> ServiceStats {
        let shard = |index: usize, completed: u64| ShardStats {
            shard: index,
            queue_depth: index,
            backlog_seconds: 0.0,
            jobs_completed: completed,
            jobs_failed: 0,
            batches: completed,
            launches: completed * 3,
            tasks: completed * 7,
            occupancy: 0.5,
            busy_seconds: 0.25,
            busy_fraction: 0.25,
            cache_hits: 1,
            cache_misses: 1,
        };
        ServiceStats {
            queue_depth: 1,
            backlog_seconds: 0.0,
            jobs_submitted: 10,
            jobs_rejected: 2,
            jobs_completed: 7,
            jobs_failed: 1,
            batches: 7,
            launches: 21,
            tasks: 49,
            occupancy: 0.5,
            avg_batch_jobs: 1.0,
            cache: CacheStats { plan_hits: 5, plan_misses: 2, ..CacheStats::default() },
            busy_seconds: 0.5,
            uptime: Duration::from_secs(2),
            throughput_jobs_per_s: 3.5,
            shards: vec![shard(0, 3), shard(1, 4)],
        }
    }

    #[test]
    fn prometheus_exposition_reconciles_and_parses_line_by_line() {
        let metrics = ServiceMetrics::default();
        metrics.queue_wait.record_ns(100);
        metrics.exec.record_ns(1000);
        let text = prometheus(&stats_fixture(), &metrics);
        assert!(text.contains("bsvd_jobs_completed_total 7"), "{text}");
        assert!(text.contains("bsvd_cache_hits_total{store=\"plan\"} 5"), "{text}");
        assert!(text.contains("bsvd_shard_jobs_completed_total{shard=\"0\"} 3"), "{text}");
        assert!(text.contains("bsvd_shard_jobs_completed_total{shard=\"1\"} 4"), "{text}");
        assert!(text.contains("bsvd_queue_wait_seconds_count 1"), "{text}");
        assert!(text.contains("bsvd_exec_seconds_bucket{le=\"+Inf\"} 1"), "{text}");
        // Per-shard series sum back to the aggregate.
        let series: Vec<u64> = text
            .lines()
            .filter(|l| l.starts_with("bsvd_shard_jobs_completed_total{"))
            .map(|l| l.rsplit(' ').next().unwrap().parse().unwrap())
            .collect();
        assert_eq!(series.iter().sum::<u64>(), 7);
        // Every line is a comment or `name{labels}? value` with a numeric
        // value Prometheus accepts (including NaN for empty quantiles).
        for line in text.lines() {
            if line.starts_with('#') || line.is_empty() {
                continue;
            }
            let (name, value) = line.rsplit_once(' ').expect(line);
            assert!(!name.is_empty());
            assert!(
                value.parse::<f64>().is_ok() || value == "NaN" || value == "+Inf",
                "unparseable sample {line:?}"
            );
        }
    }

    #[test]
    fn empty_quantiles_render_null_through_the_json_layer() {
        // The contract the `stats` verb relies on: an idle service's p99
        // is NaN, which the JSON writer must encode as null, and null
        // parses back as Json::Null (satellite: non-finite guard).
        use crate::util::json::Json;
        let h = Histogram::new();
        let rendered = Json::obj().set("p99_us", h.quantile(0.99)).render();
        assert_eq!(rendered, "{\"p99_us\":null}");
        let parsed = Json::parse(&rendered).unwrap();
        assert_eq!(parsed.get("p99_us"), Some(&Json::Null));
    }
}
