//! Observability: structured tracing, unified metrics, and measured
//! kernel profiles.
//!
//! Three cooperating, dependency-free pieces (see `docs/observability.md`
//! for the operator-facing guide):
//!
//! - [`trace`] — per-job trace ids minted in
//!   [`crate::client::ReductionRequest`] (or accepted from the caller),
//!   propagated over the wire and through queue admission → shard
//!   routing → batcher flush → per-launch backend execution, recorded as
//!   timestamped span events into a bounded ring-buffer sink with
//!   JSON-lines and Chrome trace-event export. Enabled via
//!   `BSVD_TRACE=<path>` or `banded-svd serve --trace`; off by default
//!   with zero behavior change (one relaxed atomic load per hook).
//! - [`metrics`] — counters, gauges, and log-bucketed latency histograms
//!   (p50/p99 derivation) that the existing ad-hoc surfaces
//!   ([`crate::service::ServiceStats`], per-shard breakdowns, plan-cache
//!   hit rates) are rendered onto, exposed through the `metrics` wire
//!   verb and a Prometheus-style text exposition.
//! - [`calibrate`] — backends time each launch during real execution and
//!   fold the samples into a [`calibrate::MeasuredProfile`] (per-kernel
//!   ns/task by stage, element size, packed-vs-inplace) that
//!   [`crate::simulator::simulate_plan_calibrated`] and
//!   [`crate::simulator::autotune_for_calibrated`] ingest in place of
//!   the reasoned model constants. Surfaced as `banded-svd profile
//!   --measure` and ingested service-side via `BSVD_PROFILE=<path>`.

pub mod calibrate;
pub mod metrics;
pub mod trace;

pub use calibrate::{MeasuredProfile, ProfileEntry};
pub use metrics::{Histogram, ServiceMetrics};
pub use trace::{TraceEvent, TraceId};

/// True when any backend-side observation hook is live (tracing or
/// calibration): the launch loops consult this once per run and skip all
/// timing work when it is false.
#[inline]
pub fn observing() -> bool {
    trace::enabled() || calibrate::active()
}
