//! Structured per-job tracing for the serving stack.
//!
//! A [`TraceId`] is minted client-side in a
//! [`crate::client::ReductionRequest`] (or accepted from the caller),
//! rides the wire as an optional proto-compatible field, and every layer
//! the job passes through — queue admission, shard routing, batcher
//! flush, per-launch backend execution, response — records a timestamped
//! [`TraceEvent`] under it. The span vocabulary is fixed: `submit`,
//! `admit`, `queue_wait`, `flush`, `merge`, `launch[i]`, `respond` (plus
//! `reject` on the admission error path).
//!
//! Events land in a bounded in-process ring buffer
//! ([`snapshot`] reads it back, for tests and exporters) and, when a
//! file sink is attached ([`enable_file`] / `BSVD_TRACE=<path>` /
//! `banded-svd serve --trace`), are appended as JSON lines as they
//! happen. [`jsonl`] and [`chrome_trace`] render an event slice for
//! offline tooling — the Chrome trace-event form loads directly into
//! Perfetto / `chrome://tracing`.
//!
//! Tracing is **off by default**: every hook starts with one relaxed
//! atomic load and does nothing else, so the disabled path costs nothing
//! and changes no behavior (the client/backend equivalence suites run
//! with it off and on — results are bitwise identical either way).

use crate::util::json::Json;
use std::cell::RefCell;
use std::collections::VecDeque;
use std::fs::{File, OpenOptions};
use std::io::Write as _;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Ring-buffer capacity of the in-process sink; the oldest events are
/// dropped first once a trace run exceeds it.
pub const RING_CAPACITY: usize = 65_536;

/// A per-job trace identifier: 64 bits, rendered as 16 lowercase hex
/// characters on the wire and in every export.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TraceId(pub u64);

impl TraceId {
    /// Mint a fresh id: a process-unique seed (time × pid) mixed with a
    /// monotone counter through SplitMix64, so ids from concurrent
    /// clients collide with negligible probability.
    pub fn mint() -> Self {
        static NEXT: AtomicU64 = AtomicU64::new(0);
        static SEED: OnceLock<u64> = OnceLock::new();
        let seed = *SEED.get_or_init(|| {
            let t = std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_nanos() as u64)
                .unwrap_or(0);
            t ^ ((std::process::id() as u64) << 32)
        });
        let n = NEXT.fetch_add(1, Ordering::Relaxed);
        Self(splitmix64(seed.wrapping_add(n.wrapping_mul(0x9E37_79B9_7F4A_7C15))))
    }

    /// Wire form: exactly 16 lowercase hex characters.
    pub fn to_hex(self) -> String {
        format!("{:016x}", self.0)
    }

    /// Parse the wire form; `None` unless the string is exactly 16 hex
    /// characters (absent-or-valid: callers treat `None` as malformed,
    /// never as a silent default).
    pub fn parse_hex(s: &str) -> Option<Self> {
        if s.len() != 16 || !s.bytes().all(|b| b.is_ascii_hexdigit()) {
            return None;
        }
        u64::from_str_radix(s, 16).ok().map(Self)
    }
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// One timestamped span event in a job's lifecycle.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// The job's trace id — constant across every event of one job,
    /// client and server side.
    pub trace: TraceId,
    /// Server-assigned job id (`0` client-side, before admission).
    pub job: u64,
    /// Span name: `submit` | `admit` | `queue_wait` | `flush` | `merge`
    /// | `launch[i]` | `respond` | `reject`.
    pub span: String,
    /// Which process half recorded it: `"client"` or `"server"`.
    pub side: &'static str,
    /// Batcher shard that handled the job, where known.
    pub shard: Option<usize>,
    /// Microseconds since the process trace epoch (first event).
    pub ts_us: u64,
    /// Span duration in microseconds (0 for instantaneous marks).
    pub dur_us: u64,
    /// Free-form context (`"n=96 bw=8"`, `"tasks=12"`, …).
    pub detail: String,
}

impl TraceEvent {
    /// Render one event as a JSON object (the JSON-lines record shape).
    pub fn to_json(&self) -> Json {
        let mut obj = Json::obj()
            .set("trace", self.trace.to_hex())
            .set("job", self.job as i64)
            .set("span", self.span.clone())
            .set("side", self.side)
            .set("ts_us", self.ts_us as i64)
            .set("dur_us", self.dur_us as i64);
        if let Some(s) = self.shard {
            obj = obj.set("shard", s);
        }
        if !self.detail.is_empty() {
            obj = obj.set("detail", self.detail.clone());
        }
        obj
    }
}

struct Sink {
    ring: VecDeque<TraceEvent>,
    file: Option<File>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static SINK: Mutex<Option<Sink>> = Mutex::new(None);

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// True when tracing is on. The off path is one relaxed atomic load —
/// every recording hook checks this first and does nothing else.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn on in-memory capture (ring buffer only, no file). Used by tests
/// and embedded consumers; additive — an attached file sink stays.
pub fn enable_capture() {
    let mut sink = SINK.lock().unwrap();
    if sink.is_none() {
        *sink = Some(Sink { ring: VecDeque::new(), file: None });
    }
    ENABLED.store(true, Ordering::Relaxed);
}

/// Turn on tracing with a JSON-lines file sink appended at `path` (the
/// ring buffer records too). One line per event, written as it happens.
pub fn enable_file(path: &str) -> std::io::Result<()> {
    let file = OpenOptions::new().create(true).append(true).open(path)?;
    let mut sink = SINK.lock().unwrap();
    match sink.as_mut() {
        Some(s) => s.file = Some(file),
        None => *sink = Some(Sink { ring: VecDeque::new(), file: Some(file) }),
    }
    ENABLED.store(true, Ordering::Relaxed);
    Ok(())
}

/// Resolve `BSVD_TRACE` once per process: when set to a non-empty path,
/// tracing comes on with that file sink. Unset (the default) leaves
/// tracing fully off. Errors opening the path are reported to stderr and
/// leave tracing off rather than failing the caller.
pub fn init_from_env() {
    static ONCE: OnceLock<()> = OnceLock::new();
    ONCE.get_or_init(|| {
        if let Ok(path) = std::env::var("BSVD_TRACE") {
            if !path.is_empty() {
                if let Err(e) = enable_file(&path) {
                    eprintln!("warning: BSVD_TRACE={path}: {e}; tracing stays off");
                }
            }
        }
    });
}

/// Record one span event. No-op (one atomic load) when tracing is off.
pub fn event(
    trace: TraceId,
    job: u64,
    span: impl Into<String>,
    side: &'static str,
    shard: Option<usize>,
    dur: Duration,
    detail: impl Into<String>,
) {
    if !enabled() {
        return;
    }
    let ev = TraceEvent {
        trace,
        job,
        span: span.into(),
        side,
        shard,
        ts_us: epoch().elapsed().as_micros() as u64,
        dur_us: dur.as_micros() as u64,
        detail: detail.into(),
    };
    record(ev);
}

fn record(ev: TraceEvent) {
    let mut guard = SINK.lock().unwrap();
    let sink = guard.get_or_insert_with(|| Sink { ring: VecDeque::new(), file: None });
    if let Some(f) = sink.file.as_mut() {
        let _ = writeln!(f, "{}", ev.to_json().render());
    }
    if sink.ring.len() >= RING_CAPACITY {
        sink.ring.pop_front();
    }
    sink.ring.push_back(ev);
}

/// Copy the ring buffer out (oldest first). Tests filter by their own
/// trace ids, so concurrent traced runs in one process don't interfere.
pub fn snapshot() -> Vec<TraceEvent> {
    let guard = SINK.lock().unwrap();
    guard.as_ref().map(|s| s.ring.iter().cloned().collect()).unwrap_or_default()
}

// --- launch scope ---------------------------------------------------------
//
// Backends execute *merged* plans whose launches carry tasks from several
// jobs at once, and the `Backend` trait knows nothing about jobs. The
// batcher therefore pins the jobs of the in-flight batch to its worker
// thread before calling `execute`; the launch loop (which runs on that
// same thread) fans each per-launch timing out to every pinned job.

thread_local! {
    static LAUNCH_SCOPE: RefCell<Vec<(TraceId, u64, Option<usize>)>> =
        const { RefCell::new(Vec::new()) };
}

/// RAII guard for the thread's launch scope; clears it on drop.
pub struct LaunchScope(());

/// Pin `(trace, job, shard)` triples to this thread for the duration of
/// a backend execution: per-launch events recorded by the launch loop
/// ([`record_launch`]) are attributed to every pinned job. An empty
/// slice pins nothing (and `record_launch` stays a no-op).
pub fn launch_scope(jobs: &[(TraceId, u64, Option<usize>)]) -> LaunchScope {
    LAUNCH_SCOPE.with(|s| {
        let mut v = s.borrow_mut();
        v.clear();
        v.extend_from_slice(jobs);
    });
    LaunchScope(())
}

impl Drop for LaunchScope {
    fn drop(&mut self) {
        LAUNCH_SCOPE.with(|s| s.borrow_mut().clear());
    }
}

/// Record one executed launch (`launch[i]`, `tasks` tasks, `dur` wall)
/// against every job pinned by [`launch_scope`] on this thread.
pub fn record_launch(li: usize, tasks: usize, dur: Duration) {
    if !enabled() {
        return;
    }
    LAUNCH_SCOPE.with(|s| {
        for &(trace, job, shard) in s.borrow().iter() {
            let detail = format!("tasks={tasks}");
            event(trace, job, format!("launch[{li}]"), "server", shard, dur, detail);
        }
    });
}

// --- exporters ------------------------------------------------------------

/// Render events as JSON lines (one object per line) — the same shape
/// the live file sink writes.
pub fn jsonl(events: &[TraceEvent]) -> String {
    let mut out = String::new();
    for ev in events {
        out.push_str(&ev.to_json().render());
        out.push('\n');
    }
    out
}

/// Render events in the Chrome trace-event format (complete `"X"`
/// events), loadable in Perfetto / `chrome://tracing`. Each trace id
/// becomes one row (`tid`), so a job's span chain reads left to right.
pub fn chrome_trace(events: &[TraceEvent]) -> String {
    let rows: Vec<Json> = events
        .iter()
        .map(|ev| {
            let mut args = Json::obj().set("trace", ev.trace.to_hex()).set("side", ev.side);
            if let Some(s) = ev.shard {
                args = args.set("shard", s);
            }
            if !ev.detail.is_empty() {
                args = args.set("detail", ev.detail.clone());
            }
            Json::obj()
                .set("name", ev.span.clone())
                .set("cat", "bsvd")
                .set("ph", "X")
                .set("ts", ev.ts_us as i64)
                .set("dur", ev.dur_us.max(1) as i64)
                .set("pid", 1)
                .set("tid", (ev.trace.0 & 0xFFFF_FFFF) as i64)
                .set("args", args)
        })
        .collect();
    Json::obj().set("traceEvents", Json::Arr(rows)).render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_ids_roundtrip_hex_and_reject_malformed() {
        let id = TraceId(0x0123_4567_89ab_cdef);
        assert_eq!(id.to_hex(), "0123456789abcdef");
        assert_eq!(TraceId::parse_hex(&id.to_hex()), Some(id));
        assert_eq!(TraceId::parse_hex(&TraceId(0).to_hex()), Some(TraceId(0)));
        for bad in ["", "123", "0123456789abcde", "0123456789abcdefg", "0123456789abcdxy"] {
            assert_eq!(TraceId::parse_hex(bad), None, "{bad:?}");
        }
    }

    #[test]
    fn minted_ids_are_distinct() {
        let a = TraceId::mint();
        let b = TraceId::mint();
        assert_ne!(a, b);
    }

    #[test]
    fn ring_records_and_snapshots_under_capture() {
        enable_capture();
        let id = TraceId::mint();
        event(id, 7, "submit", "client", None, Duration::ZERO, "n=8 bw=2");
        event(id, 7, "respond", "client", Some(1), Duration::from_micros(5), "");
        let mine: Vec<TraceEvent> =
            snapshot().into_iter().filter(|e| e.trace == id).collect();
        assert_eq!(mine.len(), 2);
        assert_eq!(mine[0].span, "submit");
        assert_eq!(mine[0].job, 7);
        assert_eq!(mine[1].span, "respond");
        assert_eq!(mine[1].shard, Some(1));
        assert!(mine[1].ts_us >= mine[0].ts_us);
    }

    #[test]
    fn launch_scope_fans_out_and_clears() {
        enable_capture();
        let (a, b) = (TraceId::mint(), TraceId::mint());
        {
            let _guard = launch_scope(&[(a, 1, Some(0)), (b, 2, Some(0))]);
            record_launch(3, 12, Duration::from_micros(9));
        }
        // Scope dropped: further launches attribute to nobody.
        record_launch(4, 5, Duration::ZERO);
        let events = snapshot();
        let of = |t: TraceId| -> Vec<String> {
            events.iter().filter(|e| e.trace == t).map(|e| e.span.clone()).collect()
        };
        assert_eq!(of(a), vec!["launch[3]"]);
        assert_eq!(of(b), vec!["launch[3]"]);
        let launch = events.iter().find(|e| e.trace == a).unwrap();
        assert_eq!(launch.detail, "tasks=12");
        assert_eq!(launch.side, "server");
    }

    #[test]
    fn exports_are_wellformed_json() {
        let id = TraceId(0xfeed);
        let ev = TraceEvent {
            trace: id,
            job: 3,
            span: "flush".into(),
            side: "server",
            shard: Some(0),
            ts_us: 10,
            dur_us: 2,
            detail: "batch_jobs=2".into(),
        };
        let lines = jsonl(&[ev.clone()]);
        let parsed = Json::parse(lines.trim()).unwrap();
        assert_eq!(parsed.get("trace").unwrap().as_str(), Some("000000000000feed"));
        assert_eq!(parsed.get("span").unwrap().as_str(), Some("flush"));
        assert_eq!(parsed.get("shard").and_then(Json::as_usize), Some(0));

        let chrome = Json::parse(&chrome_trace(&[ev])).unwrap();
        let rows = chrome.get("traceEvents").and_then(Json::as_array).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(rows[0].get("name").unwrap().as_str(), Some("flush"));
    }
}
