//! Measured-profile calibration: fold per-launch timings from real
//! backend execution into a [`MeasuredProfile`] the simulator and
//! autotuner ingest in place of reasoned model constants.
//!
//! The paper's performance claims rest on kernel-level *measurement*
//! (NSight profiles per launch); our cost model is reasoned from
//! first principles. This module closes the loop:
//!
//! 1. `banded-svd profile --measure` runs real reductions with the
//!    collector active ([`begin`]/[`record_sample`]/[`finish`]); the
//!    launch loops time each launch and attribute nanoseconds to the
//!    `(b, d, element size, packed-vs-inplace)` kernel class of every
//!    slot they execute.
//! 2. The folded samples serialize as the `bsvd-profile-v1` JSON schema
//!    ([`MeasuredProfile::to_json`]), which `bench-collect` merges into
//!    snapshots as `measured: true`.
//! 3. `BSVD_PROFILE=<path>` ([`from_env`]) feeds the profile back into
//!    [`crate::simulator::simulate_plan_calibrated`] and
//!    [`crate::simulator::autotune_for_calibrated`], so tuning decisions
//!    follow the hardware actually underneath, not the model's guesses.

use crate::util::json::Json;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};

/// Measured cost of one kernel class: the cycle kernel at bandwidth `b`,
/// tile width `d`, element size `es`, in its packed or in-place variant.
#[derive(Clone, Debug, PartialEq)]
pub struct ProfileEntry {
    /// Bandwidth of the stage (`Stage::b`).
    pub b: usize,
    /// Tile width of the stage (`Stage::d`).
    pub d: usize,
    /// Element size in bytes (2/4/8 — the paper's precision axis).
    pub es: usize,
    /// Whether the stage ran the packed-tile kernel
    /// ([`crate::bulge::cycle::stage_uses_packed`]).
    pub packed: bool,
    /// Cycle-tasks the sample set covers.
    pub tasks: u64,
    /// Measured nanoseconds per cycle-task, averaged over `tasks`.
    pub ns_per_task: f64,
}

impl ProfileEntry {
    /// Elements one cycle-task touches — the scaling basis when a lookup
    /// falls back to a neighboring kernel class. A task at `(b, d)` sweeps
    /// a `(1 + b + d) × (d + 1)` working window.
    fn tile_elems(b: usize, d: usize) -> f64 {
        ((1 + b + d) * (d + 1)) as f64
    }
}

/// A set of measured kernel costs, the `bsvd-profile-v1` artifact.
///
/// Lookup ([`MeasuredProfile::ns_per_task`]) degrades gracefully: exact
/// `(b, d, es, packed)` match first, then the other packedness of the
/// same shape, then the nearest same-`es` shape scaled by working-window
/// size, then any entry scaled by window *and* element size — so a
/// profile measured on a handful of shapes still calibrates the whole
/// tuning grid.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MeasuredProfile {
    pub entries: Vec<ProfileEntry>,
}

impl MeasuredProfile {
    /// Measured (or nearest-scaled) nanoseconds per cycle-task for a
    /// kernel class. `None` only when the profile is empty.
    pub fn ns_per_task(&self, b: usize, d: usize, es: usize, packed: bool) -> Option<f64> {
        if let Some(e) =
            self.entries.iter().find(|e| (e.b, e.d, e.es, e.packed) == (b, d, es, packed))
        {
            return Some(e.ns_per_task);
        }
        if let Some(e) = self.entries.iter().find(|e| (e.b, e.d, e.es) == (b, d, es)) {
            return Some(e.ns_per_task);
        }
        let want = ProfileEntry::tile_elems(b, d);
        let nearest = |candidates: &mut dyn Iterator<Item = &ProfileEntry>| {
            candidates.min_by(|x, y| {
                let dx = (ProfileEntry::tile_elems(x.b, x.d).ln() - want.ln()).abs();
                let dy = (ProfileEntry::tile_elems(y.b, y.d).ln() - want.ln()).abs();
                dx.partial_cmp(&dy).unwrap_or(std::cmp::Ordering::Equal)
            })
        };
        if let Some(e) = nearest(&mut self.entries.iter().filter(|e| e.es == es)) {
            return Some(e.ns_per_task * want / ProfileEntry::tile_elems(e.b, e.d));
        }
        nearest(&mut self.entries.iter()).map(|e| {
            e.ns_per_task * (want / ProfileEntry::tile_elems(e.b, e.d)) * (es as f64 / e.es as f64)
        })
    }

    /// Stable FNV-1a digest of the entry set — folded into
    /// [`crate::simulator::TuneKey`] so cached tune results keyed under
    /// one profile never serve another.
    pub fn fingerprint(&self) -> u64 {
        let mut hash = 0xcbf29ce484222325u64;
        let mut eat = |bytes: &[u8]| {
            for &byte in bytes {
                hash ^= byte as u64;
                hash = hash.wrapping_mul(0x100000001b3);
            }
        };
        for e in &self.entries {
            eat(&(e.b as u64).to_le_bytes());
            eat(&(e.d as u64).to_le_bytes());
            eat(&(e.es as u64).to_le_bytes());
            eat(&[e.packed as u8]);
            eat(&e.ns_per_task.to_bits().to_le_bytes());
        }
        hash
    }

    /// Serialize as the `bsvd-profile-v1` calibration artifact.
    pub fn to_json(&self) -> Json {
        let entries: Vec<Json> = self
            .entries
            .iter()
            .map(|e| {
                Json::obj()
                    .set("b", e.b)
                    .set("d", e.d)
                    .set("es", e.es)
                    .set("packed", e.packed)
                    .set("tasks", e.tasks as i64)
                    .set("ns_per_task", e.ns_per_task)
            })
            .collect();
        Json::obj()
            .set("schema", "bsvd-profile-v1")
            .set("measured", true)
            .set("entries", Json::Arr(entries))
    }

    /// Parse a `bsvd-profile-v1` value; wrong schema or a malformed entry
    /// is an error (absent-or-valid, same policy as the wire protocol).
    pub fn from_json(v: &Json) -> Result<Self, String> {
        match v.get("schema").and_then(Json::as_str) {
            Some("bsvd-profile-v1") => {}
            other => return Err(format!("unsupported profile schema {other:?}")),
        }
        let items = v
            .get("entries")
            .and_then(Json::as_array)
            .ok_or("profile has no entries array")?;
        let mut entries = Vec::with_capacity(items.len());
        for item in items {
            let field = |k: &str| item.get(k).ok_or_else(|| format!("entry missing {k:?}"));
            entries.push(ProfileEntry {
                b: field("b")?.as_usize().ok_or("bad b")?,
                d: field("d")?.as_usize().ok_or("bad d")?,
                es: field("es")?.as_usize().ok_or("bad es")?,
                packed: field("packed")?.as_bool().ok_or("bad packed")?,
                tasks: field("tasks")?.as_i64().ok_or("bad tasks")? as u64,
                ns_per_task: field("ns_per_task")?.as_f64().ok_or("bad ns_per_task")?,
            });
        }
        Ok(Self { entries })
    }

    /// Load a calibration JSON from disk.
    pub fn load(path: &str) -> Result<Self, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("cannot read {path:?}: {e}"))?;
        Self::from_json(&Json::parse(&text)?)
    }
}

/// The profile named by `BSVD_PROFILE`, loaded once per process. A load
/// error warns on stderr and calibration stays off — same fail-open
/// policy as `BSVD_TRACE`.
pub fn from_env() -> Option<&'static MeasuredProfile> {
    static LOADED: OnceLock<Option<MeasuredProfile>> = OnceLock::new();
    LOADED
        .get_or_init(|| {
            let path = std::env::var("BSVD_PROFILE").ok()?;
            match MeasuredProfile::load(&path) {
                Ok(profile) => Some(profile),
                Err(e) => {
                    eprintln!("BSVD_PROFILE ignored: {e}");
                    None
                }
            }
        })
        .as_ref()
}

static ACTIVE: AtomicBool = AtomicBool::new(false);

/// Accumulated `(tasks, nanoseconds)` per kernel class while collecting.
fn samples() -> &'static Mutex<HashMap<(usize, usize, usize, bool), (u64, f64)>> {
    static SAMPLES: OnceLock<Mutex<HashMap<(usize, usize, usize, bool), (u64, f64)>>> =
        OnceLock::new();
    SAMPLES.get_or_init(|| Mutex::new(HashMap::new()))
}

/// True while a calibration run is collecting — the launch loops consult
/// this (via [`crate::obs::observing`]) before timing anything.
#[inline]
pub fn active() -> bool {
    ACTIVE.load(Ordering::Relaxed)
}

/// Start (or restart) collecting: clears prior samples, arms
/// [`record_sample`].
pub fn begin() {
    samples().lock().unwrap().clear();
    ACTIVE.store(true, Ordering::Relaxed);
}

/// Attribute `ns` nanoseconds over `tasks` cycle-tasks of one kernel
/// class. No-op unless a collection is active.
pub fn record_sample(b: usize, d: usize, es: usize, packed: bool, tasks: u64, ns: f64) {
    if !active() || tasks == 0 {
        return;
    }
    let mut map = samples().lock().unwrap();
    let slot = map.entry((b, d, es, packed)).or_insert((0, 0.0));
    slot.0 += tasks;
    slot.1 += ns;
}

/// Stop collecting and fold the samples into a [`MeasuredProfile`]
/// (entries sorted by `(b, d, es, packed)` for a stable fingerprint).
pub fn finish() -> MeasuredProfile {
    ACTIVE.store(false, Ordering::Relaxed);
    let mut map = samples().lock().unwrap();
    let mut entries: Vec<ProfileEntry> = map
        .drain()
        .map(|((b, d, es, packed), (tasks, ns))| ProfileEntry {
            b,
            d,
            es,
            packed,
            tasks,
            ns_per_task: ns / tasks as f64,
        })
        .collect();
    entries.sort_by_key(|e| (e.b, e.d, e.es, e.packed));
    MeasuredProfile { entries }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(b: usize, d: usize, es: usize, packed: bool, ns: f64) -> ProfileEntry {
        ProfileEntry { b, d, es, packed, tasks: 100, ns_per_task: ns }
    }

    #[test]
    fn lookup_prefers_exact_then_scales_to_neighbors() {
        let p = MeasuredProfile {
            entries: vec![
                entry(32, 16, 8, true, 4000.0),
                entry(32, 16, 8, false, 5000.0),
                entry(32, 16, 4, true, 2000.0),
            ],
        };
        assert_eq!(p.ns_per_task(32, 16, 8, true), Some(4000.0));
        assert_eq!(p.ns_per_task(32, 16, 8, false), Some(5000.0));
        // Missing packedness falls back to the same shape.
        assert_eq!(p.ns_per_task(32, 16, 4, false), Some(2000.0));
        // Missing shape scales the nearest same-es entry by the working
        // window: (b=32, d=32) has (1+64)*33 elems vs (1+48)*17 measured.
        let want = ((1 + 32 + 32) * 33) as f64;
        let have = ((1 + 32 + 16) * 17) as f64;
        assert_eq!(p.ns_per_task(32, 32, 8, true), Some(4000.0 * want / have));
        // Missing es additionally scales by element size.
        let p32 = MeasuredProfile { entries: vec![entry(32, 16, 4, true, 2000.0)] };
        assert_eq!(p32.ns_per_task(32, 16, 8, true), Some(4000.0));
        // Empty profiles answer nothing.
        assert_eq!(MeasuredProfile::default().ns_per_task(32, 16, 8, true), None);
    }

    #[test]
    fn json_round_trip_preserves_entries_and_fingerprint() {
        let p = MeasuredProfile {
            entries: vec![entry(32, 16, 8, true, 4321.5), entry(48, 8, 4, false, 99.25)],
        };
        let rendered = p.to_json().render();
        assert!(rendered.contains("\"schema\":\"bsvd-profile-v1\""), "{rendered}");
        assert!(rendered.contains("\"measured\":true"), "{rendered}");
        let back = MeasuredProfile::from_json(&Json::parse(&rendered).unwrap()).unwrap();
        assert_eq!(back, p);
        assert_eq!(back.fingerprint(), p.fingerprint());
        // Different measurements fingerprint differently.
        let other = MeasuredProfile { entries: vec![entry(32, 16, 8, true, 4321.0)] };
        assert_ne!(other.fingerprint(), p.fingerprint());
        assert_ne!(MeasuredProfile::default().fingerprint(), p.fingerprint());
    }

    #[test]
    fn from_json_rejects_wrong_schema_and_malformed_entries() {
        let wrong = Json::parse("{\"schema\":\"bsvd-bench-v1\",\"entries\":[]}").unwrap();
        assert!(MeasuredProfile::from_json(&wrong).is_err());
        let missing =
            Json::parse("{\"schema\":\"bsvd-profile-v1\",\"entries\":[{\"b\":1}]}").unwrap();
        assert!(MeasuredProfile::from_json(&missing).is_err());
    }

    #[test]
    fn collector_folds_samples_into_averaged_entries() {
        // b=97 is not a bandwidth any other test executes, so parallel
        // test threads recording through live launch loops cannot collide
        // with the class this test asserts on.
        begin();
        assert!(active());
        record_sample(97, 13, 8, true, 10, 10_000.0);
        record_sample(97, 13, 8, true, 30, 70_000.0);
        record_sample(97, 13, 8, true, 0, 1.0); // zero tasks: ignored
        let profile = finish();
        assert!(!active());
        let e = profile
            .entries
            .iter()
            .find(|e| (e.b, e.d, e.es, e.packed) == (97, 13, 8, true))
            .expect("folded entry");
        assert_eq!(e.tasks, 40);
        assert_eq!(e.ns_per_task, 2000.0);
    }
}
