//! Pseudo-random number generation.
//!
//! The offline crate set has no `rand`, so we implement the generators the
//! library needs: [`SplitMix64`] (seed expansion) and [`Xoshiro256`]
//! (xoshiro256** — the general-purpose generator), plus uniform/Gaussian
//! helpers used by matrix generation and the property-testing framework.

/// SplitMix64 — tiny, fast generator used to expand user seeds into the
/// state of larger generators. Passes BigCrush when used standalone.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** by Blackman & Vigna: the workhorse generator.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64, as
    /// recommended by the xoshiro authors).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1): 53 random mantissa bits.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform usize in [0, n). n must be > 0. Uses rejection sampling to
    /// avoid modulo bias (matters for the property-test generators).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn range_inclusive(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Marsaglia polar method.
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.cached_gaussian() {
            return z;
        }
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                // A cache slot would complicate the struct; generating a
                // pair and discarding one keeps the generator stateless
                // beyond its 256-bit core and is still fast.
                return u * f;
            }
        }
    }

    #[inline]
    fn cached_gaussian(&mut self) -> Option<f64> {
        None
    }

    /// Fill a slice with standard normals.
    pub fn fill_gaussian(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.gaussian();
        }
    }

    /// Random boolean with probability p of being true.
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.uniform() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_known_vector() {
        // Reference values for seed 0 (from the public-domain reference
        // implementation by Sebastiano Vigna).
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(sm.next_u64(), 0x6E789E6AA1B965F4);
        assert_eq!(sm.next_u64(), 0x06C45D188009454F);
    }

    #[test]
    fn xoshiro_uniform_in_range() {
        let mut rng = Xoshiro256::seed_from_u64(7);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn xoshiro_mean_and_variance() {
        let mut rng = Xoshiro256::seed_from_u64(123);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            sum += u;
            sumsq += u * u;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 5e-3, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var {var}");
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Xoshiro256::seed_from_u64(99);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let g = rng.gaussian();
            sum += g;
            sumsq += g * g;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 1e-2, "mean {mean}");
        assert!((var - 1.0).abs() < 2e-2, "var {var}");
    }

    #[test]
    fn below_is_unbiased_and_in_range() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            let k = rng.below(7);
            assert!(k < 7);
            counts[k] += 1;
        }
        for &c in &counts {
            // expectation 10_000 each; loose 10% tolerance
            assert!((c as i64 - 10_000).unsigned_abs() < 1_000, "{counts:?}");
        }
    }

    #[test]
    fn range_inclusive_bounds() {
        let mut rng = Xoshiro256::seed_from_u64(11);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..1000 {
            let v = rng.range_inclusive(3, 6);
            assert!((3..=6).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 6;
        }
        assert!(seen_lo && seen_hi);
    }
}
