//! Machine-readable benchmark snapshots and regression gating.
//!
//! The perf benches (`perf_hotpath`, `batch_scaling`,
//! `service_throughput`) each emit a JSON result file under
//! `target/experiments/`. This module turns those into one *snapshot*
//! (`BENCH_*.json` at the repo root, committed per PR) and compares two
//! snapshots with a direction-aware tolerance — the `banded-svd
//! bench-collect` / `bench-gate` subcommands CI runs after the bench
//! sweep.
//!
//! A snapshot is honest about provenance: `measured: false` marks a seed
//! committed from an environment that could not run the benches (numbers
//! are placeholders), and the gate *skips* unmeasured baselines instead
//! of failing against fiction. The first CI run on real hardware
//! replaces the seed with `measured: true` numbers via the uploaded
//! artifact.

use crate::util::json::Json;
use std::path::Path;

/// Snapshot schema tag — bumped if the metric encoding changes shape.
pub const SCHEMA: &str = "bsvd-bench-v1";

/// Which way a metric improves.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Direction {
    /// Throughputs: problems/s, jobs/s.
    HigherIsBetter,
    /// Latencies: ns/task.
    LowerIsBetter,
}

impl Direction {
    pub fn name(self) -> &'static str {
        match self {
            Direction::HigherIsBetter => "higher",
            Direction::LowerIsBetter => "lower",
        }
    }

    fn parse(s: &str) -> Option<Self> {
        match s {
            "higher" => Some(Direction::HigherIsBetter),
            "lower" => Some(Direction::LowerIsBetter),
            _ => None,
        }
    }
}

/// One benchmark observation.
#[derive(Clone, Debug, PartialEq)]
pub struct Metric {
    pub name: String,
    pub value: f64,
    pub unit: &'static str,
    pub direction: Direction,
}

impl Metric {
    pub fn new(name: impl Into<String>, value: f64, unit: &'static str, dir: Direction) -> Self {
        Self { name: name.into(), value, unit, direction: dir }
    }
}

/// Render a snapshot value ready to write to a `BENCH_*.json` file.
pub fn snapshot(label: &str, measured: bool, metrics: &[Metric]) -> Json {
    let mut obj = Json::obj();
    for m in metrics {
        obj = obj.set(
            m.name.clone(),
            Json::obj()
                .set("value", m.value)
                .set("unit", m.unit)
                .set("direction", m.direction.name()),
        );
    }
    Json::obj()
        .set("schema", SCHEMA)
        .set("label", label)
        .set("measured", measured)
        .set("metrics", obj)
}

/// Parse a snapshot back into metrics; `None` for wrong-schema values.
pub fn parse_snapshot(j: &Json) -> Option<(bool, Vec<Metric>)> {
    if j.get("schema")?.as_str()? != SCHEMA {
        return None;
    }
    let measured = j.get("measured")?.as_bool()?;
    let mut out = Vec::new();
    if let Json::Obj(pairs) = j.get("metrics")? {
        for (name, m) in pairs {
            let value = m.get("value")?.as_f64()?;
            let direction = Direction::parse(m.get("direction")?.as_str()?)?;
            // The unit is display-only; a leaked &'static str per distinct
            // unit string is fine for a CLI-lifetime value.
            let unit: &'static str =
                Box::leak(m.get("unit")?.as_str()?.to_string().into_boxed_str());
            out.push(Metric { name: name.clone(), value, unit, direction });
        }
    }
    Some((measured, out))
}

/// Harvest metrics from the experiment files the perf benches wrote
/// under `dir` (normally `target/experiments/`). Missing files are
/// skipped — the snapshot records whatever the sweep produced.
pub fn collect_experiments(dir: &Path) -> Vec<Metric> {
    let mut out = Vec::new();
    if let Some(j) = read_json(&dir.join("perf_hotpath.json")) {
        if let Some(rows) = j.get("packed_kernels").and_then(Json::as_array) {
            for row in rows {
                let (Some(b), Some(d)) = (
                    row.get("b").and_then(Json::as_usize),
                    row.get("d").and_then(Json::as_usize),
                ) else {
                    continue;
                };
                for key in ["scalar_ns", "simd_ns"] {
                    if let Some(ns) = row.get(key).and_then(Json::as_f64) {
                        out.push(Metric::new(
                            format!("hotpath/cycle_b{b}_d{d}_{key}"),
                            ns,
                            "ns/task",
                            Direction::LowerIsBetter,
                        ));
                    }
                }
            }
        }
    }
    if let Some(j) = read_json(&dir.join("batch_scaling.json")) {
        if let Some(best) = best_of(&j, "results", "problems_per_s") {
            out.push(Metric::new(
                "batch/problems_per_s",
                best,
                "problems/s",
                Direction::HigherIsBetter,
            ));
        }
    }
    if let Some(j) = read_json(&dir.join("service_throughput.json")) {
        if let Some(best) = best_of(&j, "results", "jobs_per_s") {
            out.push(Metric::new("service/jobs_per_s", best, "jobs/s", Direction::HigherIsBetter));
        }
    }
    // A `banded-svd profile --measure` artifact dropped in the same
    // directory folds into the snapshot as one measured ns/task metric
    // per kernel class, so calibration drift gates like any other perf
    // number.
    if let Some(j) = read_json(&dir.join("profile_calibration.json")) {
        if let Ok(profile) = crate::obs::MeasuredProfile::from_json(&j) {
            for e in &profile.entries {
                let variant = if e.packed { "packed" } else { "inplace" };
                out.push(Metric::new(
                    format!("calibrated/cycle_b{}_d{}_es{}_{variant}_ns", e.b, e.d, e.es),
                    e.ns_per_task,
                    "ns/task",
                    Direction::LowerIsBetter,
                ));
            }
        }
    }
    // A load-generator report (`banded-svd loadgen`) dropped in the same
    // directory folds its SLO-facing aggregates into the snapshot: tail
    // latency, achieved throughput, and deadline-miss rate gate like any
    // other perf number. NaN aggregates (zero completions, no deadline
    // classes) render as JSON null and are skipped, not recorded as 0.
    if let Some(j) = read_json(&dir.join("loadgen.json")) {
        let metric = |path: &[&str]| -> Option<f64> {
            let mut node = &j;
            for key in path {
                node = node.get(key)?;
            }
            node.as_f64().filter(|v| v.is_finite())
        };
        if let Some(p99) = metric(&["tally", "latency_ms", "p99"]) {
            out.push(Metric::new("load/p99_ms", p99, "ms", Direction::LowerIsBetter));
        }
        if let Some(rate) = metric(&["throughput", "achieved_jobs_per_s"]) {
            out.push(Metric::new(
                "load/achieved_jobs_per_s",
                rate,
                "jobs/s",
                Direction::HigherIsBetter,
            ));
        }
        if let Some(miss) = metric(&["tally", "deadline", "miss_rate"]) {
            out.push(Metric::new(
                "load/deadline_miss_rate",
                miss,
                "rate",
                Direction::LowerIsBetter,
            ));
        }
    }
    out
}

fn read_json(path: &Path) -> Option<Json> {
    let text = std::fs::read_to_string(path).ok()?;
    Json::parse(&text).ok()
}

/// Max of `field` over the objects in the `rows` array of `j`.
fn best_of(j: &Json, rows: &str, field: &str) -> Option<f64> {
    j.get(rows)?
        .as_array()?
        .iter()
        .filter_map(|r| r.get(field).and_then(Json::as_f64))
        .fold(None, |acc: Option<f64>, v| Some(acc.map_or(v, |a| a.max(v))))
}

/// One metric's baseline-vs-current verdict.
#[derive(Clone, Debug)]
pub struct Delta {
    pub name: String,
    pub baseline: f64,
    pub current: f64,
    /// Fractional change in the *bad* direction (positive = worse);
    /// e.g. `0.12` = 12% slower (or 12% less throughput).
    pub worsened_by: f64,
    pub regressed: bool,
}

/// Outcome of gating `current` against `baseline`.
#[derive(Clone, Debug)]
pub enum GateOutcome {
    /// Baseline was a `measured: false` seed (or wrong schema): nothing
    /// to compare against, gate passes vacuously.
    SkippedUnmeasured,
    /// Per-metric deltas for every metric present in both snapshots.
    Compared(Vec<Delta>),
}

impl GateOutcome {
    /// True when no compared metric regressed beyond tolerance.
    pub fn passed(&self) -> bool {
        match self {
            GateOutcome::SkippedUnmeasured => true,
            GateOutcome::Compared(deltas) => deltas.iter().all(|d| !d.regressed),
        }
    }
}

/// Compare two snapshots. A metric regresses when it moves more than
/// `tolerance` (fraction, e.g. `0.10`) in its bad direction; metrics
/// missing from either side are ignored (benches may gain kernels
/// between PRs). An unmeasured baseline skips the comparison entirely.
pub fn gate(baseline: &Json, current: &Json, tolerance: f64) -> GateOutcome {
    let Some((measured, base)) = parse_snapshot(baseline) else {
        return GateOutcome::SkippedUnmeasured;
    };
    if !measured {
        return GateOutcome::SkippedUnmeasured;
    }
    let Some((_, cur)) = parse_snapshot(current) else {
        return GateOutcome::Compared(Vec::new());
    };
    let mut deltas = Vec::new();
    for b in &base {
        let Some(c) = cur.iter().find(|c| c.name == b.name) else {
            continue;
        };
        if b.value <= 0.0 {
            continue; // degenerate baseline (empty sweep); nothing to gate
        }
        let change = (c.value - b.value) / b.value;
        let worsened_by = match b.direction {
            Direction::HigherIsBetter => -change,
            Direction::LowerIsBetter => change,
        };
        deltas.push(Delta {
            name: b.name.clone(),
            baseline: b.value,
            current: c.value,
            worsened_by,
            regressed: worsened_by > tolerance,
        });
    }
    GateOutcome::Compared(deltas)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metrics() -> Vec<Metric> {
        vec![
            Metric::new(
                "hotpath/cycle_b64_d32_simd_ns",
                120.0,
                "ns/task",
                Direction::LowerIsBetter,
            ),
            Metric::new("batch/problems_per_s", 900.0, "problems/s", Direction::HigherIsBetter),
        ]
    }

    #[test]
    fn snapshot_roundtrips_through_json_text() {
        let j = snapshot("PR7", true, &metrics());
        let back = Json::parse(&j.render()).unwrap();
        let (measured, parsed) = parse_snapshot(&back).unwrap();
        assert!(measured);
        assert_eq!(parsed, metrics());
        assert_eq!(back.get("schema").unwrap().as_str(), Some(SCHEMA));
    }

    #[test]
    fn gate_is_direction_aware() {
        let base = snapshot("base", true, &metrics());
        // Latency up 20% (bad), throughput up 20% (good).
        let cur = snapshot(
            "cur",
            true,
            &[
                Metric::new(
                    "hotpath/cycle_b64_d32_simd_ns",
                    144.0,
                    "ns/task",
                    Direction::LowerIsBetter,
                ),
                Metric::new(
                    "batch/problems_per_s",
                    1080.0,
                    "problems/s",
                    Direction::HigherIsBetter,
                ),
            ],
        );
        let out = gate(&base, &cur, 0.10);
        assert!(!out.passed());
        let GateOutcome::Compared(deltas) = out else { panic!("expected comparison") };
        assert!(deltas[0].regressed && deltas[0].worsened_by > 0.19);
        assert!(!deltas[1].regressed && deltas[1].worsened_by < 0.0);

        // Throughput down 20% regresses too.
        let cur = snapshot(
            "cur",
            true,
            &[Metric::new("batch/problems_per_s", 720.0, "problems/s", Direction::HigherIsBetter)],
        );
        assert!(!gate(&base, &cur, 0.10).passed());

        // Within tolerance passes.
        let cur = snapshot(
            "cur",
            true,
            &[Metric::new("batch/problems_per_s", 860.0, "problems/s", Direction::HigherIsBetter)],
        );
        assert!(gate(&base, &cur, 0.10).passed());
    }

    #[test]
    fn unmeasured_or_alien_baseline_is_skipped() {
        let cur = snapshot("cur", true, &metrics());
        let seed = snapshot("seed", false, &metrics());
        assert!(matches!(gate(&seed, &cur, 0.1), GateOutcome::SkippedUnmeasured));
        assert!(gate(&seed, &cur, 0.1).passed());
        let alien = Json::obj().set("schema", "something-else");
        assert!(matches!(gate(&alien, &cur, 0.1), GateOutcome::SkippedUnmeasured));
    }

    #[test]
    fn missing_and_new_metrics_are_ignored() {
        let base = snapshot("base", true, &metrics());
        let cur = snapshot(
            "cur",
            true,
            &[
                Metric::new("batch/problems_per_s", 900.0, "problems/s", Direction::HigherIsBetter),
                Metric::new("brand/new_metric", 1.0, "x", Direction::LowerIsBetter),
            ],
        );
        let out = gate(&base, &cur, 0.10);
        let GateOutcome::Compared(deltas) = &out else { panic!("expected comparison") };
        assert_eq!(deltas.len(), 1, "only the shared metric is compared");
        assert!(out.passed());
    }

    #[test]
    fn collect_reads_the_experiment_files() {
        let dir = std::env::temp_dir().join(format!("bsvd-benchcmp-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let hotpath = Json::obj().set(
            "packed_kernels",
            Json::Arr(vec![Json::obj()
                .set("b", 64usize)
                .set("d", 32usize)
                .set("scalar_ns", 250.0)
                .set("simd_ns", 120.0)]),
        );
        std::fs::write(dir.join("perf_hotpath.json"), hotpath.render()).unwrap();
        let batch = Json::obj().set(
            "results",
            Json::Arr(vec![
                Json::obj().set("problems_per_s", 400.0),
                Json::obj().set("problems_per_s", 900.0),
            ]),
        );
        std::fs::write(dir.join("batch_scaling.json"), batch.render()).unwrap();

        let got = collect_experiments(&dir);
        std::fs::remove_dir_all(&dir).ok();
        let find = |n: &str| got.iter().find(|m| m.name == n).map(|m| m.value);
        assert_eq!(find("hotpath/cycle_b64_d32_scalar_ns"), Some(250.0));
        assert_eq!(find("hotpath/cycle_b64_d32_simd_ns"), Some(120.0));
        assert_eq!(find("batch/problems_per_s"), Some(900.0), "best row wins");
        // service_throughput.json absent: simply no service metric.
        assert!(find("service/jobs_per_s").is_none());
    }

    #[test]
    fn collect_folds_a_measured_calibration_profile() {
        use crate::obs::calibrate::{MeasuredProfile, ProfileEntry};
        let dir = std::env::temp_dir().join(format!("bsvd-benchcal-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let profile = MeasuredProfile {
            entries: vec![
                ProfileEntry { b: 16, d: 8, es: 8, packed: true, tasks: 40, ns_per_task: 750.0 },
                ProfileEntry { b: 16, d: 8, es: 4, packed: false, tasks: 12, ns_per_task: 310.5 },
            ],
        };
        let path = dir.join("profile_calibration.json");
        std::fs::write(&path, profile.to_json().render()).unwrap();

        let got = collect_experiments(&dir);
        std::fs::remove_dir_all(&dir).ok();
        let find = |n: &str| got.iter().find(|m| m.name == n).map(|m| m.value);
        assert_eq!(find("calibrated/cycle_b16_d8_es8_packed_ns"), Some(750.0));
        assert_eq!(find("calibrated/cycle_b16_d8_es4_inplace_ns"), Some(310.5));
        // Calibration latencies gate in the lower-is-better direction.
        let m = got.iter().find(|m| m.name.starts_with("calibrated/")).unwrap();
        assert_eq!(m.direction, Direction::LowerIsBetter);
        assert_eq!(m.unit, "ns/task");
    }

    #[test]
    fn collect_folds_a_loadgen_report_skipping_null_aggregates() {
        let dir = std::env::temp_dir().join(format!("bsvd-benchload-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let report = Json::obj()
            .set("schema", "bsvd-load-v1")
            .set(
                "tally",
                Json::obj()
                    .set("latency_ms", Json::obj().set("p99", 42.5))
                    .set("deadline", Json::obj().set("miss_rate", f64::NAN)),
            )
            .set("throughput", Json::obj().set("achieved_jobs_per_s", 310.0));
        std::fs::write(dir.join("loadgen.json"), report.render()).unwrap();

        let got = collect_experiments(&dir);
        std::fs::remove_dir_all(&dir).ok();
        let find = |n: &str| got.iter().find(|m| m.name == n).map(|m| m.value);
        assert_eq!(find("load/p99_ms"), Some(42.5));
        assert_eq!(find("load/achieved_jobs_per_s"), Some(310.0));
        // miss_rate was NaN (no deadline classes): rendered null, skipped.
        assert!(find("load/deadline_miss_rate").is_none());
        let p99 = got.iter().find(|m| m.name == "load/p99_ms").unwrap();
        assert_eq!((p99.unit, p99.direction), ("ms", Direction::LowerIsBetter));
    }
}
