//! Property-testing mini-framework (no `proptest` in the offline crate
//! set).
//!
//! Runs a property against many randomly generated cases; on failure it
//! reports the seed and case index so the exact case can be replayed with
//! `BSVD_PROP_SEED=<seed>`. Generators are plain closures over the
//! library's own RNG, which keeps shape constraints (e.g. `1 ≤ tw < bw`)
//! easy to express exactly instead of via rejection.

use crate::util::rng::Xoshiro256;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        let seed = std::env::var("BSVD_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xB5BD_5EED);
        let cases = std::env::var("BSVD_PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(64);
        Self { cases, seed }
    }
}

/// Run `cases` random cases: generate input with `gen`, check with `prop`.
/// `prop` returns `Err(reason)` to fail. Panics with a replayable report.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cfg: &Config,
    mut generator: impl FnMut(&mut Xoshiro256) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        // Derive a per-case seed so any single case can be replayed alone.
        let case_seed = cfg.seed.wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(case as u64));
        let mut rng = Xoshiro256::seed_from_u64(case_seed);
        let input = generator(&mut rng);
        if let Err(reason) = prop(&input) {
            panic!(
                "property {name:?} failed\n  case index : {case}/{}\n  seed       : {} (replay: BSVD_PROP_SEED={})\n  input      : {input:?}\n  reason     : {reason}",
                cfg.cases, cfg.seed, cfg.seed
            );
        }
    }
}

/// Shorthand using the default (env-controlled) config.
pub fn quickcheck<T: std::fmt::Debug>(
    name: &str,
    generator: impl FnMut(&mut Xoshiro256) -> T,
    prop: impl FnMut(&T) -> Result<(), String>,
) {
    check(name, &Config::default(), generator, prop)
}

/// Assert two floating-point slices match to a tolerance; returns a useful
/// message naming the worst element. Shared by tests and properties.
pub fn assert_close(a: &[f64], b: &[f64], rtol: f64, atol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    let mut worst = (0usize, 0.0f64);
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * x.abs().max(y.abs());
        let d = (x - y).abs();
        if d > tol && d - tol > worst.1 {
            worst = (i, d - tol);
        }
    }
    if worst.1 > 0.0 {
        let i = worst.0;
        Err(format!(
            "mismatch at [{i}]: {} vs {} (|d|={:.3e}, rtol={rtol:.1e}, atol={atol:.1e})",
            a[i],
            b[i],
            (a[i] - b[i]).abs()
        ))
    } else {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        quickcheck(
            "add-commutes",
            |rng| (rng.below(100) as i64, rng.below(100) as i64),
            |(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_seed() {
        check(
            "always-fails",
            &Config { cases: 3, seed: 1 },
            |rng| rng.below(10),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn cases_are_deterministic_per_seed() {
        let mut seen1 = Vec::new();
        check(
            "collect1",
            &Config { cases: 5, seed: 42 },
            |rng| rng.next_u64(),
            |v| {
                seen1.push(*v);
                Ok(())
            },
        );
        let mut seen2 = Vec::new();
        check(
            "collect2",
            &Config { cases: 5, seed: 42 },
            |rng| rng.next_u64(),
            |v| {
                seen2.push(*v);
                Ok(())
            },
        );
        assert_eq!(seen1, seen2);
    }

    #[test]
    fn assert_close_accepts_and_rejects() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-12], 1e-9, 0.0).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-9, 0.0).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-9, 0.0).is_err());
    }
}
