//! Minimal JSON writer + parser (no `serde` facade in the offline crate
//! set).
//!
//! Experiment harnesses emit machine-readable results under
//! `target/experiments/*.json` alongside the printed paper-style tables;
//! the reduction service ([`crate::service`]) speaks a JSON-lines wire
//! protocol through the same value type. Writing uses a small builder
//! enum; parsing is a recursive-descent reader ([`Json::parse`]).
//!
//! Float fidelity: `Num` renders through Rust's shortest-roundtrip
//! `f64` formatting and parses back with `str::parse::<f64>`, so a
//! finite `f64` survives a render→parse round trip **bitwise** — the
//! property the service relies on to return bitwise-identical singular
//! values over the wire.

use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn s<S: Into<String>>(v: S) -> Json {
        Json::Str(v.into())
    }

    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert into an object (panics if self is not an object).
    pub fn set<S: Into<String>, V: Into<Json>>(mut self, key: S, v: V) -> Json {
        match &mut self {
            Json::Obj(pairs) => pairs.push((key.into(), v.into())),
            _ => panic!("set() on non-object"),
        }
        self
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Parse one JSON value (object/array/string/number/bool/null) from
    /// `s`. Trailing non-whitespace is an error — the service protocol is
    /// one value per line.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing characters at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Object field lookup (`None` for non-objects and missing keys; the
    /// first binding wins, matching the writer which never duplicates).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Numeric view: `Num` as-is, `Int` widened. (`i64` → `f64` is exact
    /// up to 2^53 — far beyond any count this crate emits.)
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            Json::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// Integer view: `Int` as-is, integral `Num`s converted exactly.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Num(x) if x.fract() == 0.0 && x.abs() < 9.0e15 => Some(*x as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    // JSON has no Inf/NaN; encode as null (documented).
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    it.write(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Recursive-descent JSON reader over raw bytes (string contents are
/// re-validated as UTF-8 when sliced back out, so multi-byte characters
/// pass through untouched). Nesting is bounded: the parser recurses per
/// container, and a wire-facing consumer (the reduction service) must
/// reject a hostile `[[[[…` line with an error instead of overflowing
/// the thread's stack.
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", b as char, self.pos))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.nested(Self::array),
            Some(b'{') => self.nested(Self::object),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(c) => Err(format!("unexpected {:?} at byte {}", c as char, self.pos)),
            None => Err("unexpected end of input".into()),
        }
    }

    /// Run a container parser one nesting level down, bounded by
    /// [`MAX_DEPTH`].
    fn nested(
        &mut self,
        f: impl FnOnce(&mut Self) -> Result<Json, String>,
    ) -> Result<Json, String> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH} levels"));
        }
        let v = f(self);
        self.depth -= 1;
        v
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            pairs.push((key, self.value()?));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        let mut run = self.pos; // start of the current unescaped run
        loop {
            match self.peek() {
                Some(b'"') => {
                    out.push_str(self.slice(run, self.pos)?);
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    out.push_str(self.slice(run, self.pos)?);
                    self.pos += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'u' => out.push(self.unicode_escape()?),
                        other => {
                            return Err(format!("bad escape \\{} ", other as char));
                        }
                    }
                    run = self.pos;
                }
                Some(_) => self.pos += 1,
                None => return Err("unterminated string".into()),
            }
        }
    }

    fn unicode_escape(&mut self) -> Result<char, String> {
        let hex4 = |p: &mut Self| -> Result<u32, String> {
            let s = p
                .bytes
                .get(p.pos..p.pos + 4)
                .and_then(|b| std::str::from_utf8(b).ok())
                .ok_or("truncated \\u escape")?;
            let v = u32::from_str_radix(s, 16).map_err(|_| format!("bad \\u escape {s:?}"))?;
            p.pos += 4;
            Ok(v)
        };
        let hi = hex4(self)?;
        // Surrogate pair (the writer never emits one, but clients may).
        if (0xD800..0xDC00).contains(&hi) {
            if self.bytes.get(self.pos..self.pos + 2) != Some(b"\\u") {
                return Err("unpaired surrogate".into());
            }
            self.pos += 2;
            let lo = hex4(self)?;
            if !(0xDC00..0xE000).contains(&lo) {
                return Err("invalid low surrogate".into());
            }
            let c = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
            return char::from_u32(c).ok_or_else(|| "invalid surrogate pair".into());
        }
        char::from_u32(hi).ok_or_else(|| format!("invalid codepoint {hi:#x}"))
    }

    fn slice(&self, start: usize, end: usize) -> Result<&str, String> {
        std::str::from_utf8(&self.bytes[start..end]).map_err(|_| "invalid UTF-8".to_string())
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let mut integral = true;
        while let Some(b) = self.peek() {
            match b {
                b'0'..=b'9' => self.pos += 1,
                b'.' | b'e' | b'E' | b'+' | b'-' => {
                    integral = false;
                    self.pos += 1;
                }
                _ => break,
            }
        }
        let tok = self.slice(start, self.pos)?;
        // "-0" must stay a float: Int(0) would drop the sign bit the
        // bitwise round-trip guarantee preserves.
        if integral && tok != "-0" {
            if let Ok(i) = tok.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        tok.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number {tok:?} at byte {start}"))
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Int(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Int(v as i64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Write an experiment result file under `target/experiments/<name>.json`,
/// creating the directory as needed. Returns the path written.
pub fn write_experiment(name: &str, value: &Json) -> std::io::Result<std::path::PathBuf> {
    let dir = std::path::Path::new("target").join("experiments");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, value.render())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_scalars() {
        assert_eq!(Json::Null.render(), "null");
        assert_eq!(Json::Bool(true).render(), "true");
        assert_eq!(Json::Int(-3).render(), "-3");
        assert_eq!(Json::Num(1.5).render(), "1.5");
        assert_eq!(Json::s("hi").render(), "\"hi\"");
    }

    #[test]
    fn escapes_strings() {
        assert_eq!(Json::s("a\"b\\c\nd").render(), "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn renders_nested() {
        let j = Json::obj()
            .set("name", "fig6")
            .set("sizes", vec![128usize, 256])
            .set("ok", true)
            .set("ratio", 2.5);
        assert_eq!(
            j.render(),
            "{\"name\":\"fig6\",\"sizes\":[128,256],\"ok\":true,\"ratio\":2.5}"
        );
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    fn non_finite_floats_round_trip_as_null() {
        // JSON has no Inf/NaN, so the render guard encodes them as null
        // — and a full render→parse round trip lands on `Json::Null`,
        // never a parse error. The stats verb relies on this: idle
        // latency quantiles are NaN and must reach clients as null.
        for x in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let rendered = Json::Num(x).render();
            assert_eq!(rendered, "null");
            assert_eq!(Json::parse(&rendered).unwrap(), Json::Null);
        }
        // The guard holds inside containers too.
        let obj = Json::obj().set("p50", f64::NAN).set("count", 0usize);
        let back = Json::parse(&obj.render()).unwrap();
        assert_eq!(back.get("p50"), Some(&Json::Null));
        assert_eq!(back.get("count").and_then(Json::as_usize), Some(0));
    }

    #[test]
    fn parses_scalars_and_containers() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-42").unwrap(), Json::Int(-42));
        assert_eq!(Json::parse("1.5e2").unwrap(), Json::Num(150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::s("hi"));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{ }").unwrap(), Json::obj());
        let v = Json::parse("{\"a\": [1, 2.5, \"x\"], \"b\": {\"c\": false}}").unwrap();
        assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn parse_rejects_malformed_input() {
        for bad in ["", "{", "[1,", "\"open", "nul", "{\"a\" 1}", "1 2", "{'a':1}", "[1,]"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn nesting_is_bounded_but_generous() {
        let deep = |levels: usize| format!("{}0{}", "[".repeat(levels), "]".repeat(levels));
        assert!(Json::parse(&deep(100)).is_ok());
        let err = Json::parse(&deep(100_000)).unwrap_err();
        assert!(err.contains("nesting"), "{err}");
        // Mixed containers count against the same budget (2 levels per
        // repeat here: 120 total, inside the 128 bound).
        assert!(Json::parse(&format!("{}1{}", "[{\"k\":".repeat(60), "}]".repeat(60))).is_ok());
    }

    #[test]
    fn string_escapes_roundtrip() {
        let s = "a\"b\\c\nd\te\u{1F600}é";
        let rendered = Json::s(s).render();
        assert_eq!(Json::parse(&rendered).unwrap().as_str(), Some(s));
        // Client-side \u escapes, including a surrogate pair.
        assert_eq!(Json::parse("\"\\u0041\\ud83d\\ude00\"").unwrap().as_str(), Some("A\u{1F600}"));
        assert!(Json::parse("\"\\ud83d\"").is_err()); // unpaired surrogate
    }

    #[test]
    fn render_parse_roundtrips_f64_bitwise() {
        // The property the service wire format relies on: finite doubles
        // survive render→parse exactly.
        let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(7);
        for _ in 0..2000 {
            let bits = rng.next_u64();
            let x = f64::from_bits(bits);
            if !x.is_finite() {
                continue;
            }
            let parsed = Json::parse(&Json::Num(x).render()).unwrap();
            let y = parsed.as_f64().unwrap();
            assert_eq!(y.to_bits(), x.to_bits(), "{x:?} -> {y:?}");
        }
        // And typical values, including negative zero (kept a float so
        // the sign bit survives).
        for x in [0.0f64, -0.0, 1.0 / 3.0, f64::MIN_POSITIVE, f64::MAX] {
            let parsed = Json::parse(&Json::Num(x).render()).unwrap();
            assert_eq!(parsed.as_f64().unwrap().to_bits(), x.to_bits());
        }
    }

    #[test]
    fn numeric_accessors_convert_exactly() {
        assert_eq!(Json::Int(7).as_f64(), Some(7.0));
        assert_eq!(Json::Num(7.0).as_i64(), Some(7));
        assert_eq!(Json::Num(7.5).as_i64(), None);
        assert_eq!(Json::Int(-1).as_usize(), None);
        assert_eq!(Json::Int(3).as_usize(), Some(3));
        assert_eq!(Json::s("3").as_i64(), None);
    }
}
