//! Minimal JSON writer (no `serde` facade in the offline crate set).
//!
//! Experiment harnesses emit machine-readable results under
//! `target/experiments/*.json` alongside the printed paper-style tables.
//! Only writing is needed; values are built with a small builder enum.

use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn s<S: Into<String>>(v: S) -> Json {
        Json::Str(v.into())
    }

    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert into an object (panics if self is not an object).
    pub fn set<S: Into<String>, V: Into<Json>>(mut self, key: S, v: V) -> Json {
        match &mut self {
            Json::Obj(pairs) => pairs.push((key.into(), v.into())),
            _ => panic!("set() on non-object"),
        }
        self
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    // JSON has no Inf/NaN; encode as null (documented).
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    it.write(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Int(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Int(v as i64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Write an experiment result file under `target/experiments/<name>.json`,
/// creating the directory as needed. Returns the path written.
pub fn write_experiment(name: &str, value: &Json) -> std::io::Result<std::path::PathBuf> {
    let dir = std::path::Path::new("target").join("experiments");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, value.render())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_scalars() {
        assert_eq!(Json::Null.render(), "null");
        assert_eq!(Json::Bool(true).render(), "true");
        assert_eq!(Json::Int(-3).render(), "-3");
        assert_eq!(Json::Num(1.5).render(), "1.5");
        assert_eq!(Json::s("hi").render(), "\"hi\"");
    }

    #[test]
    fn escapes_strings() {
        assert_eq!(Json::s("a\"b\\c\nd").render(), "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn renders_nested() {
        let j = Json::obj()
            .set("name", "fig6")
            .set("sizes", vec![128usize, 256])
            .set("ok", true)
            .set("ratio", 2.5);
        assert_eq!(
            j.render(),
            "{\"name\":\"fig6\",\"sizes\":[128,256],\"ok\":true,\"ratio\":2.5}"
        );
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }
}
