//! Declarative command-line parsing (no `clap` in the offline crate set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! subcommands, typed accessors with defaults, and auto-generated help.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Specification of one option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// A parsed argument set for one (sub)command.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positionals: Vec<String>,
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.get(key) {
            Some(s) => s.parse().unwrap_or_else(|_| {
                eprintln!("warning: bad value for --{key}: {s:?}; using default");
                default
            }),
            None => default,
        }
    }

    /// Typed accessor for options whose absence is meaningful: `None`
    /// when the option is unset or set to the empty string (the
    /// conventional "defer to another source" default, e.g. an
    /// environment knob), `Err` when present but unparsable.
    pub fn parse_opt<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>, String> {
        match self.get(key) {
            None | Some("") => Ok(None),
            Some(s) => s.parse().map(Some).map_err(|_| format!("bad value for --{key}: {s:?}")),
        }
    }

    pub fn require<T: std::str::FromStr>(&self, key: &str) -> Result<T, String> {
        match self.get(key) {
            Some(s) => s
                .parse()
                .map_err(|_| format!("bad value for --{key}: {s:?}")),
            None => Err(format!("missing required option --{key}")),
        }
    }

    /// Parse a comma-separated list, e.g. `--sizes 128,256,512`.
    pub fn parse_list<T: std::str::FromStr>(&self, key: &str, default: &[T]) -> Vec<T>
    where
        T: Clone,
    {
        match self.get(key) {
            Some(s) => {
                let parsed: Result<Vec<T>, _> =
                    s.split(',').map(|p| p.trim().parse::<T>()).collect();
                match parsed {
                    Ok(v) if !v.is_empty() => v,
                    _ => {
                        eprintln!("warning: bad list for --{key}: {s:?}; using default");
                        default.to_vec()
                    }
                }
            }
            None => default.to_vec(),
        }
    }
}

/// A subcommand definition.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

/// Top-level CLI: a program name plus a set of subcommands.
pub struct Cli {
    pub program: &'static str,
    pub about: &'static str,
    pub commands: Vec<Command>,
}

/// Result of parsing: which subcommand and its arguments.
#[derive(Debug)]
pub struct Parsed {
    pub command: String,
    pub args: Args,
}

impl Cli {
    pub fn help(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{} — {}\n", self.program, self.about);
        let _ = writeln!(out, "USAGE: {} <command> [options]\n", self.program);
        let _ = writeln!(out, "COMMANDS:");
        for c in &self.commands {
            let _ = writeln!(out, "  {:<12} {}", c.name, c.about);
        }
        let _ = writeln!(out, "\nRun `{} <command> --help` for options.", self.program);
        out
    }

    pub fn command_help(&self, cmd: &Command) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{} {} — {}\n", self.program, cmd.name, cmd.about);
        let _ = writeln!(out, "OPTIONS:");
        for o in &cmd.opts {
            let mut left = format!("--{}", o.name);
            if !o.is_flag {
                left.push_str(" <v>");
            }
            let dflt = match o.default {
                Some(d) => format!(" [default: {d}]"),
                None => String::new(),
            };
            let _ = writeln!(out, "  {:<22} {}{}", left, o.help, dflt);
        }
        out
    }

    /// Parse argv. On `--help`/errors, returns Err(message) — the caller
    /// prints it and exits (keeps this testable, no process::exit here).
    pub fn parse(&self, argv: &[String]) -> Result<Parsed, String> {
        if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" || argv[0] == "help" {
            return Err(self.help());
        }
        let name = &argv[0];
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == name.as_str())
            .ok_or_else(|| format!("unknown command {name:?}\n\n{}", self.help()))?;

        let mut args = Args::default();
        // Pre-fill defaults.
        for o in &cmd.opts {
            if let Some(d) = o.default {
                args.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(self.command_help(cmd));
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = cmd
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.command_help(cmd)))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("flag --{key} takes no value"));
                    }
                    args.flags.push(key);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("option --{key} needs a value"))?
                        }
                    };
                    args.values.insert(key, val);
                }
            } else {
                args.positionals.push(a.clone());
            }
            i += 1;
        }
        Ok(Parsed { command: cmd.name.to_string(), args })
    }
}

/// Convenience constructor for an option that takes a value.
pub fn opt(name: &'static str, help: &'static str, default: &'static str) -> OptSpec {
    OptSpec { name, help, default: Some(default), is_flag: false }
}

/// Convenience constructor for a required value option.
pub fn opt_req(name: &'static str, help: &'static str) -> OptSpec {
    OptSpec { name, help, default: None, is_flag: false }
}

/// Convenience constructor for a boolean flag.
pub fn flag(name: &'static str, help: &'static str) -> OptSpec {
    OptSpec { name, help, default: None, is_flag: true }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli {
            program: "banded-svd",
            about: "test",
            commands: vec![Command {
                name: "reduce",
                about: "run reduction",
                opts: vec![
                    opt("n", "matrix size", "256"),
                    opt("tw", "inner tilewidth", "8"),
                    flag("verify", "check result"),
                    opt("sizes", "list", "1,2"),
                ],
            }],
        }
    }

    fn sv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let p = cli().parse(&sv(&["reduce"])).unwrap();
        assert_eq!(p.args.parse_or("n", 0usize), 256);
        assert!(!p.args.flag("verify"));
    }

    #[test]
    fn values_and_flags() {
        let p = cli()
            .parse(&sv(&["reduce", "--n", "512", "--verify", "--tw=16"]))
            .unwrap();
        assert_eq!(p.args.parse_or("n", 0usize), 512);
        assert_eq!(p.args.parse_or("tw", 0usize), 16);
        assert!(p.args.flag("verify"));
    }

    #[test]
    fn optional_values_distinguish_absent_empty_and_bad() {
        let p = cli().parse(&sv(&["reduce", "--tw", "16"])).unwrap();
        assert_eq!(p.args.parse_opt::<usize>("tw"), Ok(Some(16)));
        assert_eq!(p.args.parse_opt::<usize>("missing"), Ok(None));
        let p = cli().parse(&sv(&["reduce", "--tw="])).unwrap();
        assert_eq!(p.args.parse_opt::<usize>("tw"), Ok(None));
        let p = cli().parse(&sv(&["reduce", "--tw", "x"])).unwrap();
        assert!(p.args.parse_opt::<usize>("tw").is_err());
    }

    #[test]
    fn lists_parse() {
        let p = cli().parse(&sv(&["reduce", "--sizes", "4,8,16"])).unwrap();
        assert_eq!(p.args.parse_list::<usize>("sizes", &[]), vec![4, 8, 16]);
    }

    #[test]
    fn unknown_command_errors() {
        assert!(cli().parse(&sv(&["bogus"])).is_err());
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cli().parse(&sv(&["reduce", "--bogus", "1"])).is_err());
    }

    #[test]
    fn help_is_error_path() {
        let err = cli().parse(&sv(&["reduce", "--help"])).unwrap_err();
        assert!(err.contains("tilewidth"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(cli().parse(&sv(&["reduce", "--n"])).is_err());
    }
}
