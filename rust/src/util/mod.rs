//! Substrates built in-repo (the offline crate set provides only the
//! `xla` closure): thread pool, PRNG, CLI parsing, benchmarking,
//! property testing, and JSON output.

pub mod bench;
pub mod benchcmp;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod threadpool;
