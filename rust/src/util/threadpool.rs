//! A small work-crew thread pool (no rayon in the offline crate set).
//!
//! The pool is built for the bulge-chasing launch loop: every GPU "kernel
//! launch" becomes one dispatch call that splits the launch's task list
//! across workers and barriers before the next launch — exactly the
//! device-wide synchronization of Algorithm 1 line 11. Two dispatch
//! shapes:
//!
//! - [`ThreadPool::for_each_index`] / [`ThreadPool::for_each_chunk`] —
//!   self-scheduling over an atomic counter; any worker may take any
//!   index (good for irregular, affinity-free work).
//! - [`ThreadPool::for_each_slot`] — *pinned* dispatch: slot `w` always
//!   executes on the same OS thread (worker `w`; the last slot on the
//!   caller). This is the basis for sticky task→worker affinity and the
//!   persistent per-worker workspaces ([`WorkerLocal`]) that keep a
//!   chased column window in one core's cache across launches.
//!
//! Design: long-lived workers block on their own condvar'd queue; a
//! dispatch submits a batch of closures, then waits for the batch counter
//! to drain. Closures borrow the caller's stack via a scoped-lifetime
//! channel (same trick as `std::thread::scope`, implemented with raw
//! pointers behind a safe API).

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

struct WorkerQueue {
    jobs: Mutex<Vec<Job>>,
    ready: Condvar,
}

struct Shared {
    queues: Vec<WorkerQueue>,
    pending: AtomicUsize,
    done: Condvar,
    done_lock: Mutex<()>,
    shutdown: Mutex<bool>,
}

/// A fixed-size pool of worker threads with batch-barrier semantics.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    n_threads: usize,
}

impl ThreadPool {
    /// Create a pool with `n` worker threads (`n == 0` means the number of
    /// available hardware threads).
    pub fn new(n: usize) -> Self {
        let n_threads = if n == 0 {
            std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(4)
        } else {
            n
        };
        let shared = Arc::new(Shared {
            queues: (0..n_threads)
                .map(|_| WorkerQueue { jobs: Mutex::new(Vec::new()), ready: Condvar::new() })
                .collect(),
            pending: AtomicUsize::new(0),
            done: Condvar::new(),
            done_lock: Mutex::new(()),
            shutdown: Mutex::new(false),
        });
        let mut workers = Vec::with_capacity(n_threads);
        for i in 0..n_threads {
            let sh = Arc::clone(&shared);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("bsvd-worker-{i}"))
                    .spawn(move || worker_loop(sh, i))
                    .expect("spawn worker"),
            );
        }
        Self { shared, workers, n_threads }
    }

    /// Number of worker threads.
    pub fn len(&self) -> usize {
        self.n_threads
    }

    pub fn is_empty(&self) -> bool {
        self.n_threads == 0
    }

    /// Number of pinned execution slots for [`ThreadPool::for_each_slot`]:
    /// one per worker plus one for the calling thread.
    pub fn slots(&self) -> usize {
        self.n_threads + 1
    }

    /// Submit one job to each of the first `min(n_jobs, workers)` worker
    /// queues and notify them. Increments the pending counter before
    /// pushing; callers must then wait with [`Self::wait_pending`].
    fn submit_per_worker(&self, n_jobs: usize, mut make: impl FnMut(usize) -> Job) {
        let n_jobs = n_jobs.min(self.n_threads);
        self.shared.pending.fetch_add(n_jobs, Ordering::SeqCst);
        for (w, q) in self.shared.queues.iter().enumerate().take(n_jobs) {
            q.jobs.lock().unwrap().push(make(w));
            q.ready.notify_one();
        }
    }

    /// Barrier: launches are often microseconds of work, so spin briefly
    /// before falling back to the condvar (the launch loop issues
    /// thousands of barriers per reduction — §Perf).
    fn wait_pending(&self) {
        for _ in 0..10_000 {
            if self.shared.pending.load(Ordering::SeqCst) == 0 {
                return;
            }
            std::hint::spin_loop();
        }
        let mut guard = self.shared.done_lock.lock().unwrap();
        while self.shared.pending.load(Ordering::SeqCst) != 0 {
            guard = self.shared.done.wait(guard).unwrap();
        }
    }

    /// Run `f(i)` for every index in `0..count`, distributing indices over
    /// the workers, and return once all have completed. `f` may borrow from
    /// the caller's stack: the barrier at the end of this function makes
    /// that sound (no job outlives the call).
    pub fn for_each_index<F>(&self, count: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if count == 0 {
            return;
        }
        // Execute inline when trivial or when we have no parallelism.
        if count == 1 || self.n_threads <= 1 {
            for i in 0..count {
                f(i);
            }
            return;
        }
        let next = AtomicUsize::new(0);
        // SAFETY: `job` only borrows `f`, `next` — both outlive the barrier
        // below; we erase the lifetime to store it in the 'static queue.
        let f_ref: &(dyn Fn(usize) + Sync) = &f;
        let next_ref: &AtomicUsize = &next;
        self.submit_per_worker(count, |_| make_counter_job(f_ref, next_ref, count));
        // Help out from the calling thread as well.
        loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= count {
                break;
            }
            f(i);
        }
        self.wait_pending();
    }

    /// Split `0..count` into `chunks` contiguous ranges and run `f(range)`
    /// on each in parallel. Used to batch bulge tasks per worker so each
    /// "thread block" processes several bulges (the paper's software loop
    /// unrolling under the MaxBlocks limit).
    pub fn for_each_chunk<F>(&self, count: usize, chunks: usize, f: F)
    where
        F: Fn(std::ops::Range<usize>) + Sync,
    {
        self.for_each_chunk_indexed(count, chunks, |_, range| f(range));
    }

    /// [`Self::for_each_chunk`] with the chunk index passed to `f` — each
    /// index in `0..chunks` is claimed by exactly one worker per dispatch,
    /// so callers can key per-chunk state (e.g. a [`WorkerLocal`]) on it.
    pub fn for_each_chunk_indexed<F>(&self, count: usize, chunks: usize, f: F)
    where
        F: Fn(usize, std::ops::Range<usize>) + Sync,
    {
        if count == 0 {
            return;
        }
        let chunks = chunks.max(1).min(count);
        let base = count / chunks;
        let rem = count % chunks;
        self.for_each_index(chunks, |c| {
            let start = c * base + c.min(rem);
            let len = base + usize::from(c < rem);
            f(c, start..start + len);
        });
    }

    /// Run `f(slot)` for every slot in `0..self.slots()`, with slot `w`
    /// **pinned** to worker thread `w` (and the last slot to the calling
    /// thread). Pinning is stable across calls on the same pool: a given
    /// slot index is always executed by the same OS thread. No stealing —
    /// that is the point: the executor maps a task's column window to a
    /// slot, and the window's data (plus the slot's [`WorkerLocal`]
    /// workspace) stays in that core's cache across launches.
    pub fn for_each_slot<F>(&self, f: F)
    where
        F: Fn(usize) + Sync,
    {
        self.for_each_slot_where(|_| true, f);
    }

    /// [`Self::for_each_slot`] restricted to the slots `active` selects:
    /// inactive workers are neither woken nor waited on. Pinning is
    /// unaffected — a slot's closure either runs on its own thread or not
    /// at all. Lets a launch with work on few slots pay for few wakeups.
    pub fn for_each_slot_where<P, F>(&self, active: P, f: F)
    where
        P: Fn(usize) -> bool,
        F: Fn(usize) + Sync,
    {
        if self.n_threads <= 1 {
            // Degenerate pools run every slot inline (slot pinning is
            // trivially satisfied: one thread does everything).
            for w in 0..self.slots() {
                if active(w) {
                    f(w);
                }
            }
            return;
        }
        let f_ref: &(dyn Fn(usize) + Sync) = &f;
        let n_jobs = (0..self.n_threads).filter(|&w| active(w)).count();
        self.shared.pending.fetch_add(n_jobs, Ordering::SeqCst);
        for (w, q) in self.shared.queues.iter().enumerate() {
            if active(w) {
                q.jobs.lock().unwrap().push(make_slot_job(f_ref, w));
                q.ready.notify_one();
            }
        }
        if active(self.n_threads) {
            f(self.n_threads); // caller's own slot
        }
        self.wait_pending();
    }
}

/// Erase the lifetime of the borrowed closure context. Soundness argument:
/// the dispatch does not return until `pending` drains back to zero,
/// i.e. until every job constructed here has run to completion, so the
/// borrowed references never outlive the borrow.
struct SendPtr<T: ?Sized>(*const T);
unsafe impl<T: ?Sized> Send for SendPtr<T> {}
impl<T: ?Sized> SendPtr<T> {
    fn get(&self) -> *const T {
        self.0
    }
}

fn erase_fn(f: &(dyn Fn(usize) + Sync)) -> SendPtr<dyn Fn(usize) + Sync> {
    // SAFETY: lifetime erasure to 'static; the barrier in the dispatcher
    // guarantees the job dies before the borrow does.
    SendPtr(unsafe {
        std::mem::transmute::<
            *const (dyn Fn(usize) + Sync + '_),
            *const (dyn Fn(usize) + Sync + 'static),
        >(f as *const _)
    })
}

/// Self-scheduling job: drain the shared atomic counter.
fn make_counter_job(f: &(dyn Fn(usize) + Sync), next: &AtomicUsize, count: usize) -> Job {
    let fp = erase_fn(f);
    let np: SendPtr<AtomicUsize> = SendPtr(next as *const _);
    Box::new(move || {
        let f: &(dyn Fn(usize) + Sync) = unsafe { &*fp.get() };
        let next: &AtomicUsize = unsafe { &*np.get() };
        loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= count {
                break;
            }
            f(i);
        }
    })
}

/// Pinned job: run exactly slot `w`.
fn make_slot_job(f: &(dyn Fn(usize) + Sync), w: usize) -> Job {
    let fp = erase_fn(f);
    Box::new(move || {
        let f: &(dyn Fn(usize) + Sync) = unsafe { &*fp.get() };
        f(w);
    })
}

fn worker_loop(shared: Arc<Shared>, me: usize) {
    loop {
        // (Perf note, EXPERIMENTS.md §Perf: a try_lock spin here was
        // measured 3x SLOWER under contention — all workers hammer the
        // queue mutex. Plain condvar wait wins; reverted.)
        let job = {
            let q = &shared.queues[me];
            let mut jobs = q.jobs.lock().unwrap();
            loop {
                if let Some(job) = jobs.pop() {
                    break Some(job);
                }
                if *shared.shutdown.lock().unwrap() {
                    break None;
                }
                jobs = q.ready.wait(jobs).unwrap();
            }
        };
        match job {
            Some(job) => {
                job();
                if shared.pending.fetch_sub(1, Ordering::SeqCst) == 1 {
                    let _g = shared.done_lock.lock().unwrap();
                    shared.done.notify_all();
                }
            }
            None => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        *self.shared.shutdown.lock().unwrap() = true;
        for q in &self.shared.queues {
            // Hold the queue lock while notifying: a worker between its
            // shutdown check and its wait holds this lock, so the notify
            // cannot slip into that window and be missed.
            let _g = q.jobs.lock().unwrap();
            q.ready.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Persistent per-slot storage for a pool's pinned slots — the CPU analog
/// of per-SM shared memory that *survives across kernel launches*. One
/// value per [`ThreadPool::for_each_slot`] slot; because slot `w` is
/// always executed by the same thread, `get_mut(w)` from inside that
/// slot's closure is data-race free.
pub struct WorkerLocal<T> {
    values: Vec<UnsafeCell<T>>,
}

// SAFETY: distinct slots are accessed by distinct threads; access to one
// slot is externally synchronized (the pinned-dispatch contract below).
unsafe impl<T: Send> Sync for WorkerLocal<T> {}

impl<T> WorkerLocal<T> {
    /// One value per slot, built by `init(slot)`.
    pub fn new(slots: usize, mut init: impl FnMut(usize) -> T) -> Self {
        Self { values: (0..slots).map(|w| UnsafeCell::new(init(w))).collect() }
    }

    pub fn slots(&self) -> usize {
        self.values.len()
    }

    /// Exclusive access to slot `w`'s value.
    ///
    /// # Safety
    /// At most one thread may hold slot `w`'s reference at a time — upheld
    /// by calling this only from within slot `w` of
    /// [`ThreadPool::for_each_slot`] (or otherwise externally
    /// synchronizing per-slot access).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get_mut(&self, w: usize) -> &mut T {
        &mut *self.values[w].get()
    }

    /// Exclusive access to every slot (for drains/inspection after the
    /// parallel phase).
    pub fn iter_mut(&mut self) -> impl Iterator<Item = &mut T> {
        self.values.iter_mut().map(|c| c.get_mut())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_index_exactly_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        pool.for_each_index(1000, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "index {i}");
        }
    }

    #[test]
    fn chunks_cover_range_disjointly() {
        let pool = ThreadPool::new(3);
        let hits: Vec<AtomicUsize> = (0..97).map(|_| AtomicUsize::new(0)).collect();
        pool.for_each_chunk(97, 7, |r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn indexed_chunks_have_unique_ids_and_cover_range() {
        let pool = ThreadPool::new(4);
        let id_hits: Vec<AtomicUsize> = (0..5).map(|_| AtomicUsize::new(0)).collect();
        let elem_hits: Vec<AtomicUsize> = (0..83).map(|_| AtomicUsize::new(0)).collect();
        pool.for_each_chunk_indexed(83, 5, |c, r| {
            id_hits[c].fetch_add(1, Ordering::SeqCst);
            for i in r {
                elem_hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(id_hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
        assert!(elem_hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn reusable_across_batches() {
        let pool = ThreadPool::new(2);
        let total = AtomicU64::new(0);
        for round in 0..50u64 {
            pool.for_each_index(10, |i| {
                total.fetch_add(round + i as u64, Ordering::SeqCst);
            });
        }
        // sum over rounds of (10*round + 45)
        let expect: u64 = (0..50u64).map(|r| 10 * r + 45).sum();
        assert_eq!(total.load(Ordering::SeqCst), expect);
    }

    #[test]
    fn zero_count_is_noop() {
        let pool = ThreadPool::new(2);
        pool.for_each_index(0, |_| panic!("should not run"));
    }

    #[test]
    fn single_thread_pool_executes_inline() {
        let pool = ThreadPool::new(1);
        let sum = AtomicUsize::new(0);
        pool.for_each_index(100, |i| {
            sum.fetch_add(i, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 4950);
    }

    #[test]
    fn parallel_sum_matches_serial() {
        let pool = ThreadPool::new(8);
        let data: Vec<u64> = (0..10_000).collect();
        let sum = AtomicU64::new(0);
        pool.for_each_chunk(data.len(), 16, |r| {
            let part: u64 = data[r].iter().sum();
            sum.fetch_add(part, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), data.iter().sum::<u64>());
    }

    #[test]
    fn slots_run_exactly_once_per_dispatch() {
        let pool = ThreadPool::new(4);
        assert_eq!(pool.slots(), 5);
        let hits: Vec<AtomicUsize> = (0..pool.slots()).map(|_| AtomicUsize::new(0)).collect();
        for _ in 0..20 {
            pool.for_each_slot(|w| {
                hits[w].fetch_add(1, Ordering::SeqCst);
            });
        }
        for (w, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 20, "slot {w}");
        }
    }

    #[test]
    fn filtered_slots_skip_inactive_workers() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicUsize> = (0..pool.slots()).map(|_| AtomicUsize::new(0)).collect();
        pool.for_each_slot_where(
            |w| w % 2 == 0,
            |w| {
                hits[w].fetch_add(1, Ordering::SeqCst);
            },
        );
        for (w, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), usize::from(w % 2 == 0), "slot {w}");
        }
    }

    #[test]
    fn slot_pinning_is_stable_across_dispatches() {
        let pool = ThreadPool::new(3);
        let ids: Vec<Mutex<Vec<std::thread::ThreadId>>> =
            (0..pool.slots()).map(|_| Mutex::new(Vec::new())).collect();
        for _ in 0..10 {
            pool.for_each_slot(|w| {
                ids[w].lock().unwrap().push(std::thread::current().id());
            });
        }
        for (w, seen) in ids.iter().enumerate() {
            let seen = seen.lock().unwrap();
            assert_eq!(seen.len(), 10);
            assert!(
                seen.iter().all(|&id| id == seen[0]),
                "slot {w} migrated between threads"
            );
        }
    }

    #[test]
    fn worker_local_persists_across_dispatches() {
        let pool = ThreadPool::new(4);
        let scratch: WorkerLocal<u64> = WorkerLocal::new(pool.slots(), |_| 0);
        for _ in 0..25 {
            pool.for_each_slot(|w| {
                // SAFETY: called from slot w of a pinned dispatch.
                unsafe { *scratch.get_mut(w) += 1 };
            });
        }
        let mut scratch = scratch;
        for v in scratch.iter_mut() {
            assert_eq!(*v, 25);
        }
    }

    #[test]
    fn single_thread_pool_runs_slots_inline() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.slots(), 2);
        let sum = AtomicUsize::new(0);
        pool.for_each_slot(|w| {
            sum.fetch_add(w + 1, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 3);
    }
}
