//! A small work-crew thread pool (no rayon in the offline crate set).
//!
//! The pool is built for the bulge-chasing launch loop: every GPU "kernel
//! launch" becomes a [`ThreadPool::scope_chunks`] call that splits the
//! launch's task list across workers and barriers before the next launch —
//! exactly the device-wide synchronization of Algorithm 1 line 11.
//!
//! Design: long-lived workers block on a condvar; a scope submits a batch
//! of closures, then waits for the batch counter to drain. Closures borrow
//! the caller's stack via a scoped-lifetime channel (same trick as
//! `std::thread::scope`, implemented with raw pointers behind a safe API).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<Vec<Job>>,
    job_ready: Condvar,
    pending: AtomicUsize,
    done: Condvar,
    done_lock: Mutex<()>,
    shutdown: Mutex<bool>,
}

/// A fixed-size pool of worker threads with batch-barrier semantics.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    n_threads: usize,
}

impl ThreadPool {
    /// Create a pool with `n` worker threads (`n == 0` means the number of
    /// available hardware threads).
    pub fn new(n: usize) -> Self {
        let n_threads = if n == 0 {
            std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(4)
        } else {
            n
        };
        let shared = Arc::new(Shared {
            queue: Mutex::new(Vec::new()),
            job_ready: Condvar::new(),
            pending: AtomicUsize::new(0),
            done: Condvar::new(),
            done_lock: Mutex::new(()),
            shutdown: Mutex::new(false),
        });
        let mut workers = Vec::with_capacity(n_threads);
        for i in 0..n_threads {
            let sh = Arc::clone(&shared);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("bsvd-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker"),
            );
        }
        Self { shared, workers, n_threads }
    }

    /// Number of worker threads.
    pub fn len(&self) -> usize {
        self.n_threads
    }

    pub fn is_empty(&self) -> bool {
        self.n_threads == 0
    }

    /// Run `f(i)` for every index in `0..count`, distributing indices over
    /// the workers, and return once all have completed. `f` may borrow from
    /// the caller's stack: the barrier at the end of this function makes
    /// that sound (no job outlives the call).
    pub fn for_each_index<F>(&self, count: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if count == 0 {
            return;
        }
        // Execute inline when trivial or when we have no parallelism.
        if count == 1 || self.n_threads <= 1 {
            for i in 0..count {
                f(i);
            }
            return;
        }
        let next = AtomicUsize::new(0);
        // SAFETY: `job` only borrows `f`, `next` — both outlive the barrier
        // below; we erase the lifetime to store it in the 'static queue.
        let f_ref: &(dyn Fn(usize) + Sync) = &f;
        let next_ref: &AtomicUsize = &next;
        let n_jobs = self.n_threads.min(count);
        {
            let mut q = self.shared.queue.lock().unwrap();
            self.shared.pending.fetch_add(n_jobs, Ordering::SeqCst);
            for _ in 0..n_jobs {
                let job = make_static_job(f_ref, next_ref, count);
                q.push(job);
            }
        }
        self.shared.job_ready.notify_all();
        // Help out from the calling thread as well.
        loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= count {
                break;
            }
            f(i);
        }
        // Barrier: launches are often microseconds of work, so spin
        // briefly before falling back to the condvar (the launch loop
        // issues thousands of barriers per reduction — §Perf).
        for _ in 0..10_000 {
            if self.shared.pending.load(Ordering::SeqCst) == 0 {
                return;
            }
            std::hint::spin_loop();
        }
        let mut guard = self.shared.done_lock.lock().unwrap();
        while self.shared.pending.load(Ordering::SeqCst) != 0 {
            guard = self.shared.done.wait(guard).unwrap();
        }
    }

    /// Split `0..count` into `chunks` contiguous ranges and run `f(range)`
    /// on each in parallel. Used to batch bulge tasks per worker so each
    /// "thread block" processes several bulges (the paper's software loop
    /// unrolling under the MaxBlocks limit).
    pub fn for_each_chunk<F>(&self, count: usize, chunks: usize, f: F)
    where
        F: Fn(std::ops::Range<usize>) + Sync,
    {
        if count == 0 {
            return;
        }
        let chunks = chunks.max(1).min(count);
        let base = count / chunks;
        let rem = count % chunks;
        self.for_each_index(chunks, |c| {
            let start = c * base + c.min(rem);
            let len = base + usize::from(c < rem);
            f(start..start + len);
        });
    }
}

/// Erase the lifetime of the borrowed closure context. Soundness argument:
/// `for_each_index` does not return until `pending` drains back to zero,
/// i.e. until every job constructed here has run to completion, so the
/// borrowed references never outlive the borrow.
fn make_static_job(
    f: &(dyn Fn(usize) + Sync),
    next: &AtomicUsize,
    count: usize,
) -> Job {
    struct SendPtr<T: ?Sized>(*const T);
    unsafe impl<T: ?Sized> Send for SendPtr<T> {}
    impl<T: ?Sized> SendPtr<T> {
        fn get(&self) -> *const T {
            self.0
        }
    }
    // SAFETY: lifetime erasure to 'static; the barrier in
    // `for_each_index` guarantees the job dies before the borrow does.
    let fp: SendPtr<dyn Fn(usize) + Sync> = SendPtr(unsafe {
        std::mem::transmute::<
            *const (dyn Fn(usize) + Sync + '_),
            *const (dyn Fn(usize) + Sync + 'static),
        >(f as *const _)
    });
    let np: SendPtr<AtomicUsize> = SendPtr(next as *const _);
    Box::new(move || {
        let f: &(dyn Fn(usize) + Sync) = unsafe { &*fp.get() };
        let next: &AtomicUsize = unsafe { &*np.get() };
        loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= count {
                break;
            }
            f(i);
        }
    })
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        // (Perf note, EXPERIMENTS.md §Perf: a try_lock spin here was
        // measured 3x SLOWER under contention — all workers hammer the
        // queue mutex. Plain condvar wait wins; reverted.)
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop() {
                    break Some(job);
                }
                if *shared.shutdown.lock().unwrap() {
                    break None;
                }
                q = shared.job_ready.wait(q).unwrap();
            }
        };
        match job {
            Some(job) => {
                job();
                if shared.pending.fetch_sub(1, Ordering::SeqCst) == 1 {
                    let _g = shared.done_lock.lock().unwrap();
                    shared.done.notify_all();
                }
            }
            None => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        *self.shared.shutdown.lock().unwrap() = true;
        self.shared.job_ready.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_index_exactly_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        pool.for_each_index(1000, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "index {i}");
        }
    }

    #[test]
    fn chunks_cover_range_disjointly() {
        let pool = ThreadPool::new(3);
        let hits: Vec<AtomicUsize> = (0..97).map(|_| AtomicUsize::new(0)).collect();
        pool.for_each_chunk(97, 7, |r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn reusable_across_batches() {
        let pool = ThreadPool::new(2);
        let total = AtomicU64::new(0);
        for round in 0..50u64 {
            pool.for_each_index(10, |i| {
                total.fetch_add(round + i as u64, Ordering::SeqCst);
            });
        }
        // sum over rounds of (10*round + 45)
        let expect: u64 = (0..50u64).map(|r| 10 * r + 45).sum();
        assert_eq!(total.load(Ordering::SeqCst), expect);
    }

    #[test]
    fn zero_count_is_noop() {
        let pool = ThreadPool::new(2);
        pool.for_each_index(0, |_| panic!("should not run"));
    }

    #[test]
    fn single_thread_pool_executes_inline() {
        let pool = ThreadPool::new(1);
        let sum = AtomicUsize::new(0);
        pool.for_each_index(100, |i| {
            sum.fetch_add(i, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 4950);
    }

    #[test]
    fn parallel_sum_matches_serial() {
        let pool = ThreadPool::new(8);
        let data: Vec<u64> = (0..10_000).collect();
        let sum = AtomicU64::new(0);
        pool.for_each_chunk(data.len(), 16, |r| {
            let part: u64 = data[r].iter().sum();
            sum.fetch_add(part, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), data.iter().sum::<u64>());
    }
}
