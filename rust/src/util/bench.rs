//! Micro-benchmark harness (no `criterion` in the offline crate set).
//!
//! Provides warmup, adaptive iteration counts targeting a wall-clock
//! budget, and robust statistics (median + MAD), plus a tiny table printer
//! used by every `benches/` target to emit paper-style rows.

use std::time::{Duration, Instant};

/// Statistics for one benchmark case.
#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub mad: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Sample {
    pub fn median_secs(&self) -> f64 {
        self.median.as_secs_f64()
    }
}

/// Benchmark runner configuration.
#[derive(Clone, Debug)]
pub struct Bencher {
    /// Target total measurement time per case.
    pub budget: Duration,
    /// Number of timed batches used for the statistics.
    pub batches: usize,
    /// Warmup time before measuring.
    pub warmup: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            budget: Duration::from_millis(600),
            batches: 7,
            warmup: Duration::from_millis(80),
        }
    }
}

impl Bencher {
    /// Fast settings for CI / quick runs (honours BSVD_BENCH_FAST=1).
    pub fn from_env() -> Self {
        if std::env::var("BSVD_BENCH_FAST").ok().as_deref() == Some("1") {
            Self {
                budget: Duration::from_millis(120),
                batches: 3,
                warmup: Duration::from_millis(10),
            }
        } else {
            Self::default()
        }
    }

    /// Measure `f`, returning robust statistics. `f` is a full unit of
    /// work; the harness decides how many calls per batch.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Sample {
        // Warmup + estimate cost of a single call.
        let warm_start = Instant::now();
        let mut calls = 0usize;
        while warm_start.elapsed() < self.warmup || calls == 0 {
            f();
            calls += 1;
            if calls > 1_000_000 {
                break;
            }
        }
        let per_call = warm_start.elapsed().as_secs_f64() / calls as f64;
        let per_batch_budget = self.budget.as_secs_f64() / self.batches as f64;
        let iters = ((per_batch_budget / per_call.max(1e-9)).ceil() as usize).clamp(1, 1_000_000);

        let mut times: Vec<f64> = Vec::with_capacity(self.batches);
        for _ in 0..self.batches {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            times.push(t0.elapsed().as_secs_f64() / iters as f64);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = times[times.len() / 2];
        let mut dev: Vec<f64> = times.iter().map(|t| (t - median).abs()).collect();
        dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = dev[dev.len() / 2];
        Sample {
            name: name.to_string(),
            iters,
            median: Duration::from_secs_f64(median),
            mad: Duration::from_secs_f64(mad),
            min: Duration::from_secs_f64(times[0]),
            max: Duration::from_secs_f64(*times.last().unwrap()),
        }
    }

    /// Measure a single execution (for expensive cases where repetition is
    /// impractical — e.g. full reductions at large n).
    pub fn run_once<F: FnOnce()>(&self, name: &str, f: F) -> Sample {
        let t0 = Instant::now();
        f();
        let d = t0.elapsed();
        Sample {
            name: name.to_string(),
            iters: 1,
            median: d,
            mad: Duration::ZERO,
            min: d,
            max: d,
        }
    }
}

/// Format a duration with an adaptive unit.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Minimal fixed-width table printer for paper-style output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Self {
        Self {
            headers: headers.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        self.rows.push(cells.into_iter().map(Into::into).collect());
    }

    pub fn render(&self) -> String {
        let cols = self.headers.len();
        let mut w = vec![0usize; cols];
        for (i, h) in self.headers.iter().enumerate() {
            w[i] = w[i].max(h.len());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate().take(cols) {
                w[i] = w[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate().take(cols) {
                out.push_str(&format!("{:<width$}  ", c, width = w[i]));
            }
            out.push('\n');
        };
        line(&mut out, &self.headers);
        let rule: Vec<String> = w.iter().map(|n| "-".repeat(*n)).collect();
        line(&mut out, &rule);
        for r in &self.rows {
            line(&mut out, r);
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something_positive() {
        let b = Bencher {
            budget: Duration::from_millis(20),
            batches: 3,
            warmup: Duration::from_millis(2),
        };
        let mut acc = 0u64;
        let s = b.run("spin", || {
            for i in 0..100u64 {
                acc = acc.wrapping_add(std::hint::black_box(i * i));
            }
            std::hint::black_box(acc);
        });
        assert!(s.median >= Duration::ZERO);
        assert!(s.iters >= 1);
        assert!(acc != u64::MAX); // keep `acc` alive
    }

    #[test]
    fn run_once_records_single_iteration() {
        let b = Bencher::default();
        let s = b.run_once("one", || std::thread::sleep(Duration::from_millis(2)));
        assert_eq!(s.iters, 1);
        assert!(s.median >= Duration::from_millis(1));
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_duration(Duration::from_secs(2)).ends_with(" s"));
        assert!(fmt_duration(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_micros(5)).ends_with("us"));
        assert!(fmt_duration(Duration::from_nanos(50)).ends_with("ns"));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(vec!["a", "long-header"]);
        t.row(vec!["1", "2"]);
        t.row(vec!["333", "4"]);
        let r = t.render();
        assert!(r.contains("long-header"));
        assert_eq!(r.lines().count(), 4);
    }
}
