//! Submission queue with cost-priced admission control.
//!
//! A [`Job`] is one banded reduction in flight: its payload
//! ([`crate::batch::BatchInput`] — shape, precision, matrix), an optional
//! priority class and deadline, and the channel its result travels back
//! on. The queue orders jobs by `(priority, admission sequence)`: lower
//! priority values drain first, and **within a priority class jobs drain
//! strictly in admission order** — the invariant the batcher's flush
//! order inherits (property-tested in
//! `rust/tests/service_roundtrip.rs`).
//!
//! Admission control is *priced*, not counted: every job carries the
//! modeled seconds its solo plan costs on the configured backend
//! ([`crate::simulator::simulate_plan_for`] under the backend's
//! [`crate::simulator::BackendCostModel`] — the same model the autotuner
//! searches), and a submission is rejected while the queue's modeled
//! backlog exceeds [`crate::config::ServiceConfig::backlog_cap_s`] (or
//! its depth exceeds `queue_cap`). An empty queue always admits, so one
//! oversized job cannot deadlock the service.

use crate::banded::dense::Dense;
use crate::batch::BatchInput;
use crate::coordinator::metrics::LaunchMetrics;
use crate::error::{Error, JobError, Result};
use crate::obs::trace::TraceId;
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One admitted job, queued for the batcher.
pub struct Job {
    /// Service-unique id (monotone, assigned at submission).
    pub id: u64,
    /// Admission sequence number (monotone across all classes; the
    /// within-class drain order).
    pub seq: u64,
    /// The problem: matrix + bandwidth, in any supported precision.
    pub input: BatchInput,
    /// Priority class; lower drains first. Default 0.
    pub priority: u8,
    /// Latest useful completion time; jobs past it are failed at flush
    /// instead of executed.
    pub deadline: Option<Instant>,
    /// Modeled solo cost (seconds) on the service backend — the admission
    /// price, released when the job leaves the queue.
    pub est_seconds: f64,
    pub enqueued: Instant,
    /// Quota key the job was admitted under (the request's
    /// `quota_class`, falling back to `client_id`); its pending count is
    /// released when the job leaves the queue. `None` = anonymous.
    pub client: Option<String>,
    /// The job wants singular vectors: its flush records reflectors and
    /// the result carries dense U/Vᵀ panels. Admission enforces
    /// [`crate::config::ServiceConfig::vectors_cap_n`] before the job
    /// reaches the queue.
    pub vectors: bool,
    /// Trace id the job's lifecycle events are recorded under (see
    /// [`crate::obs::trace`]). `TraceId(0)` when tracing is off — the
    /// hooks no-op either way.
    pub trace: TraceId,
    /// Where the outcome is delivered.
    pub tx: Sender<JobOutcome>,
}

/// Pending-job counts per quota key, shared by every shard's queue so a
/// client's cap applies service-wide. A zero cap disables enforcement
/// (nothing is counted); anonymous jobs always pass.
pub(crate) struct QuotaTracker {
    cap: usize,
    pending: Mutex<HashMap<String, usize>>,
}

impl QuotaTracker {
    pub(crate) fn new(cap: usize) -> Self {
        Self { cap, pending: Mutex::new(HashMap::new()) }
    }

    /// Count a job against `client`'s pending budget, or reject with the
    /// retryable [`JobError::QuotaExceeded`] when the budget is spent.
    fn admit(&self, client: Option<&str>) -> std::result::Result<(), JobError> {
        let (Some(client), true) = (client, self.cap > 0) else { return Ok(()) };
        let mut pending = self.pending.lock().unwrap();
        let count = pending.entry(client.to_string()).or_insert(0);
        if *count >= self.cap {
            return Err(JobError::QuotaExceeded {
                reason: format!(
                    "client {client:?} has {count} jobs pending (cap {})",
                    self.cap
                ),
            });
        }
        *count += 1;
        Ok(())
    }

    /// Return a popped job's slot to its quota key.
    fn release(&self, client: Option<&str>) {
        let (Some(client), true) = (client, self.cap > 0) else { return };
        let mut pending = self.pending.lock().unwrap();
        if let Some(count) = pending.get_mut(client) {
            *count = count.saturating_sub(1);
            if *count == 0 {
                pending.remove(client);
            }
        }
    }
}

/// What a completed job reports back.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub id: u64,
    pub n: usize,
    pub bw: usize,
    /// Paper-style precision label ("fp64" / "fp32" / "fp16").
    pub precision: &'static str,
    /// Singular values, descending, widened to f64.
    pub sv: Vec<f64>,
    /// Per-problem launch accounting from the merged-plan execution —
    /// identical to what a solo run of the same problem records.
    pub metrics: LaunchMetrics,
    /// Jobs co-scheduled in the flush that carried this one.
    pub batch_jobs: usize,
    /// Time spent queued before the flush.
    pub queue_wait: Duration,
    /// Left singular vectors (n×n, f64), when the job requested vectors.
    pub u: Option<Dense<f64>>,
    /// Right singular vectors, transposed (n×n, f64), when requested.
    pub vt: Option<Dense<f64>>,
}

/// A job either completes with a [`JobResult`] or fails with a typed
/// [`JobError`] (backend error, expired deadline, service shutdown) —
/// the same taxonomy the client API and the wire surface.
pub type JobOutcome = std::result::Result<JobResult, JobError>;

/// Blocking handle on one submitted job.
pub struct JobTicket {
    pub id: u64,
    pub(crate) rx: Receiver<JobOutcome>,
}

impl JobTicket {
    /// Wait for the job's outcome. A disconnected channel (service torn
    /// down mid-job) reports as [`JobError::Unavailable`].
    pub fn wait(self) -> JobOutcome {
        self.rx.recv().unwrap_or_else(|_| {
            Err(JobError::Unavailable { reason: "service shut down before the job ran".into() })
        })
    }
}

struct QueueState {
    /// Pending jobs, bucketed by priority class, FIFO within a class.
    classes: BTreeMap<u8, VecDeque<Job>>,
    depth: usize,
    /// Sum of pending `est_seconds` (the priced backlog).
    backlog_s: f64,
    next_seq: u64,
    /// Jobs failed at flush because their deadline had passed — feeds
    /// the service's `jobs_failed` accounting so
    /// submitted = completed + failed + queued always reconciles.
    expired: u64,
    closed: bool,
}

impl QueueState {
    fn pop_front(&mut self) -> Option<Job> {
        let (&class, _) = self.classes.iter().find(|(_, q)| !q.is_empty())?;
        let q = self.classes.get_mut(&class).unwrap();
        let job = q.pop_front()?;
        if q.is_empty() {
            self.classes.remove(&class);
        }
        self.depth -= 1;
        self.backlog_s = (self.backlog_s - job.est_seconds).max(0.0);
        Some(job)
    }
}

/// The admission-controlled submission queue shared by submitters and the
/// batcher worker.
pub struct JobQueue {
    state: Mutex<QueueState>,
    /// Signaled on every admission and on close — what the batcher's
    /// window wait parks on.
    arrived: Condvar,
    queue_cap: usize,
    backlog_cap_s: f64,
    /// Per-client pending counts, shared across shards (quota caps are
    /// service-wide, not per queue).
    quota: Arc<QuotaTracker>,
}

impl JobQueue {
    pub fn new(queue_cap: usize, backlog_cap_s: f64) -> Self {
        Self::with_quota(queue_cap, backlog_cap_s, Arc::new(QuotaTracker::new(0)))
    }

    pub(crate) fn with_quota(
        queue_cap: usize,
        backlog_cap_s: f64,
        quota: Arc<QuotaTracker>,
    ) -> Self {
        Self {
            state: Mutex::new(QueueState {
                classes: BTreeMap::new(),
                depth: 0,
                backlog_s: 0.0,
                next_seq: 0,
                expired: 0,
                closed: false,
            }),
            arrived: Condvar::new(),
            queue_cap: queue_cap.max(1),
            backlog_cap_s,
            quota,
        }
    }

    /// Admit an anonymous values-only job or reject it —
    /// [`JobQueue::submit_for`] with no quota key and no vectors.
    pub fn submit(
        &self,
        id: u64,
        input: BatchInput,
        priority: u8,
        deadline: Option<Instant>,
        est_seconds: f64,
        tx: Sender<JobOutcome>,
    ) -> Result<()> {
        self.submit_for(None, TraceId(0), id, input, priority, deadline, est_seconds, false, tx)
    }

    /// Admit a job or reject it. Rejection reasons: queue closed, depth at
    /// `queue_cap`, (for a non-empty queue) priced backlog past
    /// `backlog_cap_s`, or `client`'s pending-job quota spent. The
    /// vectors size cap is the service's admission concern, enforced
    /// before this is reached.
    #[allow(clippy::too_many_arguments)]
    pub fn submit_for(
        &self,
        client: Option<&str>,
        trace: TraceId,
        id: u64,
        input: BatchInput,
        priority: u8,
        deadline: Option<Instant>,
        est_seconds: f64,
        vectors: bool,
        tx: Sender<JobOutcome>,
    ) -> Result<()> {
        let mut state = self.state.lock().unwrap();
        // Rejections carry the typed taxonomy: load-driven rejections are
        // retryable [`JobError::Overloaded`] (back-pressure), shutdown is
        // terminal [`JobError::Unavailable`] — so callers can branch on
        // `Error::is_retryable` instead of parsing messages.
        if state.closed {
            return Err(Error::Job(JobError::Unavailable {
                reason: "service is shutting down".into(),
            }));
        }
        if state.depth >= self.queue_cap {
            return Err(Error::Job(JobError::Overloaded {
                reason: format!(
                    "queue full: {} jobs pending (cap {})",
                    state.depth, self.queue_cap
                ),
            }));
        }
        if state.depth > 0 && state.backlog_s + est_seconds > self.backlog_cap_s {
            return Err(Error::Job(JobError::Overloaded {
                reason: format!(
                    "admission rejected: modeled backlog {:.3}s + job {:.3}s exceeds cap {:.3}s",
                    state.backlog_s, est_seconds, self.backlog_cap_s
                ),
            }));
        }
        // Quota is checked last, so a quota rejection always means "your
        // jobs are the bottleneck", never "the service is loaded".
        self.quota.admit(client).map_err(Error::Job)?;
        let seq = state.next_seq;
        state.next_seq += 1;
        let job = Job {
            id,
            seq,
            input,
            priority,
            deadline,
            est_seconds,
            enqueued: Instant::now(),
            client: client.map(String::from),
            vectors,
            trace,
            tx,
        };
        state.classes.entry(priority).or_default().push_back(job);
        state.depth += 1;
        state.backlog_s += est_seconds;
        drop(state);
        self.arrived.notify_all();
        Ok(())
    }

    /// Pending jobs.
    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().depth
    }

    /// Priced backlog (modeled seconds of pending work).
    pub fn backlog_seconds(&self) -> f64 {
        self.state.lock().unwrap().backlog_s
    }

    /// Enqueue time of the earliest-admitted pending job (the instant the
    /// batcher's time window is measured from). Any pending job is at or
    /// behind its class front, so the minimum over fronts is the oldest.
    pub fn oldest_enqueued(&self) -> Option<Instant> {
        let state = self.state.lock().unwrap();
        state.classes.values().filter_map(|q| q.front()).map(|job| job.enqueued).min()
    }

    /// Block until at least one job is pending or the queue is closed.
    /// Returns `false` when closed *and* drained (the batcher's exit
    /// signal).
    pub fn wait_job(&self) -> bool {
        let mut state = self.state.lock().unwrap();
        loop {
            if state.depth > 0 {
                return true;
            }
            if state.closed {
                return false;
            }
            state = self.arrived.wait(state).unwrap();
        }
    }

    /// Block up to `timeout` for the depth to reach `target` (the size
    /// flush trigger). Returns the depth observed at wakeup — time-window
    /// expiry simply reports fewer.
    pub fn wait_depth(&self, target: usize, timeout: Duration) -> usize {
        let deadline = Instant::now() + timeout;
        let mut state = self.state.lock().unwrap();
        loop {
            if state.depth >= target || state.closed {
                return state.depth;
            }
            let now = Instant::now();
            if now >= deadline {
                return state.depth;
            }
            let (next, timed_out) = self.arrived.wait_timeout(state, deadline - now).unwrap();
            state = next;
            if timed_out.timed_out() {
                return state.depth;
            }
        }
    }

    /// Drain up to `max` jobs in `(priority, admission seq)` order —
    /// the batcher's flush. Jobs whose deadline already passed are failed
    /// (outcome sent) and do not count toward `max`.
    pub fn pop_batch(&self, max: usize) -> Vec<Job> {
        let mut out = Vec::new();
        let now = Instant::now();
        let mut state = self.state.lock().unwrap();
        while out.len() < max {
            let Some(job) = state.pop_front() else { break };
            // A popped job has left the queue whether it executes or
            // expires — its quota slot frees either way.
            self.quota.release(job.client.as_deref());
            if job.deadline.is_some_and(|d| d < now) {
                state.expired += 1;
                let _ = job.tx.send(Err(JobError::DeadlineExpired {
                    queued_ms: job.enqueued.elapsed().as_millis() as u64,
                }));
                continue;
            }
            out.push(job);
        }
        out
    }

    /// Jobs failed at flush with an expired deadline.
    pub fn expired_jobs(&self) -> u64 {
        self.state.lock().unwrap().expired
    }

    /// Close the queue: no further admissions; blocked waits wake up.
    /// Already-admitted jobs still drain.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.arrived.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generate::random_banded;
    use crate::util::rng::Xoshiro256;
    use std::sync::mpsc;

    fn input(n: usize, bw: usize, rng: &mut Xoshiro256) -> BatchInput {
        BatchInput::from((random_banded::<f64>(n, bw, 4, rng), bw))
    }

    fn submit(q: &JobQueue, id: u64, priority: u8, est: f64) -> Receiver<JobOutcome> {
        let mut rng = Xoshiro256::seed_from_u64(id);
        let (tx, rx) = mpsc::channel();
        q.submit(id, input(24, 3, &mut rng), priority, None, est, tx).unwrap();
        rx
    }

    #[test]
    fn drains_by_priority_then_admission_order() {
        let q = JobQueue::new(16, 1e9);
        for (id, priority) in [(0u64, 1u8), (1, 0), (2, 1), (3, 0), (4, 2)] {
            submit(&q, id, priority, 0.0);
        }
        let ids: Vec<u64> = q.pop_batch(16).iter().map(|j| j.id).collect();
        assert_eq!(ids, vec![1, 3, 0, 2, 4]);
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn partial_pops_preserve_order_across_flushes() {
        let q = JobQueue::new(16, 1e9);
        for id in 0..6u64 {
            submit(&q, id, 0, 0.0);
        }
        let first: Vec<u64> = q.pop_batch(2).iter().map(|j| j.id).collect();
        submit(&q, 6, 0, 0.0);
        let rest: Vec<u64> = q.pop_batch(16).iter().map(|j| j.id).collect();
        assert_eq!(first, vec![0, 1]);
        assert_eq!(rest, vec![2, 3, 4, 5, 6]);
    }

    #[test]
    fn depth_cap_rejects_but_empty_queue_admits() {
        let q = JobQueue::new(2, 1e9);
        submit(&q, 0, 0, 0.0);
        submit(&q, 1, 0, 0.0);
        let mut rng = Xoshiro256::seed_from_u64(9);
        let (tx, _rx) = mpsc::channel();
        let err = q.submit(2, input(24, 3, &mut rng), 0, None, 0.0, tx).unwrap_err();
        assert!(err.to_string().contains("queue full"), "{err}");
        assert!(err.is_retryable(), "depth-cap rejection must be retryable back-pressure");
        q.pop_batch(16);
        submit(&q, 3, 0, 0.0); // admits again once drained
    }

    #[test]
    fn priced_backlog_rejects_only_loaded_queues() {
        let q = JobQueue::new(16, 1.0);
        // An oversized job is admitted while the queue is empty...
        submit(&q, 0, 0, 5.0);
        assert_eq!(q.backlog_seconds(), 5.0);
        // ...but any further submission is priced out.
        let mut rng = Xoshiro256::seed_from_u64(9);
        let (tx, _rx) = mpsc::channel();
        let err = q.submit(1, input(24, 3, &mut rng), 0, None, 0.1, tx).unwrap_err();
        assert!(err.to_string().contains("admission rejected"), "{err}");
        assert!(err.is_retryable(), "backlog-cap rejection must be retryable back-pressure");
        q.pop_batch(16);
        assert_eq!(q.backlog_seconds(), 0.0);
        submit(&q, 2, 0, 0.1);
    }

    #[test]
    fn expired_deadlines_fail_at_flush() {
        let q = JobQueue::new(16, 1e9);
        let mut rng = Xoshiro256::seed_from_u64(1);
        let (tx, rx) = mpsc::channel();
        let past = Instant::now() - Duration::from_millis(10);
        q.submit(0, input(24, 3, &mut rng), 0, Some(past), 0.0, tx).unwrap();
        submit(&q, 1, 0, 0.0);
        let batch = q.pop_batch(16);
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id, 1);
        let outcome = rx.try_recv().expect("expired job must get an outcome");
        let err = outcome.unwrap_err();
        assert_eq!(err.kind(), "deadline-expired");
        assert!(!err.is_retryable());
        assert_eq!(q.expired_jobs(), 1);
    }

    #[test]
    fn oldest_enqueued_tracks_the_earliest_pending_job() {
        let q = JobQueue::new(16, 1e9);
        assert!(q.oldest_enqueued().is_none());
        submit(&q, 0, 1, 0.0); // lower-urgency class first
        let first = q.oldest_enqueued().expect("one job pending");
        submit(&q, 1, 0, 0.0); // more urgent but newer
        assert_eq!(q.oldest_enqueued(), Some(first), "age, not priority, drives the window");
        q.pop_batch(16);
        assert!(q.oldest_enqueued().is_none());
    }

    #[test]
    fn close_rejects_new_work_and_wakes_waiters() {
        let q = JobQueue::new(16, 1e9);
        q.close();
        assert!(q.is_closed());
        assert!(!q.wait_job());
        let mut rng = Xoshiro256::seed_from_u64(1);
        let (tx, _rx) = mpsc::channel();
        let err = q.submit(0, input(24, 3, &mut rng), 0, None, 0.0, tx).unwrap_err();
        assert_eq!(err.as_job().unwrap().kind(), "unavailable");
        assert!(!err.is_retryable(), "shutdown is terminal, not back-pressure");
    }

    #[test]
    fn quota_cap_rejects_the_hog_but_not_other_clients() {
        let quota = Arc::new(QuotaTracker::new(2));
        let q = JobQueue::with_quota(16, 1e9, quota);
        let mut rng = Xoshiro256::seed_from_u64(3);
        let mut submit_as = |client: Option<&str>, id: u64| {
            let (tx, _rx) = mpsc::channel::<JobOutcome>();
            q.submit_for(client, TraceId(0), id, input(24, 3, &mut rng), 0, None, 0.0, false, tx)
        };
        submit_as(Some("tenant-a"), 0).unwrap();
        submit_as(Some("tenant-a"), 1).unwrap();
        let err = submit_as(Some("tenant-a"), 2).unwrap_err();
        assert_eq!(err.as_job().unwrap().kind(), "quota-exceeded");
        assert!(err.is_retryable(), "quota rejection must be retryable back-pressure");
        // Other clients and anonymous jobs are unaffected.
        submit_as(Some("tenant-b"), 3).unwrap();
        submit_as(None, 4).unwrap();
        // Draining releases the budget.
        q.pop_batch(16);
        submit_as(Some("tenant-a"), 5).unwrap();
    }

    #[test]
    fn quota_slots_are_shared_across_queues_and_freed_on_expiry() {
        let quota = Arc::new(QuotaTracker::new(1));
        let qa = JobQueue::with_quota(16, 1e9, Arc::clone(&quota));
        let qb = JobQueue::with_quota(16, 1e9, Arc::clone(&quota));
        let mut rng = Xoshiro256::seed_from_u64(5);
        let past = Instant::now() - Duration::from_millis(10);
        let (tx, _rx) = mpsc::channel::<JobOutcome>();
        qa.submit_for(
            Some("c"),
            TraceId(0),
            0,
            input(24, 3, &mut rng),
            0,
            Some(past),
            0.0,
            false,
            tx,
        )
        .unwrap();
        // The cap is service-wide: the second queue sees the same budget.
        let (tx, _rx) = mpsc::channel::<JobOutcome>();
        let err = qb
            .submit_for(Some("c"), TraceId(0), 1, input(24, 3, &mut rng), 0, None, 0.0, false, tx)
            .unwrap_err();
        assert_eq!(err.as_job().unwrap().kind(), "quota-exceeded");
        // The job expires at flush — the slot frees anyway.
        assert!(qa.pop_batch(16).is_empty());
        assert_eq!(qa.expired_jobs(), 1);
        let (tx, _rx) = mpsc::channel::<JobOutcome>();
        qb.submit_for(Some("c"), TraceId(0), 2, input(24, 3, &mut rng), 0, None, 0.0, false, tx)
            .unwrap();
    }

    #[test]
    fn zero_cap_disables_quota_enforcement() {
        let q = JobQueue::new(16, 1e9);
        let mut rng = Xoshiro256::seed_from_u64(7);
        for id in 0..8u64 {
            let (tx, _rx) = mpsc::channel::<JobOutcome>();
            q.submit_for(
                Some("free"),
                TraceId(0),
                id,
                input(24, 3, &mut rng),
                0,
                None,
                0.0,
                false,
                tx,
            )
            .unwrap();
        }
        assert_eq!(q.depth(), 8);
    }

    #[test]
    fn wait_depth_returns_current_depth_on_timeout() {
        let q = JobQueue::new(16, 1e9);
        submit(&q, 0, 0, 0.0);
        let d = q.wait_depth(4, Duration::from_millis(5));
        assert_eq!(d, 1);
        assert_eq!(q.wait_depth(1, Duration::from_secs(5)), 1); // already met
    }
}
