//! TCP JSON-lines front end over the in-process [`Service`].
//!
//! One request per line, one response per line (both single JSON
//! objects, `\n`-terminated). Verbs:
//!
//! | verb | request fields | response |
//! | --- | --- | --- |
//! | `submit` | `n`, `bw`, `band` (row-major in-band values, see [`wire::band_values`]), optional `precision` (`fp16\|fp32\|fp64`, default `fp64`), `priority` (default 0), `deadline_ms`, `client_id`/`quota_class` (identity for quota accounting), `vectors` (proto ≥ 3: accumulate singular-vector panels), `proto` | `id`, `sv` (descending, f64), `metrics` (launches/tasks/max_parallel/unrolled_launches/bytes), `batch_jobs`, `queue_us`, and — when `vectors` was set — `u`/`vt` (flat row-major n² f64 panels) |
//! | `stats` | — | queue depth/backlog, job counters, occupancy, mean batch size, cache counters + hit rate, throughput, knobs, per-shard breakdowns, latency quantiles (`latency`: queue-wait/exec p50/p99 µs, `null` while empty) |
//! | `ping` | — | `{"ok":true,"verb":"ping","proto":N,"accepted":[..],"uptime_s":..,"version":..,"backend":..,"workers":..}` |
//! | `metrics` | — | `{"ok":true,"verb":"metrics","text":"..."}` — Prometheus text exposition ([`crate::obs::metrics::prometheus`]) |
//! | `shutdown` | — | acknowledges, then stops accepting and drains the service |
//!
//! A `submit` may additionally carry `trace` — the client-minted
//! [`crate::obs::trace::TraceId`] as exactly 16 hex characters — so the
//! server records its span events under the same id the client uses
//! (absent-or-valid: a malformed value is an error, never ignored).
//!
//! Versioning: requests *may* carry `proto`
//! ([`wire::PROTO_VERSION`]). Absent means the pre-versioning wire and
//! is accepted, as is any version in [`wire::PROTO_ACCEPTED`] (v3 only
//! adds optional fields over v2; v4 only the framed band transport
//! below); anything else is rejected with a protocol error. Clients
//! handshake against the `ping` response's `proto`.
//!
//! Framed band transport (proto ≥ 4, opt-in): a `submit` control line
//! may carry `"band_frame": <count>` *instead of* the `band` array and
//! is then immediately followed by a raw binary frame
//! ([`wire::encode_band_frame`]: little-endian u64 count, then the
//! values as little-endian f64 bit patterns). The server consumes the
//! frame by its own length prefix — bounded by
//! [`wire::MAX_FRAME_VALUES`] — and cross-checks the declared count, so
//! a desynchronized client gets an error *response* while the stream
//! stays aligned on the next line. Every control and response line
//! stays JSON; only the bulk payload changes representation.
//!
//! Every response carries `"ok"`. Job-level failures additionally carry
//! the typed taxonomy (`kind` + `retryable` — see
//! [`crate::error::JobError`]), so a remote caller can branch on
//! back-pressure exactly like a local one. Numbers ride Rust's
//! shortest-roundtrip `f64` formatting, so served singular values are
//! **bitwise** what the backend produced (see [`crate::util::json`]).
//!
//! The request/response *vocabulary* — band payload shaping, request
//! rendering, response encode/decode — lives in [`crate::client::wire`],
//! shared verbatim with [`crate::client::RemoteClient`], the example
//! client, and the loopback tests: one schema, both sides.
//!
//! A `submit` blocks its connection until the job completes; concurrency
//! across connections is what feeds the micro-batcher (each connection is
//! handled on its own thread). The canonical caller is
//! [`crate::client::RemoteClient`] (`banded-svd client --remote`).

use crate::client::wire;
use crate::config::ServiceConfig;
use crate::error::{Error, Result};
use crate::obs::trace::TraceId;
use crate::service::Service;
use crate::util::json::Json;
use std::io::{BufRead, BufReader, BufWriter, Write as _};
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn stats_json(service: &Service) -> Json {
    let s = service.stats();
    let cfg = service.config();
    let cache = Json::obj()
        .set("plan_hits", Json::Int(s.cache.plan_hits as i64))
        .set("plan_misses", Json::Int(s.cache.plan_misses as i64))
        .set("merge_hits", Json::Int(s.cache.merge_hits as i64))
        .set("merge_misses", Json::Int(s.cache.merge_misses as i64))
        .set("tune_hits", Json::Int(s.cache.tune_hits as i64))
        .set("tune_misses", Json::Int(s.cache.tune_misses as i64))
        .set("hit_rate", s.cache.hit_rate());
    let shards = Json::Arr(
        s.shards
            .iter()
            .map(|shard| {
                Json::obj()
                    .set("shard", shard.shard)
                    .set("queue_depth", shard.queue_depth)
                    .set("backlog_seconds", shard.backlog_seconds)
                    .set("jobs_completed", Json::Int(shard.jobs_completed as i64))
                    .set("jobs_failed", Json::Int(shard.jobs_failed as i64))
                    .set("batches", Json::Int(shard.batches as i64))
                    .set("launches", Json::Int(shard.launches as i64))
                    .set("tasks", Json::Int(shard.tasks as i64))
                    .set("occupancy", shard.occupancy)
                    .set("busy_seconds", shard.busy_seconds)
                    .set("busy_fraction", shard.busy_fraction)
                    .set("cache_hits", Json::Int(shard.cache_hits as i64))
                    .set("cache_misses", Json::Int(shard.cache_misses as i64))
                    .set("cache_hit_rate", shard.cache_hit_rate())
            })
            .collect(),
    );
    // Latency quantiles from the unified registry. NaN (empty histogram)
    // renders as `null` through the JSON non-finite guard.
    let m = service.metrics();
    let latency = Json::obj()
        .set("queue_wait_p50_us", m.queue_wait.quantile(0.5) / 1e3)
        .set("queue_wait_p99_us", m.queue_wait.quantile(0.99) / 1e3)
        .set("exec_p50_us", m.exec.quantile(0.5) / 1e3)
        .set("exec_p99_us", m.exec.quantile(0.99) / 1e3);
    let stats = Json::obj()
        .set("queue_depth", s.queue_depth)
        .set("backlog_seconds", s.backlog_seconds)
        .set("jobs_submitted", Json::Int(s.jobs_submitted as i64))
        .set("jobs_rejected", Json::Int(s.jobs_rejected as i64))
        .set("jobs_completed", Json::Int(s.jobs_completed as i64))
        .set("jobs_failed", Json::Int(s.jobs_failed as i64))
        .set("batches", Json::Int(s.batches as i64))
        .set("launches", Json::Int(s.launches as i64))
        .set("tasks", Json::Int(s.tasks as i64))
        .set("occupancy", s.occupancy)
        .set("avg_batch_jobs", s.avg_batch_jobs)
        .set("busy_seconds", s.busy_seconds)
        .set("uptime_s", s.uptime.as_secs_f64())
        .set("throughput_jobs_per_s", s.throughput_jobs_per_s)
        .set("cache", cache)
        .set("shards", shards)
        .set("backend", cfg.backend.name())
        .set("workers", cfg.workers)
        .set("routing", cfg.routing.name())
        .set("max_coresident", cfg.batch.max_coresident)
        .set("window_us", Json::Int(cfg.window.as_micros() as i64))
        .set("capacity", cfg.params.capacity())
        .set("latency", latency);
    Json::obj()
        .set("ok", true)
        .set("verb", "stats")
        .set("proto", wire::PROTO_VERSION as usize)
        .set("stats", stats)
}

/// Handle one request line — the in-process form, with no framed
/// transport underneath (a line declaring `band_frame` is therefore an
/// error here). Returns the response and whether the server should shut
/// down after sending it.
fn respond(service: &Service, line: &str) -> (Json, bool) {
    match Json::parse(line) {
        Ok(request) => respond_parsed(service, &request, None),
        Err(e) => (wire::error_json(format!("bad request: {e}")), false),
    }
}

/// Dispatch one parsed request. `frame` is the binary band payload the
/// connection handler consumed from the stream when the control line
/// declared `band_frame` (v4 framed transport), `None` otherwise.
fn respond_parsed(service: &Service, request: &Json, frame: Option<Vec<f64>>) -> (Json, bool) {
    // Version gate: an absent `proto` is the pre-versioning wire and is
    // accepted, as is any version in `wire::PROTO_ACCEPTED` (v3 only
    // adds optional fields over v2, and v4 only the opt-in framed band
    // transport, so old clients remain valid); anything else is a
    // client this server does not speak to (see the compatibility rule
    // in `docs/client.md`).
    if let Some(proto) = request.get("proto") {
        let accepted = proto
            .as_usize()
            .is_some_and(|v| wire::PROTO_ACCEPTED.contains(&(v as u32)));
        if !accepted {
            let msg = format!(
                "protocol version mismatch: request carries proto {}, server speaks {} \
                 (accepts {:?})",
                proto.render(),
                wire::PROTO_VERSION,
                wire::PROTO_ACCEPTED
            );
            return (wire::error_json(msg), false);
        }
    }
    match request.get("verb").and_then(Json::as_str) {
        Some("ping") => (ping_json(service), false),
        Some("stats") => (stats_json(service), false),
        Some("metrics") => (metrics_json(service), false),
        Some("shutdown") => (Json::obj().set("ok", true).set("verb", "shutdown"), true),
        Some("submit") => (handle_submit(service, request, frame), false),
        Some(other) => (wire::error_json(format!("unknown verb {other:?}")), false),
        None => (wire::error_json("missing \"verb\""), false),
    }
}

/// The extended `ping` response: liveness plus provenance — protocol
/// versions (spoken and accepted), uptime, crate version, backend kind,
/// and worker count — so a client can identify what it reached before
/// submitting anything.
fn ping_json(service: &Service) -> Json {
    let cfg = service.config();
    let accepted =
        Json::Arr(wire::PROTO_ACCEPTED.iter().map(|&v| Json::Int(v as i64)).collect());
    Json::obj()
        .set("ok", true)
        .set("verb", "ping")
        .set("proto", wire::PROTO_VERSION as usize)
        .set("accepted", accepted)
        .set("uptime_s", service.uptime().as_secs_f64())
        .set("version", env!("CARGO_PKG_VERSION"))
        .set("backend", cfg.backend.name())
        .set("workers", cfg.workers)
}

/// The `metrics` verb: the Prometheus text exposition riding one JSON
/// response (`text`), so the same single-line framing serves scrapes.
fn metrics_json(service: &Service) -> Json {
    let text = crate::obs::metrics::prometheus(&service.stats(), service.metrics());
    Json::obj()
        .set("ok", true)
        .set("verb", "metrics")
        .set("proto", wire::PROTO_VERSION as usize)
        .set("text", text)
}

/// Render an [`Error`] as the wire error response: job-level failures
/// carry their taxonomy, everything else is a plain protocol error.
fn error_response(e: &Error) -> Json {
    match e.as_job() {
        Some(job) => wire::job_error_json(job),
        None => wire::error_json(e.to_string()),
    }
}

fn handle_submit(service: &Service, request: &Json, frame: Option<Vec<f64>>) -> Json {
    let field_usize = |key: &str| request.get(key).and_then(Json::as_usize);
    let (Some(n), Some(bw)) = (field_usize("n"), field_usize("bw")) else {
        return wire::error_json("submit needs integer \"n\" and \"bw\"");
    };
    let precision = request.get("precision").and_then(Json::as_str).unwrap_or("fp64");
    // Optional fields are absent-or-valid: a present-but-malformed value
    // is an error, never silently the default (a client must not believe
    // a deadline or priority class was enforced when it was dropped).
    let priority: u8 = match request.get("priority") {
        None => 0,
        Some(v) => match v.as_usize().and_then(|p| u8::try_from(p).ok()) {
            Some(p) => p,
            None => return wire::error_json("priority must be an integer in 0..=255"),
        },
    };
    let deadline = match request.get("deadline_ms") {
        None => None,
        Some(v) => match v.as_usize() {
            Some(ms) => Some(Duration::from_millis(ms as u64)),
            None => return wire::error_json("deadline_ms must be a non-negative integer"),
        },
    };
    // Singular-vector panels (proto ≥ 3). Absent means false — the v2
    // wire never carried the field.
    let vectors = match request.get("vectors") {
        None => false,
        Some(v) => match v.as_bool() {
            Some(b) => b,
            None => return wire::error_json("vectors must be a boolean"),
        },
    };
    // Client-minted trace id (see `crate::obs::trace`): exactly 16 hex
    // characters when present. Same absent-or-valid rule.
    let trace = match request.get("trace") {
        None => None,
        Some(v) => match v.as_str().and_then(TraceId::parse_hex) {
            Some(t) => Some(t),
            None => return wire::error_json("trace must be exactly 16 hex characters"),
        },
    };
    // Identity rides the request for quota accounting; same
    // absent-or-valid rule as the fields above.
    let identity = |key: &str| match request.get(key) {
        None => Ok(None),
        Some(v) => match v.as_str() {
            Some(s) => Ok(Some(s.to_string())),
            None => Err(wire::error_json(format!("{key} must be a string"))),
        },
    };
    let client_id = match identity("client_id") {
        Ok(v) => v,
        Err(e) => return e,
    };
    let quota_class = match identity("quota_class") {
        Ok(v) => v,
        Err(e) => return e,
    };
    // The band payload arrives inline (`band` array) or — proto ≥ 4 —
    // as the binary frame the connection handler already consumed from
    // the stream (`band_frame` declares its value count).
    let declared = match request.get("band_frame") {
        None => None,
        Some(v) => match v.as_usize() {
            Some(count) => Some(count),
            None => return wire::error_json("band_frame must be a non-negative integer"),
        },
    };
    let values: Vec<f64> = match (request.get("band"), declared, frame) {
        (Some(_), Some(_), _) => {
            return wire::error_json("submit carries both \"band\" and \"band_frame\"");
        }
        (Some(band), None, _) => {
            let Some(band) = band.as_array() else {
                return wire::error_json("submit needs a \"band\" array");
            };
            let mut values = Vec::with_capacity(band.len());
            for v in band {
                match v.as_f64() {
                    Some(x) => values.push(x),
                    None => return wire::error_json("band values must be numbers"),
                }
            }
            values
        }
        (None, Some(count), Some(values)) => {
            // The frame was read by its own length prefix; a control
            // line disagreeing with it is a desynchronized client, and
            // the framed transport is a v4 capability the request must
            // have claimed.
            if values.len() != count {
                return wire::error_json(format!(
                    "band frame carries {} values; the control line declared {count}",
                    values.len()
                ));
            }
            let proto = request.get("proto").and_then(Json::as_usize);
            if !proto.is_some_and(|v| v >= 4) {
                return wire::error_json("band_frame needs proto >= 4 on the request line");
            }
            values
        }
        (None, Some(_), None) => {
            return wire::error_json("band_frame requires the framed TCP transport");
        }
        (None, None, _) => return wire::error_json("submit needs a \"band\" array"),
    };
    let tw = service.config().params.effective_tw(bw);
    let input = match wire::band_from_values(n, bw, tw, precision, &values) {
        Ok(input) => input,
        Err(e) => return error_response(&e),
    };
    let outcome = service
        .submit_traced(
            client_id.as_deref(),
            quota_class.as_deref(),
            trace,
            input,
            priority,
            deadline,
            vectors,
        )
        .and_then(|ticket| ticket.wait().map_err(Error::Job));
    match outcome {
        Ok(result) => wire::result_json(&result),
        Err(e) => error_response(&e),
    }
}

/// The TCP server: a bound listener plus the service it fronts.
pub struct Server {
    listener: TcpListener,
    service: Arc<Service>,
    stop: Arc<AtomicBool>,
}

impl Server {
    /// Start the service and bind the listener (use port 0 for an
    /// ephemeral port; read it back with [`Server::local_addr`]).
    pub fn bind(cfg: ServiceConfig, addr: &str) -> Result<Self> {
        let service = Arc::new(Service::start(cfg)?);
        let listener = TcpListener::bind(addr).map_err(Error::Io)?;
        Ok(Self { listener, service, stop: Arc::new(AtomicBool::new(false)) })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.listener.local_addr().expect("bound listener has an address")
    }

    /// The fronted service (for in-process submission or stats alongside
    /// the TCP surface).
    pub fn service(&self) -> &Arc<Service> {
        &self.service
    }

    /// Accept and serve connections until a `shutdown` verb arrives, then
    /// drain the service and return. Each connection runs on its own
    /// thread; a thread dies with its connection. Requests already being
    /// answered when the shutdown verb lands still get their responses:
    /// the drain waits for every in-flight request to finish writing
    /// (idle connections — blocked reading, not answering — don't hold
    /// shutdown up).
    pub fn run(self) -> Result<()> {
        let addr = self.local_addr();
        let inflight = Arc::new(AtomicUsize::new(0));
        for stream in self.listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            let stream = match stream {
                Ok(s) => s,
                Err(_) => continue,
            };
            let service = Arc::clone(&self.service);
            let stop = Arc::clone(&self.stop);
            let inflight = Arc::clone(&inflight);
            let _ = std::thread::Builder::new().name("bsvd-serve-conn".into()).spawn(move || {
                handle_connection(stream, &service, &stop, &inflight, addr);
            });
        }
        self.service.shutdown();
        while inflight.load(Ordering::SeqCst) > 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
        Ok(())
    }
}

/// Where the shutdown handler connects to wake the accept loop: a
/// wildcard bind (`0.0.0.0` / `::`) is not a connectable destination on
/// every platform, so route the nudge through the loopback of the same
/// family instead.
fn nudge_addr(addr: SocketAddr) -> SocketAddr {
    if addr.ip().is_unspecified() {
        let ip = if addr.is_ipv4() {
            IpAddr::V4(Ipv4Addr::LOCALHOST)
        } else {
            IpAddr::V6(Ipv6Addr::LOCALHOST)
        };
        SocketAddr::new(ip, addr.port())
    } else {
        addr
    }
}

/// Longest request line the server will buffer. Generous for real
/// payloads (an n = 4096, bw = 128 f64 band is ~10 MiB of JSON) while
/// bounding what one connection can make the server hold in memory.
const MAX_LINE_BYTES: u64 = 64 * 1024 * 1024;

fn handle_connection(
    stream: TcpStream,
    service: &Service,
    stop: &AtomicBool,
    inflight: &AtomicUsize,
    addr: SocketAddr,
) {
    let mut reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(_) => return,
    };
    let mut writer = BufWriter::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    loop {
        buf.clear();
        let read = match (&mut reader).take(MAX_LINE_BYTES).read_until(b'\n', &mut buf) {
            Ok(0) => break, // EOF
            Ok(read) => read as u64,
            Err(_) => break,
        };
        if read == MAX_LINE_BYTES && buf.last() != Some(&b'\n') {
            // The line never ended within the budget; answer once and
            // drop the connection rather than buffering without bound.
            let oversized =
                wire::error_json(format!("request line exceeds {MAX_LINE_BYTES} bytes"));
            let _ = writeln!(writer, "{}", oversized.render());
            let _ = writer.flush();
            break;
        }
        let line = match std::str::from_utf8(&buf) {
            Ok(s) => s.trim(),
            Err(_) => {
                let _ = writeln!(writer, "{}", wire::error_json("request is not UTF-8").render());
                let _ = writer.flush();
                break;
            }
        };
        if line.is_empty() {
            continue;
        }
        // Parse the control line before dispatching: a framed submit
        // (v4) declares its binary band payload there, and the frame
        // must be consumed off the stream either way.
        let parsed = Json::parse(line);
        let frame = match &parsed {
            Ok(request) if request.get("band_frame").is_some() => {
                match wire::read_band_frame(&mut reader) {
                    Ok(values) => Some(values),
                    Err(e) => {
                        // Cap exceeded or the stream died mid-frame: the
                        // byte stream can no longer be trusted to align
                        // on a next line, so answer once and drop the
                        // connection (like an oversized line).
                        let response = wire::error_json(format!("bad band frame: {e}"));
                        let _ = writeln!(writer, "{}", response.render());
                        let _ = writer.flush();
                        break;
                    }
                }
            }
            _ => None,
        };
        inflight.fetch_add(1, Ordering::SeqCst);
        let (response, shutdown) = match &parsed {
            Ok(request) => respond_parsed(service, request, frame),
            Err(e) => (wire::error_json(format!("bad request: {e}")), false),
        };
        let written = writeln!(writer, "{}", response.render()).is_ok() && writer.flush().is_ok();
        inflight.fetch_sub(1, Ordering::SeqCst);
        if !written {
            break;
        }
        if shutdown {
            stop.store(true, Ordering::SeqCst);
            // Nudge the accept loop awake so it observes the flag.
            let _ = TcpStream::connect(nudge_addr(addr));
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::SequentialBackend;
    use crate::client::wire::submit_request;
    use crate::config::{BackendKind, BatchConfig, PackingPolicy, ShardRouting, TuneParams};
    use crate::generate::random_banded;
    use crate::pipeline::banded_singular_values_with;
    use crate::util::rng::Xoshiro256;

    fn cfg() -> ServiceConfig {
        ServiceConfig {
            params: TuneParams { tpb: 32, tw: 4, max_blocks: 16 },
            batch: BatchConfig { max_coresident: 4, policy: PackingPolicy::RoundRobin },
            backend: BackendKind::Sequential,
            threads: 1,
            window: Duration::from_micros(100),
            queue_cap: 32,
            backlog_cap_s: 1e6,
            cache_cap: 16,
            arch: "H100",
            workers: 1,
            routing: ShardRouting::LeastLoaded,
            quota_pending_cap: 0,
            vectors_cap_n: crate::config::DEFAULT_VECTORS_CAP_N,
        }
    }

    #[test]
    fn shutdown_nudge_routes_wildcard_binds_through_loopback() {
        let v4: SocketAddr = "0.0.0.0:7070".parse().unwrap();
        assert_eq!(nudge_addr(v4), "127.0.0.1:7070".parse().unwrap());
        let v6: SocketAddr = "[::]:7070".parse().unwrap();
        assert_eq!(nudge_addr(v6), "[::1]:7070".parse().unwrap());
        let concrete: SocketAddr = "192.0.2.1:9".parse().unwrap();
        assert_eq!(nudge_addr(concrete), concrete);
    }

    #[test]
    fn respond_handles_the_verb_set_in_process() {
        let service = Service::start(cfg()).unwrap();
        let (pong, stop) = respond(&service, "{\"verb\":\"ping\"}");
        assert_eq!(pong.get("ok").and_then(Json::as_bool), Some(true));
        assert!(!stop);
        let (stats, _) = respond(&service, "{\"verb\":\"stats\"}");
        assert_eq!(stats.get("ok").and_then(Json::as_bool), Some(true));
        assert!(stats.get("stats").and_then(|s| s.get("backend")).is_some());
        let (_, stop) = respond(&service, "{\"verb\":\"shutdown\"}");
        assert!(stop);
        let (err, _) = respond(&service, "{\"verb\":\"bogus\"}");
        assert_eq!(err.get("ok").and_then(Json::as_bool), Some(false));
        let (err, _) = respond(&service, "not json");
        assert_eq!(err.get("ok").and_then(Json::as_bool), Some(false));
        let (err, _) = respond(&service, "{\"n\":4}");
        assert!(err.get("error").unwrap().as_str().unwrap().contains("verb"));
    }

    #[test]
    fn ping_carries_the_protocol_version() {
        let service = Service::start(cfg()).unwrap();
        let (pong, _) = respond(&service, "{\"verb\":\"ping\"}");
        assert_eq!(
            pong.get("proto").and_then(Json::as_usize),
            Some(wire::PROTO_VERSION as usize),
            "{}",
            pong.render()
        );
    }

    #[test]
    fn ping_reports_uptime_and_build_provenance() {
        let service = Service::start(cfg()).unwrap();
        let (pong, _) = respond(&service, "{\"verb\":\"ping\"}");
        assert!(pong.get("uptime_s").and_then(Json::as_f64).unwrap() >= 0.0);
        assert_eq!(
            pong.get("version").and_then(Json::as_str),
            Some(env!("CARGO_PKG_VERSION"))
        );
        assert_eq!(pong.get("backend").and_then(Json::as_str), Some("sequential"));
        assert_eq!(pong.get("workers").and_then(Json::as_usize), Some(1));
        let accepted: Vec<usize> = pong
            .get("accepted")
            .and_then(Json::as_array)
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        for proto in wire::PROTO_ACCEPTED {
            assert!(accepted.contains(&(proto as usize)), "{accepted:?}");
        }
    }

    #[test]
    fn metrics_verb_serves_prometheus_text() {
        let service = Service::start(cfg()).unwrap();
        let (r, stop) = respond(&service, "{\"verb\":\"metrics\"}");
        assert!(!stop);
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true), "{}", r.render());
        let text = r.get("text").and_then(Json::as_str).unwrap();
        assert!(text.contains("bsvd_jobs_submitted_total"), "{text}");
        assert!(text.contains("bsvd_queue_wait_seconds_count"), "{text}");
        assert!(text.contains("bsvd_exec_seconds_bucket{le=\"+Inf\"}"), "{text}");
    }

    #[test]
    fn stats_reports_latency_quantiles_null_while_idle() {
        let service = Service::start(cfg()).unwrap();
        let (response, _) = respond(&service, "{\"verb\":\"stats\"}");
        let latency = response.get("stats").and_then(|s| s.get("latency")).unwrap();
        // No job has flushed: every quantile is NaN, encoded as null.
        assert_eq!(latency.get("queue_wait_p50_us"), Some(&Json::Null));
        assert_eq!(latency.get("exec_p99_us"), Some(&Json::Null));
    }

    #[test]
    fn mismatched_proto_is_rejected_but_absent_proto_is_legacy() {
        let service = Service::start(cfg()).unwrap();
        // Future (or garbage) versions are refused outright...
        for bad in [
            "{\"verb\":\"ping\",\"proto\":99}",
            "{\"verb\":\"ping\",\"proto\":1}",
            "{\"verb\":\"ping\",\"proto\":\"v2\"}",
        ] {
            let (r, stop) = respond(&service, bad);
            assert_eq!(r.get("ok").and_then(Json::as_bool), Some(false), "{bad}");
            assert!(r.get("error").unwrap().as_str().unwrap().contains("protocol version"));
            assert!(!stop);
        }
        // ...every accepted version and the pre-versioning wire work
        // (v2 lines stay valid: v3 only added optional fields).
        for accepted in wire::PROTO_ACCEPTED {
            let good = format!("{{\"verb\":\"ping\",\"proto\":{accepted}}}");
            let (r, _) = respond(&service, &good);
            assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true), "{good}");
        }
        let (r, _) = respond(&service, "{\"verb\":\"ping\"}");
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
    }

    #[test]
    fn stats_reports_per_shard_breakdowns() {
        let service = Service::start(ServiceConfig { workers: 2, ..cfg() }).unwrap();
        let (response, _) = respond(&service, "{\"verb\":\"stats\"}");
        let stats = response.get("stats").unwrap();
        assert_eq!(stats.get("workers").and_then(Json::as_usize), Some(2));
        assert_eq!(stats.get("routing").and_then(Json::as_str), Some("least-loaded"));
        let shards = stats.get("shards").and_then(Json::as_array).unwrap();
        assert_eq!(shards.len(), 2);
        for (i, shard) in shards.iter().enumerate() {
            assert_eq!(shard.get("shard").and_then(Json::as_usize), Some(i));
            assert_eq!(shard.get("jobs_completed").and_then(|v| v.as_i64()), Some(0));
        }
    }

    #[test]
    fn submit_verb_rejects_malformed_identity_fields() {
        let service = Service::start(cfg()).unwrap();
        for bad in [
            "{\"verb\":\"submit\",\"n\":16,\"bw\":2,\"band\":[1.0],\"client_id\":7}",
            "{\"verb\":\"submit\",\"n\":16,\"bw\":2,\"band\":[1.0],\"quota_class\":[]}",
        ] {
            let (r, _) = respond(&service, bad);
            assert_eq!(r.get("ok").and_then(Json::as_bool), Some(false), "{bad}");
            assert!(r.get("error").unwrap().as_str().unwrap().contains("must be a string"));
        }
    }

    #[test]
    fn submit_verb_matches_direct_pipeline_bitwise_in_process() {
        let cfg = cfg();
        let service = Service::start(cfg.clone()).unwrap();
        let mut rng = Xoshiro256::seed_from_u64(2);
        let (n, bw) = (36, 5);
        let a = random_banded::<f64>(n, bw, cfg.params.effective_tw(bw), &mut rng);
        let direct = banded_singular_values_with(&SequentialBackend::new(), &a, bw, &cfg.params)
            .unwrap();
        let (response, _) = respond(&service, &submit_request(&a, bw, 0));
        assert_eq!(response.get("ok").and_then(Json::as_bool), Some(true), "{response:?}");
        let sv: Vec<f64> = response
            .get("sv")
            .unwrap()
            .as_array()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        assert_eq!(sv.len(), direct.len());
        for (got, want) in sv.iter().zip(direct.iter()) {
            assert_eq!(got.to_bits(), want.to_bits());
        }
        let metrics = response.get("metrics").unwrap();
        assert!(metrics.get("launches").unwrap().as_i64().unwrap() > 0);
    }

    #[test]
    fn submit_verb_serves_vector_panels_bitwise() {
        use crate::batch::BatchInput;
        use crate::client::wire::{submit_request_for_input, RequestIdentity};
        use crate::pipeline::banded_svd_vectors_with;
        let cfg = cfg();
        let service = Service::start(cfg.clone()).unwrap();
        let mut rng = Xoshiro256::seed_from_u64(7);
        let (n, bw) = (40, 5);
        let a = random_banded::<f64>(n, bw, cfg.params.effective_tw(bw), &mut rng);
        let direct =
            banded_svd_vectors_with(&SequentialBackend::new(), &a, bw, &cfg.params).unwrap();
        let line = submit_request_for_input(
            &BatchInput::from((a, bw)),
            0,
            None,
            RequestIdentity::default(),
            true,
            None,
        );
        let (response, _) = respond(&service, &line);
        assert_eq!(response.get("ok").and_then(Json::as_bool), Some(true), "{response:?}");
        let panel = |key: &str| -> Vec<f64> {
            response
                .get(key)
                .and_then(Json::as_array)
                .unwrap_or_else(|| panic!("response missing {key}"))
                .iter()
                .map(|v| v.as_f64().unwrap())
                .collect()
        };
        let (u, vt) = (panel("u"), panel("vt"));
        assert_eq!(u.len(), n * n);
        assert_eq!(vt.len(), n * n);
        for (got, want) in u.iter().zip(direct.u.data.iter()) {
            assert_eq!(got.to_bits(), want.to_bits());
        }
        for (got, want) in vt.iter().zip(direct.vt.data.iter()) {
            assert_eq!(got.to_bits(), want.to_bits());
        }
        // The typed footprint rejection rides the wire taxonomy.
        let small = Service::start(ServiceConfig { vectors_cap_n: 16, ..cfg }).unwrap();
        let (r, _) = respond(&small, &line);
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(r.get("kind").and_then(Json::as_str), Some("too-large"));
        assert_eq!(r.get("retryable").and_then(Json::as_bool), Some(false));
    }

    #[test]
    fn framed_submit_matches_the_inline_band_bitwise() {
        use crate::batch::BatchInput;
        use crate::client::wire::{read_band_frame, submit_request_framed, RequestIdentity};
        let cfg = cfg();
        let service = Service::start(cfg.clone()).unwrap();
        let mut rng = Xoshiro256::seed_from_u64(11);
        let (n, bw) = (32, 4);
        let a = random_banded::<f64>(n, bw, cfg.params.effective_tw(bw), &mut rng);
        let inline_line = submit_request(&a, bw, 0);
        let (inline, _) = respond(&service, &inline_line);
        assert_eq!(inline.get("ok").and_then(Json::as_bool), Some(true), "{inline:?}");
        let (line, frame) = submit_request_framed(
            &BatchInput::from((a, bw)),
            0,
            None,
            RequestIdentity::default(),
            false,
            None,
        );
        let values = read_band_frame(&mut frame.as_slice()).unwrap();
        let request = Json::parse(&line).unwrap();
        let (framed, stop) = respond_parsed(&service, &request, Some(values));
        assert!(!stop);
        assert_eq!(framed.get("ok").and_then(Json::as_bool), Some(true), "{framed:?}");
        let sv_of = |r: &Json| -> Vec<u64> {
            r.get("sv")
                .and_then(Json::as_array)
                .unwrap()
                .iter()
                .map(|v| v.as_f64().unwrap().to_bits())
                .collect()
        };
        assert_eq!(sv_of(&framed), sv_of(&inline));
    }

    #[test]
    fn framed_submit_validates_count_proto_and_transport() {
        let service = Service::start(cfg()).unwrap();
        let base = "\"verb\":\"submit\",\"n\":16,\"bw\":2,\"band_frame\":31";
        let values = Some(vec![0.5; 31]);
        // The frame's own prefix disagreeing with the control line is a
        // desynchronized client.
        let short = Json::parse(&format!("{{{base},\"proto\":4}}")).unwrap();
        let (r, _) = respond_parsed(&service, &short, Some(vec![0.5; 30]));
        assert!(r.get("error").unwrap().as_str().unwrap().contains("declared"), "{r:?}");
        // The framed transport is a v4 capability: an old (or absent)
        // proto claim cannot use it.
        for line in [format!("{{{base}}}"), format!("{{{base},\"proto\":3}}")] {
            let request = Json::parse(&line).unwrap();
            let (r, _) = respond_parsed(&service, &request, values.clone());
            assert!(r.get("error").unwrap().as_str().unwrap().contains("proto"), "{r:?}");
        }
        // One payload per submit: inline band and a frame are exclusive.
        let line = "{\"verb\":\"submit\",\"n\":16,\"bw\":2,\"band\":[1.0],\"band_frame\":1}";
        let both = Json::parse(line).unwrap();
        let (r, _) = respond_parsed(&service, &both, Some(vec![1.0]));
        assert!(r.get("error").unwrap().as_str().unwrap().contains("both"), "{r:?}");
        // A band_frame line without the framed transport underneath
        // (the in-process respond path) cannot be served.
        let (r, _) = respond(&service, &format!("{{{base},\"proto\":4}}"));
        assert!(r.get("error").unwrap().as_str().unwrap().contains("transport"), "{r:?}");
        // A well-formed framed submit still validates shape: 31 values
        // for n=16, bw=2 is the wrong band length.
        let (r, _) = respond_parsed(&service, &short, Some(vec![0.5; 31]));
        assert!(r.get("error").unwrap().as_str().unwrap().contains("values"), "{r:?}");
    }

    #[test]
    fn submit_verb_rejects_malformed_requests() {
        let service = Service::start(cfg()).unwrap();
        for bad in [
            "{\"verb\":\"submit\"}",
            "{\"verb\":\"submit\",\"n\":16,\"bw\":2}",
            "{\"verb\":\"submit\",\"n\":16,\"bw\":2,\"band\":[1,\"x\"]}",
            "{\"verb\":\"submit\",\"n\":16,\"bw\":2,\"band\":[1.0],\"priority\":900}",
            "{\"verb\":\"submit\",\"n\":16,\"bw\":2,\"band\":[1.0],\"priority\":-1}",
            "{\"verb\":\"submit\",\"n\":16,\"bw\":2,\"band\":[1.0],\"priority\":\"hi\"}",
            "{\"verb\":\"submit\",\"n\":16,\"bw\":2,\"band\":[1.0],\"deadline_ms\":\"100\"}",
            "{\"verb\":\"submit\",\"n\":16,\"bw\":2,\"band\":[1.0],\"vectors\":\"yes\"}",
            "{\"verb\":\"submit\",\"n\":16,\"bw\":2,\"band\":[1.0],\"trace\":\"xyz\"}",
            "{\"verb\":\"submit\",\"n\":16,\"bw\":2,\"band\":[1.0],\"trace\":7}",
        ] {
            let (r, _) = respond(&service, bad);
            assert_eq!(r.get("ok").and_then(Json::as_bool), Some(false), "{bad}");
        }
    }

    #[test]
    fn expired_deadline_carries_the_taxonomy_over_the_wire() {
        // Deadline 0: the job expires in the queue; the error response
        // must carry the typed kind so remote callers classify it.
        let service =
            Service::start(ServiceConfig { window: Duration::from_millis(20), ..cfg() }).unwrap();
        let mut rng = Xoshiro256::seed_from_u64(5);
        let a = random_banded::<f64>(24, 3, 2, &mut rng);
        let line = format!(
            "{{\"verb\":\"submit\",\"n\":24,\"bw\":3,\"deadline_ms\":0,\"band\":{}}}",
            Json::Arr(
                crate::client::wire::band_values(&a, 3).into_iter().map(Json::Num).collect()
            )
            .render()
        );
        let (response, _) = respond(&service, &line);
        assert_eq!(response.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(
            response.get("kind").and_then(Json::as_str),
            Some("deadline-expired"),
            "{}",
            response.render()
        );
        assert_eq!(response.get("retryable").and_then(Json::as_bool), Some(false));
    }
}
