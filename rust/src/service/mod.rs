//! The reduction service — a long-running subsystem that serves a
//! *stream* of banded-reduction jobs.
//!
//! The batch engine (PR 1–3) answers "reduce these K problems now"; real
//! serving traffic is the harder shape — many small heterogeneous
//! problems arriving one at a time, each wanting an answer soon
//! (Abdelfattah & Fasi, "An Efficient Batch Solver for the SVD on
//! GPUs"). This module closes that gap with four parts behind one
//! in-process handle ([`Service`]) and one TCP front end
//! ([`server::Server`], the `banded-svd serve` subcommand):
//!
//! ```text
//!   submit ──▶ admission (priced by simulate_plan_for     [queue.rs]
//!   (any          under the backend's BackendCostModel)
//!   thread)          │ admit / reject
//!                    ▼
//!              JobQueue — (priority, admission seq) order
//!                    │ flush: size (max_coresident) or
//!                    │        window (BSVD_SERVICE_WINDOW_US)
//!                    ▼
//!              micro-batcher worker                       [batcher.rs]
//!                cached solo plans ── merge_refs ──▶ merged LaunchPlan
//!                    │                    ▲
//!                    │          PlanCache (LRU: plans,    [cache.rs]
//!                    │          merge skeletons, autotune)
//!                    ▼
//!              Box<dyn Backend> ──▶ per-job σ + LaunchMetrics
//! ```
//!
//! Everything upstream of the backend is *plan algebra*: lowering and
//! merging are deterministic, so the [`PlanCache`] amortizes them across
//! the repeated shapes serving traffic is dominated by, and a served
//! result is **bitwise identical** to a direct
//! [`crate::pipeline::banded_singular_values_with`] call on the same
//! backend (merged plans preserve per-problem launch order; the loopback
//! integration test `rust/tests/service_roundtrip.rs` locks this in).
//!
//! See `docs/service.md` for the wire protocol, the knob reference, the
//! cache semantics, and a deployment sketch.

pub mod batcher;
pub mod cache;
pub mod queue;
pub mod server;

pub use cache::{CacheStats, PlanCache, PlanKey};
pub use queue::{Job, JobOutcome, JobResult, JobTicket};
pub use server::Server;

use crate::backend::{cost_model_for, for_kind};
use crate::batch::BatchInput;
use crate::config::ServiceConfig;
use crate::error::{Error, Result};
use crate::simulator::hw::GpuArch;
use crate::simulator::model::BackendCostModel;
use crate::simulator::{arch_by_name, simulate_plan_for};
use batcher::WorkerStats;
use queue::JobQueue;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Snapshot of the service's operational state (the `stats` verb).
#[derive(Clone, Debug)]
pub struct ServiceStats {
    /// Jobs currently queued (admitted, not yet flushed).
    pub queue_depth: usize,
    /// Modeled seconds of queued work (the admission price).
    pub backlog_seconds: f64,
    pub jobs_submitted: u64,
    pub jobs_rejected: u64,
    pub jobs_completed: u64,
    /// Jobs that got an error outcome: backend failures plus deadlines
    /// expired in the queue. `jobs_submitted` always equals
    /// `jobs_completed + jobs_failed + queue_depth` (+ jobs currently in
    /// a flush).
    pub jobs_failed: u64,
    /// Merged-plan flushes executed.
    pub batches: u64,
    /// Shared launches executed across all flushes.
    pub launches: u64,
    /// Cycle-tasks executed across all flushes.
    pub tasks: u64,
    /// Mean launch occupancy: tasks per offered capacity slot.
    pub occupancy: f64,
    /// Mean jobs per flush (the dynamic batching actually achieved).
    pub avg_batch_jobs: f64,
    /// Plan/merge/autotune cache counters.
    pub cache: CacheStats,
    /// Wall time the worker spent executing merged plans.
    pub busy_seconds: f64,
    pub uptime: Duration,
    /// Completed jobs per second of service uptime.
    pub throughput_jobs_per_s: f64,
}

/// The in-process service handle: owns the queue, the plan cache, and
/// the batcher worker thread. Shareable across submitter threads (the
/// TCP server holds it in an `Arc`); submission is non-blocking apart
/// from admission pricing, and results come back per job through a
/// [`JobTicket`].
///
/// # Examples
///
/// ```no_run
/// use banded_svd::prelude::*;
///
/// let service = Service::start(ServiceConfig::default()).unwrap();
/// let mut rng = Xoshiro256::seed_from_u64(0);
/// let a = random_banded::<f64>(256, 16, 16, &mut rng);
/// let result = service.submit_wait(BatchInput::from((a, 16)), 0, None).unwrap();
/// println!("σ_max = {} (co-scheduled with {} jobs)", result.sv[0], result.batch_jobs - 1);
/// println!("{:#?}", service.stats());
/// ```
pub struct Service {
    cfg: ServiceConfig,
    arch: GpuArch,
    cost_model: BackendCostModel,
    queue: Arc<JobQueue>,
    cache: PlanCache,
    worker_stats: Arc<WorkerStats>,
    worker: Mutex<Option<std::thread::JoinHandle<()>>>,
    next_id: AtomicU64,
    submitted: AtomicU64,
    rejected: AtomicU64,
    started: Instant,
}

impl Service {
    /// Validate `cfg`, start the batcher worker, and open the queue. The
    /// backend is constructed *on* the worker thread (it never leaves
    /// it); admission pricing uses the kind's cost model
    /// ([`cost_model_for`]) on the submitting side.
    pub fn start(cfg: ServiceConfig) -> Result<Self> {
        cfg.validate()?;
        let arch = arch_by_name(cfg.arch)
            .ok_or_else(|| Error::Config(format!("unknown service arch {:?}", cfg.arch)))?;
        let cost_model = cost_model_for(cfg.backend)?;
        let queue = Arc::new(JobQueue::new(cfg.queue_cap, cfg.backlog_cap_s));
        let cache = PlanCache::new(cfg.cache_cap);
        let worker_stats = Arc::new(WorkerStats::default());
        let worker = {
            let queue = Arc::clone(&queue);
            let cache = cache.clone();
            let stats = Arc::clone(&worker_stats);
            let cfg = cfg.clone();
            std::thread::Builder::new()
                .name("bsvd-service-batcher".into())
                .spawn(move || {
                    let backend = for_kind(cfg.backend, cfg.threads)
                        .expect("backend kind validated by cost_model_for at start");
                    batcher::run(queue, cfg, cache, backend, stats);
                })
                .map_err(Error::Io)?
        };
        Ok(Self {
            cfg,
            arch,
            cost_model,
            queue,
            cache,
            worker_stats,
            worker: Mutex::new(Some(worker)),
            next_id: AtomicU64::new(0),
            submitted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            started: Instant::now(),
        })
    }

    /// Submit one job. Validates the storage, prices the job on the
    /// service cost model, and runs admission; on success the returned
    /// ticket resolves to the job's [`JobResult`].
    pub fn submit(
        &self,
        input: BatchInput,
        priority: u8,
        deadline: Option<Duration>,
    ) -> Result<JobTicket> {
        let admit = || -> Result<JobTicket> {
            input.validate(&self.cfg.params)?;
            let est_seconds = self.price(&input);
            let id = self.next_id.fetch_add(1, Ordering::Relaxed);
            let (tx, rx) = mpsc::channel();
            let deadline = deadline.map(|d| Instant::now() + d);
            self.queue.submit(id, input, priority, deadline, est_seconds, tx)?;
            Ok(JobTicket { id, rx })
        };
        match admit() {
            Ok(ticket) => {
                self.submitted.fetch_add(1, Ordering::Relaxed);
                Ok(ticket)
            }
            Err(e) => {
                self.rejected.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    /// [`Service::submit`] and block for the outcome. Job-level failures
    /// come back as [`Error::Job`] with the typed taxonomy (retryable
    /// admission rejections, expired deadlines, backend errors).
    pub fn submit_wait(
        &self,
        input: BatchInput,
        priority: u8,
        deadline: Option<Duration>,
    ) -> Result<JobResult> {
        self.submit(input, priority, deadline)?.wait().map_err(Error::Job)
    }

    /// Modeled solo cost (seconds) of `input` on the service backend —
    /// the admission price. Uses the cached solo plan, so pricing a
    /// repeated shape is a cache hit, not a lowering.
    pub fn price(&self, input: &BatchInput) -> f64 {
        let key = PlanKey {
            n: input.n(),
            bw: input.bw(),
            es: input.element_bytes(),
            params: self.cfg.params,
        };
        let plan = self.cache.plan_for(key);
        simulate_plan_for(&self.arch, key.es, plan.as_ref(), key.params.tpb, &self.cost_model)
            .seconds
    }

    /// Operational snapshot (queue, batching, cache, throughput).
    pub fn stats(&self) -> ServiceStats {
        let w = &self.worker_stats;
        let completed = w.jobs_completed.load(Ordering::Relaxed);
        let batches = w.batches.load(Ordering::Relaxed);
        let uptime = self.started.elapsed();
        ServiceStats {
            queue_depth: self.queue.depth(),
            backlog_seconds: self.queue.backlog_seconds(),
            jobs_submitted: self.submitted.load(Ordering::Relaxed),
            jobs_rejected: self.rejected.load(Ordering::Relaxed),
            jobs_completed: completed,
            jobs_failed: w.jobs_failed.load(Ordering::Relaxed) + self.queue.expired_jobs(),
            batches,
            launches: w.launches.load(Ordering::Relaxed),
            tasks: w.tasks.load(Ordering::Relaxed),
            occupancy: w.occupancy(),
            avg_batch_jobs: if batches == 0 { 0.0 } else { completed as f64 / batches as f64 },
            cache: self.cache.stats(),
            busy_seconds: w.busy_nanos.load(Ordering::Relaxed) as f64 / 1e9,
            uptime,
            throughput_jobs_per_s: completed as f64 / uptime.as_secs_f64().max(1e-9),
        }
    }

    /// The plan/autotune cache (shared with the worker).
    pub fn plan_cache(&self) -> &PlanCache {
        &self.cache
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// Close the queue and wait for the worker to drain. Idempotent;
    /// also invoked by `Drop`, so an explicit call is only needed to
    /// observe the joined worker before the handle goes away.
    pub fn shutdown(&self) {
        self.queue.close();
        if let Some(handle) = self.worker.lock().unwrap().take() {
            let _ = handle.join();
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::SequentialBackend;
    use crate::config::{BackendKind, BatchConfig, PackingPolicy, TuneParams};
    use crate::generate::random_banded;
    use crate::pipeline::banded_singular_values_with;
    use crate::util::rng::Xoshiro256;

    fn test_cfg() -> ServiceConfig {
        ServiceConfig {
            params: TuneParams { tpb: 32, tw: 4, max_blocks: 24 },
            batch: BatchConfig { max_coresident: 4, policy: PackingPolicy::RoundRobin },
            backend: BackendKind::Sequential,
            threads: 1,
            window: Duration::from_micros(200),
            queue_cap: 64,
            backlog_cap_s: 1e6,
            cache_cap: 32,
            arch: "H100",
        }
    }

    #[test]
    fn served_job_matches_direct_pipeline_bitwise() {
        let cfg = test_cfg();
        let service = Service::start(cfg.clone()).unwrap();
        let mut rng = Xoshiro256::seed_from_u64(3);
        let a = random_banded::<f64>(48, 6, cfg.params.effective_tw(6), &mut rng);
        let direct = banded_singular_values_with(&SequentialBackend::new(), &a, 6, &cfg.params)
            .unwrap();
        let result = service.submit_wait(BatchInput::from((a, 6)), 0, None).unwrap();
        assert_eq!(result.sv, direct);
        assert_eq!(result.n, 48);
        assert_eq!(result.precision, "fp64");
        assert!(result.metrics.launches > 0);
        assert!(result.batch_jobs >= 1);
        service.shutdown();
        let stats = service.stats();
        assert_eq!(stats.jobs_completed, 1);
        assert_eq!(stats.jobs_failed, 0);
        assert!(stats.batches >= 1);
    }

    #[test]
    fn repeated_shapes_hit_the_plan_cache() {
        let service = Service::start(test_cfg()).unwrap();
        let mut rng = Xoshiro256::seed_from_u64(5);
        for _ in 0..4 {
            let a = random_banded::<f64>(40, 5, 4, &mut rng);
            service.submit_wait(BatchInput::from((a, 5)), 0, None).unwrap();
        }
        let stats = service.stats();
        assert!(stats.cache.plan_hits > 0, "{:?}", stats.cache);
        assert!(stats.cache.hit_rate() > 0.0);
        assert_eq!(stats.jobs_completed, 4);
        assert!(stats.throughput_jobs_per_s > 0.0);
    }

    #[test]
    fn invalid_storage_is_rejected_at_admission() {
        use crate::banded::storage::Banded;
        let service = Service::start(test_cfg()).unwrap();
        // kd_sub 1 < tw 4: cannot hold the reduction's fill-in.
        let bad = Banded::<f64>::zeros(32, 9, 1);
        assert!(service.submit(BatchInput::from((bad, 8)), 0, None).is_err());
        assert_eq!(service.stats().jobs_rejected, 1);
        assert_eq!(service.stats().jobs_submitted, 0);
    }

    #[test]
    fn pricing_is_positive_and_cached() {
        let service = Service::start(test_cfg()).unwrap();
        let mut rng = Xoshiro256::seed_from_u64(7);
        let a = random_banded::<f64>(64, 8, 4, &mut rng);
        let input = BatchInput::from((a, 8));
        let p1 = service.price(&input);
        let p2 = service.price(&input);
        assert!(p1 > 0.0);
        assert_eq!(p1, p2);
        assert!(service.plan_cache().stats().plan_hits >= 1);
    }

    #[test]
    fn shutdown_fails_jobs_submitted_after_close() {
        let service = Service::start(test_cfg()).unwrap();
        service.shutdown();
        let mut rng = Xoshiro256::seed_from_u64(9);
        let a = random_banded::<f64>(24, 3, 2, &mut rng);
        assert!(service.submit(BatchInput::from((a, 3)), 0, None).is_err());
        service.shutdown(); // idempotent
    }

    #[test]
    fn expired_deadline_reports_a_typed_deadline_error() {
        // A generous window guarantees the monotone clock advances past
        // the zero deadline before the flush drains the job.
        let cfg = ServiceConfig { window: Duration::from_millis(20), ..test_cfg() };
        let service = Service::start(cfg).unwrap();
        let mut rng = Xoshiro256::seed_from_u64(11);
        let a = random_banded::<f64>(24, 3, 2, &mut rng);
        let err = service
            .submit_wait(BatchInput::from((a, 3)), 0, Some(Duration::ZERO))
            .unwrap_err();
        assert!(err.to_string().contains("deadline"), "{err}");
        assert_eq!(err.as_job().unwrap().kind(), "deadline-expired");
        assert!(!err.is_retryable());
        let stats = service.stats();
        assert_eq!(stats.jobs_failed, 1);
        assert_eq!(stats.jobs_completed, 0);
    }

    #[test]
    fn rejects_unknown_arch_and_fused_backend() {
        let bad_arch = ServiceConfig { arch: "NOPE9000", ..test_cfg() };
        assert!(Service::start(bad_arch).is_err());
        let fused = ServiceConfig { backend: BackendKind::PjrtFused, ..test_cfg() };
        assert!(Service::start(fused).is_err());
    }
}
