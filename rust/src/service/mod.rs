//! The reduction service — a long-running subsystem that serves a
//! *stream* of banded-reduction jobs.
//!
//! The batch engine (PR 1–3) answers "reduce these K problems now"; real
//! serving traffic is the harder shape — many small heterogeneous
//! problems arriving one at a time, each wanting an answer soon
//! (Abdelfattah & Fasi, "An Efficient Batch Solver for the SVD on
//! GPUs"). This module closes that gap with four parts behind one
//! in-process handle ([`Service`]) and one TCP front end
//! ([`server::Server`], the `banded-svd serve` subcommand):
//!
//! ```text
//!   submit ──▶ admission (priced by simulate_plan_for     [queue.rs]
//!   (any          under the backend's BackendCostModel;
//!   thread)        per-client quota via QuotaTracker)
//!                    │ admit / reject
//!                    ▼
//!              Router — least-loaded or size-class        [shard.rs]
//!                    │ picks one of `workers` shards
//!        ┌───────────┴───────────┐
//!        ▼                       ▼
//!   shard 0                 shard N-1
//!   JobQueue — (priority,   JobQueue — strict order
//!     admission seq) order    *within each shard*
//!        │ flush: size (max_coresident) or
//!        │        window (BSVD_SERVICE_WINDOW_US)
//!        ▼                       ▼
//!   micro-batcher worker    micro-batcher worker          [batcher.rs]
//!     cached solo plans ── merge_refs ──▶ merged LaunchPlan
//!        │                  ▲
//!        │     shared PlanCache (LRU: plans,              [cache.rs]
//!        │     merge skeletons, autotune)
//!        ▼                       ▼
//!   Box<dyn Backend>        Box<dyn Backend> ──▶ per-job σ + LaunchMetrics
//! ```
//!
//! Everything upstream of the backend is *plan algebra*: lowering and
//! merging are deterministic, so the [`PlanCache`] amortizes them across
//! the repeated shapes serving traffic is dominated by, and a served
//! result is **bitwise identical** to a direct
//! [`crate::pipeline::banded_singular_values_with`] call on the same
//! backend (merged plans preserve per-problem launch order; the loopback
//! integration test `rust/tests/service_roundtrip.rs` locks this in).
//!
//! See `docs/service.md` for the wire protocol, the knob reference, the
//! cache semantics, and a deployment sketch.

pub mod batcher;
pub mod cache;
pub mod queue;
pub mod server;
pub mod shard;

pub use cache::{CacheStats, PlanCache, PlanKey};
pub use queue::{Job, JobOutcome, JobResult, JobTicket};
pub use server::Server;
pub use shard::ShardStats;

use crate::backend::cost_model_for;
use crate::batch::BatchInput;
use crate::config::ServiceConfig;
use crate::error::{Error, JobError, Result};
use crate::obs::metrics::ServiceMetrics;
use crate::obs::trace::{self, TraceId};
use crate::simulator::hw::GpuArch;
use crate::simulator::model::BackendCostModel;
use crate::simulator::{arch_by_name, simulate_plan_for};
use queue::QuotaTracker;
use shard::{Router, Shard};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Snapshot of the service's operational state (the `stats` verb).
#[derive(Clone, Debug)]
pub struct ServiceStats {
    /// Jobs currently queued (admitted, not yet flushed).
    pub queue_depth: usize,
    /// Modeled seconds of queued work (the admission price).
    pub backlog_seconds: f64,
    pub jobs_submitted: u64,
    pub jobs_rejected: u64,
    pub jobs_completed: u64,
    /// Jobs that got an error outcome: backend failures plus deadlines
    /// expired in the queue. `jobs_submitted` always equals
    /// `jobs_completed + jobs_failed + queue_depth` (+ jobs currently in
    /// a flush).
    pub jobs_failed: u64,
    /// Merged-plan flushes executed.
    pub batches: u64,
    /// Shared launches executed across all flushes.
    pub launches: u64,
    /// Cycle-tasks executed across all flushes.
    pub tasks: u64,
    /// Mean launch occupancy: tasks per offered capacity slot.
    pub occupancy: f64,
    /// Mean jobs per flush (the dynamic batching actually achieved).
    pub avg_batch_jobs: f64,
    /// Plan/merge/autotune cache counters.
    pub cache: CacheStats,
    /// Wall time the worker spent executing merged plans.
    pub busy_seconds: f64,
    pub uptime: Duration,
    /// Completed jobs per second of service uptime.
    pub throughput_jobs_per_s: f64,
    /// Per-shard breakdowns, one entry per batcher worker. The aggregate
    /// fields above are the sums (plus the shared-cache view), so
    /// per-shard counters always reconcile with them exactly.
    pub shards: Vec<ShardStats>,
}

/// The in-process service handle: owns the batcher shards (each a
/// queue + worker thread + backend), the router that spreads jobs over
/// them, and the shared plan cache. Shareable across submitter threads
/// (the TCP server holds it in an `Arc`); submission is non-blocking
/// apart from admission pricing, and results come back per job through
/// a [`JobTicket`].
///
/// # Examples
///
/// ```no_run
/// use banded_svd::prelude::*;
///
/// let service = Service::start(ServiceConfig::default()).unwrap();
/// let mut rng = Xoshiro256::seed_from_u64(0);
/// let a = random_banded::<f64>(256, 16, 16, &mut rng);
/// let result = service.submit_wait(BatchInput::from((a, 16)), 0, None).unwrap();
/// println!("σ_max = {} (co-scheduled with {} jobs)", result.sv[0], result.batch_jobs - 1);
/// println!("{:#?}", service.stats());
/// ```
pub struct Service {
    cfg: ServiceConfig,
    arch: GpuArch,
    cost_model: BackendCostModel,
    shards: Vec<Shard>,
    router: Router,
    cache: PlanCache,
    metrics: Arc<ServiceMetrics>,
    next_id: AtomicU64,
    submitted: AtomicU64,
    rejected: AtomicU64,
    started: Instant,
}

impl Service {
    /// Validate `cfg`, start `cfg.workers` batcher shards, and open
    /// their queues. Each shard's backend is constructed *on* its worker
    /// thread (it never leaves it); admission pricing uses the kind's
    /// cost model ([`cost_model_for`]) on the submitting side.
    pub fn start(cfg: ServiceConfig) -> Result<Self> {
        cfg.validate()?;
        let arch = arch_by_name(cfg.arch)
            .ok_or_else(|| Error::Config(format!("unknown service arch {:?}", cfg.arch)))?;
        let cost_model = cost_model_for(cfg.backend)?;
        let cache = PlanCache::new(cfg.cache_cap);
        let quota = Arc::new(QuotaTracker::new(cfg.quota_pending_cap));
        let metrics = Arc::new(ServiceMetrics::default());
        let shards = (0..cfg.workers)
            .map(|i| {
                Shard::start(i, &cfg, cache.clone(), Arc::clone(&quota), Arc::clone(&metrics))
            })
            .collect::<Result<Vec<Shard>>>()?;
        let router = Router::new(cfg.routing);
        Ok(Self {
            cfg,
            arch,
            cost_model,
            shards,
            router,
            cache,
            metrics,
            next_id: AtomicU64::new(0),
            submitted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            started: Instant::now(),
        })
    }

    /// Submit one anonymous job — [`Service::submit_as`] with no
    /// identity (never counted against a quota) and no vector panels.
    pub fn submit(
        &self,
        input: BatchInput,
        priority: u8,
        deadline: Option<Duration>,
    ) -> Result<JobTicket> {
        self.submit_as(None, None, input, priority, deadline, false)
    }

    /// Submit one job under a client identity. Validates the storage,
    /// prices the job on the service cost model, routes it to a shard,
    /// and runs admission (including the per-client pending quota, keyed
    /// by `quota_class` falling back to `client_id`); on success the
    /// returned ticket resolves to the job's [`JobResult`].
    ///
    /// With `vectors`, the job also accumulates dense singular-vector
    /// panels (`U`, `Vᵀ`) — two n×n f64 factors held and shipped per
    /// job, so admission additionally enforces
    /// [`crate::config::ServiceConfig::vectors_cap_n`]: a vectors
    /// request with `n` above the cap is declined with the terminal
    /// [`JobError::TooLarge`] before it can reach a queue.
    #[allow(clippy::too_many_arguments)]
    pub fn submit_as(
        &self,
        client_id: Option<&str>,
        quota_class: Option<&str>,
        input: BatchInput,
        priority: u8,
        deadline: Option<Duration>,
        vectors: bool,
    ) -> Result<JobTicket> {
        self.submit_traced(client_id, quota_class, None, input, priority, deadline, vectors)
    }

    /// [`Service::submit_as`] carrying an explicit trace id — the server
    /// path, where the client minted the id and sent it over the wire.
    /// With `trace: None` a fresh id is minted when tracing is enabled
    /// ([`crate::obs::trace::enabled`]); when it is off the job carries
    /// the inert `TraceId(0)` and every hook no-ops.
    #[allow(clippy::too_many_arguments)]
    pub fn submit_traced(
        &self,
        client_id: Option<&str>,
        quota_class: Option<&str>,
        trace: Option<TraceId>,
        input: BatchInput,
        priority: u8,
        deadline: Option<Duration>,
        vectors: bool,
    ) -> Result<JobTicket> {
        let quota_key = quota_class.or(client_id);
        let trace_id = trace.unwrap_or_else(|| {
            if trace::enabled() {
                TraceId::mint()
            } else {
                TraceId(0)
            }
        });
        let admit = || -> Result<JobTicket> {
            input.validate(&self.cfg.params)?;
            if vectors && input.n() > self.cfg.vectors_cap_n {
                return Err(Error::Job(JobError::TooLarge {
                    reason: format!(
                        "singular-vector panels for n={} exceed the service cap \
                         (vectors_cap_n={}); submit a values-only job or raise the cap",
                        input.n(),
                        self.cfg.vectors_cap_n
                    ),
                }));
            }
            let est_seconds = self.price(&input);
            let shard_idx = self.router.pick(&self.shards, input.n());
            let shard = &self.shards[shard_idx];
            let id = self.next_id.fetch_add(1, Ordering::Relaxed);
            let (tx, rx) = mpsc::channel();
            let deadline = deadline.map(|d| Instant::now() + d);
            let detail = if trace::enabled() {
                let (n, bw) = (input.n(), input.bw());
                format!("n={n} bw={bw} priority={priority} est_s={est_seconds:.3e}")
            } else {
                String::new()
            };
            shard.queue.submit_for(
                quota_key,
                trace_id,
                id,
                input,
                priority,
                deadline,
                est_seconds,
                vectors,
                tx,
            )?;
            if trace::enabled() {
                let zero = Duration::ZERO;
                trace::event(trace_id, id, "admit", "server", Some(shard_idx), zero, detail);
            }
            Ok(JobTicket { id, rx })
        };
        match admit() {
            Ok(ticket) => {
                self.submitted.fetch_add(1, Ordering::Relaxed);
                Ok(ticket)
            }
            Err(e) => {
                self.rejected.fetch_add(1, Ordering::Relaxed);
                if trace::enabled() {
                    let zero = Duration::ZERO;
                    trace::event(trace_id, 0, "reject", "server", None, zero, e.to_string());
                }
                Err(e)
            }
        }
    }

    /// The unified metrics registry backing this service's latency
    /// histograms (queue wait, merged-flush execution). Shared with every
    /// shard's batcher; see [`crate::obs::metrics`].
    pub fn metrics(&self) -> &ServiceMetrics {
        &self.metrics
    }

    /// [`Service::submit`] and block for the outcome. Job-level failures
    /// come back as [`Error::Job`] with the typed taxonomy (retryable
    /// admission rejections, expired deadlines, backend errors).
    pub fn submit_wait(
        &self,
        input: BatchInput,
        priority: u8,
        deadline: Option<Duration>,
    ) -> Result<JobResult> {
        self.submit(input, priority, deadline)?.wait().map_err(Error::Job)
    }

    /// [`Service::submit_as`] and block for the outcome.
    #[allow(clippy::too_many_arguments)]
    pub fn submit_wait_as(
        &self,
        client_id: Option<&str>,
        quota_class: Option<&str>,
        input: BatchInput,
        priority: u8,
        deadline: Option<Duration>,
        vectors: bool,
    ) -> Result<JobResult> {
        self.submit_as(client_id, quota_class, input, priority, deadline, vectors)?
            .wait()
            .map_err(Error::Job)
    }

    /// Modeled solo cost (seconds) of `input` on the service backend —
    /// the admission price. Uses the cached solo plan, so pricing a
    /// repeated shape is a cache hit, not a lowering.
    pub fn price(&self, input: &BatchInput) -> f64 {
        let key = PlanKey {
            n: input.n(),
            bw: input.bw(),
            es: input.element_bytes(),
            params: self.cfg.params,
        };
        let plan = self.cache.plan_for(key);
        simulate_plan_for(&self.arch, key.es, plan.as_ref(), key.params.tpb, &self.cost_model)
            .seconds
    }

    /// Time since [`Service::start`] returned.
    pub fn uptime(&self) -> Duration {
        self.started.elapsed()
    }

    /// Operational snapshot (queue, batching, cache, throughput) with a
    /// per-shard breakdown. Aggregate counters are the sums of the
    /// per-shard snapshots, so the two views reconcile by construction.
    pub fn stats(&self) -> ServiceStats {
        let uptime = self.started.elapsed();
        let shards: Vec<ShardStats> =
            self.shards.iter().map(|s| s.snapshot(uptime)).collect();
        let completed: u64 = shards.iter().map(|s| s.jobs_completed).sum();
        let batches: u64 = shards.iter().map(|s| s.batches).sum();
        let tasks: u64 = shards.iter().map(|s| s.tasks).sum();
        let capacity_slots: u64 = self.shards.iter().map(Shard::capacity_slots).sum();
        ServiceStats {
            queue_depth: shards.iter().map(|s| s.queue_depth).sum(),
            backlog_seconds: shards.iter().map(|s| s.backlog_seconds).sum(),
            jobs_submitted: self.submitted.load(Ordering::Relaxed),
            jobs_rejected: self.rejected.load(Ordering::Relaxed),
            jobs_completed: completed,
            jobs_failed: shards.iter().map(|s| s.jobs_failed).sum(),
            batches,
            launches: shards.iter().map(|s| s.launches).sum(),
            tasks,
            occupancy: if capacity_slots == 0 {
                0.0
            } else {
                tasks as f64 / capacity_slots as f64
            },
            avg_batch_jobs: if batches == 0 { 0.0 } else { completed as f64 / batches as f64 },
            cache: self.cache.stats(),
            busy_seconds: shards.iter().map(|s| s.busy_seconds).sum(),
            uptime,
            throughput_jobs_per_s: completed as f64 / uptime.as_secs_f64().max(1e-9),
            shards,
        }
    }

    /// The plan/autotune cache (shared by every shard).
    pub fn plan_cache(&self) -> &PlanCache {
        &self.cache
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// Close every shard's queue, then wait for the workers to drain.
    /// Idempotent; also invoked by `Drop`, so an explicit call is only
    /// needed to observe the joined workers before the handle goes away.
    pub fn shutdown(&self) {
        for shard in &self.shards {
            shard.close();
        }
        for shard in &self.shards {
            shard.join();
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::SequentialBackend;
    use crate::config::{BackendKind, BatchConfig, PackingPolicy, TuneParams};
    use crate::generate::random_banded;
    use crate::pipeline::banded_singular_values_with;
    use crate::util::rng::Xoshiro256;

    fn test_cfg() -> ServiceConfig {
        ServiceConfig {
            params: TuneParams { tpb: 32, tw: 4, max_blocks: 24 },
            batch: BatchConfig { max_coresident: 4, policy: PackingPolicy::RoundRobin },
            backend: BackendKind::Sequential,
            threads: 1,
            window: Duration::from_micros(200),
            queue_cap: 64,
            backlog_cap_s: 1e6,
            cache_cap: 32,
            arch: "H100",
            workers: 1,
            routing: crate::config::ShardRouting::LeastLoaded,
            quota_pending_cap: 0,
            vectors_cap_n: crate::config::DEFAULT_VECTORS_CAP_N,
        }
    }

    #[test]
    fn served_job_matches_direct_pipeline_bitwise() {
        let cfg = test_cfg();
        let service = Service::start(cfg.clone()).unwrap();
        let mut rng = Xoshiro256::seed_from_u64(3);
        let a = random_banded::<f64>(48, 6, cfg.params.effective_tw(6), &mut rng);
        let direct = banded_singular_values_with(&SequentialBackend::new(), &a, 6, &cfg.params)
            .unwrap();
        let result = service.submit_wait(BatchInput::from((a, 6)), 0, None).unwrap();
        assert_eq!(result.sv, direct);
        assert_eq!(result.n, 48);
        assert_eq!(result.precision, "fp64");
        assert!(result.metrics.launches > 0);
        assert!(result.batch_jobs >= 1);
        service.shutdown();
        let stats = service.stats();
        assert_eq!(stats.jobs_completed, 1);
        assert_eq!(stats.jobs_failed, 0);
        assert!(stats.batches >= 1);
    }

    #[test]
    fn served_vectors_job_matches_the_direct_logged_pipeline_bitwise() {
        use crate::pipeline::banded_svd_vectors_with;
        let cfg = test_cfg();
        let service = Service::start(cfg.clone()).unwrap();
        let mut rng = Xoshiro256::seed_from_u64(19);
        let a = random_banded::<f64>(48, 6, cfg.params.effective_tw(6), &mut rng);
        let direct =
            banded_svd_vectors_with(&SequentialBackend::new(), &a, 6, &cfg.params).unwrap();
        let ticket =
            service.submit_as(None, None, BatchInput::from((a, 6)), 0, None, true).unwrap();
        let result = ticket.wait().unwrap();
        assert_eq!(result.sv, direct.sv, "vectors σ comes from the dk_qr stream");
        assert_eq!(result.u.as_ref().unwrap(), &direct.u);
        assert_eq!(result.vt.as_ref().unwrap(), &direct.vt);
    }

    #[test]
    fn oversized_vectors_request_is_declined_as_too_large() {
        let cfg = ServiceConfig { vectors_cap_n: 32, ..test_cfg() };
        let service = Service::start(cfg).unwrap();
        let mut rng = Xoshiro256::seed_from_u64(23);
        let a = random_banded::<f64>(48, 6, 4, &mut rng);
        let err = service
            .submit_as(None, None, BatchInput::from((a.clone(), 6)), 0, None, true)
            .unwrap_err();
        assert_eq!(err.as_job().unwrap().kind(), "too-large");
        assert!(!err.is_retryable(), "resubmitting the same request cannot succeed");
        assert!(err.to_string().contains("n=48"), "{err}");
        // The same shape without vectors is not footprint-capped.
        service.submit(BatchInput::from((a, 6)), 0, None).unwrap().wait().unwrap();
        assert_eq!(service.stats().jobs_rejected, 1);
        assert_eq!(service.stats().jobs_completed, 1);
    }

    #[test]
    fn repeated_shapes_hit_the_plan_cache() {
        let service = Service::start(test_cfg()).unwrap();
        let mut rng = Xoshiro256::seed_from_u64(5);
        for _ in 0..4 {
            let a = random_banded::<f64>(40, 5, 4, &mut rng);
            service.submit_wait(BatchInput::from((a, 5)), 0, None).unwrap();
        }
        let stats = service.stats();
        assert!(stats.cache.plan_hits > 0, "{:?}", stats.cache);
        assert!(stats.cache.hit_rate() > 0.0);
        assert_eq!(stats.jobs_completed, 4);
        assert!(stats.throughput_jobs_per_s > 0.0);
    }

    #[test]
    fn invalid_storage_is_rejected_at_admission() {
        use crate::banded::storage::Banded;
        let service = Service::start(test_cfg()).unwrap();
        // kd_sub 1 < tw 4: cannot hold the reduction's fill-in.
        let bad = Banded::<f64>::zeros(32, 9, 1);
        assert!(service.submit(BatchInput::from((bad, 8)), 0, None).is_err());
        assert_eq!(service.stats().jobs_rejected, 1);
        assert_eq!(service.stats().jobs_submitted, 0);
    }

    #[test]
    fn pricing_is_positive_and_cached() {
        let service = Service::start(test_cfg()).unwrap();
        let mut rng = Xoshiro256::seed_from_u64(7);
        let a = random_banded::<f64>(64, 8, 4, &mut rng);
        let input = BatchInput::from((a, 8));
        let p1 = service.price(&input);
        let p2 = service.price(&input);
        assert!(p1 > 0.0);
        assert_eq!(p1, p2);
        assert!(service.plan_cache().stats().plan_hits >= 1);
    }

    #[test]
    fn shutdown_fails_jobs_submitted_after_close() {
        let service = Service::start(test_cfg()).unwrap();
        service.shutdown();
        let mut rng = Xoshiro256::seed_from_u64(9);
        let a = random_banded::<f64>(24, 3, 2, &mut rng);
        assert!(service.submit(BatchInput::from((a, 3)), 0, None).is_err());
        service.shutdown(); // idempotent
    }

    #[test]
    fn expired_deadline_reports_a_typed_deadline_error() {
        // A generous window guarantees the monotone clock advances past
        // the zero deadline before the flush drains the job.
        let cfg = ServiceConfig { window: Duration::from_millis(20), ..test_cfg() };
        let service = Service::start(cfg).unwrap();
        let mut rng = Xoshiro256::seed_from_u64(11);
        let a = random_banded::<f64>(24, 3, 2, &mut rng);
        let err = service
            .submit_wait(BatchInput::from((a, 3)), 0, Some(Duration::ZERO))
            .unwrap_err();
        assert!(err.to_string().contains("deadline"), "{err}");
        assert_eq!(err.as_job().unwrap().kind(), "deadline-expired");
        assert!(!err.is_retryable());
        let stats = service.stats();
        assert_eq!(stats.jobs_failed, 1);
        assert_eq!(stats.jobs_completed, 0);
    }

    #[test]
    fn rejects_unknown_arch_and_fused_backend() {
        let bad_arch = ServiceConfig { arch: "NOPE9000", ..test_cfg() };
        assert!(Service::start(bad_arch).is_err());
        let fused = ServiceConfig { backend: BackendKind::PjrtFused, ..test_cfg() };
        assert!(Service::start(fused).is_err());
    }

    #[test]
    fn sharded_service_drains_mixed_priorities_and_stats_reconcile() {
        // Two shards, mixed priorities, results bitwise-stable: the
        // router only decides placement, never numerics, and the
        // per-shard breakdown sums back to the aggregate exactly.
        let cfg = ServiceConfig { workers: 2, ..test_cfg() };
        let service = Service::start(cfg.clone()).unwrap();
        let direct = SequentialBackend::new();
        let mut rng = Xoshiro256::seed_from_u64(13);
        let mut tickets = Vec::new();
        let mut expected = Vec::new();
        for i in 0..10u8 {
            let (n, bw) = [(48usize, 6usize), (36, 5), (28, 3)][i as usize % 3];
            let a = random_banded::<f64>(n, bw, cfg.params.effective_tw(bw), &mut rng);
            expected.push(
                banded_singular_values_with(&direct, &a, bw, &cfg.params).unwrap(),
            );
            tickets.push(service.submit(BatchInput::from((a, bw)), i % 3, None).unwrap());
        }
        for (ticket, want) in tickets.into_iter().zip(expected) {
            assert_eq!(ticket.wait().unwrap().sv, want);
        }
        service.shutdown();
        let stats = service.stats();
        assert_eq!(stats.shards.len(), 2);
        assert_eq!(stats.jobs_completed, 10);
        assert_eq!(stats.jobs_failed, 0);
        assert_eq!(stats.queue_depth, 0);
        let by_shard: u64 = stats.shards.iter().map(|s| s.jobs_completed).sum();
        assert_eq!(by_shard, stats.jobs_completed, "per-shard completions reconcile");
        assert_eq!(
            stats.shards.iter().map(|s| s.batches).sum::<u64>(),
            stats.batches,
            "per-shard batches reconcile"
        );
        assert_eq!(
            stats.shards.iter().map(|s| s.launches).sum::<u64>(),
            stats.launches,
            "per-shard launches reconcile"
        );
        assert_eq!(
            stats.shards.iter().map(|s| s.tasks).sum::<u64>(),
            stats.tasks,
            "per-shard tasks reconcile"
        );
        // The shared cache saw every shard's lookups.
        let lookups: u64 =
            stats.shards.iter().map(|s| s.cache_hits + s.cache_misses).sum();
        assert_eq!(lookups, stats.cache.hits() + stats.cache.misses());
    }

    #[test]
    fn quota_cap_limits_one_client_without_starving_others() {
        // A huge window keeps submissions queued, so the second job of
        // the capped client is still pending when the third arrives.
        let cfg = ServiceConfig {
            window: Duration::from_secs(30),
            quota_pending_cap: 2,
            ..test_cfg()
        };
        let service = Service::start(cfg).unwrap();
        let mut rng = Xoshiro256::seed_from_u64(17);
        let mut input = || BatchInput::from((random_banded::<f64>(24, 3, 2, &mut rng), 3));
        let t1 = service.submit_as(Some("hog"), None, input(), 0, None, false).unwrap();
        let t2 = service.submit_as(Some("hog"), None, input(), 0, None, false).unwrap();
        let err = service.submit_as(Some("hog"), None, input(), 0, None, false).unwrap_err();
        assert_eq!(err.as_job().unwrap().kind(), "quota-exceeded");
        assert!(err.is_retryable());
        // quota_class overrides client_id as the key: same budget.
        let err =
            service.submit_as(Some("other"), Some("hog"), input(), 0, None, false).unwrap_err();
        assert_eq!(err.as_job().unwrap().kind(), "quota-exceeded");
        // Other clients and anonymous submitters are unaffected.
        let t3 = service.submit_as(Some("guest"), None, input(), 0, None, false).unwrap();
        let t4 = service.submit(input(), 0, None).unwrap();
        for t in [t1, t2, t3, t4] {
            t.wait().unwrap();
        }
        // Budget freed once the jobs drained; shutdown flushes the last
        // job immediately instead of holding the 30 s window open.
        let t5 = service.submit_as(Some("hog"), None, input(), 0, None, false).unwrap();
        service.shutdown();
        t5.wait().unwrap();
        let stats = service.stats();
        assert_eq!(stats.jobs_rejected, 2);
        assert_eq!(stats.jobs_completed, 5);
    }
}
