//! The dynamic micro-batcher: a dedicated worker thread that coalesces
//! pending jobs into merged [`LaunchPlan`]s and executes them. The
//! service runs one batcher per shard ([`crate::service::shard`]), each
//! draining its own queue on its own backend.
//!
//! Flush policy (Abdelfattah & Fasi's dynamic-batching argument applied
//! to the plan IR): once at least one job is pending, the batcher holds
//! the flush open until either
//!
//! - **size**: the queue reaches `max_coresident` jobs (a full merge
//!   window — waiting longer cannot improve packing), or
//! - **time**: the micro-batch window elapses
//!   ([`crate::config::ServiceConfig::window`], env
//!   `BSVD_SERVICE_WINDOW_US`) — bounding the latency a lone job pays
//!   for the chance of co-scheduling.
//!
//! The flush drains jobs in queue order ([priority, admission seq] —
//! see [`crate::service::queue::JobQueue::pop_batch`]), resolves each
//! job's solo plan through the [`PlanCache`], merges the parts under the
//! joint MaxBlocks capacity ([`LaunchPlan::merge_refs`] via the cached
//! merge skeleton), and executes the merged plan on the service's
//! [`Backend`]. Per-problem ordering inside a merged plan is preserved by
//! construction, so a served result is bitwise identical to a direct
//! [`crate::pipeline::banded_singular_values_with`] call on the same
//! backend — the property `rust/tests/service_roundtrip.rs` locks in over
//! loopback TCP.

use crate::backend::{Backend, BandStorageMut};
use crate::banded::dense::Dense;
use crate::config::ServiceConfig;
use crate::obs::metrics::ServiceMetrics;
use crate::obs::trace;
use crate::pipeline::{accumulate_panels, bidiagonal_singular_values, complete_svd};
use crate::plan::{LaunchPlan, ReflectorLog};
use crate::service::cache::{PlanCache, PlanKey};
use crate::service::queue::{Job, JobQueue, JobResult};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Aggregate counters the worker publishes (relaxed atomics: the `stats`
/// verb reads a monotone snapshot, not a transaction).
#[derive(Debug, Default)]
pub(crate) struct WorkerStats {
    pub batches: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub jobs_failed: AtomicU64,
    /// Shared launches executed.
    pub launches: AtomicU64,
    /// Cycle-tasks executed.
    pub tasks: AtomicU64,
    /// Capacity slots offered (launches × MaxBlocks) — occupancy is
    /// `tasks / capacity_slots`.
    pub capacity_slots: AtomicU64,
    /// Wall time spent executing merged plans (nanoseconds).
    pub busy_nanos: AtomicU64,
    /// Plan/merge lookups this worker served from the shared cache —
    /// per-shard attribution the global [`PlanCache`] counters cannot
    /// give once several shards share one cache.
    pub cache_hits: AtomicU64,
    pub cache_misses: AtomicU64,
}

impl WorkerStats {
    pub fn occupancy(&self) -> f64 {
        let offered = self.capacity_slots.load(Ordering::Relaxed);
        if offered == 0 {
            0.0
        } else {
            self.tasks.load(Ordering::Relaxed) as f64 / offered as f64
        }
    }
}

/// Run the batcher loop until the queue closes and drains. Owns the
/// backend (plan execution happens only on this thread; submitters never
/// touch it).
pub(crate) fn run(
    queue: Arc<JobQueue>,
    cfg: ServiceConfig,
    cache: PlanCache,
    backend: Box<dyn Backend>,
    stats: Arc<WorkerStats>,
    shard: usize,
    metrics: Arc<ServiceMetrics>,
) {
    let max_coresident = cfg.batch.max_coresident.max(1);
    while queue.wait_job() {
        // Hold the window open for co-scheduling (the size trigger fires
        // inside the wait; the time trigger is the timeout). The window
        // is measured from the *oldest pending job's admission*, not from
        // when this worker came free: a job that already out-waited the
        // window while a previous flush executed is not held again.
        if max_coresident > 1 && !cfg.window.is_zero() {
            let remaining = match queue.oldest_enqueued() {
                Some(enqueued) => cfg.window.saturating_sub(enqueued.elapsed()),
                None => cfg.window,
            };
            if !remaining.is_zero() {
                queue.wait_depth(max_coresident, remaining);
            }
        }
        let mut jobs = queue.pop_batch(max_coresident);
        if jobs.is_empty() {
            continue; // every drained job had an expired deadline
        }
        flush(&mut jobs, &cfg, &cache, backend.as_ref(), &stats, shard, &metrics);
    }
}

/// Execute one flushed batch and deliver every outcome.
fn flush(
    jobs: &mut [Job],
    cfg: &ServiceConfig,
    cache: &PlanCache,
    backend: &dyn Backend,
    stats: &WorkerStats,
    shard: usize,
    metrics: &ServiceMetrics,
) {
    let capacity = cfg.params.capacity();
    // Solo plans from the cache, in batch order (= merged problem order).
    let keys: Vec<PlanKey> = jobs
        .iter()
        .map(|job| PlanKey {
            n: job.input.n(),
            bw: job.input.bw(),
            es: job.input.element_bytes(),
            params: cfg.params,
        })
        .collect();
    let mut hits = 0u64;
    let mut misses = 0u64;
    let mut trace = |hit: bool| if hit { hits += 1 } else { misses += 1 };
    let parts: Vec<Arc<LaunchPlan>> = keys
        .iter()
        .map(|&k| {
            let (plan, hit) = cache.plan_for_traced(k);
            trace(hit);
            plan
        })
        .collect();
    let (merged, merge_hit) = cache.merged_for_traced(
        &keys,
        &parts,
        capacity,
        cfg.batch.policy,
        cfg.batch.max_coresident,
    );
    trace(merge_hit);
    stats.cache_hits.fetch_add(hits, Ordering::Relaxed);
    stats.cache_misses.fetch_add(misses, Ordering::Relaxed);

    // Queue waits end here: everything after is execution time.
    let waits: Vec<Duration> = jobs.iter().map(|job| job.enqueued.elapsed()).collect();
    for &wait in &waits {
        metrics.queue_wait.record(wait);
    }
    if trace::enabled() {
        let batch_jobs = jobs.len();
        for (job, &wait) in jobs.iter().zip(waits.iter()) {
            let shape = format!("n={} bw={}", job.input.n(), job.input.bw());
            trace::event(job.trace, job.id, "queue_wait", "server", Some(shard), wait, shape);
            let detail = format!("batch_jobs={batch_jobs} hit={merge_hit}");
            trace::event(job.trace, job.id, "merge", "server", Some(shard), Duration::ZERO, detail);
        }
    }
    // One reflector log covers the merged plan when any co-scheduled job
    // wants singular vectors; values-only jobs in the same flush ride
    // along untouched (the log records per-problem arenas, and recording
    // never changes what the kernels write to the bands).
    let mut log =
        jobs.iter().any(|job| job.vectors).then(|| ReflectorLog::for_plan(merged.as_ref()));
    // Pin this batch's jobs to the worker thread so the backend's launch
    // loop can attribute per-launch events to every co-scheduled job.
    let _launch_guard = if trace::enabled() {
        let pinned: Vec<(trace::TraceId, u64, Option<usize>)> =
            jobs.iter().map(|job| (job.trace, job.id, Some(shard))).collect();
        Some(trace::launch_scope(&pinned))
    } else {
        None
    };
    let t_exec = Instant::now();
    let exec = {
        let mut bands: Vec<BandStorageMut<'_>> =
            jobs.iter_mut().map(|job| job.input.as_band_storage_mut()).collect();
        match log.as_mut() {
            Some(log) => backend.execute_logged(merged.as_ref(), &mut bands, log),
            None => backend.execute(merged.as_ref(), &mut bands),
        }
    };
    let busy = t_exec.elapsed();
    drop(_launch_guard);
    metrics.exec.record(busy);

    match exec {
        Ok(exec) => {
            stats.batches.fetch_add(1, Ordering::Relaxed);
            stats.launches.fetch_add(exec.aggregate.launches as u64, Ordering::Relaxed);
            stats.tasks.fetch_add(exec.aggregate.tasks as u64, Ordering::Relaxed);
            stats
                .capacity_slots
                .fetch_add((exec.aggregate.launches * capacity) as u64, Ordering::Relaxed);
            stats.busy_nanos.fetch_add(busy.as_nanos() as u64, Ordering::Relaxed);
            stats.jobs_completed.fetch_add(jobs.len() as u64, Ordering::Relaxed);
            let batch_jobs = jobs.len();
            for (p, ((job, metrics), queue_wait)) in
                jobs.iter().zip(exec.per_problem).zip(waits).enumerate()
            {
                let (diag, superdiag) = job.input.bidiagonal_f64();
                // Vectors jobs take σ from the Demmel–Kahan rotation
                // stream so (σ, U, Vᵀ) is one consistent factorization;
                // values-only jobs keep the bisection path bit-for-bit.
                let (sv, u, vt) = if job.vectors {
                    let log = log.as_ref().expect("vectors flush built a reflector log");
                    let n = job.input.n();
                    let mut u = Dense::<f64>::identity(n);
                    let mut vt = Dense::<f64>::identity(n);
                    accumulate_panels(merged.as_ref(), log, p, &mut u, &mut vt);
                    let sv = complete_svd(&diag, &superdiag, &mut u, &mut vt);
                    (sv, Some(u), Some(vt))
                } else {
                    (bidiagonal_singular_values(&diag, &superdiag), None, None)
                };
                let result = JobResult {
                    id: job.id,
                    n: job.input.n(),
                    bw: job.input.bw(),
                    precision: job.input.precision(),
                    sv,
                    u,
                    vt,
                    metrics,
                    batch_jobs,
                    queue_wait,
                };
                if trace::enabled() {
                    let detail = format!("batch_jobs={batch_jobs}");
                    trace::event(job.trace, job.id, "flush", "server", Some(shard), busy, detail);
                    let out = format!("sv={}", result.sv.len());
                    let zero = Duration::ZERO;
                    trace::event(job.trace, job.id, "respond", "server", Some(shard), zero, out);
                }
                let _ = job.tx.send(Ok(result));
            }
        }
        Err(e) => {
            stats.jobs_failed.fetch_add(jobs.len() as u64, Ordering::Relaxed);
            let err = crate::error::JobError::Execution {
                reason: format!("backend {} failed: {e}", backend.name()),
            };
            for job in jobs.iter() {
                if trace::enabled() {
                    let reason = err.to_string();
                    trace::event(job.trace, job.id, "flush", "server", Some(shard), busy, reason);
                }
                let _ = job.tx.send(Err(err.clone()));
            }
        }
    }
}
