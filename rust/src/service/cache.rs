//! Bounded LRU plan-and-autotune cache.
//!
//! Plan lowering is deterministic — [`LaunchPlan::for_problem`] is a pure
//! function of `(n, bw, TuneParams)`, [`LaunchPlan::merge_refs`] of its
//! parts plus the packing knobs, and [`crate::simulator::autotune_for`]
//! of its [`TuneKey`] — so all three are cacheable without invalidation
//! logic:
//! an entry can never go stale, only cold. The cache therefore amortizes
//! the per-request lowering/merging/tuning work across the repeated
//! shapes a serving workload is dominated by (Abdelfattah & Fasi: batch
//! SVD traffic is many small problems from few distinct shapes).
//!
//! Three stores share one handle and one stats block:
//!
//! - **solo plans**, keyed by [`PlanKey`] `(n, bw, element size,
//!   TuneParams)` — shared by the service batcher, admission pricing, and
//!   [`crate::batch::BatchCoordinator::plan`] (so `batch` and `serve`
//!   lower through one path);
//! - **merge skeletons**, keyed by the part keys plus the packing knobs —
//!   a window of identical shapes re-uses the merged plan outright;
//! - **autotune results**, keyed by [`TuneKey`].
//!
//! Each store is LRU-bounded to `cap` entries; plans are handed out as
//! `Arc<LaunchPlan>` so hits never clone. Hit/miss counters are exposed
//! via [`PlanCache::stats`] and surfaced by the service `stats` verb.

use crate::config::{PackingPolicy, TuneParams};
use crate::plan::LaunchPlan;
use crate::simulator::hw::GpuArch;
use crate::simulator::model::BackendCostModel;
use crate::simulator::{autotune_for_calibrated, TuneKey, TuneResult};
use std::collections::HashMap;
use std::hash::Hash;
use std::sync::{Arc, Mutex};

/// Identity of one solo lowering. `es` (element size in bytes) does not
/// change the lowered plan, but it *does* change admission pricing and
/// tuning, so the service keys shapes by precision throughout — mixed
/// fp32/fp64 traffic of one shape costs two (identical-valued) entries.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub n: usize,
    pub bw: usize,
    /// Element size in bytes (the paper's precision axis: 2/4/8).
    pub es: usize,
    pub params: TuneParams,
}

#[derive(Clone, Debug, PartialEq, Eq, Hash)]
struct MergeKey {
    parts: Vec<PlanKey>,
    capacity: usize,
    policy: PackingPolicy,
    max_coresident: usize,
}

/// Hit/miss counters, split per store. A "hit rate" over everything the
/// cache absorbed is `hits() / (hits() + misses())`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub plan_hits: u64,
    pub plan_misses: u64,
    pub merge_hits: u64,
    pub merge_misses: u64,
    pub tune_hits: u64,
    pub tune_misses: u64,
}

impl CacheStats {
    pub fn hits(&self) -> u64 {
        self.plan_hits + self.merge_hits + self.tune_hits
    }

    pub fn misses(&self) -> u64 {
        self.plan_misses + self.merge_misses + self.tune_misses
    }

    /// Fraction of lookups served from cache (0.0 when none were made).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits() + self.misses();
        if total == 0 {
            0.0
        } else {
            self.hits() as f64 / total as f64
        }
    }
}

/// One LRU-bounded store: values stamped with a logical tick; eviction
/// drops the least-recently-used entry. Eviction scans for the minimum
/// stamp — O(len) on insert-past-cap, which is irrelevant at the tens to
/// hundreds of entries the service caps its stores at.
struct LruStore<K, V> {
    map: HashMap<K, (u64, V)>,
    cap: usize,
}

impl<K: Eq + Hash + Clone, V: Clone> LruStore<K, V> {
    fn new(cap: usize) -> Self {
        Self { map: HashMap::new(), cap: cap.max(1) }
    }

    fn get(&mut self, key: &K, tick: u64) -> Option<V> {
        let (stamp, v) = self.map.get_mut(key)?;
        *stamp = tick;
        Some(v.clone())
    }

    fn insert(&mut self, key: K, value: V, tick: u64) {
        if self.map.len() >= self.cap && !self.map.contains_key(&key) {
            if let Some(oldest) =
                self.map.iter().min_by_key(|(_, (stamp, _))| *stamp).map(|(k, _)| k.clone())
            {
                self.map.remove(&oldest);
            }
        }
        self.map.insert(key, (tick, value));
    }
}

struct CacheInner {
    tick: u64,
    plans: LruStore<PlanKey, Arc<LaunchPlan>>,
    merges: LruStore<MergeKey, Arc<LaunchPlan>>,
    tunes: LruStore<TuneKey, TuneResult>,
    stats: CacheStats,
}

impl CacheInner {
    fn tick(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }
}

/// The shared cache handle — cheap to clone (one `Arc`), safe to consult
/// from any thread. Lowering/merging/tuning on a miss happens *outside*
/// the lock, so a cold expensive entry never blocks concurrent hits;
/// racing misses on the same key both compute and last-insert wins (the
/// values are identical by determinism, so this is benign).
#[derive(Clone)]
pub struct PlanCache {
    inner: Arc<Mutex<CacheInner>>,
}

impl PlanCache {
    /// A cache holding up to `cap` entries per store.
    pub fn new(cap: usize) -> Self {
        Self {
            inner: Arc::new(Mutex::new(CacheInner {
                tick: 0,
                plans: LruStore::new(cap),
                merges: LruStore::new(cap),
                tunes: LruStore::new(cap),
                stats: CacheStats::default(),
            })),
        }
    }

    /// The solo plan for `key`, lowered on miss. The returned plan is the
    /// identical value `LaunchPlan::for_problem(key.n, key.bw,
    /// &key.params)` produces — cached or not.
    pub fn plan_for(&self, key: PlanKey) -> Arc<LaunchPlan> {
        self.plan_for_traced(key).0
    }

    /// Like [`PlanCache::plan_for`], also reporting whether the lookup
    /// hit (`true`) or lowered fresh (`false`) — callers that attribute
    /// cache behavior to a shard read this instead of diffing the global
    /// counters, which other shards mutate concurrently.
    pub fn plan_for_traced(&self, key: PlanKey) -> (Arc<LaunchPlan>, bool) {
        {
            let mut inner = self.inner.lock().unwrap();
            let tick = inner.tick();
            if let Some(plan) = inner.plans.get(&key, tick) {
                inner.stats.plan_hits += 1;
                return (plan, true);
            }
            inner.stats.plan_misses += 1;
        }
        let plan = Arc::new(LaunchPlan::for_problem(key.n, key.bw, &key.params));
        let mut inner = self.inner.lock().unwrap();
        let tick = inner.tick();
        inner.plans.insert(key, Arc::clone(&plan), tick);
        (plan, false)
    }

    /// The merged shared-launch plan for `parts` (the plans cached under
    /// `keys`, in batch order) under the packing knobs — the merge
    /// skeleton. `keys[i]` must identify `parts[i]`.
    pub fn merged_for(
        &self,
        keys: &[PlanKey],
        parts: &[Arc<LaunchPlan>],
        capacity: usize,
        policy: PackingPolicy,
        max_coresident: usize,
    ) -> Arc<LaunchPlan> {
        self.merged_for_traced(keys, parts, capacity, policy, max_coresident).0
    }

    /// [`PlanCache::merged_for`] with the same hit/miss trace as
    /// [`PlanCache::plan_for_traced`].
    pub fn merged_for_traced(
        &self,
        keys: &[PlanKey],
        parts: &[Arc<LaunchPlan>],
        capacity: usize,
        policy: PackingPolicy,
        max_coresident: usize,
    ) -> (Arc<LaunchPlan>, bool) {
        debug_assert_eq!(keys.len(), parts.len());
        let key = MergeKey { parts: keys.to_vec(), capacity, policy, max_coresident };
        {
            let mut inner = self.inner.lock().unwrap();
            let tick = inner.tick();
            if let Some(plan) = inner.merges.get(&key, tick) {
                inner.stats.merge_hits += 1;
                return (plan, true);
            }
            inner.stats.merge_misses += 1;
        }
        let refs: Vec<&LaunchPlan> = parts.iter().map(|p| p.as_ref()).collect();
        let merged = Arc::new(LaunchPlan::merge_refs(&refs, capacity, policy, max_coresident));
        let mut inner = self.inner.lock().unwrap();
        let tick = inner.tick();
        inner.merges.insert(key, Arc::clone(&merged), tick);
        (merged, false)
    }

    /// The [`crate::simulator::autotune_for`] result for the workload,
    /// searched on miss.
    /// When a measured calibration is active (`BSVD_PROFILE`, see
    /// [`crate::obs::calibrate::from_env`]), the search runs under the
    /// calibrated simulator and the entry is keyed by the profile's
    /// fingerprint — swapping calibrations can never serve a stale tune.
    pub fn tune_for(
        &self,
        arch: &GpuArch,
        element_bytes: usize,
        n: usize,
        bw: usize,
        backend: &BackendCostModel,
    ) -> TuneResult {
        let profile = crate::obs::calibrate::from_env();
        let mut key = TuneKey::new(arch, element_bytes, n, bw, backend);
        if let Some(p) = profile {
            key = key.with_profile_fingerprint(p.fingerprint());
        }
        {
            let mut inner = self.inner.lock().unwrap();
            let tick = inner.tick();
            if let Some(result) = inner.tunes.get(&key, tick) {
                inner.stats.tune_hits += 1;
                return result;
            }
            inner.stats.tune_misses += 1;
        }
        let result = autotune_for_calibrated(arch, element_bytes, n, bw, backend, profile);
        let mut inner = self.inner.lock().unwrap();
        let tick = inner.tick();
        inner.tunes.insert(key, result.clone(), tick);
        result
    }

    /// Snapshot of the hit/miss counters.
    pub fn stats(&self) -> CacheStats {
        self.inner.lock().unwrap().stats
    }

    /// Entries currently resident (plans, merges, tunes).
    pub fn len(&self) -> (usize, usize, usize) {
        let inner = self.inner.lock().unwrap();
        (inner.plans.map.len(), inner.merges.map.len(), inner.tunes.map.len())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == (0, 0, 0)
    }
}

impl Default for PlanCache {
    /// A cache with the default [`crate::config::ServiceConfig`]
    /// capacity ([`crate::config::DEFAULT_CACHE_CAP`]).
    fn default() -> Self {
        Self::new(crate::config::DEFAULT_CACHE_CAP)
    }
}

impl std::fmt::Debug for PlanCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let (plans, merges, tunes) = self.len();
        f.debug_struct("PlanCache")
            .field("plans", &plans)
            .field("merges", &merges)
            .field("tunes", &tunes)
            .field("stats", &self.stats())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::{autotune_for, hw};

    fn key(n: usize, bw: usize, es: usize) -> PlanKey {
        PlanKey { n, bw, es, params: TuneParams { tpb: 32, tw: 4, max_blocks: 16 } }
    }

    #[test]
    fn plan_hits_return_the_same_arc() {
        let cache = PlanCache::new(8);
        let a = cache.plan_for(key(64, 8, 8));
        let b = cache.plan_for(key(64, 8, 8));
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(*a, LaunchPlan::for_problem(64, 8, &key(64, 8, 8).params));
        let s = cache.stats();
        assert_eq!((s.plan_hits, s.plan_misses), (1, 1));
        assert!(s.hit_rate() > 0.0);
    }

    #[test]
    fn precision_is_part_of_the_key() {
        let cache = PlanCache::new(8);
        let a = cache.plan_for(key(64, 8, 4));
        let b = cache.plan_for(key(64, 8, 8));
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(*a, *b); // identical plan values, distinct entries
        assert_eq!(cache.stats().plan_misses, 2);
    }

    #[test]
    fn merge_skeletons_cache_and_match_direct_merge() {
        let cache = PlanCache::new(8);
        let keys = [key(48, 6, 8), key(32, 4, 8), key(48, 6, 8)];
        let parts: Vec<Arc<LaunchPlan>> = keys.iter().map(|&k| cache.plan_for(k)).collect();
        let m1 = cache.merged_for(&keys, &parts, 16, PackingPolicy::RoundRobin, 4);
        let m2 = cache.merged_for(&keys, &parts, 16, PackingPolicy::RoundRobin, 4);
        assert!(Arc::ptr_eq(&m1, &m2));
        let direct: Vec<LaunchPlan> = parts.iter().map(|p| (**p).clone()).collect();
        assert_eq!(*m1, LaunchPlan::merge(&direct, 16, PackingPolicy::RoundRobin, 4));
        // Different knobs are different skeletons.
        let m3 = cache.merged_for(&keys, &parts, 16, PackingPolicy::GreedyFill, 4);
        assert!(!Arc::ptr_eq(&m1, &m3));
        let s = cache.stats();
        assert_eq!((s.merge_hits, s.merge_misses), (1, 2));
        // The duplicate shape hit the plan store.
        assert_eq!(s.plan_hits, 1);
    }

    #[test]
    fn tune_results_cache_and_reproduce_the_search() {
        let cache = PlanCache::new(4);
        let native = BackendCostModel::native();
        let warm = cache.tune_for(&hw::H100, 4, 4096, 32, &native);
        let hit = cache.tune_for(&hw::H100, 4, 4096, 32, &native);
        assert_eq!(warm.params, hit.params);
        assert_eq!(warm.modeled_seconds, hit.modeled_seconds);
        let fresh = autotune_for(&hw::H100, 4, 4096, 32, &native);
        assert_eq!(warm.params, fresh.params);
        let s = cache.stats();
        assert_eq!((s.tune_hits, s.tune_misses), (1, 1));
    }

    #[test]
    fn lru_evicts_the_least_recently_used_entry() {
        let cache = PlanCache::new(2);
        let (a, b, c) = (key(32, 4, 8), key(40, 5, 8), key(48, 6, 8));
        cache.plan_for(a);
        cache.plan_for(b);
        cache.plan_for(a); // refresh a; b is now LRU
        cache.plan_for(c); // evicts b
        assert_eq!(cache.len().0, 2);
        let before = cache.stats();
        cache.plan_for(a); // still resident
        cache.plan_for(b); // evicted -> miss
        let after = cache.stats();
        assert_eq!(after.plan_hits - before.plan_hits, 1);
        assert_eq!(after.plan_misses - before.plan_misses, 1);
    }

    #[test]
    fn traced_lookups_agree_with_the_global_counters() {
        let cache = PlanCache::new(8);
        let (_, hit) = cache.plan_for_traced(key(64, 8, 8));
        assert!(!hit);
        let (_, hit) = cache.plan_for_traced(key(64, 8, 8));
        assert!(hit);
        let keys = [key(64, 8, 8), key(64, 8, 8)];
        let parts: Vec<Arc<LaunchPlan>> = keys.iter().map(|&k| cache.plan_for(k)).collect();
        let (_, hit) = cache.merged_for_traced(&keys, &parts, 16, PackingPolicy::RoundRobin, 4);
        assert!(!hit);
        let (_, hit) = cache.merged_for_traced(&keys, &parts, 16, PackingPolicy::RoundRobin, 4);
        assert!(hit);
        let s = cache.stats();
        assert_eq!((s.plan_hits, s.plan_misses), (3, 1));
        assert_eq!((s.merge_hits, s.merge_misses), (1, 1));
    }

    #[test]
    fn clones_share_one_cache() {
        let cache = PlanCache::new(8);
        let clone = cache.clone();
        clone.plan_for(key(64, 8, 8));
        cache.plan_for(key(64, 8, 8));
        let s = cache.stats();
        assert_eq!((s.plan_hits, s.plan_misses), (1, 1));
        assert!(!cache.is_empty());
    }
}
