//! One batcher shard — its own admission queue, its own backend
//! executor, its own worker thread — plus the [`Router`] that spreads
//! admitted jobs over the shards.
//!
//! The paper's scaling argument (memory-bound bulge-chasing wants work
//! spread over many parallel compute resources with careful placement)
//! applies to the serving tier too: one batcher thread on one backend is
//! the throughput ceiling no matter how large the machine. A sharded
//! [`crate::service::Service`] runs `workers` independent batcher loops,
//! each owning a `Box<dyn Backend>` built on its own thread (PJRT
//! executors never cross threads), all sharing one
//! [`PlanCache`] — lowering and merging stay amortized service-wide
//! while execution scales out.
//!
//! Each shard keeps its own [`crate::service::queue::JobQueue`], so the
//! strict `(priority, admission seq)` drain order holds *within a
//! shard*; the router decides only which shard a job lands on
//! ([`crate::config::ShardRouting`]). Admission caps (`queue_cap`,
//! `backlog_cap_s`) apply per shard; client quota
//! ([`crate::service::queue::QuotaTracker`]) is shared, so a client's
//! pending cap is service-wide.

use crate::backend::for_kind;
use crate::config::{ServiceConfig, ShardRouting};
use crate::error::{Error, Result};
use crate::obs::metrics::ServiceMetrics;
use crate::service::batcher::{self, WorkerStats};
use crate::service::cache::PlanCache;
use crate::service::queue::{JobQueue, QuotaTracker};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Operational snapshot of one batcher shard — the per-shard breakdown
/// riding [`crate::service::ServiceStats::shards`]. Summing the
/// per-shard counters reproduces the aggregate view exactly (the
/// aggregate *is* the sum; `rust/src/service/mod.rs` tests lock the
/// reconciliation in).
#[derive(Clone, Debug)]
pub struct ShardStats {
    /// Shard index — also the suffix of its worker thread's name
    /// (`bsvd-service-batcher-{shard}`).
    pub shard: usize,
    /// Jobs queued on this shard (admitted, not yet flushed).
    pub queue_depth: usize,
    /// Modeled seconds of this shard's queued work.
    pub backlog_seconds: f64,
    pub jobs_completed: u64,
    /// Backend failures plus deadlines expired in this shard's queue.
    pub jobs_failed: u64,
    /// Merged-plan flushes this shard executed.
    pub batches: u64,
    pub launches: u64,
    pub tasks: u64,
    /// Mean launch occupancy of this shard's flushes.
    pub occupancy: f64,
    /// Wall time this shard spent executing merged plans.
    pub busy_seconds: f64,
    /// Fraction of service uptime this shard spent executing — the
    /// utilization signal the least-loaded router is balancing.
    pub busy_fraction: f64,
    /// This shard's lookups into the *shared* plan cache (the global
    /// [`crate::service::CacheStats`] cannot attribute hits to shards).
    pub cache_hits: u64,
    pub cache_misses: u64,
}

impl ShardStats {
    /// Fraction of this shard's cache lookups served from cache
    /// (0.0 when it has made none).
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }
}

/// One running batcher worker and the queue that feeds it.
pub(crate) struct Shard {
    index: usize,
    pub(crate) queue: Arc<JobQueue>,
    stats: Arc<WorkerStats>,
    worker: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Shard {
    /// Spawn the shard's worker thread. The backend is constructed *on*
    /// that thread and never leaves it (PJRT executors are not `Send`);
    /// the kind must already be validated by
    /// [`crate::backend::cost_model_for`].
    pub(crate) fn start(
        index: usize,
        cfg: &ServiceConfig,
        cache: PlanCache,
        quota: Arc<QuotaTracker>,
        metrics: Arc<ServiceMetrics>,
    ) -> Result<Self> {
        let queue = Arc::new(JobQueue::with_quota(cfg.queue_cap, cfg.backlog_cap_s, quota));
        let stats = Arc::new(WorkerStats::default());
        let worker = {
            let queue = Arc::clone(&queue);
            let stats = Arc::clone(&stats);
            let cfg = cfg.clone();
            std::thread::Builder::new()
                .name(format!("bsvd-service-batcher-{index}"))
                .spawn(move || {
                    let backend = for_kind(cfg.backend, cfg.threads)
                        .expect("backend kind validated by cost_model_for at start");
                    batcher::run(queue, cfg, cache, backend, stats, index, metrics);
                })
                .map_err(Error::Io)?
        };
        Ok(Self { index, queue, stats, worker: Mutex::new(Some(worker)) })
    }

    /// The per-shard breakdown at this instant.
    pub(crate) fn snapshot(&self, uptime: Duration) -> ShardStats {
        let w = &self.stats;
        let busy_seconds = w.busy_nanos.load(Ordering::Relaxed) as f64 / 1e9;
        ShardStats {
            shard: self.index,
            queue_depth: self.queue.depth(),
            backlog_seconds: self.queue.backlog_seconds(),
            jobs_completed: w.jobs_completed.load(Ordering::Relaxed),
            jobs_failed: w.jobs_failed.load(Ordering::Relaxed) + self.queue.expired_jobs(),
            batches: w.batches.load(Ordering::Relaxed),
            launches: w.launches.load(Ordering::Relaxed),
            tasks: w.tasks.load(Ordering::Relaxed),
            occupancy: w.occupancy(),
            busy_seconds,
            busy_fraction: busy_seconds / uptime.as_secs_f64().max(1e-9),
            cache_hits: w.cache_hits.load(Ordering::Relaxed),
            cache_misses: w.cache_misses.load(Ordering::Relaxed),
        }
    }

    /// Capacity slots this shard's flushes offered (the occupancy
    /// denominator — the aggregate occupancy needs the raw sum).
    pub(crate) fn capacity_slots(&self) -> u64 {
        self.stats.capacity_slots.load(Ordering::Relaxed)
    }

    /// Stop accepting work; already-admitted jobs still drain.
    pub(crate) fn close(&self) {
        self.queue.close();
    }

    /// Join the worker after [`Shard::close`]. Idempotent.
    pub(crate) fn join(&self) {
        if let Some(handle) = self.worker.lock().unwrap().take() {
            let _ = handle.join();
        }
    }
}

/// Picks the shard an admitted job lands on
/// ([`crate::config::ShardRouting`]).
pub(crate) struct Router {
    routing: ShardRouting,
    /// Tie-break rotation for least-loaded: equally idle shards take
    /// turns going first, so a burst hitting an idle service spreads
    /// round-robin instead of piling onto shard 0.
    rotate: AtomicUsize,
}

impl Router {
    pub(crate) fn new(routing: ShardRouting) -> Self {
        Self { routing, rotate: AtomicUsize::new(0) }
    }

    /// The shard index for a job on an `n × n` problem.
    pub(crate) fn pick(&self, shards: &[Shard], n: usize) -> usize {
        if shards.len() <= 1 {
            return 0;
        }
        match self.routing {
            ShardRouting::LeastLoaded => {
                let offset = self.rotate.fetch_add(1, Ordering::Relaxed) % shards.len();
                let load = |idx: usize| {
                    (shards[idx].queue.backlog_seconds(), shards[idx].queue.depth())
                };
                let mut best = offset;
                let mut best_load = load(offset);
                for step in 1..shards.len() {
                    let idx = (offset + step) % shards.len();
                    let candidate = load(idx);
                    if candidate.0 < best_load.0
                        || (candidate.0 == best_load.0 && candidate.1 < best_load.1)
                    {
                        best = idx;
                        best_load = candidate;
                    }
                }
                best
            }
            ShardRouting::SizeClass => {
                // log2(n) buckets: problems within a factor of two of each
                // other share a shard, so merged plans pack densely and
                // each shard's slice of the shared cache stays hot.
                let bucket = (usize::BITS - n.max(1).leading_zeros()) as usize;
                bucket % shards.len()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BackendKind, BatchConfig, PackingPolicy, TuneParams};
    use std::time::Duration;

    fn cfg() -> ServiceConfig {
        ServiceConfig {
            params: TuneParams { tpb: 32, tw: 4, max_blocks: 24 },
            batch: BatchConfig { max_coresident: 4, policy: PackingPolicy::RoundRobin },
            backend: BackendKind::Sequential,
            threads: 1,
            window: Duration::from_micros(100),
            queue_cap: 16,
            backlog_cap_s: 1e9,
            cache_cap: 16,
            arch: "H100",
            workers: 2,
            routing: ShardRouting::LeastLoaded,
            quota_pending_cap: 0,
            vectors_cap_n: crate::config::DEFAULT_VECTORS_CAP_N,
        }
    }

    fn idle_shards(count: usize) -> Vec<Shard> {
        let cfg = cfg();
        let cache = PlanCache::new(16);
        let quota = Arc::new(QuotaTracker::new(0));
        let metrics = Arc::new(ServiceMetrics::default());
        (0..count)
            .map(|i| {
                Shard::start(i, &cfg, cache.clone(), Arc::clone(&quota), Arc::clone(&metrics))
                    .unwrap()
            })
            .collect()
    }

    #[test]
    fn least_loaded_rotates_over_idle_shards() {
        let shards = idle_shards(3);
        let router = Router::new(ShardRouting::LeastLoaded);
        // All idle: the rotating offset spreads a burst round-robin.
        let picks: Vec<usize> = (0..6).map(|_| router.pick(&shards, 64)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        for shard in &shards {
            shard.close();
            shard.join();
        }
    }

    #[test]
    fn size_class_routes_same_sizes_together() {
        let shards = idle_shards(2);
        let router = Router::new(ShardRouting::SizeClass);
        // Same size class always lands on the same shard...
        let a = router.pick(&shards, 48);
        assert_eq!(router.pick(&shards, 48), a);
        assert_eq!(router.pick(&shards, 40), a, "same log2 bucket (32..=63)");
        // ...and the adjacent class lands on the other one.
        assert_ne!(router.pick(&shards, 64), a);
        for shard in &shards {
            shard.close();
            shard.join();
        }
    }

    #[test]
    fn snapshot_starts_clean_and_hit_rate_handles_zero() {
        let shards = idle_shards(1);
        let stats = shards[0].snapshot(Duration::from_secs(1));
        assert_eq!(stats.shard, 0);
        assert_eq!(stats.queue_depth, 0);
        assert_eq!((stats.jobs_completed, stats.jobs_failed), (0, 0));
        assert_eq!(stats.cache_hit_rate(), 0.0);
        assert_eq!(stats.busy_fraction, 0.0);
        shards[0].close();
        shards[0].join();
    }
}
