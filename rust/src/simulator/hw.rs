//! GPU hardware characteristics — the paper's Table II, plus the derived
//! peaks the performance model needs.
//!
//! Only *public* numbers are encoded (the same sources the paper cites:
//! vendor datasheets and the chips-and-cheese microbenchmark series).
//! Fields the table does not give (L1/L2 peak bandwidth) are derived from
//! latency, width and unit counts — deliberately, because the paper's
//! headline hardware finding is that *latency-linked bandwidth*, not
//! cache size, predicts performance.

/// One GPU architecture (a row of Table II).
#[derive(Clone, Debug, PartialEq)]
pub struct GpuArch {
    pub name: &'static str,
    pub vendor: &'static str,
    /// L1 / shared memory per execution unit (KB). Table II row 2.
    pub l1_per_unit_kb: f64,
    /// Device-level L2 (or L2.5 / Infinity Cache) capacity (MB).
    pub l2_mb: f64,
    /// DRAM bandwidth (TB/s).
    pub dram_tbs: f64,
    /// L1 latency (cycles); Table II "N.A." → vendor-class estimate.
    pub l1_lat_cycles: f64,
    /// L2 latency (cycles).
    pub l2_lat_cycles: f64,
    /// Execution units (SMs / CUs / Xe cores).
    pub units: usize,
    /// Concurrent block slots for the occupancy model (Table I/II "ALUs":
    /// SMs × warp schedulers on NVIDIA, CUs on AMD, Xe cores on Intel).
    pub alus: usize,
    /// Device memory (GB).
    pub mem_gb: f64,
    /// Boost clock (GHz).
    pub clock_ghz: f64,
    /// Cache line (bytes) — 128 on every architecture benchmarked.
    pub cache_line_bytes: usize,
    /// Register file per execution unit (KB).
    pub reg_per_unit_kb: f64,
    /// Sustained in-flight L1 lines per unit (memory-level parallelism;
    /// microbenchmark-derived — PVC sustains far less than its caches'
    /// size suggests, which is the paper's §V-E finding).
    pub mlp_l1: f64,
    /// Sustained in-flight L2 lines per unit.
    pub mlp_l2: f64,
}

impl GpuArch {
    /// Aggregate L1 bandwidth (bytes/s): each unit sources cache lines
    /// pipelined over `l1_lat` with per-unit memory-level parallelism —
    /// the latency×concurrency bandwidth law (Little's law).
    pub fn l1_peak_bytes_per_s(&self) -> f64 {
        self.units as f64 * self.mlp_l1 * self.cache_line_bytes as f64 * self.clock_ghz * 1e9
            / self.l1_lat_cycles
    }

    /// Aggregate L2 bandwidth (bytes/s), same law with device-level MLP.
    pub fn l2_peak_bytes_per_s(&self) -> f64 {
        self.units as f64 * self.mlp_l2 * self.cache_line_bytes as f64 * self.clock_ghz * 1e9
            / self.l2_lat_cycles
    }

    pub fn dram_peak_bytes_per_s(&self) -> f64 {
        self.dram_tbs * 1e12
    }

    /// Peak FP32 throughput (FLOP/s) — vector-ALU estimate: 128 lanes ×
    /// 2 (FMA) per unit per clock.
    pub fn fp32_peak_flops(&self) -> f64 {
        self.units as f64 * 128.0 * 2.0 * self.clock_ghz * 1e9
    }

    /// Kernel-launch overhead (seconds): back-to-back launches in one
    /// stream overlap the CPU-side cost, leaving the device-side gap.
    pub fn launch_overhead_s(&self) -> f64 {
        0.5e-6
    }
}

/// NVIDIA A100 (SXM). 108 SMs × 4 warp schedulers.
pub const A100: GpuArch = GpuArch {
    name: "A100",
    vendor: "NVIDIA",
    l1_per_unit_kb: 192.0,
    l2_mb: 40.0,
    dram_tbs: 2.0,
    l1_lat_cycles: 40.0,
    l2_lat_cycles: 200.0,
    units: 108,
    alus: 108 * 4,
    mem_gb: 80.0,
    clock_ghz: 1.41,
    cache_line_bytes: 128,
    reg_per_unit_kb: 256.0,
    mlp_l1: 8.0,
    mlp_l2: 16.0,
};

/// NVIDIA H100 (SXM).
pub const H100: GpuArch = GpuArch {
    name: "H100",
    vendor: "NVIDIA",
    l1_per_unit_kb: 256.0,
    l2_mb: 50.0,
    dram_tbs: 3.35,
    l1_lat_cycles: 30.0,
    l2_lat_cycles: 300.0,
    units: 132,
    alus: 132 * 4,
    mem_gb: 80.0,
    clock_ghz: 1.785,
    cache_line_bytes: 128,
    reg_per_unit_kb: 256.0,
    mlp_l1: 8.0,
    mlp_l2: 16.0,
};

/// NVIDIA RTX 4060 (Ada, consumer) — the Table III profiling target.
/// Table II gives no latencies; Ada-class estimates (chips-and-cheese).
pub const RTX4060: GpuArch = GpuArch {
    name: "RTX4060",
    vendor: "NVIDIA",
    l1_per_unit_kb: 128.0,
    l2_mb: 32.0,
    dram_tbs: 0.28,
    l1_lat_cycles: 35.0,
    l2_lat_cycles: 280.0,
    units: 24,
    alus: 24 * 4,
    mem_gb: 8.0,
    clock_ghz: 2.46,
    cache_line_bytes: 128,
    reg_per_unit_kb: 256.0,
    mlp_l1: 8.0,
    mlp_l2: 16.0,
};

/// AMD MI250X (one GCD as scheduled by the paper's runs).
pub const MI250X: GpuArch = GpuArch {
    name: "MI250X",
    vendor: "AMD",
    l1_per_unit_kb: 16.0,
    l2_mb: 4.0,
    dram_tbs: 3.2,
    l1_lat_cycles: 120.0,
    l2_lat_cycles: 230.0,
    units: 220,
    alus: 220,
    mem_gb: 128.0,
    clock_ghz: 1.7,
    cache_line_bytes: 128,
    reg_per_unit_kb: 512.0,
    mlp_l1: 8.0,
    mlp_l2: 12.0,
};

/// AMD MI300X (CDNA3; 256 MB Infinity Cache as "L2.5").
pub const MI300X: GpuArch = GpuArch {
    name: "MI300X",
    vendor: "AMD",
    l1_per_unit_kb: 32.0,
    l2_mb: 256.0,
    dram_tbs: 5.3,
    l1_lat_cycles: 120.0,
    l2_lat_cycles: 200.0,
    units: 304,
    alus: 304,
    mem_gb: 192.0,
    clock_ghz: 2.1,
    cache_line_bytes: 128,
    reg_per_unit_kb: 512.0,
    mlp_l1: 8.0,
    mlp_l2: 16.0,
};

/// Intel Data Center GPU Max 1100 (Ponte Vecchio).
pub const PVC1100: GpuArch = GpuArch {
    name: "PVC1100",
    vendor: "Intel",
    l1_per_unit_kb: 512.0,
    l2_mb: 108.0,
    dram_tbs: 1.2,
    l1_lat_cycles: 60.0,
    l2_lat_cycles: 420.0,
    units: 56,
    alus: 56,
    mem_gb: 48.0,
    clock_ghz: 1.55,
    cache_line_bytes: 128,
    reg_per_unit_kb: 512.0,
    mlp_l1: 4.0,
    mlp_l2: 6.0,
};

/// Apple M1 (integrated, 8-core GPU; 67 GB/s shared LPDDR).
pub const M1: GpuArch = GpuArch {
    name: "M1",
    vendor: "Apple",
    l1_per_unit_kb: 128.0,
    l2_mb: 12.0,
    dram_tbs: 0.067,
    l1_lat_cycles: 50.0,
    l2_lat_cycles: 250.0,
    units: 8,
    alus: 8 * 16,
    mem_gb: 16.0,
    clock_ghz: 1.27,
    cache_line_bytes: 128,
    reg_per_unit_kb: 208.0,
    mlp_l1: 4.0,
    mlp_l2: 8.0,
};

/// All Table II architectures.
pub fn all_archs() -> Vec<GpuArch> {
    vec![
        A100.clone(),
        H100.clone(),
        RTX4060.clone(),
        MI250X.clone(),
        MI300X.clone(),
        PVC1100.clone(),
        M1.clone(),
    ]
}

/// Look up an architecture by (case-insensitive) name.
pub fn arch_by_name(name: &str) -> Option<GpuArch> {
    let lower = name.to_ascii_lowercase();
    all_archs().into_iter().find(|a| a.name.to_ascii_lowercase() == lower)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_ii_values_spotcheck() {
        assert_eq!(H100.l1_per_unit_kb, 256.0);
        assert_eq!(A100.l2_mb, 40.0);
        assert_eq!(MI300X.l2_mb, 256.0);
        assert_eq!(PVC1100.l2_lat_cycles, 420.0);
        assert_eq!(MI250X.units, 220);
        assert_eq!(M1.dram_tbs, 0.067);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(arch_by_name("h100").unwrap().name, "H100");
        assert_eq!(arch_by_name("MI300X").unwrap().vendor, "AMD");
        assert!(arch_by_name("B200").is_none());
    }

    #[test]
    fn h100_outclasses_a100_on_derived_peaks() {
        assert!(H100.l1_peak_bytes_per_s() > A100.l1_peak_bytes_per_s());
        assert!(H100.dram_peak_bytes_per_s() > A100.dram_peak_bytes_per_s());
    }

    #[test]
    fn pvc_has_low_derived_l2_bandwidth_despite_big_cache() {
        // The paper's §V-E insight: PVC's caches are the largest but the
        // latency-derived bandwidth is the worst of the data-center parts.
        assert!(PVC1100.l2_mb > H100.l2_mb);
        assert!(PVC1100.l2_peak_bytes_per_s() < H100.l2_peak_bytes_per_s() / 4.0);
    }

    #[test]
    fn derived_bandwidth_orders_of_magnitude_sane() {
        // H100 L1 aggregate should be tens of TB/s, L2 single-digit TB/s.
        let l1 = H100.l1_peak_bytes_per_s() / 1e12;
        let l2 = H100.l2_peak_bytes_per_s() / 1e12;
        assert!(l1 > 5.0 && l1 < 100.0, "L1 {l1} TB/s");
        assert!(l2 > 1.0 && l2 < 30.0, "L2 {l2} TB/s");
    }
}
