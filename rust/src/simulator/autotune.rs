//! Auto-tuning (paper §V-E "a heuristic per architecture can be
//! provided" / §VII "future work could integrate auto-tuning
//! approaches").
//!
//! Searches the (TPB, TW, MaxBlocks) space against the hardware
//! performance model for a given (architecture, precision, n, bw)
//! workload — brute force over the paper's grid plus a local refinement
//! pass, which is exactly the structure of the auto-tuners the paper
//! cites [93].

use crate::config::TuneParams;
use crate::obs::calibrate::MeasuredProfile;
use crate::simulator::hw::GpuArch;
use crate::simulator::model::{simulate_reduction_calibrated, BackendCostModel};

/// Result of a tuning run.
#[derive(Clone, Debug)]
pub struct TuneResult {
    pub params: TuneParams,
    pub modeled_seconds: f64,
    /// Configurations evaluated.
    pub evaluated: usize,
}

/// Memoization key for an [`autotune_for`] call — the cache hook the
/// service plan/autotune cache ([`crate::service::PlanCache`]) stores
/// results under. The search is a pure function of exactly these inputs
/// (grid + refinement over the analytical model, no RNG, no hardware
/// probing), so equal keys always reproduce the identical `TuneResult`.
/// Float model fields are keyed by their bit patterns, making the key
/// `Eq + Hash` without tolerance games.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TuneKey {
    arch: &'static str,
    element_bytes: usize,
    n: usize,
    bw: usize,
    dispatch_bits: u64,
    element_size: Option<usize>,
    staged_bits: u64,
    /// [`MeasuredProfile::fingerprint`] of the calibration the search ran
    /// under, or 0 for the uncalibrated (reasoned-model) search — so a
    /// cached result tuned against one machine's measurements never
    /// serves a different profile (or the profile-free search).
    profile_bits: u64,
}

impl TuneKey {
    pub fn new(
        arch: &GpuArch,
        element_bytes: usize,
        n: usize,
        bw: usize,
        backend: &BackendCostModel,
    ) -> Self {
        Self {
            arch: arch.name,
            element_bytes,
            n,
            bw,
            dispatch_bits: backend.dispatch_overhead_s.to_bits(),
            element_size: backend.element_size,
            staged_bits: backend.staged_bytes_per_elem.to_bits(),
            profile_bits: 0,
        }
    }

    /// Key the search under a measured profile's fingerprint
    /// ([`MeasuredProfile::fingerprint`]).
    pub fn with_profile_fingerprint(self, fingerprint: u64) -> Self {
        Self { profile_bits: fingerprint, ..self }
    }
}

/// The paper's hardware-adapted starting heuristic: tilewidth = one full
/// cache line of elements, generous TPB, MaxBlocks sized to the device's
/// execution-unit count.
pub fn heuristic_params(arch: &GpuArch, element_bytes: usize, bw: usize) -> TuneParams {
    let tw = (arch.cache_line_bytes / element_bytes).clamp(1, bw.saturating_sub(1).max(1));
    TuneParams {
        tpb: 32,
        tw,
        // ~1.5 resident blocks per ALU slot keeps latency hiding high
        // without starving per-block L1 (Table III's 192 on 96 slots).
        max_blocks: (arch.alus * 3 / 2).max(32),
    }
}

/// Brute-force grid search (the paper's §IV-a method: "3 parameters
/// across 3–5 values") followed by a local refinement around the best
/// grid point, under the native backend's cost profile.
pub fn autotune(arch: &GpuArch, element_bytes: usize, n: usize, bw: usize) -> TuneResult {
    autotune_for(arch, element_bytes, n, bw, &BackendCostModel::native())
}

/// [`autotune`] for a specific backend: the search costs every candidate
/// with the backend's [`BackendCostModel`]
/// ([`crate::backend::Backend::cost_model`]), so per-launch dispatch
/// overhead and staging traffic shift the optimum exactly as they would
/// on the real executor (a dispatch-heavy backend tilts toward fewer,
/// fuller launches — larger `max_blocks`, wider tilewidths).
pub fn autotune_for(
    arch: &GpuArch,
    element_bytes: usize,
    n: usize,
    bw: usize,
    backend: &BackendCostModel,
) -> TuneResult {
    autotune_for_calibrated(arch, element_bytes, n, bw, backend, None)
}

/// [`autotune_for`] under an optional [`MeasuredProfile`]: every
/// candidate is costed by [`simulate_reduction_calibrated`], so measured
/// per-kernel ns/task — not the reasoned analytical constants — decides
/// the optimum when a calibration is supplied. With `None` this *is*
/// `autotune_for`. Callers caching results must key them with
/// [`TuneKey::with_profile_fingerprint`].
pub fn autotune_for_calibrated(
    arch: &GpuArch,
    element_bytes: usize,
    n: usize,
    bw: usize,
    backend: &BackendCostModel,
    profile: Option<&MeasuredProfile>,
) -> TuneResult {
    let mut evaluated = 0;
    let mut eval = |p: TuneParams| -> f64 {
        evaluated += 1;
        simulate_reduction_calibrated(arch, element_bytes, n, bw, &p, backend, profile).seconds
    };

    let tpb_grid = [8usize, 16, 32, 64, 128];
    let tw_grid = [4usize, 8, 16, 32, 64];
    let mb_grid = [
        arch.alus / 2,
        arch.alus,
        arch.alus * 3 / 2,
        arch.alus * 2,
        arch.alus * 4,
    ];
    let mut best = (f64::INFINITY, heuristic_params(arch, element_bytes, bw));
    for &tpb in &tpb_grid {
        for &tw in &tw_grid {
            if tw >= bw {
                continue;
            }
            for &mb in &mb_grid {
                let p = TuneParams { tpb, tw, max_blocks: mb.max(1) };
                let s = eval(p);
                if s < best.0 {
                    best = (s, p);
                }
            }
        }
    }
    // Local refinement: halve/double each parameter around the optimum.
    let mut improved = true;
    while improved {
        improved = false;
        let base = best.1;
        let candidates = [
            TuneParams { tpb: (base.tpb / 2).max(1), ..base },
            TuneParams { tpb: base.tpb * 2, ..base },
            TuneParams { tw: (base.tw / 2).max(1), ..base },
            TuneParams { tw: (base.tw * 2).min(bw.saturating_sub(1).max(1)), ..base },
            TuneParams { max_blocks: (base.max_blocks / 2).max(1), ..base },
            TuneParams { max_blocks: base.max_blocks * 2, ..base },
        ];
        for p in candidates {
            if p == base || p.tw >= bw {
                continue;
            }
            let s = eval(p);
            if s < best.0 * 0.999 {
                best = (s, p);
                improved = true;
            }
        }
    }
    TuneResult { params: best.1, modeled_seconds: best.0, evaluated }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::hw;
    use crate::simulator::model::{simulate_reduction, simulate_reduction_for};

    #[test]
    fn heuristic_matches_paper_cache_line_rule() {
        assert_eq!(heuristic_params(&hw::H100, 4, 128).tw, 32); // fp32
        assert_eq!(heuristic_params(&hw::H100, 8, 128).tw, 16); // fp64
        assert_eq!(heuristic_params(&hw::H100, 2, 128).tw, 64); // fp16
        // Clamped by the bandwidth.
        assert_eq!(heuristic_params(&hw::H100, 4, 16).tw, 15);
    }

    #[test]
    fn autotune_beats_or_matches_a_bad_config() {
        let bad = TuneParams { tpb: 8, tw: 4, max_blocks: 24 };
        let bad_time = simulate_reduction(&hw::H100, 4, 32768, 128, &bad).seconds;
        let tuned = autotune(&hw::H100, 4, 32768, 128);
        assert!(tuned.modeled_seconds < bad_time, "{tuned:?} vs bad {bad_time}");
        assert!(tuned.evaluated > 50);
    }

    #[test]
    fn autotune_finds_cache_line_tilewidth_at_scale() {
        // The tuned tilewidth at the paper's sweep size must land on the
        // cache-line optimum (32 for fp32, 16 for fp64).
        let fp32 = autotune(&hw::H100, 4, 65536, 128);
        assert_eq!(fp32.params.tw, 32, "{fp32:?}");
        let fp64 = autotune(&hw::H100, 8, 65536, 128);
        assert_eq!(fp64.params.tw, 16, "{fp64:?}");
    }

    #[test]
    fn backend_aware_tuning_is_no_worse_under_its_own_profile() {
        // Tuning *for* the PJRT cost profile must beat (or match) reusing
        // the natively tuned parameters under that same profile — the
        // point of the per-backend hook.
        let profile = BackendCostModel::pjrt();
        let (n, bw) = (16384, 64);
        let native = autotune(&hw::H100, 4, n, bw);
        let for_pjrt = autotune_for(&hw::H100, 4, n, bw, &profile);
        let native_under_pjrt =
            simulate_reduction_for(&hw::H100, 4, n, bw, &native.params, &profile).seconds;
        assert!(
            for_pjrt.modeled_seconds <= native_under_pjrt * 1.0001,
            "pjrt-tuned {} vs native-tuned-under-pjrt {}",
            for_pjrt.modeled_seconds,
            native_under_pjrt
        );
        assert!(for_pjrt.evaluated > 50);
    }

    #[test]
    fn tune_keys_distinguish_exactly_the_search_inputs() {
        let native = BackendCostModel::native();
        let a = TuneKey::new(&hw::H100, 4, 1024, 32, &native);
        assert_eq!(a, TuneKey::new(&hw::H100, 4, 1024, 32, &native));
        assert_ne!(a, TuneKey::new(&hw::A100, 4, 1024, 32, &native));
        assert_ne!(a, TuneKey::new(&hw::H100, 8, 1024, 32, &native));
        assert_ne!(a, TuneKey::new(&hw::H100, 4, 2048, 32, &native));
        assert_ne!(a, TuneKey::new(&hw::H100, 4, 1024, 64, &native));
        assert_ne!(a, TuneKey::new(&hw::H100, 4, 1024, 32, &BackendCostModel::pjrt()));
        assert_ne!(
            TuneKey::new(&hw::H100, 4, 1024, 32, &BackendCostModel::pjrt()),
            TuneKey::new(&hw::H100, 4, 1024, 32, &BackendCostModel::pjrt_tile_streaming())
        );
        // A measured-profile fingerprint is part of the key identity.
        assert_ne!(a, a.with_profile_fingerprint(0xDEAD_BEEF));
        assert_eq!(a.with_profile_fingerprint(7), a.with_profile_fingerprint(7));
        assert_eq!(a, a.with_profile_fingerprint(0), "no profile keys as zero");
    }

    #[test]
    fn measured_profile_overrides_the_reasoned_tilewidth_optimum() {
        // The acceptance property: feeding the tuner a measured profile
        // that contradicts the reasoned constants changes its output.
        // The reasoned model tunes fp32 at the sweep size to the
        // cache-line tilewidth (tw=32, locked in by
        // `autotune_finds_cache_line_tilewidth_at_scale`); a profile in
        // which narrow-tile kernels measured orders of magnitude cheaper
        // per task must drag the optimum off that point.
        use crate::obs::calibrate::{MeasuredProfile, ProfileEntry};
        let entry = |d: usize, packed: bool, ns: f64| ProfileEntry {
            b: 128,
            d,
            es: 4,
            packed,
            tasks: 1000,
            ns_per_task: ns,
        };
        let contradicting = MeasuredProfile {
            entries: vec![entry(4, false, 10.0), entry(32, true, 100_000.0)],
        };
        let native = BackendCostModel::native();
        let calibrated =
            autotune_for_calibrated(&hw::H100, 4, 65536, 128, &native, Some(&contradicting));
        assert!(
            calibrated.params.tw <= 8,
            "measured profile should pull the tilewidth below the \
             reasoned cache-line optimum of 32: {calibrated:?}"
        );
        assert!(calibrated.evaluated > 50);
        // And the degenerate calibration (None) is exactly autotune_for.
        let plain = autotune_for(&hw::H100, 4, 16384, 64, &native);
        let none = autotune_for_calibrated(&hw::H100, 4, 16384, 64, &native, None);
        assert_eq!(plain.params, none.params);
        assert_eq!(plain.modeled_seconds, none.modeled_seconds);
    }

    #[test]
    fn autotune_is_no_worse_than_the_heuristic() {
        for arch in [&hw::H100, &hw::MI300X, &hw::PVC1100] {
            let h = heuristic_params(arch, 4, 64);
            let h_time = simulate_reduction(arch, 4, 16384, 64, &h).seconds;
            let tuned = autotune(arch, 4, 16384, 64);
            assert!(
                tuned.modeled_seconds <= h_time * 1.0001,
                "{}: tuned {} vs heuristic {}",
                arch.name,
                tuned.modeled_seconds,
                h_time
            );
        }
    }
}
