//! GPU hardware performance model.
//!
//! This testbed has no GPU (see DESIGN.md §Hardware-Adaptation): the
//! paper's performance claims are reproduced through an analytical model
//! whose inputs are the public Table II hardware characteristics and
//! whose mechanics follow the paper's own §III-C/D/E reasoning — cache
//! line utilization of the tilewidth, L1-slice fitting, register spill to
//! L2, latency×concurrency bandwidth, occupancy eq. (1), and software
//! loop unrolling past the MaxBlocks limit.
//!
//! - [`hw`]        — Table II architectures (A100…M1) + derived peaks.
//! - [`model`]     — per-launch cost; costs the *same*
//!   [`crate::plan::LaunchPlan`] value the coordinator executes (no
//!   schedule re-derivation in this layer).
//! - [`profile`]   — NSight-style counters (Table III) + geam reference.
//! - [`occupancy`] — eq. (1) / Table I.

pub mod autotune;
pub mod hw;
pub mod model;
pub mod occupancy;
pub mod profile;

pub use autotune::{
    autotune, autotune_for, autotune_for_calibrated, heuristic_params, TuneKey, TuneResult,
};
pub use hw::{all_archs, arch_by_name, GpuArch};
pub use model::{
    launch_cost, simulate_plan, simulate_plan_calibrated, simulate_plan_for, simulate_reduction,
    simulate_reduction_calibrated, simulate_reduction_for, simulate_stage, BackendCostModel,
    LaunchCost, SimReport,
};
pub use occupancy::{full_occupancy_n, occupancy_fraction, table1};
pub use profile::{
    profile_geam_reference, profile_kernel, profile_kernel_calibrated, ProfileMetrics,
};
