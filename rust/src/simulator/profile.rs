//! Kernel profiling counters (paper Table III / §III-E).
//!
//! Emulates the NSight Compute metrics the paper reports for one kernel
//! launch: runtime, achieved throughput fraction per memory level
//! (DRAM / L1 / L2 / total memory), compute throughput, and warps per SM.
//! Also models the CUBLAS `geam` (B = A + Aᵀ) streaming reference the
//! paper profiles for comparison.

use crate::bulge::cycle::stage_uses_packed;
use crate::bulge::schedule::Stage;
use crate::obs::calibrate::MeasuredProfile;
use crate::simulator::hw::GpuArch;
use crate::simulator::model::launch_cost;

/// NSight-style metrics for one kernel configuration.
#[derive(Clone, Debug)]
pub struct ProfileMetrics {
    pub time_us: f64,
    /// Total memory throughput (max over levels), % of peak.
    pub memory_pct: f64,
    pub dram_pct: f64,
    pub l1_pct: f64,
    pub l2_pct: f64,
    pub compute_pct: f64,
    pub warps_per_sm: f64,
    pub bound_by: &'static str,
}

/// Profile one launch of the bulge-chasing kernel: stage (b, d), element
/// size `es`, `blocks` concurrent bulge tasks (paper: n = 32k, b = 64,
/// full parallelism ⇒ blocks = n / (3·64) ≈ 170).
pub fn profile_kernel(
    arch: &GpuArch,
    es: usize,
    stage: &Stage,
    tpb: usize,
    max_blocks: usize,
    blocks: usize,
) -> ProfileMetrics {
    profile_kernel_calibrated(arch, es, stage, tpb, max_blocks, blocks, None)
}

/// [`profile_kernel`] with an optional [`MeasuredProfile`]: when the
/// launch's kernel class was measured, the busy time (and so every
/// achieved-throughput percentage) is derived from the *measured*
/// ns/task instead of the analytical launch cost — byte and flop counts
/// stay algorithmic, exactly as NSight reports measured time against
/// known traffic. `profile_kernel_calibrated(.., None)` ≡
/// [`profile_kernel`].
#[allow(clippy::too_many_arguments)]
pub fn profile_kernel_calibrated(
    arch: &GpuArch,
    es: usize,
    stage: &Stage,
    tpb: usize,
    max_blocks: usize,
    blocks: usize,
    measured: Option<&MeasuredProfile>,
) -> ProfileMetrics {
    let cost = launch_cost(arch, es, stage, tpb, max_blocks, blocks);
    // Achieved rates come from the modeled launch time (occupancy-driven
    // bandwidth efficiency is already folded into the cost) — or from the
    // measured per-task time when a calibration covers this kernel class.
    let measured_busy = measured
        .and_then(|p| p.ns_per_task(stage.b, stage.d, es, stage_uses_packed(stage)))
        .map(|ns_per_task| blocks as f64 * ns_per_task * 1e-9);
    let busy = measured_busy.unwrap_or(cost.seconds - arch.launch_overhead_s()).max(1e-9);
    let time_us = (busy + arch.launch_overhead_s()) * 1e6;

    let dram_pct = 100.0 * (cost.dram_bytes / busy) / arch.dram_peak_bytes_per_s();
    let l1_pct = 100.0 * (cost.l1_bytes / busy) / arch.l1_peak_bytes_per_s();
    let l2_pct = 100.0 * (cost.l2_bytes / busy) / arch.l2_peak_bytes_per_s();
    let compute_pct = 100.0 * (cost.flops / busy)
        / (arch.fp32_peak_flops() * (4.0 / es as f64).clamp(0.5, 2.0));
    let memory_pct = dram_pct.max(l1_pct).max(l2_pct);

    // Warps per SM: resident threads / 32 (matches Table III's row).
    let warps_per_sm = cost.active_blocks as f64 / arch.units as f64 * tpb as f64 / 32.0;

    ProfileMetrics {
        time_us,
        memory_pct,
        dram_pct,
        l1_pct,
        l2_pct,
        compute_pct,
        warps_per_sm,
        bound_by: cost.bound_by,
    }
}

/// The paper's reference profile: CUBLAS `geam` B = A + Aᵀ on a dense
/// m×m matrix — a pure streaming kernel with no reuse: high DRAM
/// throughput (~78%), low L1/L2 reuse (~18%).
pub fn profile_geam_reference(arch: &GpuArch, es: usize, m: usize) -> ProfileMetrics {
    let bytes = 3.0 * (m as f64) * (m as f64) * es as f64; // read A twice (row+col order), write B
    // Transpose access: column-order reads waste most of each line until
    // the tile fits; model the classic tiled transpose at ~80% DRAM eff.
    let t_dram = bytes / (arch.dram_peak_bytes_per_s() * 0.78);
    let time_us = t_dram * 1e6;
    // No reuse: every byte passes each level exactly once, so the cache
    // levels run far under their (much higher) peaks.
    let dram_pct = 78.0;
    let l1_pct = 100.0 * (bytes / t_dram) / arch.l1_peak_bytes_per_s();
    let l2_pct = 100.0 * (bytes / t_dram) / arch.l2_peak_bytes_per_s();
    ProfileMetrics {
        time_us,
        memory_pct: dram_pct,
        dram_pct,
        l1_pct,
        l2_pct,
        compute_pct: 5.0,
        warps_per_sm: 12.0,
        bound_by: "dram",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::hw;

    /// Table III's workload: RTX4060, 32k matrix, bandwidth 64 → 32
    /// (tw=32) or 64 → 48 (tw=16), full parallelism.
    fn table3_case(tpb: usize, max_blocks: usize, tw: usize) -> ProfileMetrics {
        let stage = Stage::new(64, tw);
        let blocks = 32768 / (3 * 64);
        profile_kernel(&hw::RTX4060, 4, &stage, tpb, max_blocks, blocks)
    }

    #[test]
    fn best_config_matches_table3_shape() {
        // Best config (32, 192, 32): memory ~52%, L1 ~64%, DRAM ~16%,
        // compute low. We check the *shape*: L1 > L2 ≥ memory-ish,
        // DRAM ≪ L1, compute ≪ memory.
        let m = table3_case(32, 192, 32);
        assert!(m.l1_pct > m.dram_pct * 2.0, "L1 {} vs DRAM {}", m.l1_pct, m.dram_pct);
        assert!(m.l2_pct > m.dram_pct, "L2 {} vs DRAM {}", m.l2_pct, m.dram_pct);
        assert!(m.compute_pct < m.memory_pct, "compute-bound?");
        assert!(m.time_us > 10.0 && m.time_us < 1000.0, "time {}", m.time_us);
    }

    #[test]
    fn smaller_tilewidth_lowers_cache_throughput() {
        // Table III configurations A vs B: tw=16 shows lower L1/L2
        // throughput at similar DRAM throughput.
        let a = table3_case(16, 192, 32);
        let b = table3_case(32, 96, 16);
        assert!(
            b.l1_pct < a.l1_pct,
            "B L1 {} should be below A L1 {}",
            b.l1_pct,
            a.l1_pct
        );
        // tw=16 must run ~2× to reduce as much: per-tilewidth time is
        // what the paper compares. B's single-launch time may be lower.
        assert!(b.time_us / 16.0 > 0.8 * a.time_us / 32.0 * 0.5, "sanity");
    }

    #[test]
    fn runtime_correlates_with_memory_not_dram() {
        // §III-E: "runtime correlates more strongly with total memory
        // throughput than with DRAM throughput alone" — across the
        // Table III grid, the fastest per-tilewidth config has the
        // highest total-memory %, not the highest DRAM %.
        let grid = [
            (64, 48, 32),
            (64, 96, 32),
            (32, 96, 32),
            (32, 192, 32),
            (16, 192, 32),
        ];
        let metrics: Vec<ProfileMetrics> =
            grid.iter().map(|&(tpb, mb, tw)| table3_case(tpb, mb, tw)).collect();
        let fastest = metrics
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.time_us.partial_cmp(&b.1.time_us).unwrap())
            .unwrap()
            .0;
        let best_mem = metrics
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.memory_pct.partial_cmp(&b.1.memory_pct).unwrap())
            .unwrap()
            .0;
        assert_eq!(fastest, best_mem, "fastest config should have top memory%");
    }

    #[test]
    fn geam_reference_profile_shape() {
        // ~78% DRAM, low L1/L2 (§III-E): streaming vs our reuse-heavy
        // kernel.
        let g = profile_geam_reference(&hw::RTX4060, 4, 16384);
        assert!((g.dram_pct - 78.0).abs() < 1.0);
        assert!(g.l1_pct < 30.0, "L1 {}", g.l1_pct);
        assert!(g.l2_pct < 60.0, "L2 {}", g.l2_pct);
        let ours = table3_case(32, 192, 32);
        assert!(ours.l1_pct > g.l1_pct, "our kernel must show cache reuse");
        assert!(ours.dram_pct < g.dram_pct, "ours trades DRAM for reuse");
    }

    #[test]
    fn warps_scale_with_tpb_and_blocks() {
        let lo = table3_case(16, 48, 32);
        let hi = table3_case(64, 192, 32);
        assert!(hi.warps_per_sm > lo.warps_per_sm);
    }

    #[test]
    fn measured_profile_rescales_achieved_throughput() {
        use crate::obs::calibrate::{MeasuredProfile, ProfileEntry};
        let stage = Stage::new(64, 32);
        let blocks = 32768 / (3 * 64);
        let modeled = profile_kernel(&hw::RTX4060, 4, &stage, 32, 192, blocks);
        // None is bit-identical to the uncalibrated entry point.
        let none = profile_kernel_calibrated(&hw::RTX4060, 4, &stage, 32, 192, blocks, None);
        assert_eq!(none.time_us, modeled.time_us);
        assert_eq!(none.l1_pct, modeled.l1_pct);
        // A kernel measured 10× slower than the model halves-and-more
        // every achieved-throughput percentage: same traffic over more
        // time.
        let modeled_busy_ns =
            (modeled.time_us - hw::RTX4060.launch_overhead_s() * 1e6) * 1e3;
        let slow = MeasuredProfile {
            entries: vec![ProfileEntry {
                b: 64,
                d: 32,
                es: 4,
                packed: true,
                tasks: blocks as u64,
                ns_per_task: 10.0 * modeled_busy_ns / blocks as f64,
            }],
        };
        let calibrated =
            profile_kernel_calibrated(&hw::RTX4060, 4, &stage, 32, 192, blocks, Some(&slow));
        assert!(calibrated.time_us > 5.0 * modeled.time_us);
        assert!(calibrated.l1_pct < modeled.l1_pct / 5.0);
        assert!(calibrated.dram_pct < modeled.dram_pct / 5.0);
        assert_eq!(calibrated.bound_by, modeled.bound_by, "bound label stays modeled");
    }
}
