//! Analytical launch-cost model for the bulge-chasing kernel
//! (paper §III-B/C/D/E).
//!
//! Everything is derived from algorithm-level access counts (the same
//! counts the paper reasons with) and the Table II hardware numbers:
//!
//! - A task (thread block) touches a `(1+b+d) × (d+1)` tile twice (right
//!   op + left op), read + write, in `passes` sweeps (gather, apply,
//!   write-back — Alg. 2's loop structure).
//! - Cache-line utilization of the short (left-op) column segments is
//!   `min(1, (d+1)·es / line)` — the mechanism behind the paper's
//!   "tilewidth = one full cache line" optimum (32 FP32 / 16 FP64).
//! - The first pass streams from L2; later passes hit L1 for the
//!   fraction of the tile that fits the block's L1 slice; register
//!   spills (per-thread row exceeding the register budget) re-route
//!   traffic to L2 (§III-B).
//! - Concurrency = min(blocks, MaxBlocks, ALU slots); excess blocks
//!   serialize ("software loop unrolling", §III-C-c). MaxBlocks is the
//!   device-wide cap (Table III uses 48–192 on a 24-SM part).
//! - A launch costs max(latency term, per-level bandwidth terms, compute
//!   term) + launch overhead; a reduction sums over the launch schedule
//!   of the stage plan (closed forms, no numerics).

use crate::bulge::cycle::stage_uses_packed;
use crate::bulge::schedule::Stage;
use crate::config::TuneParams;
use crate::obs::calibrate::MeasuredProfile;
use crate::plan::{slot_bytes, LaunchPlan};
use crate::simulator::hw::GpuArch;

/// L1 passes over the tile per op: gather, HH dot, apply, write-back,
/// plus vector re-broadcasts — each element is touched repeatedly through
/// L1/shared while only the first touch reaches L2 (§III-E: "our kernel
/// reuses the same elements multiple times through L1/L2 caching").
const PASSES: f64 = 6.0;

/// Cost and traffic breakdown of a single kernel launch.
#[derive(Clone, Debug, Default)]
pub struct LaunchCost {
    pub seconds: f64,
    pub dram_bytes: f64,
    pub l2_bytes: f64,
    pub l1_bytes: f64,
    pub flops: f64,
    /// Which term bounded the launch ("latency"|"l1"|"l2"|"dram"|"compute").
    pub bound_by: &'static str,
    /// Concurrently executing blocks.
    pub active_blocks: usize,
    /// Serialization multiplier (ceil(blocks / active)).
    pub unroll: usize,
}

/// Aggregate simulation result for a full reduction (or one stage).
#[derive(Clone, Debug, Default)]
pub struct SimReport {
    pub seconds: f64,
    pub launches: usize,
    pub tasks: usize,
    pub dram_bytes: f64,
    pub l2_bytes: f64,
    pub l1_bytes: f64,
    pub flops: f64,
    /// Algorithmic byte traffic ([`slot_bytes`]) — the same plan-derived
    /// quantity the executor's `LaunchMetrics` records, so predicted and
    /// executed traffic can be compared exactly, launch by launch.
    pub algo_bytes: u64,
    /// Tasks per launch, in plan order (mirrors
    /// `LaunchMetrics::per_launch` on the execution side).
    pub per_launch: Vec<u32>,
}

impl SimReport {
    pub fn add_launch(&mut self, c: &LaunchCost) {
        self.seconds += c.seconds;
        self.launches += 1;
        self.dram_bytes += c.dram_bytes;
        self.l2_bytes += c.l2_bytes;
        self.l1_bytes += c.l1_bytes;
        self.flops += c.flops;
    }

    pub fn merge(&mut self, o: &SimReport) {
        self.seconds += o.seconds;
        self.launches += o.launches;
        self.tasks += o.tasks;
        self.dram_bytes += o.dram_bytes;
        self.l2_bytes += o.l2_bytes;
        self.l1_bytes += o.l1_bytes;
        self.flops += o.flops;
        self.algo_bytes += o.algo_bytes;
        self.per_launch.extend_from_slice(&o.per_launch);
    }
}

/// Model one kernel launch executing `blocks` bulge tasks of stage
/// (b, d) in element size `es` with tuning `(tpb, max_blocks)`, under
/// the native (scalar-issue, zero-dispatch) backend profile.
pub fn launch_cost(
    arch: &GpuArch,
    es: usize,
    stage: &Stage,
    tpb: usize,
    max_blocks: usize,
    blocks: usize,
) -> LaunchCost {
    launch_cost_for(arch, es, stage, tpb, max_blocks, blocks, &BackendCostModel::native())
}

/// [`launch_cost`] under a backend's [`BackendCostModel`]: a non-zero
/// `vector_width_bytes` scales the compute term by the lane speedup
/// `1 + 0.6·(lanes − 1)` with `lanes = vector_width / es` — full-width
/// issue discounted for the scalar tails, reflector latency chains, and
/// below-gate stages the vector path cannot touch. Memory terms are
/// unchanged: SIMD does not add bandwidth, so a launch that was
/// bandwidth- or latency-bound stays exactly where it was.
pub fn launch_cost_for(
    arch: &GpuArch,
    es: usize,
    stage: &Stage,
    tpb: usize,
    max_blocks: usize,
    blocks: usize,
    backend: &BackendCostModel,
) -> LaunchCost {
    if blocks == 0 {
        return LaunchCost { seconds: arch.launch_overhead_s(), ..Default::default() };
    }
    let b = stage.b as f64;
    let d = stage.d as f64;
    let es_f = es as f64;
    let line = arch.cache_line_bytes as f64;
    let tpb_f = tpb.max(1) as f64;

    // --- Per-task element counts ------------------------------------
    let tile_elems = (1.0 + b + d) * (d + 1.0);
    let task_elems = 2.0 * tile_elems; // right + left op

    // Cache-line utilization: long segments (right op, 1+b+d elements)
    // vs short segments (left op, d+1 elements — the TW-sensitive term).
    let u_right = ((1.0 + b + d) * es_f / line).min(1.0);
    let u_left = ((d + 1.0) * es_f / line).min(1.0);
    // Line-padded bytes for one read+write pass over both tiles.
    let pass_bytes = 2.0 * tile_elems * es_f * (1.0 / u_right + 1.0 / u_left);

    // --- Concurrency ---------------------------------------------------
    // MaxBlocks is device-wide; per-unit residency drives L1 sharing.
    let blocks_per_unit = max_blocks.div_ceil(arch.units).max(1);
    // Register budget per thread; a spilled row re-routes to L2.
    let reg_bytes_per_thread =
        arch.reg_per_unit_kb * 1024.0 / (blocks_per_unit as f64 * tpb_f);
    let row_bytes = (d + 1.0) * es_f;
    let spill = (row_bytes / reg_bytes_per_thread - 1.0).clamp(0.0, 1.0);
    // Resident blocks: bounded by the MaxBlocks cap (residency beyond
    // the ALU count is normal — resident warps are what hide latency).
    let resident = blocks.min(max_blocks).max(1);
    let unroll = blocks.div_ceil(resident);
    // Warps per unit drive achieved-bandwidth efficiency (latency
    // hiding): eff = w/(w+2.5) saturates around 8–10 warps/unit, the
    // regime Table III's best configurations sit in.
    let warps_per_unit = resident as f64 / arch.units as f64 * tpb_f / 32.0;
    let eff = (warps_per_unit / (warps_per_unit + 2.5)).max(0.05);
    let active = resident;

    // --- Traffic by level ----------------------------------------------
    // L1 sees every pass.
    let l1_bytes = blocks as f64 * pass_bytes * PASSES;
    // First pass streams from L2; later passes hit L1 for the fitting
    // fraction of the working set (tile + Householder vector).
    let l1_slice = arch.l1_per_unit_kb * 1024.0 / blocks_per_unit as f64;
    let ws_bytes = tile_elems * es_f + (d + 1.0) * es_f;
    let fit = (l1_slice / ws_bytes).min(1.0);
    let l2_factor = 1.0 + (PASSES - 1.0) * (1.0 - fit) + (PASSES - 1.0) * spill;
    let l2_bytes = blocks as f64 * pass_bytes * l2_factor;
    // DRAM: the chase advances b columns per cycle — only the fresh
    // window streams from DRAM while the overlap stays in L2 (if the
    // per-launch footprint fits; beyond capacity everything re-streams).
    let window_bytes = 2.0 * b * (d + 1.0) * es_f / u_left;
    let l2_capacity = arch.l2_mb * 1e6;
    let resident = blocks as f64 * tile_elems * es_f;
    let l2_hit = if resident <= l2_capacity { 1.0 } else { l2_capacity / resident };
    let dram_bytes = blocks as f64 * (window_bytes + (1.0 - l2_hit) * pass_bytes);

    // --- Flops -----------------------------------------------------------
    let flops = blocks as f64 * (4.0 * task_elems + 10.0 * (d + 1.0));

    // --- Time terms -------------------------------------------------------
    // Serialization ("software loop unrolling"): only `active` blocks run
    // at a time, so the launch executes `unroll` batches back-to-back —
    // every term is per-batch, multiplied by `unroll`.
    let clock_hz = arch.clock_ghz * 1e9;
    let batch = active as f64 / blocks as f64;
    // Latency term: ceil((1+b+d)/tpb) dependent chunk round-trips per op,
    // each an L2-latency access plus d+1 pipelined lanes of math.
    let chunks = ((1.0 + b + d) / tpb_f).ceil();
    let trip_cycles = arch.l2_lat_cycles + (d + 1.0);
    let t_latency = 2.0 * chunks * trip_cycles / clock_hz;
    let t_l1 = batch * l1_bytes / (arch.l1_peak_bytes_per_s() * eff);
    let t_l2 = batch * l2_bytes / (arch.l2_peak_bytes_per_s() * eff);
    let t_dram = batch * dram_bytes / (arch.dram_peak_bytes_per_s() * eff);
    // Element-size-aware vector throughput (fp16 ≈ 2× fp32; fp64 ≈ ½),
    // times the backend's lane speedup (1.0 for scalar-issue backends).
    let lanes = (backend.vector_width_bytes / es_f).max(1.0);
    let lane_speedup = 1.0 + 0.6 * (lanes - 1.0);
    let t_compute = batch * flops
        / (arch.fp32_peak_flops() * (4.0 / es_f).clamp(0.5, 2.0) * lane_speedup);

    let mut per_batch = t_latency;
    let mut bound_by = "latency";
    for (t, name) in [
        (t_l1, "l1"),
        (t_l2, "l2"),
        (t_dram, "dram"),
        (t_compute, "compute"),
    ] {
        if t > per_batch {
            per_batch = t;
            bound_by = name;
        }
    }
    let seconds = unroll as f64 * per_batch;
    LaunchCost {
        seconds: seconds + arch.launch_overhead_s(),
        dram_bytes,
        l2_bytes,
        l1_bytes,
        flops,
        bound_by,
        active_blocks: active,
        unroll,
    }
}

/// Backend-specific adjustments to the plan-cost model — the hook
/// [`crate::backend::Backend::cost_model`] feeds into
/// [`simulate_plan_for`] / [`crate::simulator::autotune_for`] so the
/// autotuner tunes for the backend that will actually run (dispatch
/// overheads and staging traffic differ by orders of magnitude between a
/// native launch loop and a PJRT call).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BackendCostModel {
    /// Extra host-side overhead per launch (seconds), paid on top of the
    /// device launch overhead — the dispatch/FFI cost of issuing one
    /// launch through the backend.
    pub dispatch_overhead_s: f64,
    /// Element size the backend forces, if any (PJRT artifacts execute
    /// in f32 regardless of the in-memory precision).
    pub element_size: Option<usize>,
    /// Host↔device staging bytes charged per packed-footprint element
    /// per launch ([`LaunchPlan::launch_footprint_elems`]) — zero for
    /// device-resident backends; positive for tile-streaming execution
    /// that uploads/downloads each launch's footprint.
    pub staged_bytes_per_elem: f64,
    /// Vector register width (bytes) the backend's packed kernels issue
    /// at, or `0.0` for scalar issue. Feeds the compute-term lane
    /// speedup in [`launch_cost_for`]; `lanes = width / element_size`,
    /// so one width models f64×4 and f32×8 at once (a 32-byte AVX2
    /// register, the paper-repro host baseline).
    pub vector_width_bytes: f64,
}

impl BackendCostModel {
    /// The native launch loop: no per-launch host overhead beyond the
    /// modeled device overhead, runs at the storage precision, fully
    /// resident.
    pub fn native() -> Self {
        Self {
            dispatch_overhead_s: 0.0,
            element_size: None,
            staged_bytes_per_elem: 0.0,
            vector_width_bytes: 0.0,
        }
    }

    /// The SIMD launch loop: the native profile with packed kernels
    /// issuing 32-byte (AVX2-class) vectors — same dispatch, same
    /// storage precision, same residency; only the compute term speeds
    /// up, so memory-bound launches cost exactly what native ones do.
    pub fn simd() -> Self {
        Self { vector_width_bytes: 32.0, ..Self::native() }
    }

    /// The PJRT plan executor: one FFI call per launch (≈ µs-scale
    /// dispatch), f32 artifacts, device-resident buffers (no per-launch
    /// staging — storage uploads once per problem).
    pub fn pjrt() -> Self {
        Self {
            dispatch_overhead_s: 3e-6,
            element_size: Some(4),
            staged_bytes_per_elem: 0.0,
            vector_width_bytes: 0.0,
        }
    }

    /// A hypothetical tile-streaming PJRT executor that stages each
    /// launch's packed footprint up and down (8 bytes per f32 element):
    /// the quantity to beat when deciding whether tile-payload artifacts
    /// are worth compiling (see `docs/performance-model.md`).
    pub fn pjrt_tile_streaming() -> Self {
        Self { staged_bytes_per_elem: 8.0, ..Self::pjrt() }
    }
}

impl Default for BackendCostModel {
    fn default() -> Self {
        Self::native()
    }
}

/// Cost every launch of a [`LaunchPlan`] — the *same value* the
/// backends execute, so the simulator never re-derives a
/// schedule of its own: launch count, tasks per launch, and algorithmic
/// byte traffic agree with the executor by construction (property-tested
/// in `rust/tests/plan_consistency.rs`).
///
/// Multi-slot (batched) launches cost each slot's blocks independently and
/// pay the launch overhead once. Costs are cached per distinct
/// `(problem, stage, count)` — counts repeat across a stage's plateau and
/// ramps, so the cache stays tiny even for very long plans.
///
/// `es` applies to every slot of the plan. For a *mixed-precision* merged
/// plan the executor accounts each problem at its own element size, so to
/// get exact byte agreement there, cost each problem's single-problem
/// plan at its own `es` (the exactness contract is per
/// `(n, bw, TuneParams)` problem, which is also all the autotuner needs).
pub fn simulate_plan(arch: &GpuArch, es: usize, plan: &LaunchPlan, tpb: usize) -> SimReport {
    simulate_plan_for(arch, es, plan, tpb, &BackendCostModel::native())
}

/// [`simulate_plan`] with a backend's [`BackendCostModel`] applied: the
/// per-launch dispatch overhead, the forced element size, and (for
/// tile-streaming backends) per-launch footprint staging at DRAM
/// bandwidth. `simulate_plan(..)` ≡
/// `simulate_plan_for(.., &BackendCostModel::native())`.
pub fn simulate_plan_for(
    arch: &GpuArch,
    es: usize,
    plan: &LaunchPlan,
    tpb: usize,
    backend: &BackendCostModel,
) -> SimReport {
    simulate_plan_calibrated(arch, es, plan, tpb, backend, None)
}

/// [`simulate_plan_for`] with an optional [`MeasuredProfile`]: when a
/// profile is present, each slot's busy time comes from the *measured*
/// ns-per-task of its kernel class (`(stage.b, stage.d, element size,
/// packed-vs-inplace)`, with the profile's nearest-neighbor fallback)
/// instead of the analytical terms, while launch overheads, dispatch
/// costs, staging, and all traffic accounting stay modeled — measurement
/// replaces exactly the constants it measured, nothing else.
/// `simulate_plan_calibrated(.., None)` ≡ `simulate_plan_for(..)`.
pub fn simulate_plan_calibrated(
    arch: &GpuArch,
    es: usize,
    plan: &LaunchPlan,
    tpb: usize,
    backend: &BackendCostModel,
    profile: Option<&MeasuredProfile>,
) -> SimReport {
    let es = backend.element_size.unwrap_or(es);
    let mut report = SimReport::default();
    let overhead = arch.launch_overhead_s();
    let mut cache: std::collections::HashMap<(u32, u32, u32), LaunchCost> =
        std::collections::HashMap::new();
    for li in 0..plan.num_launches() {
        let mut busy = 0.0;
        let mut launch_tasks = 0usize;
        for slot in plan.launch(li) {
            let stage = plan.slot_stage(slot);
            let cost = cache
                .entry((slot.problem, slot.stage, slot.count))
                .or_insert_with(|| {
                    launch_cost_for(
                        arch,
                        es,
                        stage,
                        tpb,
                        plan.capacity,
                        slot.count as usize,
                        backend,
                    )
                });
            let measured = profile.and_then(|p| {
                p.ns_per_task(stage.b, stage.d, es, stage_uses_packed(stage))
            });
            busy += match measured {
                Some(ns_per_task) => slot.count as f64 * ns_per_task * 1e-9,
                None => cost.seconds - overhead,
            };
            report.dram_bytes += cost.dram_bytes;
            report.l2_bytes += cost.l2_bytes;
            report.l1_bytes += cost.l1_bytes;
            report.flops += cost.flops;
            report.algo_bytes += slot_bytes(stage, slot.count as usize, es);
            launch_tasks += slot.count as usize;
        }
        let staging = if backend.staged_bytes_per_elem > 0.0 {
            let bytes = plan.launch_footprint_elems(li) as f64 * backend.staged_bytes_per_elem;
            report.dram_bytes += bytes;
            bytes / arch.dram_peak_bytes_per_s()
        } else {
            0.0
        };
        report.launches += 1;
        report.tasks += launch_tasks;
        report.per_launch.push(launch_tasks as u32);
        report.seconds += busy + overhead + backend.dispatch_overhead_s + staging;
    }
    report
}

/// Simulate one full stage: lower its (non-empty) launches to a
/// single-stage plan and cost that.
pub fn simulate_stage(
    arch: &GpuArch,
    es: usize,
    n: usize,
    stage: &Stage,
    tpb: usize,
    max_blocks: usize,
) -> SimReport {
    simulate_plan(arch, es, &LaunchPlan::from_stages(n, vec![*stage], max_blocks), tpb)
}

/// Simulate a full banded→bidiagonal reduction: lower the identical
/// [`LaunchPlan`] the coordinator would execute for `(n, bw, params)` and
/// cost it launch by launch.
pub fn simulate_reduction(
    arch: &GpuArch,
    es: usize,
    n: usize,
    bw: usize,
    params: &TuneParams,
) -> SimReport {
    simulate_plan(arch, es, &LaunchPlan::for_problem(n, bw, params), params.tpb)
}

/// [`simulate_reduction`] under a backend's [`BackendCostModel`] — lower
/// the identical plan, cost it for the backend that will actually run.
pub fn simulate_reduction_for(
    arch: &GpuArch,
    es: usize,
    n: usize,
    bw: usize,
    params: &TuneParams,
    backend: &BackendCostModel,
) -> SimReport {
    simulate_plan_for(arch, es, &LaunchPlan::for_problem(n, bw, params), params.tpb, backend)
}

/// [`simulate_reduction_for`] under an optional [`MeasuredProfile`] —
/// the calibrated entry point [`crate::simulator::autotune_for_calibrated`]
/// searches with.
pub fn simulate_reduction_calibrated(
    arch: &GpuArch,
    es: usize,
    n: usize,
    bw: usize,
    params: &TuneParams,
    backend: &BackendCostModel,
    profile: Option<&MeasuredProfile>,
) -> SimReport {
    simulate_plan_calibrated(
        arch,
        es,
        &LaunchPlan::for_problem(n, bw, params),
        params.tpb,
        backend,
        profile,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::hw;

    fn params(tpb: usize, tw: usize, mb: usize) -> TuneParams {
        TuneParams { tpb, tw, max_blocks: mb }
    }

    #[test]
    fn larger_matrices_take_longer() {
        let p = params(32, 32, 192);
        let t1 = simulate_reduction(&hw::H100, 4, 4096, 64, &p).seconds;
        let t2 = simulate_reduction(&hw::H100, 4, 16384, 64, &p).seconds;
        assert!(t2 > 2.0 * t1, "{t1} vs {t2}");
    }

    #[test]
    fn runtime_scales_roughly_linearly_with_bandwidth() {
        // Paper abstract: "performance scales linearly with the matrix
        // bandwidth".
        let p = params(32, 32, 192);
        let n = 8192;
        let t64 = simulate_reduction(&hw::H100, 4, n, 64, &p).seconds;
        let t128 = simulate_reduction(&hw::H100, 4, n, 128, &p).seconds;
        let t256 = simulate_reduction(&hw::H100, 4, n, 256, &p).seconds;
        let r1 = t128 / t64;
        let r2 = t256 / t128;
        assert!(r1 > 1.2 && r1 < 4.0, "r1={r1}");
        assert!(r2 > 1.2 && r2 < 4.0, "r2={r2}");
    }

    #[test]
    fn fp32_optimal_tilewidth_is_32() {
        // Fig. 4 headline: cache-line tilewidth (128 B / 4 B = 32) wins
        // at the paper's 65k hyperparameter-sweep size.
        let n = 65536;
        let t = |tw| simulate_reduction(&hw::H100, 4, n, 128, &params(32, tw, 192)).seconds;
        let (t16, t32, t64) = (t(16), t(32), t(64));
        assert!(t32 < t16, "tw=32 ({t32}) should beat tw=16 ({t16})");
        assert!(t32 < t64, "tw=32 ({t32}) should beat tw=64 ({t64})");
    }

    #[test]
    fn fp64_optimal_tilewidth_is_16() {
        let n = 65536;
        let t = |tw| simulate_reduction(&hw::H100, 8, n, 128, &params(32, tw, 192)).seconds;
        let (t8, t16, t32) = (t(8), t(16), t(32));
        assert!(t16 < t8, "tw=16 ({t16}) should beat tw=8 ({t8})");
        assert!(t16 < t32, "tw=16 ({t16}) should beat tw=32 ({t32})");
    }

    #[test]
    fn h100_beats_a100_and_mi300x_beats_mi250x() {
        // Fig. 5: architecture generation gains.
        let p = params(32, 32, 192);
        let n = 16384;
        let h100 = simulate_reduction(&hw::H100, 4, n, 64, &p).seconds;
        let a100 = simulate_reduction(&hw::A100, 4, n, 64, &p).seconds;
        assert!(a100 > 1.05 * h100, "A100 {a100} vs H100 {h100}");
        let mi300 = simulate_reduction(&hw::MI300X, 4, n, 64, &p).seconds;
        let mi250 = simulate_reduction(&hw::MI250X, 4, n, 64, &p).seconds;
        assert!(mi250 > 1.1 * mi300, "MI250X {mi250} vs MI300X {mi300}");
    }

    #[test]
    fn pvc_is_an_order_of_magnitude_behind_h100() {
        // Fig. 7 / §V-E: PVC far slower despite larger caches.
        let p = params(32, 32, 192);
        let n = 32768;
        let h100 = simulate_reduction(&hw::H100, 4, n, 32, &p).seconds;
        let pvc = simulate_reduction(&hw::PVC1100, 4, n, 32, &p).seconds;
        let ratio = pvc / h100;
        assert!(ratio > 4.0, "PVC/H100 = {ratio}");
    }

    #[test]
    fn more_blocks_and_threads_help_at_scale() {
        // Fig. 4: larger max_blocks / tpb generally faster (tilewidth at
        // its optimum).
        let n = 32768;
        let slow = simulate_reduction(&hw::H100, 4, n, 128, &params(8, 32, 24)).seconds;
        let fast = simulate_reduction(&hw::H100, 4, n, 128, &params(64, 32, 192)).seconds;
        assert!(fast < slow, "fast {fast} vs slow {slow}");
    }

    #[test]
    fn launch_cost_reports_positive_traffic() {
        let stage = Stage::new(64, 32);
        let c = launch_cost(&hw::RTX4060, 4, &stage, 32, 192, 96);
        assert!(c.seconds > 0.0);
        assert!(c.dram_bytes > 0.0 && c.l1_bytes > c.dram_bytes);
        assert!(c.active_blocks >= 1 && c.unroll >= 1);
    }

    #[test]
    fn zero_blocks_costs_only_overhead() {
        let stage = Stage::new(8, 4);
        let c = launch_cost(&hw::H100, 4, &stage, 32, 192, 0);
        assert_eq!(c.seconds, hw::H100.launch_overhead_s());
    }

    #[test]
    fn plan_costing_matches_naive_per_launch_sum() {
        let stage = Stage::new(8, 4);
        let n = 512;
        let grouped = simulate_stage(&hw::H100, 4, n, &stage, 32, 192);
        // Naive sum over the schedule's *non-empty* launches (the plan
        // never lowers empty cycles, matching what executors run).
        let mut naive = SimReport::default();
        for t in 0..stage.total_launches(n) {
            let blocks = stage.tasks_at_count(n, t);
            if blocks == 0 {
                continue;
            }
            naive.tasks += blocks;
            naive.add_launch(&launch_cost(&hw::H100, 4, &stage, 32, 192, blocks));
        }
        assert_eq!(grouped.launches, naive.launches);
        assert_eq!(grouped.tasks, naive.tasks);
        assert!((grouped.seconds - naive.seconds).abs() < 1e-9 * naive.seconds.max(1e-12));
    }

    #[test]
    fn backend_cost_hook_orders_backends_sensibly() {
        let p = params(32, 4, 16);
        let plan = LaunchPlan::for_problem(256, 8, &p);
        let native = simulate_plan_for(&hw::H100, 4, &plan, 32, &BackendCostModel::native());
        let pjrt = simulate_plan_for(&hw::H100, 4, &plan, 32, &BackendCostModel::pjrt());
        let streaming =
            simulate_plan_for(&hw::H100, 4, &plan, 32, &BackendCostModel::pjrt_tile_streaming());
        // The default entry point is exactly the native profile.
        assert_eq!(native.seconds, simulate_plan(&hw::H100, 4, &plan, 32).seconds);
        // Per-launch dispatch overhead and footprint staging stack up.
        assert!(pjrt.seconds > native.seconds, "{} vs {}", pjrt.seconds, native.seconds);
        assert!(streaming.seconds > pjrt.seconds);
        assert!(streaming.dram_bytes > pjrt.dram_bytes);
        // The PJRT profile forces f32 regardless of storage precision.
        let native64 = simulate_plan_for(&hw::H100, 8, &plan, 32, &BackendCostModel::native());
        let pjrt64 = simulate_plan_for(&hw::H100, 8, &plan, 32, &BackendCostModel::pjrt());
        assert_eq!(pjrt64.algo_bytes * 2, native64.algo_bytes);
    }

    #[test]
    fn simd_profile_speeds_up_compute_and_only_compute() {
        // Compute-bound regime: many blocks of a wide stage on a small
        // part. The SIMD profile must be strictly faster there…
        let stage = Stage::new(64, 32);
        let scalar = launch_cost(&hw::RTX4060, 8, &stage, 32, 192, 192);
        let simd =
            launch_cost_for(&hw::RTX4060, 8, &stage, 32, 192, 192, &BackendCostModel::simd());
        if scalar.bound_by == "compute" {
            assert!(simd.seconds < scalar.seconds, "{} vs {}", simd.seconds, scalar.seconds);
        }
        // …never slower anywhere, and identical in traffic.
        assert!(simd.seconds <= scalar.seconds);
        assert_eq!(simd.dram_bytes, scalar.dram_bytes);
        assert_eq!(simd.l2_bytes, scalar.l2_bytes);
        assert_eq!(simd.flops, scalar.flops);

        // Whole-plan ordering: simd ≤ native, equal byte accounting.
        let p = params(32, 16, 48);
        let plan = LaunchPlan::for_problem(2048, 64, &p);
        let native = simulate_plan_for(&hw::H100, 8, &plan, 32, &BackendCostModel::native());
        let simd = simulate_plan_for(&hw::H100, 8, &plan, 32, &BackendCostModel::simd());
        assert!(simd.seconds <= native.seconds);
        assert_eq!(simd.algo_bytes, native.algo_bytes);
        assert_eq!(simd.launches, native.launches);
        // Lane speedup: f64 lanes = 32/8 = 4 → divisor 1 + 0.6·3 = 2.8.
        let m = BackendCostModel::simd();
        assert_eq!(m.vector_width_bytes, 32.0);
        assert_eq!(m.element_size, None);
        assert_eq!(m.dispatch_overhead_s, 0.0);
    }

    #[test]
    fn reduction_costs_the_coordinator_plan_value() {
        let p = params(32, 4, 16);
        let (n, bw) = (96usize, 8usize);
        let plan = LaunchPlan::for_problem(n, bw, &p);
        let via_reduction = simulate_reduction(&hw::H100, 8, n, bw, &p);
        let via_plan = simulate_plan(&hw::H100, 8, &plan, p.tpb);
        assert_eq!(via_reduction.launches, via_plan.launches);
        assert_eq!(via_reduction.per_launch, via_plan.per_launch);
        assert_eq!(via_reduction.algo_bytes, via_plan.algo_bytes);
        assert_eq!(via_plan.launches, plan.num_launches());
        assert_eq!(via_plan.tasks, plan.total_tasks());
        for (li, &t) in via_plan.per_launch.iter().enumerate() {
            assert_eq!(t as usize, plan.launch_tasks(li));
        }
    }

    #[test]
    fn measured_profile_replaces_busy_time_but_not_traffic() {
        use crate::obs::calibrate::ProfileEntry;
        let p = params(32, 4, 16);
        let plan = LaunchPlan::for_problem(256, 8, &p);
        let native = BackendCostModel::native();
        let modeled = simulate_plan_for(&hw::H100, 8, &plan, 32, &native);
        // No profile: bit-identical to the modeled path.
        let none = simulate_plan_calibrated(&hw::H100, 8, &plan, 32, &native, None);
        assert_eq!(none.seconds, modeled.seconds);
        assert_eq!(none.algo_bytes, modeled.algo_bytes);
        // A deliberately slow measured kernel (1 ms/task) dominates the
        // schedule: busy time follows the measurement...
        let slow = MeasuredProfile {
            entries: vec![ProfileEntry {
                b: 8,
                d: 4,
                es: 8,
                packed: false,
                tasks: 100,
                ns_per_task: 1e6,
            }],
        };
        let calibrated =
            simulate_plan_calibrated(&hw::H100, 8, &plan, 32, &native, Some(&slow));
        assert!(
            calibrated.seconds > 10.0 * modeled.seconds,
            "{} vs {}",
            calibrated.seconds,
            modeled.seconds
        );
        // ...while launch structure and traffic accounting stay modeled.
        assert_eq!(calibrated.launches, modeled.launches);
        assert_eq!(calibrated.per_launch, modeled.per_launch);
        assert_eq!(calibrated.algo_bytes, modeled.algo_bytes);
        assert_eq!(calibrated.dram_bytes, modeled.dram_bytes);
        // An empty profile answers nothing and falls back to the model.
        let empty = MeasuredProfile::default();
        let fallback =
            simulate_plan_calibrated(&hw::H100, 8, &plan, 32, &native, Some(&empty));
        assert_eq!(fallback.seconds, modeled.seconds);
    }
}
