//! GPU occupancy model (paper §III-D, eq. (1), Table I).
//!
//! Bulge-chasing blocks are spaced `3 · CBW` rows apart (CBW = current
//! bandwidth), so a matrix saturates all execution-unit slots once
//! `n / (3·CBW) ≥ ALUs`, i.e. `n ≥ 3 · CBW · ALUs`.

use crate::simulator::hw::GpuArch;

/// Matrix size needed for full occupancy at current bandwidth `cbw`
/// (paper eq. (1) rearranged).
pub fn full_occupancy_n(arch: &GpuArch, cbw: usize) -> usize {
    3 * cbw * arch.alus
}

/// Fraction of ALU slots occupied at size `n`, bandwidth `cbw`.
pub fn occupancy_fraction(arch: &GpuArch, n: usize, cbw: usize) -> f64 {
    let blocks = n as f64 / (3.0 * cbw as f64);
    (blocks / arch.alus as f64).min(1.0)
}

/// One row of Table I.
#[derive(Clone, Debug)]
pub struct OccupancyRow {
    pub arch: &'static str,
    pub alus: usize,
    pub n_required: usize,
}

/// Regenerate Table I (CBW = 32) for the paper's three entries.
pub fn table1(cbw: usize) -> Vec<OccupancyRow> {
    use crate::simulator::hw::{H100, MI300X, PVC1100};
    [&H100, &MI300X, &PVC1100]
        .into_iter()
        .map(|a| OccupancyRow {
            arch: a.name,
            alus: a.alus,
            n_required: full_occupancy_n(a, cbw),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::hw;

    #[test]
    fn table1_matches_paper_values() {
        // Paper Table I at CBW=32: H100 50,688; MI300X 29,184; PVC 5,376.
        let rows = table1(32);
        let find = |name: &str| rows.iter().find(|r| r.arch == name).unwrap().n_required;
        assert_eq!(find("H100"), 50_688);
        assert_eq!(find("MI300X"), 29_184);
        assert_eq!(find("PVC1100"), 5_376);
    }

    #[test]
    fn occupancy_fraction_saturates_at_one() {
        let f_small = occupancy_fraction(&hw::H100, 1024, 32);
        let f_big = occupancy_fraction(&hw::H100, 100_000, 32);
        assert!(f_small < 0.05, "{f_small}");
        assert_eq!(f_big, 1.0);
    }

    #[test]
    fn wider_bands_need_larger_matrices() {
        assert!(full_occupancy_n(&hw::H100, 128) == 4 * full_occupancy_n(&hw::H100, 32));
    }
}
