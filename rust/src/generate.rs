//! Test-matrix generation (paper §V-A protocol).
//!
//! Matrices with a *prescribed* spectrum are built as `A = U Σ Vᵀ` where
//! U, V are products of random Householder reflectors (exactly orthogonal
//! up to rounding) — singular values are invariant under the construction,
//! which is what makes the Fig. 3 accuracy experiment well-posed.

use crate::banded::dense::Dense;
use crate::banded::storage::Banded;
use crate::householder::{apply_reflector_cols, apply_reflector_rows, make_reflector};
use crate::scalar::Scalar;
use crate::util::rng::Xoshiro256;

/// The paper's three singular-value profiles (Fig. 3): uniform spacing
/// ("structured"), logarithmic decay ("ill-conditioned"), and the
/// quarter-circle law ("random matrices").
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Spectrum {
    Arithmetic,
    Logarithmic,
    QuarterCircle,
}

impl Spectrum {
    pub const ALL: [Spectrum; 3] =
        [Spectrum::Arithmetic, Spectrum::Logarithmic, Spectrum::QuarterCircle];

    pub fn name(self) -> &'static str {
        match self {
            Spectrum::Arithmetic => "arithmetic",
            Spectrum::Logarithmic => "logarithmic",
            Spectrum::QuarterCircle => "quarter-circle",
        }
    }

    /// Sample `n` singular values in [0, 1], sorted descending.
    pub fn sample(self, n: usize, rng: &mut Xoshiro256) -> Vec<f64> {
        let mut s: Vec<f64> = match self {
            // σ_k evenly spaced in (0, 1].
            Spectrum::Arithmetic => (0..n).map(|k| (n - k) as f64 / n as f64).collect(),
            // σ_k = 10^(-6 k / n): six decades of decay.
            Spectrum::Logarithmic => {
                (0..n).map(|k| 10f64.powf(-6.0 * k as f64 / n as f64)).collect()
            }
            // Quarter-circle law: density ∝ sqrt(1 - x²) on [0, 1];
            // sample via inverse-CDF bisection.
            Spectrum::QuarterCircle => {
                (0..n).map(|_| quarter_circle_sample(rng.uniform())).collect()
            }
        };
        s.sort_by(|a, b| b.partial_cmp(a).unwrap());
        s
    }
}

/// Inverse CDF of the quarter-circle density f(x) = (4/π)·sqrt(1−x²) on
/// [0, 1], by bisection (CDF is monotone; 40 iterations ≈ 1e-12).
fn quarter_circle_sample(u: f64) -> f64 {
    let cdf = |x: f64| (2.0 / std::f64::consts::PI) * (x * (1.0 - x * x).sqrt() + x.asin());
    let (mut lo, mut hi) = (0.0f64, 1.0f64);
    for _ in 0..48 {
        let mid = 0.5 * (lo + hi);
        if cdf(mid) < u {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

/// Build a dense matrix `A = U Σ Vᵀ` with the given singular values by
/// applying `n_reflectors` random Householder reflectors on each side of
/// `diag(σ)`. Any number of reflectors preserves the spectrum exactly;
/// more reflectors make the matrix "denser"/less structured. Use
/// `n_reflectors = n` for fully random orthogonal factors.
pub fn dense_with_spectrum(
    n: usize,
    sigma: &[f64],
    rng: &mut Xoshiro256,
    n_reflectors: usize,
) -> Dense<f64> {
    assert_eq!(sigma.len(), n);
    let mut a = Dense::<f64>::zeros(n, n);
    for i in 0..n {
        a.set(i, i, sigma[i]);
    }
    let k = n_reflectors.min(n.saturating_sub(1)).max(1);
    let mut v = vec![0.0f64; 0];
    for r in 0..k {
        // Left reflector on rows r0.., random span.
        let r0 = rng.below(n.saturating_sub(1).max(1));
        let m = n - r0;
        v.resize(m, 0.0);
        rng.fill_gaussian(&mut v);
        let tau = make_reflector(&mut v);
        let tail = v[1..].to_vec();
        apply_reflector_rows(&mut a, tau, &tail, r0, 0, n - 1);
        // Right reflector on cols c0...
        let c0 = rng.below(n.saturating_sub(1).max(1));
        let m = n - c0;
        v.resize(m, 0.0);
        rng.fill_gaussian(&mut v);
        let tau = make_reflector(&mut v);
        let tail = v[1..].to_vec();
        apply_reflector_cols(&mut a, tau, &tail, c0, 0, n - 1);
        let _ = r;
    }
    a
}

/// Random upper-banded matrix (Gaussian entries in the band), in working
/// storage for a reduction with inner tilewidth `tw`.
pub fn random_banded<T: Scalar>(
    n: usize,
    bw: usize,
    tw: usize,
    rng: &mut Xoshiro256,
) -> Banded<T> {
    let mut b = Banded::<T>::for_reduction(n, bw, tw);
    for i in 0..n {
        for j in i..=(i + bw).min(n - 1) {
            b.set(i, j, T::from_f64(rng.gaussian()));
        }
    }
    b
}

/// Random upper-*bidiagonal* values (d, e) for stage-3 tests.
pub fn random_bidiagonal(n: usize, rng: &mut Xoshiro256) -> (Vec<f64>, Vec<f64>) {
    let d: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
    let e: Vec<f64> = (0..n - 1).map(|_| rng.gaussian()).collect();
    (d, e)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spectra_are_sorted_and_in_unit_interval() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        for kind in Spectrum::ALL {
            let s = kind.sample(50, &mut rng);
            assert_eq!(s.len(), 50);
            assert!(s.windows(2).all(|w| w[0] >= w[1]), "{kind:?} not sorted");
            assert!(s.iter().all(|&x| (0.0..=1.0).contains(&x)), "{kind:?} out of range");
        }
    }

    #[test]
    fn arithmetic_spectrum_is_uniformly_spaced() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let s = Spectrum::Arithmetic.sample(4, &mut rng);
        assert_eq!(s, vec![1.0, 0.75, 0.5, 0.25]);
    }

    #[test]
    fn logarithmic_spectrum_spans_six_decades() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let s = Spectrum::Logarithmic.sample(100, &mut rng);
        assert!((s[0] - 1.0).abs() < 1e-12);
        assert!(s[99] < 1e-5 && s[99] > 1e-7);
    }

    #[test]
    fn quarter_circle_mean_matches_theory() {
        // E[X] for density (4/π)sqrt(1-x²)·? on [0,1]: with f(x) =
        // (2/π)·2·sqrt(1−x²)... mean = 4/(3π) ≈ 0.4244.
        let mut rng = Xoshiro256::seed_from_u64(4);
        let s = Spectrum::QuarterCircle.sample(20_000, &mut rng);
        let mean: f64 = s.iter().sum::<f64>() / s.len() as f64;
        assert!((mean - 4.0 / (3.0 * std::f64::consts::PI)).abs() < 5e-3, "mean {mean}");
    }

    #[test]
    fn dense_with_spectrum_preserves_frobenius_norm() {
        // ||A||_F² = Σ σ² is invariant under orthogonal transforms.
        let mut rng = Xoshiro256::seed_from_u64(5);
        let sigma: Vec<f64> = (1..=16).map(|k| k as f64 / 16.0).collect();
        let a = dense_with_spectrum(16, &sigma, &mut rng, 16);
        let target = sigma.iter().map(|s| s * s).sum::<f64>().sqrt();
        assert!((a.fro_norm() - target).abs() < 1e-10, "{} vs {target}", a.fro_norm());
    }

    #[test]
    fn dense_with_spectrum_is_actually_dense() {
        let mut rng = Xoshiro256::seed_from_u64(6);
        let sigma = vec![1.0; 12];
        let a = dense_with_spectrum(12, &sigma, &mut rng, 12);
        let nonzero = a.data.iter().filter(|v| v.abs() > 1e-14).count();
        assert!(nonzero > 100, "only {nonzero} nonzeros");
    }

    #[test]
    fn random_banded_respects_band() {
        let mut rng = Xoshiro256::seed_from_u64(7);
        let b = random_banded::<f64>(10, 3, 2, &mut rng);
        assert_eq!(b.max_off_band(3), 0.0);
        // Band itself nonzero.
        assert!(b.get(0, 3).abs() > 0.0);
        assert!(b.get(4, 4).abs() > 0.0);
    }
}
