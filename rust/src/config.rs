//! Tunable parameters (paper §III-C), per-architecture heuristics, the
//! backend selector, and the serving-subsystem knobs.
//!
//! # Environment knobs
//!
//! These settings can be changed without a rebuild:
//!
//! | Variable | Default | Effect |
//! | --- | --- | --- |
//! | `BSVD_PACKED_SPAN_MIN` | `48` | Minimum stage span `b + d` routed through the packed-tile kernel path ([`crate::bulge::cycle::PACKED_SPAN_MIN`]); `0` forces every stage packed, a huge value forces in-place. Read once, on first use (tests/benches may override via [`crate::bulge::cycle::set_packed_span_min`]). |
//! | `BSVD_SIMD` | `auto` | ISA policy of the [`crate::backend::SimdBackend`] kernel spec ([`crate::simd::SimdSpec::from_env`]): `auto` uses the detected ISA and falls back to scalar, `force` insists on a vector path (portable lanes when detection fails), `off` pins the scalar kernels. Read once, on first use. |
//! | `BSVD_SIMD_CONTRACT` | `0` | `1` lets the SIMD reductions (dot, column norm) use fixed-width lane partials — deterministic and ulp-bounded, but no longer bitwise-identical to the sequential oracle. Read once, with `BSVD_SIMD`. |
//! | `BSVD_ARTIFACTS` | `artifacts` | Directory the PJRT backends load AOT-compiled HLO artifacts from ([`crate::runtime::artifact_dir`]). Read on every resolution, so it can be repointed between engine loads. |
//! | `BSVD_SERVICE_WINDOW_US` | `500` | Micro-batching window of the reduction service ([`ServiceConfig::window`]), in microseconds: how long the batcher holds the first pending job open for co-scheduling before flushing. Read when a [`ServiceConfig`] is constructed with `Default`. |
//! | `BSVD_SERVICE_QUEUE_CAP` | `1024` | Maximum pending jobs in the service submission queue ([`ServiceConfig::queue_cap`]); submissions beyond it are rejected at admission. Read when a [`ServiceConfig`] is constructed with `Default`. |
//! | `BSVD_SERVICE_WORKERS` | `1` | Batcher shards the reduction service runs ([`ServiceConfig::workers`]); each shard owns its own backend and admission queue, all sharing one plan cache. Read when a [`ServiceConfig`] is constructed with `Default`. |
//! | `BSVD_TRACE` | unset | Path of a JSON-lines span-event sink ([`crate::obs::trace::init_from_env`]): when set, every job's lifecycle (`submit` → `admit` → `queue_wait` → `flush` → `launch[i]` → `respond`) is appended as it happens, client and server side. Unset leaves tracing fully off (zero-cost: one relaxed atomic load per hook). Read once, at process start. |
//! | `BSVD_PROFILE` | unset | Path of a `bsvd-profile-v1` calibration artifact ([`crate::obs::calibrate::from_env`], written by `banded-svd profile --measure`): when set, the simulator and autotuner replace modeled per-task kernel costs with the measured ones ([`crate::simulator::autotune_for_calibrated`]). Read once, on first use. |
//!
//! The kernel-path knobs are bitwise-identical in results — they trade
//! performance, never numerics (see `docs/performance-model.md`). The
//! service knobs shape batching latency and admission, never per-job
//! numerics (see `docs/service.md`). The observability knobs record and
//! calibrate but never change what any kernel computes (see
//! `docs/observability.md`).

use crate::error::{Error, Result};
use std::time::Duration;

/// The three hyperparameters the paper exposes.
///
/// - `tpb`   — threads per block: parallelism vs register/L2 pressure.
/// - `tw`    — inner tilewidth: bandwidth reduced per stage; optimal value
///   matches a full cache line (32 for FP32, 16 for FP64 on 128-B lines).
/// - `max_blocks` — concurrently active blocks per execution unit;
///   excess bulge tasks are loop-unrolled into the same block.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub struct TuneParams {
    pub tpb: usize,
    pub tw: usize,
    pub max_blocks: usize,
}

impl TuneParams {
    pub fn new(tpb: usize, tw: usize, max_blocks: usize) -> Result<Self> {
        if tpb == 0 || tw == 0 || max_blocks == 0 {
            return Err(Error::Config(format!(
                "all TuneParams must be positive (tpb={tpb}, tw={tw}, max_blocks={max_blocks})"
            )));
        }
        Ok(Self { tpb, tw, max_blocks })
    }

    /// The paper's hardware-adapted default (§V-E): tilewidth matching a
    /// full cache line for the element size, generous threads-per-block,
    /// and the per-architecture MaxBlocks heuristic.
    pub fn heuristic(element_bytes: usize, cache_line_bytes: usize) -> Self {
        let tw = (cache_line_bytes / element_bytes).max(4);
        Self { tpb: 32, tw, max_blocks: 192 }
    }

    /// Clamp the tilewidth to a valid value for a given starting bandwidth
    /// (tw ≤ bw − 1 is all a single reduction can consume; larger tw would
    /// skip past bidiagonal form).
    pub fn effective_tw(&self, bw: usize) -> usize {
        self.tw.min(bw.saturating_sub(1)).max(1)
    }

    /// Block capacity per launch: MaxBlocks tasks run concurrently, the
    /// rest are loop-unrolled inside workers (the paper's per-device
    /// limit, §III-C-c). The single clamp shared by the coordinator, the
    /// batch engine, and the plan IR.
    pub fn capacity(&self) -> usize {
        self.max_blocks.max(1)
    }
}

impl Default for TuneParams {
    fn default() -> Self {
        // FP32 on a 128-byte-cache-line device — the paper's headline
        // configuration (tilewidth 32).
        Self { tpb: 32, tw: 32, max_blocks: 192 }
    }
}

/// How the batch engine packs per-problem launches into shared launches
/// (paper §III analogy: co-scheduling thread blocks from independent
/// grids under the joint MaxBlocks capacity).
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq, Hash)]
pub enum PackingPolicy {
    /// Visit live problems in rotating order, packing each problem's next
    /// launch while it fits. Fair: every problem periodically goes first.
    #[default]
    RoundRobin,
    /// Sort live problems by their next launch's task count (descending)
    /// and fill the capacity bin greedily. Maximizes launch occupancy.
    GreedyFill,
}

impl std::str::FromStr for PackingPolicy {
    type Err = String;

    fn from_str(s: &str) -> std::result::Result<Self, String> {
        match s {
            "rr" | "round-robin" => Ok(PackingPolicy::RoundRobin),
            "greedy" | "greedy-fill" => Ok(PackingPolicy::GreedyFill),
            other => Err(format!("unknown packing policy {other:?} (round-robin|greedy-fill)")),
        }
    }
}

/// Knobs of the batched reduction engine
/// ([`crate::batch::BatchCoordinator`]).
///
/// # Examples
///
/// ```
/// use banded_svd::config::{BatchConfig, PackingPolicy};
///
/// let cfg = BatchConfig::new(8, PackingPolicy::GreedyFill).unwrap();
/// assert_eq!(cfg.max_coresident, 8);
/// // Zero co-residency is rejected — at least one problem must run.
/// assert!(BatchConfig::new(0, PackingPolicy::RoundRobin).is_err());
/// // The default interleaves up to 64 problems, round-robin.
/// assert_eq!(BatchConfig::default().policy, PackingPolicy::RoundRobin);
/// ```
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct BatchConfig {
    /// Maximum problems interleaved at once; problems beyond the window
    /// are admitted as earlier ones finish (bounds peak working-set).
    pub max_coresident: usize,
    /// How per-problem launches are packed into shared launches.
    pub policy: PackingPolicy,
}

impl BatchConfig {
    pub fn new(max_coresident: usize, policy: PackingPolicy) -> Result<Self> {
        if max_coresident == 0 {
            return Err(Error::Config("max_coresident must be positive".into()));
        }
        Ok(Self { max_coresident, policy })
    }
}

impl Default for BatchConfig {
    fn default() -> Self {
        Self { max_coresident: 64, policy: PackingPolicy::RoundRobin }
    }
}

/// Knobs of the reduction service ([`crate::service::Service`]): the
/// long-running subsystem that accepts a *stream* of reduction jobs,
/// coalesces them into merged [`crate::plan::LaunchPlan`]s, and executes
/// them on one or more backend shards.
///
/// Three knobs also have environment overrides picked up by `Default`
/// (`BSVD_SERVICE_WINDOW_US`, `BSVD_SERVICE_QUEUE_CAP`,
/// `BSVD_SERVICE_WORKERS` — see the module docs); explicit field
/// assignment always wins over the environment.
///
/// # Examples
///
/// ```
/// use banded_svd::config::ServiceConfig;
///
/// let cfg = ServiceConfig::default();
/// assert!(cfg.queue_cap >= 1);
/// assert!(cfg.validate().is_ok());
/// // Admission must be able to hold at least one job.
/// let bad = ServiceConfig { queue_cap: 0, ..ServiceConfig::default() };
/// assert!(bad.validate().is_err());
/// ```
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Bulge-chasing tuning shared by every job (plans are keyed on it in
    /// the service plan cache).
    pub params: TuneParams,
    /// Micro-batching shape: `max_coresident` is the flush size trigger
    /// and the merge admission window; `policy` packs the shared launches.
    pub batch: BatchConfig,
    /// Executor the batcher worker runs merged plans on.
    pub backend: BackendKind,
    /// Worker threads for a threadpool backend (`0` = all cores).
    pub threads: usize,
    /// Micro-batching window: how long the batcher holds the first
    /// pending job open for co-scheduling before flushing a partial
    /// batch. `Duration::ZERO` flushes immediately (solo submission).
    pub window: Duration,
    /// Maximum pending jobs; submissions beyond it are rejected.
    pub queue_cap: usize,
    /// Admission control: a submission is rejected while the modeled
    /// backlog (sum of per-job costs priced by
    /// [`crate::simulator::simulate_plan_for`] under the backend's
    /// [`crate::simulator::BackendCostModel`]) exceeds this many seconds.
    pub backlog_cap_s: f64,
    /// Entries per store of the plan/autotune LRU cache.
    pub cache_cap: usize,
    /// Architecture name ([`crate::simulator::arch_by_name`]) whose cost
    /// model prices admission.
    pub arch: &'static str,
    /// Batcher shards the service runs. Each shard owns its own backend
    /// executor and its own admission queue (`queue_cap` and
    /// `backlog_cap_s` apply per shard), all sharing one plan cache.
    /// `1` reproduces the single-worker service exactly.
    pub workers: usize,
    /// How admitted jobs pick a shard when `workers > 1`.
    pub routing: ShardRouting,
    /// Per-client pending-job cap: a submission is rejected with
    /// [`crate::error::JobError::QuotaExceeded`] while its quota key
    /// (the request's `quota_class`, falling back to `client_id`)
    /// already has this many jobs queued across all shards. `0`
    /// disables quota enforcement; anonymous jobs are never counted.
    pub quota_pending_cap: usize,
    /// Largest problem side `n` admitted when the job requests singular
    /// vectors: the vectors path materializes two dense n×n f64 panels
    /// plus the reflector log (~16·n² bytes a panel, log in the same
    /// order), so unbounded n would let one job exhaust service memory.
    /// Submissions above the cap are rejected with
    /// [`crate::error::JobError::TooLarge`] (terminal, not retryable).
    /// Values-only jobs are never bounded by this.
    pub vectors_cap_n: usize,
}

impl ServiceConfig {
    /// Reject configurations the service cannot run with.
    pub fn validate(&self) -> Result<()> {
        if self.queue_cap == 0 {
            return Err(Error::Config("service queue_cap must be positive".into()));
        }
        if self.cache_cap == 0 {
            return Err(Error::Config("service cache_cap must be positive".into()));
        }
        if !self.backlog_cap_s.is_finite() || self.backlog_cap_s <= 0.0 {
            return Err(Error::Config(format!(
                "service backlog_cap_s must be positive and finite (got {})",
                self.backlog_cap_s
            )));
        }
        if self.batch.max_coresident == 0 {
            return Err(Error::Config("service max_coresident must be positive".into()));
        }
        if self.workers == 0 {
            return Err(Error::Config("service workers must be positive".into()));
        }
        if self.vectors_cap_n == 0 {
            return Err(Error::Config(
                "service vectors_cap_n must be positive (it bounds admission of \
                 vectors jobs; values-only jobs are unaffected)"
                    .into(),
            ));
        }
        Ok(())
    }
}

/// Default entries per store of the service plan/autotune cache — the
/// single source for [`ServiceConfig::cache_cap`] and
/// [`crate::service::PlanCache`]'s `Default`.
pub const DEFAULT_CACHE_CAP: usize = 256;

fn env_usize(var: &str, default: usize) -> usize {
    std::env::var(var).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            params: TuneParams::default(),
            batch: BatchConfig { max_coresident: 16, policy: PackingPolicy::RoundRobin },
            backend: BackendKind::Threadpool,
            threads: 0,
            window: Duration::from_micros(env_usize("BSVD_SERVICE_WINDOW_US", 500) as u64),
            queue_cap: env_usize("BSVD_SERVICE_QUEUE_CAP", 1024),
            backlog_cap_s: 60.0,
            cache_cap: DEFAULT_CACHE_CAP,
            arch: "H100",
            workers: env_usize("BSVD_SERVICE_WORKERS", 1).max(1),
            routing: ShardRouting::default(),
            quota_pending_cap: 0,
            vectors_cap_n: DEFAULT_VECTORS_CAP_N,
        }
    }
}

/// Default [`ServiceConfig::vectors_cap_n`]: 4096² f64 panels are
/// ~134 MB per factor — a deliberate ceiling for a CPU-serving tier.
pub const DEFAULT_VECTORS_CAP_N: usize = 4096;

/// How the service's admission router spreads jobs over its batcher
/// shards when [`ServiceConfig::workers`] is above one. Either policy
/// preserves strict (priority, admission-seq) drain order *within* each
/// shard; they differ only in which shard a job lands on.
///
/// # Examples
///
/// ```
/// use banded_svd::config::ShardRouting;
///
/// let routing: ShardRouting = "least-loaded".parse().unwrap();
/// assert_eq!(routing, ShardRouting::LeastLoaded);
/// assert_eq!(routing.name(), "least-loaded");
/// assert_eq!("size".parse::<ShardRouting>().unwrap(), ShardRouting::SizeClass);
/// ```
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq)]
pub enum ShardRouting {
    /// Send each job to the shard with the smallest modeled backlog
    /// (priced by [`crate::simulator::simulate_plan_for`]), breaking
    /// ties by queue depth and then a rotating offset. Best utilization
    /// under mixed job sizes.
    #[default]
    LeastLoaded,
    /// Send each job to the shard owning its problem-size class
    /// (`log2(n)` bucket modulo the shard count). Same-sized problems
    /// land together, so merged plans pack densely and the shared plan
    /// cache sees a hot working set per shard.
    SizeClass,
}

impl ShardRouting {
    /// Canonical CLI spelling.
    pub fn name(self) -> &'static str {
        match self {
            ShardRouting::LeastLoaded => "least-loaded",
            ShardRouting::SizeClass => "size-class",
        }
    }
}

impl std::str::FromStr for ShardRouting {
    type Err = String;

    fn from_str(s: &str) -> std::result::Result<Self, String> {
        match s {
            "least-loaded" | "cost" => Ok(ShardRouting::LeastLoaded),
            "size-class" | "size" => Ok(ShardRouting::SizeClass),
            other => Err(format!(
                "unknown shard routing {other:?} (least-loaded|size-class)"
            )),
        }
    }
}

/// Names an execution backend — the selector the CLI and the high-level
/// drivers map onto a [`crate::backend::Backend`] trait object via
/// [`crate::backend::for_kind`]. Every executor behind a kind consumes
/// the same [`crate::plan::LaunchPlan`]; the kinds differ only in *how*
/// the plan's launches are carried out.
///
/// # Examples
///
/// ```
/// use banded_svd::config::BackendKind;
///
/// let kind: BackendKind = "threadpool".parse().unwrap();
/// assert_eq!(kind, BackendKind::Threadpool);
/// assert_eq!(kind.name(), "threadpool");
/// assert!(BackendKind::ALL.contains(&kind));
/// ```
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust, one task at a time, inline in the calling thread — the
    /// schedule-order oracle every other backend is checked against.
    Sequential,
    /// Pure-Rust, launch-level parallelism over the worker thread pool
    /// (one pinned dispatch + one barrier per launch).
    Threadpool,
    /// The threadpool launch loop with packed-path cycle kernels routed
    /// through explicit SIMD lanes (`BSVD_SIMD` selects the ISA policy;
    /// scalar fallback keeps it runnable everywhere).
    ///
    /// ```
    /// use banded_svd::config::BackendKind;
    ///
    /// let kind: BackendKind = "simd".parse().unwrap();
    /// assert_eq!(kind.name(), "simd");
    /// assert!(BackendKind::ALL.contains(&BackendKind::Simd));
    /// ```
    Simd,
    /// AOT JAX/Pallas artifacts executed through PJRT, one call per
    /// launch, with per-problem device-resident buffers.
    Pjrt,
    /// Fused whole-stage PJRT artifacts (one call per bandwidth stage).
    PjrtFused,
}

impl BackendKind {
    /// Every registered backend kind, in reference-first order (the
    /// equivalence property test iterates this).
    pub const ALL: [BackendKind; 5] = [
        BackendKind::Sequential,
        BackendKind::Threadpool,
        BackendKind::Simd,
        BackendKind::Pjrt,
        BackendKind::PjrtFused,
    ];

    /// Canonical CLI spelling.
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Sequential => "sequential",
            BackendKind::Threadpool => "threadpool",
            BackendKind::Simd => "simd",
            BackendKind::Pjrt => "pjrt",
            BackendKind::PjrtFused => "pjrt-fused",
        }
    }
}

impl std::str::FromStr for BackendKind {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, String> {
        match s {
            "seq" | "sequential" => Ok(BackendKind::Sequential),
            // "par"/"parallel" kept as aliases from when the threadpool
            // executor was the only parallel backend.
            "par" | "parallel" | "tp" | "threadpool" => Ok(BackendKind::Threadpool),
            "simd" | "vector" => Ok(BackendKind::Simd),
            "pjrt" => Ok(BackendKind::Pjrt),
            "pjrt-fused" | "fused" => Ok(BackendKind::PjrtFused),
            other => Err(format!(
                "unknown backend {other:?} (sequential|threadpool|simd|pjrt|pjrt-fused)"
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heuristic_matches_paper_optima() {
        // FP32: tilewidth 32; FP64: tilewidth 16 (128-byte cache line).
        assert_eq!(TuneParams::heuristic(4, 128).tw, 32);
        assert_eq!(TuneParams::heuristic(8, 128).tw, 16);
    }

    #[test]
    fn zero_params_rejected() {
        assert!(TuneParams::new(0, 32, 192).is_err());
        assert!(TuneParams::new(32, 0, 192).is_err());
        assert!(TuneParams::new(32, 32, 0).is_err());
        assert!(TuneParams::new(1, 1, 1).is_ok());
    }

    #[test]
    fn effective_tw_clamps() {
        let p = TuneParams { tpb: 32, tw: 32, max_blocks: 192 };
        assert_eq!(p.effective_tw(64), 32);
        assert_eq!(p.effective_tw(8), 7);
        assert_eq!(p.effective_tw(2), 1);
        assert_eq!(p.effective_tw(1), 1);
    }

    #[test]
    fn capacity_clamps_to_one() {
        assert_eq!(TuneParams { tpb: 1, tw: 1, max_blocks: 7 }.capacity(), 7);
        // max_blocks = 0 is rejected by `new`, but struct-literal configs
        // must still execute: clamp instead of panicking.
        assert_eq!(TuneParams { tpb: 1, tw: 1, max_blocks: 0 }.capacity(), 1);
    }

    #[test]
    fn packing_policy_parses() {
        assert_eq!("rr".parse::<PackingPolicy>().unwrap(), PackingPolicy::RoundRobin);
        assert_eq!(
            "greedy-fill".parse::<PackingPolicy>().unwrap(),
            PackingPolicy::GreedyFill
        );
        assert!("bogus".parse::<PackingPolicy>().is_err());
    }

    #[test]
    fn batch_config_validates() {
        assert!(BatchConfig::new(0, PackingPolicy::RoundRobin).is_err());
        let cfg = BatchConfig::new(8, PackingPolicy::GreedyFill).unwrap();
        assert_eq!(cfg.max_coresident, 8);
        assert_eq!(BatchConfig::default().policy, PackingPolicy::RoundRobin);
        assert!(BatchConfig::default().max_coresident >= 1);
    }

    #[test]
    fn service_config_validates() {
        let cfg = ServiceConfig::default();
        assert!(cfg.validate().is_ok());
        assert!(cfg.queue_cap >= 1 && cfg.cache_cap >= 1);
        assert!(ServiceConfig { queue_cap: 0, ..ServiceConfig::default() }.validate().is_err());
        assert!(ServiceConfig { cache_cap: 0, ..ServiceConfig::default() }.validate().is_err());
        assert!(ServiceConfig { backlog_cap_s: 0.0, ..ServiceConfig::default() }
            .validate()
            .is_err());
        assert!(ServiceConfig { backlog_cap_s: f64::NAN, ..ServiceConfig::default() }
            .validate()
            .is_err());
        let bad_batch = ServiceConfig {
            batch: BatchConfig { max_coresident: 0, policy: PackingPolicy::RoundRobin },
            ..ServiceConfig::default()
        };
        assert!(bad_batch.validate().is_err());
        assert!(ServiceConfig { workers: 0, ..ServiceConfig::default() }.validate().is_err());
        assert!(ServiceConfig { workers: 4, ..ServiceConfig::default() }.validate().is_ok());
        assert_eq!(ServiceConfig::default().vectors_cap_n, DEFAULT_VECTORS_CAP_N);
        assert!(ServiceConfig { vectors_cap_n: 0, ..ServiceConfig::default() }
            .validate()
            .is_err());
    }

    #[test]
    fn shard_routing_parses_and_defaults_to_least_loaded() {
        assert_eq!(ServiceConfig::default().routing, ShardRouting::LeastLoaded);
        assert_eq!(ServiceConfig::default().quota_pending_cap, 0);
        assert!(ServiceConfig::default().workers >= 1);
        assert_eq!("cost".parse::<ShardRouting>().unwrap(), ShardRouting::LeastLoaded);
        assert_eq!("size-class".parse::<ShardRouting>().unwrap(), ShardRouting::SizeClass);
        assert!("bogus".parse::<ShardRouting>().is_err());
        for routing in [ShardRouting::LeastLoaded, ShardRouting::SizeClass] {
            assert_eq!(routing.name().parse::<ShardRouting>().unwrap(), routing);
        }
    }

    #[test]
    fn tune_params_are_hashable_cache_keys() {
        use std::collections::HashMap;
        let mut m: HashMap<TuneParams, usize> = HashMap::new();
        m.insert(TuneParams { tpb: 32, tw: 8, max_blocks: 192 }, 1);
        assert_eq!(m.get(&TuneParams { tpb: 32, tw: 8, max_blocks: 192 }), Some(&1));
        assert_eq!(m.get(&TuneParams { tpb: 32, tw: 4, max_blocks: 192 }), None);
    }

    #[test]
    fn backend_parses() {
        assert_eq!("seq".parse::<BackendKind>().unwrap(), BackendKind::Sequential);
        assert_eq!("threadpool".parse::<BackendKind>().unwrap(), BackendKind::Threadpool);
        // Legacy aliases from before the trait refactor keep working.
        assert_eq!("par".parse::<BackendKind>().unwrap(), BackendKind::Threadpool);
        assert_eq!("simd".parse::<BackendKind>().unwrap(), BackendKind::Simd);
        assert_eq!("vector".parse::<BackendKind>().unwrap(), BackendKind::Simd);
        assert_eq!("pjrt-fused".parse::<BackendKind>().unwrap(), BackendKind::PjrtFused);
        assert!("bogus".parse::<BackendKind>().is_err());
        for kind in BackendKind::ALL {
            assert_eq!(kind.name().parse::<BackendKind>().unwrap(), kind);
        }
    }
}
