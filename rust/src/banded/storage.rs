//! Banded column-major storage (paper §IV-b).
//!
//! An upper-banded n×n matrix with `bw` superdiagonals is stored as a
//! (`ld` × n) column-major array with `ld = kd_sub + kd_super + 1`:
//! element (i, j) lives at `data[j*ld + (kd_super + i - j)]`.
//!
//! For bulge chasing with inner tilewidth `tw`, fill-in reaches `tw`
//! diagonals beyond the band on both sides, so the working storage is
//! `kd_super = bw + tw`, `kd_sub = tw` — the paper's "height of the matrix
//! bandwidth increased by twice the inner tilewidth".
//!
//! Key property exploited by the hot loops: a *column segment*
//! `(i0..=i1, j)` is contiguous in memory.

use crate::scalar::Scalar;

/// Upper-banded matrix with room for bulge fill-in.
#[derive(Clone, Debug, PartialEq)]
pub struct Banded<T> {
    n: usize,
    kd_super: usize,
    kd_sub: usize,
    ld: usize,
    data: Vec<T>,
}

impl<T: Scalar> Banded<T> {
    /// Zero-initialized banded storage.
    pub fn zeros(n: usize, kd_super: usize, kd_sub: usize) -> Self {
        assert!(n > 0, "empty matrix");
        let ld = kd_super + kd_sub + 1;
        Self { n, kd_super, kd_sub, ld, data: vec![T::zero(); ld * n] }
    }

    /// Working storage for a bulge-chasing reduction of an upper-banded
    /// matrix with bandwidth `bw`, inner tilewidth `tw`.
    pub fn for_reduction(n: usize, bw: usize, tw: usize) -> Self {
        Self::zeros(n, bw + tw, tw)
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }
    #[inline]
    pub fn kd_super(&self) -> usize {
        self.kd_super
    }
    #[inline]
    pub fn kd_sub(&self) -> usize {
        self.kd_sub
    }
    #[inline]
    pub fn ld(&self) -> usize {
        self.ld
    }
    #[inline]
    pub fn data(&self) -> &[T] {
        &self.data
    }
    #[inline]
    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Check that this working storage can hold a bulge-chasing reduction
    /// of bandwidth `bw` with inner tilewidth `tw`: fill-in reaches `tw`
    /// diagonals past the band on both sides, so `kd_sub ≥ tw` and
    /// `kd_super ≥ bw + tw`. The single validation shared by the
    /// coordinator and the batch engine.
    pub fn check_reduction_storage(&self, bw: usize, tw: usize) -> crate::error::Result<()> {
        if self.kd_sub < tw || self.kd_super < bw + tw {
            return Err(crate::error::Error::Config(format!(
                "storage (kd_sub={}, kd_super={}) too small for bw={bw}, tw={tw}",
                self.kd_sub, self.kd_super
            )));
        }
        Ok(())
    }

    /// True if (i, j) lies within the representable diagonals.
    #[inline]
    pub fn in_band(&self, i: usize, j: usize) -> bool {
        i < self.n
            && j < self.n
            && (j + self.kd_sub >= i) // i - j <= kd_sub
            && (i + self.kd_super >= j) // j - i <= kd_super
    }

    /// Flat index of (i, j). Panics outside the representable band (the
    /// hot path uses `SharedBanded`'s unchecked view instead).
    #[inline]
    pub fn idx(&self, i: usize, j: usize) -> usize {
        assert!(self.in_band(i, j), "({i},{j}) outside band");
        j * self.ld + (self.kd_super + i - j)
    }

    /// Read element (i, j); zero outside the representable band.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> T {
        if self.in_band(i, j) {
            self.data[self.idx(i, j)]
        } else {
            T::zero()
        }
    }

    /// Write element (i, j). Panics outside the representable band.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: T) {
        let ix = self.idx(i, j);
        self.data[ix] = v;
    }

    /// Contiguous column segment rows `i0..=i1` of column `j`.
    #[inline]
    pub fn col_segment(&self, j: usize, i0: usize, i1: usize) -> &[T] {
        debug_assert!(i0 <= i1);
        let lo = self.idx(i0, j);
        let hi = self.idx(i1, j);
        &self.data[lo..=hi]
    }

    /// Mutable contiguous column segment rows `i0..=i1` of column `j`.
    #[inline]
    pub fn col_segment_mut(&mut self, j: usize, i0: usize, i1: usize) -> &mut [T] {
        debug_assert!(i0 <= i1);
        let lo = self.idx(i0, j);
        let hi = self.idx(i1, j);
        &mut self.data[lo..=hi]
    }

    /// Split into disjoint mutable column-segment views for a set of
    /// columns `j0..=j1`, all rows clamped to the band. Used by the cycle
    /// kernels to walk a parallelogram tile column-by-column.
    #[inline]
    pub fn col_ptr(&mut self, j: usize) -> *mut T {
        self.data[j * self.ld..].as_mut_ptr()
    }

    /// Range of rows representable in column `j` (also clipped to matrix).
    #[inline]
    pub fn col_row_range(&self, j: usize) -> (usize, usize) {
        let lo = j.saturating_sub(self.kd_super);
        let hi = (j + self.kd_sub).min(self.n - 1);
        (lo, hi)
    }

    /// Extract the main diagonal and first superdiagonal (the bidiagonal
    /// result of a completed reduction).
    pub fn bidiagonal(&self) -> (Vec<T>, Vec<T>) {
        let d: Vec<T> = (0..self.n).map(|i| self.get(i, i)).collect();
        let e: Vec<T> = (0..self.n - 1).map(|i| self.get(i, i + 1)).collect();
        (d, e)
    }

    /// Maximum |element| strictly outside the first `keep_super`
    /// superdiagonals (and on all subdiagonals). Zero for a completed
    /// reduction with `keep_super = 1`.
    pub fn max_off_band(&self, keep_super: usize) -> f64 {
        let mut worst = 0.0f64;
        for j in 0..self.n {
            let (lo, hi) = self.col_row_range(j);
            for i in lo..=hi {
                let within = i <= j && j - i <= keep_super;
                if !within {
                    worst = worst.max(self.get(i, j).to_f64().abs());
                }
            }
        }
        worst
    }

    /// Frobenius norm (over representable entries).
    pub fn fro_norm(&self) -> f64 {
        let mut s = 0.0;
        for j in 0..self.n {
            let (lo, hi) = self.col_row_range(j);
            for i in lo..=hi {
                let v = self.get(i, j).to_f64();
                s += v * v;
            }
        }
        s.sqrt()
    }

    /// Convert the representable band to a dense row-major n×n matrix.
    pub fn to_dense(&self) -> Vec<T> {
        let n = self.n;
        let mut out = vec![T::zero(); n * n];
        for j in 0..n {
            let (lo, hi) = self.col_row_range(j);
            for i in lo..=hi {
                out[i * n + j] = self.get(i, j);
            }
        }
        out
    }

    /// Build banded storage from a dense row-major matrix, keeping `bw`
    /// superdiagonals and reserving `tw` fill diagonals each side. Entries
    /// outside the kept band must be (numerically) zero; they are dropped.
    pub fn from_dense(a: &[T], n: usize, bw: usize, tw: usize) -> Self {
        assert_eq!(a.len(), n * n);
        let mut b = Self::for_reduction(n, bw, tw);
        for i in 0..n {
            for j in i..=(i + bw).min(n - 1) {
                b.set(i, j, a[i * n + j]);
            }
        }
        b
    }

    /// Convert elements to another precision.
    pub fn convert<U: Scalar>(&self) -> Banded<U> {
        Banded {
            n: self.n,
            kd_super: self.kd_super,
            kd_sub: self.kd_sub,
            ld: self.ld,
            data: self.data.iter().map(|v| U::from_f64(v.to_f64())).collect(),
        }
    }

    /// Flat f32 buffer in (ld × n) column-major order — the exact layout
    /// the L2 JAX model and the PJRT artifacts consume.
    pub fn to_f32_flat(&self) -> Vec<f32> {
        self.data.iter().map(|v| v.to_f64() as f32).collect()
    }

    /// Overwrite contents from a flat f32 buffer (layout as `to_f32_flat`).
    pub fn from_f32_flat(&mut self, flat: &[f32]) {
        assert_eq!(flat.len(), self.data.len());
        for (d, &s) in self.data.iter_mut().zip(flat.iter()) {
            *d = T::from_f64(s as f64);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_roundtrip() {
        let mut b = Banded::<f64>::for_reduction(8, 3, 2);
        b.set(0, 0, 1.0);
        b.set(0, 3, 2.0); // edge of band
        b.set(2, 0, 3.0); // subdiagonal fill (within tw=2)
        b.set(1, 6, 4.0); // superdiagonal fill (bw+tw = 5)
        assert_eq!(b.get(0, 0), 1.0);
        assert_eq!(b.get(0, 3), 2.0);
        assert_eq!(b.get(2, 0), 3.0);
        assert_eq!(b.get(1, 6), 4.0);
        assert_eq!(b.get(5, 0), 0.0); // outside band reads zero
    }

    #[test]
    #[should_panic]
    fn set_outside_band_panics() {
        let mut b = Banded::<f64>::for_reduction(8, 3, 2);
        b.set(7, 0, 1.0);
    }

    #[test]
    fn column_segment_is_contiguous_and_matches_get() {
        let mut b = Banded::<f64>::for_reduction(10, 4, 2);
        for i in 2..=6 {
            b.set(i, 6, i as f64);
        }
        let seg = b.col_segment(6, 2, 6);
        assert_eq!(seg, &[2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn dense_roundtrip() {
        let n = 6;
        let bw = 2;
        let mut dense = vec![0.0f64; n * n];
        for i in 0..n {
            for j in i..=(i + bw).min(n - 1) {
                dense[i * n + j] = (i * 10 + j) as f64 + 1.0;
            }
        }
        let b = Banded::from_dense(&dense, n, bw, 1);
        assert_eq!(b.to_dense(), dense);
    }

    #[test]
    fn bidiagonal_extraction() {
        let n = 5;
        let mut b = Banded::<f64>::for_reduction(n, 2, 1);
        for i in 0..n {
            b.set(i, i, (i + 1) as f64);
            if i + 1 < n {
                b.set(i, i + 1, 0.5);
            }
        }
        let (d, e) = b.bidiagonal();
        assert_eq!(d, vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(e, vec![0.5; 4]);
    }

    #[test]
    fn max_off_band_detects_leftovers() {
        let n = 6;
        let mut b = Banded::<f64>::for_reduction(n, 3, 1);
        b.set(0, 1, 1.0);
        assert_eq!(b.max_off_band(1), 0.0);
        b.set(0, 2, 0.25);
        assert_eq!(b.max_off_band(1), 0.25);
        b.set(3, 2, 0.75); // subdiagonal
        assert_eq!(b.max_off_band(1), 0.75);
    }

    #[test]
    fn f32_flat_roundtrip() {
        let mut b = Banded::<f64>::for_reduction(7, 3, 2);
        b.set(2, 4, 1.5);
        b.set(3, 3, -2.5);
        let flat = b.to_f32_flat();
        let mut c = Banded::<f64>::for_reduction(7, 3, 2);
        c.from_f32_flat(&flat);
        assert_eq!(b, c);
    }

    #[test]
    fn precision_conversion() {
        use crate::scalar::F16;
        let mut b = Banded::<f64>::for_reduction(4, 2, 1);
        b.set(0, 0, 0.333333333333);
        let h: Banded<F16> = b.convert();
        let back: Banded<f64> = h.convert();
        assert!((back.get(0, 0) - 0.333333333333).abs() < 1e-3);
    }

    #[test]
    fn col_row_range_clips() {
        let b = Banded::<f64>::for_reduction(10, 3, 2);
        assert_eq!(b.col_row_range(0), (0, 2));
        assert_eq!(b.col_row_range(9), (4, 9));
        assert_eq!(b.col_row_range(7), (2, 9));
    }
}
