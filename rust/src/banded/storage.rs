//! Banded column-major storage (paper §IV-b).
//!
//! An upper-banded n×n matrix with `bw` superdiagonals is stored as a
//! (`ld` × n) column-major array with `ld = kd_sub + kd_super + 1`:
//! element (i, j) lives at `data[j*ld + (kd_super + i - j)]`.
//!
//! For bulge chasing with inner tilewidth `tw`, fill-in reaches `tw`
//! diagonals beyond the band on both sides, so the working storage is
//! `kd_super = bw + tw`, `kd_sub = tw` — the paper's "height of the matrix
//! bandwidth increased by twice the inner tilewidth".
//!
//! Key property exploited by the hot loops: a *column segment*
//! `(i0..=i1, j)` is contiguous in memory. The full index diagram lives
//! next to the tile pack/unpack code ([`TileSpec`]), which is where the
//! mapping actually matters.

use crate::scalar::Scalar;

/// Geometry of a packed, contiguous tile workspace — the CPU analog of
/// the paper's L1-resident tiles. A bulge-chasing cycle touches a
/// two-block parallelogram of the band, which pack/unpack copies into a
/// dense column-major scratch so the whole chase runs cache-resident and
/// is written back once.
///
/// ## Banded-storage index diagram
///
/// Banded storage keeps diagonals as rows of a `(ld × n)` column-major
/// array (`ld = kd_sub + kd_super + 1`); element `(i, j)` lives at
/// `data[j·ld + (kd_super + i − j)]`, so a column segment `(i0..=i1, j)`
/// is contiguous. A cycle anchored at column `j0` (pivot row `rp`,
/// `jd = min(j0+d, n−1)`, `c1 = min(j0+b+d, n−1)`) accesses exactly:
///
/// ```text
///             j0        jd  jd+1        c1
///            ┌───────────┬───────────────┐
///        rp  │           │               │
///            │  block A  │   (not in     │   block A: right op rows
///            │ rows rp..=jd   the tile)  │   rp..=jd  × cols j0..=jd
///        j0  │ · · · · · ├───────────────┤
///            │           │    block B    │   block B: left op rows
///        jd  │           │ rows j0..=jd  │   j0..=jd  × cols jd+1..=c1
///            └───────────┴───────────────┘
/// ```
///
/// Packed layout: one column slot of `pitch() = jd − rp + 1` elements
/// per tile column; block-B columns (shorter, `jd − j0 + 1` elements)
/// occupy the head of their slot. Both blocks stay within the
/// representable band whenever the storage passed
/// `check_reduction_storage` (block A's deepest offset is `b + d ≤
/// kd_super`, its lowest subdiagonal `d ≤ kd_sub`).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct TileSpec {
    /// First tile column (the cycle anchor).
    pub j0: usize,
    /// Last block-A column (`jd`); later columns use the block-B rows.
    pub split: usize,
    /// Last tile column (`c1`).
    pub c1: usize,
    /// Top row of block-A columns (the pivot row).
    pub lo_a: usize,
    /// Top row of block-B columns (the anchor row).
    pub lo_b: usize,
    /// Bottom row of every tile column (`jd`).
    pub hi: usize,
}

impl TileSpec {
    pub fn new(j0: usize, split: usize, c1: usize, lo_a: usize, lo_b: usize, hi: usize) -> Self {
        assert!(j0 <= split && split <= c1, "bad tile columns {j0}..{split}..{c1}");
        assert!(lo_a <= lo_b && lo_b <= hi, "bad tile rows {lo_a}/{lo_b}/{hi}");
        Self { j0, split, c1, lo_a, lo_b, hi }
    }

    /// Elements per column slot (the block-A column height).
    #[inline]
    pub fn pitch(&self) -> usize {
        self.hi - self.lo_a + 1
    }

    /// Number of tile columns.
    #[inline]
    pub fn width(&self) -> usize {
        self.c1 - self.j0 + 1
    }

    /// Workspace elements the packed tile occupies.
    #[inline]
    pub fn elems(&self) -> usize {
        self.width() * self.pitch()
    }

    /// Top row of tile column `j`.
    #[inline]
    pub fn lo(&self, j: usize) -> usize {
        if j <= self.split {
            self.lo_a
        } else {
            self.lo_b
        }
    }

    /// `(offset into the packed buffer, top row, element count)` of tile
    /// column `j` — the single home of the packing index map; every
    /// pack/unpack loop (here and in `bulge::cycle`) goes through it.
    #[inline]
    pub fn col_span(&self, j: usize) -> (usize, usize, usize) {
        let lo = self.lo(j);
        ((j - self.j0) * self.pitch(), lo, self.hi - lo + 1)
    }
}

/// Upper-banded matrix with room for bulge fill-in.
#[derive(Clone, Debug, PartialEq)]
pub struct Banded<T> {
    n: usize,
    kd_super: usize,
    kd_sub: usize,
    ld: usize,
    data: Vec<T>,
}

impl<T: Scalar> Banded<T> {
    /// Zero-initialized banded storage.
    pub fn zeros(n: usize, kd_super: usize, kd_sub: usize) -> Self {
        assert!(n > 0, "empty matrix");
        let ld = kd_super + kd_sub + 1;
        Self { n, kd_super, kd_sub, ld, data: vec![T::zero(); ld * n] }
    }

    /// Working storage for a bulge-chasing reduction of an upper-banded
    /// matrix with bandwidth `bw`, inner tilewidth `tw`.
    pub fn for_reduction(n: usize, bw: usize, tw: usize) -> Self {
        Self::zeros(n, bw + tw, tw)
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }
    #[inline]
    pub fn kd_super(&self) -> usize {
        self.kd_super
    }
    #[inline]
    pub fn kd_sub(&self) -> usize {
        self.kd_sub
    }
    #[inline]
    pub fn ld(&self) -> usize {
        self.ld
    }
    #[inline]
    pub fn data(&self) -> &[T] {
        &self.data
    }
    #[inline]
    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Check that this working storage can hold a bulge-chasing reduction
    /// of bandwidth `bw` with inner tilewidth `tw`: fill-in reaches `tw`
    /// diagonals past the band on both sides, so `kd_sub ≥ tw` and
    /// `kd_super ≥ bw + tw`. The single validation shared by the
    /// coordinator and the batch engine.
    pub fn check_reduction_storage(&self, bw: usize, tw: usize) -> crate::error::Result<()> {
        if self.kd_sub < tw || self.kd_super < bw + tw {
            return Err(crate::error::Error::Config(format!(
                "storage (kd_sub={}, kd_super={}) too small for bw={bw}, tw={tw}",
                self.kd_sub, self.kd_super
            )));
        }
        Ok(())
    }

    /// True if (i, j) lies within the representable diagonals.
    #[inline]
    pub fn in_band(&self, i: usize, j: usize) -> bool {
        i < self.n
            && j < self.n
            && (j + self.kd_sub >= i) // i - j <= kd_sub
            && (i + self.kd_super >= j) // j - i <= kd_super
    }

    /// Flat index of (i, j). Panics outside the representable band (the
    /// hot path uses `SharedBanded`'s unchecked view instead).
    #[inline]
    pub fn idx(&self, i: usize, j: usize) -> usize {
        assert!(self.in_band(i, j), "({i},{j}) outside band");
        j * self.ld + (self.kd_super + i - j)
    }

    /// Read element (i, j); zero outside the representable band.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> T {
        if self.in_band(i, j) {
            self.data[self.idx(i, j)]
        } else {
            T::zero()
        }
    }

    /// Write element (i, j). Panics outside the representable band.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: T) {
        let ix = self.idx(i, j);
        self.data[ix] = v;
    }

    /// Contiguous column segment rows `i0..=i1` of column `j`.
    #[inline]
    pub fn col_segment(&self, j: usize, i0: usize, i1: usize) -> &[T] {
        debug_assert!(i0 <= i1);
        let lo = self.idx(i0, j);
        let hi = self.idx(i1, j);
        &self.data[lo..=hi]
    }

    /// Mutable contiguous column segment rows `i0..=i1` of column `j`.
    #[inline]
    pub fn col_segment_mut(&mut self, j: usize, i0: usize, i1: usize) -> &mut [T] {
        debug_assert!(i0 <= i1);
        let lo = self.idx(i0, j);
        let hi = self.idx(i1, j);
        &mut self.data[lo..=hi]
    }

    /// Split into disjoint mutable column-segment views for a set of
    /// columns `j0..=j1`, all rows clamped to the band. Used by the cycle
    /// kernels to walk a parallelogram tile column-by-column.
    #[inline]
    pub fn col_ptr(&mut self, j: usize) -> *mut T {
        self.data[j * self.ld..].as_mut_ptr()
    }

    /// Range of rows representable in column `j` (also clipped to matrix).
    #[inline]
    pub fn col_row_range(&self, j: usize) -> (usize, usize) {
        let lo = j.saturating_sub(self.kd_super);
        let hi = (j + self.kd_sub).min(self.n - 1);
        (lo, hi)
    }

    /// Copy the tile described by `spec` into the contiguous workspace
    /// `out` (length ≥ `spec.elems()`), column by column. See [`TileSpec`]
    /// for the layout and the banded-storage index diagram.
    pub fn pack_tile(&self, spec: &TileSpec, out: &mut [T]) {
        for j in spec.j0..=spec.c1 {
            let (off, lo, len) = spec.col_span(j);
            out[off..off + len].copy_from_slice(self.col_segment(j, lo, spec.hi));
        }
    }

    /// Write the packed tile `buf` back into banded storage — the inverse
    /// of [`Banded::pack_tile`]. Elements outside the tile are untouched.
    pub fn unpack_tile(&mut self, spec: &TileSpec, buf: &[T]) {
        for j in spec.j0..=spec.c1 {
            let (off, lo, len) = spec.col_span(j);
            self.col_segment_mut(j, lo, spec.hi).copy_from_slice(&buf[off..off + len]);
        }
    }

    /// Extract the main diagonal and first superdiagonal (the bidiagonal
    /// result of a completed reduction).
    pub fn bidiagonal(&self) -> (Vec<T>, Vec<T>) {
        let d: Vec<T> = (0..self.n).map(|i| self.get(i, i)).collect();
        let e: Vec<T> = (0..self.n - 1).map(|i| self.get(i, i + 1)).collect();
        (d, e)
    }

    /// Maximum |element| strictly outside the first `keep_super`
    /// superdiagonals (and on all subdiagonals). Zero for a completed
    /// reduction with `keep_super = 1`.
    pub fn max_off_band(&self, keep_super: usize) -> f64 {
        let mut worst = 0.0f64;
        for j in 0..self.n {
            let (lo, hi) = self.col_row_range(j);
            for i in lo..=hi {
                let within = i <= j && j - i <= keep_super;
                if !within {
                    worst = worst.max(self.get(i, j).to_f64().abs());
                }
            }
        }
        worst
    }

    /// Frobenius norm (over representable entries).
    pub fn fro_norm(&self) -> f64 {
        let mut s = 0.0;
        for j in 0..self.n {
            let (lo, hi) = self.col_row_range(j);
            for i in lo..=hi {
                let v = self.get(i, j).to_f64();
                s += v * v;
            }
        }
        s.sqrt()
    }

    /// Convert the representable band to a dense row-major n×n matrix.
    pub fn to_dense(&self) -> Vec<T> {
        let n = self.n;
        let mut out = vec![T::zero(); n * n];
        for j in 0..n {
            let (lo, hi) = self.col_row_range(j);
            for i in lo..=hi {
                out[i * n + j] = self.get(i, j);
            }
        }
        out
    }

    /// Build banded storage from a dense row-major matrix, keeping `bw`
    /// superdiagonals and reserving `tw` fill diagonals each side. Entries
    /// outside the kept band must be (numerically) zero; they are dropped.
    pub fn from_dense(a: &[T], n: usize, bw: usize, tw: usize) -> Self {
        assert_eq!(a.len(), n * n);
        let mut b = Self::for_reduction(n, bw, tw);
        for i in 0..n {
            for j in i..=(i + bw).min(n - 1) {
                b.set(i, j, a[i * n + j]);
            }
        }
        b
    }

    /// Convert elements to another precision.
    pub fn convert<U: Scalar>(&self) -> Banded<U> {
        Banded {
            n: self.n,
            kd_super: self.kd_super,
            kd_sub: self.kd_sub,
            ld: self.ld,
            data: self.data.iter().map(|v| U::from_f64(v.to_f64())).collect(),
        }
    }

    /// Flat f32 buffer in (ld × n) column-major order — the exact layout
    /// the L2 JAX model and the PJRT artifacts consume.
    pub fn to_f32_flat(&self) -> Vec<f32> {
        self.data.iter().map(|v| v.to_f64() as f32).collect()
    }

    /// Overwrite contents from a flat f32 buffer (layout as `to_f32_flat`).
    pub fn from_f32_flat(&mut self, flat: &[f32]) {
        assert_eq!(flat.len(), self.data.len());
        for (d, &s) in self.data.iter_mut().zip(flat.iter()) {
            *d = T::from_f64(s as f64);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_roundtrip() {
        let mut b = Banded::<f64>::for_reduction(8, 3, 2);
        b.set(0, 0, 1.0);
        b.set(0, 3, 2.0); // edge of band
        b.set(2, 0, 3.0); // subdiagonal fill (within tw=2)
        b.set(1, 6, 4.0); // superdiagonal fill (bw+tw = 5)
        assert_eq!(b.get(0, 0), 1.0);
        assert_eq!(b.get(0, 3), 2.0);
        assert_eq!(b.get(2, 0), 3.0);
        assert_eq!(b.get(1, 6), 4.0);
        assert_eq!(b.get(5, 0), 0.0); // outside band reads zero
    }

    #[test]
    #[should_panic]
    fn set_outside_band_panics() {
        let mut b = Banded::<f64>::for_reduction(8, 3, 2);
        b.set(7, 0, 1.0);
    }

    #[test]
    fn column_segment_is_contiguous_and_matches_get() {
        let mut b = Banded::<f64>::for_reduction(10, 4, 2);
        for i in 2..=6 {
            b.set(i, 6, i as f64);
        }
        let seg = b.col_segment(6, 2, 6);
        assert_eq!(seg, &[2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn dense_roundtrip() {
        let n = 6;
        let bw = 2;
        let mut dense = vec![0.0f64; n * n];
        for i in 0..n {
            for j in i..=(i + bw).min(n - 1) {
                dense[i * n + j] = (i * 10 + j) as f64 + 1.0;
            }
        }
        let b = Banded::from_dense(&dense, n, bw, 1);
        assert_eq!(b.to_dense(), dense);
    }

    #[test]
    fn bidiagonal_extraction() {
        let n = 5;
        let mut b = Banded::<f64>::for_reduction(n, 2, 1);
        for i in 0..n {
            b.set(i, i, (i + 1) as f64);
            if i + 1 < n {
                b.set(i, i + 1, 0.5);
            }
        }
        let (d, e) = b.bidiagonal();
        assert_eq!(d, vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(e, vec![0.5; 4]);
    }

    #[test]
    fn max_off_band_detects_leftovers() {
        let n = 6;
        let mut b = Banded::<f64>::for_reduction(n, 3, 1);
        b.set(0, 1, 1.0);
        assert_eq!(b.max_off_band(1), 0.0);
        b.set(0, 2, 0.25);
        assert_eq!(b.max_off_band(1), 0.25);
        b.set(3, 2, 0.75); // subdiagonal
        assert_eq!(b.max_off_band(1), 0.75);
    }

    #[test]
    fn f32_flat_roundtrip() {
        let mut b = Banded::<f64>::for_reduction(7, 3, 2);
        b.set(2, 4, 1.5);
        b.set(3, 3, -2.5);
        let flat = b.to_f32_flat();
        let mut c = Banded::<f64>::for_reduction(7, 3, 2);
        c.from_f32_flat(&flat);
        assert_eq!(b, c);
    }

    #[test]
    fn precision_conversion() {
        use crate::scalar::F16;
        let mut b = Banded::<f64>::for_reduction(4, 2, 1);
        b.set(0, 0, 0.333333333333);
        let h: Banded<F16> = b.convert();
        let back: Banded<f64> = h.convert();
        assert!((back.get(0, 0) - 0.333333333333).abs() < 1e-3);
    }

    #[test]
    fn tile_pack_unpack_identity() {
        let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(3);
        let a = crate::generate::random_banded::<f64>(24, 5, 3, &mut rng);
        let spec = TileSpec::new(8, 10, 15, 4, 8, 10);
        let mut buf = vec![0.0; spec.elems()];
        a.pack_tile(&spec, &mut buf);
        let mut b = a.clone();
        b.unpack_tile(&spec, &buf);
        assert_eq!(a, b);
        // Packed cells mirror storage.
        for j in spec.j0..=spec.c1 {
            for i in spec.lo(j)..=spec.hi {
                assert_eq!(buf[(j - spec.j0) * spec.pitch() + (i - spec.lo(j))], a.get(i, j));
            }
        }
    }

    #[test]
    fn prop_tile_pack_mutate_unpack_roundtrip() {
        use crate::util::prop::{check, Config};
        use crate::util::rng::Xoshiro256;

        #[derive(Debug)]
        struct Case {
            n: usize,
            bw: usize,
            tw: usize,
            spec: TileSpec,
            seed: u64,
        }

        fn gen_case(rng: &mut Xoshiro256) -> Case {
            let bw = rng.range_inclusive(2, 10);
            let tw = rng.range_inclusive(1, bw - 1);
            let n = rng.range_inclusive(bw + tw + 4, 64);
            // A cycle-shaped tile: anchor j0, depth d ≤ tw, pivot offset
            // ≤ bw above, width ≤ bw + tw right — the bounds
            // `check_reduction_storage` guarantees representable.
            let j0 = rng.range_inclusive(0, n - 2);
            let hi = (j0 + rng.range_inclusive(1, tw)).min(n - 1);
            let lo_a = j0 - rng.range_inclusive(0, bw.min(j0));
            let c1 = (j0 + rng.range_inclusive(hi - j0, bw + tw)).min(n - 1);
            let split = rng.range_inclusive(j0, hi.min(c1));
            Case {
                n,
                bw,
                tw,
                spec: TileSpec::new(j0, split, c1, lo_a, j0, hi),
                seed: rng.next_u64(),
            }
        }

        let cfg = Config { cases: 64, ..Config::default() };
        check("tile-pack-mutate-unpack", &cfg, gen_case, |case| {
            let mut rng = Xoshiro256::seed_from_u64(case.seed);
            let mut a = crate::generate::random_banded::<f64>(case.n, case.bw, case.tw, &mut rng);
            let spec = &case.spec;
            let mut buf = vec![0.0f64; spec.elems()];
            a.pack_tile(spec, &mut buf);
            // Mutate every packed cell and mirror the mutation directly
            // into an oracle copy of the storage.
            let mut want = a.clone();
            for j in spec.j0..=spec.c1 {
                for i in spec.lo(j)..=spec.hi {
                    let idx = (j - spec.j0) * spec.pitch() + (i - spec.lo(j));
                    if buf[idx] != a.get(i, j) {
                        return Err(format!("pack mismatch at ({i},{j})"));
                    }
                    buf[idx] = 2.0 * buf[idx] + 1.0;
                    want.set(i, j, 2.0 * a.get(i, j) + 1.0);
                }
            }
            a.unpack_tile(spec, &buf);
            if a != want {
                return Err("unpack did not write back the mutation exactly (or touched \
                            elements outside the tile)"
                    .into());
            }
            Ok(())
        });
    }

    #[test]
    fn col_row_range_clips() {
        let b = Banded::<f64>::for_reduction(10, 3, 2);
        assert_eq!(b.col_row_range(0), (0, 2));
        assert_eq!(b.col_row_range(9), (4, 9));
        assert_eq!(b.col_row_range(7), (2, 9));
    }
}
