//! Banded and dense matrix containers.

pub mod dense;
pub mod storage;

pub use dense::Dense;
pub use storage::{Banded, TileSpec};
