//! Small dense row-major matrix helpers used by generation, stage 1, and
//! the test oracles. Not a general linear-algebra library — only what the
//! pipeline needs, kept simple and correct.

use crate::scalar::Scalar;

/// Dense row-major matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Dense<T> {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<T>,
}

impl<T: Scalar> Dense<T> {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![T::zero(); rows * cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.set(i, i, T::one());
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<T>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { rows, cols, data }
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> T {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: T) {
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[T] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [T] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// C = A * B (naive triple loop with row-major-friendly ordering).
    pub fn matmul(&self, other: &Dense<T>) -> Dense<T> {
        assert_eq!(self.cols, other.rows);
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Dense::zeros(m, n);
        for i in 0..m {
            for p in 0..k {
                let a = self.get(i, p);
                if a == T::zero() {
                    continue;
                }
                let brow = other.row(p);
                let orow = out.row_mut(i);
                for j in 0..n {
                    orow[j] = a.mul_add(brow[j], orow[j]);
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> Dense<T> {
        let mut out = Dense::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.set(j, i, self.get(i, j));
            }
        }
        out
    }

    pub fn fro_norm(&self) -> f64 {
        self.data
            .iter()
            .map(|v| {
                let x = v.to_f64();
                x * x
            })
            .sum::<f64>()
            .sqrt()
    }

    /// max |self - other| over all entries.
    pub fn max_abs_diff(&self, other: &Dense<T>) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a.to_f64() - b.to_f64()).abs())
            .fold(0.0, f64::max)
    }

    /// Deviation from orthogonality: ||AᵀA - I||_max. Used by tests on the
    /// generated U, V factors.
    pub fn orthogonality_error(&self) -> f64 {
        let g = self.transpose().matmul(self);
        let mut worst = 0.0f64;
        for i in 0..g.rows {
            for j in 0..g.cols {
                let target = if i == j { 1.0 } else { 0.0 };
                worst = worst.max((g.get(i, j).to_f64() - target).abs());
            }
        }
        worst
    }

    pub fn convert<U: Scalar>(&self) -> Dense<U> {
        Dense {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|v| U::from_f64(v.to_f64())).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_matmul_is_noop() {
        let mut a = Dense::<f64>::zeros(3, 3);
        for i in 0..3 {
            for j in 0..3 {
                a.set(i, j, (i * 3 + j) as f64);
            }
        }
        let i3 = Dense::identity(3);
        assert_eq!(a.matmul(&i3), a);
        assert_eq!(i3.matmul(&a), a);
    }

    #[test]
    fn matmul_known_product() {
        let a = Dense::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Dense::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn transpose_involution() {
        let a = Dense::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose().get(2, 1), 6.0);
    }

    #[test]
    fn fro_norm_matches_hand_value() {
        let a = Dense::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-15);
    }

    #[test]
    fn orthogonality_error_of_identity_is_zero() {
        let i = Dense::<f64>::identity(4);
        assert_eq!(i.orthogonality_error(), 0.0);
    }
}
