//! # banded-svd — memory-aware bulge chasing for banded→bidiagonal
//! reduction
//!
//! Reproduction of *Accelerating Bidiagonalization of Banded Matrices
//! through Memory-Aware Bulge-Chasing on GPUs* (Ringoot, Alomairy,
//! Edelman; CS.DC 2025) as a three-layer Rust + JAX + Pallas system:
//!
//! - **L1** (build time): Pallas kernels implementing the paper's
//!   Algorithm 2 (`python/compile/kernels/bulge.py`).
//! - **L2** (build time): JAX cycle/stage functions lowered to HLO text
//!   (`python/compile/model.py`, `aot.py` → `artifacts/*.hlo.txt`).
//! - **L3** (run time, this crate): the coordinator — schedule, launch
//!   loop, batching, PJRT execution of the AOT artifacts, plus a complete
//!   native implementation, CPU baselines, the three-stage SVD pipeline,
//!   and the GPU performance model that regenerates the paper's tables
//!   and figures.
//!
//! ## Architecture: schedule → plan → backend execution
//!
//! The paper's hardware-aware tuning only works if the model tunes the
//! *actual* schedule the device runs. The crate therefore funnels every
//! consumer through one launch-plan IR ([`plan::LaunchPlan`]), and every
//! executor behind one trait ([`backend::Backend`]) whose single
//! obligation is *execute a `LaunchPlan` against banded storage*:
//!
//! ```text
//!   bulge/schedule.rs ── lower ──▶ plan::LaunchPlan ── merge ──▶ (batched)
//!                                      │
//!                ┌─────────────────────┼──────────────────────┐
//!                ▼                     ▼                      ▼
//!        backend::Backend     simulator::simulate_plan   autotune_for
//!   ┌────────┬───┴───┬────────┐ (costs the identical     (per-backend
//!   ▼        ▼       ▼        ▼  value, exactly)          cost hook)
//! Sequential Threadpool Simd Pjrt
//!  (inline) (pool+pins) (pool+  (AOT artifacts, one
//!                   lane kernels) device buffer per problem)
//! ```
//!
//! - The **scheduler** lowers the 3-cycle schedule into symbolic
//!   [`plan::TaskSlot`]s (problem, stage, cycle, count) — compact enough
//!   to materialize n = 65536 plans, exact enough to reconstruct every
//!   task.
//! - The **backends** ([`backend`]) walk the plan launch by launch; the
//!   coordinator, batch engine, pipeline, and CLI all select executors
//!   through the trait. Batching is [`plan::LaunchPlan::merge`]:
//!   per-problem streams interleaved into shared launches under the
//!   joint MaxBlocks capacity, preserving per-problem order (hence
//!   bitwise-identical results); the PJRT backend maps each merged-plan
//!   problem onto its own device-resident buffer.
//! - The **simulator** costs the *same* plan value
//!   ([`simulator::model::simulate_plan`]), so predicted launch counts,
//!   per-launch task counts, and byte traffic match execution exactly —
//!   property-tested in `rust/tests/plan_consistency.rs` — and
//!   [`simulator::autotune_for`] tunes under the cost profile of the
//!   backend that will actually run
//!   ([`backend::Backend::cost_model`]).
//!
//! The narrative version of this section lives in `docs/architecture.md`;
//! the backend contract in `docs/backends.md`; the byte-accounting model
//! in `docs/performance-model.md`.
//!
//! ## Memory-aware packed-tile execution
//!
//! Wide stages chase bulges inside a packed, contiguous tile workspace
//! (the CPU analog of the paper's L1-resident tiles): the cycle's whole
//! footprint is gathered ([`banded::Banded::pack_tile`]), chased there by
//! the same generic kernels (bitwise-identical results), and written back
//! once. Workspaces are persistent per pool slot
//! (`util::threadpool::WorkerLocal`), and the executor routes tasks with
//! sticky column-window affinity so a chased window stays in one core's
//! cache across launches.
//!
//! ## Quick start
//!
//! ```no_run
//! use banded_svd::prelude::*;
//!
//! let mut rng = Xoshiro256::seed_from_u64(0);
//! let n = 256;
//! let bw = 16;
//! let params = TuneParams { tpb: 32, tw: 8, max_blocks: 192 };
//! let mut a = random_banded::<f64>(n, bw, params.effective_tw(bw), &mut rng);
//! let result = reduce_to_bidiagonal(&mut a, bw, &params);
//! let sv = bidiagonal_singular_values(&result.diag, &result.superdiag);
//! println!("σ_max = {}", sv[0]);
//! ```
//!
//! ## Batched reduction
//!
//! One mid-sized matrix cannot fill the device (Table I); a *batch* can.
//! [`batch::BatchCoordinator`] reduces many banded problems (mixed `n`,
//! `bw`, precision) concurrently by interleaving their launch streams
//! into shared launches under the joint MaxBlocks capacity — per-problem
//! results stay bitwise identical to solo runs:
//!
//! ```no_run
//! use banded_svd::prelude::*;
//!
//! let mut rng = Xoshiro256::seed_from_u64(0);
//! let params = TuneParams { tpb: 32, tw: 8, max_blocks: 192 };
//! let mut problems: Vec<BatchInput> = (0..16)
//!     .map(|_| {
//!         let a = random_banded::<f64>(512, 16, params.effective_tw(16), &mut rng);
//!         BatchInput::from((a, 16))
//!     })
//!     .collect();
//! let coord = BatchCoordinator::new(params, BatchConfig::default(), 0);
//! let report = coord.run(&mut problems).unwrap();
//! println!(
//!     "{} problems, {:.0} problems/s, launch occupancy {:.2}",
//!     report.problems.len(),
//!     report.throughput(),
//!     report.metrics.occupancy_ratio()
//! );
//! ```
//!
//! ## Serving a stream of reductions
//!
//! Batching answers "reduce these K problems"; production traffic is a
//! *stream* — jobs arriving one at a time, each wanting an answer soon.
//! The [`service`] subsystem runs the batch engine as a long-lived
//! system: an admission-controlled queue (priced by the simulator under
//! the backend's cost model), a dynamic micro-batcher that coalesces
//! pending jobs into merged plans (size or time-window flush), and a
//! bounded LRU cache over plan lowering, merge skeletons, and autotune
//! results — fronted in-process by [`service::Service`] and over TCP
//! JSON-lines by [`service::Server`] (`banded-svd serve`). A service
//! runs one or more batcher **shards** (`--workers N`), each with its
//! own queue and backend executor, routed by modeled load or problem
//! size class and sharing one plan cache ([`service::shard`]); per-shard
//! breakdowns ride [`service::ServiceStats::shards`]. Served results are
//! bitwise identical to the direct pipeline on the same backend.
//!
//! ## One front door: the client API
//!
//! All of the above sits behind a single request/response contract —
//! the [`client`] module. A [`client::ReductionRequest`] (batch of
//! problems, tuning override, priority/deadline) goes into any
//! [`client::Client`]; a [`client::ReductionOutcome`] (typed singular
//! values, per-problem [`coordinator::metrics::LaunchMetrics`], plan
//! provenance) comes back. [`client::LocalClient`] executes in-process
//! (directly on a backend, or queued through an embedded
//! [`service::Service`]); [`client::RemoteClient`] speaks the
//! version-checked JSON-lines wire to a `banded-svd serve` endpoint;
//! [`client::ShardedClient`] spreads requests over a *fleet* of
//! endpoints with hash or least-loaded routing, ping-based health
//! checks, and failover when a member dies. All are interchangeable:
//! same request, **bitwise-identical** singular values
//! (`rust/tests/client_equivalence.rs`, including under single-endpoint
//! failure). Failures resolve to the typed [`error::JobError`] taxonomy
//! on every path, so retryable back-pressure (overloaded,
//! quota-exceeded) is distinguishable from terminal errors without
//! parsing messages.
//!
//! ```no_run
//! use banded_svd::prelude::*;
//!
//! let client = LocalClient::new(TuneParams { tpb: 32, tw: 8, max_blocks: 192 });
//! let outcome = client
//!     .submit_wait(ReductionRequest::new().random(512, 16, ScalarKind::F64, 0))
//!     .unwrap();
//! let p = &outcome.problems[0];
//! println!(
//!     "σ_max = {} ({} launches on {})",
//!     p.sv[0],
//!     p.metrics.launches,
//!     outcome.provenance.backend
//! );
//! ```

pub mod backend;
pub mod banded;
pub mod batch;
pub mod baselines;
pub mod bulge;
pub mod client;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod generate;
pub mod householder;
pub mod loadgen;
pub mod obs;
pub mod pipeline;
pub mod plan;
pub mod runtime;
pub mod scalar;
pub mod service;
pub mod simd;
pub mod simulator;
pub mod util;

/// Convenient re-exports of the public API surface.
pub mod prelude {
    pub use crate::backend::{
        AsBandStorageMut, Backend, PjrtBackend, SequentialBackend, SimdBackend,
        ThreadpoolBackend,
    };
    pub use crate::banded::{Banded, Dense};
    pub use crate::batch::{
        BatchCoordinator, BatchInput, BatchMetrics, BatchPlan, BatchReport, ProblemReport,
    };
    pub use crate::bulge::{
        reduce_to_bidiagonal, reduce_to_bidiagonal_parallel, stage_plan, Stage,
    };
    pub use crate::client::{
        Client, ClientStats, ExecutionSource, JobHandle, LocalClient, PlanProvenance,
        ProblemOutcome, ProblemSpec, ReductionOutcome, ReductionRequest, RemoteClient,
        RouteStrategy, ShardedClient,
    };
    pub use crate::config::{
        BackendKind, BatchConfig, PackingPolicy, ServiceConfig, ShardRouting, TuneParams,
    };
    pub use crate::error::{Error, JobError, Result};
    pub use crate::generate::{dense_with_spectrum, random_banded, Spectrum};
    pub use crate::loadgen::{ArrivalProcess, ScenarioOptions, Slo, WorkloadMix};
    pub use crate::obs::{MeasuredProfile, TraceId};
    pub use crate::pipeline::{
        bidiagonal_singular_values, dense_to_band, singular_values_3stage, SvdOptions,
    };
    pub use crate::plan::{LaunchPlan, TaskSlot};
    pub use crate::scalar::{Scalar, ScalarKind, F16};
    pub use crate::simd::{SimdIsa, SimdSpec};
    pub use crate::service::{
        JobResult, JobTicket, PlanCache, Server, Service, ServiceStats, ShardStats,
    };
    pub use crate::util::rng::Xoshiro256;
    pub use crate::util::threadpool::ThreadPool;
}
