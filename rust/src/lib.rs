//! # banded-svd — memory-aware bulge chasing for banded→bidiagonal
//! reduction
//!
//! Reproduction of *Accelerating Bidiagonalization of Banded Matrices
//! through Memory-Aware Bulge-Chasing on GPUs* (Ringoot, Alomairy,
//! Edelman; CS.DC 2025) as a three-layer Rust + JAX + Pallas system:
//!
//! - **L1** (build time): Pallas kernels implementing the paper's
//!   Algorithm 2 (`python/compile/kernels/bulge.py`).
//! - **L2** (build time): JAX cycle/stage functions lowered to HLO text
//!   (`python/compile/model.py`, `aot.py` → `artifacts/*.hlo.txt`).
//! - **L3** (run time, this crate): the coordinator — schedule, launch
//!   loop, batching, PJRT execution of the AOT artifacts, plus a complete
//!   native implementation, CPU baselines, the three-stage SVD pipeline,
//!   and the GPU performance model that regenerates the paper's tables
//!   and figures.
//!
//! ## Quick start
//!
//! ```no_run
//! use banded_svd::prelude::*;
//!
//! let mut rng = Xoshiro256::seed_from_u64(0);
//! let n = 256;
//! let bw = 16;
//! let params = TuneParams { tpb: 32, tw: 8, max_blocks: 192 };
//! let mut a = random_banded::<f64>(n, bw, params.effective_tw(bw), &mut rng);
//! let result = reduce_to_bidiagonal(&mut a, bw, &params);
//! let sv = bidiagonal_singular_values(&result.diag, &result.superdiag);
//! println!("σ_max = {}", sv[0]);
//! ```
//!
//! ## Batched reduction
//!
//! One mid-sized matrix cannot fill the device (Table I); a *batch* can.
//! [`batch::BatchCoordinator`] reduces many banded problems (mixed `n`,
//! `bw`, precision) concurrently by interleaving their launch streams
//! into shared launches under the joint MaxBlocks capacity — per-problem
//! results stay bitwise identical to solo runs:
//!
//! ```no_run
//! use banded_svd::prelude::*;
//!
//! let mut rng = Xoshiro256::seed_from_u64(0);
//! let params = TuneParams { tpb: 32, tw: 8, max_blocks: 192 };
//! let mut problems: Vec<BatchInput> = (0..16)
//!     .map(|_| {
//!         let a = random_banded::<f64>(512, 16, params.effective_tw(16), &mut rng);
//!         BatchInput::from((a, 16))
//!     })
//!     .collect();
//! let coord = BatchCoordinator::new(params, BatchConfig::default(), 0);
//! let report = coord.run(&mut problems).unwrap();
//! println!(
//!     "{} problems, {:.0} problems/s, launch occupancy {:.2}",
//!     report.problems.len(),
//!     report.throughput(),
//!     report.metrics.occupancy_ratio()
//! );
//! ```

pub mod banded;
pub mod batch;
pub mod baselines;
pub mod bulge;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod generate;
pub mod householder;
pub mod pipeline;
pub mod runtime;
pub mod scalar;
pub mod simulator;
pub mod util;

/// Convenient re-exports of the public API surface.
pub mod prelude {
    pub use crate::banded::{Banded, Dense};
    pub use crate::batch::{
        BatchCoordinator, BatchInput, BatchMetrics, BatchPlan, BatchReport, ProblemReport,
    };
    pub use crate::bulge::{
        reduce_to_bidiagonal, reduce_to_bidiagonal_parallel, stage_plan, Stage,
    };
    pub use crate::config::{Backend, BatchConfig, PackingPolicy, TuneParams};
    pub use crate::error::{Error, Result};
    pub use crate::generate::{dense_with_spectrum, random_banded, Spectrum};
    pub use crate::pipeline::{
        batch_singular_values, bidiagonal_singular_values, dense_to_band,
        singular_values_3stage, SvdOptions,
    };
    pub use crate::scalar::{Scalar, F16};
    pub use crate::util::rng::Xoshiro256;
    pub use crate::util::threadpool::ThreadPool;
}
