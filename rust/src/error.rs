//! Library error type (hand-rolled `Display`/`Error` impls — the offline
//! crate set has no `thiserror`).

use std::fmt;

#[derive(Debug)]
pub enum Error {
    Config(String),
    ArtifactMissing { path: String, variant: String },
    Pjrt(String),
    Numerical(String),
    /// A reduction-service job failed (backend error on the worker,
    /// expired deadline, or shutdown before execution).
    Service(String),
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(msg) => write!(f, "invalid configuration: {msg}"),
            Error::ArtifactMissing { path, variant } => write!(
                f,
                "artifact not found: {path} (run `make artifacts`; looked for variant {variant})"
            ),
            Error::Pjrt(msg) => write!(f, "PJRT runtime error: {msg}"),
            Error::Numerical(msg) => write!(f, "numerical failure: {msg}"),
            Error::Service(msg) => write!(f, "service error: {msg}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Pjrt(e.to_string())
    }
}

#[cfg(not(feature = "pjrt"))]
impl From<crate::runtime::stub::Error> for Error {
    fn from(e: crate::runtime::stub::Error) -> Self {
        Error::Pjrt(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats_match_seed_wording() {
        assert_eq!(
            Error::Config("bad tw".into()).to_string(),
            "invalid configuration: bad tw"
        );
        let e = Error::ArtifactMissing { path: "a/b.txt".into(), variant: "n=8".into() };
        assert!(e.to_string().contains("a/b.txt"));
        assert!(e.to_string().contains("n=8"));
        assert!(Error::Pjrt("boom".into()).to_string().starts_with("PJRT"));
        assert_eq!(Error::Service("queue full".into()).to_string(), "service error: queue full");
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(std::error::Error::source(&e).is_some());
        assert!(e.to_string().contains("gone"));
    }
}
