//! Library error type.

use thiserror::Error;

#[derive(Error, Debug)]
pub enum Error {
    #[error("invalid configuration: {0}")]
    Config(String),

    #[error("artifact not found: {path} (run `make artifacts`; looked for variant {variant})")]
    ArtifactMissing { path: String, variant: String },

    #[error("PJRT runtime error: {0}")]
    Pjrt(String),

    #[error("numerical failure: {0}")]
    Numerical(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Pjrt(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
