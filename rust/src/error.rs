//! Library error type (hand-rolled `Display`/`Error` impls — the offline
//! crate set has no `thiserror`).
//!
//! Job-level failures ride a typed taxonomy ([`JobError`]) instead of a
//! stringly variant, so every front door — [`crate::client::Client`],
//! the in-process [`crate::service::Service`], the TCP wire — can tell a
//! *retryable* admission rejection (back-pressure) apart from a terminal
//! failure (expired deadline, backend error) without parsing messages.

use std::fmt;

/// Why a reduction job was declined or failed — the error taxonomy of
/// the client API ([`crate::client::ReductionOutcome`] waits resolve to
/// this on failure) and of the service queue. Every kind rides the JSON
/// wire (`kind` + `retryable` fields), so a
/// [`crate::client::RemoteClient`] surfaces exactly what a local one
/// would.
#[derive(Clone, Debug, PartialEq)]
pub enum JobError {
    /// Admission control declined the job because the service is loaded
    /// (queue depth cap or priced-backlog cap). **Retryable**: the same
    /// submission is expected to succeed once the queue drains.
    Overloaded { reason: String },
    /// Admission control declined the job because the submitting client
    /// (its `client_id`, or its shared `quota_class`) already has its
    /// cap of pending jobs in the queue. **Retryable**: the same
    /// submission is expected to succeed once that client's pending
    /// jobs drain.
    QuotaExceeded { reason: String },
    /// The service is not accepting work (shutting down, or torn down
    /// before the job ran). Not retryable against this endpoint.
    Unavailable { reason: String },
    /// The job's deadline passed while it was still queued; it was
    /// failed at flush instead of executed.
    DeadlineExpired { queued_ms: u64 },
    /// Admission control declined the job because its resource footprint
    /// exceeds a service cap (e.g. a singular-vector request whose dense
    /// n×n panels exceed `vectors_cap_n`). **Not retryable**: the same
    /// submission fails identically until the request shrinks or the
    /// service is reconfigured.
    TooLarge { reason: String },
    /// The backend failed while executing the job's plan.
    Execution { reason: String },
}

impl JobError {
    /// True when resubmitting the identical job later is expected to
    /// succeed — the back-pressure signal admission control emits.
    pub fn is_retryable(&self) -> bool {
        matches!(self, JobError::Overloaded { .. } | JobError::QuotaExceeded { .. })
    }

    /// Stable wire code for the `kind` field of an error response.
    pub fn kind(&self) -> &'static str {
        match self {
            JobError::Overloaded { .. } => "overloaded",
            JobError::QuotaExceeded { .. } => "quota-exceeded",
            JobError::Unavailable { .. } => "unavailable",
            JobError::DeadlineExpired { .. } => "deadline-expired",
            JobError::TooLarge { .. } => "too-large",
            JobError::Execution { .. } => "execution",
        }
    }

    /// Rebuild a taxonomy member from its wire fields — the decode side
    /// of [`JobError::kind`] (`queued_ms` rides the error response as its
    /// own field for deadline expiries, so the decoded error reports the
    /// server's actual queue time, never a fabricated one). Unknown codes
    /// map to [`JobError::Execution`] (terminal, message preserved)
    /// rather than erroring: an old client must still classify a new
    /// server's failures.
    pub fn from_kind(kind: &str, message: &str, queued_ms: Option<u64>) -> JobError {
        match kind {
            "overloaded" => JobError::Overloaded { reason: message.to_string() },
            "quota-exceeded" => JobError::QuotaExceeded { reason: message.to_string() },
            "unavailable" => JobError::Unavailable { reason: message.to_string() },
            "deadline-expired" => {
                JobError::DeadlineExpired { queued_ms: queued_ms.unwrap_or(0) }
            }
            "too-large" => JobError::TooLarge { reason: message.to_string() },
            _ => JobError::Execution { reason: message.to_string() },
        }
    }
}

impl fmt::Display for JobError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JobError::Overloaded { reason } => write!(f, "overloaded (retryable): {reason}"),
            JobError::QuotaExceeded { reason } => {
                write!(f, "quota exceeded (retryable): {reason}")
            }
            JobError::Unavailable { reason } => write!(f, "service unavailable: {reason}"),
            JobError::DeadlineExpired { queued_ms } => {
                write!(f, "deadline exceeded before execution (queued {queued_ms} ms)")
            }
            JobError::TooLarge { reason } => write!(f, "request too large: {reason}"),
            JobError::Execution { reason } => write!(f, "execution failed: {reason}"),
        }
    }
}

#[derive(Debug)]
pub enum Error {
    Config(String),
    ArtifactMissing { path: String, variant: String },
    Pjrt(String),
    Numerical(String),
    /// A reduction job was declined or failed — see [`JobError`] for the
    /// taxonomy (retryable admission rejection vs terminal failure).
    Job(JobError),
    Io(std::io::Error),
}

impl Error {
    /// The job taxonomy member, when this error is job-level.
    pub fn as_job(&self) -> Option<&JobError> {
        match self {
            Error::Job(e) => Some(e),
            _ => None,
        }
    }

    /// True when retrying the same operation later is expected to
    /// succeed (job-level back-pressure; everything else is terminal).
    pub fn is_retryable(&self) -> bool {
        self.as_job().is_some_and(JobError::is_retryable)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(msg) => write!(f, "invalid configuration: {msg}"),
            Error::ArtifactMissing { path, variant } => write!(
                f,
                "artifact not found: {path} (run `make artifacts`; looked for variant {variant})"
            ),
            Error::Pjrt(msg) => write!(f, "PJRT runtime error: {msg}"),
            Error::Numerical(msg) => write!(f, "numerical failure: {msg}"),
            Error::Job(e) => write!(f, "job error: {e}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<JobError> for Error {
    fn from(e: JobError) -> Self {
        Error::Job(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Pjrt(e.to_string())
    }
}

#[cfg(not(feature = "pjrt"))]
impl From<crate::runtime::stub::Error> for Error {
    fn from(e: crate::runtime::stub::Error) -> Self {
        Error::Pjrt(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats_match_seed_wording() {
        assert_eq!(
            Error::Config("bad tw".into()).to_string(),
            "invalid configuration: bad tw"
        );
        let e = Error::ArtifactMissing { path: "a/b.txt".into(), variant: "n=8".into() };
        assert!(e.to_string().contains("a/b.txt"));
        assert!(e.to_string().contains("n=8"));
        assert!(Error::Pjrt("boom".into()).to_string().starts_with("PJRT"));
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(std::error::Error::source(&e).is_some());
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn job_taxonomy_separates_retryable_from_terminal() {
        let overloaded = JobError::Overloaded { reason: "queue full".into() };
        assert!(overloaded.is_retryable());
        assert!(Error::Job(overloaded.clone()).is_retryable());
        let quota = JobError::QuotaExceeded { reason: "client tenant-a has 4 pending".into() };
        assert!(quota.is_retryable());
        assert!(Error::Job(quota).is_retryable());
        for terminal in [
            JobError::Unavailable { reason: "shutting down".into() },
            JobError::DeadlineExpired { queued_ms: 7 },
            JobError::TooLarge { reason: "n=9000 exceeds vectors cap".into() },
            JobError::Execution { reason: "backend".into() },
        ] {
            assert!(!terminal.is_retryable(), "{terminal:?}");
            assert!(!Error::Job(terminal).is_retryable());
        }
        assert!(!Error::Config("x".into()).is_retryable());
        assert_eq!(Error::Job(overloaded).as_job().unwrap().kind(), "overloaded");
    }

    #[test]
    fn job_kinds_roundtrip_over_the_wire_codes() {
        for e in [
            JobError::Overloaded { reason: "queue full: 4 jobs".into() },
            JobError::QuotaExceeded { reason: "client tenant-a has 4 pending (cap 4)".into() },
            JobError::Unavailable { reason: "service is shutting down".into() },
            JobError::TooLarge { reason: "vectors for n=9000 exceed the cap".into() },
            JobError::Execution { reason: "backend threadpool failed".into() },
        ] {
            let back = JobError::from_kind(e.kind(), &e.to_string(), None);
            assert_eq!(back.kind(), e.kind());
            assert_eq!(back.is_retryable(), e.is_retryable());
        }
        // The deadline queue time rides its own wire field and rebuilds
        // exactly — no fabricated zero.
        let expired = JobError::DeadlineExpired { queued_ms: 150 };
        let back = JobError::from_kind(expired.kind(), &expired.to_string(), Some(150));
        assert_eq!(back, expired);
        assert!(back.to_string().contains("150 ms"), "{back}");
        // Unknown kinds classify as terminal execution failures.
        assert_eq!(JobError::from_kind("novel", "msg", None).kind(), "execution");
    }

    #[test]
    fn deadline_display_names_the_deadline() {
        let e = Error::Job(JobError::DeadlineExpired { queued_ms: 3 });
        assert!(e.to_string().contains("deadline"), "{e}");
    }
}
