//! Determinism diagnostic: repeatedly reduce the same matrix through the
//! sequential and parallel native backends and report any element-level
//! divergence (there must be none — the parallel schedule executes the
//! exact same reflector ops on disjoint data).

use banded_svd::banded::storage::Banded;
use banded_svd::config::{BackendKind, TuneParams};
use banded_svd::coordinator::Coordinator;
use banded_svd::generate::random_banded;
use banded_svd::util::rng::Xoshiro256;

fn main() {
    let params = TuneParams { tpb: 32, tw: 4, max_blocks: 8 };
    let coord = Coordinator::new(params, 4);
    let mut rng = Xoshiro256::seed_from_u64(1);
    let (n, bw) = (64usize, 8usize);
    let a0: Banded<f64> = random_banded::<f64>(n, bw, 4, &mut rng);
    for trial in 0..5 {
        let mut a1 = a0.clone();
        let mut a2 = a0.clone();
        coord.reduce_native(&mut a1, bw, BackendKind::Sequential).unwrap();
        coord.reduce_native(&mut a2, bw, BackendKind::Threadpool).unwrap();
        let mut ndiff = 0;
        let mut worst = 0.0f64;
        for (i, (x, y)) in a1.data().iter().zip(a2.data().iter()).enumerate() {
            if x != y {
                ndiff += 1;
                worst = worst.max((x - y).abs());
                if ndiff < 4 {
                    println!("trial {trial} idx {i}: {x} vs {y}");
                }
            }
        }
        println!("trial {trial}: ndiff={ndiff} worst={worst:.3e}");
    }
}
