//! The unified client API — **one front door** over every way this
//! crate can run a reduction.
//!
//! The paper's pitch is a *single function* that is hardware-agnostic
//! and precision-aware; the crate's execution machinery (plan IR, batch
//! merge, backends, the serving subsystem) grew four entry points with
//! four argument conventions. This module collapses them behind one
//! request/response contract:
//!
//! ```text
//!   ReductionRequest ──▶ Client::submit ──▶ JobHandle
//!    (problems, params,       │
//!     priority, deadline)     ▼
//!                        Client::wait ──▶ ReductionOutcome
//!                                          (σ per problem, LaunchMetrics,
//!                                           plan provenance)
//!
//!   impl Client ──┬── LocalClient    direct: BatchCoordinator + PlanCache
//!                 │                  queued: embedded in-process Service
//!                 ├── RemoteClient   JSON-lines wire to `banded-svd serve`
//!                 └── ShardedClient  several serve endpoints, routed +
//!                                    health-checked ([`sharded`])
//! ```
//!
//! The contract every implementation upholds (locked in by
//! `rust/tests/client_equivalence.rs`): for the same
//! [`ReductionRequest`], [`LocalClient`], [`RemoteClient`], and
//! [`ShardedClient`] return **bitwise-identical** singular values and the
//! same per-problem launch accounting on the same backend kind — local,
//! served, and sharded execution are interchangeable behind
//! `dyn Client`. Failures resolve to the typed [`JobError`] taxonomy
//! (retryable admission back-pressure vs terminal errors) on every path,
//! including over the wire.
//!
//! # Examples
//!
//! ```no_run
//! use banded_svd::client::{Client, LocalClient, ReductionRequest};
//! use banded_svd::config::TuneParams;
//! use banded_svd::scalar::ScalarKind;
//!
//! let client = LocalClient::new(TuneParams { tpb: 32, tw: 8, max_blocks: 192 });
//! let outcome = client
//!     .submit_wait(
//!         ReductionRequest::new()
//!             .random(256, 16, ScalarKind::F64, 7)
//!             .random(128, 8, ScalarKind::F32, 8),
//!     )
//!     .unwrap();
//! for p in &outcome.problems {
//!     println!("{} n={}: σ_max = {}", p.precision, p.n, p.sv[0]);
//! }
//! println!("ran on {} ({})", outcome.provenance.backend, outcome.provenance.source.name());
//! ```
//!
//! See `docs/client.md` for the request builder reference, the trait
//! contract, and the local-vs-remote capability matrix.

pub mod sharded;
pub mod wire;

pub use sharded::{RouteStrategy, ShardedClient};

use crate::backend::{cost_model_for, for_kind};
use crate::banded::dense::Dense;
use crate::batch::{BatchCoordinator, BatchInput, BatchMetrics};
use crate::config::{BackendKind, BatchConfig, ServiceConfig, TuneParams};
use crate::coordinator::metrics::LaunchMetrics;
use crate::error::{Error, JobError, Result};
use crate::generate::random_banded;
use crate::obs::trace::{self, TraceId};
use crate::pipeline::stage3::bidiagonal_singular_values;
use crate::pipeline::{accumulate_panels, complete_svd};
use crate::scalar::ScalarKind;
use crate::service::cache::PlanCache;
use crate::service::queue::JobTicket;
use crate::service::{CacheStats, Service};
use crate::util::json::Json;
use crate::util::rng::Xoshiro256;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write as _};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// One problem of a request: either an explicit band payload or a
/// generator spec the client materializes at submit time (both sides of
/// a local/remote pair generate identical values — the band depends only
/// on `(n, bw, seed)`).
#[derive(Clone, Debug)]
pub enum ProblemSpec {
    /// An owned banded matrix plus its bandwidth, any supported
    /// precision.
    Band(BatchInput),
    /// A seeded random banded problem ([`random_banded`]) — the
    /// shape-only form used by the CLI, benches, and tests.
    Random { n: usize, bw: usize, kind: ScalarKind, seed: u64 },
}

impl ProblemSpec {
    fn materialize(self, params: &TuneParams) -> BatchInput {
        match self {
            ProblemSpec::Band(input) => input,
            ProblemSpec::Random { n, bw, kind, seed } => {
                let tw = params.effective_tw(bw);
                let mut rng = Xoshiro256::seed_from_u64(seed);
                match kind {
                    ScalarKind::F64 => {
                        BatchInput::from((random_banded::<f64>(n, bw, tw, &mut rng), bw))
                    }
                    ScalarKind::F32 => {
                        BatchInput::from((random_banded::<f32>(n, bw, tw, &mut rng), bw))
                    }
                    ScalarKind::F16 => BatchInput::from((
                        random_banded::<crate::scalar::F16>(n, bw, tw, &mut rng),
                        bw,
                    )),
                }
            }
        }
    }
}

/// Builder-style description of one submission: a batch of N problems
/// plus the knobs that shape *how* they run (tuning override, priority
/// class, deadline). Build it fluently, hand it to any
/// [`Client::submit`].
///
/// # Examples
///
/// ```
/// use banded_svd::client::ReductionRequest;
/// use banded_svd::scalar::ScalarKind;
/// use std::time::Duration;
///
/// let request = ReductionRequest::new()
///     .random(64, 8, ScalarKind::F64, 1)
///     .random(48, 6, ScalarKind::F32, 2)
///     .priority(1)
///     .deadline(Duration::from_millis(500));
/// assert_eq!(request.len(), 2);
/// assert!(!request.is_empty());
/// ```
#[derive(Clone, Debug, Default)]
pub struct ReductionRequest {
    problems: Vec<ProblemSpec>,
    params: Option<TuneParams>,
    priority: u8,
    deadline: Option<Duration>,
    client_id: Option<String>,
    quota_class: Option<String>,
    vectors: bool,
    trace: Option<TraceId>,
}

impl ReductionRequest {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append an explicit problem (anything convertible to
    /// [`BatchInput`], e.g. `(Banded<f64>, bw)`).
    pub fn problem(mut self, input: impl Into<BatchInput>) -> Self {
        self.problems.push(ProblemSpec::Band(input.into()));
        self
    }

    /// Append a seeded random problem of the given shape and precision.
    pub fn random(mut self, n: usize, bw: usize, kind: ScalarKind, seed: u64) -> Self {
        self.problems.push(ProblemSpec::Random { n, bw, kind, seed });
        self
    }

    /// Override the client's tuning parameters for this request.
    /// Supported by [`LocalClient`] direct mode only: the queued and
    /// remote paths run under the serving side's configured tuning (the
    /// plan cache is keyed on it), and reject an override with a clear
    /// [`Error::Config`] instead of silently ignoring it.
    pub fn params(mut self, params: TuneParams) -> Self {
        self.params = Some(params);
        self
    }

    /// Priority class, lower drains first (queued/remote paths; direct
    /// execution is immediate). Default 0.
    pub fn priority(mut self, priority: u8) -> Self {
        self.priority = priority;
        self
    }

    /// Deadline relative to submission (queued/remote paths): a problem
    /// still queued past it fails with [`JobError::DeadlineExpired`]
    /// instead of executing.
    pub fn deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Caller identity for quota accounting (queued/remote paths). When
    /// the serving side enforces a per-client pending cap
    /// ([`crate::config::ServiceConfig::quota_pending_cap`]), this is the
    /// key it counts against unless a [`ReductionRequest::quota_class`]
    /// overrides it. Anonymous requests are never quota-limited.
    pub fn client_id(mut self, id: impl Into<String>) -> Self {
        self.client_id = Some(id.into());
        self
    }

    /// Quota bucket for admission accounting — lets many client ids
    /// share one pending budget (e.g. a tenant). Takes precedence over
    /// [`ReductionRequest::client_id`] as the quota key.
    pub fn quota_class(mut self, class: impl Into<String>) -> Self {
        self.quota_class = Some(class.into());
        self
    }

    /// Request full singular vectors: every [`ProblemOutcome`] carries
    /// dense n×n `u` / `vt` panels (and σ from the same Demmel–Kahan
    /// rotation stream, so (σ, U, Vᵀ) is one consistent factorization).
    /// The panels are **bitwise identical** across every execution
    /// surface and backend kind — direct, queued, remote, sharded.
    ///
    /// Costs 2·n² f64 per problem; the serving paths decline requests
    /// above [`crate::config::ServiceConfig::vectors_cap_n`] with the
    /// terminal [`JobError::TooLarge`], and a [`RemoteClient`] connected
    /// to a pre-vectors server (wire protocol < 3) declines with the
    /// terminal [`JobError::Unavailable`] instead of silently returning
    /// values only.
    pub fn with_vectors(mut self, vectors: bool) -> Self {
        self.vectors = vectors;
        self
    }

    /// Attach an explicit trace id (see [`crate::obs::trace`]): every
    /// span event the request's problems generate — client side and, on
    /// the queued/remote paths, server side — records under it. Without
    /// this, a fresh id is minted per submission when tracing is enabled
    /// ([`crate::obs::trace::enabled`]); when tracing is off the request
    /// carries no id and every hook no-ops.
    pub fn trace(mut self, trace: TraceId) -> Self {
        self.trace = Some(trace);
        self
    }

    /// The trace id that will cover this request's submission, minting
    /// one now if tracing is on and none was set. `None` when tracing is
    /// off (and no explicit id was attached) — the untraced fast path.
    fn effective_trace(&self) -> Option<TraceId> {
        self.trace.or_else(|| trace::enabled().then(TraceId::mint))
    }

    /// Number of problems in the request.
    pub fn len(&self) -> usize {
        self.problems.len()
    }

    pub fn is_empty(&self) -> bool {
        self.problems.is_empty()
    }

    fn validate(&self) -> Result<()> {
        if self.is_empty() {
            return Err(Error::Config("request has no problems; add .problem()/.random()".into()));
        }
        Ok(())
    }
}

/// Opaque handle on one submitted request, resolved by [`Client::wait`]
/// on the client that issued it.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub struct JobHandle {
    id: u64,
}

/// Handle ids are unique across every client in the process, so a handle
/// from one client can never silently resolve another client's request —
/// waiting on a foreign handle fails with the documented
/// [`Error::Config`] instead.
static NEXT_HANDLE_ID: AtomicU64 = AtomicU64::new(0);

fn next_handle_id() -> u64 {
    NEXT_HANDLE_ID.fetch_add(1, Ordering::Relaxed)
}

/// Where a request was executed and which plan machinery served it.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum ExecutionSource {
    /// [`LocalClient`] direct mode: the caller's `BatchCoordinator`.
    LocalDirect,
    /// [`LocalClient`] queued mode: the embedded in-process [`Service`].
    LocalQueued,
    /// [`RemoteClient`]: a `banded-svd serve` endpoint over TCP.
    Remote,
    /// [`ShardedClient`]: one of several `banded-svd serve` endpoints,
    /// chosen by the client-side router.
    Sharded,
}

impl ExecutionSource {
    pub fn name(self) -> &'static str {
        match self {
            ExecutionSource::LocalDirect => "local-direct",
            ExecutionSource::LocalQueued => "local-queued",
            ExecutionSource::Remote => "remote",
            ExecutionSource::Sharded => "sharded",
        }
    }
}

/// Where this outcome's launch plans came from: execution source,
/// backend, effective tuning, and — for local paths — the plan-cache
/// activity this request caused (hits mean the lowering was amortized).
#[derive(Clone, Debug)]
pub struct PlanProvenance {
    pub source: ExecutionSource,
    /// Backend name (canonical [`BackendKind`] spelling; for remote, the
    /// serving side's reported backend).
    pub backend: String,
    /// Tuning the plans were lowered under. `None` for remote: the
    /// server owns its tuning and does not expose it per job.
    pub params: Option<TuneParams>,
    /// Plan/merge/autotune cache deltas attributable to this request
    /// (local paths only — the remote cache belongs to the server).
    pub cache: Option<CacheStats>,
}

/// One problem's slice of a [`ReductionOutcome`].
#[derive(Clone, Debug)]
pub struct ProblemOutcome {
    pub n: usize,
    pub bw: usize,
    /// Paper-style precision label ("fp64" / "fp32" / "fp16").
    pub precision: &'static str,
    /// Singular values, descending, widened to f64 — **bitwise identical**
    /// across local and remote execution on the same backend kind.
    pub sv: Vec<f64>,
    /// Per-problem launch accounting — identical to a solo run of the
    /// same problem (the batch merge preserves per-problem order). Over
    /// the wire the summary fields ride; `per_launch`/`wall` stay local.
    pub metrics: LaunchMetrics,
    /// Problems co-scheduled in the merged plan that carried this one.
    pub batch_jobs: usize,
    /// Time spent queued before the flush (queued/remote paths).
    pub queue_wait: Option<Duration>,
    /// Largest |element| outside the bidiagonal after the run — observable
    /// only where the reduced matrix lives (local paths).
    pub residual_off_band: Option<f64>,
    /// Dense n×n left singular-vector panel (columns of U), present iff
    /// the request set [`ReductionRequest::with_vectors`]. Widened to
    /// f64 and bitwise identical across every execution surface.
    pub u: Option<Dense<f64>>,
    /// Dense n×n right singular-vector panel (rows of Vᵀ), present iff
    /// the request set [`ReductionRequest::with_vectors`].
    pub vt: Option<Dense<f64>>,
}

/// What a completed request reports back: one [`ProblemOutcome`] per
/// problem (request order), wall time, the batch-level accounting where
/// the client executed locally, and the plan provenance.
#[derive(Clone, Debug)]
pub struct ReductionOutcome {
    pub problems: Vec<ProblemOutcome>,
    /// Wall-clock from submission to last result, as observed by this
    /// client (direct mode: execution; queued/remote: queue + execution).
    pub wall: Duration,
    /// Shared-launch aggregate of the merged plan ([`LocalClient`]
    /// direct mode only — elsewhere the batch composition belongs to the
    /// serving side).
    pub batch: Option<BatchMetrics>,
    pub provenance: PlanProvenance,
}

impl ReductionOutcome {
    /// Problems completed per second of [`ReductionOutcome::wall`].
    /// Clamped like `BatchReport::throughput`: coarse monotone clocks can
    /// report a zero wall for a tiny request, and the rate must stay
    /// finite on every platform.
    pub fn throughput(&self) -> f64 {
        if self.problems.is_empty() {
            return 0.0;
        }
        self.problems.len() as f64 / self.wall.as_secs_f64().max(1e-9)
    }
}

/// Submission counters a client keeps about its own traffic — the
/// client-side half of the reconciliation the equivalence test performs
/// against the server's `stats` verb.
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq)]
pub struct ClientStats {
    /// Problems admitted (handles issued cover this many problems).
    pub jobs_submitted: u64,
    /// Problems whose outcome came back successfully.
    pub jobs_completed: u64,
    /// Problems rejected at submit or failed at wait.
    pub jobs_failed: u64,
}

/// The one obligation every execution surface implements: accept a
/// [`ReductionRequest`], hand back a [`JobHandle`], resolve it to a
/// [`ReductionOutcome`]. Local (in-process, direct or queued) and served
/// (TCP) execution are interchangeable behind `dyn Client`.
pub trait Client {
    /// Validate and admit `request`. Depending on the implementation the
    /// work may run during this call (direct mode) or asynchronously
    /// (queued and remote modes); either way the returned handle resolves
    /// through [`Client::wait`] on the same client.
    fn submit(&self, request: ReductionRequest) -> Result<JobHandle>;

    /// Block until the request behind `handle` completes and return its
    /// outcome. Each handle resolves exactly once; waiting on a foreign
    /// or already-resolved handle is an [`Error::Config`]. Job-level
    /// failures surface as [`Error::Job`] with the typed taxonomy.
    fn wait(&self, handle: JobHandle) -> Result<ReductionOutcome>;

    /// [`Client::submit`] and immediately [`Client::wait`].
    fn submit_wait(&self, request: ReductionRequest) -> Result<ReductionOutcome> {
        let handle = self.submit(request)?;
        self.wait(handle)
    }

    /// This client's own submission counters.
    fn stats(&self) -> ClientStats;
}

fn cache_delta(after: CacheStats, before: CacheStats) -> CacheStats {
    CacheStats {
        plan_hits: after.plan_hits - before.plan_hits,
        plan_misses: after.plan_misses - before.plan_misses,
        merge_hits: after.merge_hits - before.merge_hits,
        merge_misses: after.merge_misses - before.merge_misses,
        tune_hits: after.tune_hits - before.tune_hits,
        tune_misses: after.tune_misses - before.tune_misses,
    }
}

#[derive(Default)]
struct Counters {
    submitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
}

impl Counters {
    fn snapshot(&self) -> ClientStats {
        ClientStats {
            jobs_submitted: self.submitted.load(Ordering::Relaxed),
            jobs_completed: self.completed.load(Ordering::Relaxed),
            jobs_failed: self.failed.load(Ordering::Relaxed),
        }
    }
}

enum LocalPending {
    /// Direct mode executes at submit; the outcome is ready.
    Ready(Box<ReductionOutcome>),
    /// Queued mode: one service ticket per problem, resolved at wait.
    /// `submitted` anchors the outcome's wall at submission time (the
    /// reported wall covers queue + execution no matter how late the
    /// caller waits), `cache_before` snapshots the service's cache
    /// counters at submission so the provenance delta covers the flush
    /// even when it beats the wait, and `trace` carries the request's
    /// trace id to the client-side `respond` events at wait.
    Tickets {
        tickets: Vec<JobTicket>,
        submitted: Instant,
        cache_before: CacheStats,
        trace: Option<TraceId>,
    },
}

enum LocalMode {
    /// Direct execution state: the per-request executor's kind/threads
    /// plus the batch knobs and the persistent plan cache — all of it
    /// unused (and therefore not carried) in queued mode, where the
    /// embedded service owns its own.
    Direct { kind: BackendKind, threads: usize, batch: BatchConfig, cache: PlanCache },
    Queued(Service),
}

/// In-process implementation of [`Client`].
///
/// Two modes share one type:
///
/// - **direct** ([`LocalClient::new`] / [`LocalClient::direct`]): each
///   request becomes one merged [`crate::plan::LaunchPlan`] executed by a
///   [`BatchCoordinator`] on the selected backend, synchronously at
///   submit. Lowerings route through a persistent [`PlanCache`], so
///   repeated shapes amortize across requests exactly as they do in the
///   service.
/// - **queued** ([`LocalClient::queued`]): requests feed an embedded
///   in-process [`Service`] — priced admission, priority/deadline,
///   dynamic micro-batching with concurrent submitters — without a
///   socket in the path.
pub struct LocalClient {
    params: TuneParams,
    mode: LocalMode,
    pending: Mutex<HashMap<u64, LocalPending>>,
    counters: Counters,
}

impl LocalClient {
    /// Direct-mode client on the default threadpool backend (all cores).
    pub fn new(params: TuneParams) -> Self {
        Self::direct(params, BatchConfig::default(), BackendKind::Threadpool, 0)
            .expect("threadpool backend always constructs")
    }

    /// Direct-mode client on an explicit backend kind. Fails for kinds
    /// with no plan-executor form ([`BackendKind::PjrtFused`]).
    ///
    /// Each submit constructs its own executor: backends are deliberately
    /// not shared across requests — the PJRT backend is not `Send` (the
    /// service pins it to one worker thread for the same reason), and the
    /// threadpool's pinned-slot scratch assumes one dispatch at a time —
    /// which is what keeps the client itself shareable across submitter
    /// threads. (Holding a `Mutex<Box<dyn Backend>>` instead would make
    /// the client `!Sync`, because the un-`Send` trait object poisons the
    /// mutex.) Per-request executor construction is the price of a
    /// shareable front door; benchmarks should time
    /// [`ReductionOutcome::wall`], which excludes it, and throughput
    /// workloads should prefer queued mode, whose embedded service owns
    /// one long-lived executor.
    pub fn direct(
        params: TuneParams,
        batch: BatchConfig,
        kind: BackendKind,
        threads: usize,
    ) -> Result<Self> {
        // Validates the kind (rejecting pjrt-fused) without constructing
        // an executor — kept in lockstep with `for_kind` by the backend
        // module's own tests.
        cost_model_for(kind)?;
        Ok(Self {
            params,
            mode: LocalMode::Direct { kind, threads, batch, cache: PlanCache::default() },
            pending: Mutex::new(HashMap::new()),
            counters: Counters::default(),
        })
    }

    /// Queued-mode client: starts (and owns) an in-process [`Service`]
    /// with `cfg`; requests run under the service's tuning and admission
    /// control.
    pub fn queued(cfg: ServiceConfig) -> Result<Self> {
        let params = cfg.params;
        let service = Service::start(cfg)?;
        Ok(Self {
            params,
            mode: LocalMode::Queued(service),
            pending: Mutex::new(HashMap::new()),
            counters: Counters::default(),
        })
    }

    /// The embedded service (queued mode only) — for operational stats
    /// alongside the client surface.
    pub fn service(&self) -> Option<&Service> {
        match &self.mode {
            LocalMode::Queued(service) => Some(service),
            LocalMode::Direct { .. } => None,
        }
    }

    /// The client's default tuning parameters.
    pub fn params(&self) -> &TuneParams {
        &self.params
    }

    fn submit_direct(
        &self,
        request: ReductionRequest,
        kind: BackendKind,
        threads: usize,
        batch: BatchConfig,
        cache: &PlanCache,
    ) -> Result<ReductionOutcome> {
        let params = request.params.unwrap_or(self.params);
        let vectors = request.vectors;
        let mut inputs: Vec<BatchInput> =
            request.problems.into_iter().map(|p| p.materialize(&params)).collect();
        let coord = BatchCoordinator::with_backend(params, batch, for_kind(kind, threads)?)
            .with_plan_cache(cache.clone());
        let before = cache.stats();
        let t0 = Instant::now();
        let (report, log) = if vectors {
            let (report, log) = coord.run_logged(&mut inputs)?;
            (report, Some(log))
        } else {
            (coord.run(&mut inputs)?, None)
        };
        let wall = t0.elapsed();
        let batch_jobs = report.problems.len();
        let problems = report
            .problems
            .iter()
            .enumerate()
            .map(|(p_idx, p)| {
                // Vectors requests take σ from the Demmel–Kahan rotation
                // stream so (σ, U, Vᵀ) is one consistent factorization;
                // values-only requests keep the bisection path bit-for-bit.
                let (sv, u, vt) = match log.as_ref() {
                    Some(log) => {
                        let mut u = Dense::<f64>::identity(p.n);
                        let mut vt = Dense::<f64>::identity(p.n);
                        accumulate_panels(
                            report.plan.merged.as_ref(),
                            log,
                            p_idx,
                            &mut u,
                            &mut vt,
                        );
                        let sv = complete_svd(&p.diag, &p.superdiag, &mut u, &mut vt);
                        (sv, Some(u), Some(vt))
                    }
                    None => (bidiagonal_singular_values(&p.diag, &p.superdiag), None, None),
                };
                ProblemOutcome {
                    n: p.n,
                    bw: p.bw,
                    precision: p.precision,
                    sv,
                    metrics: p.metrics.clone(),
                    batch_jobs,
                    queue_wait: None,
                    residual_off_band: Some(p.residual_off_band),
                    u,
                    vt,
                }
            })
            .collect();
        Ok(ReductionOutcome {
            problems,
            wall,
            batch: Some(report.metrics.clone()),
            provenance: PlanProvenance {
                source: ExecutionSource::LocalDirect,
                backend: kind.name().to_string(),
                params: Some(params),
                cache: Some(cache_delta(cache.stats(), before)),
            },
        })
    }

    /// Submit every problem of `request` to the embedded service. Owns
    /// the request's counter accounting on every path — including the
    /// partial-admission one: when problem k of N is rejected, the k
    /// already-admitted problems still execute on the service, so their
    /// outcomes are drained (and counted) before the rejection is
    /// surfaced. Client and service counters therefore always reconcile,
    /// and a caller retrying a retryable rejection re-submits a request
    /// whose earlier problems are not silently in flight.
    fn submit_queued(
        &self,
        request: ReductionRequest,
        trace_id: Option<TraceId>,
        service: &Service,
    ) -> Result<Vec<JobTicket>> {
        let jobs = request.len() as u64;
        if let Some(params) = request.params {
            if params != self.params {
                self.counters.failed.fetch_add(jobs, Ordering::Relaxed);
                return Err(Error::Config(format!(
                    "queued client runs under the service's tuning {:?}; start the service \
                     with the desired params instead of overriding per request ({params:?})",
                    self.params
                )));
            }
        }
        let priority = request.priority;
        let deadline = request.deadline;
        let client_id = request.client_id;
        let quota_class = request.quota_class;
        let vectors = request.vectors;
        let inputs: Vec<BatchInput> =
            request.problems.into_iter().map(|p| p.materialize(&self.params)).collect();
        let mut tickets = Vec::with_capacity(inputs.len());
        for input in inputs {
            if let Some(t) = trace_id {
                let shape = format!("n={} bw={}", input.n(), input.bw());
                trace::event(t, 0, "submit", "client", None, Duration::ZERO, shape);
            }
            match service.submit_traced(
                client_id.as_deref(),
                quota_class.as_deref(),
                trace_id,
                input,
                priority,
                deadline,
                vectors,
            ) {
                Ok(ticket) => tickets.push(ticket),
                Err(e) => {
                    let admitted = tickets.len() as u64;
                    self.counters.submitted.fetch_add(admitted, Ordering::Relaxed);
                    self.counters.failed.fetch_add(jobs - admitted, Ordering::Relaxed);
                    for ticket in tickets {
                        match ticket.wait() {
                            Ok(_) => self.counters.completed.fetch_add(1, Ordering::Relaxed),
                            Err(_) => self.counters.failed.fetch_add(1, Ordering::Relaxed),
                        };
                    }
                    return Err(e);
                }
            }
        }
        self.counters.submitted.fetch_add(jobs, Ordering::Relaxed);
        Ok(tickets)
    }

    fn wait_queued(
        &self,
        tickets: Vec<JobTicket>,
        submitted: Instant,
        cache_before: CacheStats,
        trace_id: Option<TraceId>,
        service: &Service,
    ) -> Result<ReductionOutcome> {
        let mut problems = Vec::with_capacity(tickets.len());
        let mut first_error: Option<JobError> = None;
        for ticket in tickets {
            match ticket.wait() {
                Ok(r) => {
                    self.counters.completed.fetch_add(1, Ordering::Relaxed);
                    if let Some(t) = trace_id {
                        let detail = format!("n={} sv={}", r.n, r.sv.len());
                        trace::event(t, r.id, "respond", "client", None, Duration::ZERO, detail);
                    }
                    problems.push(ProblemOutcome {
                        n: r.n,
                        bw: r.bw,
                        precision: r.precision,
                        sv: r.sv,
                        metrics: r.metrics,
                        batch_jobs: r.batch_jobs,
                        queue_wait: Some(r.queue_wait),
                        residual_off_band: None,
                        u: r.u,
                        vt: r.vt,
                    });
                }
                Err(e) => {
                    self.counters.failed.fetch_add(1, Ordering::Relaxed);
                    first_error.get_or_insert(e);
                }
            }
        }
        if let Some(e) = first_error {
            return Err(Error::Job(e));
        }
        Ok(ReductionOutcome {
            problems,
            wall: submitted.elapsed(),
            batch: None,
            provenance: PlanProvenance {
                source: ExecutionSource::LocalQueued,
                backend: service.config().backend.name().to_string(),
                params: Some(self.params),
                cache: Some(cache_delta(service.stats().cache, cache_before)),
            },
        })
    }
}

impl Client for LocalClient {
    fn submit(&self, request: ReductionRequest) -> Result<JobHandle> {
        request.validate()?;
        let jobs = request.len() as u64;
        let pending = match &self.mode {
            LocalMode::Direct { kind, threads, batch, cache } => {
                let outcome = match self.submit_direct(request, *kind, *threads, *batch, cache) {
                    Ok(outcome) => outcome,
                    Err(e) => {
                        self.counters.failed.fetch_add(jobs, Ordering::Relaxed);
                        return Err(e);
                    }
                };
                self.counters.submitted.fetch_add(jobs, Ordering::Relaxed);
                self.counters.completed.fetch_add(jobs, Ordering::Relaxed);
                LocalPending::Ready(Box::new(outcome))
            }
            // submit_queued owns the counter accounting for this arm
            // (including the partial-admission drain).
            LocalMode::Queued(service) => {
                let submitted = Instant::now();
                let cache_before = service.stats().cache;
                let trace = request.effective_trace();
                LocalPending::Tickets {
                    tickets: self.submit_queued(request, trace, service)?,
                    submitted,
                    cache_before,
                    trace,
                }
            }
        };
        let id = next_handle_id();
        self.pending.lock().unwrap().insert(id, pending);
        Ok(JobHandle { id })
    }

    fn wait(&self, handle: JobHandle) -> Result<ReductionOutcome> {
        let pending = self.pending.lock().unwrap().remove(&handle.id).ok_or_else(|| {
            Error::Config(format!("unknown or already-resolved handle {:?}", handle))
        })?;
        match pending {
            LocalPending::Ready(outcome) => Ok(*outcome),
            LocalPending::Tickets { tickets, submitted, cache_before, trace } => {
                match &self.mode {
                    LocalMode::Queued(service) => {
                        self.wait_queued(tickets, submitted, cache_before, trace, service)
                    }
                    LocalMode::Direct { .. } => unreachable!("tickets only exist in queued mode"),
                }
            }
        }
    }

    fn stats(&self) -> ClientStats {
        self.counters.snapshot()
    }
}

struct RemoteState {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// Outcomes of submitted requests awaiting their [`Client::wait`].
    done: HashMap<u64, Result<ReductionOutcome>>,
}

/// [`Client`] over the JSON-lines wire of a `banded-svd serve` endpoint
/// — the served twin of [`LocalClient`]. One connection per client;
/// spin up several clients for the concurrency that feeds the server's
/// micro-batcher.
///
/// Each problem is one strict request/response round trip (the server
/// serializes a connection anyway — a `submit` blocks it until the job
/// completes — so client-side pipelining would buy nothing and risks
/// filling both socket buffers). `submit` therefore blocks for the
/// request's duration, like [`LocalClient`] direct mode.
pub struct RemoteClient {
    addr: String,
    backend: String,
    /// Wire protocol version the endpoint reported at connect — one of
    /// [`wire::PROTO_ACCEPTED`]. Capability gate: vector requests need
    /// protocol ≥ 3 (older servers would silently drop the flag), and
    /// the binary band-frame transport needs ≥ 4.
    proto: u32,
    /// Submit band payloads as v4 binary frames instead of inline JSON
    /// arrays (see [`RemoteClient::binary_band_frames`]).
    binary_frames: bool,
    state: Mutex<RemoteState>,
    counters: Counters,
}

impl RemoteClient {
    /// Connect and handshake: a `ping` round trip first (the server must
    /// speak a protocol in [`wire::PROTO_ACCEPTED`] — a missing or
    /// unsupported `proto` is a typed [`JobError::Unavailable`], not a
    /// config error, so routing layers treat the endpoint as down), then
    /// one `stats` round trip recording the serving backend for
    /// provenance. The negotiated version is kept: a protocol-2 server
    /// serves values-only traffic exactly as before, and a vectors
    /// request against it fails client-side with a terminal
    /// [`JobError::Unavailable`] instead of a silently degraded result.
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr).map_err(Error::Io)?;
        let reader = BufReader::new(stream.try_clone().map_err(Error::Io)?);
        let mut state = RemoteState { reader, writer: stream, done: HashMap::new() };
        let pong = Self::roundtrip(&mut state, "{\"verb\":\"ping\"}")?;
        let proto = match pong.get("proto").and_then(Json::as_usize) {
            Some(v) if wire::PROTO_ACCEPTED.contains(&(v as u32)) => v as u32,
            Some(v) => {
                return Err(Error::Job(JobError::Unavailable {
                    reason: format!(
                        "endpoint {addr} speaks wire protocol {v}; this client accepts {:?}",
                        wire::PROTO_ACCEPTED
                    ),
                }));
            }
            None => {
                return Err(Error::Job(JobError::Unavailable {
                    reason: format!(
                        "endpoint {addr} reports no wire protocol version (pre-versioning \
                         server); this client accepts {:?}",
                        wire::PROTO_ACCEPTED
                    ),
                }));
            }
        };
        let stats = Self::roundtrip(&mut state, "{\"verb\":\"stats\"}")?;
        let backend = stats
            .get("stats")
            .and_then(|s| s.get("backend"))
            .and_then(Json::as_str)
            .unwrap_or("unknown")
            .to_string();
        Ok(Self {
            addr: addr.to_string(),
            backend,
            proto,
            binary_frames: false,
            state: Mutex::new(state),
            counters: Counters::default(),
        })
    }

    /// Opt in to (or out of) the v4 binary band-frame transport for
    /// subsequent submits: every control and response line stays JSON,
    /// but the band payload follows the control line as a
    /// length-prefixed binary frame ([`wire::encode_band_frame`]) —
    /// bitwise-identical values in ~2.5× fewer wire bytes. Errors when
    /// the connected endpoint predates the framed transport (wire
    /// protocol < 4), so the opt-in can never silently downgrade to a
    /// server that would misread the stream.
    pub fn binary_band_frames(&mut self, on: bool) -> Result<()> {
        if on && self.proto < 4 {
            return Err(Error::Job(JobError::Unavailable {
                reason: format!(
                    "endpoint {} speaks wire protocol {}, which predates binary band \
                     frames (needs >= 4); upgrade the server or keep inline bands",
                    self.addr, self.proto
                ),
            }));
        }
        self.binary_frames = on;
        Ok(())
    }

    /// The endpoint this client speaks to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// The serving side's backend name (from the connect handshake).
    pub fn backend(&self) -> &str {
        &self.backend
    }

    /// The wire protocol version negotiated at connect (one of
    /// [`wire::PROTO_ACCEPTED`]).
    pub fn proto(&self) -> u32 {
        self.proto
    }

    fn roundtrip(state: &mut RemoteState, line: &str) -> Result<Json> {
        writeln!(state.writer, "{line}").map_err(Error::Io)?;
        state.writer.flush().map_err(Error::Io)?;
        Self::read_response(state)
    }

    fn read_response(state: &mut RemoteState) -> Result<Json> {
        let mut response = String::new();
        state.reader.read_line(&mut response).map_err(Error::Io)?;
        if response.is_empty() {
            return Err(Error::Job(JobError::Unavailable {
                reason: "server closed the connection".into(),
            }));
        }
        Json::parse(response.trim_end())
            .map_err(|e| Error::Config(format!("bad response from server: {e}")))
    }

    /// Fetch the server's operational stats (`stats` verb) — the
    /// server-side counters the equivalence test reconciles client
    /// counters against.
    pub fn server_stats(&self) -> Result<Json> {
        let mut state = self.state.lock().unwrap();
        let response = Self::roundtrip(&mut state, "{\"verb\":\"stats\"}")?;
        response
            .get("stats")
            .cloned()
            .ok_or_else(|| Error::Config("stats response missing body".into()))
    }

    /// Fetch the server's Prometheus text exposition (`metrics` verb) —
    /// the unified-metrics rendering of the same counters `stats`
    /// reports, plus the latency histograms.
    pub fn server_metrics(&self) -> Result<String> {
        let mut state = self.state.lock().unwrap();
        let response = Self::roundtrip(&mut state, "{\"verb\":\"metrics\"}")?;
        if response.get("ok").and_then(Json::as_bool) != Some(true) {
            return Err(wire::parse_error(&response));
        }
        response
            .get("text")
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| Error::Config("metrics response missing text body".into()))
    }

    /// Ask the server to shut down (acknowledged, then the endpoint
    /// drains and exits).
    pub fn shutdown(&self) -> Result<()> {
        let mut state = self.state.lock().unwrap();
        let ack = Self::roundtrip(&mut state, "{\"verb\":\"shutdown\"}")?;
        if ack.get("ok").and_then(Json::as_bool) == Some(true) {
            Ok(())
        } else {
            Err(Error::Config(format!("shutdown refused: {}", ack.render())))
        }
    }

    /// Run one request's problems as strict round trips on the locked
    /// connection. Every written line gets its response read before the
    /// next write, so the connection can never desynchronize and the
    /// socket buffers can never fill in both directions at once.
    ///
    /// Counter accounting covers every exit: a transport failure
    /// (connection gone, unparsable response) counts the current problem
    /// *and* every not-yet-attempted one into `jobs_failed`, so
    /// `submitted = completed + failed` reconciles even when the server
    /// dies mid-request.
    #[allow(clippy::too_many_arguments)]
    fn run_request(
        &self,
        state: &mut RemoteState,
        inputs: Vec<BatchInput>,
        priority: u8,
        deadline: Option<Duration>,
        identity: wire::RequestIdentity<'_>,
        vectors: bool,
        trace_id: Option<TraceId>,
    ) -> Result<ReductionOutcome> {
        let t0 = Instant::now();
        let mut problems = Vec::with_capacity(inputs.len());
        let mut first_error: Option<Error> = None;
        for (idx, input) in inputs.iter().enumerate() {
            let fail_rest = |e: Error| {
                let remaining = (inputs.len() - idx) as u64;
                self.counters.failed.fetch_add(remaining, Ordering::Relaxed);
                e
            };
            if let Some(t) = trace_id {
                let shape = format!("n={} bw={}", input.n(), input.bw());
                trace::event(t, 0, "submit", "client", None, Duration::ZERO, shape);
            }
            let transport = if self.binary_frames {
                let (line, frame) = wire::submit_request_framed(
                    input, priority, deadline, identity, vectors, trace_id,
                );
                writeln!(state.writer, "{line}")
                    .and_then(|()| state.writer.write_all(&frame))
                    .and_then(|()| state.writer.flush())
                    .map_err(Error::Io)
            } else {
                let line = wire::submit_request_for_input(
                    input, priority, deadline, identity, vectors, trace_id,
                );
                writeln!(state.writer, "{line}")
                    .and_then(|()| state.writer.flush())
                    .map_err(Error::Io)
            };
            if let Err(e) = transport {
                return Err(fail_rest(e));
            }
            let response = match Self::read_response(state) {
                Ok(response) => response,
                Err(e) => return Err(fail_rest(e)),
            };
            match wire::parse_submit_response(&response) {
                Ok(r) => {
                    self.counters.completed.fetch_add(1, Ordering::Relaxed);
                    if let Some(t) = trace_id {
                        let detail = format!("n={} sv={}", r.n, r.sv.len());
                        trace::event(t, r.id, "respond", "client", None, Duration::ZERO, detail);
                    }
                    problems.push(ProblemOutcome {
                        n: r.n,
                        bw: r.bw,
                        precision: r.precision,
                        sv: r.sv,
                        metrics: r.metrics,
                        batch_jobs: r.batch_jobs,
                        queue_wait: Some(r.queue_wait),
                        residual_off_band: None,
                        u: r.u,
                        vt: r.vt,
                    });
                }
                Err(e) => {
                    self.counters.failed.fetch_add(1, Ordering::Relaxed);
                    if first_error.is_none() {
                        first_error = Some(e);
                    }
                }
            }
        }
        if let Some(e) = first_error {
            return Err(e);
        }
        Ok(ReductionOutcome {
            problems,
            wall: t0.elapsed(),
            batch: None,
            provenance: PlanProvenance {
                source: ExecutionSource::Remote,
                backend: self.backend.clone(),
                params: None,
                cache: None,
            },
        })
    }
}

impl Client for RemoteClient {
    fn submit(&self, request: ReductionRequest) -> Result<JobHandle> {
        request.validate()?;
        if request.params.is_some() {
            self.counters.failed.fetch_add(request.len() as u64, Ordering::Relaxed);
            return Err(Error::Config(
                "the remote server owns its tuning parameters; start `banded-svd serve` with \
                 the desired --tw/--tpb/--max-blocks instead of overriding per request"
                    .into(),
            ));
        }
        if request.vectors && self.proto < 3 {
            self.counters.failed.fetch_add(request.len() as u64, Ordering::Relaxed);
            return Err(Error::Job(JobError::Unavailable {
                reason: format!(
                    "endpoint {} speaks wire protocol {}, which predates singular-vector \
                     serving (needs >= 3); upgrade the server or drop .with_vectors()",
                    self.addr, self.proto
                ),
            }));
        }
        let trace_id = request.effective_trace();
        let priority = request.priority;
        let deadline = request.deadline;
        let client_id = request.client_id;
        let quota_class = request.quota_class;
        let vectors = request.vectors;
        // Materialization params only size local fill-in storage; the
        // band payload depends solely on (n, bw, seed), so local and
        // remote materializations agree (see ProblemSpec).
        let materialize_params = TuneParams { tpb: 1, tw: 1, max_blocks: 1 };
        let inputs: Vec<BatchInput> = request
            .problems
            .into_iter()
            .map(|p| p.materialize(&materialize_params))
            .collect();
        self.counters.submitted.fetch_add(inputs.len() as u64, Ordering::Relaxed);
        let identity = wire::RequestIdentity {
            client_id: client_id.as_deref(),
            quota_class: quota_class.as_deref(),
        };
        let mut state = self.state.lock().unwrap();
        let outcome =
            self.run_request(&mut state, inputs, priority, deadline, identity, vectors, trace_id);
        let id = next_handle_id();
        state.done.insert(id, outcome);
        Ok(JobHandle { id })
    }

    fn wait(&self, handle: JobHandle) -> Result<ReductionOutcome> {
        self.state.lock().unwrap().done.remove(&handle.id).ok_or_else(|| {
            Error::Config(format!("unknown or already-resolved handle {:?}", handle))
        })?
    }

    fn stats(&self) -> ClientStats {
        self.counters.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::SequentialBackend;
    use crate::config::PackingPolicy;
    use crate::pipeline::banded_singular_values_with;

    fn params() -> TuneParams {
        TuneParams { tpb: 32, tw: 4, max_blocks: 24 }
    }

    fn service_cfg() -> ServiceConfig {
        ServiceConfig {
            params: params(),
            batch: BatchConfig { max_coresident: 4, policy: PackingPolicy::RoundRobin },
            backend: BackendKind::Sequential,
            threads: 1,
            window: Duration::from_micros(100),
            queue_cap: 32,
            backlog_cap_s: 1e9,
            cache_cap: 16,
            arch: "H100",
            workers: 1,
            routing: crate::config::ShardRouting::LeastLoaded,
            quota_pending_cap: 0,
            vectors_cap_n: crate::config::DEFAULT_VECTORS_CAP_N,
        }
    }

    #[test]
    fn direct_client_matches_the_explicit_backend_pipeline_bitwise() {
        let params = params();
        let mut rng = Xoshiro256::seed_from_u64(11);
        let (n, bw) = (48, 6);
        let a = random_banded::<f64>(n, bw, params.effective_tw(bw), &mut rng);
        let want =
            banded_singular_values_with(&SequentialBackend::new(), &a, bw, &params).unwrap();

        let client =
            LocalClient::direct(params, BatchConfig::default(), BackendKind::Sequential, 1)
                .unwrap();
        let outcome = client.submit_wait(ReductionRequest::new().problem((a, bw))).unwrap();
        assert_eq!(outcome.problems.len(), 1);
        let p = &outcome.problems[0];
        assert_eq!(p.sv, want);
        assert_eq!((p.n, p.bw, p.precision), (n, bw, "fp64"));
        assert_eq!(p.residual_off_band, Some(0.0));
        assert!(p.metrics.launches > 0);
        assert_eq!(outcome.provenance.source, ExecutionSource::LocalDirect);
        assert_eq!(outcome.provenance.backend, "sequential");
        assert_eq!(outcome.provenance.params, Some(params));
        assert!(outcome.batch.is_some());
        let stats = client.stats();
        assert_eq!((stats.jobs_submitted, stats.jobs_completed, stats.jobs_failed), (1, 1, 0));
    }

    #[test]
    fn queued_client_matches_direct_client_bitwise() {
        let request = || {
            ReductionRequest::new()
                .random(40, 5, ScalarKind::F64, 3)
                .random(32, 4, ScalarKind::F32, 4)
        };
        let direct =
            LocalClient::direct(params(), BatchConfig::default(), BackendKind::Sequential, 1)
                .unwrap();
        let queued = LocalClient::queued(service_cfg()).unwrap();
        let d = direct.submit_wait(request()).unwrap();
        let q = queued.submit_wait(request()).unwrap();
        assert_eq!(d.problems.len(), q.problems.len());
        for (dp, qp) in d.problems.iter().zip(q.problems.iter()) {
            assert_eq!(dp.sv, qp.sv);
            assert_eq!(dp.metrics.launches, qp.metrics.launches);
            assert_eq!(dp.metrics.tasks, qp.metrics.tasks);
            assert_eq!(dp.metrics.bytes, qp.metrics.bytes);
            assert!(qp.queue_wait.is_some());
        }
        assert_eq!(q.provenance.source, ExecutionSource::LocalQueued);
        assert_eq!(queued.service().unwrap().stats().jobs_completed, 2);
    }

    #[test]
    fn direct_client_vector_panels_match_the_logged_pipeline_bitwise() {
        use crate::pipeline::banded_svd_vectors_with;
        let params = params();
        let mut rng = Xoshiro256::seed_from_u64(29);
        let (n, bw) = (48, 6);
        let a = random_banded::<f64>(n, bw, params.effective_tw(bw), &mut rng);
        let want =
            banded_svd_vectors_with(&SequentialBackend::new(), &a, bw, &params).unwrap();

        let client =
            LocalClient::direct(params, BatchConfig::default(), BackendKind::Sequential, 1)
                .unwrap();
        let outcome = client
            .submit_wait(ReductionRequest::new().problem((a.clone(), bw)).with_vectors(true))
            .unwrap();
        let p = &outcome.problems[0];
        assert_eq!(p.sv, want.sv);
        assert_eq!(p.u.as_ref().unwrap().data, want.u.data);
        assert_eq!(p.vt.as_ref().unwrap().data, want.vt.data);
        // Values-only requests stay panel-free (and keep bisection σ).
        let plain = client.submit_wait(ReductionRequest::new().problem((a, bw))).unwrap();
        assert!(plain.problems[0].u.is_none());
        assert!(plain.problems[0].vt.is_none());
    }

    #[test]
    fn queued_client_vector_panels_match_direct_bitwise() {
        let request = || {
            ReductionRequest::new()
                .random(40, 5, ScalarKind::F64, 31)
                .random(32, 4, ScalarKind::F64, 32)
                .with_vectors(true)
        };
        let direct =
            LocalClient::direct(params(), BatchConfig::default(), BackendKind::Sequential, 1)
                .unwrap();
        let queued = LocalClient::queued(service_cfg()).unwrap();
        let d = direct.submit_wait(request()).unwrap();
        let q = queued.submit_wait(request()).unwrap();
        assert_eq!(d.problems.len(), q.problems.len());
        for (dp, qp) in d.problems.iter().zip(q.problems.iter()) {
            assert_eq!(dp.sv, qp.sv);
            assert_eq!(dp.u.as_ref().unwrap().data, qp.u.as_ref().unwrap().data);
            assert_eq!(dp.vt.as_ref().unwrap().data, qp.vt.as_ref().unwrap().data);
        }
    }

    #[test]
    fn oversized_vectors_request_is_a_terminal_too_large_error() {
        let cfg = ServiceConfig { vectors_cap_n: 32, ..service_cfg() };
        let client = LocalClient::queued(cfg).unwrap();
        let err = client
            .submit(ReductionRequest::new().random(48, 6, ScalarKind::F64, 5).with_vectors(true))
            .unwrap_err();
        assert_eq!(err.as_job().unwrap().kind(), "too-large");
        assert!(!err.is_retryable(), "{err}");
        // The same shape without vectors is admitted.
        client
            .submit_wait(ReductionRequest::new().random(48, 6, ScalarKind::F64, 5))
            .unwrap();
    }

    #[test]
    fn plan_cache_amortizes_across_requests() {
        let client =
            LocalClient::direct(params(), BatchConfig::default(), BackendKind::Sequential, 1)
                .unwrap();
        let request = || ReductionRequest::new().random(36, 5, ScalarKind::F64, 9);
        let cold = client.submit_wait(request()).unwrap();
        let warm = client.submit_wait(request()).unwrap();
        let cold_cache = cold.provenance.cache.unwrap();
        let warm_cache = warm.provenance.cache.unwrap();
        assert!(cold_cache.plan_misses > 0);
        assert!(warm_cache.plan_hits > 0, "{warm_cache:?}");
        // Bitwise-stable across the cache hit, of course.
        assert_eq!(cold.problems[0].sv, warm.problems[0].sv);
    }

    #[test]
    fn params_override_applies_in_direct_mode_and_rejects_in_queued_mode() {
        let override_params = TuneParams { tpb: 32, tw: 2, max_blocks: 8 };
        let client =
            LocalClient::direct(params(), BatchConfig::default(), BackendKind::Sequential, 1)
                .unwrap();
        let outcome = client
            .submit_wait(
                ReductionRequest::new()
                    .random(32, 4, ScalarKind::F64, 5)
                    .params(override_params),
            )
            .unwrap();
        assert_eq!(outcome.provenance.params, Some(override_params));

        let queued = LocalClient::queued(service_cfg()).unwrap();
        let err = queued
            .submit_wait(
                ReductionRequest::new()
                    .random(32, 4, ScalarKind::F64, 5)
                    .params(override_params),
            )
            .unwrap_err();
        assert!(err.to_string().contains("tuning"), "{err}");
        // Overriding with the service's own params is fine.
        queued
            .submit_wait(
                ReductionRequest::new().random(32, 4, ScalarKind::F64, 5).params(params()),
            )
            .unwrap();
    }

    #[test]
    fn empty_requests_and_stale_handles_are_config_errors() {
        let client = LocalClient::new(params());
        assert!(client.submit(ReductionRequest::new()).is_err());
        let handle =
            client.submit(ReductionRequest::new().random(24, 3, ScalarKind::F64, 1)).unwrap();
        client.wait(handle).unwrap();
        let err = client.wait(handle).unwrap_err();
        assert!(err.to_string().contains("handle"), "{err}");
    }

    #[test]
    fn queued_rejection_surfaces_the_retryable_taxonomy() {
        // Depth cap 1 via queue_cap; fill the queue with a long window so
        // the second submission is rejected at admission.
        let cfg = ServiceConfig {
            queue_cap: 1,
            window: Duration::from_millis(200),
            batch: BatchConfig { max_coresident: 8, policy: PackingPolicy::RoundRobin },
            ..service_cfg()
        };
        let client = LocalClient::queued(cfg).unwrap();
        let request = || ReductionRequest::new().random(48, 6, ScalarKind::F64, 2).priority(0);
        // Two problems in one request: the first occupies the queue, the
        // second must bounce off the depth cap — retryably.
        let err = client
            .submit(
                ReductionRequest::new()
                    .random(48, 6, ScalarKind::F64, 2)
                    .random(48, 6, ScalarKind::F64, 3),
            )
            .unwrap_err();
        assert!(err.is_retryable(), "{err}");
        assert_eq!(err.as_job().unwrap().kind(), "overloaded");
        // The taxonomy is actionable: retry until the queue drains.
        loop {
            match client.submit_wait(request()) {
                Ok(_) => break,
                Err(e) if e.is_retryable() => std::thread::sleep(Duration::from_millis(10)),
                Err(e) => panic!("non-retryable error during backoff: {e}"),
            }
        }
        let stats = client.stats();
        assert!(stats.jobs_failed >= 1);
    }

    #[test]
    fn quota_cap_surfaces_the_retryable_quota_taxonomy() {
        // Pending cap 1 per client: the second problem of an identified
        // request bounces off the quota, retryably; anonymous traffic
        // (no client_id/quota_class) is never quota-limited.
        let cfg = ServiceConfig {
            quota_pending_cap: 1,
            window: Duration::from_millis(100),
            ..service_cfg()
        };
        let client = LocalClient::queued(cfg).unwrap();
        let err = client
            .submit(
                ReductionRequest::new()
                    .random(32, 4, ScalarKind::F64, 1)
                    .random(32, 4, ScalarKind::F64, 2)
                    .client_id("tenant-a"),
            )
            .unwrap_err();
        assert!(err.is_retryable(), "{err}");
        assert_eq!(err.as_job().unwrap().kind(), "quota-exceeded");
        client
            .submit_wait(
                ReductionRequest::new()
                    .random(32, 4, ScalarKind::F64, 3)
                    .random(32, 4, ScalarKind::F64, 4),
            )
            .unwrap();
    }

    #[test]
    fn expired_deadline_is_a_terminal_deadline_error() {
        let cfg = ServiceConfig { window: Duration::from_millis(20), ..service_cfg() };
        let client = LocalClient::queued(cfg).unwrap();
        let err = client
            .submit_wait(
                ReductionRequest::new()
                    .random(24, 3, ScalarKind::F64, 1)
                    .deadline(Duration::ZERO),
            )
            .unwrap_err();
        let job = err.as_job().expect("job-level error");
        assert_eq!(job.kind(), "deadline-expired");
        assert!(!err.is_retryable());
    }
}
