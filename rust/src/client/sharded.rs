//! [`ShardedClient`] — the [`Client`] over *several* `banded-svd serve`
//! endpoints at once.
//!
//! One serve process is one failure domain and one throughput ceiling;
//! the sharded client spreads requests over a fleet of them and keeps
//! working when members die. Per request it:
//!
//! 1. **routes** to a preferred endpoint ([`RouteStrategy`]): `hash`
//!    pins identical request shapes to the same endpoint (its plan cache
//!    stays hot), `least-loaded` picks the endpoint with the fewest
//!    in-flight requests from this client;
//! 2. **fails over** on endpoint death — a transport error or a typed
//!    [`JobError::Unavailable`] (including the ping handshake refusing a
//!    protocol mismatch, see [`crate::client::wire::PROTO_ACCEPTED`])
//!    marks the endpoint down and the request moves to the next one,
//!    reconnecting lazily when a downed endpoint comes back. Capability
//!    gaps ride the same signal: a vectors request
//!    ([`ReductionRequest::with_vectors`]) against a protocol-2 member
//!    fails client-side with `Unavailable`, so a mixed fleet routes it
//!    to a protocol-3 member, and an all-legacy fleet surfaces the
//!    terminal "all endpoints down" error instead of a degraded result;
//! 3. **retries** retryable rejections ([`JobError::is_retryable`]:
//!    overloaded, quota-exceeded) with a short backoff, bounded by
//!    [`MAX_RETRY_ROUNDS`] full sweeps of the fleet.
//!
//! Replaying a request on another endpoint after a mid-request failure
//! is safe because a reduction is pure: the band payload determines the
//! result bitwise on a given backend kind, so the survivor returns
//! exactly what the dead endpoint would have
//! (`rust/tests/client_equivalence.rs` kills an endpoint mid-stream and
//! checks σ stays bitwise equal to [`super::LocalClient`]).
//!
//! Only when *every* endpoint is down does a request fail, with
//! [`JobError::Unavailable`] naming the fleet size — itself retryable
//! context for a caller-side supervisor.

use super::{
    next_handle_id, Client, ClientStats, Counters, ExecutionSource, JobHandle, ProblemSpec,
    ReductionOutcome, ReductionRequest, RemoteClient,
};
use crate::error::{Error, JobError, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// How [`ShardedClient`] picks the endpoint a request starts on (failover
/// then proceeds round-robin from there).
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq)]
pub enum RouteStrategy {
    /// Stable FNV-1a hash of the request's problem shapes: the same
    /// request spec always lands on the same (healthy) endpoint, keeping
    /// each server's plan cache hot for its slice of the traffic.
    #[default]
    Hash,
    /// The endpoint with the fewest requests in flight *from this
    /// client*; ties rotate so an idle fleet is filled round-robin.
    LeastLoaded,
}

impl RouteStrategy {
    pub fn name(self) -> &'static str {
        match self {
            RouteStrategy::Hash => "hash",
            RouteStrategy::LeastLoaded => "least-loaded",
        }
    }
}

impl std::str::FromStr for RouteStrategy {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "hash" => Ok(RouteStrategy::Hash),
            "least-loaded" | "load" => Ok(RouteStrategy::LeastLoaded),
            other => Err(Error::Config(format!(
                "unknown route strategy {other:?} (hash|least-loaded)"
            ))),
        }
    }
}

/// Full fleet sweeps a request may spend backing off retryable
/// rejections before the last rejection is surfaced to the caller.
pub const MAX_RETRY_ROUNDS: usize = 3;

/// One fleet member: its address, the lazily (re)established connection
/// (`None` = currently down), and this client's in-flight count against
/// it (the least-loaded signal).
struct Endpoint {
    addr: String,
    client: Mutex<Option<RemoteClient>>,
    inflight: AtomicUsize,
}

/// [`Client`] over several `banded-svd serve` endpoints — routing,
/// health-checked failover, bounded retry. See the module docs for the
/// policy; see [`super::RemoteClient`] for the single-endpoint wire
/// behavior each attempt delegates to.
pub struct ShardedClient {
    endpoints: Vec<Endpoint>,
    strategy: RouteStrategy,
    /// Tie-break rotation for least-loaded routing.
    rotate: AtomicUsize,
    done: Mutex<HashMap<u64, Result<ReductionOutcome>>>,
    counters: Counters,
}

impl ShardedClient {
    /// Connect to a fleet. Each endpoint gets the full [`RemoteClient`]
    /// handshake (ping-first protocol check, then backend discovery); at
    /// least one must succeed — members that are down now are retried
    /// lazily when a request routes to them.
    pub fn connect<S: AsRef<str>>(addrs: &[S], strategy: RouteStrategy) -> Result<Self> {
        if addrs.is_empty() {
            return Err(Error::Config("sharded client needs at least one endpoint".into()));
        }
        let endpoints: Vec<Endpoint> = addrs
            .iter()
            .map(|a| Endpoint {
                addr: a.as_ref().to_string(),
                client: Mutex::new(None),
                inflight: AtomicUsize::new(0),
            })
            .collect();
        let mut healthy = 0usize;
        let mut last: Option<Error> = None;
        for endpoint in &endpoints {
            match RemoteClient::connect(&endpoint.addr) {
                Ok(client) => {
                    *endpoint.client.lock().unwrap() = Some(client);
                    healthy += 1;
                }
                Err(e) => last = Some(e),
            }
        }
        if healthy == 0 {
            return Err(Error::Job(JobError::Unavailable {
                reason: format!(
                    "all {} endpoints are down (last: {})",
                    endpoints.len(),
                    last.expect("at least one endpoint was attempted")
                ),
            }));
        }
        Ok(Self {
            endpoints,
            strategy,
            rotate: AtomicUsize::new(0),
            done: Mutex::new(HashMap::new()),
            counters: Counters::default(),
        })
    }

    /// The configured endpoint addresses, in routing order.
    pub fn endpoints(&self) -> Vec<&str> {
        self.endpoints.iter().map(|e| e.addr.as_str()).collect()
    }

    /// Endpoints currently holding a live connection. Down members may
    /// come back: every request routed to one retries the connect.
    pub fn healthy(&self) -> usize {
        self.endpoints.iter().filter(|e| e.client.lock().unwrap().is_some()).count()
    }

    pub fn strategy(&self) -> RouteStrategy {
        self.strategy
    }

    /// Ask every reachable endpoint to shut down. Members that are down
    /// (and stay unreachable) are skipped — they have nothing to stop;
    /// the first *refusal* from a live endpoint is the returned error,
    /// after every endpoint has been attempted.
    pub fn shutdown(&self) -> Result<()> {
        let mut refused: Option<Error> = None;
        for endpoint in &self.endpoints {
            let mut slot = endpoint.client.lock().unwrap();
            if slot.is_none() {
                match RemoteClient::connect(&endpoint.addr) {
                    Ok(client) => *slot = Some(client),
                    Err(_) => continue, // already down — nothing to stop
                }
            }
            let result = slot.as_ref().expect("slot populated above").shutdown();
            *slot = None; // the endpoint drains and exits either way
            if let Err(e) = result {
                refused.get_or_insert(e);
            }
        }
        match refused {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// An error meaning the endpoint itself is gone (vs a job-level
    /// outcome): transport failures and the typed unavailable kind —
    /// which the connect handshake also uses for protocol mismatches.
    fn endpoint_down(e: &Error) -> bool {
        matches!(e, Error::Io(_)) || matches!(e.as_job(), Some(JobError::Unavailable { .. }))
    }

    /// The preferred starting endpoint for `request`.
    fn route(&self, request: &ReductionRequest) -> usize {
        let count = self.endpoints.len();
        if count <= 1 {
            return 0;
        }
        match self.strategy {
            RouteStrategy::Hash => (fnv_request(request) % count as u64) as usize,
            RouteStrategy::LeastLoaded => {
                let offset = self.rotate.fetch_add(1, Ordering::Relaxed) % count;
                let mut best = offset;
                let mut best_load = self.endpoints[offset].inflight.load(Ordering::Relaxed);
                for step in 1..count {
                    let idx = (offset + step) % count;
                    let load = self.endpoints[idx].inflight.load(Ordering::Relaxed);
                    if load < best_load {
                        best = idx;
                        best_load = load;
                    }
                }
                best
            }
        }
    }

    /// One attempt on one endpoint: reconnect if down, run the whole
    /// request as strict round trips, drop the connection on transport
    /// death so the next attempt reconnects from scratch.
    fn run_on(&self, endpoint: &Endpoint, request: &ReductionRequest) -> Result<ReductionOutcome> {
        endpoint.inflight.fetch_add(1, Ordering::Relaxed);
        let result = (|| {
            let mut slot = endpoint.client.lock().unwrap();
            if slot.is_none() {
                *slot = Some(RemoteClient::connect(&endpoint.addr)?);
            }
            let client = slot.as_ref().expect("slot populated above");
            let outcome = client.submit(request.clone()).and_then(|handle| client.wait(handle));
            if let Err(e) = &outcome {
                if Self::endpoint_down(e) {
                    *slot = None;
                }
            }
            outcome
        })();
        endpoint.inflight.fetch_sub(1, Ordering::Relaxed);
        result
    }

    /// The full policy: route, sweep the fleet failing over downed
    /// members, back off and re-sweep on retryable rejections, give up
    /// only when every endpoint is down or the retry budget is spent.
    fn run_with_failover(&self, request: &ReductionRequest) -> Result<ReductionOutcome> {
        let count = self.endpoints.len();
        let start = self.route(request);
        let mut last: Option<Error> = None;
        for round in 0..=MAX_RETRY_ROUNDS {
            let mut saw_retryable = false;
            for step in 0..count {
                let endpoint = &self.endpoints[(start + step) % count];
                match self.run_on(endpoint, request) {
                    Ok(outcome) => return Ok(outcome),
                    Err(e) if Self::endpoint_down(&e) => last = Some(e),
                    Err(e) if e.is_retryable() => {
                        saw_retryable = true;
                        last = Some(e);
                    }
                    Err(e) => return Err(e), // terminal job/config error
                }
            }
            if !saw_retryable {
                // Every member of this sweep was down, not busy.
                return Err(Error::Job(JobError::Unavailable {
                    reason: format!(
                        "all {count} endpoints are down (last: {})",
                        last.expect("a full sweep recorded at least one error")
                    ),
                }));
            }
            if round < MAX_RETRY_ROUNDS {
                std::thread::sleep(Duration::from_millis(10 * (round as u64 + 1)));
            }
        }
        Err(last.expect("retry rounds recorded the rejection they backed off"))
    }
}

impl Client for ShardedClient {
    fn submit(&self, request: ReductionRequest) -> Result<JobHandle> {
        request.validate()?;
        // Pin the trace id before the failover loop: every attempt clones
        // the request, so a job that fails over (or retries) keeps one
        // span chain instead of minting a fresh id per endpoint.
        let mut request = request;
        request.trace = request.effective_trace();
        let jobs = request.len() as u64;
        if request.params.is_some() {
            self.counters.failed.fetch_add(jobs, Ordering::Relaxed);
            return Err(Error::Config(
                "the serving fleet owns its tuning parameters; start each `banded-svd serve` \
                 with the desired --tw/--tpb/--max-blocks instead of overriding per request"
                    .into(),
            ));
        }
        self.counters.submitted.fetch_add(jobs, Ordering::Relaxed);
        let outcome = self.run_with_failover(&request).map(|mut outcome| {
            outcome.provenance.source = ExecutionSource::Sharded;
            outcome
        });
        match &outcome {
            Ok(_) => self.counters.completed.fetch_add(jobs, Ordering::Relaxed),
            Err(_) => self.counters.failed.fetch_add(jobs, Ordering::Relaxed),
        }
        let id = next_handle_id();
        self.done.lock().unwrap().insert(id, outcome);
        Ok(JobHandle { id })
    }

    fn wait(&self, handle: JobHandle) -> Result<ReductionOutcome> {
        self.done.lock().unwrap().remove(&handle.id).ok_or_else(|| {
            Error::Config(format!("unknown or already-resolved handle {:?}", handle))
        })?
    }

    fn stats(&self) -> ClientStats {
        self.counters.snapshot()
    }
}

/// Stable FNV-1a over the request's problem specs — hashes the *shape*
/// (and seed, for generated problems), not the band payload, so routing
/// a large explicit band costs nothing.
fn fnv_request(request: &ReductionRequest) -> u64 {
    let mut hash = 0xcbf2_9ce4_8422_2325u64;
    let mut eat = |x: u64| {
        for byte in x.to_le_bytes() {
            hash ^= byte as u64;
            hash = hash.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    for problem in &request.problems {
        match problem {
            ProblemSpec::Band(input) => {
                eat(input.n() as u64);
                eat(input.bw() as u64);
                eat(input.element_bytes() as u64);
            }
            ProblemSpec::Random { n, bw, kind, seed } => {
                eat(*n as u64);
                eat(*bw as u64);
                eat(*kind as u64);
                eat(*seed);
            }
        }
    }
    hash
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scalar::ScalarKind;

    fn fleet(count: usize, strategy: RouteStrategy) -> ShardedClient {
        ShardedClient {
            endpoints: (0..count)
                .map(|i| Endpoint {
                    addr: format!("127.0.0.1:{}", 9000 + i),
                    client: Mutex::new(None),
                    inflight: AtomicUsize::new(0),
                })
                .collect(),
            strategy,
            rotate: AtomicUsize::new(0),
            done: Mutex::new(HashMap::new()),
            counters: Counters::default(),
        }
    }

    #[test]
    fn route_strategy_parses_and_defaults_to_hash() {
        assert_eq!(RouteStrategy::default(), RouteStrategy::Hash);
        assert_eq!("hash".parse::<RouteStrategy>().unwrap(), RouteStrategy::Hash);
        assert_eq!("least-loaded".parse::<RouteStrategy>().unwrap(), RouteStrategy::LeastLoaded);
        assert_eq!("load".parse::<RouteStrategy>().unwrap(), RouteStrategy::LeastLoaded);
        assert!("random".parse::<RouteStrategy>().is_err());
        assert_eq!(RouteStrategy::Hash.name(), "hash");
        assert_eq!(RouteStrategy::LeastLoaded.name(), "least-loaded");
    }

    #[test]
    fn hash_routing_is_stable_and_seed_sensitive() {
        let client = fleet(4, RouteStrategy::Hash);
        let request = |seed| ReductionRequest::new().random(64, 8, ScalarKind::F64, seed);
        // Identical specs always route identically...
        assert_eq!(client.route(&request(1)), client.route(&request(1)));
        // ...and distinct seeds spread over more than one endpoint.
        let spread: std::collections::HashSet<usize> =
            (0..32).map(|seed| client.route(&request(seed))).collect();
        assert!(spread.len() > 1, "32 seeds all hashed to one endpoint");
    }

    #[test]
    fn least_loaded_prefers_idle_endpoints_and_rotates_ties() {
        let client = fleet(3, RouteStrategy::LeastLoaded);
        // All idle: the rotation spreads consecutive picks.
        let picks: Vec<usize> =
            (0..3).map(|_| client.route(&ReductionRequest::new())).collect();
        assert_eq!(picks, vec![0, 1, 2]);
        // A busy endpoint is avoided regardless of rotation.
        client.endpoints[1].inflight.store(5, Ordering::Relaxed);
        for _ in 0..6 {
            assert_ne!(client.route(&ReductionRequest::new()), 1);
        }
    }

    #[test]
    fn connecting_an_empty_fleet_is_a_config_error() {
        let err = ShardedClient::connect::<&str>(&[], RouteStrategy::Hash).unwrap_err();
        assert!(err.to_string().contains("at least one endpoint"), "{err}");
    }
}
