//! The JSON-lines wire vocabulary shared by **both sides** of the
//! served protocol: the request/response shaping the TCP server
//! ([`crate::service::server`]) renders and the [`super::RemoteClient`]
//! (plus the example client and the loopback tests) parse.
//!
//! Keeping encode *and* decode in one module is what makes the remote
//! path a drop-in for the local one: field names exist exactly once,
//! floats ride Rust's shortest-roundtrip `f64` formatting (so singular
//! values survive the wire **bitwise** — see [`crate::util::json`]), and
//! a [`JobResult`] rendered by [`result_json`] parses back equal via
//! [`parse_submit_response`] (round-trip–tested below).
//!
//! Vocabulary:
//!
//! - band payloads: [`band_expected_len`], [`band_values`],
//!   [`band_from_values`] — the row-major in-band serialization of a
//!   `submit` request;
//! - requests: [`submit_request`] (typed matrix),
//!   [`submit_request_for_input`] (type-erased [`BatchInput`] with
//!   priority/deadline);
//! - responses: [`result_json`] / [`parse_submit_response`],
//!   [`error_json`] / [`job_error_json`] / [`parse_error`].

use crate::banded::dense::Dense;
use crate::banded::storage::Banded;
use crate::batch::BatchInput;
use crate::coordinator::metrics::LaunchMetrics;
use crate::error::{Error, JobError, Result};
use crate::obs::trace::TraceId;
use crate::scalar::{Scalar, F16};
use crate::service::queue::JobResult;
use crate::util::json::Json;
use std::time::Duration;

/// Version of the JSON-lines protocol this build speaks. Stamped on
/// every request a client renders and on the server's `ping` response.
///
/// Compatibility rule (documented in `docs/client.md`): the server
/// **tolerates requests without a `proto` field** (the PR 5 wire, v1 —
/// hand-rolled clients keep working) and accepts any version in
/// [`PROTO_ACCEPTED`] (v3 only *adds* optional fields — `vectors` on
/// requests, `u`/`vt` on responses — and v4 only adds an opt-in
/// transport encoding — the binary band frame below — so a v2 line is
/// still a valid v4 conversation); anything else present is rejected.
/// Clients handshake by pinging first, record the server's advertised
/// version, and refuse a server whose `ping` response is missing or
/// unsupported with a typed [`JobError::Unavailable`] instead of a
/// parse failure downstream. A vectors request against a v2 server
/// fails client-side the same way: the old server would silently drop
/// the flag, which must never masquerade as a served answer.
///
/// v4 adds the **binary band frame**: a `submit` control line may carry
/// `"band_frame": <count>` *instead of* the `"band"` array, and is then
/// immediately followed on the stream by a raw length-prefixed frame
/// ([`encode_band_frame`]) holding the same values bitwise. The control
/// path (every other field, every response) stays JSON lines; only the
/// bulk payload changes representation, and only when the client opted
/// in ([`super::RemoteClient::binary_band_frames`]).
pub const PROTO_VERSION: u32 = 4;

/// Protocol versions a v4 build accepts from its peer (see the
/// compatibility rule on [`PROTO_VERSION`]).
pub const PROTO_ACCEPTED: [u32; 3] = [2, 3, 4];

/// Cap on the value count of one binary band frame (64 MiB of payload)
/// — the framed analog of the server's line-length budget. Checked
/// *before* allocating anything sized by the client-supplied prefix.
pub const MAX_FRAME_VALUES: u64 = 8 * 1024 * 1024;

/// Number of in-band values of an upper-banded `n × n` matrix with `bw`
/// superdiagonals — the required `band` payload length. Closed form
/// (O(1), `bw` clamped to `n − 1`): full rows contribute `bw + 1`
/// values, the last `bw` rows taper triangularly.
pub fn band_expected_len(n: usize, bw: usize) -> usize {
    if n == 0 {
        return 0;
    }
    let bw = bw.min(n - 1);
    n * (bw + 1) - bw * (bw + 1) / 2
}

/// Serialize the in-band entries of `a` (rows `i`, columns
/// `i ..= min(i+bw, n−1)`, row-major) as f64 — the `band` payload of a
/// `submit` request. Widening to f64 is exact for every supported
/// precision, so the payload round-trips bitwise.
pub fn band_values<T: Scalar>(a: &Banded<T>, bw: usize) -> Vec<f64> {
    let n = a.n();
    let mut out = Vec::with_capacity(band_expected_len(n, bw));
    for i in 0..n {
        for j in i..=(i + bw).min(n - 1) {
            out.push(a.get(i, j).to_f64());
        }
    }
    out
}

/// Rebuild a reduction-ready [`BatchInput`] from a `band` payload — the
/// server side of [`band_values`]. `tw` sizes the fill-in storage (the
/// service uses its configured tuning).
pub fn band_from_values(
    n: usize,
    bw: usize,
    tw: usize,
    precision: &str,
    values: &[f64],
) -> Result<BatchInput> {
    if n < 2 || bw == 0 || bw >= n {
        return Err(Error::Config(format!(
            "bad problem shape: need n ≥ 2 and 1 ≤ bw < n (got n={n}, bw={bw})"
        )));
    }
    // O(1) length check in u128: `n` is client-supplied and must be
    // rejected before anything walks or allocates proportional to it
    // (the closed form would overflow usize for hostile n × bw).
    let expected = {
        let (n, bw) = (n as u128, bw as u128);
        n * (bw + 1) - bw * (bw + 1) / 2
    };
    if values.len() as u128 != expected {
        return Err(Error::Config(format!(
            "band payload has {} values; n={n}, bw={bw} needs {expected}",
            values.len()
        )));
    }
    fn fill<T: Scalar>(n: usize, bw: usize, tw: usize, values: &[f64]) -> Banded<T> {
        let mut a = Banded::<T>::for_reduction(n, bw, tw);
        let mut k = 0;
        for i in 0..n {
            for j in i..=(i + bw).min(n - 1) {
                a.set(i, j, T::from_f64(values[k]));
                k += 1;
            }
        }
        a
    }
    Ok(match precision {
        "fp64" => BatchInput::from((fill::<f64>(n, bw, tw, values), bw)),
        "fp32" => BatchInput::from((fill::<f32>(n, bw, tw, values), bw)),
        "fp16" => BatchInput::from((fill::<F16>(n, bw, tw, values), bw)),
        other => {
            return Err(Error::Config(format!("unknown precision {other:?} (fp16|fp32|fp64)")))
        }
    })
}

/// Encode a band payload as the v4 binary frame: a little-endian `u64`
/// value count followed by the values as little-endian `f64` bit
/// patterns. Bit patterns, not formatted text — the payload is bitwise
/// by construction, and ~2.5× smaller than its JSON rendering.
pub fn encode_band_frame(values: &[f64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + values.len() * 8);
    out.extend_from_slice(&(values.len() as u64).to_le_bytes());
    for &v in values {
        out.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    out
}

/// Read one binary band frame — the receiving side of
/// [`encode_band_frame`]. Reads exactly `8 + 8·count` bytes, so a
/// well-formed frame leaves the stream aligned on the next JSON line
/// even when the surrounding control line turns out to be invalid. A
/// count beyond [`MAX_FRAME_VALUES`] is rejected before any
/// proportional allocation or read.
pub fn read_band_frame(r: &mut impl std::io::Read) -> Result<Vec<f64>> {
    let mut word = [0u8; 8];
    r.read_exact(&mut word).map_err(Error::Io)?;
    let count = u64::from_le_bytes(word);
    if count > MAX_FRAME_VALUES {
        return Err(Error::Config(format!(
            "band frame declares {count} values; cap is {MAX_FRAME_VALUES}"
        )));
    }
    let mut values = Vec::with_capacity(count as usize);
    for _ in 0..count {
        r.read_exact(&mut word).map_err(Error::Io)?;
        values.push(f64::from_bits(u64::from_le_bytes(word)));
    }
    Ok(values)
}

#[allow(clippy::too_many_arguments)]
fn submit_head(
    n: usize,
    bw: usize,
    precision: &str,
    priority: u8,
    deadline: Option<Duration>,
    identity: RequestIdentity<'_>,
    vectors: bool,
    trace: Option<TraceId>,
) -> Json {
    let mut request = Json::obj()
        .set("verb", "submit")
        .set("proto", PROTO_VERSION as usize)
        .set("n", n)
        .set("bw", bw)
        .set("precision", precision)
        .set("priority", priority as usize);
    if let Some(deadline) = deadline {
        request = request.set("deadline_ms", Json::Int(deadline.as_millis() as i64));
    }
    if vectors {
        // Absent means false: values-only lines stay byte-compatible
        // with what a v2 client renders.
        request = request.set("vectors", true);
    }
    if let Some(client_id) = identity.client_id {
        request = request.set("client_id", client_id);
    }
    if let Some(quota_class) = identity.quota_class {
        request = request.set("quota_class", quota_class);
    }
    if let Some(trace) = trace {
        // Client-minted trace id (16 hex chars) so both sides record the
        // job's span chain under one id. Absent when tracing is off —
        // the line stays byte-compatible with an untraced client's.
        request = request.set("trace", trace.to_hex());
    }
    request
}

#[allow(clippy::too_many_arguments)]
fn submit_json(
    n: usize,
    bw: usize,
    precision: &str,
    priority: u8,
    deadline: Option<Duration>,
    identity: RequestIdentity<'_>,
    vectors: bool,
    trace: Option<TraceId>,
    band: Vec<f64>,
) -> String {
    let band: Vec<Json> = band.into_iter().map(Json::Num).collect();
    submit_head(n, bw, precision, priority, deadline, identity, vectors, trace)
        .set("band", Json::Arr(band))
        .render()
}

/// Who a `submit` line is from — the request-owned identity fields
/// ([`super::ReductionRequest::client_id`] /
/// [`super::ReductionRequest::quota_class`]) as they ride the wire.
#[derive(Clone, Copy, Debug, Default)]
pub struct RequestIdentity<'a> {
    pub client_id: Option<&'a str>,
    pub quota_class: Option<&'a str>,
}

/// Render a complete anonymous `submit` request line for `a`. The
/// precision label comes from `T`.
pub fn submit_request<T: Scalar>(a: &Banded<T>, bw: usize, priority: u8) -> String {
    submit_json(
        a.n(),
        bw,
        T::NAME,
        priority,
        None,
        RequestIdentity::default(),
        false,
        None,
        band_values(a, bw),
    )
}

/// Render a `submit` request line for a type-erased problem — what the
/// [`super::RemoteClient`] sends for each problem of a request, carrying
/// the request's priority class, optional deadline, identity, whether
/// the job should accumulate singular-vector panels, and (when tracing)
/// the client-minted [`TraceId`] the server records spans under.
#[allow(clippy::too_many_arguments)]
pub fn submit_request_for_input(
    input: &BatchInput,
    priority: u8,
    deadline: Option<Duration>,
    identity: RequestIdentity<'_>,
    vectors: bool,
    trace: Option<TraceId>,
) -> String {
    let band = match input {
        BatchInput::F64 { a, bw } => band_values(a, *bw),
        BatchInput::F32 { a, bw } => band_values(a, *bw),
        BatchInput::F16 { a, bw } => band_values(a, *bw),
    };
    submit_json(
        input.n(),
        input.bw(),
        input.precision(),
        priority,
        deadline,
        identity,
        vectors,
        trace,
        band,
    )
}

/// Render a `submit` as the v4 framed transport: the JSON control line
/// (carrying `band_frame` — the declared value count — instead of the
/// `band` array) plus the binary frame to write immediately after it.
/// The server cross-checks the declared count against the frame's own
/// prefix, so a desynchronized client is a protocol error, never a
/// silently misread payload.
#[allow(clippy::too_many_arguments)]
pub fn submit_request_framed(
    input: &BatchInput,
    priority: u8,
    deadline: Option<Duration>,
    identity: RequestIdentity<'_>,
    vectors: bool,
    trace: Option<TraceId>,
) -> (String, Vec<u8>) {
    let band = match input {
        BatchInput::F64 { a, bw } => band_values(a, *bw),
        BatchInput::F32 { a, bw } => band_values(a, *bw),
        BatchInput::F16 { a, bw } => band_values(a, *bw),
    };
    let line = submit_head(
        input.n(),
        input.bw(),
        input.precision(),
        priority,
        deadline,
        identity,
        vectors,
        trace,
    )
    .set("band_frame", band.len())
    .render();
    (line, encode_band_frame(&band))
}

fn metrics_json(m: &LaunchMetrics) -> Json {
    Json::obj()
        .set("launches", m.launches)
        .set("tasks", m.tasks)
        .set("max_parallel", m.max_parallel)
        .set("unrolled_launches", m.unrolled_launches)
        .set("bytes", Json::Int(m.bytes as i64))
}

/// Flat row-major serialization of a dense n×n panel — the `u`/`vt`
/// payload of a vectors response. Shortest-roundtrip formatting keeps
/// the entries bitwise.
fn panel_json(p: &Dense<f64>) -> Json {
    Json::Arr(p.data.iter().map(|&x| Json::Num(x)).collect())
}

/// Render a completed job as the `submit` response object — the server
/// side of [`parse_submit_response`]. Vector panels ride as optional
/// flat row-major `n²` arrays (`u`, `vt`), present exactly when the job
/// requested them (proto ≥ 3).
pub fn result_json(r: &JobResult) -> Json {
    let mut response = Json::obj()
        .set("ok", true)
        .set("verb", "submit")
        .set("id", Json::Int(r.id as i64))
        .set("n", r.n)
        .set("bw", r.bw)
        .set("precision", r.precision)
        .set("batch_jobs", r.batch_jobs)
        .set("queue_us", Json::Int(r.queue_wait.as_micros() as i64))
        .set("metrics", metrics_json(&r.metrics))
        .set("sv", Json::Arr(r.sv.iter().map(|&x| Json::Num(x)).collect()));
    if let Some(u) = &r.u {
        response = response.set("u", panel_json(u));
    }
    if let Some(vt) = &r.vt {
        response = response.set("vt", panel_json(vt));
    }
    response
}

/// Generic protocol-level error response (malformed request, unknown
/// verb). Job-level failures use [`job_error_json`] so the taxonomy
/// rides the wire.
pub fn error_json(msg: impl Into<String>) -> Json {
    Json::obj().set("ok", false).set("error", Json::s(msg))
}

/// Error response for a failed job: carries the taxonomy `kind` and the
/// `retryable` flag alongside the message (plus the structured
/// `queued_ms` for deadline expiries), so a remote client surfaces
/// exactly the [`JobError`] a local caller would see.
pub fn job_error_json(e: &JobError) -> Json {
    let mut response = Json::obj()
        .set("ok", false)
        .set("error", e.to_string())
        .set("kind", e.kind())
        .set("retryable", e.is_retryable());
    if let JobError::DeadlineExpired { queued_ms } = e {
        response = response.set("queued_ms", Json::Int(*queued_ms as i64));
    }
    response
}

/// Decode a `{"ok":false,...}` response into the error taxonomy:
/// responses stamped with a job `kind` rebuild the [`JobError`]; plain
/// protocol errors (malformed request, bad shape) are terminal
/// [`Error::Config`]s.
pub fn parse_error(response: &Json) -> Error {
    let message = response.get("error").and_then(Json::as_str).unwrap_or("unknown error");
    match response.get("kind").and_then(Json::as_str) {
        Some(kind) => {
            let queued_ms =
                response.get("queued_ms").and_then(Json::as_i64).map(|ms| ms.max(0) as u64);
            Error::Job(JobError::from_kind(kind, message, queued_ms))
        }
        None => Error::Config(format!("server rejected the request: {message}")),
    }
}

fn field_usize(obj: &Json, key: &str) -> Result<usize> {
    obj.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| Error::Config(format!("submit response missing integer {key:?}")))
}

/// Decode an optional flat row-major `n²` panel field (`u`/`vt`) — the
/// client side of the vectors extension. Present-but-malformed is an
/// error, never a silently absent panel.
fn parse_panel(response: &Json, key: &str, n: usize) -> Result<Option<Dense<f64>>> {
    let Some(field) = response.get(key) else {
        return Ok(None);
    };
    let arr = field
        .as_array()
        .ok_or_else(|| Error::Config(format!("submit response {key:?} must be an array")))?;
    if arr.len() != n * n {
        return Err(Error::Config(format!(
            "submit response {key:?} has {} values; n={n} needs {}",
            arr.len(),
            n * n
        )));
    }
    let data: Vec<f64> = arr
        .iter()
        .map(|v| {
            v.as_f64().ok_or_else(|| Error::Config(format!("non-numeric {key:?} panel entry")))
        })
        .collect::<Result<_>>()?;
    Ok(Some(Dense::from_vec(n, n, data)))
}

/// Parse a `submit` response line into the same [`JobResult`] the
/// in-process service delivers. `{"ok":false}` responses decode through
/// [`parse_error`]. The wire carries the launch-accounting summary, not
/// the per-launch trace, so `metrics.per_launch` comes back empty and
/// `metrics.wall` zero; everything else — including the singular values
/// and any `u`/`vt` panels, bitwise — round-trips exactly.
pub fn parse_submit_response(response: &Json) -> Result<JobResult> {
    if response.get("ok").and_then(Json::as_bool) != Some(true) {
        return Err(parse_error(response));
    }
    let n = field_usize(response, "n")?;
    let precision = match response.get("precision").and_then(Json::as_str) {
        Some("fp64") => <f64 as Scalar>::NAME,
        Some("fp32") => <f32 as Scalar>::NAME,
        Some("fp16") => F16::NAME,
        other => {
            return Err(Error::Config(format!("submit response has bad precision {other:?}")))
        }
    };
    let sv: Vec<f64> = response
        .get("sv")
        .and_then(Json::as_array)
        .ok_or_else(|| Error::Config("submit response missing \"sv\" array".into()))?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| Error::Config("non-numeric singular value".into())))
        .collect::<Result<_>>()?;
    if sv.len() != n {
        return Err(Error::Config(format!("{} singular values for n={n}", sv.len())));
    }
    let m = response
        .get("metrics")
        .ok_or_else(|| Error::Config("submit response missing \"metrics\"".into()))?;
    let metrics = LaunchMetrics {
        launches: field_usize(m, "launches")?,
        tasks: field_usize(m, "tasks")?,
        max_parallel: field_usize(m, "max_parallel")?,
        unrolled_launches: field_usize(m, "unrolled_launches")?,
        bytes: m
            .get("bytes")
            .and_then(Json::as_i64)
            .ok_or_else(|| Error::Config("submit response missing integer \"bytes\"".into()))?
            as u64,
        per_launch: Vec::new(),
        wall: Duration::ZERO,
    };
    Ok(JobResult {
        id: response.get("id").and_then(Json::as_i64).unwrap_or(0) as u64,
        n,
        bw: field_usize(response, "bw")?,
        precision,
        sv,
        u: parse_panel(response, "u", n)?,
        vt: parse_panel(response, "vt", n)?,
        metrics,
        batch_jobs: field_usize(response, "batch_jobs")?,
        queue_wait: Duration::from_micros(
            response.get("queue_us").and_then(Json::as_i64).unwrap_or(0).max(0) as u64,
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generate::random_banded;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn band_payload_roundtrips_bitwise() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let (n, bw, tw) = (40, 5, 4);
        let a = random_banded::<f64>(n, bw, tw, &mut rng);
        let values = band_values(&a, bw);
        assert_eq!(values.len(), band_expected_len(n, bw));
        let back = band_from_values(n, bw, tw, "fp64", &values).unwrap();
        match back {
            BatchInput::F64 { a: b, bw: bw2 } => {
                assert_eq!(bw2, bw);
                assert_eq!(b, a);
            }
            _ => panic!("wrong precision"),
        }
    }

    #[test]
    fn band_payload_validates_shape_and_length() {
        assert!(band_from_values(1, 1, 1, "fp64", &[]).is_err()); // n too small
        assert!(band_from_values(8, 0, 1, "fp64", &[]).is_err()); // bw too small
        assert!(band_from_values(8, 8, 1, "fp64", &[]).is_err()); // bw ≥ n
        assert!(band_from_values(8, 2, 1, "fp64", &[0.0; 3]).is_err()); // short
        assert!(band_from_values(8, 2, 1, "nope", &[0.0; 21]).is_err());
        assert_eq!(band_expected_len(8, 2), 21);
        assert!(band_from_values(8, 2, 1, "fp32", &[0.0; 21]).is_ok());
    }

    #[test]
    fn oversized_shape_is_rejected_in_constant_time() {
        // A hostile n must be rejected by arithmetic, not by iterating
        // (or allocating) anything proportional to it.
        let t0 = std::time::Instant::now();
        let err = band_from_values(usize::MAX / 2, 3, 1, "fp64", &[1.0]).unwrap_err();
        assert!(t0.elapsed() < Duration::from_secs(1), "shape check not O(1)");
        assert!(err.to_string().contains("values"), "{err}");
    }

    #[test]
    fn band_frames_roundtrip_bitwise() {
        let values = vec![1.5, -0.0, 1e-300, f64::MAX, 2.0f64.sqrt()];
        let frame = encode_band_frame(&values);
        assert_eq!(frame.len(), 8 + values.len() * 8);
        let back = read_band_frame(&mut frame.as_slice()).unwrap();
        assert_eq!(back.len(), values.len());
        for (got, want) in back.iter().zip(values.iter()) {
            assert_eq!(got.to_bits(), want.to_bits());
        }
        // An empty frame is valid: just the zero length prefix.
        let empty = encode_band_frame(&[]);
        assert_eq!(empty.len(), 8);
        assert!(read_band_frame(&mut empty.as_slice()).unwrap().is_empty());
    }

    #[test]
    fn band_frames_reject_oversized_and_truncated_streams() {
        // A hostile count is rejected by arithmetic before any
        // allocation or read proportional to it.
        let oversized = u64::MAX.to_le_bytes().to_vec();
        let err = read_band_frame(&mut oversized.as_slice()).unwrap_err();
        assert!(err.to_string().contains("cap"), "{err}");
        // A truncated payload is an I/O error, never a short result.
        let mut frame = encode_band_frame(&[1.0, 2.0]);
        frame.truncate(frame.len() - 3);
        assert!(read_band_frame(&mut frame.as_slice()).is_err());
    }

    #[test]
    fn framed_request_carries_the_count_and_the_payload_bitwise() {
        let mut rng = Xoshiro256::seed_from_u64(9);
        let a = random_banded::<f64>(20, 3, 2, &mut rng);
        let values = band_values(&a, 3);
        let input = BatchInput::from((a, 3));
        let (line, frame) =
            submit_request_framed(&input, 2, None, RequestIdentity::default(), false, None);
        let parsed = Json::parse(&line).unwrap();
        assert_eq!(parsed.get("band_frame").and_then(Json::as_usize), Some(values.len()));
        assert!(parsed.get("band").is_none(), "framed line must not carry the inline array");
        assert_eq!(parsed.get("priority").and_then(Json::as_usize), Some(2));
        let back = read_band_frame(&mut frame.as_slice()).unwrap();
        assert_eq!(back.len(), values.len());
        for (got, want) in back.iter().zip(values.iter()) {
            assert_eq!(got.to_bits(), want.to_bits());
        }
    }

    #[test]
    fn typed_and_erased_request_lines_agree() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let a = random_banded::<f32>(24, 3, 2, &mut rng);
        let typed = submit_request(&a, 3, 2);
        let erased = submit_request_for_input(
            &BatchInput::from((a, 3)),
            2,
            None,
            RequestIdentity::default(),
            false,
            None,
        );
        assert_eq!(typed, erased);
    }

    #[test]
    fn deadline_rides_the_request_line() {
        let mut rng = Xoshiro256::seed_from_u64(4);
        let a = random_banded::<f64>(16, 2, 1, &mut rng);
        let input = BatchInput::from((a, 2));
        let line = submit_request_for_input(
            &input,
            1,
            Some(Duration::from_millis(250)),
            RequestIdentity::default(),
            false,
            None,
        );
        let parsed = Json::parse(&line).unwrap();
        assert_eq!(parsed.get("deadline_ms").and_then(Json::as_i64), Some(250));
        assert_eq!(parsed.get("priority").and_then(Json::as_usize), Some(1));
        let bare =
            submit_request_for_input(&input, 0, None, RequestIdentity::default(), false, None);
        assert!(Json::parse(&bare).unwrap().get("deadline_ms").is_none());
    }

    #[test]
    fn proto_and_identity_ride_the_request_line() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let a = random_banded::<f64>(16, 2, 1, &mut rng);
        let input = BatchInput::from((a, 2));
        let identity =
            RequestIdentity { client_id: Some("tenant-a"), quota_class: Some("batch") };
        let line = submit_request_for_input(&input, 0, None, identity, false, None);
        let parsed = Json::parse(&line).unwrap();
        assert_eq!(
            parsed.get("proto").and_then(Json::as_usize),
            Some(PROTO_VERSION as usize)
        );
        assert_eq!(parsed.get("client_id").and_then(Json::as_str), Some("tenant-a"));
        assert_eq!(parsed.get("quota_class").and_then(Json::as_str), Some("batch"));
        // Anonymous lines omit the identity fields but still carry proto.
        let bare =
            submit_request_for_input(&input, 0, None, RequestIdentity::default(), false, None);
        let parsed = Json::parse(&bare).unwrap();
        assert!(parsed.get("client_id").is_none());
        assert!(parsed.get("quota_class").is_none());
        assert!(parsed.get("proto").is_some());
    }

    #[test]
    fn trace_id_rides_the_request_line_only_when_set() {
        let mut rng = Xoshiro256::seed_from_u64(8);
        let a = random_banded::<f64>(16, 2, 1, &mut rng);
        let input = BatchInput::from((a, 2));
        let id = TraceId(0xdead_beef_0012_3456);
        let line = submit_request_for_input(
            &input,
            0,
            None,
            RequestIdentity::default(),
            false,
            Some(id),
        );
        let parsed = Json::parse(&line).unwrap();
        let on_wire = parsed.get("trace").and_then(Json::as_str).unwrap();
        assert_eq!(on_wire, "deadbeef00123456");
        assert_eq!(TraceId::parse_hex(on_wire), Some(id), "wire form parses back");
        // An untraced line omits the field entirely — byte-compatible
        // with what every earlier client rendered.
        let bare =
            submit_request_for_input(&input, 0, None, RequestIdentity::default(), false, None);
        assert!(Json::parse(&bare).unwrap().get("trace").is_none());
    }

    #[test]
    fn submit_response_roundtrips_through_the_wire_shapes() {
        let result = JobResult {
            id: 9,
            n: 5,
            bw: 2,
            precision: "fp32",
            sv: vec![3.5, 1.25, 0.5, 0.25, -0.0],
            metrics: LaunchMetrics {
                launches: 7,
                tasks: 21,
                max_parallel: 4,
                unrolled_launches: 1,
                bytes: 12345,
                per_launch: Vec::new(),
                wall: Duration::ZERO,
            },
            batch_jobs: 3,
            queue_wait: Duration::from_micros(417),
            u: None,
            vt: None,
        };
        let line = result_json(&result).render();
        let back = parse_submit_response(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back.id, result.id);
        assert_eq!(back.n, result.n);
        assert_eq!(back.bw, result.bw);
        assert_eq!(back.precision, result.precision);
        assert_eq!(back.batch_jobs, result.batch_jobs);
        assert_eq!(back.queue_wait, result.queue_wait);
        assert_eq!(back.metrics.launches, result.metrics.launches);
        assert_eq!(back.metrics.tasks, result.metrics.tasks);
        assert_eq!(back.metrics.max_parallel, result.metrics.max_parallel);
        assert_eq!(back.metrics.unrolled_launches, result.metrics.unrolled_launches);
        assert_eq!(back.metrics.bytes, result.metrics.bytes);
        for (got, want) in back.sv.iter().zip(result.sv.iter()) {
            assert_eq!(got.to_bits(), want.to_bits());
        }
        assert!(back.u.is_none() && back.vt.is_none(), "values-only response has no panels");
    }

    #[test]
    fn vectors_flag_rides_the_request_line_only_when_set() {
        let mut rng = Xoshiro256::seed_from_u64(6);
        let a = random_banded::<f64>(16, 2, 1, &mut rng);
        let input = BatchInput::from((a, 2));
        let with =
            submit_request_for_input(&input, 0, None, RequestIdentity::default(), true, None);
        let parsed = Json::parse(&with).unwrap();
        assert_eq!(parsed.get("vectors").and_then(Json::as_bool), Some(true));
        // A values-only line omits the field entirely — byte-compatible
        // with the v2 rendering a legacy server expects.
        let without =
            submit_request_for_input(&input, 0, None, RequestIdentity::default(), false, None);
        assert!(Json::parse(&without).unwrap().get("vectors").is_none());
    }

    #[test]
    fn vector_panels_roundtrip_bitwise_and_validate_length() {
        let n = 3;
        let u = Dense::from_vec(n, n, vec![1.0, 0.25, -0.5, 0.125, 1e-300, -0.0, 2.5, 3.0, 4.0]);
        let vt = Dense::from_vec(n, n, (0..9).map(|k| (k as f64).sqrt()).collect());
        let result = JobResult {
            id: 1,
            n,
            bw: 1,
            precision: "fp64",
            sv: vec![3.0, 2.0, 1.0],
            u: Some(u.clone()),
            vt: Some(vt.clone()),
            metrics: LaunchMetrics {
                launches: 1,
                tasks: 2,
                max_parallel: 1,
                unrolled_launches: 0,
                bytes: 64,
                per_launch: Vec::new(),
                wall: Duration::ZERO,
            },
            batch_jobs: 1,
            queue_wait: Duration::ZERO,
        };
        let line = result_json(&result).render();
        let back = parse_submit_response(&Json::parse(&line).unwrap()).unwrap();
        let (bu, bvt) = (back.u.unwrap(), back.vt.unwrap());
        assert_eq!((bu.rows, bu.cols), (n, n));
        for (got, want) in bu.data.iter().zip(u.data.iter()) {
            assert_eq!(got.to_bits(), want.to_bits());
        }
        for (got, want) in bvt.data.iter().zip(vt.data.iter()) {
            assert_eq!(got.to_bits(), want.to_bits());
        }
        // A panel of the wrong length is a protocol error, not a panel.
        let mut tampered = result_json(&result);
        tampered = tampered.set("u", Json::Arr(vec![Json::Num(1.0); 4]));
        assert!(parse_submit_response(&tampered).is_err());
        // Wrong type too.
        let tampered = result_json(&result).set("vt", Json::s("nope"));
        assert!(parse_submit_response(&tampered).is_err());
    }

    #[test]
    fn error_responses_decode_into_the_taxonomy() {
        let overloaded = JobError::Overloaded { reason: "queue full: 8 jobs (cap 8)".into() };
        let decoded = parse_error(&job_error_json(&overloaded));
        assert!(decoded.is_retryable());
        assert_eq!(decoded.as_job().unwrap().kind(), "overloaded");

        // Deadline expiries carry their queue time as a structured field
        // and rebuild it exactly — the remote display never fabricates 0.
        let expired = JobError::DeadlineExpired { queued_ms: 150 };
        let decoded = parse_error(&job_error_json(&expired));
        assert_eq!(decoded.as_job(), Some(&expired));

        let terminal = parse_error(&job_error_json(&JobError::Execution {
            reason: "backend pjrt failed".into(),
        }));
        assert!(!terminal.is_retryable());
        assert_eq!(terminal.as_job().unwrap().kind(), "execution");

        // Plain protocol errors (no kind) are config errors, not jobs.
        let config = parse_error(&error_json("submit needs a \"band\" array"));
        assert!(config.as_job().is_none());
        assert!(config.to_string().contains("band"));
    }

    #[test]
    fn malformed_submit_responses_are_rejected() {
        for bad in [
            "{\"ok\":true}",
            "{\"ok\":true,\"n\":4,\"bw\":2,\"precision\":\"fp64\",\"batch_jobs\":1,\
             \"metrics\":{},\"sv\":[1.0]}",
            "{\"ok\":true,\"n\":2,\"bw\":1,\"precision\":\"fp7\",\"batch_jobs\":1,\"sv\":[1,2]}",
        ] {
            let parsed = Json::parse(bad).unwrap();
            assert!(parse_submit_response(&parsed).is_err(), "{bad}");
        }
    }
}
