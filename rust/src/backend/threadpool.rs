//! The thread-pooled executor — the CPU analog of the paper's GPU
//! execution model (one pinned dispatch + one barrier per launch).

use crate::backend::{check_problems, Backend, BandStorageMut, Execution};
use crate::batch::engine::{execute_plan, Runner};
use crate::config::BackendKind;
use crate::error::Result;
use crate::plan::{LaunchPlan, ReflectorLog};
use crate::simd::SimdSpec;
use crate::util::threadpool::ThreadPool;

enum PoolRef<'p> {
    Owned(ThreadPool),
    Borrowed(&'p ThreadPool),
}

/// Executes a [`LaunchPlan`] over a worker [`ThreadPool`]: every launch
/// is one pinned pool dispatch plus one barrier, tasks are routed to
/// slots by sticky column-window affinity, and each slot keeps a
/// persistent packed-tile workspace across launches (see
/// `crate::batch::engine` for the launch loop itself).
///
/// The pool is usually owned ([`ThreadpoolBackend::new`]); callers that
/// already hold a pool — e.g. the parallel SVD pipeline — can borrow it
/// ([`ThreadpoolBackend::borrowing`]) without spawning new threads.
pub struct ThreadpoolBackend<'p> {
    pool: PoolRef<'p>,
}

impl ThreadpoolBackend<'static> {
    /// Backend with its own pool; `threads == 0` uses all available
    /// hardware threads.
    pub fn new(threads: usize) -> Self {
        Self { pool: PoolRef::Owned(ThreadPool::new(threads)) }
    }
}

impl<'p> ThreadpoolBackend<'p> {
    /// Backend over an existing pool (no threads spawned).
    pub fn borrowing(pool: &'p ThreadPool) -> Self {
        Self { pool: PoolRef::Borrowed(pool) }
    }

    /// The pool launches dispatch over.
    pub fn pool(&self) -> &ThreadPool {
        match &self.pool {
            PoolRef::Owned(p) => p,
            PoolRef::Borrowed(p) => p,
        }
    }

    fn run(
        &self,
        plan: &LaunchPlan,
        problems: &mut [BandStorageMut<'_>],
        mut log: Option<&mut ReflectorLog>,
    ) -> Result<Execution> {
        check_problems(plan, problems)?;
        let mut runners: Vec<Runner<'_>> = problems
            .iter_mut()
            .zip(plan.problems.iter())
            .enumerate()
            .map(|(p, (band, shape))| {
                let view = log.as_deref_mut().map(|l| l.view(p));
                Runner::for_band_logged(band, shape, SimdSpec::scalar(), view)
            })
            .collect::<Result<_>>()?;
        let aggregate = execute_plan(plan, &mut runners, self.pool());
        Ok(Execution {
            per_problem: runners.iter().map(|r| r.metrics.clone()).collect(),
            aggregate,
        })
    }
}

impl Backend for ThreadpoolBackend<'_> {
    fn kind(&self) -> BackendKind {
        BackendKind::Threadpool
    }

    fn execute(
        &self,
        plan: &LaunchPlan,
        problems: &mut [BandStorageMut<'_>],
    ) -> Result<Execution> {
        self.run(plan, problems, None)
    }

    fn execute_logged(
        &self,
        plan: &LaunchPlan,
        problems: &mut [BandStorageMut<'_>],
        log: &mut ReflectorLog,
    ) -> Result<Execution> {
        log.check_plan(plan)?;
        self.run(plan, problems, Some(log))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{AsBandStorageMut, SequentialBackend};
    use crate::config::{PackingPolicy, TuneParams};
    use crate::generate::random_banded;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn borrowed_pool_matches_owned_pool_bitwise() {
        let params = TuneParams { tpb: 32, tw: 4, max_blocks: 8 };
        let (n, bw) = (64, 8);
        let mut rng = Xoshiro256::seed_from_u64(5);
        let base = random_banded::<f64>(n, bw, params.effective_tw(bw), &mut rng);
        let plan = LaunchPlan::for_problem(n, bw, &params);

        let mut owned = base.clone();
        ThreadpoolBackend::new(3)
            .execute(&plan, &mut [owned.as_band_storage_mut()])
            .unwrap();

        let pool = ThreadPool::new(3);
        let mut borrowed = base.clone();
        ThreadpoolBackend::borrowing(&pool)
            .execute(&plan, &mut [borrowed.as_band_storage_mut()])
            .unwrap();

        assert_eq!(owned, borrowed);
    }

    #[test]
    fn merged_plan_results_match_sequential_backend() {
        let params = TuneParams { tpb: 32, tw: 3, max_blocks: 12 };
        let mut rng = Xoshiro256::seed_from_u64(23);
        let shapes = [(48usize, 6usize), (36, 4), (28, 3)];
        let mats: Vec<_> = shapes
            .iter()
            .map(|&(n, bw)| random_banded::<f64>(n, bw, params.effective_tw(bw), &mut rng))
            .collect();
        let parts: Vec<LaunchPlan> = shapes
            .iter()
            .map(|&(n, bw)| LaunchPlan::for_problem(n, bw, &params))
            .collect();
        let merged = LaunchPlan::merge(&parts, 12, PackingPolicy::GreedyFill, 8);

        let mut seq_mats = mats.clone();
        {
            let mut bands: Vec<BandStorageMut<'_>> =
                seq_mats.iter_mut().map(|a| a.as_band_storage_mut()).collect();
            SequentialBackend::new().execute(&merged, &mut bands).unwrap();
        }
        let mut tp_mats = mats.clone();
        {
            let mut bands: Vec<BandStorageMut<'_>> =
                tp_mats.iter_mut().map(|a| a.as_band_storage_mut()).collect();
            ThreadpoolBackend::new(4).execute(&merged, &mut bands).unwrap();
        }
        assert_eq!(seq_mats, tp_mats);
    }
}
