//! The SIMD executor — the threadpool pinned-dispatch loop with packed
//! tasks routed through the explicit vector kernels of [`crate::simd`].
//!
//! Everything about scheduling is shared with [`ThreadpoolBackend`]:
//! same launch loop, same sticky column-window affinity, same persistent
//! per-slot workspaces. The only difference is the [`SimdSpec`] threaded
//! into each problem's runner, which swaps the packed-tile hot loops
//! (reflector generate/apply over the contiguous 64-byte-aligned
//! workspace) for fixed-width lane kernels. Below-gate (in-place) stages
//! stay scalar on every backend.
//!
//! With contraction off (the default) results are **bitwise-identical**
//! to [`SequentialBackend`](crate::backend::SequentialBackend) — the
//! same equivalence property every native backend carries. The resolved
//! ISA is an executor detail, not part of the backend's identity: the
//! backend is always named `"simd"` (stable across hosts, which is what
//! the client handshake records), and [`SimdBackend::spec`] /
//! [`SimdBackend::isa_name`] surface what actually runs.

use crate::backend::{check_problems, Backend, BandStorageMut, Execution, ThreadpoolBackend};
use crate::batch::engine::{execute_plan, Runner};
use crate::config::BackendKind;
use crate::error::Result;
use crate::plan::{LaunchPlan, ReflectorLog};
use crate::simd::SimdSpec;
use crate::simulator::model::BackendCostModel;
use crate::util::threadpool::ThreadPool;

/// Executes a [`LaunchPlan`] like [`ThreadpoolBackend`], but chases
/// packed-path tasks with the SIMD lane kernels selected by its
/// [`SimdSpec`] (resolved once from `BSVD_SIMD` / `BSVD_SIMD_CONTRACT`
/// by [`SimdBackend::new`], or injected via [`SimdBackend::with_spec`]).
pub struct SimdBackend<'p> {
    inner: ThreadpoolBackend<'p>,
    spec: SimdSpec,
}

impl SimdBackend<'static> {
    /// Backend with its own pool and the process-wide spec from the
    /// environment; `threads == 0` uses all available hardware threads.
    pub fn new(threads: usize) -> Self {
        Self::with_spec(SimdSpec::from_env(), threads)
    }

    /// Backend with an explicit kernel spec — the injectable form tests
    /// use to pin an ISA / contraction mode without touching the
    /// process environment.
    pub fn with_spec(spec: SimdSpec, threads: usize) -> Self {
        Self { inner: ThreadpoolBackend::new(threads), spec }
    }
}

impl<'p> SimdBackend<'p> {
    /// Backend over an existing pool (no threads spawned), environment
    /// spec — what the coordinator uses for its resident pool.
    pub fn borrowing(pool: &'p ThreadPool) -> Self {
        Self { inner: ThreadpoolBackend::borrowing(pool), spec: SimdSpec::from_env() }
    }

    /// The kernel spec every packed task runs under.
    pub fn spec(&self) -> SimdSpec {
        self.spec
    }

    /// Resolved ISA label for provenance output, e.g. `"avx2+fma"` or
    /// `"scalar"` (after `BSVD_SIMD=off` or failed detection).
    pub fn isa_name(&self) -> &'static str {
        self.spec.isa.name()
    }

    fn run(
        &self,
        plan: &LaunchPlan,
        problems: &mut [BandStorageMut<'_>],
        mut log: Option<&mut ReflectorLog>,
    ) -> Result<Execution> {
        check_problems(plan, problems)?;
        let mut runners: Vec<Runner<'_>> = problems
            .iter_mut()
            .zip(plan.problems.iter())
            .enumerate()
            .map(|(p, (band, shape))| {
                let view = log.as_deref_mut().map(|l| l.view(p));
                Runner::for_band_logged(band, shape, self.spec, view)
            })
            .collect::<Result<_>>()?;
        let aggregate = execute_plan(plan, &mut runners, self.inner.pool());
        Ok(Execution {
            per_problem: runners.iter().map(|r| r.metrics.clone()).collect(),
            aggregate,
        })
    }
}

impl Backend for SimdBackend<'_> {
    fn kind(&self) -> BackendKind {
        BackendKind::Simd
    }

    fn execute(
        &self,
        plan: &LaunchPlan,
        problems: &mut [BandStorageMut<'_>],
    ) -> Result<Execution> {
        self.run(plan, problems, None)
    }

    fn execute_logged(
        &self,
        plan: &LaunchPlan,
        problems: &mut [BandStorageMut<'_>],
        log: &mut ReflectorLog,
    ) -> Result<Execution> {
        log.check_plan(plan)?;
        self.run(plan, problems, Some(log))
    }

    fn cost_model(&self) -> BackendCostModel {
        BackendCostModel::simd()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{AsBandStorageMut, SequentialBackend};
    use crate::config::TuneParams;
    use crate::generate::random_banded;
    use crate::simd::SimdIsa;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn simd_backend_matches_sequential_bitwise_above_the_gate() {
        // tw = 32 against bw = 40 keeps every stage span b + d ≥ 48: the
        // whole reduction runs through the packed (vectorized) path.
        let params = TuneParams { tpb: 32, tw: 32, max_blocks: 16 };
        let (n, bw) = (192, 40);
        let mut rng = Xoshiro256::seed_from_u64(17);
        let base = random_banded::<f64>(n, bw, params.effective_tw(bw), &mut rng);
        let plan = LaunchPlan::for_problem(n, bw, &params);

        let mut reference = base.clone();
        SequentialBackend::new()
            .execute(&plan, &mut [reference.as_band_storage_mut()])
            .unwrap();

        for spec in [
            SimdSpec::scalar(),
            SimdSpec::with_contract(SimdIsa::Portable, false),
        ] {
            let mut vectored = base.clone();
            let backend = SimdBackend::with_spec(spec, 3);
            let exec = backend
                .execute(&plan, &mut [vectored.as_band_storage_mut()])
                .unwrap();
            assert_eq!(reference, vectored, "{spec:?}");
            assert_eq!(exec.aggregate.launches, plan.num_launches());
        }
    }

    #[test]
    fn backend_identity_is_stable_but_isa_is_surfaced() {
        let backend = SimdBackend::with_spec(SimdSpec::with_contract(SimdIsa::Portable, true), 1);
        assert_eq!(backend.kind(), BackendKind::Simd);
        assert_eq!(backend.name(), "simd");
        assert_eq!(backend.isa_name(), "portable");
        assert!(backend.spec().contract);
        assert!(!backend.requires_artifacts());
        assert_eq!(backend.cost_model(), BackendCostModel::simd());
    }

    #[test]
    fn borrowed_pool_matches_owned_pool_bitwise() {
        let params = TuneParams { tpb: 32, tw: 24, max_blocks: 8 };
        let (n, bw) = (128, 28);
        let mut rng = Xoshiro256::seed_from_u64(29);
        let base = random_banded::<f64>(n, bw, params.effective_tw(bw), &mut rng);
        let plan = LaunchPlan::for_problem(n, bw, &params);

        let mut owned = base.clone();
        SimdBackend::new(2).execute(&plan, &mut [owned.as_band_storage_mut()]).unwrap();

        let pool = ThreadPool::new(2);
        let mut borrowed = base.clone();
        SimdBackend::borrowing(&pool)
            .execute(&plan, &mut [borrowed.as_band_storage_mut()])
            .unwrap();

        assert_eq!(owned, borrowed);
    }
}
