//! Execution backends — everything that can run a
//! [`LaunchPlan`](crate::plan::LaunchPlan).
//!
//! The paper's claim is that one memory-aware bulge-chasing formulation
//! runs "hardware-agnostic and data-precision-aware" across devices. The
//! crate encodes that as a single obligation: a backend **executes a
//! `LaunchPlan` against banded storage** — nothing else. Scheduling,
//! batching (plan merge), and cost modeling all happen *on the plan*,
//! before any backend is involved, so adding a device means implementing
//! one trait, not re-deriving a schedule.
//!
//! Four executors ship with the crate (see `docs/backends.md` for the
//! full contract a new backend must uphold):
//!
//! - [`SequentialBackend`] — inline, one task at a time, in plan order.
//!   The reference every other backend must match bitwise.
//! - [`ThreadpoolBackend`] — one pinned pool dispatch + one barrier per
//!   launch, sticky column-window affinity, persistent per-slot
//!   workspaces (the CPU analog of the paper's GPU execution model).
//! - [`SimdBackend`] — the threadpool loop with packed-path tasks routed
//!   through the explicit vector kernels of [`crate::simd`] (runtime ISA
//!   detection, `BSVD_SIMD` knob, scalar fallback); bitwise-identical to
//!   the reference with contraction off.
//! - [`PjrtBackend`] — walks the plan launch by launch through
//!   AOT-compiled HLO artifacts on the PJRT client, holding one
//!   device-resident buffer *per plan problem* (so merged batch plans map
//!   onto multiple buffers and empty cycles are never launched).
//!
//! # Contract (summary)
//!
//! For `Backend::execute(plan, problems)`:
//!
//! 1. `problems[p]` is the storage of `plan.problems[p]`; the slice
//!    length must equal `plan.problems.len()`.
//! 2. Launches execute in plan order with a barrier between them; the
//!    tasks *within* one launch are pairwise element-disjoint and may run
//!    in any order or concurrently.
//! 3. Native (non-artifact) backends must produce **bitwise-identical**
//!    storage to [`SequentialBackend`] — property-tested in
//!    `rust/tests/plan_consistency.rs`.
//! 4. Per-problem metrics record one launch per plan slot of that
//!    problem, with the plan's own task counts and
//!    [`slot_bytes`](crate::plan::slot_bytes) traffic, so executed
//!    metrics equal simulated metrics by construction.
//!
//! # Examples
//!
//! Execute a plan through the reference backend:
//!
//! ```
//! use banded_svd::backend::{AsBandStorageMut, Backend, SequentialBackend};
//! use banded_svd::config::TuneParams;
//! use banded_svd::generate::random_banded;
//! use banded_svd::plan::LaunchPlan;
//! use banded_svd::util::rng::Xoshiro256;
//!
//! let params = TuneParams { tpb: 32, tw: 4, max_blocks: 16 };
//! let (n, bw) = (48, 6);
//! let mut rng = Xoshiro256::seed_from_u64(1);
//! let mut a = random_banded::<f64>(n, bw, params.effective_tw(bw), &mut rng);
//!
//! let plan = LaunchPlan::for_problem(n, bw, &params);
//! let backend = SequentialBackend::new();
//! let exec = backend.execute(&plan, &mut [a.as_band_storage_mut()]).unwrap();
//!
//! assert_eq!(exec.aggregate.launches, plan.num_launches());
//! assert_eq!(exec.aggregate.tasks, plan.total_tasks());
//! assert_eq!(a.max_off_band(1), 0.0); // fully bidiagonal
//! ```

pub mod pjrt;
mod sequential;
mod simd;
mod threadpool;

pub use pjrt::PjrtBackend;
pub use sequential::SequentialBackend;
pub use simd::SimdBackend;
pub use threadpool::ThreadpoolBackend;

use crate::banded::storage::Banded;
use crate::config::{BackendKind, TuneParams};
use crate::coordinator::metrics::LaunchMetrics;
use crate::error::{Error, Result};
use crate::plan::{LaunchPlan, ReflectorLog};
use crate::scalar::{Scalar, F16};
use crate::simulator::model::BackendCostModel;

/// A mutable, type-erased borrow of one problem's banded working storage
/// in one of the three supported precisions — what a backend executes a
/// plan against. Erasing the scalar type here (instead of making the
/// trait generic) keeps `dyn Backend` object-safe and lets one merged
/// plan span problems of mixed precision.
pub enum BandStorageMut<'a> {
    F64(&'a mut Banded<f64>),
    F32(&'a mut Banded<f32>),
    F16(&'a mut Banded<F16>),
}

impl BandStorageMut<'_> {
    /// Matrix dimension.
    pub fn n(&self) -> usize {
        match self {
            BandStorageMut::F64(a) => a.n(),
            BandStorageMut::F32(a) => a.n(),
            BandStorageMut::F16(a) => a.n(),
        }
    }

    /// Leading dimension of the banded storage.
    pub fn ld(&self) -> usize {
        match self {
            BandStorageMut::F64(a) => a.ld(),
            BandStorageMut::F32(a) => a.ld(),
            BandStorageMut::F16(a) => a.ld(),
        }
    }

    /// Representable superdiagonals.
    pub fn kd_super(&self) -> usize {
        match self {
            BandStorageMut::F64(a) => a.kd_super(),
            BandStorageMut::F32(a) => a.kd_super(),
            BandStorageMut::F16(a) => a.kd_super(),
        }
    }

    /// Element size in bytes (traffic accounting).
    pub fn element_bytes(&self) -> usize {
        match self {
            BandStorageMut::F64(_) => <f64 as Scalar>::BYTES,
            BandStorageMut::F32(_) => <f32 as Scalar>::BYTES,
            BandStorageMut::F16(_) => <F16 as Scalar>::BYTES,
        }
    }

    /// Paper-style precision label ("fp64" / "fp32" / "fp16").
    pub fn precision(&self) -> &'static str {
        match self {
            BandStorageMut::F64(_) => <f64 as Scalar>::NAME,
            BandStorageMut::F32(_) => <f32 as Scalar>::NAME,
            BandStorageMut::F16(_) => <F16 as Scalar>::NAME,
        }
    }

    /// Validate the storage for a bandwidth-`bw`, tilewidth-`tw` run.
    pub fn check_reduction_storage(&self, bw: usize, tw: usize) -> Result<()> {
        match self {
            BandStorageMut::F64(a) => a.check_reduction_storage(bw, tw),
            BandStorageMut::F32(a) => a.check_reduction_storage(bw, tw),
            BandStorageMut::F16(a) => a.check_reduction_storage(bw, tw),
        }
    }

    /// Flat f32 copy in the artifact layout (see
    /// [`Banded::to_f32_flat`]).
    pub fn to_f32_flat(&self) -> Vec<f32> {
        match self {
            BandStorageMut::F64(a) => a.to_f32_flat(),
            BandStorageMut::F32(a) => a.to_f32_flat(),
            BandStorageMut::F16(a) => a.to_f32_flat(),
        }
    }

    /// Overwrite from a flat f32 buffer (see [`Banded::from_f32_flat`]).
    pub fn from_f32_flat(&mut self, flat: &[f32]) {
        match self {
            BandStorageMut::F64(a) => a.from_f32_flat(flat),
            BandStorageMut::F32(a) => a.from_f32_flat(flat),
            BandStorageMut::F16(a) => a.from_f32_flat(flat),
        }
    }
}

/// Conversion into the type-erased [`BandStorageMut`] view — implemented
/// for the three concrete precisions so generic drivers
/// (`Coordinator::reduce_with`, the pipeline entry points) can hand any
/// supported matrix to a `dyn Backend`.
pub trait AsBandStorageMut {
    fn as_band_storage_mut(&mut self) -> BandStorageMut<'_>;
}

impl AsBandStorageMut for Banded<f64> {
    fn as_band_storage_mut(&mut self) -> BandStorageMut<'_> {
        BandStorageMut::F64(self)
    }
}

impl AsBandStorageMut for Banded<f32> {
    fn as_band_storage_mut(&mut self) -> BandStorageMut<'_> {
        BandStorageMut::F32(self)
    }
}

impl AsBandStorageMut for Banded<F16> {
    fn as_band_storage_mut(&mut self) -> BandStorageMut<'_> {
        BandStorageMut::F16(self)
    }
}

/// Outcome of executing a plan: per-problem launch accounting (index `p`
/// matches `plan.problems[p]`) plus the aggregate over shared launches.
/// For a single-problem plan the two agree launch by launch.
#[derive(Clone, Debug, Default)]
pub struct Execution {
    pub per_problem: Vec<LaunchMetrics>,
    pub aggregate: LaunchMetrics,
}

/// An executor of [`LaunchPlan`]s — the one trait a new device target
/// implements. See the module docs for the execution contract and
/// `docs/backends.md` for the narrative version with invariants.
pub trait Backend {
    /// The selector this backend answers to.
    fn kind(&self) -> BackendKind;

    /// Human-readable name (defaults to the kind's canonical spelling).
    fn name(&self) -> &'static str {
        self.kind().name()
    }

    /// Execute every launch of `plan`, in plan order with a barrier
    /// between launches, against `problems` (`problems[p]` is the storage
    /// of `plan.problems[p]`). Storage is validated before any work; on
    /// error nothing is partially executed unless the error comes from
    /// the device mid-run.
    fn execute(
        &self,
        plan: &LaunchPlan,
        problems: &mut [BandStorageMut<'_>],
    ) -> Result<Execution>;

    /// Execute like [`Backend::execute`], additionally recording every
    /// bulge-chasing reflector into `log` (a [`ReflectorLog`] sized for
    /// this exact plan, see [`ReflectorLog::for_plan`]) — the capture
    /// side of singular-vector accumulation
    /// (`crate::pipeline::vectors`). Captured bits must be identical
    /// across backends, exactly like the band storage itself. Backends
    /// that cannot observe individual reflectors (the artifact-based
    /// PJRT executor) keep this default, a typed configuration error.
    fn execute_logged(
        &self,
        plan: &LaunchPlan,
        problems: &mut [BandStorageMut<'_>],
        log: &mut ReflectorLog,
    ) -> Result<Execution> {
        let _ = (plan, problems, log);
        Err(Error::Config(format!(
            "backend '{}' cannot record reflectors for singular vectors; \
             use a native backend (sequential/threadpool/simd)",
            self.name()
        )))
    }

    /// True when the backend needs pre-compiled artifacts (and therefore
    /// cannot run in a bare checkout). Native backends return `false`.
    fn requires_artifacts(&self) -> bool {
        false
    }

    /// Cost-model adjustments for this backend, consumed by
    /// [`crate::simulator::model::simulate_plan_for`] and
    /// [`crate::simulator::autotune_for`] so the autotuner tunes for the
    /// backend that will actually run.
    fn cost_model(&self) -> BackendCostModel {
        BackendCostModel::native()
    }
}

/// Validate that `problems` matches `plan` shape-for-shape — the common
/// prologue every backend runs before touching data.
pub(crate) fn check_problems(plan: &LaunchPlan, problems: &[BandStorageMut<'_>]) -> Result<()> {
    if plan.problems.len() != problems.len() {
        return Err(Error::Config(format!(
            "plan has {} problems but {} storages were supplied",
            plan.problems.len(),
            problems.len()
        )));
    }
    for (p, (shape, band)) in plan.problems.iter().zip(problems.iter()).enumerate() {
        if band.n() != shape.n {
            return Err(Error::Config(format!(
                "problem {p}: storage is {}×{} but the plan was lowered for n = {}",
                band.n(),
                band.n(),
                shape.n
            )));
        }
        band.check_reduction_storage(shape.bw, shape.tw)?;
    }
    Ok(())
}

/// Construct the backend registered under `kind`.
///
/// `threads` affects [`ThreadpoolBackend`] and [`SimdBackend`] (`0` =
/// all hardware threads); [`SimdBackend`] additionally resolves its
/// kernel spec from `BSVD_SIMD` / `BSVD_SIMD_CONTRACT` at construction.
/// [`BackendKind::Pjrt`] resolves artifacts from
/// [`crate::runtime::artifact_dir`] lazily at execute time, so
/// construction always succeeds; execution fails cleanly when artifacts
/// (or the `pjrt` feature) are missing. [`BackendKind::PjrtFused`] runs
/// whole-stage artifacts and is driven by
/// [`crate::coordinator::Coordinator::reduce_pjrt`] rather than a plan
/// executor, so it has no trait-object form.
pub fn for_kind(kind: BackendKind, threads: usize) -> Result<Box<dyn Backend>> {
    match kind {
        BackendKind::Sequential => Ok(Box::new(SequentialBackend::new())),
        BackendKind::Threadpool => Ok(Box::new(ThreadpoolBackend::new(threads))),
        BackendKind::Simd => Ok(Box::new(SimdBackend::new(threads))),
        BackendKind::Pjrt => Ok(Box::new(PjrtBackend::from_env())),
        BackendKind::PjrtFused => Err(Error::Config(
            "pjrt-fused executes whole-stage artifacts (one call per stage), not a \
             launch plan; use `Coordinator::reduce_pjrt` or the plain `pjrt` backend"
                .into(),
        )),
    }
}

/// The [`BackendCostModel`] the backend constructed for `kind` would
/// report, *without constructing it* — what the reduction service prices
/// admission with before any executor exists on the submitting thread
/// (the executor itself lives on the batcher worker). Kept in lockstep
/// with each backend's [`Backend::cost_model`] by the
/// `kind_cost_models_match_constructed_backends` test; rejects
/// [`BackendKind::PjrtFused`] for the same reason [`for_kind`] does.
pub fn cost_model_for(kind: BackendKind) -> Result<BackendCostModel> {
    match kind {
        BackendKind::Sequential | BackendKind::Threadpool => Ok(BackendCostModel::native()),
        BackendKind::Simd => Ok(BackendCostModel::simd()),
        BackendKind::Pjrt => Ok(BackendCostModel::pjrt()),
        BackendKind::PjrtFused => Err(Error::Config(
            "pjrt-fused executes whole-stage artifacts (one call per stage), not a \
             launch plan; use `Coordinator::reduce_pjrt` or the plain `pjrt` backend"
                .into(),
        )),
    }
}

/// Lower the plan for a bandwidth-`bw` problem under `params` and execute
/// it on `backend` — the single-problem driver shared by the coordinator
/// and the pipeline. Returns the executed plan alongside the execution so
/// callers can cross-check metrics against the IR.
pub fn execute_reduction<A: AsBandStorageMut + ?Sized>(
    backend: &dyn Backend,
    a: &mut A,
    bw: usize,
    params: &TuneParams,
) -> Result<(LaunchPlan, Execution)> {
    let mut band = a.as_band_storage_mut();
    let n = band.n();
    band.check_reduction_storage(bw, params.effective_tw(bw))?;
    let plan = LaunchPlan::for_problem(n, bw, params);
    let exec = backend.execute(&plan, std::slice::from_mut(&mut band))?;
    Ok((plan, exec))
}

/// [`execute_reduction`] with reflector capture: sizes a
/// [`ReflectorLog`] for the lowered plan, executes through
/// [`Backend::execute_logged`], and returns the filled log alongside
/// the plan — everything [`crate::pipeline::vectors`] needs to
/// accumulate U/Vᵀ panels.
pub fn execute_reduction_logged<A: AsBandStorageMut + ?Sized>(
    backend: &dyn Backend,
    a: &mut A,
    bw: usize,
    params: &TuneParams,
) -> Result<(LaunchPlan, Execution, ReflectorLog)> {
    let mut band = a.as_band_storage_mut();
    let n = band.n();
    band.check_reduction_storage(bw, params.effective_tw(bw))?;
    let plan = LaunchPlan::for_problem(n, bw, params);
    let mut log = ReflectorLog::for_plan(&plan);
    let exec = backend.execute_logged(&plan, std::slice::from_mut(&mut band), &mut log)?;
    Ok((plan, exec, log))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generate::random_banded;
    use crate::util::rng::Xoshiro256;

    fn params() -> TuneParams {
        TuneParams { tpb: 32, tw: 4, max_blocks: 12 }
    }

    #[test]
    fn registry_builds_every_plan_backend() {
        for kind in BackendKind::ALL {
            match for_kind(kind, 2) {
                Ok(b) => {
                    assert_eq!(b.kind(), kind);
                    assert_eq!(b.name(), kind.name());
                }
                Err(_) => assert_eq!(kind, BackendKind::PjrtFused),
            }
        }
    }

    #[test]
    fn kind_cost_models_match_constructed_backends() {
        for kind in BackendKind::ALL {
            match (cost_model_for(kind), for_kind(kind, 1)) {
                (Ok(model), Ok(backend)) => assert_eq!(model, backend.cost_model(), "{kind:?}"),
                (Err(_), Err(_)) => assert_eq!(kind, BackendKind::PjrtFused),
                (model, _) => panic!("{kind:?}: cost_model_for/for_kind disagree ({model:?})"),
            }
        }
    }

    #[test]
    fn native_backends_match_bitwise_through_the_trait() {
        let params = params();
        let (n, bw) = (56, 7);
        let mut rng = Xoshiro256::seed_from_u64(3);
        let base = random_banded::<f64>(n, bw, params.effective_tw(bw), &mut rng);

        let mut reference = base.clone();
        let seq = SequentialBackend::new();
        let (plan, exec_seq) =
            execute_reduction(&seq, &mut reference, bw, &params).unwrap();

        let mut pooled = base.clone();
        let tp = ThreadpoolBackend::new(3);
        let (_, exec_tp) = execute_reduction(&tp, &mut pooled, bw, &params).unwrap();

        assert_eq!(reference, pooled);
        assert_eq!(exec_seq.aggregate.launches, plan.num_launches());
        assert_eq!(exec_seq.aggregate.per_launch, exec_tp.aggregate.per_launch);
        assert_eq!(exec_seq.per_problem[0].bytes, exec_tp.per_problem[0].bytes);
        assert_eq!(reference.max_off_band(1), 0.0);
    }

    #[test]
    fn logged_execution_matches_plain_and_pjrt_declines() {
        let params = params();
        let (n, bw) = (48, 6);
        let mut rng = Xoshiro256::seed_from_u64(9);
        let base = random_banded::<f64>(n, bw, params.effective_tw(bw), &mut rng);

        let mut plain = base.clone();
        execute_reduction(&SequentialBackend::new(), &mut plain, bw, &params).unwrap();
        let mut logged = base.clone();
        let (plan, _, log) =
            execute_reduction_logged(&SequentialBackend::new(), &mut logged, bw, &params)
                .unwrap();
        assert_eq!(plain, logged, "capture changed the band");
        assert_eq!(log.tasks(0), plan.total_tasks());

        // A log sized for a different plan is rejected before any work.
        let other = LaunchPlan::for_problem(24, 3, &params);
        let mut wrong = ReflectorLog::for_plan(&other);
        let mut a = base.clone();
        let seq = SequentialBackend::new();
        assert!(seq
            .execute_logged(&plan, &mut [a.as_band_storage_mut()], &mut wrong)
            .is_err());

        // The artifact-based backend declines with a typed config error.
        let mut a = base.clone();
        let err = execute_reduction_logged(&PjrtBackend::from_env(), &mut a, bw, &params)
            .unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err:?}");
    }

    #[test]
    fn mismatched_problem_count_is_rejected() {
        let plan = LaunchPlan::for_problem(32, 4, &params());
        let seq = SequentialBackend::new();
        assert!(seq.execute(&plan, &mut []).is_err());
    }

    #[test]
    fn undersized_storage_is_rejected_by_every_native_backend() {
        let params = TuneParams { tpb: 32, tw: 8, max_blocks: 8 };
        for kind in [BackendKind::Sequential, BackendKind::Threadpool, BackendKind::Simd] {
            let backend = for_kind(kind, 1).unwrap();
            let mut bad = Banded::<f64>::zeros(32, 9, 1); // kd_sub 1 < tw 8
            assert!(
                execute_reduction(backend.as_ref(), &mut bad, 8, &params).is_err(),
                "{kind:?}"
            );
        }
    }

    #[test]
    fn band_storage_view_reports_shape_and_precision() {
        let mut a = Banded::<f32>::for_reduction(8, 3, 2);
        let view = a.as_band_storage_mut();
        assert_eq!(view.n(), 8);
        assert_eq!(view.ld(), 8); // (3+2) + 2 + 1
        assert_eq!(view.kd_super(), 5);
        assert_eq!(view.element_bytes(), 4);
        assert_eq!(view.precision(), "fp32");
    }
}
