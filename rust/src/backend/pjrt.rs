//! The PJRT plan executor — AOT-compiled HLO artifacts driven by the
//! launch-plan IR.
//!
//! This is the backend the IR was built for: the schedule is lowered
//! once, and this executor walks the resulting [`LaunchPlan`] **launch
//! by launch**, issuing one PJRT `execute` per plan slot. Three
//! memory-aware properties fall out of consuming the plan instead of
//! re-deriving a schedule from the manifest (which is what the legacy
//! `reduce_per_cycle` loop did):
//!
//! - **Device-resident chaining, one buffer per problem.** Each plan
//!   problem's banded storage is uploaded once into its own device
//!   buffer and chained through every launch (`execute_b`); only the
//!   4-byte cycle index crosses the host boundary per call. A *merged*
//!   batch plan therefore maps onto multiple co-resident device buffers —
//!   the multi-buffer execution the batch path was waiting on.
//! - **Empty cycles are never launched.** The plan only lowers non-empty
//!   launches, so ramp-up/ramp-down cycles with zero ready tasks cost
//!   nothing here, while the manifest-driven loop paid a full PJRT call
//!   for each.
//! - **Footprint-accounted traffic.** Per-launch metrics carry the same
//!   plan-derived [`slot_bytes`] the simulator costs, and
//!   [`LaunchPlan::launch_footprint_elems`] bounds what a tile-payload
//!   artifact would need to stage per launch. This backend's own cost
//!   profile ([`BackendCostModel::pjrt`]) charges no staging — buffers
//!   are device-resident — but the hypothetical
//!   [`BackendCostModel::pjrt_tile_streaming`] profile prices exactly
//!   that footprint, which is how to evaluate tile-payload artifacts
//!   before compiling any (see `docs/performance-model.md`).
//!
//! Artifacts execute in f32 regardless of the in-memory precision
//! (storage converts on upload/download); without the `pjrt` feature the
//! stub client makes every execution fail with a clear message before
//! any work is attempted.

use crate::backend::{check_problems, Backend, BandStorageMut, Execution};
use crate::bulge::cycle::stage_uses_packed;
use crate::config::BackendKind;
use crate::coordinator::metrics::LaunchMetrics;
use crate::error::{Error, Result};
use crate::obs::{calibrate, trace};
use crate::plan::{slot_bytes, LaunchPlan};
use crate::runtime::{artifact_dir, PjrtEngine};
use crate::simulator::model::BackendCostModel;
use std::cell::RefCell;
use std::path::PathBuf;
use std::time::{Duration, Instant};

#[cfg(not(feature = "pjrt"))]
use crate::runtime::stub as xla;

/// Executes [`LaunchPlan`]s through pre-compiled PJRT artifacts, loading
/// (and caching) one [`PjrtEngine`] per distinct `(n, bw, tw)` variant a
/// plan's problems require. See the module docs for the execution model.
pub struct PjrtBackend {
    dir: PathBuf,
    engines: RefCell<Vec<PjrtEngine>>,
}

impl PjrtBackend {
    /// Backend resolving artifacts from `dir`.
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        Self { dir: dir.into(), engines: RefCell::new(Vec::new()) }
    }

    /// Backend resolving artifacts from [`artifact_dir`] (the
    /// `BSVD_ARTIFACTS` environment knob). Construction is infallible;
    /// missing artifacts or a stub build surface as a clean error at
    /// execute time.
    pub fn from_env() -> Self {
        Self::new(artifact_dir())
    }

    /// Backend seeded with an already-loaded engine (further variants
    /// load from the engine's own artifact directory).
    pub fn with_engine(engine: PjrtEngine) -> Self {
        let dir = engine.manifest().dir.clone();
        Self { dir, engines: RefCell::new(vec![engine]) }
    }
}

impl Backend for PjrtBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Pjrt
    }

    fn requires_artifacts(&self) -> bool {
        true
    }

    fn cost_model(&self) -> BackendCostModel {
        BackendCostModel::pjrt()
    }

    fn execute(
        &self,
        plan: &LaunchPlan,
        problems: &mut [BandStorageMut<'_>],
    ) -> Result<Execution> {
        check_problems(plan, problems)?;
        let mut engines = self.engines.borrow_mut();
        let mut engine_of: Vec<usize> = Vec::with_capacity(plan.problems.len());
        for shape in &plan.problems {
            let key = (shape.n, shape.bw, shape.tw);
            let idx = match engines.iter().position(|e| {
                let m = e.manifest();
                (m.n, m.bw, m.tw) == key
            }) {
                Some(i) => i,
                None => {
                    engines.push(PjrtEngine::load(&self.dir, shape.n, shape.bw, shape.tw)?);
                    engines.len() - 1
                }
            };
            engine_of.push(idx);
        }
        execute_plan_on_engines(&engines, &engine_of, plan, problems)
    }
}

/// Walk `plan` launch by launch through a single pre-loaded engine (all
/// problems must match its variant) — the path
/// [`crate::coordinator::Coordinator::reduce_pjrt`] drives.
pub(crate) fn execute_plan_on_engine(
    engine: &PjrtEngine,
    plan: &LaunchPlan,
    problems: &mut [BandStorageMut<'_>],
) -> Result<Execution> {
    check_problems(plan, problems)?;
    let engine_of = vec![0usize; plan.problems.len()];
    execute_plan_on_engines(std::slice::from_ref(engine), &engine_of, plan, problems)
}

/// The shared launch walk: `engine_of[p]` names the engine executing plan
/// problem `p`. One device-resident buffer per problem, launches in plan
/// order, per-slot chaining, single download at the end.
fn execute_plan_on_engines(
    engines: &[PjrtEngine],
    engine_of: &[usize],
    plan: &LaunchPlan,
    problems: &mut [BandStorageMut<'_>],
) -> Result<Execution> {
    // Validate every problem against its artifact variant before any
    // upload: the artifact's schedule (stage indices, cycle counts) must
    // be the schedule the plan was lowered from, and the storage layout
    // must match what the artifact was compiled for.
    for (p, shape) in plan.problems.iter().enumerate() {
        let m = engines[engine_of[p]].manifest();
        if (m.n, m.bw, m.tw) != (shape.n, shape.bw, shape.tw) {
            return Err(Error::Config(format!(
                "problem {p}: plan was lowered for (n={}, bw={}, tw={}) but the artifact \
                 variant is (n={}, bw={}, tw={})",
                shape.n, shape.bw, shape.tw, m.n, m.bw, m.tw
            )));
        }
        if problems[p].ld() != m.ld || problems[p].kd_super() != m.kd_super {
            return Err(Error::Config(format!(
                "problem {p}: storage layout (ld={}, kd_super={}) does not match artifact \
                 layout (ld={}, kd_super={})",
                problems[p].ld(),
                problems[p].kd_super(),
                m.ld,
                m.kd_super
            )));
        }
    }

    // Upload once: one device-resident buffer per plan problem (merged
    // batch plans co-reside as multiple buffers).
    let mut bufs: Vec<Option<xla::PjRtBuffer>> = Vec::with_capacity(problems.len());
    for (p, band) in problems.iter().enumerate() {
        let flat = band.to_f32_flat();
        bufs.push(Some(engines[engine_of[p]].upload_flat(&flat)?));
    }

    // Artifacts execute in f32 regardless of the in-memory precision.
    let es = 4usize;
    let capacity = plan.capacity;
    let mut per_problem = vec![LaunchMetrics::default(); problems.len()];
    let mut aggregate = LaunchMetrics::default();
    // One PJRT `execute` per slot means per-slot timing is exact, like
    // the sequential backend (the device call is synchronous here).
    let observing = crate::obs::observing();
    for li in 0..plan.num_launches() {
        let mut launch_tasks = 0usize;
        let mut launch_bytes = 0u64;
        let mut launch_dur = Duration::ZERO;
        for slot in plan.launch(li) {
            let p = slot.problem as usize;
            let stage = plan.slot_stage(slot);
            let count = slot.count as usize;
            let bytes = slot_bytes(stage, count, es);
            per_problem[p].record_launch(count, capacity, bytes);
            let buf = bufs[p].take().expect("device buffer live between launches");
            let t_slot = observing.then(Instant::now);
            bufs[p] = Some(engines[engine_of[p]].execute_cycle_step(
                buf,
                slot.stage as usize,
                slot.t as usize,
            )?);
            if let Some(t0) = t_slot {
                let dur = t0.elapsed();
                launch_dur += dur;
                let packed = stage_uses_packed(stage);
                let ns = dur.as_nanos() as f64;
                calibrate::record_sample(stage.b, stage.d, es, packed, count as u64, ns);
            }
            launch_tasks += count;
            launch_bytes += bytes;
        }
        aggregate.record_launch(launch_tasks, capacity, launch_bytes);
        if observing {
            trace::record_launch(li, launch_tasks, launch_dur);
        }
    }

    // Single download per problem, written back at the storage precision.
    let mut flat: Vec<f32> = Vec::new();
    for (p, band) in problems.iter_mut().enumerate() {
        let buf = bufs[p].take().expect("device buffer live after final launch");
        engines[engine_of[p]].download_flat(&buf, &mut flat)?;
        band.from_f32_flat(&flat);
    }
    Ok(Execution { per_problem, aggregate })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::AsBandStorageMut;
    use crate::banded::storage::Banded;
    use crate::config::TuneParams;

    #[test]
    fn missing_artifacts_fail_cleanly_before_any_work() {
        // A variant that certainly has no artifacts: the error must
        // surface from execute(), leaving the storage untouched.
        let params = TuneParams { tpb: 32, tw: 4, max_blocks: 8 };
        let backend = PjrtBackend::new("/nonexistent-artifact-dir");
        assert!(backend.requires_artifacts());
        let mut a = Banded::<f32>::for_reduction(32, 6, 4);
        let before = a.clone();
        let plan = LaunchPlan::for_problem(32, 6, &params);
        let err = backend
            .execute(&plan, &mut [a.as_band_storage_mut()])
            .expect_err("no artifacts available");
        let msg = err.to_string();
        assert!(
            msg.contains("artifact") || msg.contains("pjrt") || msg.contains("PJRT"),
            "{msg}"
        );
        assert_eq!(a, before);
    }

    #[test]
    fn cost_model_is_the_pjrt_profile() {
        let backend = PjrtBackend::from_env();
        let cm = backend.cost_model();
        assert_eq!(cm.element_size, Some(4));
        assert!(cm.dispatch_overhead_s > 0.0);
    }
}
