//! The inline reference executor: plan order, one task at a time.

use crate::backend::{check_problems, Backend, BandStorageMut, Execution};
use crate::batch::engine::{Runner, SlotScratch};
use crate::bulge::cycle::stage_uses_packed;
use crate::bulge::schedule::CycleTask;
use crate::config::BackendKind;
use crate::coordinator::metrics::LaunchMetrics;
use crate::error::Result;
use crate::obs::{calibrate, trace};
use crate::plan::{slot_bytes, LaunchPlan, ReflectorLog};
use crate::simd::SimdSpec;
use std::time::{Duration, Instant};

/// Executes a [`LaunchPlan`] inline on the calling thread, in plan order,
/// one task at a time — the schedule-order oracle. Every other backend's
/// storage must match this one bitwise on the same plan (the per-task
/// float-op sequence is identical; only concurrency differs, and tasks
/// within a launch are element-disjoint).
///
/// Also the cheapest backend for tiny problems: no pool threads, no
/// dispatch overhead, one lazily grown workspace per precision.
#[derive(Clone, Copy, Debug, Default)]
pub struct SequentialBackend;

impl SequentialBackend {
    pub fn new() -> Self {
        Self
    }

    fn run(
        &self,
        plan: &LaunchPlan,
        problems: &mut [BandStorageMut<'_>],
        mut log: Option<&mut ReflectorLog>,
    ) -> Result<Execution> {
        check_problems(plan, problems)?;
        let capacity = plan.capacity;
        let mut runners: Vec<Runner<'_>> = problems
            .iter_mut()
            .zip(plan.problems.iter())
            .enumerate()
            .map(|(p, (band, shape))| {
                let view = log.as_deref_mut().map(|l| l.view(p));
                Runner::for_band_logged(band, shape, SimdSpec::scalar(), view)
            })
            .collect::<Result<_>>()?;
        let mut scratch = SlotScratch::new();
        let mut tasks: Vec<CycleTask> = Vec::new();
        let mut ordinals: Vec<usize> = vec![0; runners.len()];
        let mut aggregate = LaunchMetrics::default();
        // One task at a time means per-slot timing is exact here (no
        // proportional split): this backend produces the cleanest
        // calibration samples per kernel class.
        let observing = crate::obs::observing();
        for li in 0..plan.num_launches() {
            let mut launch_tasks = 0usize;
            let mut launch_bytes = 0u64;
            let mut launch_dur = Duration::ZERO;
            for slot in plan.launch(li) {
                let p = slot.problem as usize;
                let shape = &plan.problems[p];
                let stage = &shape.stages[slot.stage as usize];
                let count = slot.count as usize;
                let es = runners[p].element_bytes();
                let bytes = slot_bytes(stage, count, es);
                runners[p].metrics.record_launch(count, capacity, bytes);
                tasks.clear();
                stage.tasks_at_into(shape.n, slot.t as usize, &mut tasks);
                debug_assert_eq!(tasks.len(), count);
                let base = ordinals[p];
                let t_slot = observing.then(Instant::now);
                for (i, task) in tasks.iter().enumerate() {
                    // SAFETY: problems are exclusively borrowed for the
                    // whole call and tasks execute strictly one at a
                    // time — no concurrent access exists at all.
                    unsafe {
                        runners[p].exec_task(slot.stage as usize, task, base + i, &mut scratch)
                    };
                }
                if let Some(t0) = t_slot {
                    let dur = t0.elapsed();
                    launch_dur += dur;
                    let packed = stage_uses_packed(stage);
                    let ns = dur.as_nanos() as f64;
                    calibrate::record_sample(stage.b, stage.d, es, packed, count as u64, ns);
                }
                ordinals[p] = base + count;
                launch_tasks += count;
                launch_bytes += bytes;
            }
            aggregate.record_launch(launch_tasks, capacity, launch_bytes);
            if observing {
                trace::record_launch(li, launch_tasks, launch_dur);
            }
        }
        Ok(Execution {
            per_problem: runners.iter().map(|r| r.metrics.clone()).collect(),
            aggregate,
        })
    }
}

impl Backend for SequentialBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Sequential
    }

    fn execute(
        &self,
        plan: &LaunchPlan,
        problems: &mut [BandStorageMut<'_>],
    ) -> Result<Execution> {
        self.run(plan, problems, None)
    }

    fn execute_logged(
        &self,
        plan: &LaunchPlan,
        problems: &mut [BandStorageMut<'_>],
        log: &mut ReflectorLog,
    ) -> Result<Execution> {
        log.check_plan(plan)?;
        self.run(plan, problems, Some(log))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::AsBandStorageMut;
    use crate::config::{PackingPolicy, TuneParams};
    use crate::generate::random_banded;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn executes_merged_plans_with_per_problem_metrics() {
        let params = TuneParams { tpb: 32, tw: 3, max_blocks: 10 };
        let mut rng = Xoshiro256::seed_from_u64(17);
        let shapes = [(40usize, 5usize), (32, 4)];
        let mut mats: Vec<_> = shapes
            .iter()
            .map(|&(n, bw)| random_banded::<f64>(n, bw, params.effective_tw(bw), &mut rng))
            .collect();
        let parts: Vec<LaunchPlan> = shapes
            .iter()
            .map(|&(n, bw)| LaunchPlan::for_problem(n, bw, &params))
            .collect();
        let merged = LaunchPlan::merge(&parts, 10, PackingPolicy::RoundRobin, 4);

        let (a, b) = mats.split_at_mut(1);
        let mut bands = [a[0].as_band_storage_mut(), b[0].as_band_storage_mut()];
        let exec = SequentialBackend::new().execute(&merged, &mut bands).unwrap();
        drop(bands);

        assert_eq!(exec.per_problem.len(), 2);
        assert_eq!(exec.aggregate.launches, merged.num_launches());
        for ((part, m), mat) in parts.iter().zip(&exec.per_problem).zip(&mats) {
            assert_eq!(m.launches, part.num_launches());
            assert_eq!(m.tasks, part.total_tasks());
            assert_eq!(mat.max_off_band(1), 0.0);
        }
    }
}
